"""Fleet-layer tests: the vmapped whole-fleet fit must match N sequential
single-stream fits (params + RMSE parity), a one-stream fleet must stay
byte-identical to the single-stream executors, bus multiplexing must keep
per-stream topics/state separate under one deployment with exactly one
train dispatch per window, and drift-gated retraining must skip stationary
streams while drifting streams keep retraining."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (
    FleetStages,
    PipelineStages,
    lstm_fleet_forecaster,
    lstm_forecaster,
    pretrain_batch_model,
)
from repro.core.drift import DriftGate
from repro.runtime import (
    CostModel,
    FleetBusExecutor,
    InProcessExecutor,
    InProcessFleetExecutor,
    edge_centric,
    edge_cloud_integrated,
    fleet_key_chains,
    paper_topology,
)
from repro.runtime.modules import T_MODEL, T_STREAM
from repro.streams.sources import fleet_windowed_streams
from repro.training.compiled import (
    CompiledForecaster,
    FleetForecaster,
    bucket_streams,
)

N_WINDOWS = 4
RPW = 150
N_STREAMS = 3
EPOCHS = 6


@pytest.fixture(scope="module")
def cfg():
    return get_config("lstm-paper")


@pytest.fixture(scope="module")
def fleet_setup(cfg):
    """Three correlated turbines (stationary / gradual / abrupt), per-stream
    scalers, one shared batch model."""
    streams, hist0 = fleet_windowed_streams(
        N_STREAMS, N_WINDOWS, RPW, ["none", "gradual", "abrupt"],
        seed=0, hist_len=1200, alphas=np.full(5, 1.5e-3))
    fc_batch = lstm_forecaster(cfg, epochs=4, batch_size=256)
    bp, _ = pretrain_batch_model(fc_batch, hist0, jax.random.PRNGKey(0))
    return streams, bp


def _fleet_stages(cfg, mode="dynamic"):
    ff = lstm_fleet_forecaster(cfg, epochs=EPOCHS, batch_size=64)
    return FleetStages.build(ff, mode=mode), ff


# ---------------------------------------------------------------------------
# vmapped fleet fit vs sequential single-stream fits
# ---------------------------------------------------------------------------


def _window(n, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, 5, 5)).astype(np.float32)
    y = x[:, :, 0].mean(axis=1, keepdims=True).astype(np.float32)
    return {"x": x, "y": y}


def test_fleet_fit_matches_sequential_params_and_rmse(cfg):
    """One vmapped dispatch == N sequential CompiledForecaster fits, to
    vmap-batching tolerance, for a non-power-of-two fleet (stream padding
    in play)."""
    from repro.models import get_model

    model = get_model(cfg)
    S = 5  # buckets to 8: three padded stream slots
    datas = [_window(150, seed=i) for i in range(S)]
    keys = [jax.random.fold_in(jax.random.PRNGKey(1), i) for i in range(S)]

    ff = FleetForecaster(model, epochs=4, batch_size=64,
                         predict_fn=None)
    fleet_params, wall = ff.train_fleet(datas, keys)
    assert wall > 0
    assert ff.train_dispatches == 1
    assert ff.trace_counts() == {(8, 256): 1}

    for i in range(S):
        fc = CompiledForecaster(model, epochs=4, batch_size=64)
        seq_params, _ = fc.train(datas[i], None, keys[i])
        for a, b in zip(jax.tree_util.tree_leaves(seq_params),
                        jax.tree_util.tree_leaves(fleet_params[i])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-5, rtol=1e-4)
        # RMSE parity on the window itself
        loss_seq, _ = model.loss_fn(
            seq_params, {k: jax.numpy.asarray(v) for k, v in datas[i].items()})
        loss_fleet, _ = model.loss_fn(
            fleet_params[i],
            {k: jax.numpy.asarray(v) for k, v in datas[i].items()})
        assert float(loss_fleet) == pytest.approx(float(loss_seq), rel=1e-3,
                                                  abs=1e-6)

    # second window, same shapes: zero new traces, one more dispatch
    ff.train_fleet([_window(150, seed=100 + i) for i in range(S)], keys)
    assert ff.train_dispatches == 2
    assert ff.trace_counts() == {(8, 256): 1}


def test_fleet_fit_single_stream_delegates_byte_identical(cfg):
    """A one-stream fleet fit must go through the wrapped single-stream
    trainer — bitwise-identical params, no vmapped executable."""
    from repro.models import get_model

    model = get_model(cfg)
    data = _window(150)
    key = jax.random.PRNGKey(3)
    ff = FleetForecaster(model, epochs=3, batch_size=64)
    (fleet_p,), _ = ff.train_fleet([data], [key])
    fc = CompiledForecaster(model, epochs=3, batch_size=64)
    seq_p, _ = fc.train(data, None, key)
    for a, b in zip(jax.tree_util.tree_leaves(seq_p),
                    jax.tree_util.tree_leaves(fleet_p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ff.trace_counts() == {}  # no fleet executable was ever built
    assert ff.single.retrace_count == 1


def test_resolve_fleet_params_shared_per_stream_and_partial():
    from repro.core import resolve_fleet_params

    ids = ["t00", "t01"]
    shared = {"lstm": {"kernel": np.zeros(3)}}  # a params tree, not per-stream
    out = resolve_fleet_params(shared, ids)
    assert out["t00"] is shared and out["t01"] is shared
    per = {"t00": {"a": 1}, "t01": {"a": 2}, "t02": {"a": 3}}
    out = resolve_fleet_params(per, ids)
    assert out == {"t00": {"a": 1}, "t01": {"a": 2}}
    with pytest.raises(ValueError, match="missing streams.*t01"):
        resolve_fleet_params({"t00": {"a": 1}}, ids)


def test_bucket_streams():
    assert bucket_streams(1) == 1
    assert bucket_streams(2) == 2
    assert bucket_streams(3) == 4
    assert bucket_streams(8) == 8
    assert bucket_streams(9) == 16
    with pytest.raises(ValueError):
        bucket_streams(0)


def test_bucket_streams_beyond_pow2_of_8():
    """The thousand-stream regime: buckets keep doubling past 8, so 1k+
    streams land in a handful of executables instead of thrashing the
    trace cache."""
    assert bucket_streams(100) == 128
    assert bucket_streams(512) == 512
    assert bucket_streams(1000) == 1024
    assert bucket_streams(1024) == 1024
    assert bucket_streams(1025) == 2048
    # every fleet size up to 1024 shares O(log S) buckets
    assert len({bucket_streams(s) for s in range(1, 1025)}) == 11


def test_fleet_thousand_streams_padded_slots_no_leak(cfg):
    """S=1000 at tiny shapes buckets to 1024 — 24 padded stream slots in
    play — and must still (a) fit in ONE dispatch through one executable,
    and (b) reproduce the unsharded sequential fit per sampled stream (the
    padded slots' zero-masked work never leaks into real streams)."""
    from repro.models import get_model
    from repro.runtime import fleet_key_chains

    model = get_model(cfg)
    S = 1000
    ids = [f"s{i:04d}" for i in range(S)]
    datas = [_window(8, seed=i) for i in range(S)]
    chains = fleet_key_chains(jax.random.PRNGKey(11), ids, 1)
    keys = [chains[sid][0] for sid in ids]

    ff = FleetForecaster(model, epochs=1, batch_size=8, predict_fn=None)
    params, _ = ff.train_fleet(datas, keys)
    assert ff.train_dispatches == 1
    assert ff.trace_counts() == {(1024, 8): 1}

    for i in (0, S // 2, S - 1):
        fc = CompiledForecaster(model, epochs=1, batch_size=8)
        seq_p, _ = fc.train(datas[i], None, keys[i])
        for a, b in zip(jax.tree_util.tree_leaves(seq_p),
                        jax.tree_util.tree_leaves(params[i])):
            assert float(np.max(np.abs(np.asarray(a) - np.asarray(b)))) \
                <= 1e-6


_SCRIPT_NON_POW2_DEVICES = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
import jax, numpy as np
from repro.configs import get_config
from repro.core import lstm_fleet_forecaster, lstm_forecaster
from repro.runtime import fleet_key_chains
from repro.training.compiled import bucket_streams, stream_mesh_devices

assert jax.device_count() == 6, jax.device_count()
S = 5  # buckets to 8; 8 does not divide 6 devices -> pow2-floor mesh of 4
assert len(stream_mesh_devices(bucket_streams(S))) == 4
cfg = get_config("lstm-paper")
ids = [f"s{i}" for i in range(S)]
chains = fleet_key_chains(jax.random.PRNGKey(5), ids, 1)

def window(i):
    rng = np.random.default_rng(100 + i)
    x = rng.normal(0, 1, (16, 5, 5)).astype(np.float32)
    y = x[:, :, 0].mean(axis=1, keepdims=True).astype(np.float32)
    return {"x": x, "y": y}

datas = [window(i) for i in range(S)]
keys = [chains[s][0] for s in ids]
ff = lstm_fleet_forecaster(cfg, epochs=2, batch_size=16)
params, _ = ff.train_fleet(datas, keys)
assert ff.train_dispatches == 1, ff.train_dispatches
worst = 0.0
for i in (0, S - 1):
    fc = lstm_forecaster(cfg, epochs=2, batch_size=16)
    sp, _ = fc.train(datas[i], None, keys[i])
    for a, b in zip(jax.tree_util.tree_leaves(sp),
                    jax.tree_util.tree_leaves(params[i])):
        worst = max(worst, float(np.max(np.abs(
            np.asarray(a) - np.asarray(b)))))
assert worst <= 1e-6, worst
print("OK", worst)
"""


def test_fleet_mesh_non_pow2_device_count():
    """6 forced host devices (a bucket of 8 cannot divide them): the mesh
    must fall back to the pow2 floor (4) instead of crashing or silently
    unsharding, and the sharded fit must match the unsharded sequential
    path."""
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    res = subprocess.run([sys.executable, "-c", _SCRIPT_NON_POW2_DEVICES],
                         env=env, capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    assert "OK" in res.stdout


# ---------------------------------------------------------------------------
# fleet executors: parity with the single-stream loop
# ---------------------------------------------------------------------------


def test_inprocess_fleet_matches_sequential_runs(fleet_setup, cfg):
    """Ungated fleet run == N sequential InProcessExecutor runs with the
    same per-stream root keys, to vmap tolerance; one dispatch per window."""
    streams, bp = fleet_setup
    stages, ff = _fleet_stages(cfg)
    key = jax.random.PRNGKey(1)
    res = InProcessFleetExecutor(stages).run(streams, bp, key)
    assert res.train_dispatches == N_WINDOWS
    assert res.skipped_retrains() == 0

    for i, sid in enumerate(streams):
        fc = lstm_forecaster(cfg, epochs=EPOCHS, batch_size=64)
        seq = InProcessExecutor(PipelineStages.build(fc, mode="dynamic")).run(
            streams[sid], bp, jax.random.fold_in(key, i))
        fleet_recs = res.results[sid].records
        assert len(seq.records) == len(fleet_recs) == N_WINDOWS - 1
        for a, b in zip(seq.records, fleet_recs):
            assert a.window == b.window
            assert a.rmse_batch == pytest.approx(b.rmse_batch, abs=1e-6)
            assert a.rmse_speed == pytest.approx(b.rmse_speed, abs=1e-4)
            assert a.rmse_hybrid == pytest.approx(b.rmse_hybrid, abs=1e-4)
            assert a.w_speed == pytest.approx(b.w_speed, abs=1e-3)


def test_single_stream_fleet_byte_identical_to_inprocess(fleet_setup, cfg):
    """The fleet loop over ONE stream reproduces InProcessExecutor records
    exactly: the single-stream path through the fleet layer is the
    pre-fleet path."""
    streams, bp = fleet_setup
    sid = next(iter(streams))
    root = jax.random.PRNGKey(7)
    stages, _ = _fleet_stages(cfg)
    res = InProcessFleetExecutor(stages).run({sid: streams[sid]}, bp,
                                             {sid: root})
    fc = lstm_forecaster(cfg, epochs=EPOCHS, batch_size=64)
    seq = InProcessExecutor(PipelineStages.build(fc, mode="dynamic")).run(
        streams[sid], bp, root)
    assert len(seq.records) == len(res.results[sid].records)
    for a, b in zip(seq.records, res.results[sid].records):
        assert a.window == b.window
        assert a.rmse_batch == b.rmse_batch
        assert a.rmse_speed == b.rmse_speed
        assert a.rmse_hybrid == b.rmse_hybrid
        assert a.w_speed == b.w_speed and a.w_batch == b.w_batch


def test_fleet_key_chains_match_single_stream_derivation():
    key = jax.random.PRNGKey(5)
    ids = ["t00", "t01"]
    chains = fleet_key_chains(key, ids, 3)
    from repro.core import split_chain

    for i, sid in enumerate(ids):
        expect = split_chain(jax.random.fold_in(key, i), 3)
        for a, b in zip(expect, chains[sid]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # explicit per-stream roots pass through
    roots = {sid: jax.random.fold_in(key, 100 + i)
             for i, sid in enumerate(ids)}
    chains2 = fleet_key_chains(roots, ids, 2)
    for sid in ids:
        expect = split_chain(roots[sid], 2)
        for a, b in zip(expect, chains2[sid]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# fleet under the bus: multiplexed topics, one deployment
# ---------------------------------------------------------------------------


def test_fleet_bus_matches_inprocess_fleet(fleet_setup, cfg):
    """Same fleet + same keys under the topic bus (integrated deployment)
    produce the in-process fleet's per-stream accuracy, and the fleet
    trains in one dispatch per window."""
    streams, bp = fleet_setup
    key = jax.random.PRNGKey(1)
    stages_a, _ = _fleet_stages(cfg)
    sync = InProcessFleetExecutor(stages_a).run(streams, bp, key)
    stages_b, _ = _fleet_stages(cfg)
    ex = FleetBusExecutor(stages_b, edge_cloud_integrated(),
                          paper_topology(), CostModel(ingest_s=0.5))
    bus = ex.run(streams, bp, key)
    assert bus.train_dispatches == N_WINDOWS
    for sid in streams:
        assert len(bus.results[sid].records) == N_WINDOWS - 1
        for a, b in zip(sync.results[sid].records, bus.results[sid].records):
            assert a.window == b.window
            assert a.rmse_batch == pytest.approx(b.rmse_batch, abs=1e-12)
            assert a.rmse_speed == pytest.approx(b.rmse_speed, abs=1e-12)
            assert a.rmse_hybrid == pytest.approx(b.rmse_hybrid, abs=1e-12)
        # per-stream e2e latency recorded for every inference window
        assert set(bus.e2e_s[sid]) == set(range(1, N_WINDOWS))


def test_fleet_bus_per_stream_topics_and_models(fleet_setup, cfg):
    """Messages are multiplexed per stream (stream/window/<sid>), and each
    stream's model publishes on its own model/latest/<sid> topic."""
    streams, bp = fleet_setup
    stages, _ = _fleet_stages(cfg)
    ex = FleetBusExecutor(stages, edge_cloud_integrated(), paper_topology(),
                          CostModel(ingest_s=0.5))
    res = ex.run(streams, bp, jax.random.PRNGKey(1))
    topics = {m.topic for m in res.message_log}
    for sid in streams:
        assert f"{T_STREAM}/{sid}" in topics
        assert f"{T_MODEL}/{sid}" in topics
    model_msgs = [m for m in res.message_log
                  if m.topic.startswith(T_MODEL + "/")]
    # every window publishes one model per stream (ungated)
    assert len(model_msgs) == N_WINDOWS * len(streams)
    for m in model_msgs:
        assert m.topic == f"{T_MODEL}/{m.payload['stream']}"


def test_fleet_bus_edge_centric_oom_degrades_all_streams(fleet_setup, cfg):
    """Speed training placed on the Pi OOMs for the whole fleet: no model
    is ever published, every stream serves the batch model."""
    streams, bp = fleet_setup
    stages, _ = _fleet_stages(cfg)
    ex = FleetBusExecutor(stages, edge_centric(), paper_topology(),
                          CostModel(ingest_s=0.5))
    res = ex.run(streams, bp, jax.random.PRNGKey(1))
    assert res.failures and "OOM" in res.failures[0]
    assert res.train_dispatches == 0
    for sid in streams:
        for r in res.results[sid].records:
            assert r.rmse_speed == pytest.approx(r.rmse_batch, abs=1e-12)


# ---------------------------------------------------------------------------
# drift-gated retraining
# ---------------------------------------------------------------------------


def test_gated_bus_skips_stationary_streams(cfg):
    """Under the bus, a gated fleet with one stationary and one drifting
    stream skips retrains on the stationary stream while the drifting
    stream keeps training — and skipped windows publish no model."""
    n_windows, rpw = 6, 150
    streams, hist0 = fleet_windowed_streams(
        2, n_windows, rpw, ["none", "abrupt"], seed=3, hist_len=1200)
    fc_batch = lstm_forecaster(cfg, epochs=4, batch_size=256)
    bp, _ = pretrain_batch_model(fc_batch, hist0, jax.random.PRNGKey(0))

    stages, _ = _fleet_stages(cfg)
    ex = FleetBusExecutor(stages, edge_cloud_integrated(), paper_topology(),
                          CostModel(ingest_s=0.5), gate=DriftGate())
    res = ex.run(streams, bp, jax.random.PRNGKey(1))

    assert res.skipped_retrains() > 0
    stats = res.gate_stats["per_stream"]
    assert stats["t00"]["skipped"] > 0  # the stationary stream skips
    # every stream still serves every window
    for sid in streams:
        assert len(res.results[sid].records) == n_windows - 1
    # models only transfer for retrained windows
    model_msgs = [m for m in res.message_log
                  if m.topic.startswith(T_MODEL + "/")]
    assert len(model_msgs) == res.total_retrains()
    assert res.train_dispatches <= n_windows
    # the shared fleet dispatch's wall is charged only to streams that
    # actually trained: a skipped window's record keeps t_speed_train = 0
    for sid in streams:
        for r in res.results[sid].records:
            if not res.retrain_log[sid][r.window]:
                assert r.t_speed_train == 0.0
    # gate stats stay consistent with the executor's retrain log
    stats = res.gate_stats
    assert stats["retrained"] == res.total_retrains()
    assert stats["skipped"] == res.skipped_retrains()


# ---------------------------------------------------------------------------
# one-dispatch fleet serving: vmapped predict, device-resident state, int8
# ---------------------------------------------------------------------------


def test_predict_fleet_matches_single_predicts(cfg):
    """One vmapped dispatch serves every stream's (ragged) batch under its
    own params, to <=1e-6 of the sequential CompiledForecaster.predict —
    and the padded stream slots never leak into real streams' results."""
    from repro.models import get_model, lstm as lstm_mod

    model = get_model(cfg)
    ff = FleetForecaster(model, epochs=3, batch_size=64,
                         predict_fn=lambda p, x: lstm_mod.predict(cfg, p, x))
    S = 3  # buckets to 4: one padded stream slot in train AND predict
    datas = [_window(150, seed=i) for i in range(S)]
    keys = [jax.random.fold_in(jax.random.PRNGKey(2), i) for i in range(S)]
    params, _ = ff.train_fleet(datas, keys)

    xs = [_window(100, seed=10)["x"], _window(150, seed=11)["x"],
          _window(37, seed=12)["x"]]  # ragged: 3 different batch buckets
    d0 = ff.predict_dispatches
    preds = ff.predict_fleet(params, xs)
    assert ff.predict_dispatches - d0 == 1
    assert len(preds) == S  # exactly the real streams, no padded slots
    for i in range(S):
        assert preds[i].shape == (len(xs[i]), 1)
        single = ff.single.predict(params[i], xs[i])
        np.testing.assert_allclose(preds[i], single, atol=1e-6, rtol=0)

    # a one-stream call delegates to the single-stream path byte-identically
    (p1,) = ff.predict_fleet([params[0]], [xs[0]])
    np.testing.assert_array_equal(p1, ff.single.predict(params[0], xs[0]))


def test_fleet_device_resident_no_restaging(cfg):
    """The device-resident hot path: after a bucket's first window, further
    windows perform zero new XLA traces and zero host staging-buffer
    allocations (data is re-staged in place, params stay stacked on
    device)."""
    from repro.models import get_model, lstm as lstm_mod

    model = get_model(cfg)
    ff = FleetForecaster(model, epochs=3, batch_size=64,
                         predict_fn=lambda p, x: lstm_mod.predict(cfg, p, x))
    S = 4
    keys = [jax.random.fold_in(jax.random.PRNGKey(3), i) for i in range(S)]

    def one_window(w):
        datas = [_window(150, seed=100 * w + i) for i in range(S)]
        params, _ = ff.train_fleet(datas, keys)
        xs = [d["x"] for d in datas]
        ff.predict_fleet(params, xs)
        return params

    one_window(0)
    traces0 = ff.retrace_count
    ptraces0 = dict(ff.predict_trace_counts())
    allocs0 = ff.staging_allocs
    dispatches0 = (ff.train_dispatches, ff.predict_dispatches)
    for w in (1, 2):
        one_window(w)
    assert ff.retrace_count == traces0  # 0 retraces after window 1
    assert ff.predict_trace_counts() == ptraces0
    assert ff.staging_allocs == allocs0  # 0 host re-stacks after window 1
    assert ff.train_dispatches == dispatches0[0] + 2
    assert ff.predict_dispatches == dispatches0[1] + 2


def test_fleet_quantized_sync_e2e(fleet_setup, cfg):
    """Fleet int8 sync end to end: every retrained stream's model arrives
    as a QTensor tree on its own model topic, the measured transfer is the
    int8 size (<0.45x the float sync), and the fleet's hybrid accuracy
    stays within the single-stream int8 bound (mirrors
    tests/test_quantize.py)."""
    from repro.serving.quantize import QTensor

    streams, bp = fleet_setup
    key = jax.random.PRNGKey(1)

    runs = {}
    for label, quant in (("float", False), ("int8", True)):
        stages, _ = _fleet_stages(cfg)
        ex = FleetBusExecutor(stages, edge_cloud_integrated(),
                              paper_topology(), CostModel(ingest_s=0.5),
                              quantized_sync=quant)
        runs[label] = ex.run(streams, bp, key)

    def model_msgs(res):
        return [m for m in res.message_log
                if m.topic.startswith(T_MODEL + "/")]

    fmsgs, qmsgs = model_msgs(runs["float"]), model_msgs(runs["int8"])
    assert len(qmsgs) == N_WINDOWS * len(streams)  # ungated: every window
    for m in qmsgs:
        leaves = jax.tree_util.tree_leaves(
            m.payload["params"], is_leaf=lambda x: isinstance(x, QTensor))
        assert any(isinstance(x, QTensor) for x in leaves), m.topic
    # the per-stream model transfer carries the real int8 byte count
    fbytes = {(m.topic, m.payload["window"]): m.nbytes for m in fmsgs}
    for m in qmsgs:
        assert m.nbytes < 0.45 * fbytes[(m.topic, m.payload["window"])]
    # serving accuracy: int8 fleet inference tracks the float fleet
    rf = runs["float"].mean_rmse()["hybrid"]
    rq = runs["int8"].mean_rmse()["hybrid"]
    assert rq < rf * 1.05, (rf, rq)
    # every stream still served every inference window
    for sid in streams:
        assert len(runs["int8"].results[sid].records) == N_WINDOWS - 1


def test_gated_inprocess_serves_prior_model_on_skip(fleet_setup, cfg):
    """A skipped window's speed inference still runs — on the prior model
    (not the batch fallback), so rmse_speed stays distinct from
    rmse_batch."""
    streams, bp = fleet_setup
    stages, _ = _fleet_stages(cfg)
    gate = DriftGate()
    res = InProcessFleetExecutor(stages, gate=gate).run(
        streams, bp, jax.random.PRNGKey(1))
    assert res.gate_stats is not None
    skipped_some = [sid for sid, log in res.retrain_log.items()
                    if not all(log)]
    assert skipped_some, "gate never skipped — thresholds off"
    for sid in skipped_some:
        for r in res.results[sid].records:
            # a synced speed model exists from window 0 on; even when stale
            # it is a different model from the batch one
            assert r.rmse_speed != pytest.approx(r.rmse_batch, abs=1e-12)


# ---------------------------------------------------------------------------
# batch-model refresh from archived drifted windows
# ---------------------------------------------------------------------------


def test_batch_refresh_rides_fleet_dispatch(fleet_setup, cfg):
    """Gated run with a BatchRefresh stage: archived drifted windows
    retrain the batch models in whole-fleet dispatches on the refresh
    cadence, counted separately from the speed-training dispatches, and
    the result reproduces deterministically."""
    from repro.core.stages import BatchRefresh

    streams, bp = fleet_setup
    key = jax.random.PRNGKey(1)

    stages, ff = _fleet_stages(cfg)
    # the fixture's gate fires only on the warmup window, so one archived
    # window must be enough to join the refresh cohort here
    rf = BatchRefresh(ff, every=2, min_windows=1, max_windows=4)
    ex = InProcessFleetExecutor(stages, gate=DriftGate(), batch_refresh=rf)
    res = ex.run(streams, bp, key)

    assert res.refresh is not None
    assert res.refresh["rounds"] >= 1
    assert res.refresh["dispatches"] >= 1
    assert res.refresh["refreshed"], "no stream ever refreshed"
    # speed-training accounting excludes the refresh dispatches
    assert res.train_dispatches <= res.n_windows
    # refreshed streams had archived >= min_windows drifted windows
    for sid in res.refresh["refreshed"]:
        assert sum(res.retrain_log[sid]) >= rf.min_windows

    # a second run through the same executor reproduces exactly
    res2 = ex.run(streams, bp, key)
    assert res2.refresh["rounds"] == res.refresh["rounds"]
    assert res2.refresh["refreshed"] == res.refresh["refreshed"]
    assert res2.retrain_log == res.retrain_log


def test_batch_refresh_updates_batch_params(cfg):
    """After a refresh round, the refreshed stream's batch-inference RMSE
    must change on later windows (the new batch model is actually
    installed), while an un-refreshed baseline run keeps the pretrained
    one throughout."""
    from repro.core.stages import BatchRefresh

    streams, hist0 = fleet_windowed_streams(
        2, 6, RPW, ["abrupt", "abrupt"], seed=3, hist_len=1200)
    fc_batch = lstm_forecaster(cfg, epochs=4, batch_size=256)
    bp, _ = pretrain_batch_model(fc_batch, hist0, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(9)

    stages_a, _ = _fleet_stages(cfg)
    base = InProcessFleetExecutor(stages_a).run(streams, bp, key)
    stages_b, ffb = _fleet_stages(cfg)
    rf = BatchRefresh(ffb, every=2, min_windows=2, max_windows=4)
    ref = InProcessFleetExecutor(stages_b, batch_refresh=rf).run(
        streams, bp, key)

    assert ref.refresh["rounds"] >= 1
    # identical up to the first refresh round, so any divergence proves
    # the refreshed batch model was installed and served
    changed = False
    for sid in ref.refresh["refreshed"]:
        for a, b in zip(base.results[sid].records, ref.results[sid].records):
            if a.rmse_batch != b.rmse_batch:
                changed = True
    assert changed, "refreshed batch model never served"
