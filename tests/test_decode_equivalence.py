"""Decode-path correctness: step-by-step decoding with the KV/state cache
must reproduce the logits of a single full forward pass over the same tokens
(teacher forcing).  This is the strongest serving invariant we have."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import blocks, get_model

ARCHS = ["tinyllama-1.1b", "h2o-danube-3-4b", "grok-1-314b", "rwkv6-3b",
         "zamba2-1.2b", "seamless-m4t-medium"]


def full_logits(cfg, params, batch):
    """All-position logits from the training forward pass."""
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        from repro.models import transformer as m

        h, _ = m.forward(cfg, params, batch)
        n_prefix = h.shape[1] - batch["tokens"].shape[1]
        if n_prefix > 0:
            h = h[:, n_prefix:]
        return blocks.logits_fn(cfg, params, h)
    if fam == "ssm":
        from repro.models import rwkv as m

        h, _, _ = m.forward(cfg, params, batch)
        return blocks.logits_fn(cfg, params, h)
    if fam == "hybrid":
        from repro.models import hybrid_arch as m

        h, _ = m.forward(cfg, params, batch)
        return blocks.logits_fn(cfg, params, h)
    if fam == "audio":
        from repro.models import encdec as m

        memory = m.encode(cfg, params, batch["prefix_embed"])
        h, _ = m._decoder_seq(cfg, params, batch["tokens"], memory)
        return blocks.logits_fn(cfg, params, h)
    raise ValueError(fam)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    import dataclasses

    cfg = get_config(arch).reduced().replace(attn_chunk=16)
    if cfg.moe is not None:
        # equivalence holds when no tokens are dropped: raise the reference
        # forward's capacity to worst case (serving paths are no-drop)
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, S_pre, S_dec = 2, 8, 6
    S = S_pre + S_dec
    tokens = jax.random.randint(key, (B, S), 1, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.frontend is not None:
        batch["prefix_embed"] = (
            jax.random.normal(
                key, (B, cfg.frontend.n_prefix_tokens, cfg.frontend.embed_dim)
            )
            * 0.02
        )

    ref = np.asarray(full_logits(cfg, params, batch))  # (B, S, V)

    n_prefix = (cfg.frontend.n_prefix_tokens
                if cfg.family == "vlm" and cfg.frontend else 0)
    pre_batch = dict(batch, tokens=tokens[:, :S_pre])
    logits, cache = model.prefill(params, pre_batch, S + n_prefix)
    np.testing.assert_allclose(
        np.asarray(logits), ref[:, S_pre - 1], atol=2e-3, rtol=2e-3,
        err_msg=f"{arch}: prefill logits mismatch",
    )
    for i in range(S_dec):
        pos = jnp.full((B,), S_pre + i + n_prefix, jnp.int32)
        tok = tokens[:, S_pre + i : S_pre + i + 1]
        logits, cache = model.decode_step(params, {"token": tok, "pos": pos},
                                          cache)
        np.testing.assert_allclose(
            np.asarray(logits), ref[:, S_pre + i], atol=2e-3, rtol=2e-3,
            err_msg=f"{arch}: decode step {i} logits mismatch",
        )


def test_swa_ring_buffer_decode():
    """SWA decode with a ring-buffer cache smaller than the sequence must
    match the full forward pass (window masking equivalence)."""
    cfg = get_config("h2o-danube-3-4b").reduced().replace(
        window_size=8, attn_chunk=8)
    model = get_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    B, S_pre, S_dec = 1, 10, 8
    S = S_pre + S_dec
    tokens = jax.random.randint(key, (B, S), 1, cfg.vocab_size)
    ref = np.asarray(full_logits(cfg, params, {"tokens": tokens}))

    logits, cache = model.prefill(params, {"tokens": tokens[:, :S_pre]}, S)
    assert cache["k"].shape[2] == cfg.window_size  # ring buffer size
    np.testing.assert_allclose(np.asarray(logits), ref[:, S_pre - 1],
                               atol=2e-3, rtol=2e-3)
    for i in range(S_dec):
        pos = jnp.full((B,), S_pre + i, jnp.int32)
        tok = tokens[:, S_pre + i : S_pre + i + 1]
        logits, cache = model.decode_step(params, {"token": tok, "pos": pos},
                                          cache)
        np.testing.assert_allclose(
            np.asarray(logits), ref[:, S_pre + i], atol=2e-3, rtol=2e-3,
            err_msg=f"SWA decode step {i}",
        )
