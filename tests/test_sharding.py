"""Sharding rules: divisibility fallback, no axis reuse, full PARAM_AXES
coverage over every model's parameter tree, cache spec coverage."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED, SHAPES, get_config, shape_applicable
from repro.distributed.sharding import (
    AxisRules,
    logical_to_spec,
    param_axes_for,
    _path_str,
)
from repro.models import get_model, input_specs


def fake_mesh(shape=(16, 16), axes=("data", "model")):
    # AbstractMesh suffices for spec resolution via axis sizes
    import numpy as _np

    class M:
        axis_names = axes
        devices = _np.empty(shape, dtype=object)

    return M()


def test_divisible_maps_to_axis():
    mesh = fake_mesh()
    spec = logical_to_spec(("batch", "ffn"), (256, 4096), mesh)
    assert spec == P("data", "model")


def test_non_divisible_drops_axis():
    mesh = fake_mesh()
    # paligemma: 8 q-heads cannot split the 16-way model axis
    spec = logical_to_spec(("batch", None, "heads", None), (32, 4, 8, 256), mesh)
    assert spec == P("data", None, None, None)


def test_no_axis_reuse():
    mesh = fake_mesh()
    # kimi expert weights: experts take model; ffn must not reuse it
    spec = logical_to_spec(("experts", "fsdp", "tp"), (384, 7168, 2048), mesh)
    assert spec == P("model", "data", None)


def test_joint_axes_multi_pod():
    mesh = fake_mesh((2, 16, 16), ("pod", "data", "model"))
    spec = logical_to_spec(("batch", None), (256, 128), mesh)
    assert spec == P(("pod", "data"), None)
    # batch=8: divisible by pod(2) only -> greedy prefix
    spec = logical_to_spec(("batch", None), (8, 128), mesh)
    assert spec == P("pod", None)


@pytest.mark.parametrize("arch", [c.name for c in ASSIGNED] + ["lstm-paper"])
def test_param_axes_cover_all_leaves(arch):
    """Every parameter in every model must resolve through PARAM_AXES."""
    cfg = get_config(arch).reduced() if arch != "lstm-paper" else get_config(arch)
    model = get_model(cfg)
    sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    mesh = fake_mesh()

    def one(path, s):
        names = param_axes_for(_path_str(path), len(s.shape))
        spec = logical_to_spec(names, s.shape, mesh)
        # sharded dims must divide
        for dim, ax in zip(s.shape, spec):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            n = int(np.prod([dict(zip(mesh.axis_names, mesh.devices.shape))[a]
                             for a in axes]))
            assert dim % n == 0

    jax.tree_util.tree_map_with_path(one, sds)


@pytest.mark.parametrize("arch", [c.name for c in ASSIGNED])
def test_input_specs_exist_for_applicable_shapes(arch):
    cfg = get_config(arch)
    for shape in SHAPES.values():
        ok, _ = shape_applicable(cfg, shape)
        if not ok:
            continue
        specs = input_specs(cfg, shape)
        assert "batch" in specs
        leaves = jax.tree_util.tree_leaves(specs)
        assert all(isinstance(x, jax.ShapeDtypeStruct) for x in leaves)
        if shape.kind == "decode":
            assert "cache" in specs
            tok = specs["batch"]["token"]
            assert tok.shape == (shape.global_batch, 1)


def test_activation_shard_noop_without_context():
    from repro.distributed.sharding import shard

    x = jnp.ones((4, 4))
    assert shard(x, "batch", None) is x


def test_shard_constraint_under_real_mesh():
    from repro.distributed.sharding import shard, use_mesh_rules

    mesh = jax.make_mesh((1, 1), ("data", "model"))

    @jax.jit
    def f(x):
        with use_mesh_rules(mesh):
            return shard(x * 2, "batch", "ffn")

    out = f(jnp.ones((4, 8)))
    np.testing.assert_allclose(np.asarray(out), 2.0)


# ---------------------------------------------------------------------------
# The stream mesh (fleet hot path)
# ---------------------------------------------------------------------------


def test_largest_pow2_divisor():
    from repro.distributed.sharding import largest_pow2_divisor

    assert largest_pow2_divisor(1) == 1
    assert largest_pow2_divisor(6) == 2
    assert largest_pow2_divisor(12) == 4
    assert largest_pow2_divisor(1024) == 1024
    with pytest.raises(ValueError):
        largest_pow2_divisor(0)


@pytest.mark.parametrize("sb,nd,want", [
    (2, 8, 2),      # bucket smaller than the host: cap at the bucket
    (1024, 6, 4),   # non-pow2 device count: pow2 floor
    (8, 6, 4),
    (4, 3, 2),
    (16, 1, 1),     # single device: no sharding
    (12, 8, 4),     # non-pow2 bucket: its own pow2 divisor
    (1, 8, 1),
])
def test_stream_mesh_size(sb, nd, want):
    from repro.distributed.sharding import stream_mesh_size

    assert stream_mesh_size(sb, nd) == want


def test_stream_mesh_and_sharding_single_device():
    """On one device the mesh collapses and stream_sharding opts out —
    the tests' one-CPU configuration never constructs a sharding."""
    from repro.distributed.sharding import stream_mesh, stream_sharding

    devs = jax.devices()[:1]
    assert stream_mesh(8, devs) is None
    assert stream_sharding(8, devs) is None


def test_stream_batch_spec_divisibility_fallback():
    from repro.distributed.sharding import (
        STREAM_AXIS,
        stream_batch_spec,
    )

    mesh = fake_mesh((4,), (STREAM_AXIS,))
    assert stream_batch_spec(8, mesh) == P(STREAM_AXIS)
    # indivisible bucket degrades to replicated instead of erroring
    assert stream_batch_spec(3, mesh) == P(None)


def test_fleet_param_shardings_specs():
    """Stacked fleet leaves: stream axis sharded, per-stream LSTM trailing
    dims replicated; unregistered leaves (opt-state counters) fall back to
    replicated trailing dims instead of raising."""
    from repro.distributed.sharding import STREAM_AXIS, fleet_param_shardings

    mesh = jax.make_mesh((1,), (STREAM_AXIS,))
    stacked = {
        "lstm": {"kernel": jnp.zeros((8, 20, 160))},
        "head": {"head_b": jnp.zeros((8, 1))},
        "opt_count": jnp.zeros((8,), jnp.int32),  # no PARAM_AXES entry
    }
    sh = fleet_param_shardings(stacked, mesh)
    got = jax.tree_util.tree_map(lambda s: s.spec, sh)
    assert got["lstm"]["kernel"] == P(STREAM_AXIS, None, None)
    assert got["head"]["head_b"] == P(STREAM_AXIS, None)
    assert got["opt_count"] == P(STREAM_AXIS)
