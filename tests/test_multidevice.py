"""Multi-device numerical equivalence, run in a subprocess with 8 forced
host devices (the main test process must keep seeing 1 device).

Checks that sharded execution (GSPMD constraints + shard_map EP in both
expert-sharded and ffn-sharded regimes) produces the same numbers as the
single-device reference.
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ModelConfig, MoEConfig
from repro.models import moe as moe_mod
from repro.distributed.sharding import use_mesh_rules
from repro.configs import get_config
from repro.models import get_model
from repro.training import adamw, make_train_step

assert jax.device_count() == 8, jax.device_count()

# --- MoE: fine-grained (E=8 over model=4) and coarse (E=2, f over model=4)
for E, f, tag in ((8, 64, "fine"), (2, 64, "coarse")):
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
        n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
        mlp_variant="swiglu", dtype="float32", param_dtype="float32",
        moe=MoEConfig(n_experts=E, top_k=2, d_ff_expert=f,
                      capacity_factor=float(E)))
    key = jax.random.PRNGKey(0)
    p = moe_mod.init_moe(key, "moe", cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32))
    ref, aux_ref = moe_mod.moe_onehot(cfg, p, x, no_drop=True)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with use_mesh_rules(mesh):
        out, aux = jax.jit(lambda xx, pp: moe_mod.moe_shard_map(cfg, pp, xx))(x, p)
    err = float(jnp.abs(ref - out).max())
    print(tag, "err", err)
    assert err < 1e-4, (tag, err)

# --- full train step: sharded == single-device reference
cfg = get_config("tinyllama-1.1b").reduced()
model = get_model(cfg)
params = model.init(jax.random.PRNGKey(0))
opt = adamw(1e-3)
batch = {
    "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size),
    "targets": jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0, cfg.vocab_size),
}
step = make_train_step(model, opt)
_, _, m_ref = jax.jit(step)(params, opt.init(params), batch)
mesh = jax.make_mesh((4, 2), ("data", "model"))
with use_mesh_rules(mesh):
    _, _, m_sh = jax.jit(step)(params, opt.init(params), batch)
a, b = float(m_ref["loss"]), float(m_sh["loss"])
print("train loss ref", a, "sharded", b)
assert abs(a - b) < 5e-4, (a, b)
print("OK")
"""


@pytest.mark.slow
def test_sharded_equals_single_device():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    assert "OK" in res.stdout
