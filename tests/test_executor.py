"""Executor-layer tests: the synchronous and bus-scheduled paths drive the
SAME stage objects, so for one seed/stream they must produce identical
per-window accuracy; the edge-centric placement must record the paper's
speed-training OOM and degrade its speed layer to the batch model; and the
measured end-to-end window latency must preserve the paper's deployment
ordering."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (
    PipelineStages,
    WindowPlan,
    WindowedStream,
    lstm_forecaster,
    make_supervised,
    pretrain_batch_model,
)
from repro.runtime import (
    BusExecutor,
    CapacityError,
    CostModel,
    InProcessExecutor,
    cloud_centric,
    edge_centric,
    edge_cloud_integrated,
    paper_topology,
)
from repro.streams.normalize import MinMaxScaler
from repro.streams.sources import gradual_drift, wind_turbine_series

N_WINDOWS = 4


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("lstm-paper")
    series = wind_turbine_series(1200 + 150 * N_WINDOWS, seed=0)
    hist, stream_raw = series[:1200], series[1200:]
    stream_raw = gradual_drift(stream_raw, alphas=np.full(5, 1.5e-3), seed=1)
    scaler = MinMaxScaler.fit(hist)
    fc_batch = lstm_forecaster(cfg, epochs=4, batch_size=256)
    fc_speed = lstm_forecaster(cfg, epochs=6, batch_size=64)
    bp, _ = pretrain_batch_model(
        fc_batch, make_supervised(scaler.transform(hist), 5, 0),
        jax.random.PRNGKey(0))
    stream = WindowedStream(scaler.transform(stream_raw),
                            WindowPlan(N_WINDOWS, 150, lag=5))
    stages = PipelineStages.build(fc_speed, mode="dynamic")
    return stages, bp, stream


def bus_run(setup, dep, strict=False, period=30.0):
    stages, bp, stream = setup
    ex = BusExecutor(stages, dep, paper_topology(),
                     CostModel(ingest_s=0.5), strict_capacity=strict,
                     window_period_s=period)
    return ex.run(stream, bp, jax.random.PRNGKey(1))


def test_inprocess_and_bus_identical_rmse(setup):
    """Same stages + same seed -> identical per-window accuracy, whether the
    pipeline runs as the synchronous loop or bus-scheduled on a deployment
    where speed training succeeds."""
    stages, bp, stream = setup
    sync = InProcessExecutor(stages).run(stream, bp, jax.random.PRNGKey(1))
    for dep in (edge_cloud_integrated(), cloud_centric()):
        bus = bus_run(setup, dep)
        assert len(bus.records) == len(sync.records) == N_WINDOWS - 1
        for rs, rb in zip(sync.records, bus.records):
            assert rs.window == rb.window
            assert rs.rmse_batch == pytest.approx(rb.rmse_batch, abs=1e-12)
            assert rs.rmse_speed == pytest.approx(rb.rmse_speed, abs=1e-12)
            assert rs.rmse_hybrid == pytest.approx(rb.rmse_hybrid, abs=1e-12)
            assert rs.w_speed == pytest.approx(rb.w_speed, abs=1e-12)


def test_edge_centric_bus_records_oom(setup):
    """Speed training placed on the Pi fails every window; no model is ever
    published, so the speed layer serves the batch model (fallback)."""
    res = bus_run(setup, edge_centric())
    assert len(res.failures) == N_WINDOWS
    assert "OOM" in res.failures[0]
    for r in res.records:
        assert r.rmse_speed == pytest.approx(r.rmse_batch, abs=1e-12)
    with pytest.raises(CapacityError):
        bus_run(setup, edge_centric(), strict=True)


def test_measured_e2e_latency_ordering(setup):
    """Paper Table 3 on real compute: integrated < cloud-centric (WAN round
    trip) < edge-centric (single-worker Pi thrashed by the training
    attempt)."""
    e2e = {}
    for dep in (edge_cloud_integrated(), cloud_centric(), edge_centric()):
        e2e[dep.name] = bus_run(setup, dep).mean_e2e_s()
    assert (e2e["edge-cloud-integrated"] < e2e["cloud-centric"]
            < e2e["edge-centric"]), e2e


def test_stale_model_inference_from_event_ordering(setup):
    """With the window period shrunk below the training time, windows arrive
    while training is still in flight: early windows see no synced speed
    model yet (cold-start fallback) — M^s_{t-1} staleness emerging from
    event ordering, not loop order."""
    fresh = bus_run(setup, edge_cloud_integrated(), period=30.0)
    stale = bus_run(setup, edge_cloud_integrated(), period=1e-4)
    # steady period: window 1 uses M^s_0, distinct from the batch model
    assert fresh.records[0].rmse_speed != pytest.approx(
        fresh.records[0].rmse_batch, abs=1e-12)
    # compressed period: window 1 is inferred before any model sync lands
    assert stale.records[0].rmse_speed == pytest.approx(
        stale.records[0].rmse_batch, abs=1e-12)


def test_quantized_sync_serves_int8_model(setup):
    """``quantized_sync=True``: the model topic carries the ~4x smaller int8
    byte count, the serving side really runs on QTensor params, and per-window
    speed RMSE shifts from the float run by only a quantization-sized amount.

    (Hybrid RMSE is deliberately not compared: the dynamic weight solve reads
    whatever model_sync has installed at that *virtual* moment, and the two
    runs' measured stage walls differ by enough to legitimately reorder a
    model install against a window's weight solve — real event-ordering
    sensitivity, not a quantization effect.)"""
    from repro.runtime.modules import T_MODEL
    from repro.serving.quantize import QTensor

    stages, bp, stream = setup
    dep = edge_cloud_integrated()

    def run(quantized):
        ex = BusExecutor(stages, dep, paper_topology(),
                         CostModel(ingest_s=0.5), quantized_sync=quantized)
        return ex.run(stream, bp, jax.random.PRNGKey(1))

    res_f, res_q = run(False), run(True)
    nb_f = [m.nbytes for m in res_f.message_log if m.topic == T_MODEL]
    nb_q = [m.nbytes for m in res_q.message_log if m.topic == T_MODEL]
    assert nb_f and nb_q
    assert max(nb_q) < 0.45 * min(nb_f)  # ~4x smaller sync transfers

    # the published params really are quantized (QTensor leaves)
    qmsg = next(m for m in res_q.message_log if m.topic == T_MODEL)
    leaves = jax.tree_util.tree_leaves(
        qmsg.payload["params"], is_leaf=lambda x: isinstance(x, QTensor))
    assert any(isinstance(x, QTensor) for x in leaves)

    # int8 serving tracks the float-sync accuracy window for window
    for rf, rq in zip(res_f.records, res_q.records):
        assert rq.rmse_speed == pytest.approx(rf.rmse_speed, rel=0.05)


def test_bus_ledger_and_e2e_structure(setup):
    res = bus_run(setup, edge_cloud_integrated())
    t = res.table3()
    for mod in ("batch_inference", "speed_inference", "hybrid_inference",
                "speed_training", "model_sync", "data_sync"):
        assert mod in t
        assert t[mod]["total"] >= 0.0
    # measured compute is real (nonzero) for the JAX modules
    assert t["batch_inference"]["computation"] > 0
    assert t["speed_training"]["computation"] > 0
    assert set(res.e2e_s) == {w for w in range(1, N_WINDOWS)}
    assert all(v > 0 for v in res.e2e_s.values())
