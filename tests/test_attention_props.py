"""Hypothesis property tests on the attention substrate's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property tests skip; deterministic tests run
    from _hypothesis_stub import given, settings, st

from repro.models.attention import attend, attend_full_ref


@st.composite
def attn_case(draw):
    B = draw(st.integers(1, 2))
    Sq = draw(st.integers(1, 24))
    Sk = draw(st.integers(1, 40))
    Hkv = draw(st.sampled_from([1, 2]))
    G = draw(st.sampled_from([1, 2, 4]))
    D = draw(st.sampled_from([4, 8]))
    causal = draw(st.booleans())
    window = draw(st.sampled_from([0, 4, 16]))
    chunk = draw(st.sampled_from([4, 8, 64]))
    seed = draw(st.integers(0, 2**16))
    return B, Sq, Sk, Hkv, G, D, causal, window, chunk, seed


@given(attn_case())
@settings(max_examples=25, deadline=None)
def test_chunked_equals_reference(case):
    B, Sq, Sk, Hkv, G, D, causal, window, chunk, seed = case
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, Hkv * G, D))
    k = jax.random.normal(ks[1], (B, Sk, Hkv, D))
    v = jax.random.normal(ks[2], (B, Sk, Hkv, D))
    # decode-style positions: queries continue after the keys
    q_pos = jnp.broadcast_to(jnp.arange(Sk, Sk + Sq), (B, Sq)) \
        if causal else jnp.zeros((B, Sq), jnp.int32)
    kv_pos = jnp.broadcast_to(jnp.arange(Sk), (B, Sk))
    o1 = attend(q, k, v, q_pos, kv_pos, causal=causal, window=window,
                chunk=chunk)
    o2 = attend_full_ref(q, k, v, q_pos, kv_pos, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5,
                               rtol=2e-5)


def test_invalid_slots_are_ignored():
    """kv_pos = -1 slots must contribute nothing (ring-buffer invariant)."""
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    B, S, H, D = 1, 8, 2, 4
    q = jax.random.normal(ks[0], (B, 1, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    q_pos = jnp.full((B, 1), 100, jnp.int32)
    kv_pos = jnp.where(jnp.arange(S)[None] < 4, jnp.arange(S)[None],
                       -1).astype(jnp.int32)
    o_masked = attend(q, k, v, q_pos, kv_pos, causal=True, chunk=4)
    o_trunc = attend(q, k[:, :4], v[:, :4], q_pos, kv_pos[:, :4],
                     causal=True, chunk=4)
    np.testing.assert_allclose(np.asarray(o_masked), np.asarray(o_trunc),
                               atol=1e-6)


def test_window_equals_truncated_keys():
    """SWA masking == physically truncating old keys."""
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    B, S, H, D, W = 1, 32, 2, 8, 8
    q = jax.random.normal(ks[0], (B, 1, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    q_pos = jnp.full((B, 1), S - 1, jnp.int32)
    o_win = attend(q, k, v, q_pos, pos, causal=True, window=W, chunk=8)
    lo = S - W
    o_cut = attend(q, k[:, lo:], v[:, lo:], q_pos, pos[:, lo:], causal=True,
                   chunk=8)
    np.testing.assert_allclose(np.asarray(o_win), np.asarray(o_cut),
                               atol=1e-6)


def test_softmax_rows_sum_to_one_effectively():
    """With all-equal V, attention returns exactly V regardless of masks."""
    B, Sq, Sk, H, D = 1, 4, 16, 2, 4
    q = jax.random.normal(jax.random.PRNGKey(2), (B, Sq, H, D))
    k = jax.random.normal(jax.random.PRNGKey(3), (B, Sk, H, D))
    v = jnp.ones((B, Sk, H, D)) * 3.5
    q_pos = jnp.broadcast_to(jnp.arange(Sk, Sk + Sq), (B, Sq))
    kv_pos = jnp.broadcast_to(jnp.arange(Sk), (B, Sk))
    o = attend(q, k, v, q_pos, kv_pos, causal=True, chunk=4)
    np.testing.assert_allclose(np.asarray(o), 3.5, atol=1e-5)


@pytest.mark.parametrize("arch", ["rwkv6-3b", "zamba2-1.2b",
                                  "seamless-m4t-medium"])
def test_engine_generate_more_archs(arch):
    """Serving engine works across model families, not just dense."""
    from repro.configs import get_config
    from repro.models import get_model
    from repro.serving import Engine

    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(cfg, params, max_len=24)
    prompts = np.random.default_rng(0).integers(
        1, cfg.vocab_size, (2, 8), dtype=np.int32)
    prefix = None
    if cfg.frontend is not None:
        prefix = np.random.default_rng(1).normal(
            0, 0.02, (2, cfg.frontend.n_prefix_tokens,
                      cfg.frontend.embed_dim)).astype(np.float32)
    out, stats = engine.generate(prompts, 5, prefix_embed=prefix)
    assert out.shape == (2, 5)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()
