"""HLO analyzer tests: parse a real compiled program and check dot FLOPs,
while trip counts, and collective detection (on a 1-device 'mesh' the
collective count is zero — the structure tests still hold)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import analysis


def test_dot_flops_simple_matmul():
    @jax.jit
    def f(a, b):
        return a @ b

    a = jnp.zeros((64, 128))
    b = jnp.zeros((128, 32))
    hlo = f.lower(a, b).compile().as_text()
    s = analysis.summarize(hlo)
    expect = 2 * 64 * 128 * 32
    assert s.dot_flops == pytest.approx(expect, rel=0.01)


def test_while_trip_count_multiplier():
    @jax.jit
    def f(x, w):
        def body(carry, _):
            return jnp.tanh(carry @ w), None

        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    x = jnp.zeros((16, 64))
    w = jnp.zeros((64, 64))
    hlo = f.lower(x, w).compile().as_text()
    s = analysis.summarize(hlo)
    assert 7 in s.trip_counts
    expect = 7 * 2 * 16 * 64 * 64
    assert s.dot_flops == pytest.approx(expect, rel=0.01)


def test_shape_bytes():
    assert analysis.shape_bytes("f32[4,8]{1,0}") == 128
    assert analysis.shape_bytes("bf16[10]") == 20
    assert analysis.shape_bytes("(f32[2], s32[3])") == 8 + 12
    assert analysis.shape_bytes("pred[]") == 1  # scalar predicate


def test_model_flops_moe_active_vs_total():
    from repro.configs import get_config, get_shape

    cfg = get_config("kimi-k2-1t-a32b")
    total, active = analysis.count_params_analytic(cfg)
    # 1T-class total, ~32B-class active
    assert total > 7e11
    assert active < 0.1 * total
    mf_train = analysis.model_flops(cfg, get_shape("train_4k"))
    mf_decode = analysis.model_flops(cfg, get_shape("decode_32k"))
    assert mf_train > mf_decode


def test_roofline_dominant_term():
    s = analysis.HLOSummary(
        dot_flops=1e12, traffic_bytes=1e9, collective_bytes=1e12,
        collectives={"all-reduce": 1e12}, n_while=0, trip_counts=[],
        param_bytes=0, output_bytes=0,
    )
    r = analysis.roofline(s, 256, model_flops=1e15)
    assert r.dominant == "collective"
    assert r.collective_s == pytest.approx(1e12 / 50e9)
