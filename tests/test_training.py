"""Optimizer, train loop and checkpoint tests."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import get_model
from repro.training import adamw, checkpoint, fit, make_train_step, sgd, warmup_cosine
from repro.training.optimizer import global_norm


def test_adamw_matches_numpy_reference():
    """One AdamW step against a hand-rolled numpy implementation."""
    lr, b1, b2, eps = 0.1, 0.9, 0.999, 1e-8
    p0 = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.5, -0.1, 0.2])}
    opt = adamw(lr, b1, b2, eps, weight_decay=0.0, clip_norm=None)
    st = opt.init(p0)
    p1, st1, _ = opt.update(g, st, p0)

    gn = np.asarray(g["w"])
    m = (1 - b1) * gn
    v = (1 - b2) * gn * gn
    mhat = m / (1 - b1)
    vhat = v / (1 - b2)
    expect = np.asarray(p0["w"]) - lr * mhat / (np.sqrt(vhat) + eps)
    np.testing.assert_allclose(np.asarray(p1["w"]), expect, rtol=1e-6)


def test_adamw_weight_decay_and_clip():
    opt = adamw(0.1, weight_decay=0.1, clip_norm=1.0)
    p0 = {"w": jnp.asarray([10.0])}
    g = {"w": jnp.asarray([100.0])}  # will be clipped to norm 1
    st = opt.init(p0)
    p1, _, metrics = opt.update(g, st, p0)
    assert float(metrics["grad_norm"]) == pytest.approx(100.0)
    # clipped g=1.0 -> mhat/sqrt(vhat) = 1; decay adds 0.1*10
    expect = 10.0 - 0.1 * (1.0 + 0.1 * 10.0)
    np.testing.assert_allclose(np.asarray(p1["w"]), [expect], rtol=1e-4)


def test_warmup_cosine_schedule():
    s = warmup_cosine(1.0, warmup=10, total=110, final_frac=0.1)
    assert float(s(jnp.asarray(0))) == 0.0
    assert float(s(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(s(jnp.asarray(110))) == pytest.approx(0.1, abs=1e-6)
    assert float(s(jnp.asarray(5))) == pytest.approx(0.5)


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)


def test_fit_reduces_lstm_loss():
    cfg = get_config("lstm-paper")
    model = get_model(cfg)
    rng = np.random.default_rng(0)
    # learnable signal: y = mean of last lag of target channel
    x = rng.normal(0, 1, (256, 5, 5)).astype(np.float32)
    y = x[:, :, 0].mean(axis=1, keepdims=True).astype(np.float32)
    res = fit(model, {"x": x, "y": y}, epochs=30, batch_size=64, lr=1e-2)
    first = res.history[0]["loss"] if res.history else None
    loss, _ = model.loss_fn(res.params, {"x": jnp.asarray(x), "y": jnp.asarray(y)})
    assert float(loss) < 0.05, f"LSTM failed to fit: {float(loss)}"
    assert res.steps == 30 * (256 // 64)


def test_sgd_descends_quadratic():
    opt = sgd(0.05, momentum=0.5)
    p = {"w": jnp.asarray([5.0])}
    st = opt.init(p)
    for _ in range(100):
        g = {"w": 2 * p["w"]}
        p, st, _ = opt.update(g, st, p)
    assert abs(float(p["w"][0])) < 0.05


def test_checkpoint_roundtrip():
    tree = {
        "layers": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
        "head": {"b": jnp.asarray([1.5], jnp.bfloat16)},
    }
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt")
        h = checkpoint.save(path, tree, step=7, meta={"arch": "test"})
        assert h.nbytes > 0 and h.path.endswith(".npz")
        back = checkpoint.load(h.path)
        np.testing.assert_array_equal(
            np.asarray(back["layers"]["w"]), np.asarray(tree["layers"]["w"])
        )
        assert back["head"]["b"].dtype == jnp.bfloat16


def test_train_step_is_jittable_and_deterministic():
    cfg = get_config("tinyllama-1.1b").reduced()
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    opt = adamw(1e-3)
    step = jax.jit(make_train_step(model, opt))
    batch = {
        "tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab_size),
        "targets": jax.random.randint(key, (2, 16), 0, cfg.vocab_size),
    }
    p1, s1, m1 = step(params, opt.init(params), batch)
    p2, s2, m2 = step(params, opt.init(params), batch)
    assert float(m1["loss"]) == float(m2["loss"])
