"""Compile-once hot-path tests: the cached epoch-scan train step must
compile exactly once per shape bucket (zero retraces for windows 2..N), the
fixed-shape padding must be loss-neutral (masked loss == unpadded loss), and
the legacy minibatcher must no longer drop the ragged tail batch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import lstm_forecaster
from repro.models import get_model
from repro.training import CompiledForecaster
from repro.training.train_loop import batch_iterator


def _window(n, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, 5, 5)).astype(np.float32)
    y = x[:, :, 0].mean(axis=1, keepdims=True).astype(np.float32)
    return {"x": x, "y": y}


@pytest.fixture(scope="module")
def cfg():
    return get_config("lstm-paper")


# ---------------------------------------------------------------------------
# compile-cache behavior
# ---------------------------------------------------------------------------


def test_windows_after_first_reuse_compiled_step(cfg):
    """Retrace-count regression: windows 2..N of one shape bucket must reuse
    window 1's compiled train step — zero new traces."""
    fc = lstm_forecaster(cfg, epochs=2, batch_size=64)
    eng = fc.engine
    key = jax.random.PRNGKey(0)
    for w in range(4):
        fc.train(_window(150, seed=w), None, jax.random.fold_in(key, w))
        if w == 0:
            assert eng.retrace_count == 1
    assert eng.retrace_count == 1, eng.trace_counts()
    assert eng.cache_size == 1


def test_new_shape_bucket_compiles_once_then_caches(cfg):
    fc = lstm_forecaster(cfg, epochs=2, batch_size=64)
    eng = fc.engine
    key = jax.random.PRNGKey(0)
    fc.train(_window(100), None, key)    # bucket 128
    fc.train(_window(150), None, key)    # bucket 256: one new trace
    fc.train(_window(200), None, key)    # bucket 256 again: cached
    fc.train(_window(90), None, key)     # bucket 128 again: cached
    assert eng.retrace_count == 2, eng.trace_counts()
    assert eng.cache_size == 2


def test_warm_start_shares_cold_start_executable(cfg):
    """Warm and cold starts differ only in where params come from, so they
    must share one compiled executable per bucket — a warm-start window must
    never pay a second compile."""
    fc = lstm_forecaster(cfg, epochs=2, batch_size=64, warm_start=True)
    eng = fc.engine
    key = jax.random.PRNGKey(0)
    params, _ = fc.train(_window(150), None, key)            # cold
    params2, _ = fc.train(_window(150, seed=1), params, key)  # warm
    assert eng.retrace_count == 1, eng.trace_counts()
    # donation safety: the caller-held tree survives the warm-start fit
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree_util.tree_leaves(params))
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree_util.tree_leaves(params2))


def test_mask_blind_model_rejected(cfg):
    """A model whose loss_fn ignores the validity mask must be rejected the
    first time a window needs padding, not silently biased toward zeros."""
    from repro.models import lstm as lstm_mod
    from repro.models.model import Model

    blind = Model(
        cfg=cfg,
        init=lambda key: lstm_mod.init_params(cfg, key),
        loss_fn=lambda p, b: lstm_mod.loss_fn(
            cfg, p, {"x": b["x"], "y": b["y"]}),  # drops the mask
        prefill=None, decode_step=None, init_cache=None)
    fc = CompiledForecaster(blind, epochs=1, batch_size=64)
    with pytest.raises(ValueError, match="mask"):
        fc.train(_window(150), None, jax.random.PRNGKey(0))
    # no padding needed -> mask is irrelevant and the model is fine
    params, _ = fc.train(_window(64), None, jax.random.PRNGKey(0))
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree_util.tree_leaves(params))


def test_compiled_fit_learns(cfg):
    """Parity with test_fit_reduces_lstm_loss on the compiled path."""
    model = get_model(cfg)
    data = _window(256)
    fc = CompiledForecaster(model, epochs=30, batch_size=64, lr=1e-2)
    params, wall = fc.train(data, None, jax.random.PRNGKey(0))
    loss, _ = model.loss_fn(params, {k: jnp.asarray(v)
                                     for k, v in data.items()})
    assert float(loss) < 0.05, f"compiled fit failed to learn: {float(loss)}"
    assert wall > 0
    # one epoch-scan dispatch covers epochs*steps updates
    assert fc.last_losses.shape == (30 * (256 // 64),)


# ---------------------------------------------------------------------------
# fixed-shape bucketing + masking
# ---------------------------------------------------------------------------


def test_bucket_examples_shapes():
    from repro.training import bucket_examples

    assert bucket_examples(64, 64) == 64
    assert bucket_examples(65, 64) == 128
    assert bucket_examples(150, 64) == 256
    assert bucket_examples(245, 64) == 256
    assert bucket_examples(10, 64) == 64
    with pytest.raises(ValueError):
        bucket_examples(0, 64)


def test_pad_to_bucket_mask():
    from repro.training import pad_to_bucket

    data = _window(150)
    padded = pad_to_bucket(data, 256)
    assert padded["x"].shape == (256, 5, 5)
    assert padded["mask"].sum() == 150
    assert (padded["mask"][:150] == 1).all() and (padded["mask"][150:] == 0).all()
    np.testing.assert_array_equal(padded["x"][:150], data["x"])
    assert (padded["x"][150:] == 0).all()


def test_padded_masked_loss_equals_unpadded_loss(cfg):
    """Padding to a shape bucket with the validity mask threaded into
    loss_fn is numerically invisible: the masked loss on the padded batch
    equals the plain loss on the unpadded batch."""
    from repro.training import bucket_examples, pad_to_bucket

    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = _window(150)
    nb = bucket_examples(150, 64)
    padded = pad_to_bucket(data, nb)

    plain, plain_m = model.loss_fn(
        params, {k: jnp.asarray(v) for k, v in data.items()})
    masked, masked_m = model.loss_fn(
        params, {k: jnp.asarray(v) for k, v in padded.items()})
    assert float(masked) == pytest.approx(float(plain), rel=1e-6)
    assert float(masked_m["rmse"]) == pytest.approx(float(plain_m["rmse"]),
                                                    rel=1e-6)
    # all-ones mask on the unpadded batch is also a no-op
    allones, _ = model.loss_fn(
        params, {**{k: jnp.asarray(v) for k, v in data.items()},
                 "mask": jnp.ones((150,), jnp.float32)})
    assert float(allones) == pytest.approx(float(plain), rel=1e-6)


def test_compiled_predict_matches_unpadded(cfg):
    """Inference-shape bucketing (pad + slice) must not change predictions."""
    from repro.models import lstm as lstm_mod

    fc = lstm_forecaster(cfg, epochs=1, batch_size=64)
    data = _window(150)
    params, _ = fc.train(data, None, jax.random.PRNGKey(0))
    got = fc.predict(params, data["x"])
    want = np.asarray(lstm_mod.predict(cfg, params, jnp.asarray(data["x"])))
    np.testing.assert_allclose(got, want, atol=1e-6, rtol=1e-6)
    assert got.shape == (150, 1)


# ---------------------------------------------------------------------------
# legacy minibatcher: ragged tail no longer dropped
# ---------------------------------------------------------------------------


def test_batch_iterator_yields_tail_examples():
    """n % batch_size tail examples must be trained every epoch (they are the
    window's freshest records)."""
    n, bs, epochs = 100, 64, 3
    data = {"x": np.arange(n, dtype=np.float32).reshape(n, 1)}
    seen_per_epoch = n_batches = 0
    seen = set()
    for batch in batch_iterator(data, bs, epochs, jax.random.PRNGKey(0)):
        vals = np.asarray(batch["x"]).ravel()
        seen_per_epoch += len(vals)
        seen.update(int(v) for v in vals)
        n_batches += 1
    assert n_batches == epochs * 2          # 64 + ragged 36 per epoch
    assert seen_per_epoch == epochs * n     # every example, every epoch
    assert seen == set(range(n))


def test_batch_iterator_tiny_window_single_batch():
    n, bs = 10, 64
    data = {"x": np.arange(n, dtype=np.float32).reshape(n, 1)}
    batches = list(batch_iterator(data, bs, 2, jax.random.PRNGKey(0)))
    assert len(batches) == 2
    assert all(b["x"].shape[0] == n for b in batches)
