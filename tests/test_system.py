"""End-to-end behaviour tests for the paper's system: the full hybrid
stream-analytics pipeline (batch pretrain -> windowed stream -> speed
re-training -> static/dynamic hybrid inference) on drifting data."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (
    HybridStreamAnalytics,
    WindowedStream,
    WindowPlan,
    lstm_forecaster,
    make_supervised,
    pretrain_batch_model,
)
from repro.streams.normalize import MinMaxScaler
from repro.streams.sources import gradual_drift, wind_turbine_series


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("lstm-paper")
    series = wind_turbine_series(3200, seed=0)
    hist, stream_raw = series[:1600], series[1600:]
    stream = gradual_drift(stream_raw, alphas=np.full(5, 1.5e-3), seed=1)
    scaler = MinMaxScaler.fit(hist)
    fc_batch = lstm_forecaster(cfg, epochs=8, batch_size=256)
    fc_speed = lstm_forecaster(cfg, epochs=15, batch_size=64)
    bp, _ = pretrain_batch_model(
        fc_batch, make_supervised(scaler.transform(hist), 5, 0),
        jax.random.PRNGKey(0))
    plan = WindowPlan(n_windows=6, records_per_window=250, lag=5)
    ws = WindowedStream(scaler.transform(stream), plan)
    return cfg, fc_speed, bp, ws


def run_mode(setup, mode, solver="closed_form"):
    cfg, fc_speed, bp, ws = setup
    h = HybridStreamAnalytics(fc_speed, mode=mode, dwa_solver=solver)
    return h.run(ws, bp, jax.random.PRNGKey(1))


def test_speed_beats_batch_under_drift(setup):
    res = run_mode(setup, "speed")
    m = res.mean_rmse()
    assert m["speed"] < m["batch"], m


def test_dynamic_hybrid_close_to_best(setup):
    """Dynamic hybrid RMSE must be within a small margin of the best
    constituent (and strictly better than the worst)."""
    res = run_mode(setup, "dynamic")
    m = res.mean_rmse()
    best = min(m["speed"], m["batch"])
    worst = max(m["speed"], m["batch"])
    assert m["hybrid"] <= best * 1.10
    assert m["hybrid"] < worst


def test_dynamic_beats_static_extremes(setup):
    r_dyn = run_mode(setup, "dynamic").mean_rmse()["hybrid"]
    r_30 = run_mode(setup, ("static", 0.3)).mean_rmse()["hybrid"]
    # with drift, a batch-heavy static mix should lose to dynamic
    assert r_dyn < r_30


def test_dwa_solvers_agree_end_to_end(setup):
    r_cf = run_mode(setup, "dynamic", solver="closed_form")
    r_sp = run_mode(setup, "dynamic", solver="scipy")
    a = r_cf.mean_rmse()["hybrid"]
    b = r_sp.mean_rmse()["hybrid"]
    assert abs(a - b) / max(a, b) < 0.02
    # per-window weights close
    for rc, rs in zip(r_cf.records, r_sp.records):
        assert abs(rc.w_speed - rs.w_speed) < 0.02


def test_window_records_complete(setup):
    res = run_mode(setup, "dynamic")
    assert len(res.records) == 5  # windows 1..5 (first trains only)
    for r in res.records:
        assert np.isfinite([r.rmse_batch, r.rmse_speed, r.rmse_hybrid]).all()
        assert 0 <= r.w_speed <= 1 and abs(r.w_speed + r.w_batch - 1) < 1e-9
        assert r.t_speed_train > 0
