"""Launch-layer unit tests: override parsing, optimized presets, step specs,
mesh construction (logical), and the roofline report recompute path."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_shape
from repro.launch.dryrun import OPTIMIZED_PRESETS, apply_overrides, parse_overrides


def test_parse_overrides_types():
    ov = parse_overrides(["attn_chunk=2048", "moe.capacity_factor=1.0",
                          "scan_chunked=true", "attn_p_dtype=bfloat16"])
    assert ov == {"attn_chunk": 2048, "moe.capacity_factor": 1.0,
                  "scan_chunked": True, "attn_p_dtype": "bfloat16"}


def test_apply_overrides_nested():
    cfg = get_config("grok-1-314b")
    cfg2 = apply_overrides(cfg, {"moe.ep_mode": "shard_map",
                                 "attn_chunk": 512})
    assert cfg2.moe.ep_mode == "shard_map"
    assert cfg2.attn_chunk == 512
    assert cfg.moe.ep_mode == "auto"  # original untouched


def test_optimized_presets_valid():
    for arch, ov in OPTIMIZED_PRESETS.items():
        cfg = apply_overrides(get_config(arch), ov)
        assert cfg.name == arch


def test_adamw_bf16_moments_still_learn():
    from repro.training import adamw

    opt = adamw(0.1, moment_dtype="bfloat16", clip_norm=None)
    p = {"w": jnp.asarray([5.0])}
    st = opt.init(p)
    assert st.mu["w"].dtype == jnp.bfloat16
    for _ in range(60):
        g = {"w": 2 * p["w"]}
        p, st, _ = opt.update(g, st, p)
    # bf16 moment quantization stalls near the optimum; descent from 5.0
    # to <0.6 is the capacity/quality tradeoff being tested
    assert abs(float(p["w"][0])) < 0.6


def test_roofline_recompute_from_artifact():
    # representative artifact (if the sweep has run)
    path = "experiments/dryrun/tinyllama-1.1b_train_4k_single.json"
    if not os.path.exists(path):
        pytest.skip("no dry-run artifacts")
    from benchmarks.roofline_report import recompute

    rec = json.load(open(path))
    row = recompute(rec)
    assert row["dominant"] in ("compute", "memory", "collective")
    assert row["compute_s"] > 0 and row["memory_s"] > 0
    assert 0 < row["useful_ratio"] <= 10.0


def test_build_step_specs_have_shardings():
    from repro.launch.steps import build_step
    from repro.distributed.sharding import AxisRules

    cfg = get_config("tinyllama-1.1b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    fn, specs = build_step(cfg, get_shape("decode_32k"), mesh, AxisRules())
    leaves = jax.tree_util.tree_leaves(specs)
    assert all(x.sharding is not None for x in leaves)
    # decode step: token/pos/cache present
    assert specs["batch"]["token"].shape == (128, 1)
    assert specs["cache"]["k"].shape[2] == 32768
