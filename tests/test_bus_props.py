"""Property tests on the TopicBus wildcard matcher and subscriber
re-registration — the two bus behaviors the elastic placement controller
leans on (per-stream exact-topic subscriptions moved between sites at
migration time, ``+`` patterns at any segment position).

Each property has two forms: an exhaustive/seeded deterministic sweep that
always runs, and a hypothesis ``@given`` version (skipped when hypothesis
isn't installed, via the suite's stub) that explores a much larger space.
"""
import itertools

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property tests skip; deterministic tests run
    from _hypothesis_stub import given, settings, st

from repro.runtime import (
    EventKernel,
    Link,
    Site,
    TopicBus,
    Topology,
    topic_matches,
)


def ref_matches(pattern: str, topic: str) -> bool:
    """Independent reference for MQTT single-level-wildcard matching: equal
    segment counts, every pattern segment either ``+`` or an exact match."""
    ps, ts = pattern.split("/"), topic.split("/")
    if len(ps) != len(ts):
        return False
    return all(p == t or p == "+" for p, t in zip(ps, ts))


def two_site_bus():
    topo = Topology(
        sites={
            "edge": Site("edge", "edge", workers=1),
            "cloud": Site("cloud", "cloud", workers=1),
        },
        links={("edge", "cloud"): Link(latency_s=0.01, bandwidth_Bps=1e6)},
    )
    k = EventKernel()
    return k, TopicBus(k, topo)


def delivered(pattern: str, topic: str) -> bool:
    """Subscribe ``pattern`` at cloud, publish ``topic`` from edge, and
    report whether the bus delivered it."""
    k, bus = two_site_bus()
    got = []
    bus.subscribe(pattern, "cloud", got.append)
    bus.publish(topic, {"x": 1}, nbytes=8.0, src="edge")
    k.run()
    assert len(got) <= 1, "a single subscription must never double-deliver"
    return bool(got)


SEGS = ["a", "b", "+"]
TOPIC_SEGS = ["a", "b", "c"]


def all_patterns(max_len=3):
    for n in range(1, max_len + 1):
        for combo in itertools.product(SEGS, repeat=n):
            yield "/".join(combo)


def all_topics(max_len=3):
    for n in range(1, max_len + 1):
        for combo in itertools.product(TOPIC_SEGS, repeat=n):
            yield "/".join(combo)


# ---------------------------------------------------------------------------
# wildcard matching == reference semantics, end to end through the bus
# ---------------------------------------------------------------------------


def test_topic_matches_agrees_with_reference_exhaustive():
    """Every (pattern, topic) pair over a 3-segment alphabet — covers leaf
    ``+``, interior ``+`` (the scan-list path), multi-``+``, bare ``+``,
    and every length mismatch."""
    for pat in all_patterns():
        for top in all_topics():
            assert topic_matches(pat, top) == ref_matches(pat, top), \
                (pat, top)


def test_bus_delivery_agrees_with_matcher_exhaustive():
    """The bus's actual delivery decision (dict fast path + scan list) must
    equal ``topic_matches`` for every pair — a subscription routed to the
    wrong lookup structure shows up as a missed or spurious delivery."""
    for pat in all_patterns():
        for top in all_topics():
            assert delivered(pat, top) == topic_matches(pat, top), (pat, top)


def test_bus_delivery_agrees_with_matcher_seeded_random():
    """Wider random sweep: longer topics, bigger alphabet, fixed seed."""
    rng = np.random.default_rng(0)
    alphabet = ["a", "b", "c", "win", "t00", "stream"]
    for _ in range(300):
        n_p = int(rng.integers(1, 5))
        n_t = int(rng.integers(1, 5))
        pat = "/".join(
            "+" if rng.random() < 0.35
            else alphabet[int(rng.integers(len(alphabet)))]
            for _ in range(n_p))
        top = "/".join(alphabet[int(rng.integers(len(alphabet)))]
                       for _ in range(n_t))
        assert delivered(pat, top) == topic_matches(pat, top) \
            == ref_matches(pat, top), (pat, top)


@st.composite
def pattern_topic(draw):
    alphabet = ["a", "b", "c", "d", "t00", "window"]
    n_p = draw(st.integers(1, 5))
    n_t = draw(st.integers(1, 5))
    pat = "/".join(
        draw(st.sampled_from(alphabet + ["+"])) for _ in range(n_p))
    top = "/".join(draw(st.sampled_from(alphabet)) for _ in range(n_t))
    return pat, top


@given(pattern_topic())
@settings(max_examples=200, deadline=None)
def test_bus_delivery_agrees_with_matcher_property(case):
    pat, top = case
    assert delivered(pat, top) == topic_matches(pat, top) \
        == ref_matches(pat, top)


# ---------------------------------------------------------------------------
# subscriber re-registration (the migration primitive)
# ---------------------------------------------------------------------------


def _reregister_roundtrip(pattern: str, topic: str) -> None:
    """unsubscribe at one site + resubscribe at another must move exactly
    one registration: the topic then delivers to the new site only."""
    k, bus = two_site_bus()
    at_edge, at_cloud = [], []
    bus.subscribe(pattern, "edge", at_edge.append)
    assert bus.unsubscribe(pattern, "edge", at_edge.append) in (True, False)
    # bound list.append identity differs per lookup; register real handlers
    k, bus = two_site_bus()

    def on_edge(m):
        at_edge.append(m)

    def on_cloud(m):
        at_cloud.append(m)

    bus.subscribe(pattern, "edge", on_edge)
    assert bus.unsubscribe(pattern, "edge", on_edge)
    assert not bus.unsubscribe(pattern, "edge", on_edge), \
        "second unsubscribe of the same registration must be a no-op"
    bus.subscribe(pattern, "cloud", on_cloud)
    bus.publish(topic, {"x": 1}, nbytes=8.0, src="edge")
    k.run()
    expect = topic_matches(pattern, topic)
    assert at_edge == []
    assert len(at_cloud) == (1 if expect else 0), (pattern, topic)


def test_reregistration_exhaustive():
    for pat in all_patterns():
        for top in all_topics(max_len=2):
            _reregister_roundtrip(pat, top)


def test_unsubscribe_removes_one_of_duplicates():
    """Two identical registrations: removing one must leave the other
    delivering (the fleet executor registers one handler per stream)."""
    k, bus = two_site_bus()
    got = []

    def fn(m):
        got.append(m)

    bus.subscribe("s/+", "cloud", fn)
    bus.subscribe("s/+", "cloud", fn)
    assert bus.unsubscribe("s/+", "cloud", fn)
    bus.publish("s/x", {}, nbytes=1.0, src="edge")
    k.run()
    assert len(got) == 1


def test_unsubscribe_unknown_pattern_is_false():
    _, bus = two_site_bus()
    assert not bus.unsubscribe("never/registered", "edge", lambda m: None)
    assert not bus.unsubscribe("+/interior/+", "edge", lambda m: None)


@given(pattern_topic())
@settings(max_examples=100, deadline=None)
def test_reregistration_property(case):
    pat, top = case
    _reregister_roundtrip(pat, top)


def test_inflight_delivery_survives_migration():
    """A message already in flight when its subscriber re-registers at a new
    site still reaches the handler it was matched to at publish time — the
    executor's zero-dropped-windows-during-migration guarantee."""
    k, bus = two_site_bus()
    got = []

    def fn(m):
        got.append(m)

    bus.subscribe("w/t00", "cloud", fn)
    bus.publish("w/t00", {"n": 1}, nbytes=8.0, src="edge")  # in flight
    assert bus.unsubscribe("w/t00", "cloud", fn)
    bus.subscribe("w/t00", "edge", fn)
    k.run()
    assert len(got) == 1 and got[0].payload == {"n": 1}
