"""Elastic placement tests: the controller's migration/scaling policy in
isolation (hysteresis, scale-ahead), the queue-depth signal it feeds on,
and the end-to-end contract inside ``FleetBusExecutor(elastic=True)`` —
no-spike runs match static placement exactly, spike runs migrate at least
one stream with zero dropped windows, and the aggregated
one-dispatch-per-window train/predict path survives a migration."""
import jax
import numpy as np
import pytest

from repro.core.scenarios import CHAOS_STAGE_COSTS, forecast_signature
from repro.runtime import (
    FleetBusExecutor,
    LatencyLedger,
    LoadForecaster,
    PlacementController,
    SiteSignal,
    StreamSignal,
    paper_topology,
)
from repro.runtime.deployment import edge_cloud_integrated

PERIOD = 5.0


def sigs(edge_backlog=0.0, cloud_backlog=0.0, edge_workers=1,
         cloud_workers=4):
    return [
        SiteSignal("edge", "edge", edge_workers, 1, edge_backlog),
        SiteSignal("cloud", "cloud", cloud_workers, 4, cloud_backlog),
    ]


def reactive(**kw):
    kw.setdefault("proactive", False)
    return PlacementController(**kw)


# ---------------------------------------------------------------------------
# controller policy: migration
# ---------------------------------------------------------------------------


def test_drifting_stream_migrates_to_cloud():
    ctl = reactive(persistence=2, min_residency=0)
    stream = StreamSignal("t00", "edge", drift_hot=1.0, queue_s=0.0)
    d1 = ctl.step(0.0, sigs(), [stream])
    assert d1.migrations == {}, "one hot tick must not move anything"
    d2 = ctl.step(1.0, sigs(), [stream])
    assert d2.migrations == {"t00": "cloud"}
    ev = [e for e in ctl.events if e["event"] == "migrate"]
    assert ev and ev[0]["reason"] == "hot"


def test_queued_stream_migrates_to_cloud():
    """No drift signal at all: sustained per-worker backlog on the stream's
    site alone must push it to the cloud."""
    ctl = reactive(persistence=2, min_residency=0, migrate_up_s=0.5)
    stream = StreamSignal("t00", "edge", drift_hot=0.0, queue_s=3.0)
    out = {}
    for k in range(4):
        out = ctl.step(float(k), sigs(edge_backlog=3.0), [stream]).migrations
        if out:
            break
    assert out == {"t00": "cloud"}


def test_cold_stream_demotes_to_edge():
    ctl = reactive(persistence=2, min_residency=0)
    stream = StreamSignal("t00", "cloud", drift_hot=0.0, queue_s=0.0)
    d1 = ctl.step(0.0, sigs(), [stream])
    d2 = ctl.step(1.0, sigs(), [stream])
    assert d1.migrations == {} and d2.migrations == {"t00": "edge"}


def test_cold_demotion_requires_idle_edge():
    """A stationary stream must NOT demote onto an edge that is itself
    saturated — demotion is a cost optimization, not an obligation."""
    ctl = reactive(persistence=2, min_residency=0)
    stream = StreamSignal("t00", "cloud", drift_hot=0.0, queue_s=0.0)
    for k in range(5):
        d = ctl.step(float(k), sigs(edge_backlog=5.0), [stream])
        assert d.migrations == {}


def test_min_residency_blocks_immediate_bounce():
    """hot -> cloud, then instantly-cold conditions: the stream stays put
    for ``min_residency`` ticks instead of bouncing straight back."""
    ctl = reactive(persistence=1, min_residency=3)
    hot = StreamSignal("t00", "edge", drift_hot=1.0, queue_s=0.0)
    d = ctl.step(0.0, sigs(), [hot])
    assert d.migrations == {"t00": "cloud"}
    cold = StreamSignal("t00", "cloud", drift_hot=0.0, queue_s=0.0)
    moved_at = None
    for k in range(1, 6):
        if ctl.step(float(k), sigs(), [cold]).migrations:
            moved_at = k
            break
    # moved at tick 1, residency 3 -> earliest return is controller tick 4
    # (k=3), and the cold streak must also rebuild from zero after the move
    assert moved_at is not None and moved_at >= 3


def test_migrations_per_tick_are_capped():
    ctl = reactive(persistence=1, min_residency=0, max_migrations_per_tick=2)
    streams = [StreamSignal(f"t{i:02d}", "edge", 1.0, 0.0) for i in range(5)]
    d = ctl.step(0.0, sigs(), streams)
    assert len(d.migrations) == 2


# ---------------------------------------------------------------------------
# controller policy: scaling hysteresis
# ---------------------------------------------------------------------------


def test_reactive_scale_up_then_down_to_base():
    ctl = reactive(persistence=2, cooldown=0, max_workers=3)
    workers = 1
    for k in range(6):
        d = ctl.step(float(k), sigs(edge_backlog=4.0 * workers,
                                    edge_workers=workers), [])
        workers = d.workers.get("edge", workers)
    assert workers == 3, "sustained overload must reach max_workers"
    for k in range(6, 16):
        d = ctl.step(float(k), sigs(edge_backlog=0.0, edge_workers=workers),
                     [])
        workers = d.workers.get("edge", workers)
    assert workers == 1, "idle must shrink back to base_workers, never below"
    s = ctl.stats()
    assert s["scale_events"] >= 4 and s["proactive_scale_events"] == 0


def test_oscillating_load_does_not_flap():
    """Load alternating hard between overload and idle every tick: the EWMA
    + persistence + dead-band hysteresis must hold the worker count still."""
    ctl = reactive(persistence=2, cooldown=2)
    for k in range(20):
        load = 0.8 if k % 2 == 0 else 0.0
        d = ctl.step(float(k), sigs(edge_backlog=load), [])
        assert d.workers == {}, f"flapped at tick {k}: {d.workers}"
    assert ctl.stats()["scale_events"] == 0


def test_dead_band_load_changes_nothing():
    """Load sitting between scale_down_s and scale_up_s is steady state."""
    ctl = reactive(scale_up_s=0.5, scale_down_s=0.05, persistence=1,
                   cooldown=0)
    for k in range(10):
        d = ctl.step(float(k), sigs(edge_backlog=0.2), [])
        assert d.empty()


def test_cooldown_spaces_scale_events():
    ctl = reactive(persistence=1, cooldown=3, max_workers=8)
    ticks_changed = []
    workers = 1
    for k in range(9):
        d = ctl.step(float(k), sigs(edge_backlog=10.0 * workers,
                                    edge_workers=workers), [])
        if "edge" in d.workers:
            workers = d.workers["edge"]
            ticks_changed.append(k)
    assert all(b - a >= 3 for a, b in zip(ticks_changed, ticks_changed[1:]))
    assert len(ticks_changed) >= 2


def test_inverted_hysteresis_thresholds_raise():
    with pytest.raises(ValueError):
        PlacementController(scale_up_s=0.1, scale_down_s=0.2)
    with pytest.raises(ValueError):
        PlacementController(migrate_up_s=0.05, migrate_down_s=0.05)


# ---------------------------------------------------------------------------
# proactive scale-ahead
# ---------------------------------------------------------------------------


def test_load_forecaster_sees_ramp_coming():
    fc = LoadForecaster(horizon=2, epochs=4)
    ramp = [0.05 * k for k in range(8)]
    pred = fc.forecast(ramp)
    assert pred > ramp[-1], "a linear ramp must forecast above its last point"
    assert fc.fits == 1
    assert fc.forecast([0.0] * 8) <= 1e-6, "idle history forecasts ~zero"
    assert fc.forecast([0.1, 0.2]) == pytest.approx(0.2), \
        "short history falls back to the last sample"


def test_proactive_scales_ahead_of_reactive_threshold():
    """Feed a ramp that stays below the reactive trigger: the forecaster
    must scale the site up while the reactive path would still be idle."""
    ctl = PlacementController(proactive=True, persistence=2, cooldown=0,
                              scale_up_s=0.5, max_workers=2,
                              forecaster=LoadForecaster(horizon=3, epochs=4))
    scaled_at = None
    for k in range(10):
        load = 0.07 * (k + 1)  # reaches only 0.7 at k=9; ewma lags lower
        d = ctl.step(float(k), sigs(edge_backlog=load), [])
        if d.workers.get("edge") == 2:
            scaled_at = k
            break
    assert scaled_at is not None, "proactive path never fired on a ramp"
    s = ctl.stats()
    assert s["proactive_scale_events"] == 1 and s["forecaster_fits"] >= 1
    ev = [e for e in ctl.events if e["event"] == "scale"][0]
    assert ev["trigger"] == "proactive-up"
    assert ev["ewma"] < 0.5, "must have fired before the reactive threshold"


# ---------------------------------------------------------------------------
# the queue-depth signal
# ---------------------------------------------------------------------------


def test_ledger_depth_sampling_and_ewma():
    led = LatencyLedger()
    assert led.depth_series("edge") == [] and led.depth_ewma("edge") == 0.0
    led.sample_depth("edge", 0.0, 1.0)
    led.sample_depth("edge", 1.0, 3.0)
    assert led.depth_series("edge") == [(0.0, 1.0), (1.0, 3.0)]
    a = 0.3
    assert led.depth_ewma("edge", a) == pytest.approx(
        (1 - a) * (a * 1.0) + a * 3.0)
    assert "edge" not in led.table(), "depth samples must not leak into the" \
        " per-module table (ledger_signature compatibility)"


# ---------------------------------------------------------------------------
# end-to-end: FleetBusExecutor(elastic=True)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def pipeline():
    from repro.launch.edge_cloud import build_fleet_pipeline

    return build_fleet_pipeline(2, 4, fast=True, records_per_window=80,
                                scenario="gradual", verbose=False)


def make_executor(pipeline, *, elastic=False, qps=6.0, stage_costs=None,
                  controller_factory=None):
    stages, bp, streams, cost = pipeline
    ex = FleetBusExecutor(
        stages, edge_cloud_integrated(), paper_topology(), cost,
        window_period_s=PERIOD, qps=qps, serve_slots=4,
        stage_costs=dict(stage_costs or CHAOS_STAGE_COSTS), elastic=elastic,
        controller_factory=controller_factory)
    return ex, streams, bp


def spike_costs():
    costs = dict(CHAOS_STAGE_COSTS)
    costs["serving"] = 0.35
    costs["speed_inference"] = 0.4
    costs["batch_inference"] = 0.4
    return costs


def spike_controller():
    return PlacementController(proactive=True, migrate_up_s=0.15,
                               scale_up_s=0.6, persistence=1, cooldown=1,
                               max_workers=2, min_residency=2)


def test_elastic_no_spike_matches_static(pipeline):
    """Calm load: the controller observes but never acts, so per-stream
    forecasts, window RMSE, and served answers are *identical* to static
    placement (<= 1e-6 by the acceptance bar; exactly equal in practice)."""
    ex_s, streams, bp = make_executor(pipeline, elastic=False)
    ex_e, _, _ = make_executor(pipeline, elastic=True)
    rs = ex_s.run(streams, bp, jax.random.PRNGKey(1))
    re = ex_e.run(streams, bp, jax.random.PRNGKey(1))
    assert re.placement is not None and re.placement["migrations"] == []
    for sid in rs.results:
        a, b = rs.results[sid].records, re.results[sid].records
        assert len(a) == len(b)
        for ra, rb in zip(a, b):
            assert abs(ra.rmse_hybrid - rb.rmse_hybrid) <= 1e-6
            assert abs(ra.rmse_speed - rb.rmse_speed) <= 1e-6
            assert abs(ra.rmse_batch - rb.rmse_batch) <= 1e-6
    assert forecast_signature(rs) == forecast_signature(re)


def test_spike_migrates_without_dropping_windows(pipeline):
    ex, streams, bp = make_executor(
        pipeline, elastic=True, qps=25.0, stage_costs=spike_costs(),
        controller_factory=spike_controller)
    res = ex.run(streams, bp, jax.random.PRNGKey(1))
    p = res.placement
    assert len(p["migrations"]) >= 1, "spike must push a stream to the cloud"
    assert all(m["to"] == "cloud" and m["state_nbytes"] > 0
               for m in p["migrations"])
    # zero dropped windows: every stream scores every post-warmup window
    n_expected = 3  # 4 windows - 1 warmup
    for sid, r in res.results.items():
        assert len(r.records) == n_expected, (sid, len(r.records))
        assert [rec.window for rec in r.records] == list(range(1, 4))
    # the aggregated fleet dispatch path survived the migration: one
    # train dispatch per published window (warmup included) and one
    # predict dispatch per scored window, per kind
    assert res.train_dispatches == 4
    for kind in ("batch", "speed"):
        d = res.infer_dispatches[kind]
        assert d["ticks"] == d["dispatches"] == n_expected, (kind, d)
    assert "placement_migration" in res.ledger.table()


def test_elastic_runs_are_byte_identical(pipeline):
    """Determinism regression: two seeded elastic runs (chaos off) produce
    byte-identical ledgers, depth series, forecasts, and final fleet
    params — the controller (fresh per run) replays its decisions exactly."""
    ex, streams, bp = make_executor(
        pipeline, elastic=True, qps=25.0, stage_costs=spike_costs(),
        controller_factory=spike_controller)
    r1 = ex.run(streams, bp, jax.random.PRNGKey(1))
    r2 = ex.run(streams, bp, jax.random.PRNGKey(1))
    assert r1.ledger.table() == r2.ledger.table()
    for site in ("edge", "cloud"):
        assert r1.ledger.depth_series(site) == r2.ledger.depth_series(site)
    assert forecast_signature(r1) == forecast_signature(r2)
    assert r1.placement["migrations"] == r2.placement["migrations"]
    assert r1.placement["stream_site"] == r2.placement["stream_site"]
    for sid in r1.final_params:
        l1 = jax.tree_util.tree_leaves(r1.final_params[sid])
        l2 = jax.tree_util.tree_leaves(r2.final_params[sid])
        assert len(l1) == len(l2)
        for a, b in zip(l1, l2):
            assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


def test_elastic_run_samples_queue_depth(pipeline):
    """The depth series the controller feeds on must actually be populated
    — both at stage entry and at publish time (the publish-time fix)."""
    ex, streams, bp = make_executor(pipeline, elastic=True)
    res = ex.run(streams, bp, jax.random.PRNGKey(1))
    edge = res.ledger.depth_series("edge")
    assert len(edge) > 0
    ts = [t for t, _ in edge]
    assert ts == sorted(ts), "samples must arrive in virtual-time order"
    # worker restoration: the run must not leak scaled worker counts into
    # the (shared) topology object
    assert res.placement["base_workers"] == {"edge": 1, "cloud": 4}
    assert ex.topo.sites["edge"].workers == 1
