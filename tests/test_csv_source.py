"""CSV stream source tests (ENGIE-format roundtrip, gap handling)."""
import os
import tempfile

import numpy as np

from repro.streams.csv_source import (
    PAPER_CHANNELS,
    read_csv,
    read_csv_str,
    write_csv,
)
from repro.streams.sources import wind_turbine_series


def test_roundtrip():
    data = wind_turbine_series(200, seed=0)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "turbine.csv")
        write_csv(path, data)
        back = read_csv(path)
    np.testing.assert_allclose(back, data, atol=1e-3)


def test_column_selection_and_order():
    text = "Date_time,Ot_avg,Db1t_avg,junk,Db2t_avg,Gb1t_avg,Gb2t_avg\n"
    text += "t0,10,1,x,2,3,4\nt1,11,5,y,6,7,8\n"
    arr = read_csv_str(text)
    np.testing.assert_allclose(arr, [[1, 2, 3, 4, 10], [5, 6, 7, 8, 11]])


def test_forward_fill_gaps():
    text = "Db1t_avg,Db2t_avg,Gb1t_avg,Gb2t_avg,Ot_avg\n"
    text += "1,2,3,4,5\n,NA,3.5,nan,6\n"
    arr = read_csv_str(text)
    np.testing.assert_allclose(arr, [[1, 2, 3, 4, 5], [1, 2, 3.5, 4, 6]])


def test_leading_incomplete_rows_dropped():
    text = "Db1t_avg,Db2t_avg,Gb1t_avg,Gb2t_avg,Ot_avg\n"
    text += ",2,3,4,5\n1,2,3,4,5\n"
    arr = read_csv_str(text)
    assert arr.shape == (1, 5)


def test_max_rows():
    data = wind_turbine_series(100, seed=1)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.csv")
        write_csv(path, data)
        back = read_csv(path, max_rows=10)
    assert back.shape == (10, len(PAPER_CHANNELS))
