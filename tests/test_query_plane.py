"""Request-plane tests: slot recycling under staggered arrivals, FIFO
no-starvation, batched-vs-unbatched answer parity, deterministic open-loop
traces, and a trace replayed end-to-end through ``FleetBusExecutor`` (every
request answered on its stream's response topic, one vmapped dispatch per
serving tick, stale-bounded serving models)."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (
    lstm_fleet_forecaster,
    lstm_forecaster,
    pretrain_batch_model,
)
from repro.core.stages import FleetStages, ServingStage
from repro.runtime import (
    FleetBusExecutor,
    edge_cloud_integrated,
    fleet_key_chains,
    paper_topology,
)
from repro.runtime.modules import T_RESPONSE, stream_topic
from repro.serving.batching import BatchScheduler, Request
from repro.serving.query_plane import (
    ForecastQuery,
    QueryPlane,
    answer_query_unbatched,
    latency_stats,
    open_loop_trace,
)
from repro.streams.sources import fleet_windowed_streams

N_STREAMS = 3
RPW = 150
EPOCHS = 4


@pytest.fixture(scope="module")
def cfg():
    return get_config("lstm-paper")


@pytest.fixture(scope="module")
def fleet_setup(cfg):
    streams, hist0 = fleet_windowed_streams(
        N_STREAMS, 3, RPW, "gradual", seed=0, hist_len=1200,
        alphas=np.full(5, 1.5e-3))
    fc_batch = lstm_forecaster(cfg, epochs=4, batch_size=256)
    bp, _ = pretrain_batch_model(fc_batch, hist0, jax.random.PRNGKey(0))
    return streams, bp


# ---------------------------------------------------------------------------
# scheduler: slot recycling + clock stamping
# ---------------------------------------------------------------------------


def _req(uid, n_new=1):
    return Request(uid=uid, prompt=np.arange(3, dtype=np.int32),
                   max_new_tokens=n_new)


def test_scheduler_slot_recycling_staggered_arrivals():
    """Slots freed by short requests refill from the queue in FIFO order
    without waiting for the long co-batched request to drain; the runtime
    clock stamps both admission and finish."""
    s = BatchScheduler(2)
    long_req = _req(0, n_new=5)
    s.submit(long_req)
    s.submit(_req(1, n_new=1))
    assert s.admit(now=0.0) == [0, 1]
    assert long_req.admitted_at == 0.0

    s.submit(_req(2, n_new=1))  # staggered arrival: queue is full
    assert s.admit(now=1.0) == []  # no free slot yet

    # request 1 finishes -> its slot recycles, request 2 admits next tick
    s.slots[1].request.generated.append(7)
    done = s.retire_finished(now=2.0)
    assert [r.uid for r in done] == [1] and done[0].finished_at == 2.0
    assert s.admit(now=3.0) == [1]
    assert s.slots[1].request.uid == 2
    assert s.slots[1].request.admitted_at == 3.0
    # the long request never left its slot
    assert s.slots[0].request is long_req
    assert not s.idle


def test_scheduler_retire_requires_clock():
    """``retire_finished`` no longer silently stamps 0.0 — the clock is a
    required argument."""
    with pytest.raises(TypeError):
        BatchScheduler(1).retire_finished()


def test_queryplane_fifo_no_starvation():
    """A queue much longer than the slot count drains completely in FIFO
    admission order — multi-tick horizon queries occupy slots but never
    push later queries out of order or starve them."""
    ids = ["a", "b"]
    plane = QueryPlane(ids, n_slots=2)
    ctx = np.ones((5, 5), np.float32)
    for sid in ids:
        plane.observe_window(sid, ctx[None].repeat(3, 0).reshape(3, 5, 5), 0)
    qs = [ForecastQuery(uid=i, stream=ids[i % 2],
                        kind="horizon" if i % 3 == 0 else "point",
                        horizon=3 if i % 3 == 0 else 1)
          for i in range(9)]
    for q in qs:
        plane.submit(q)

    preds_const = lambda xs: [np.full((len(x), 1), 0.5) for x in xs]
    tick = 0
    while plane.busy:
        plane.admit(float(tick))
        batch = plane.build_batch()
        assert batch is not None
        by_stream, xs = batch
        plane.apply(by_stream, preds_const(xs), {sid: 0 for sid in ids})
        plane.retire(float(tick))
        tick += 1
        assert tick < 50, "queue starved"

    assert all(q.done and q.finished_at is not None for q in qs)
    # strict FIFO: admission times never decrease in submission order
    admits = [q.admitted_at for q in qs]
    assert admits == sorted(admits)


def test_queryplane_blocks_until_stream_has_context():
    """A queue-head query for a stream with no window yet holds admission
    (strict FIFO, no reordering) and admits as soon as the context lands."""
    plane = QueryPlane(["a", "b"], n_slots=2)
    x = np.ones((3, 5, 5), np.float32)
    plane.observe_window("b", x, 0)
    plane.submit(ForecastQuery(uid=0, stream="a"))
    plane.submit(ForecastQuery(uid=1, stream="b"))
    assert plane.admit(0.0) == []  # head blocks, "b" must wait behind it
    plane.observe_window("a", x, 0)
    assert plane.admit(1.0) == [0, 1]


def test_whatif_perturbs_context_once():
    plane = QueryPlane(["a"], n_slots=1)
    x = np.full((3, 5, 5), 2.0, np.float32)
    plane.observe_window("a", x, 0)
    q = ForecastQuery(uid=0, stream="a", kind="whatif",
                      perturb_scale=2.0, perturb_offset=1.0)
    plane.submit(q)
    plane.admit(0.0)
    np.testing.assert_allclose(q.ctx, 2.0 * 2.0 + 1.0)


# ---------------------------------------------------------------------------
# batched vs unbatched answers
# ---------------------------------------------------------------------------


def test_batched_vs_unbatched_answer_parity(fleet_setup, cfg):
    """Every query kind answered by the batched serving tick matches the
    unbatched per-query reference to vmap tolerance, including multi-step
    horizon feedback and same-stream queries sharing a tick."""
    streams, _ = fleet_setup
    ids = list(streams)
    ff = lstm_fleet_forecaster(cfg, epochs=EPOCHS, batch_size=64)
    keys = fleet_key_chains(jax.random.PRNGKey(3), ids, 1)
    params, _ = ff.train_fleet(
        [streams[sid].supervised(0) for sid in ids],
        [keys[sid][0] for sid in ids])
    base_ctx = {sid: np.asarray(streams[sid].supervised(0)["x"])[-1]
                for sid in ids}

    qs = [
        ForecastQuery(uid=0, stream=ids[0]),
        ForecastQuery(uid=1, stream=ids[0], kind="horizon", horizon=3),
        ForecastQuery(uid=2, stream=ids[1], kind="whatif",
                      perturb_scale=1.1, perturb_offset=0.05),
        ForecastQuery(uid=3, stream=ids[2], kind="horizon", horizon=2),
        ForecastQuery(uid=4, stream=ids[1]),
    ]
    plane = QueryPlane(ids, n_slots=5)
    for sid in ids:
        plane.observe_window(sid, streams[sid].supervised(0)["x"], 0)
    for q in qs:
        plane.submit(q)

    stage = ServingStage(ff)
    tick = 0
    while plane.busy:
        plane.admit(float(tick))
        by_stream, xs = plane.build_batch()
        out = stage(params_seq=params, xs=xs)
        plane.apply(by_stream, out["preds"], {sid: 0 for sid in ids})
        plane.retire(float(tick))
        tick += 1

    assert stage.dispatches == stage.ticks  # one vmapped dispatch per tick
    for q in qs:
        ref = answer_query_unbatched(
            ff.single.predict, params[ids.index(q.stream)], q,
            base_ctx[q.stream])
        assert len(q.answer) == q.horizon
        assert max(abs(a - b) for a, b in zip(q.answer, ref)) <= 1e-6


# ---------------------------------------------------------------------------
# open-loop trace + full bus replay
# ---------------------------------------------------------------------------


def test_open_loop_trace_deterministic():
    a = open_loop_trace(["s0", "s1"], qps=10.0, n_requests=40, seed=7)
    b = open_loop_trace(["s0", "s1"], qps=10.0, n_requests=40, seed=7)
    c = open_loop_trace(["s0", "s1"], qps=10.0, n_requests=40, seed=8)
    assert [(q.stream, q.kind, q.horizon, q.perturb_scale, q.perturb_offset,
             q.arrived_at) for q in a] == \
           [(q.stream, q.kind, q.horizon, q.perturb_scale, q.perturb_offset,
             q.arrived_at) for q in b]
    assert [(q.kind, q.perturb_scale) for q in a] != \
           [(q.kind, q.perturb_scale) for q in c]
    # exact open-loop spacing, round-robin streams
    assert a[1].arrived_at - a[0].arrived_at == pytest.approx(0.1)
    assert [q.stream for q in a[:4]] == ["s0", "s1", "s0", "s1"]


def test_latency_stats_empty_is_infinite():
    s = latency_stats([])
    assert s["p99_s"] == float("inf") and s["p50_s"] == float("inf")


def test_fleet_bus_serving_replays_trace_e2e(fleet_setup, cfg):
    """A deterministic arrival trace replayed through the full fleet bus:
    every request is answered on its own stream's response topic, serving
    costs one vmapped dispatch per tick, and every answer's serving model
    trails its context by at most one training window."""
    streams, bp = fleet_setup
    ids = list(streams)
    ff = lstm_fleet_forecaster(cfg, epochs=EPOCHS, batch_size=64)
    trace = open_loop_trace(ids, qps=12.0, n_requests=30, start=5.0, seed=3)
    ex = FleetBusExecutor(
        FleetStages.build(ff, mode="dynamic"), edge_cloud_integrated(),
        paper_topology(), window_period_s=5.0, query_trace=trace,
        serve_slots=4)
    res = ex.run(streams, bp, jax.random.PRNGKey(1), n_windows=3)

    s = res.serving
    assert s is not None
    assert s["n_requests"] == 30
    assert s["n_starved"] == 0 and s["n_answered"] == 30
    assert s["dispatches_per_tick"] == 1.0
    assert s["sustained_qps"] >= s["offered_qps"]
    assert np.isfinite(s["p99_s"]) and s["p99_s"] > 0

    # per-stream response topics, one response per request
    resp_topics = [m.topic for m in res.message_log
                   if m.topic.startswith(T_RESPONSE)]
    assert len(resp_topics) == 30
    for q in res.queries:
        assert stream_topic(T_RESPONSE, q.stream) in resp_topics
        assert q.done and q.finished_at is not None
        assert q.admitted_at >= q.arrived_at
        # staleness bound: the serving model is at most one training
        # window behind the context it answered against
        assert 0 <= q.context_window - q.model_window <= 1


def test_staleness_watchdog_serves_fallback_under_delayed_sync(fleet_setup,
                                                               cfg):
    """Regression for the staleness watchdog: when every model-sync publish
    is delayed well past the staleness bound, answers whose speed model has
    fallen more than ``staleness_bound`` windows behind their context must
    be served from the batch fallback (and stamped ``served_fallback``),
    while every answer still served from a speed model keeps honouring the
    bound."""
    from repro.core.scenarios import CHAOS_STAGE_COSTS
    from repro.runtime import FaultPlane, MessageFault

    streams, bp = fleet_setup
    ids = list(streams)
    ff = lstm_fleet_forecaster(cfg, epochs=EPOCHS, batch_size=64)
    period = 5.0
    # window 0's sync (published ~0.3s) lands clean; every later sync is
    # delayed 3 windows, so the serving model is pinned at window 0
    plane = FaultPlane(0, message_faults=[
        MessageFault("model/latest/*", "delay", p=1.0, delay_s=3 * period,
                     start=0.8 * period)])
    # arrivals span windows 1..2+, after the delayed syncs start biting
    trace = open_loop_trace(ids, qps=3.0, n_requests=30, start=2 * period,
                            seed=3)
    ex = FleetBusExecutor(
        FleetStages.build(ff, mode="dynamic"), edge_cloud_integrated(),
        paper_topology(), window_period_s=period, query_trace=trace,
        serve_slots=4, fault_plane=plane,
        stage_costs=dict(CHAOS_STAGE_COSTS), staleness_bound=1)
    res = ex.run(streams, bp, jax.random.PRNGKey(1), n_windows=3)

    s = res.serving
    assert s is not None and s["n_answered"] == 30
    assert plane.stats["msg_delay"] > 0
    # the watchdog flipped stale answers to the fallback ...
    assert s["fallback_frac"] > 0.0
    assert any(q.served_fallback for q in res.queries)
    # ... and whatever was still served from a speed model obeys the bound
    for q in res.queries:
        if not q.served_fallback and q.model_window >= 0:
            assert 0 <= q.context_window - q.model_window <= 1
    assert s["max_staleness"] <= 1
