"""Window manager, supervised construction, scaler and injection tests."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property tests skip; deterministic tests run
    from _hypothesis_stub import given, settings, st

from repro.core.windows import WindowedStream, WindowPlan, make_supervised
from repro.streams import DataInjection, MinMaxScaler, ThrottleConfig
from repro.streams.injection import stream_windows
from repro.streams.sources import abrupt_drift, gradual_drift, wind_turbine_series


def test_make_supervised_alignment():
    series = np.arange(20, dtype=np.float32)[:, None]
    d = make_supervised(series, lag=5, target_col=0)
    assert d["x"].shape == (15, 5, 1) and d["y"].shape == (15, 1)
    # y_i follows its lag window
    np.testing.assert_allclose(d["x"][0, :, 0], [0, 1, 2, 3, 4])
    np.testing.assert_allclose(d["y"][0], [5])
    np.testing.assert_allclose(d["x"][-1, :, 0], [14, 15, 16, 17, 18])
    np.testing.assert_allclose(d["y"][-1], [19])


@given(st.integers(6, 200), st.integers(1, 5), st.integers(1, 4))
@settings(max_examples=30, deadline=None)
def test_make_supervised_shapes(T, lag, F):
    series = np.random.default_rng(0).normal(size=(T, F)).astype(np.float32)
    d = make_supervised(series, lag)
    n = max(T - lag, 0)
    assert d["x"].shape == (n, lag, F)
    assert d["y"].shape == (n, 1)


def test_windowed_stream_boundary_context():
    """Window t>0 must include lag records of left context so no samples
    are lost at window boundaries."""
    series = np.arange(100, dtype=np.float32)[:, None]
    ws = WindowedStream(series, WindowPlan(n_windows=4, records_per_window=25, lag=5))
    assert len(ws) == 4
    d1 = ws.supervised(1)
    # first sample of window 1 predicts record 25 from records 20..24
    np.testing.assert_allclose(d1["x"][0, :, 0], [20, 21, 22, 23, 24])
    np.testing.assert_allclose(d1["y"][0], [25])
    assert len(d1["y"]) == 25  # full window coverage


def test_minmax_scaler_roundtrip():
    rng = np.random.default_rng(0)
    x = rng.normal(10, 5, (200, 3)).astype(np.float32)
    sc = MinMaxScaler.fit(x)
    z = sc.transform(x)
    assert z.min() >= -1e-6 and z.max() <= 1 + 1e-6
    back = sc.inverse(z)
    np.testing.assert_allclose(back, x, rtol=1e-4, atol=1e-3)


@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_minmax_scaler_bounds(seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, rng.uniform(0.1, 10), (50, 2)).astype(np.float32)
    sc = MinMaxScaler.fit(x)
    z = sc.transform(x)
    assert np.all(z >= -1e-5) and np.all(z <= 1 + 1e-5)


def test_data_injection_throttle():
    inj = DataInjection(ThrottleConfig(min_records=10, max_buffer=15))
    rng = np.random.default_rng(0)
    inj.push(rng.normal(size=(8, 3)))
    assert not inj.ready() and inj.emit() is None
    inj.push(rng.normal(size=(4, 3)))
    assert inj.ready()
    out = inj.emit()
    assert out.shape == (12, 3)
    assert inj.emitted_windows == 1
    # overflow drops oldest
    inj.push(rng.normal(size=(20, 3)))
    assert inj.dropped == 5


def test_stream_windows_chop():
    s = np.zeros((103, 2), np.float32)
    ws = stream_windows(s, 25)
    assert len(ws) == 4 and all(w.shape == (25, 2) for w in ws)


def test_drift_generators():
    base = wind_turbine_series(2000, seed=0)
    g = gradual_drift(base, seed=1)
    a = abrupt_drift(base, seed=2)
    assert g.shape == base.shape and a.shape == base.shape
    # gradual drift grows with t: late-window mean exceeds base's by the trend
    delta = (g[-500:] - base[-500:]).mean() - (g[:500] - base[:500]).mean()
    assert delta > 0.1
    # abrupt drift changes level at switch points (std of windowed mean diff)
    dd = (a - base).mean(axis=1)
    assert np.std(dd[1:] - dd[:-1]) >= 0.0  # exists and finite
    assert np.isfinite(a).all() and np.isfinite(g).all()
