"""Health-plane units: shape/dtype-aware checksums, HMAC-signed model sync
vs the checksum-recomputing forger, phi-accrual partition detection,
Byzantine value screening, and the adaptive-threshold policy (tight under
fault pressure, byte-identical to the static knobs when calm)."""
import numpy as np
import pytest

from repro.runtime.faults import FaultPlane, MessageFault, forge_tree, tree_checksum
from repro.runtime.health import (
    ByzantineGuard,
    FaultRateEstimator,
    HealthConfig,
    HealthPlane,
    PhiAccrual,
    derive_sync_key,
    sign_tree,
    verify_tree,
)


# ---------------------------------------------------------------------------
# tree_checksum: the shape/dtype regression
# ---------------------------------------------------------------------------


def test_tree_checksum_distinguishes_shape_with_identical_bytes():
    """The old bytes-only checksum collided a (3, 4) leaf with its (4, 3)
    reshape — same buffer, different model.  Shape is now part of the
    serialization."""
    a = np.arange(12, dtype=np.float32).reshape(3, 4)
    t1 = {"w": a}
    t2 = {"w": a.reshape(4, 3)}
    assert a.tobytes() == t2["w"].tobytes()  # the collision precondition
    assert tree_checksum(t1) != tree_checksum(t2)


def test_tree_checksum_distinguishes_dtype_with_identical_bytes():
    raw = np.arange(8, dtype=np.int8)
    t1 = {"q": raw}
    t2 = {"q": raw.view(np.uint8)}
    assert t1["q"].tobytes() == t2["q"].tobytes()
    assert tree_checksum(t1) != tree_checksum(t2)


def test_tree_checksum_stable_across_calls():
    tree = {"w": np.ones((2, 5), np.float32), "b": np.zeros(5, np.int8)}
    assert tree_checksum(tree) == tree_checksum(tree)


# ---------------------------------------------------------------------------
# signed sync: HMAC catches what crc32 cannot
# ---------------------------------------------------------------------------


def _tree():
    return {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "q": np.arange(6, dtype=np.int8)}


def test_sign_verify_roundtrip_and_key_separation():
    key = derive_sync_key(0)
    tree = _tree()
    sig = sign_tree(tree, key)
    assert verify_tree(tree, key, sig)
    assert not verify_tree(tree, key, None)
    assert not verify_tree(tree, derive_sync_key(1), sig)
    assert derive_sync_key(3) == derive_sync_key(3)  # per-seed deterministic


def test_forged_tree_passes_recomputed_checksum_but_fails_hmac():
    """The forge threat model: the adversary perturbs the params and
    recomputes the crc32, so checksum verification alone would install the
    tampered model.  Only the keyed HMAC rejects it."""
    key = derive_sync_key(0)
    tree = _tree()
    sig = sign_tree(tree, key)
    forged = forge_tree(tree, np.random.default_rng(0))
    # the forger's recomputed checksum is self-consistent ...
    assert tree_checksum(forged) == tree_checksum(forged)
    assert tree_checksum(forged) != tree_checksum(tree)
    # ... so a checksum-only receiver accepts it; the HMAC does not
    assert not verify_tree(forged, key, sig)
    assert not verify_tree(forged, key, sign_tree(forged, b"wrong-key" * 4))


def test_fault_plane_forge_recomputes_checksum_in_payload():
    """``MessageFault(kind="forge")`` must emit a payload whose checksum
    matches its (tampered) params — indistinguishable from clean to crc32."""
    plane = FaultPlane(0, message_faults=[
        MessageFault("model/latest/*", "forge", p=1.0)])
    tree = _tree()
    payload = {"params": tree, "checksum": tree_checksum(tree),
               "window": 3, "stream": "t00"}
    out = plane.plan_deliveries("model/latest/t00", payload, "cloud", "edge",
                                t_pub=1.0, dt=0.05, bus=None)
    assert len(out) == 1
    _, forged = out[0]
    assert tree_checksum(forged["params"]) == forged["checksum"]
    assert tree_checksum(forged["params"]) != tree_checksum(tree)
    assert plane.stats["msg_forge"] == 1


# ---------------------------------------------------------------------------
# phi-accrual partition detection
# ---------------------------------------------------------------------------


def test_phi_accrual_rises_only_when_heartbeats_stop():
    tr = PhiAccrual(expected_s=1.0, window=16)
    for k in range(1, 9):
        tr.arrive(float(k), healthy=True)
    assert tr.phi(8.4) == pytest.approx(0.4, abs=0.05)
    assert tr.phi(9.8) == pytest.approx(1.8, abs=0.05)  # silence grows phi
    tr.arrive(10.0, healthy=True)
    assert tr.phi(10.1) < 0.2


def test_phi_accrual_excludes_outage_gap_from_baseline():
    """The outage interval itself (and burst arrivals after a heal) must not
    inflate the learned cadence, or detection would go numb post-heal."""
    tr = PhiAccrual(expected_s=1.0, window=16)
    for k in range(1, 6):
        tr.arrive(float(k), healthy=True)
    tr.arrive(15.0, healthy=False)  # first hb after a 10s outage
    assert tr.mean() == pytest.approx(1.0, abs=1e-6)
    tr.arrive(15.1, healthy=True)  # burst release: gap 0.1 < 0.25*expected
    assert tr.mean() == pytest.approx(1.0, abs=1e-6)


def test_site_monitor_escalates_and_recovers():
    cfg = HealthConfig()
    hp = HealthPlane(cfg)
    hp.bind(sites=["edge", "cloud"], hb_interval_s=1.0, halflife_s=2.0,
            quarantine_after=3, staleness_bound=1, sync_seed=0)
    for k in range(1, 7):
        hp.observe_heartbeat("edge", "cloud", float(k))
        hp.check("edge", k + 0.5)
    assert hp.verdict_stats.get("partition_suspected", 0) == 0
    # cloud goes silent: suspicion then site_down at the phi thresholds
    hp.check("edge", 7.5)   # phi 1.5 >= 1.4 -> suspected
    hp.check("edge", 8.5)   # phi 2.5: still suspected
    hp.check("edge", 9.5)   # phi 3.5 >= 3.2 -> down
    assert hp.verdict_stats["partition_suspected"] == 1
    assert hp.verdict_stats["site_down"] == 1
    assert hp.first_verdict_t("partition_suspected") == 7.5
    hp.observe_heartbeat("edge", "cloud", 10.0)
    assert hp.verdict_stats["recovered"] == 1


def test_site_monitor_rebaselines_after_its_own_outage():
    """A monitor whose own site was down must not blame peers for the
    heartbeats it was not alive to receive."""
    cfg = HealthConfig()
    hp = HealthPlane(cfg)
    hp.bind(sites=["edge", "cloud"], hb_interval_s=1.0, halflife_s=2.0,
            quarantine_after=3, staleness_bound=1, sync_seed=0)
    for k in range(1, 4):
        hp.observe_heartbeat("edge", "cloud", float(k))
        hp.check("edge", k + 0.5)
    # the edge monitor itself goes dark for 5s, then its checks resume
    hp.check("edge", 8.5)
    assert hp.verdict_stats.get("monitor_gap", 0) == 1
    assert hp.verdict_stats.get("partition_suspected", 0) == 0
    hp.observe_heartbeat("edge", "cloud", 9.0)
    hp.check("edge", 9.5)
    assert hp.verdict_stats.get("partition_suspected", 0) == 0


# ---------------------------------------------------------------------------
# Byzantine guard
# ---------------------------------------------------------------------------


def _warm_guard(cfg):
    g = ByzantineGuard(cfg)
    rng = np.random.default_rng(0)
    base = rng.normal(10.0, 1.0, 200).astype(np.float32)
    g.screen("t00", {"x": np.zeros((200, 5), np.float32), "y": base}, 0.0)
    return g


def test_byzantine_guard_flags_and_imputes_outliers():
    cfg = HealthConfig()
    g = _warm_guard(cfg)
    y = np.array([10.0, 10.5, 60.0, 9.5], np.float32)  # 60 is ~50 sigma off
    out, n = g.screen("t00", {"x": np.zeros((4, 5), np.float32), "y": y},
                      1.0)
    assert n == 1
    assert out["y"][2] != 60.0  # imputed with the rolling median
    assert abs(out["y"][2] - 10.0) < 1.0
    assert list(out["y"][[0, 1, 3]]) == [10.0, 10.5, 9.5]
    assert g.flagged["t00"] == 1


def test_byzantine_guard_returns_original_objects_when_clean():
    """Calm-path byte-identity: no copy, no reallocation — the exact arrays
    go through."""
    cfg = HealthConfig()
    g = _warm_guard(cfg)
    data = {"x": np.zeros((4, 5), np.float32),
            "y": np.array([10.0, 9.8, 10.2, 10.1], np.float32)}
    out, n = g.screen("t00", data, 1.0)
    assert n == 0
    assert out is data


def test_byzantine_guard_inactive_until_min_history():
    cfg = HealthConfig(byz_min_history=48)
    g = ByzantineGuard(cfg)
    y = np.array([1e6], np.float32)  # absurd, but no baseline yet
    out, n = g.screen("t00", {"x": np.zeros((1, 5), np.float32), "y": y},
                      0.0)
    assert n == 0 and out["y"][0] == 1e6


# ---------------------------------------------------------------------------
# fault-rate estimation + adaptive thresholds
# ---------------------------------------------------------------------------


def test_fault_rate_estimator_decays_by_halflife():
    est = FaultRateEstimator(halflife_s=10.0)
    est.observe(0.0)
    est.observe(0.0)
    assert est.pressure(0.0) == pytest.approx(2.0)
    assert est.pressure(10.0) == pytest.approx(1.0)
    assert est.pressure(20.0) == pytest.approx(0.5)


def test_adaptive_thresholds_tighten_under_rising_fault_rate():
    hp = HealthPlane(HealthConfig())
    hp.bind(sites=["edge", "cloud"], hb_interval_s=1.0, halflife_s=10.0,
            quarantine_after=3, staleness_bound=2, sync_seed=0)
    # calm: base values exactly, nothing recorded
    assert hp.quarantine_after("t00", 0.0) == 3
    assert hp.staleness_bound("t00", 0.0) == 2
    assert hp.adaptations == []
    # one isolated fault is not a *rate*: still the base knob
    hp.observe_fault("sensor", "t00", 1.0)
    assert hp.quarantine_after("t00", 1.0) == 3
    # a burst inside the halflife is: the threshold tightens, floored
    for t in (2.0, 2.5, 3.0, 3.5):
        hp.observe_fault("sensor", "t00", t)
    tightened = hp.quarantine_after("t00", 4.0)
    assert 1 <= tightened < 3
    assert len(hp.adaptations) >= 1
    assert hp.summary()["adapted_quarantine_after"]["t00"] == tightened
    # an unaffected stream keeps the base knob
    assert hp.quarantine_after("t01", 4.0) == 3
    # link suspicion tightens the staleness watchdog fleet-wide
    for t in (2.0, 2.5, 3.0, 3.5):
        hp.observe_fault("link", "cloud", t)
    assert hp.staleness_bound("t00", 4.0) < 2
    # pressure decays: far enough out, everything returns to base
    assert hp.quarantine_after("t00", 500.0) == 3
    assert hp.staleness_bound("t00", 500.0) == 2


def test_static_plane_never_adapts():
    hp = HealthPlane(HealthConfig(adaptive=False))
    hp.bind(sites=["edge", "cloud"], hb_interval_s=1.0, halflife_s=10.0,
            quarantine_after=3, staleness_bound=2, sync_seed=0)
    for t in (1.0, 1.2, 1.4, 1.6, 1.8):
        hp.observe_fault("sensor", "t00", t)
    assert hp.quarantine_after("t00", 2.0) == 3
    assert hp.staleness_bound("t00", 2.0) == 2
    assert hp.adaptations == []


def test_health_plane_reset_rewinds_everything():
    hp = HealthPlane(HealthConfig())
    hp.bind(sites=["edge", "cloud"], hb_interval_s=1.0, halflife_s=10.0,
            quarantine_after=3, staleness_bound=1, sync_seed=0)
    hp.observe_fault("sensor", "t00", 1.0)
    hp.verdict(1.0, "partition_suspected", "edge", "cloud")
    hp.reset()
    assert hp.verdicts == [] and hp.pressure("sensor", "t00", 1.0) == 0.0
    assert hp.sync_key is None  # until the next bind
