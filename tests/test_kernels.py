"""Per-kernel allclose vs ref.py oracles: sweep shapes and dtypes, all in
interpret mode (the kernel body executes in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ops import gqa_flash
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.lstm_cell.kernel import lstm_cell
from repro.kernels.lstm_cell.ops import lstm_sequence
from repro.kernels.lstm_cell.ref import lstm_cell_ref, lstm_sequence_ref
from repro.kernels.rwkv6_scan.kernel import rwkv6_scan
from repro.kernels.rwkv6_scan.ops import wkv
from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref
from repro.kernels.ssm_scan.kernel import ssm_scan
from repro.kernels.ssm_scan.ops import selective_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref

KEY = jax.random.PRNGKey(0)


def tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else dict(
        atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# lstm_cell
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,F,H", [(4, 5, 40), (128, 5, 40), (33, 7, 16),
                                   (1, 1, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lstm_cell_sweep(B, F, H, dtype):
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (B, F), dtype)
    h = jax.random.normal(ks[1], (B, H), dtype)
    c = jax.random.normal(ks[2], (B, H), dtype)
    wx = (jax.random.normal(ks[3], (F, 4 * H)) * 0.2).astype(dtype)
    wh = (jax.random.normal(ks[4], (H, 4 * H)) * 0.2).astype(dtype)
    b = (jax.random.normal(ks[5], (4 * H,)) * 0.2).astype(dtype)
    h1, c1 = lstm_cell(x, h, c, wx, wh, b, interpret=True, block_b=32)
    h2, c2 = lstm_cell_ref(x, h, c, wx, wh, b)
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32), **tol(dtype))
    np.testing.assert_allclose(np.asarray(c1, np.float32),
                               np.asarray(c2, np.float32), **tol(dtype))


def test_lstm_sequence_matches_ref():
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (8, 5, 5))
    wx = jax.random.normal(ks[1], (5, 160)) * 0.2
    wh = jax.random.normal(ks[2], (40, 160)) * 0.2
    b = jax.random.normal(ks[3], (160,)) * 0.2
    h1 = lstm_sequence(x, wx, wh, b, interpret=True)
    h2 = lstm_sequence_ref(x, wx, wh, b)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("B,T,F,H", [(8, 5, 5, 40), (128, 5, 5, 40),
                                     (33, 7, 3, 16), (1, 1, 2, 8),
                                     (130, 12, 4, 24)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lstm_sequence_fused_sweep(B, T, F, H, dtype):
    """The fused-sequence kernel (time loop inside one pallas_call) against
    the full-sequence oracle — both final h and final c."""
    from repro.kernels.lstm_cell.kernel import lstm_sequence_fused

    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (B, T, F), dtype)
    wx = (jax.random.normal(ks[1], (F, 4 * H)) * 0.2).astype(dtype)
    wh = (jax.random.normal(ks[2], (H, 4 * H)) * 0.2).astype(dtype)
    b = (jax.random.normal(ks[3], (4 * H,)) * 0.2).astype(dtype)
    h1, c1 = lstm_sequence_fused(x, wx, wh, b, interpret=True, block_b=32)
    h2, c2 = lstm_sequence_ref(x, wx, wh, b, return_state=True)
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32), **tol(dtype))
    np.testing.assert_allclose(np.asarray(c1, np.float32),
                               np.asarray(c2, np.float32), **tol(dtype))


def test_lstm_sequence_fused_agrees_with_scanned_cells():
    """Fused path vs the pre-fusion per-timestep kernel scan it replaced."""
    from repro.kernels.lstm_cell.ops import lstm_sequence_scan

    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (16, 5, 5))
    wx = jax.random.normal(ks[1], (5, 160)) * 0.2
    wh = jax.random.normal(ks[2], (40, 160)) * 0.2
    b = jax.random.normal(ks[3], (160,)) * 0.2
    h_fused = lstm_sequence(x, wx, wh, b, interpret=True)
    h_scan = lstm_sequence_scan(x, wx, wh, b, interpret=True)
    np.testing.assert_allclose(np.asarray(h_fused), np.asarray(h_scan),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# lstm_sequence fused VJP
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,T,F,H", [(8, 5, 5, 40), (64, 5, 5, 40),
                                     (33, 7, 3, 16), (1, 1, 2, 8),
                                     (130, 12, 4, 24)])  # 130 > block_b: pads
def test_lstm_sequence_grad_matches_scan_autodiff(B, T, F, H):
    """The tentpole oracle: the fused Pallas VJP (residual-emitting forward +
    reverse-time backward kernel) must match autodiff through the sequence
    scan to tight f32 tolerance, for every input (x, wx, wh, b) and with a
    random cotangent.

    Reverse-mode AD cannot trace through a ``pallas_call`` itself in this
    JAX version (differentiating ``lstm_sequence_scan``'s per-step kernel
    raises inside ``ad.linearize`` — the very reason the custom VJP exists),
    so the autodiff side runs the mathematically-identical ``lax.scan``
    oracle ``lstm_sequence_ref``, whose primal ``lstm_sequence_scan`` is
    pinned against elsewhere in this file."""
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, T, F))
    wx = jax.random.normal(ks[1], (F, 4 * H)) * 0.2
    wh = jax.random.normal(ks[2], (H, 4 * H)) * 0.2
    b = jax.random.normal(ks[3], (4 * H,)) * 0.2
    ct = jax.random.normal(ks[4], (B, H))  # random cotangent

    g_fused = jax.grad(
        lambda *a: jnp.sum(lstm_sequence(*a, interpret=True) * ct),
        argnums=(0, 1, 2, 3))(x, wx, wh, b)
    g_scan = jax.grad(
        lambda *a: jnp.sum(lstm_sequence_ref(*a) * ct),
        argnums=(0, 1, 2, 3))(x, wx, wh, b)
    for name, gf, gs in zip(("dx", "dwx", "dwh", "db"), g_fused, g_scan):
        np.testing.assert_allclose(np.asarray(gf), np.asarray(gs),
                                   atol=2e-5, rtol=2e-5, err_msg=name)


def test_lstm_model_grads_fused_vs_scan():
    """Model-level anchor: ``value_and_grad`` of the forecaster loss through
    the fused kernels (``use_pallas=True`` -> custom VJP) equals autodiff
    through the jnp scan path the speed layer trained on before."""
    import dataclasses

    from repro.configs import get_config
    from repro.models import lstm as lstm_mod

    cfg = get_config("lstm-paper")
    cfg_fused = dataclasses.replace(cfg, use_pallas=True)
    p = lstm_mod.init_params(cfg, jax.random.PRNGKey(0))
    batch = {
        "x": jax.random.normal(jax.random.PRNGKey(1), (32, 5, 5)),
        "y": jax.random.normal(jax.random.PRNGKey(2), (32, 1)),
        "mask": jnp.ones((32,), jnp.float32).at[-5:].set(0.0),
    }
    loss_s, g_s = jax.value_and_grad(
        lambda p: lstm_mod.loss_fn(cfg, p, batch)[0])(p)
    loss_f, g_f = jax.value_and_grad(
        lambda p: lstm_mod.loss_fn(cfg_fused, p, batch)[0])(p)
    np.testing.assert_allclose(float(loss_f), float(loss_s), rtol=1e-5)
    flat_s = jax.tree_util.tree_leaves_with_path(g_s)
    flat_f = dict(jax.tree_util.tree_leaves_with_path(g_f))
    for path, leaf in flat_s:
        np.testing.assert_allclose(
            np.asarray(flat_f[path]), np.asarray(leaf), atol=2e-5, rtol=2e-5,
            err_msg=jax.tree_util.keystr(path))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "B,H,S,D,causal,window",
    [
        (2, 2, 128, 32, True, 0),
        (1, 4, 256, 64, True, 0),
        (2, 2, 100, 32, True, 0),  # ragged
        (2, 2, 250, 32, True, 64),  # SWA + ragged
        (1, 2, 77, 16, False, 0),  # non-causal
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, S, D, causal, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, H, S, D), dtype)
    v = jax.random.normal(ks[2], (B, H, S, D), dtype)
    o1 = flash_attention(q, k, v, causal=causal, window=window,
                         block_q=64, block_k=64, interpret=True)
    o2 = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), **tol(dtype))


def test_gqa_flash_matches_model_oracle():
    from repro.models.attention import attend, attend_full_ref

    B, S, Hq, Hkv, D = 2, 96, 8, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    o_kernel = gqa_flash(q, k, v, causal=True, interpret=True)
    o_ref = attend_full_ref(q, k, v, pos, pos, causal=True)
    o_chunked = attend(q, k, v, pos, pos, causal=True, chunk=32)
    np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(o_chunked), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# rwkv6 scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("BH,T,N,chunk", [(4, 64, 16, 32), (2, 100, 32, 32),
                                          (3, 17, 8, 8), (1, 256, 64, 128)])
def test_rwkv6_scan_sweep(BH, T, N, chunk):
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (BH, T, N)) * 0.5
    k = jax.random.normal(ks[1], (BH, T, N)) * 0.5
    v = jax.random.normal(ks[2], (BH, T, N)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (BH, T, N))) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (BH, N)) * 0.1
    y1, s1 = rwkv6_scan(r, k, v, w, u, chunk=chunk, interpret=True)
    y2, s2 = rwkv6_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4,
                               rtol=1e-4)


def test_wkv_model_layout():
    B, T, H, N = 2, 40, 3, 16
    ks = jax.random.split(KEY, 5)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, N)) * 0.5 for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, N))) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (H, N)) * 0.1
    y, s = wkv(r, k, v, w, u, chunk=16, interpret=True)
    assert y.shape == (B, T, H, N) and s.shape == (B, H, N, N)

    def flat(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, T, N)

    y2, s2 = rwkv6_scan_ref(flat(r), flat(k), flat(v), flat(w),
                            jnp.broadcast_to(u[None], (B, H, N)).reshape(B * H, N))
    np.testing.assert_allclose(np.asarray(flat(y)), np.asarray(y2), atol=1e-4,
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# ssm scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("BH,T,P,N,chunk", [(4, 64, 16, 16, 32),
                                            (2, 90, 32, 16, 32),
                                            (1, 33, 8, 8, 16)])
def test_ssm_scan_sweep(BH, T, P, N, chunk):
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (BH, T, P))
    b = jax.random.normal(ks[1], (BH, T, N)) * 0.3
    c = jax.random.normal(ks[2], (BH, T, N)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(ks[3], (BH, T)))
    a = -jnp.exp(jax.random.normal(ks[4], (BH,)))
    d = jax.random.normal(ks[5], (BH,))
    y1, s1 = ssm_scan(x, b, c, dt, a, d, chunk=chunk, interpret=True)
    y2, s2 = ssm_scan_ref(x, b, c, dt, a, d)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4,
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# int8 dequant matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("M,K,N", [(64, 128, 96), (33, 100, 17),
                                   (1, 40, 160), (128, 512, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_int8_matmul_sweep(M, K, N, dtype):
    from repro.kernels.int8_matmul.kernel import int8_matmul
    from repro.kernels.int8_matmul.ref import int8_matmul_ref

    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (M, K), dtype)
    q = jax.random.randint(ks[1], (K, N), -127, 128).astype(jnp.int8)
    s = jnp.abs(jax.random.normal(ks[2], (N,))) * 0.01
    y1 = int8_matmul(x, q, s, block_m=32, block_n=32, block_k=64,
                     interpret=True)
    y2 = int8_matmul_ref(x, q, s)
    # blocked K accumulation reorders the f32 sum; bound relative not exact
    loose = dict(atol=1e-3, rtol=1e-3) if dtype == jnp.float32 else tol(dtype)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), **loose)


def test_qmatmul_matches_dequant_path():
    from repro.kernels.int8_matmul.ops import qmatmul
    from repro.serving.quantize import dequantize, quantize

    w = jax.random.normal(KEY, (64, 32))
    qt = quantize(w)
    x = jax.random.normal(KEY, (4, 5, 64))
    y1 = qmatmul(x, qt, interpret=True)
    y2 = jnp.einsum("...k,kn->...n", x, dequantize(qt))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


def test_selective_scan_model_layout():
    B, T, H, P, N = 2, 32, 3, 8, 16
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (B, T, H, P))
    b = jax.random.normal(ks[1], (B, T, N)) * 0.3
    c = jax.random.normal(ks[2], (B, T, N)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, T, H)))
    a = -jnp.exp(jax.random.normal(ks[4], (H,)))
    d = jax.random.normal(ks[5], (H,))
    y, s = selective_scan(x, b, c, dt, a, d, chunk=16, interpret=True)
    assert y.shape == (B, T, H, P) and s.shape == (B, H, P, N)
    assert bool(jnp.isfinite(y).all())
