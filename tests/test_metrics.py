import os
import tempfile

import numpy as np

from repro.training.metrics import MetricLogger


def test_log_and_summary():
    ml = MetricLogger()
    for i in range(10):
        ml.log(i, loss=float(10 - i), lr=1e-3)
    s = ml.summary()
    assert s["loss"]["last"] == 1.0 and s["loss"]["max"] == 10.0
    assert abs(ml.mean("loss") - 5.5) < 1e-9
    assert ml.mean("loss", last_n=2) == 1.5
    assert len(ml.series("lr")) == 10


def test_jsonl_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "m", "train.jsonl")
        ml = MetricLogger(path=path)
        ml.log(0, loss=3.0, note="warmup")
        ml.log(1, loss=2.0)
        ml.close()
        back = MetricLogger.read(path)
        assert back.series("loss") == [3.0, 2.0]
        assert back._rows[0]["note"] == "warmup"
