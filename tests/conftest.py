import os
import sys

# tests must see ONE cpu device (the dry-run alone forces 512); keep any
# inherited flag from leaking in
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
