"""Drop-in stand-ins for the hypothesis API used by this suite, so test
modules that mix property tests with deterministic tests still collect (and
run their deterministic tests) when hypothesis isn't installed.

``given`` marks the decorated test as skipped; ``settings`` is a no-op
decorator; ``st`` yields inert strategy placeholders for module-level
strategy construction (``st.composite``, ``st.integers(...)``, ...).
"""
import pytest


def _inert(*_args, **_kwargs):
    """Absorbs any call chain strategies make at module level."""
    return _inert


class _Strategies:
    def __getattr__(self, _name):
        return _inert


st = _Strategies()


def given(*_args, **_kwargs):
    return pytest.mark.skip(reason="hypothesis not installed")


def settings(*_args, **_kwargs):
    def deco(fn):
        return fn

    return deco


settings.register_profile = _inert
settings.load_profile = _inert
