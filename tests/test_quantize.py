"""Int8 edge-quantization tests: roundtrip error bounds, size reduction,
end-to-end forecaster accuracy, and the int8 matmul identity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property tests skip; deterministic tests run
    from _hypothesis_stub import given, settings, st

from repro.configs import get_config
from repro.models import get_model
from repro.serving.quantize import (
    QTensor,
    dequantize,
    dequantize_tree,
    int8_matmul,
    quantization_error,
    quantize,
    quantize_tree,
    tree_nbytes,
)


def test_quantize_roundtrip_bound():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 128))
    qt = quantize(w)
    back = dequantize(qt)
    # symmetric int8: error <= scale/2 = amax/254 per column
    amax = np.abs(np.asarray(w)).max(axis=0)
    err = np.abs(np.asarray(back) - np.asarray(w))
    assert (err <= amax[None] / 254 + 1e-7).all()
    assert qt.q.dtype == jnp.int8


@given(st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_quantize_property(seed):
    w = jax.random.normal(jax.random.PRNGKey(seed), (32, 48)) * (seed % 7 + 1)
    back = dequantize(quantize(w))
    rel = float(jnp.max(jnp.abs(back - w)) / jnp.maximum(jnp.max(jnp.abs(w)), 1e-9))
    assert rel < 1 / 120  # < one int8 step


def test_int8_matmul_matches_dequant():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (4, 64))
    w = jax.random.normal(jax.random.PRNGKey(2), (64, 32))
    qt = quantize(w)
    y1 = int8_matmul(x, qt)
    y2 = x @ dequantize(qt)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


def test_tree_quantization_size_and_selectivity():
    cfg = get_config("tinyllama-1.1b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qp = quantize_tree(params)
    # big matrices quantized, norms left alone
    leaves = jax.tree_util.tree_leaves(
        qp, is_leaf=lambda x: isinstance(x, QTensor))
    assert any(isinstance(x, QTensor) for x in leaves)
    n_f = tree_nbytes(params)
    n_q = tree_nbytes(qp)
    assert n_q < 0.45 * n_f  # ~4x smaller (f32 baseline)


def test_quantized_forecaster_accuracy():
    """The paper's edge model (LSTM) must survive int8 weight quantization
    with negligible RMSE change — the TFLite-analog check."""
    from repro.core import lstm_forecaster, make_supervised
    from repro.streams.sources import wind_turbine_series
    from repro.streams.normalize import MinMaxScaler

    cfg = get_config("lstm-paper")
    series = wind_turbine_series(1200, seed=0)
    sc = MinMaxScaler.fit(series)
    data = make_supervised(sc.transform(series), 5, 0)
    fc = lstm_forecaster(cfg, epochs=10, batch_size=128)
    params, _ = fc.train(data, None, jax.random.PRNGKey(0))

    # LSTM kernels are small; lower the quantize threshold for the test
    import repro.serving.quantize as qz

    old = qz.MIN_QUANT_SIZE
    qz.MIN_QUANT_SIZE = 64
    try:
        p8 = dequantize_tree(quantize_tree(params))
        errs = quantization_error(params)
    finally:
        qz.MIN_QUANT_SIZE = old

    pred_f = fc.predict(params, data["x"])
    pred_q = fc.predict(p8, data["x"])
    rmse_f = float(np.sqrt(np.mean((pred_f - data["y"]) ** 2)))
    rmse_q = float(np.sqrt(np.mean((pred_q - data["y"]) ** 2)))
    assert rmse_q < rmse_f * 1.05, (rmse_f, rmse_q)
    assert errs and max(errs.values()) < 0.01


def test_qtensor_is_a_pytree():
    """QTensor registers as a pytree node: quantized trees flow through
    tree_map/jit, and a byte count over the flattened leaves sees the real
    int8+scale size (what the BusExecutor's transfer accounting relies on)."""
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
    qt = quantize(w)
    leaves, treedef = jax.tree_util.tree_flatten(qt)
    assert len(leaves) == 2  # q, scale
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(rebuilt, QTensor) and rebuilt.orig_dtype == qt.orig_dtype
    flat_bytes = sum(np.asarray(x).nbytes for x in leaves)
    assert flat_bytes == qt.nbytes
    y = jax.jit(lambda q, x: x @ dequantize(q))(
        qt, jax.random.normal(jax.random.PRNGKey(1), (4, 64)))
    assert y.shape == (4, 32)


def test_quantize_fleet_bitwise_matches_per_stream():
    """The batched fleet sync quantization (one vectorized pass over the
    stacked host tree) must be bitwise identical to per-stream
    ``quantize_tree``, preserve input order with plain trees mixed in, and
    pass small/1-D leaves through in float."""
    from repro.serving.quantize import quantize_fleet
    from repro.training.compiled import FleetParamView, _FleetStack

    k = jax.random.PRNGKey(0)
    stacked = {
        "w": jax.random.normal(k, (4, 64, 32)) * 3.0,
        "b": jax.random.normal(jax.random.PRNGKey(1), (4, 32)),
    }
    stack = _FleetStack(stacked)
    views = [FleetParamView(stack, j) for j in range(4)]
    plain = {"w": jax.random.normal(jax.random.PRNGKey(2), (64, 32)),
             "b": jnp.zeros((32,))}
    seq = [views[0], plain, views[2], views[1], views[3]]

    out = quantize_fleet(seq, min_size=64)
    assert len(out) == len(seq)
    for got, src in zip(out, seq):
        ref = quantize_tree(
            src.tree() if isinstance(src, FleetParamView) else src,
            min_size=64)
        assert isinstance(got["w"], QTensor)
        np.testing.assert_array_equal(np.asarray(got["w"].q),
                                      np.asarray(ref["w"].q))
        np.testing.assert_array_equal(np.asarray(got["w"].scale),
                                      np.asarray(ref["w"].scale))
        # 1-D bias passes through in float, bitwise
        assert not isinstance(got["b"], QTensor)
        np.testing.assert_array_equal(np.asarray(got["b"]),
                                      np.asarray(ref["b"]))


def test_int8_synced_model_serving_accuracy():
    """The int8 *serving* path: QTensor params handed straight to the
    forecaster (what ``BusExecutor(quantized_sync=True)`` installs at the
    edge) route through ``models.lstm._forward_int8`` and the fused
    ``int8_matmul`` kernel, and the RMSE delta vs the float-synced model is
    tightly bounded — plus the sync payload is ~4x smaller."""
    from repro.core import lstm_forecaster, make_supervised
    from repro.serving.quantize import quantize_tree
    from repro.streams.sources import wind_turbine_series
    from repro.streams.normalize import MinMaxScaler

    cfg = get_config("lstm-paper")
    series = wind_turbine_series(1200, seed=0)
    sc = MinMaxScaler.fit(series)
    data = make_supervised(sc.transform(series), 5, 0)
    fc = lstm_forecaster(cfg, epochs=10, batch_size=128)
    params, _ = fc.train(data, None, jax.random.PRNGKey(0))
    qp = quantize_tree(params, min_size=64)  # the speed-layer sync threshold

    pred_f = fc.predict(params, data["x"])
    pred_q = fc.predict(qp, data["x"])
    rmse_f = float(np.sqrt(np.mean((pred_f - data["y"]) ** 2)))
    rmse_q = float(np.sqrt(np.mean((pred_q - data["y"]) ** 2)))
    assert rmse_q < rmse_f * 1.05, (rmse_f, rmse_q)
    assert tree_nbytes(qp) < 0.45 * tree_nbytes(params)
