"""MoE layer semantics: routing exactness, capacity behavior, aux loss."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import moe as moe_mod
from repro.models import nn


def make_cfg(E=4, k=2, d=32, f=64, cf=8.0):
    return ModelConfig(
        name="test-moe", family="moe", n_layers=1, d_model=d, n_heads=2,
        n_kv_heads=2, d_ff=f, vocab_size=64, mlp_variant="swiglu",
        dtype="float32", param_dtype="float32",
        moe=MoEConfig(n_experts=E, top_k=k, d_ff_expert=f, capacity_factor=cf),
    )


def manual_moe(cfg, p, x):
    """Token-by-token loop reference (no capacity limit)."""
    moe = cfg.moe
    B, S, d = x.shape
    out = np.zeros((B, S, d), np.float32)
    logits = np.asarray(
        x.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = np.asarray(jax.nn.softmax(jnp.asarray(logits), -1))
    for b in range(B):
        for s in range(S):
            idx = np.argsort(-probs[b, s])[: moe.top_k]
            w = probs[b, s, idx]
            w = w / w.sum()
            for e, we in zip(idx, w):
                h_in = np.asarray(x[b, s] @ p["we_in"][e])
                gate = np.asarray(x[b, s] @ p["we_gate"][e])
                h = (gate / (1 + np.exp(-gate))) * h_in  # silu(gate)*h
                y = h @ np.asarray(p["we_out"][e])
                out[b, s] += we * y
    return out


def test_onehot_matches_manual_reference():
    cfg = make_cfg()
    key = jax.random.PRNGKey(0)
    p = moe_mod.init_moe(key, "moe", cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    out, aux = moe_mod.moe_onehot(cfg, p, x, no_drop=True)
    ref = manual_moe(cfg, p, x)
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-4, rtol=1e-4)
    assert float(aux) > 0


def test_capacity_drops_reduce_output():
    """With capacity 1 and skewed routing, some tokens lose expert mass."""
    cfg = make_cfg(cf=0.1)  # tiny capacity
    key = jax.random.PRNGKey(0)
    p = moe_mod.init_moe(key, "moe", cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model))
    out_drop, _ = moe_mod.moe_onehot(cfg, p, x, no_drop=False)
    out_full, _ = moe_mod.moe_onehot(cfg, p, x, no_drop=True)
    # dropped version differs and has smaller norm
    n_drop = float(jnp.linalg.norm(out_drop))
    n_full = float(jnp.linalg.norm(out_full))
    assert n_drop < n_full


def test_aux_loss_balanced_vs_skewed():
    """Load-balance loss is ~1 for uniform routing, larger when skewed."""
    cfg = make_cfg(E=4, k=1)
    E = 4
    probs_uniform = jnp.full((64, E), 1 / E)
    idx_uniform = jnp.tile(jnp.arange(E), 16)[:, None]
    aux_u = moe_mod._aux_loss(cfg, probs_uniform, idx_uniform)
    probs_skew = jnp.tile(jnp.asarray([[0.97, 0.01, 0.01, 0.01]]), (64, 1))
    idx_skew = jnp.zeros((64, 1), jnp.int32)
    aux_s = moe_mod._aux_loss(cfg, probs_skew, idx_skew)
    assert float(aux_u) == pytest.approx(1.0, rel=1e-3)
    assert float(aux_s) > 2.0


def test_shared_expert_path():
    cfg = make_cfg()
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, n_shared_experts=1))
    key = jax.random.PRNGKey(0)
    p = moe_mod.init_moe(key, "moe", cfg)
    assert "w_in" in p and "w_out" in p
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, cfg.d_model))
    out, aux = moe_mod.apply_moe(cfg, p, x, ep_mode="onehot")
    assert out.shape == x.shape and bool(jnp.isfinite(out).all())


def test_moe_gradients_flow():
    cfg = make_cfg()
    key = jax.random.PRNGKey(0)
    p = moe_mod.init_moe(key, "moe", cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))

    def loss(p):
        out, aux = moe_mod.moe_onehot(cfg, p, x)
        return jnp.mean(out**2) + aux

    g = jax.grad(loss)(p)
    gn = {k: float(jnp.linalg.norm(v)) for k, v in g.items()}
    assert all(np.isfinite(list(gn.values())))
    assert gn["router"] > 0 and gn["we_in"] > 0
