"""DWA / weighting tests incl. hypothesis property tests on the invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property tests skip; deterministic tests run
    from _hypothesis_stub import given, settings, st

from repro.core.weighting import (
    _project_simplex,
    combine,
    dwa_closed_form,
    dwa_jax,
    dwa_scipy,
    rmse,
    static_weights,
)


def _problem(seed, n=128, k=2):
    rng = np.random.default_rng(seed)
    y = rng.normal(0, 1, n)
    preds = [y + rng.normal(0, 0.2 + 0.5 * i, n) + 0.1 * i for i in range(k)]
    return preds, y


@pytest.mark.parametrize("seed", range(5))
def test_solvers_agree(seed):
    preds, y = _problem(seed)
    w_sp = dwa_scipy(preds, y)
    ws, wb = dwa_closed_form(preds[0], preds[1], y)
    w_j = np.asarray(dwa_jax(jnp.stack([jnp.asarray(p) for p in preds]),
                             jnp.asarray(y)))
    assert abs(w_sp[0] - ws) < 1e-3
    assert abs(w_j[0] - ws) < 5e-3
    assert abs(sum(w_sp) - 1) < 1e-6 and abs(ws + wb - 1) < 1e-12


@pytest.mark.parametrize("seed", range(5))
def test_dwa_beats_static_on_fit_window(seed):
    """On the window it optimizes, DWA RMSE <= any static weighting."""
    preds, y = _problem(seed)
    ws, wb = dwa_closed_form(preds[0], preds[1], y)
    r_dyn = rmse(y, combine(preds, [ws, wb]))
    for w in (0.0, 0.3, 0.5, 0.7, 1.0):
        r_stat = rmse(y, combine(preds, [w, 1 - w]))
        assert r_dyn <= r_stat + 1e-9


@given(st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_closed_form_weights_valid(seed):
    preds, y = _problem(seed)
    ws, wb = dwa_closed_form(preds[0], preds[1], y)
    assert 0.0 <= ws <= 1.0 and 0.0 <= wb <= 1.0
    assert abs(ws + wb - 1.0) < 1e-12


@given(
    st.lists(st.floats(-10, 10), min_size=2, max_size=8),
)
@settings(max_examples=50, deadline=None)
def test_simplex_projection(v):
    w = np.asarray(_project_simplex(jnp.asarray(v, jnp.float32)))
    assert (w >= -1e-6).all()
    assert abs(w.sum() - 1.0) < 1e-4
    # projection of a simplex point is itself
    if len(v) == 2:
        p = jnp.asarray([0.25, 0.75], jnp.float32)
        w2 = np.asarray(_project_simplex(p))
        np.testing.assert_allclose(w2, [0.25, 0.75], atol=1e-6)


def test_static_weights():
    assert static_weights(0.3) == (0.3, 0.7)
    with pytest.raises(AssertionError):
        static_weights(1.5)


def test_dwa_degenerate_identical_preds():
    y = np.zeros(16)
    p = np.ones(16)
    ws, wb = dwa_closed_form(p, p, y)
    assert ws == 0.5 and wb == 0.5


def test_dwa_k3_scipy():
    rng = np.random.default_rng(0)
    y = rng.normal(0, 1, 64)
    preds = [y + rng.normal(0, s, 64) for s in (0.1, 0.5, 1.0)]
    w = dwa_scipy(preds, y)
    assert len(w) == 3 and abs(w.sum() - 1) < 1e-6
    assert w[0] > w[2]  # best model gets most weight
    wj = np.asarray(dwa_jax(jnp.stack([jnp.asarray(p) for p in preds]),
                            jnp.asarray(y), n_steps=500))
    r_sp = rmse(y, combine(preds, w))
    r_j = rmse(y, combine(preds, wj))
    assert abs(r_sp - r_j) < 5e-3
