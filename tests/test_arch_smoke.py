"""Per-architecture smoke tests (required): instantiate a REDUCED variant of
each assigned arch family (2 layers, d_model<=512, <=4 experts) and run one
forward + one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import get_model
from repro.training import adamw, make_train_step

ARCH_IDS = [c.name for c in ASSIGNED]


def make_batch(cfg, key, B=2, S=16):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "targets": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
    }
    if cfg.frontend is not None:
        batch["prefix_embed"] = (
            jax.random.normal(
                ks[2], (B, cfg.frontend.n_prefix_tokens, cfg.frontend.embed_dim)
            )
            * 0.02
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = make_batch(cfg, key)

    loss, metrics = model.loss_fn(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"

    opt = adamw(1e-3)
    step = jax.jit(make_train_step(model, opt))
    params2, opt_state, m2 = step(params, opt.init(params), batch)
    assert bool(jnp.isfinite(m2["loss"]))
    assert bool(jnp.isfinite(m2["grad_norm"]))
    # params actually changed
    l0 = jax.tree_util.tree_leaves(params)[0]
    l1 = jax.tree_util.tree_leaves(params2)[0]
    assert not np.allclose(np.asarray(l0, np.float32),
                           np.asarray(l1, np.float32))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_prefill_decode_shapes(arch):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    if model.prefill is None:
        pytest.skip("no decoder")
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    B, S = 2, 12
    batch = {"tokens": jax.random.randint(key, (B, S), 1, cfg.vocab_size)}
    n_prefix = 0
    if cfg.frontend is not None:
        batch["prefix_embed"] = (
            jax.random.normal(
                key, (B, cfg.frontend.n_prefix_tokens, cfg.frontend.embed_dim)
            )
            * 0.02
        )
        if cfg.family == "vlm":
            n_prefix = cfg.frontend.n_prefix_tokens
    logits, cache = model.prefill(params, batch, S + n_prefix + 4)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    pos = jnp.full((B,), S + n_prefix, jnp.int32)
    logits2, cache = model.decode_step(params, {"token": tok, "pos": pos}, cache)
    assert logits2.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize("arch", ["rwkv6-3b", "zamba2-1.2b"])
def test_chunked_scan_matches_stepwise_loss(arch):
    """The §Perf chunked scan path must be numerically equivalent to the
    per-step baseline at the whole-model level."""
    key = jax.random.PRNGKey(0)
    cfg0 = get_config(arch).reduced()
    m0 = get_model(cfg0)
    params = m0.init(key)
    batch = make_batch(cfg0, key)
    l0, _ = m0.loss_fn(params, batch)
    cfg1 = cfg0.replace(scan_chunked=True, scan_chunk=8)
    m1 = get_model(cfg1)
    l1, _ = m1.loss_fn(params, batch)
    assert abs(float(l0) - float(l1)) < 2e-4, (float(l0), float(l1))


def test_lstm_paper_param_count():
    """The paper reports 10,981 parameters for LSTM(40)+Dense(10)+Dense(1)."""
    from repro.models import nn as nn_mod

    cfg = get_config("lstm-paper")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = nn_mod.count_params(params)
    # 4*40*(5+40+1) + 40*10+10 + 10*1+1 = 7360+410+11... keras counts 10981
    # with recurrent biases merged; our cell uses a single bias vector:
    assert n == 4 * 40 * (5 + 40 + 1) + (40 * 10 + 10) + (10 * 1 + 1)
