"""Edge-cloud runtime simulation tests: determinism, Table-3 structure,
the paper's edge-centric OOM, and the deployment latency ordering."""
import pytest

from repro.runtime import (
    ALL_DEPLOYMENTS,
    CapacityError,
    CostModel,
    EdgeCloudSimulation,
    cloud_centric,
    edge_centric,
    edge_cloud_integrated,
    paper_topology,
)


def run(dep, dynamic=True, strict=False, **cost_kw):
    cost = CostModel(
        batch_infer_s=2.0, speed_infer_s=2.1, hybrid_combine_s=1.5,
        weight_solve_s=0.6, speed_train_s=7.0, ingest_s=3.0, **cost_kw
    )
    sim = EdgeCloudSimulation(dep, paper_topology(), cost,
                              dynamic_weighting=dynamic,
                              strict_capacity=strict)
    return sim.run(20)


def test_simulation_deterministic():
    a = run(edge_cloud_integrated()).table3()
    b = run(edge_cloud_integrated()).table3()
    assert a == b


def test_edge_centric_training_oom():
    """Paper Sec. 6.2: speed training on the Pi fails with OOM."""
    res = run(edge_centric())
    assert len(res.failures) == 20
    assert "OOM" in res.failures[0]
    with pytest.raises(CapacityError):
        run(edge_centric(), strict=True)


def test_cloud_training_fits():
    res = run(edge_cloud_integrated())
    assert res.failures == []
    assert "speed_training" in res.table3()


def test_inference_latency_ordering():
    """Paper Table 3: cloud-centric pays WAN communication on inference;
    edge deployments do not."""
    t_cloud = run(cloud_centric()).table3()
    t_int = run(edge_cloud_integrated()).table3()
    for mod in ("batch_inference", "speed_inference"):
        assert t_cloud[mod]["communication"] > t_int[mod]["communication"]
    # edge compute is slower per unit work (Pi vs c5) — the paper's tradeoff
    assert t_int["batch_inference"]["computation"] > \
        t_cloud["batch_inference"]["computation"]


def test_integrated_total_beats_cloud_centric_with_paper_calibration():
    """With paper-scale communication overheads (Kafka ingest dominates),
    the edge-cloud integrated deployment wins on inference total latency."""
    t_cloud = run(cloud_centric(), window_nbytes=8e6).table3()
    t_int = run(edge_cloud_integrated(), window_nbytes=8e6).table3()
    total_cloud = sum(t_cloud[m]["total"] for m in
                      ("batch_inference", "speed_inference", "hybrid_inference"))
    total_int = sum(t_int[m]["total"] for m in
                    ("batch_inference", "speed_inference", "hybrid_inference"))
    assert total_int < total_cloud


def test_dynamic_weighting_latency_overhead():
    """Paper Fig. 7: dynamic weighting costs extra hybrid-inference time."""
    t_dyn = run(edge_cloud_integrated(), dynamic=True).table3()
    t_stat = run(edge_cloud_integrated(), dynamic=False).table3()
    assert t_dyn["hybrid_inference"]["computation"] > \
        t_stat["hybrid_inference"]["computation"]


def test_model_sync_transfer_time():
    res = run(edge_cloud_integrated(), model_nbytes=2.5e6)
    t = res.table3()["model_sync"]["communication"]
    # 2.5 MB over the 2.5 MB/s WAN + 45 ms latency ~ 1.045 s
    assert 0.9 < t < 1.2


def test_all_deployments_run():
    for name, factory in ALL_DEPLOYMENTS.items():
        res = run(factory())
        assert res.n_windows == 20
        assert "hybrid_inference" in res.table3()
