"""Concept-drift layer tests: the ADF stationarity test (including p-value
interpolation at and beyond the MacKinnon table ends), the online detectors
(Page-Hinkley, two-window mean shift), and the drift-gated retraining policy
built on them."""
import numpy as np
import pytest

from repro.core.drift import (
    _P_TABLE,
    _TAU_TABLE,
    DriftGate,
    PageHinkleyDetector,
    adf_test,
    mackinnon_pvalue,
    window_mean_shift,
)
from repro.streams.sources import apply_scenario, wind_turbine_series


# ---------------------------------------------------------------------------
# ADF stationarity test
# ---------------------------------------------------------------------------


def test_adf_stationary_vs_random_walk():
    rng = np.random.default_rng(0)
    stationary = wind_turbine_series(4000, seed=0)[:, 0]
    res = adf_test(stationary)
    walk = np.cumsum(rng.normal(0, 1, 4000))
    res_walk = adf_test(walk)
    assert res.statistic < res_walk.statistic
    assert res.stationary_5pct
    assert not res_walk.stationary_5pct
    assert res.pvalue < 0.05 < res_walk.pvalue


def test_mackinnon_pvalue_interpolation_bounds():
    """tau beyond either end of the MacKinnon table must clamp to the end
    value (np.interp semantics), never extrapolate outside [0, 1]."""
    lo_tau, hi_tau = _TAU_TABLE[0], _TAU_TABLE[-1]
    lo_p, hi_p = _P_TABLE[0], _P_TABLE[-1]
    # exactly at the table ends
    assert mackinnon_pvalue(lo_tau) == pytest.approx(lo_p)
    assert mackinnon_pvalue(hi_tau) == pytest.approx(hi_p)
    # far beyond either end: clamped, not extrapolated
    assert mackinnon_pvalue(-50.0) == pytest.approx(lo_p)
    assert mackinnon_pvalue(50.0) == pytest.approx(hi_p)
    for tau in (-1e6, -7.3, 2.2, 1e6):
        assert 0.0 <= mackinnon_pvalue(tau) <= 1.0


def test_mackinnon_pvalue_monotone():
    taus = np.linspace(-8.0, 3.0, 200)
    ps = [mackinnon_pvalue(t) for t in taus]
    assert all(b >= a for a, b in zip(ps, ps[1:]))
    # interior table points reproduce exactly
    assert mackinnon_pvalue(-2.86) == pytest.approx(5e-2)
    assert mackinnon_pvalue(-3.43) == pytest.approx(5e-3)


def test_adf_extreme_series_pvalues_clamped():
    """End-to-end: series whose tau lands beyond the table still produce
    p-values inside the table range."""
    # heavily mean-reverting AR(1): tau far more negative than -6
    rng = np.random.default_rng(1)
    y = np.zeros(3000)
    eps = rng.normal(0, 1, 3000)
    for i in range(1, 3000):
        y[i] = -0.9 * y[i - 1] + eps[i]
    res = adf_test(y)
    assert res.statistic < _TAU_TABLE[0]
    assert res.pvalue == pytest.approx(_P_TABLE[0])
    assert res.stationary_5pct
    # explosive trend: tau beyond the positive end
    up = np.exp(np.linspace(0, 12, 600)) + rng.normal(0, 1e-6, 600)
    res_up = adf_test(up)
    assert res_up.pvalue <= _P_TABLE[-1]
    assert not res_up.stationary_5pct


# ---------------------------------------------------------------------------
# online detectors
# ---------------------------------------------------------------------------


def test_page_hinkley_detects_shift():
    det = PageHinkleyDetector(delta=0.01, threshold=1.5)
    rng = np.random.default_rng(0)
    fired_early = any(det.update(x) for x in rng.normal(0, 0.02, 300))
    fired_late = any(det.update(x) for x in rng.normal(2.0, 0.02, 100))
    assert not fired_early
    assert fired_late


def test_window_mean_shift():
    rng = np.random.default_rng(0)
    a = rng.normal(0, 1, 500)
    b = rng.normal(0.05, 1, 500)
    c = rng.normal(3, 1, 500)
    assert not window_mean_shift(a, b)
    assert window_mean_shift(a, c)
    assert isinstance(window_mean_shift(a, c), bool)  # JSON-serializable


# ---------------------------------------------------------------------------
# drift-gated retraining policy
# ---------------------------------------------------------------------------


def _window_targets(scenario, n_windows=12, rpw=250, seed=0, drift_seed=1,
                    alphas=None):
    from repro.core.windows import WindowPlan, WindowedStream
    from repro.streams.normalize import MinMaxScaler

    series = wind_turbine_series(1600 + rpw * n_windows + 5, seed=seed)
    hist, tail = series[:1600], series[1600:]
    if alphas is None and scenario == "gradual":
        alphas = np.full(5, 1.5e-3)
    tail = apply_scenario(tail, scenario, seed=drift_seed, alphas=alphas)
    scaler = MinMaxScaler.fit(hist)
    stream = WindowedStream(scaler.transform(tail),
                            WindowPlan(n_windows, rpw, lag=5))
    return [stream.supervised(t)["y"] for t in range(n_windows)]


def test_gate_always_retrains_warmup_then_skips_stationary():
    gate = DriftGate()
    ys = _window_targets("none")
    decisions = [gate.decide("t00", y) for y in ys]
    assert decisions[0] is True  # warmup
    stats = gate.stats()
    assert stats["skipped"] > 0
    assert stats["retrained"] + stats["skipped"] == len(ys)
    # a stationary stream skips most windows
    assert stats["skipped"] > stats["retrained"]


def test_gate_fires_on_drift_more_than_stationary():
    counts = {}
    for scenario, alphas in (("none", None),
                             ("gradual", np.full(5, 5e-3))):
        gate = DriftGate()
        for y in _window_targets(scenario, n_windows=16, alphas=alphas):
            gate.decide("s", y)
        counts[scenario] = gate.stats()["retrained"]
    assert counts["gradual"] > counts["none"]
    assert counts["none"] < 16  # the stationary stream skips windows


def test_gate_abrupt_jump_fires_immediately():
    """A hard mean jump after warmup must fire on the window it appears."""
    gate = DriftGate()
    rng = np.random.default_rng(0)
    base = [rng.normal(0.5, 0.01, 250) for _ in range(4)]
    jumped = rng.normal(0.9, 0.01, 250)
    decisions = [gate.decide("s", y) for y in base]
    assert decisions[0] is True and not any(decisions[1:])
    assert gate.decide("s", jumped) is True


def test_gate_per_stream_state_independent():
    gate = DriftGate()
    rng = np.random.default_rng(0)
    steady = [rng.normal(0.5, 0.01, 250) for _ in range(6)]
    drifting = [rng.normal(0.5 + 0.1 * i, 0.01, 250) for i in range(6)]
    for ys, sid in ((steady, "a"), (drifting, "b")):
        for y in ys:
            gate.decide(sid, y)
    per = gate.stats()["per_stream"]
    assert per["a"]["skipped"] == 5  # everything after warmup
    assert per["b"]["retrained"] == 6  # fires every window
    log = gate.retrain_log()
    assert len(log["a"]) == len(log["b"]) == 6
