"""Serving engine + batching tests (the drift-detector tests live in
``tests/test_drift.py``)."""
import jax
import numpy as np

from repro.configs import get_config
from repro.models import get_model
from repro.serving import BatchScheduler, Engine, Request


def test_engine_generate_greedy_deterministic():
    cfg = get_config("tinyllama-1.1b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(cfg, params, max_len=32)
    prompts = np.random.default_rng(0).integers(1, cfg.vocab_size, (2, 8),
                                                dtype=np.int32)
    out1, stats = engine.generate(prompts, 6)
    out2, _ = engine.generate(prompts, 6)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(out1, out2)
    assert stats.prefill_s > 0 and stats.tokens_out == 12


def test_batch_scheduler_slots():
    s = BatchScheduler(2)
    reqs = [Request(uid=i, prompt=np.arange(4, dtype=np.int32),
                    max_new_tokens=2) for i in range(3)]
    for r in reqs:
        s.submit(r)
    admitted = s.admit()
    assert admitted == [0, 1]
    assert s.active() == [0, 1]
    # finish slot 0's request
    s.slots[0].request.generated = [1, 2]
    done = s.retire_finished(now=1.0)
    assert len(done) == 1 and done[0].uid == 0
    assert s.admit() == [0]  # third request admitted into freed slot
    assert not s.idle


def test_engine_serve_continuous_batching():
    """Wave batching drains a queue larger than the slot count, honoring
    per-request max_new_tokens and varying prompt lengths."""
    cfg = get_config("tinyllama-1.1b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(cfg, params, max_len=48)
    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=i,
                prompt=rng.integers(1, cfg.vocab_size, (4 + 3 * (i % 3),),
                                    dtype=np.int32),
                max_new_tokens=2 + (i % 4))
        for i in range(5)
    ]
    done = engine.serve(list(reqs), n_slots=2)
    assert len(done) == 5
    assert {r.uid for r in done} == set(range(5))
    for r in done:
        assert len(r.generated) == r.max_new_tokens
        assert all(0 <= t < cfg.vocab_size for t in r.generated)
