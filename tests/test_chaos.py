"""Chaos-plane tests: kernel pause/resume ordering, dead-letter delivery,
checksum/corruption primitives, seeded fault-schedule determinism, and the
end-to-end properties the chaos bench gates on — same seed means a
byte-identical run, corrupted int8 model publishes are never installed, a
stream whose sensor goes totally dark is quarantined without stalling the
rest of the fleet, forged publishes are HMAC-rejected and re-requested,
partitions are detected within two heartbeat intervals with zero
fault-free false positives, and the adaptive-threshold path is
byte-identical to static thresholds when calm."""
import jax
import numpy as np
import pytest

from repro.core.scenarios import (
    RMSE_RATIO_MAX,
    ChaosHarness,
    bus_signature,
    forecast_signature,
    ledger_signature,
)
from repro.runtime import (
    EventKernel,
    FaultPlane,
    MessageFault,
    SensorFault,
    TopicBus,
    Topology,
    corrupt_tree,
    paper_topology,
    tree_checksum,
)

SEED = 0
PERIOD = 5.0


@pytest.fixture(scope="module")
def harness():
    return ChaosHarness(n_streams=2, n_windows=4, records_per_window=80,
                        period_s=PERIOD, qps=6.0)


@pytest.fixture(scope="module")
def fault_free(harness):
    return harness.run_scenario("fault_free", seed=SEED)


# ---------------------------------------------------------------------------
# kernel + bus primitives
# ---------------------------------------------------------------------------


def test_kernel_run_until_pauses_and_resumes_in_order():
    """run(until=) must not consume events beyond the horizon: pausing
    mid-schedule and resuming replays the remainder in exact (time, FIFO)
    order, including events that share a timestamp."""
    k = EventKernel()
    fired = []
    for name, t in [("a", 1.0), ("b", 2.0), ("b2", 2.0), ("c", 3.0)]:
        k.at(t, lambda n=name: fired.append((n, k.now)))
    k.run(until=1.5)
    assert fired == [("a", 1.0)]
    k.run(until=2.0)
    assert fired == [("a", 1.0), ("b", 2.0), ("b2", 2.0)]
    k.run()
    assert fired == [("a", 1.0), ("b", 2.0), ("b2", 2.0), ("c", 3.0)]


def test_publish_without_link_is_dead_lettered_not_raised():
    from repro.runtime import Site

    topo = Topology(sites={
        "edge": Site("edge", "edge", compute_scale=1.0, memory_bytes=1e9,
                     workers=1),
        "cloud": Site("cloud", "cloud", compute_scale=1.0, memory_bytes=1e9,
                      workers=1),
    }, links={})  # no link between them
    k = EventKernel()
    bus = TopicBus(k, topo)
    got = []
    bus.subscribe("data/+", "cloud", lambda m: got.append(m))
    bus.publish("data/t00", {"x": 1}, src="edge", nbytes=8.0)
    k.run()
    assert got == []
    assert len(bus.dead_letters) == 1
    dl = bus.dead_letters[0]
    assert dl.topic == "data/t00" and dl.reason == "no-link"
    assert (dl.src, dl.dst) == ("edge", "cloud")


def test_tree_checksum_catches_single_bit_flip():
    tree = {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": np.zeros(4, dtype=np.int8)}
    ck = tree_checksum(tree)
    assert ck == tree_checksum(tree)  # stable
    for trial in range(8):
        bad = corrupt_tree(tree, np.random.default_rng(trial))
        assert tree_checksum(bad) != ck
    assert tree_checksum(tree) == ck  # corrupt_tree copies, never mutates


# ---------------------------------------------------------------------------
# fault-plane determinism units
# ---------------------------------------------------------------------------


def _plan_all(plane, n=40):
    topo = paper_topology()
    k = EventKernel()
    bus = TopicBus(k, topo, fault_plane=plane)
    out = []
    for i in range(n):
        out.append([t for t, _ in plane.plan_deliveries(
            f"model/latest/t{i % 3:02d}", {"i": i}, "cloud", "edge",
            t_pub=float(i), dt=0.05, bus=bus)])
    return out


def test_message_fault_schedule_is_seed_deterministic():
    spec = [MessageFault("model/latest/*", "drop", p=0.3),
            MessageFault("model/latest/*", "delay", p=0.5, delay_s=1.0,
                         jitter_s=0.5)]
    a = _plan_all(FaultPlane(11, message_faults=list(spec)))
    b = _plan_all(FaultPlane(11, message_faults=list(spec)))
    c = _plan_all(FaultPlane(12, message_faults=list(spec)))
    assert a == b
    assert a != c
    p = FaultPlane(11, message_faults=list(spec))
    first = _plan_all(p)
    p.reset()
    assert _plan_all(p) == first  # reset() rewinds the RNG streams


def test_sensor_fault_windows_are_seed_deterministic():
    spec = SensorFault(p_drop_window=0.3, p_dup_window=0.3, p_reorder=0.5,
                       reorder_jitter_s=1.0, p_drop_record=0.2)
    data = {"x": np.ones((20, 5), np.float32), "y": np.ones(20, np.float32)}

    def schedule(plane):
        out = []
        for w in range(12):
            for t, d in plane.sensor_windows("t00", w, float(w), data):
                out.append((w, t, d["x"].shape[0]))
        return out

    a = schedule(FaultPlane(5, sensor_faults=[spec]))
    b = schedule(FaultPlane(5, sensor_faults=[spec]))
    c = schedule(FaultPlane(6, sensor_faults=[spec]))
    assert a == b
    assert a != c


# ---------------------------------------------------------------------------
# end-to-end properties (small fleet, module-shared pretrain)
# ---------------------------------------------------------------------------


def test_same_seed_same_run_different_seed_differs(harness):
    """The satellite determinism contract: one fault seed reproduces the
    whole run byte for byte — bus log, latency ledger, forecasts and served
    answers — while a different seed yields a different fault schedule."""
    _, r1 = harness.run_scenario("sensor_chaos", seed=SEED)
    _, r2 = harness.run_scenario("sensor_chaos", seed=SEED)
    _, r3 = harness.run_scenario("sensor_chaos", seed=SEED + 7)
    assert bus_signature(r1) == bus_signature(r2)
    assert ledger_signature(r1) == ledger_signature(r2)
    assert forecast_signature(r1) == forecast_signature(r2)
    assert bus_signature(r1) != bus_signature(r3)


def test_corrupted_sync_always_detected_never_installed(harness):
    """Bit-flip every int8 model publish: the checksum must reject 100% of
    them, no speed model may ever be installed, and serving must survive on
    the batch path (every answer is fallback or batch-model)."""
    plane = FaultPlane(SEED, message_faults=[
        MessageFault("model/latest/*", "corrupt", p=1.0)])
    ex = harness.executor(plane, quantized=True)
    res = ex.run(harness._base_streams, harness.bp, jax.random.PRNGKey(1))
    chaos = res.chaos
    assert chaos["fault_stats"]["msg_corrupt"] > 0
    # every corrupted delivery was rejected at verification
    assert chaos["corrupt_rejected"] == chaos["fault_stats"]["msg_corrupt"]
    assert chaos["checksum_verified"] == 0  # nothing clean ever arrived
    # no speed model was ever installed, so serving never left the fallback
    for q in res.queries:
        assert q.served_fallback or q.model_window < 0
    # and the re-request path was exercised (bounded retries)
    assert chaos["resync_requests"] > 0


def test_dark_sensor_stream_is_quarantined_fleet_continues(harness):
    """t00's sensor goes permanently dark after the first window: the fleet
    must quarantine it (after repeated aggregation misses) instead of
    stalling every other stream's windowed dispatch."""
    plane = FaultPlane(SEED, sensor_faults=[
        SensorFault(stream="t00", p_drop_window=1.0, start=0.9 * PERIOD)])
    ex = harness.executor(plane)
    res = ex.run(harness._base_streams, harness.bp, jax.random.PRNGKey(1))
    assert "t00" in res.chaos["quarantined"]
    assert res.chaos["fault_stats"]["stream_quarantined"] >= 1
    # the healthy stream kept scoring windows after t00 went dark (window 0
    # bootstraps the speed model, so a clean run scores n_windows - 1)
    assert len(res.results["t01"].records) == harness.n_windows - 1
    assert (len(res.results["t00"].records)
            < len(res.results["t01"].records))
    # quarantine must not poison the run: the healthy stream still trains
    assert res.train_dispatches >= 1


# ---------------------------------------------------------------------------
# the health plane (end to end)
# ---------------------------------------------------------------------------


def test_fault_free_run_has_zero_health_false_positives(fault_free):
    """The detector's floor: a calm run must produce no suspicions, no
    Byzantine flags, no signature rejections, no threshold adaptations."""
    env, res = fault_free
    h = env["health"]
    assert h["n_suspected"] == 0 and h["n_site_down"] == 0
    assert h["byz_flagged"] == 0 and h["byz_screened"] > 0
    assert env["forged_rejected"] == 0
    assert h["threshold_adaptations"] == 0


def test_partition_detected_within_two_heartbeat_intervals(harness):
    """The goldpinger-style monitors must name the injected partition within
    two heartbeat intervals of onset, and see the heal as a recovery."""
    env, res = harness.run_scenario("partitioned_sync", seed=SEED)
    h = env["health"]
    assert h["n_suspected"] >= 1
    assert h["detection_latency_hb_intervals"] <= 2.0
    assert h["n_recovered"] >= 1


def test_forged_sync_always_hmac_rejected_never_installed(harness):
    """Forge every int8 model publish (valid recomputed crc32): the
    checksum layer must catch nothing, the HMAC layer must catch all, and
    no forged model may ever be served."""
    plane = FaultPlane(SEED, message_faults=[
        MessageFault("model/latest/*", "forge", p=1.0)])
    ex = harness.executor(plane, quantized=True, health_plane=harness.health)
    res = ex.run(harness._base_streams, harness.bp, jax.random.PRNGKey(1))
    chaos = res.chaos
    assert chaos["fault_stats"]["msg_forge"] > 0
    assert chaos["forged_rejected"] == chaos["fault_stats"]["msg_forge"]
    assert chaos["corrupt_rejected"] == 0  # crc32 accepted every forgery
    for q in res.queries:
        assert q.served_fallback or q.model_window < 0
    assert chaos["resync_requests"] > 0


def test_forged_sync_scenario_recovers_via_resync(harness):
    """At forge p=0.5 the reject -> re-request -> accept loop must land
    clean models: every forgery rejected, yet speed models still install
    and serve."""
    env, res = harness.run_scenario("forged_sync", seed=SEED)
    assert env["unhandled_exception"] is None
    assert env["fault_stats"]["msg_forge"] > 0
    assert env["forged_rejected"] == env["fault_stats"]["msg_forge"]
    assert env["resync_requests"] > 0
    # clean (re-sent) publishes made it through both layers and served
    assert env["checksum_verified"] > 0
    assert any(q.model_window >= 0 and not q.served_fallback
               for q in res.queries)


def test_byzantine_values_flagged_imputed_within_envelope(harness,
                                                          fault_free):
    """Plausible-but-wrong sensor values are flagged by the median/MAD gate
    and imputed before training — degradation stays inside the scenario's
    envelope and no stream is quarantined (the windows still flow)."""
    env, res = harness.run_scenario("byzantine", seed=SEED)
    env_ff, _ = fault_free
    h = env["health"]
    assert h["byz_flagged"] > 0
    assert env["quarantined"] == {}
    ratio = env["rmse_hybrid"] / env_ff["rmse_hybrid"]
    assert ratio <= RMSE_RATIO_MAX["byzantine"]


def test_quarantined_stream_revives_under_adaptive_thresholds(harness):
    """t00 goes dark long enough to be quarantined (misses feed the fault
    rate, which tightens its quarantine threshold), then its sensor
    resumes: the stream must be revived and score again by the end."""
    plane = FaultPlane(SEED, sensor_faults=[
        SensorFault(stream="t00", p_drop_window=1.0, start=0.9 * PERIOD,
                    end=2.9 * PERIOD)])
    ex = harness.executor(plane, health_plane=harness.health)
    res = ex.run(harness._base_streams, harness.bp, jax.random.PRNGKey(1))
    stats = res.chaos["fault_stats"]
    assert stats["stream_quarantined"] >= 1
    assert stats["quarantine_revived"] >= 1
    assert "t00" not in res.chaos["quarantined"]  # back in the fleet at end
    assert len(res.results["t00"].records) >= 1  # scored after revival
    # the misses registered as fault pressure and tightened the threshold
    assert res.health["threshold_adaptations"] >= 1
    assert res.health["adapted_quarantine_after"].get("t00", 99) \
        < res.health["base_quarantine_after"]


def test_adaptive_calm_run_byte_identical_to_static_thresholds(harness,
                                                               fault_free):
    """Adaptation must cost nothing when nothing is wrong: the fault-free
    run under adaptive thresholds is byte-identical — bus log, ledger,
    forecasts — to the same run under static thresholds."""
    _, r_adaptive = fault_free
    _, r_static = harness.run_scenario("fault_free", seed=SEED,
                                       adaptive=False)
    assert bus_signature(r_adaptive) == bus_signature(r_static)
    assert ledger_signature(r_adaptive) == ledger_signature(r_static)
    assert forecast_signature(r_adaptive) == forecast_signature(r_static)


def test_compound_drift_includes_seasonal_and_holds_envelope(harness,
                                                             fault_free):
    """The compound scenario's per-stream cycle now includes the seasonal
    excursion-and-return regime (second in the cycle, so even this
    2-stream harness exercises it) and must stay inside its envelope."""
    streams = harness.streams_for("compound_drift")
    assert len(streams) == harness.n_streams  # gradual + seasonal here
    env, res = harness.run_scenario("compound_drift", seed=SEED)
    env_ff, _ = fault_free
    assert env["unhandled_exception"] is None
    ratio = env["rmse_hybrid"] / env_ff["rmse_hybrid"]
    assert ratio <= RMSE_RATIO_MAX["compound_drift"]


def test_seasonal_drift_departs_and_returns():
    """The seasonal scenario's defining property (vs Eq. 6's monotone
    ramp): the drift component is periodic — it leaves the baseline,
    crosses back through it inside every cycle, and repeats exactly one
    period later instead of ramping away forever."""
    from repro.streams.sources import seasonal_drift

    rng = np.random.default_rng(0)
    base = rng.normal(0.0, 1.0, (600, 5)).astype(np.float32)
    period = 200
    out = seasonal_drift(base, period=period, eps_scale=0.0, seed=3,
                         start=0)
    comp = out - base
    # excursion reaches ~1 sigma ...
    assert np.abs(comp[1:]).max() > 0.5
    # ... crosses back through the baseline within each cycle (per channel)
    per_ch_min = np.abs(comp[1:1 + period]).min(axis=0)
    assert (per_ch_min < 0.05 * np.abs(comp[1:]).max(axis=0)).all()
    # ... and repeats: one full period later the component is identical
    np.testing.assert_allclose(comp[1:1 + period],
                               comp[1 + period:1 + 2 * period],
                               rtol=0, atol=1e-4)
