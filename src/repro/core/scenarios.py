"""Chaos scenarios: named fault-plane configurations with measured
degradation envelopes.

Each scenario pairs a :class:`~repro.runtime.faults.FaultPlane` recipe with
the fleet pipeline (``launch.edge_cloud.build_fleet_pipeline``) under the
edge-cloud-integrated deployment and an open-loop query load, and measures
how the system *degrades* — RMSE ratio vs the fault-free run, answer-latency
tail, worst served staleness, fraction of answers from the batch-model
fallback — instead of assuming it degrades gracefully:

* ``fault_free``          — an empty fault plane; must be parity with the
  plain (no-plane) run: identical forecasts, identical dispatch counts.
* ``site_crash``          — the cloud (speed training) crashes mid-window-2
  with in-flight work lost, restarts cold at window 3.5; staleness grows
  until training resumes.
* ``partitioned_sync``    — the edge<->cloud WAN partitions for ~2 windows
  (deliveries queue until heal): model sync is delayed past the staleness
  bound, the watchdog must flip serving to the batch fallback.
* ``sensor_chaos``        — windows drop, duplicate, arrive late; records
  drop inside windows; flush timeouts + per-stream quarantine keep the
  fleet's aggregated dispatch moving.
* ``corrupted_int8_sync`` — int8 model sync with bit-flip corruption on half
  the model publishes: every corrupt publish must be checksum-detected and
  never served; re-requests recover clean copies.
* ``forged_sync``         — int8 model sync with *forged* publishes: the
  adversary perturbs the parameters and recomputes the crc32 so the
  checksum alone would accept — only the HMAC signature (health plane's
  signed sync) catches it; every forge must be rejected and re-requested.
* ``byzantine``           — sensors emit plausible-but-wrong values (offset
  by several robust sigmas, not NaN garbage): the per-stream median/MAD
  guard must flag and impute them before they reach training.
* ``compound_drift``      — no injected faults, adversarial *data*: the
  fleet mixes gradual, seasonal, abrupt, and stationary streams per stream.

All runs use ``CHAOS_STAGE_COSTS`` — fixed virtual stage walls instead of
perf-counter measurements — so the same fault seed reproduces the run
byte-for-byte (bus log, ledger, forecasts): determinism is an asserted
property, not an aspiration (see ``schedule_signature``/``bus_signature``).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.runtime.faults import (
    FaultPlane,
    MessageFault,
    PartitionFault,
    SensorFault,
    SiteFault,
)

# fixed virtual wall-seconds per module: deterministic stand-ins for the
# measured stage walls, sized to the fast-mode steady-state measurements
# (training dominates; serving ticks are cheap).  Both the chaos runs AND
# the fault-free baseline use these, so RMSE parity is exact.
CHAOS_STAGE_COSTS: Dict[str, float] = {
    "batch_inference": 0.05,
    "speed_inference": 0.05,
    "hybrid_inference": 0.01,
    "speed_training": 0.5,
    "model_sync": 0.01,
    "data_sync": 0.005,
    "serving": 0.02,
    # the elastic control plane's fixed decision cost (elastic runs only;
    # chaos scenarios never enable the controller, so this key is inert
    # there — it exists so elastic runs under stage_costs replay
    # byte-for-byte too)
    "placement_controller": 0.005,
}

SCENARIOS = ("fault_free", "site_crash", "partitioned_sync", "sensor_chaos",
             "corrupted_int8_sync", "forged_sync", "byzantine",
             "compound_drift")

# per-scenario degradation envelope: max hybrid-RMSE ratio vs the fault-free
# run.  fault_free is exact parity; partition/crash must stay within the
# paper-claim bound (the watchdog serving the batch model is itself a model,
# not garbage); sensor and compound chaos change the *data*, so their
# envelopes are looser.
RMSE_RATIO_MAX: Dict[str, float] = {
    "fault_free": 1.0 + 1e-9,
    "site_crash": 1.5,
    "partitioned_sync": 1.5,
    "sensor_chaos": 2.0,
    "corrupted_int8_sync": 1.5,
    "forged_sync": 1.5,
    "byzantine": 2.5,
    "compound_drift": 3.0,
}


def scenario_plane(name: str, seed: int, period_s: float) -> FaultPlane:
    """Build the named scenario's seeded fault plane.  Times are in units
    of the window period so the faults land mid-pipeline at any period."""
    p = period_s
    if name == "fault_free" or name == "compound_drift":
        return FaultPlane(seed)
    if name == "site_crash":
        # down during window 2's training, cold restart mid-window 3
        return FaultPlane(seed, site_faults=[
            SiteFault("cloud", t_down=2.02 * p, t_up=3.5 * p)])
    if name == "partitioned_sync":
        return FaultPlane(seed, partitions=[
            PartitionFault("edge", "cloud", t_start=1.2 * p, t_heal=3.4 * p,
                           mode="queue")])
    if name == "sensor_chaos":
        return FaultPlane(seed, sensor_faults=[
            SensorFault(p_drop_window=0.15, p_dup_window=0.15, p_reorder=0.3,
                        reorder_jitter_s=0.3 * p, p_drop_record=0.1)])
    if name == "corrupted_int8_sync":
        return FaultPlane(seed, message_faults=[
            MessageFault("model/latest/*", "corrupt", p=0.5)])
    if name == "forged_sync":
        return FaultPlane(seed, message_faults=[
            MessageFault("model/latest/*", "forge", p=0.5)])
    if name == "byzantine":
        return FaultPlane(seed, sensor_faults=[
            SensorFault(p_byzantine=0.5)])
    raise ValueError(f"unknown scenario {name!r}; pick from {SCENARIOS}")


def scenario_quantized(name: str) -> bool:
    """The corruption and forgery scenarios force int8 sync: bit flips in a
    quantized tree are corruption's whole point, and forgery must prove the
    HMAC covers the int8 QTensor serialization too.  The rest inherit the
    harness default."""
    return name in ("corrupted_int8_sync", "forged_sync")


def scenario_fault_start(name: str, period_s: float) -> Optional[float]:
    """Virtual time the named scenario's connectivity fault begins — the
    reference point for measured partition/crash detection latency.  None
    for scenarios with no site/link outage."""
    if name == "site_crash":
        return 2.02 * period_s
    if name == "partitioned_sync":
        return 1.2 * period_s
    return None


# -- determinism signatures ---------------------------------------------------


def bus_signature(res) -> List[Tuple]:
    """The bus log reduced to its schedule: (topic, src, bytes, publish,
    deliver) per message, exact floats — two runs under one fault seed must
    match entry for entry."""
    return [(m.topic, m.src, float(m.nbytes), m.publish_time, m.deliver_time)
            for m in res.message_log]


def ledger_signature(res) -> Dict[str, Dict[str, float]]:
    return res.ledger.table()


def forecast_signature(res) -> List[Tuple]:
    """Per-stream window forecasts (+ served query answers), excluding the
    measured host walls (t_*) which are not part of the deterministic
    contract."""
    sig: List[Tuple] = []
    for sid in sorted(res.results):
        for r in res.results[sid].records:
            sig.append((sid, r.window, r.rmse_batch, r.rmse_speed,
                        r.rmse_hybrid, r.w_speed))
    for q in res.queries:
        sig.append((q.stream, q.uid, tuple(q.answer), q.model_window,
                    q.context_window, q.served_fallback))
    return sig


class ChaosHarness:
    """Build the fleet pipeline once, run it under any scenario's fault
    plane.

    The pretrained batch model and stage set are shared across scenarios
    (stream *history* is drift-independent by construction —
    ``fleet_windowed_streams`` starts drift where the live stream starts —
    so one pretrain serves every stream-scenario mix, including
    ``compound_drift``'s per-stream gradual/seasonal/abrupt/none cycle).

    Every scenario run carries the self-diagnosing health plane
    (``runtime.health.HealthPlane``): heartbeat partition detection, signed
    model sync, the Byzantine value guard, and adaptive fault thresholds.
    ``run_scenario(..., adaptive=False)`` swaps in a static-threshold plane
    so the adaptive path can be proven byte-identical when no faults fire.
    ``run_plain`` stays plane-less — the parity reference."""

    def __init__(self, *, n_streams: int = 3, n_windows: int = 6,
                 records_per_window: int = 120, period_s: float = 5.0,
                 qps: float = 8.0, serve_slots: int = 4,
                 staleness_bound: int = 1, base_scenario: str = "gradual",
                 verbose: bool = False):
        from repro.launch.edge_cloud import build_fleet_pipeline
        from repro.runtime.health import HealthConfig, HealthPlane

        self.n_streams = n_streams
        self.n_windows = n_windows
        self.rpw = records_per_window
        self.period = period_s
        self.qps = qps
        self.serve_slots = serve_slots
        self.staleness_bound = staleness_bound
        self.base_scenario = base_scenario
        self.health = HealthPlane(HealthConfig())
        self.health_static = HealthPlane(HealthConfig(adaptive=False))
        self.stages, self.bp, self._base_streams, self.cost = \
            build_fleet_pipeline(n_streams, n_windows, fast=True,
                                 records_per_window=records_per_window,
                                 scenario=base_scenario, verbose=verbose)
        self._compound_streams = None

    def streams_for(self, name: str):
        if name != "compound_drift":
            return self._base_streams
        if self._compound_streams is None:
            from repro.streams.sources import fleet_windowed_streams

            # seasonal sits second so even the 2-stream smoke harness
            # exercises the excursion-and-return regime
            cycle = ["gradual", "seasonal", "abrupt", "none"]
            scenarios = [cycle[i % len(cycle)]
                         for i in range(self.n_streams)]
            self._compound_streams, _ = fleet_windowed_streams(
                self.n_streams, self.n_windows, self.rpw, scenarios,
                alphas=np.full(5, 1.5e-3))
        return self._compound_streams

    def executor(self, fault_plane: Optional[FaultPlane],
                 quantized: bool = False, health_plane=None):
        from repro.runtime import FleetBusExecutor, paper_topology
        from repro.runtime.deployment import edge_cloud_integrated

        return FleetBusExecutor(
            self.stages, edge_cloud_integrated(), paper_topology(),
            self.cost, window_period_s=self.period, qps=self.qps,
            serve_slots=self.serve_slots, quantized_sync=quantized,
            fault_plane=fault_plane, stage_costs=dict(CHAOS_STAGE_COSTS),
            staleness_bound=self.staleness_bound,
            health_plane=health_plane)

    def run_plain(self):
        """The non-chaos reference path: no fault plane at all (the bus
        publish fast path, no flush timers) but the same deterministic
        stage costs — what ``fault_free`` must be parity with."""
        import jax

        ex = self.executor(None)
        return ex.run(self._base_streams, self.bp, jax.random.PRNGKey(1))

    def run_scenario(self, name: str, seed: int = 0, adaptive: bool = True
                     ) -> Tuple[Dict[str, Any], Any]:
        """Run one scenario; returns (envelope, FleetBusRunResult).  Any
        exception is itself a failed envelope (``unhandled_exception``) —
        chaos must degrade the numbers, never crash the runtime."""
        import jax

        plane = scenario_plane(name, seed, self.period)
        hp = self.health if adaptive else self.health_static
        ex = self.executor(plane, quantized=scenario_quantized(name),
                           health_plane=hp)
        try:
            res = ex.run(self.streams_for(name), self.bp,
                         jax.random.PRNGKey(1))
        except Exception as e:  # noqa: BLE001 - the envelope records it
            return {"scenario": name, "seed": seed,
                    "unhandled_exception": f"{type(e).__name__}: {e}"}, None
        env = self.envelope(name, seed, res)
        return env, res

    def envelope(self, name: str, seed: int, res) -> Dict[str, Any]:
        s = res.serving or {}
        env = {
            "scenario": name,
            "seed": seed,
            "unhandled_exception": None,
            "rmse_hybrid": res.mean_rmse()["hybrid"],
            "n_windows_scored": sum(len(r.records)
                                    for r in res.results.values()),
            "train_dispatches": res.train_dispatches,
            "n_answered": s.get("n_answered", 0),
            "n_starved": s.get("n_starved", 0),
            "p99_latency_s": s.get("p99_s", float("inf")),
            "max_staleness": s.get("max_staleness", 0),
            "fallback_frac": s.get("fallback_frac", 0.0),
            "capacity_failures": len(res.failures),
            "dead_letters": len(res.dead_letters),
        }
        if res.chaos is not None:
            env["fault_stats"] = res.chaos["fault_stats"]
            env["n_fault_events"] = res.chaos["n_fault_events"]
            env["corrupt_rejected"] = res.chaos["corrupt_rejected"]
            env["checksum_verified"] = res.chaos["checksum_verified"]
            env["resync_requests"] = res.chaos["resync_requests"]
            env["quarantined"] = res.chaos["quarantined"]
            env["forged_rejected"] = res.chaos.get("forged_rejected", 0)
        h = getattr(res, "health", None)
        if h is not None:
            env["health"] = {
                "signed_sync": h["signed_sync"],
                "adaptive": h["adaptive"],
                "n_suspected": h["n_suspected"],
                "n_site_down": h["n_site_down"],
                "n_recovered": h["n_recovered"],
                "first_suspect_t": h["first_suspect_t"],
                "hb_interval_s": h["hb_interval_s"],
                "byz_screened": h["byz_screened"],
                "byz_flagged": h["byz_flagged"],
                "threshold_adaptations": h["threshold_adaptations"],
                "adapted_quarantine_after": h["adapted_quarantine_after"],
                "adapted_staleness_bound": h["adapted_staleness_bound"],
            }
            t0 = scenario_fault_start(name, self.period)
            if t0 is not None and h["first_suspect_t"] is not None:
                env["health"]["detection_latency_s"] = (
                    h["first_suspect_t"] - t0)
                env["health"]["detection_latency_hb_intervals"] = (
                    (h["first_suspect_t"] - t0) / h["hb_interval_s"])
        return env
