"""Adaptive hybrid stream analytics (paper Sec. 5): lambda-architecture
orchestration of batch, speed and hybrid layers over a windowed stream.

Per time window t (paper Fig. 4):

  inference phase: batch inference with the one-time pre-trained model M^b;
  speed inference with M^s_{t-1} (trained on the previous window); hybrid
  inference combines the two with static or dynamic (Algorithm 1) weights.

  training phase (async): speed training of M^s_t on window t's records.

The orchestrator is generic over ``Forecaster`` so any model-zoo member can
be the backbone; ``lstm_forecaster`` builds the paper's exact setup
(batch: 50 epochs x bs 512; speed: 100 epochs x bs 64; lr 1e-3).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.weighting import (
    combine,
    dwa_closed_form,
    dwa_scipy,
    rmse,
    static_weights,
)
from repro.core.windows import WindowedStream
from repro.models.model import Model, get_model
from repro.training.train_loop import fit

Params = Any


@dataclass(frozen=True)
class Forecaster:
    """train(data, params, key) -> (params, wall_s); predict(params, x) -> y."""

    train: Callable[[Dict[str, np.ndarray], Optional[Params], jax.Array],
                    Tuple[Params, float]]
    predict: Callable[[Params, np.ndarray], np.ndarray]


def lstm_forecaster(cfg: ModelConfig, *, epochs: int, batch_size: int,
                    lr: float = 1e-3, warm_start: bool = False) -> Forecaster:
    model = get_model(cfg)
    from repro.models import lstm as lstm_mod

    predict_jit = jax.jit(lambda p, x: lstm_mod.predict(cfg, p, x))

    def train(data, params, key):
        res = fit(model, data, epochs=epochs, batch_size=batch_size, lr=lr,
                  params=params if warm_start else None, key=key)
        return res.params, res.wall_time_s

    def predict(params, x):
        return np.asarray(predict_jit(params, x))

    return Forecaster(train=train, predict=predict)


@dataclass
class WindowRecord:
    window: int
    rmse_batch: float
    rmse_speed: float
    rmse_hybrid: float
    w_speed: float
    w_batch: float
    t_speed_train: float = 0.0
    t_batch_infer: float = 0.0
    t_speed_infer: float = 0.0
    t_hybrid_infer: float = 0.0
    t_weight_solve: float = 0.0


@dataclass
class HybridRunResult:
    records: List[WindowRecord]
    mode: str

    def mean_rmse(self) -> Dict[str, float]:
        return {
            "batch": float(np.mean([r.rmse_batch for r in self.records])),
            "speed": float(np.mean([r.rmse_speed for r in self.records])),
            "hybrid": float(np.mean([r.rmse_hybrid for r in self.records])),
        }

    def best_fraction(self) -> Dict[str, float]:
        """Paper Tables 4-6: time percentage each inference is the best."""
        wins = {"batch": 0, "speed": 0, "hybrid": 0}
        for r in self.records:
            best = min(
                ("speed", r.rmse_speed),
                ("batch", r.rmse_batch),
                ("hybrid", r.rmse_hybrid),
                key=lambda kv: kv[1],
            )[0]
            wins[best] += 1
        n = max(len(self.records), 1)
        return {k: v / n for k, v in wins.items()}

    def mean_latency(self) -> Dict[str, float]:
        return {
            "speed_train": float(np.mean([r.t_speed_train for r in self.records])),
            "batch_infer": float(np.mean([r.t_batch_infer for r in self.records])),
            "speed_infer": float(np.mean([r.t_speed_infer for r in self.records])),
            "hybrid_infer": float(np.mean([r.t_hybrid_infer for r in self.records])),
            "weight_solve": float(np.mean([r.t_weight_solve for r in self.records])),
        }


class HybridStreamAnalytics:
    """The adaptive hybrid learner.

    mode: "dynamic" (Algorithm 1), ("static", w_speed), "speed", "batch".
    ``dwa_solver``: "scipy" (paper SLSQP) or "closed_form" (TPU-native).
    """

    def __init__(
        self,
        forecaster: Forecaster,
        mode: str | Tuple[str, float] = "dynamic",
        dwa_solver: str = "closed_form",
    ):
        self.forecaster = forecaster
        self.mode = mode
        self.dwa_solver = dwa_solver

    def _weights(self, prev_preds, prev_y) -> Tuple[float, float, float]:
        """(w_speed, w_batch, solve_seconds) for the current window."""
        if isinstance(self.mode, tuple) and self.mode[0] == "static":
            ws, wb = static_weights(self.mode[1])
            return ws, wb, 0.0
        if self.mode == "dynamic":
            if prev_preds is None:
                return 0.5, 0.5, 0.0
            t0 = time.perf_counter()
            if self.dwa_solver == "scipy":
                w = dwa_scipy([prev_preds[0], prev_preds[1]], prev_y)
                ws, wb = float(w[0]), float(w[1])
            else:
                ws, wb = dwa_closed_form(prev_preds[0], prev_preds[1], prev_y)
            return ws, wb, time.perf_counter() - t0
        # degenerate modes for baselines
        if self.mode == "speed":
            return 1.0, 0.0, 0.0
        if self.mode == "batch":
            return 0.0, 1.0, 0.0
        raise ValueError(f"unknown mode {self.mode!r}")

    def run(
        self,
        stream: WindowedStream,
        batch_params: Params,
        key: jax.Array,
        start_window: int = 1,
    ) -> HybridRunResult:
        fc = self.forecaster
        records: List[WindowRecord] = []
        speed_params: Optional[Params] = None
        prev_preds: Optional[Tuple[np.ndarray, np.ndarray]] = None
        prev_y: Optional[np.ndarray] = None

        n = len(stream)
        for t in range(n):
            data = stream.supervised(t)
            x, y = data["x"], data["y"]
            if t >= start_window and speed_params is not None and len(x) > 0:
                t0 = time.perf_counter()
                pb = fc.predict(batch_params, x)
                t_b = time.perf_counter() - t0
                t0 = time.perf_counter()
                ps = fc.predict(speed_params, x)
                t_s = time.perf_counter() - t0

                ws, wb, t_w = self._weights(prev_preds, prev_y)
                t0 = time.perf_counter()
                ph = combine([ps, pb], [ws, wb])
                t_h = time.perf_counter() - t0 + t_w

                records.append(
                    WindowRecord(
                        window=t,
                        rmse_batch=rmse(y, pb),
                        rmse_speed=rmse(y, ps),
                        rmse_hybrid=rmse(y, ph),
                        w_speed=ws,
                        w_batch=wb,
                        t_batch_infer=t_b,
                        t_speed_infer=t_s,
                        t_hybrid_infer=t_h,
                        t_weight_solve=t_w,
                    )
                )
                # Algorithm 1 inputs for the *next* window: predictions of
                # (M^s trained below, M^b) on this window's data are produced
                # after speed training; the paper stacks M^s_{t-1} with the
                # previous window's test set.
            # training phase: speed model for the next window
            key, sub = jax.random.split(key)
            t0 = time.perf_counter()
            new_speed, t_train = fc.train(data, speed_params, sub)
            if records and records[-1].window == t:
                records[-1].t_speed_train = t_train
            # stash Algorithm-1 inputs: predictions of (M^s_t, M^b) on
            # window t — consumed when weighting window t+1
            if len(x) > 0:
                prev_preds = (fc.predict(new_speed, x), fc.predict(batch_params, x))
                prev_y = y
            speed_params = new_speed
        return HybridRunResult(records=records, mode=str(self.mode))


def pretrain_batch_model(
    forecaster: Forecaster, historical: Dict[str, np.ndarray], key: jax.Array
) -> Tuple[Params, float]:
    """One-time batch training on historical data (paper: 20k observations,
    50 epochs, batch 512)."""
    return forecaster.train(historical, None, key)
