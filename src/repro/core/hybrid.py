"""Adaptive hybrid stream analytics (paper Sec. 5): lambda-architecture
orchestration of batch, speed and hybrid layers over a windowed stream.

Per time window t (paper Fig. 4):

  inference phase: batch inference with the one-time pre-trained model M^b;
  speed inference with M^s_{t-1} (trained on the previous window); hybrid
  inference combines the two with static or dynamic (Algorithm 1) weights.

  training phase (async): speed training of M^s_t on window t's records.

The orchestrator is generic over ``Forecaster`` so any model-zoo member can
be the backbone; ``lstm_forecaster`` builds the paper's exact setup
(batch: 50 epochs x bs 512; speed: 100 epochs x bs 64; lr 1e-3).

The per-window work itself lives in ``repro.core.stages`` as discrete,
individually-invokable pipeline stages; ``HybridStreamAnalytics.run`` is a
thin wrapper over ``repro.runtime.executor.InProcessExecutor`` (the
synchronous modality), and the same stages run bus-scheduled under any
``Deployment`` via ``repro.runtime.executor.BusExecutor``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.windows import WindowedStream
from repro.models.model import get_model
from repro.training.train_loop import fit

Params = Any


@dataclass(frozen=True)
class Forecaster:
    """train(data, params, key) -> (params, wall_s); predict(params, x) -> y.

    ``engine`` (optional) exposes the backing trainer — for the compiled
    path, the ``CompiledForecaster`` whose ``retrace_count`` the hot-path
    benchmark and the compile-cache regression tests inspect."""

    train: Callable[[Dict[str, np.ndarray], Optional[Params], jax.Array],
                    Tuple[Params, float]]
    predict: Callable[[Params, np.ndarray], np.ndarray]
    engine: Any = None


def lstm_forecaster(cfg: ModelConfig, *, epochs: int, batch_size: int,
                    lr: float = 1e-3, warm_start: bool = False,
                    compiled: bool = True) -> Forecaster:
    """The paper's LSTM forecaster.  ``compiled=True`` (default) rides the
    compile-once hot path: one cached jitted ``lax.scan`` fit executable per
    shape bucket (``repro.training.compiled``), one dispatch per window.
    ``compiled=False`` keeps the legacy per-call ``fit`` (fresh trace+compile
    every window, one dispatch per minibatch) — the pre-optimization
    baseline the hot-path benchmark measures against."""
    model = get_model(cfg)
    from repro.models import lstm as lstm_mod

    if compiled:
        from repro.training.compiled import CompiledForecaster

        eng = CompiledForecaster(
            model, epochs=epochs, batch_size=batch_size, lr=lr,
            warm_start=warm_start,
            predict_fn=lambda p, x: lstm_mod.predict(cfg, p, x))
        return Forecaster(train=eng.train, predict=eng.predict, engine=eng)

    predict_jit = jax.jit(lambda p, x: lstm_mod.predict(cfg, p, x))

    def train(data, params, key):
        res = fit(model, data, epochs=epochs, batch_size=batch_size, lr=lr,
                  params=params if warm_start else None, key=key)
        return res.params, res.wall_time_s

    def predict(params, x):
        return np.asarray(predict_jit(params, x))

    return Forecaster(train=train, predict=predict)


def lstm_fleet_forecaster(cfg: ModelConfig, *, epochs: int, batch_size: int,
                          lr: float = 1e-3):
    """The paper's LSTM speed layer lifted to a fleet of streams: a
    ``repro.training.compiled.FleetForecaster`` that trains every stream's
    speed model in one vmapped dispatch per window (and satisfies the
    single-stream ``Forecaster`` protocol by delegating to its wrapped
    ``CompiledForecaster``)."""
    from repro.models import lstm as lstm_mod
    from repro.training.compiled import FleetForecaster

    model = get_model(cfg)
    return FleetForecaster(
        model, epochs=epochs, batch_size=batch_size, lr=lr,
        predict_fn=lambda p, x: lstm_mod.predict(cfg, p, x))


@dataclass
class WindowRecord:
    window: int
    rmse_batch: float
    rmse_speed: float
    rmse_hybrid: float
    w_speed: float
    w_batch: float
    t_speed_train: float = 0.0
    t_batch_infer: float = 0.0
    t_speed_infer: float = 0.0
    t_hybrid_infer: float = 0.0
    t_weight_solve: float = 0.0


@dataclass
class HybridRunResult:
    records: List[WindowRecord]
    mode: str

    def mean_rmse(self) -> Dict[str, float]:
        return {
            "batch": float(np.mean([r.rmse_batch for r in self.records])),
            "speed": float(np.mean([r.rmse_speed for r in self.records])),
            "hybrid": float(np.mean([r.rmse_hybrid for r in self.records])),
        }

    def best_fraction(self) -> Dict[str, float]:
        """Paper Tables 4-6: time percentage each inference is the best."""
        wins = {"batch": 0, "speed": 0, "hybrid": 0}
        for r in self.records:
            best = min(
                ("speed", r.rmse_speed),
                ("batch", r.rmse_batch),
                ("hybrid", r.rmse_hybrid),
                key=lambda kv: kv[1],
            )[0]
            wins[best] += 1
        n = max(len(self.records), 1)
        return {k: v / n for k, v in wins.items()}

    def mean_latency(self) -> Dict[str, float]:
        return {
            "speed_train": float(np.mean([r.t_speed_train for r in self.records])),
            "batch_infer": float(np.mean([r.t_batch_infer for r in self.records])),
            "speed_infer": float(np.mean([r.t_speed_infer for r in self.records])),
            "hybrid_infer": float(np.mean([r.t_hybrid_infer for r in self.records])),
            "weight_solve": float(np.mean([r.t_weight_solve for r in self.records])),
        }


class HybridStreamAnalytics:
    """The adaptive hybrid learner.

    mode: "dynamic" (Algorithm 1), ("static", w_speed), "speed", "batch".
    ``dwa_solver``: "scipy" (paper SLSQP) or "closed_form" (TPU-native).
    """

    def __init__(
        self,
        forecaster: Forecaster,
        mode: str | Tuple[str, float] = "dynamic",
        dwa_solver: str = "closed_form",
    ):
        self.forecaster = forecaster
        self.mode = mode
        self.dwa_solver = dwa_solver

    def stages(self):
        """The learner decomposed into bus-schedulable pipeline stages."""
        from repro.core.stages import PipelineStages

        return PipelineStages.build(self.forecaster, self.mode,
                                    self.dwa_solver)

    def run(
        self,
        stream: WindowedStream,
        batch_params: Params,
        key: jax.Array,
        start_window: int = 1,
    ) -> HybridRunResult:
        from repro.runtime.executor import InProcessExecutor

        return InProcessExecutor(self.stages(), start_window=start_window).run(
            stream, batch_params, key)


def pretrain_batch_model(
    forecaster: Forecaster, historical: Dict[str, np.ndarray], key: jax.Array
) -> Tuple[Params, float]:
    """One-time batch training on historical data (paper: 20k observations,
    50 epochs, batch 512)."""
    return forecaster.train(historical, None, key)
