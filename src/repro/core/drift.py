"""Concept drift: stationarity testing and drift detection.

* ``adf_test`` — augmented Dickey-Fuller test (the paper applies it to each
  turbine channel, Sec. 6.1.1) implemented from scratch on numpy lstsq, with
  MacKinnon (1994/2010) approximate p-values for the constant-only case.

* ``PageHinkleyDetector`` / ``window_mean_shift`` — lightweight online drift
  detectors feeding the runtime's drift-gated retraining.

* ``DriftGate`` — the per-stream retraining policy built on them: the fleet
  executors consult it once per (stream, window) at training time, and only
  drifting streams pay a retrain — stationary streams keep serving their
  prior speed model (beyond-paper extension; the paper re-trains every
  window regardless).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

# MacKinnon approximate critical values (constant, no trend), 1/5/10 %
ADF_CRIT = {-1: None, 1: -3.43, 5: -2.86, 10: -2.57}

# MacKinnon (2010) response-surface coefficients for p-value interpolation
# (constant only).  tau -> p via a logistic fit on tabulated points.
_TAU_TABLE = np.array(
    [-6.0, -5.0, -4.5, -4.0, -3.6, -3.43, -3.2, -3.0, -2.86, -2.57, -2.2,
     -1.9, -1.6, -1.2, -0.8, -0.4, 0.0, 0.5, 1.0, 2.0]
)
_P_TABLE = np.array(
    [1e-8, 5e-6, 5e-5, 4e-4, 2e-3, 5e-3, 1.5e-2, 3e-2, 5e-2, 1e-1, 2e-1,
     3e-1, 4.4e-1, 5.9e-1, 7.3e-1, 8.4e-1, 9.1e-1, 9.6e-1, 9.85e-1, 9.99e-1]
)


def mackinnon_pvalue(tau: float) -> float:
    """Approximate ADF p-value (constant only) by interpolation on the
    MacKinnon table.  ``tau`` beyond either table end clamps to the end
    value (``np.interp`` semantics): more negative than -6.0 -> 1e-8, more
    positive than +2.0 -> 0.999 — adequate for reject/fail-to-reject use,
    and monotone non-decreasing in tau by construction."""
    return float(np.interp(tau, _TAU_TABLE, _P_TABLE))


@dataclass(frozen=True)
class ADFResult:
    statistic: float
    pvalue: float
    n_lags: int
    stationary_5pct: bool


def adf_test(y: np.ndarray, max_lag: Optional[int] = None) -> ADFResult:
    """ADF with constant; lag order by Schwert rule, p-value by interpolation
    on the MacKinnon table (adequate for the paper's reject/fail-to-reject
    usage; exact statsmodels values differ in the 3rd decimal)."""
    y = np.asarray(y, np.float64).ravel()
    n = len(y)
    if max_lag is None:
        max_lag = int(np.floor(12.0 * (n / 100.0) ** 0.25))
        max_lag = min(max_lag, n // 2 - 2)
    dy = np.diff(y)
    k = max_lag
    # regression: dy_t = c + rho*y_{t-1} + sum_i g_i dy_{t-i}
    T = len(dy) - k
    X = [np.ones(T), y[k:-1]]
    for i in range(1, k + 1):
        X.append(dy[k - i : len(dy) - i])
    X = np.stack(X, axis=1)
    target = dy[k:]
    beta, *_ = np.linalg.lstsq(X, target, rcond=None)
    resid = target - X @ beta
    dof = max(T - X.shape[1], 1)
    sigma2 = resid @ resid / dof
    cov = sigma2 * np.linalg.pinv(X.T @ X)
    se_rho = np.sqrt(max(cov[1, 1], 1e-300))
    tau = float(beta[1] / se_rho)
    p = mackinnon_pvalue(tau)
    return ADFResult(statistic=tau, pvalue=p, n_lags=k,
                     stationary_5pct=tau < ADF_CRIT[5])


@dataclass
class PageHinkleyDetector:
    """Page-Hinkley mean-shift detector over a scalar stream (e.g. per-window
    RMSE): alarm when the cumulative deviation exceeds ``threshold``."""

    delta: float = 0.005
    threshold: float = 0.2
    alpha: float = 0.999
    _mean: float = 0.0
    _cum: float = 0.0
    _min_cum: float = 0.0
    n: int = 0
    alarms: int = 0

    def update(self, x: float) -> bool:
        self.n += 1
        self._mean += (x - self._mean) / self.n
        self._cum = self.alpha * self._cum + (x - self._mean - self.delta)
        self._min_cum = min(self._min_cum, self._cum)
        if self._cum - self._min_cum > self.threshold:
            self.alarms += 1
            self._cum = 0.0
            self._min_cum = 0.0
            return True
        return False


def window_mean_shift(prev: np.ndarray, cur: np.ndarray, z: float = 3.0) -> bool:
    """Two-window mean-shift check (z-test on window means)."""
    prev = np.asarray(prev, np.float64).ravel()
    cur = np.asarray(cur, np.float64).ravel()
    se = np.sqrt(prev.var() / max(len(prev), 1) + cur.var() / max(len(cur), 1))
    if se == 0:
        return False
    return bool(abs(cur.mean() - prev.mean()) / se > z)


# ---------------------------------------------------------------------------
# Drift-gated retraining policy
# ---------------------------------------------------------------------------


@dataclass
class _GateState:
    """One stream's gate state: the reference window (what the serving
    speed model last trained on) and a Page-Hinkley detector over the
    window means observed since that retrain."""

    ph: PageHinkleyDetector
    ref: Optional[np.ndarray] = None
    seen: int = 0
    retrained: int = 0
    skipped: int = 0
    log: List[bool] = field(default_factory=list)


@dataclass
class DriftGate:
    """Per-stream drift-gated retraining: decide, at training time, whether
    a stream's window is worth a speed-model retrain.

    ``decide(sid, y)`` is called once per (stream, window) with the window's
    supervised targets and returns True (retrain) when either detector
    fires:

    * ``window_mean_shift`` z-test of this window against the *reference*
      window — the one the serving model last trained on — so abrupt jumps
      fire immediately and gradual drift fires once it has accumulated past
      the threshold relative to the model's training distribution;
    * ``PageHinkleyDetector`` over the sequence of window means since the
      last retrain — the cumulative test that catches slow drift the
      two-window z-test under-powers.

    The first ``warmup`` windows of every stream always retrain (a model
    must exist, and the detectors need a baseline).  On retrain the
    reference window and the PH state reset: the gate always measures drift
    *since the stream's last retrain*, so a stationary stream settles into
    skipping every window while a drifting one keeps firing.

    ``z`` defaults well above the textbook 3.0 because the turbine channels
    are strongly autocorrelated within a window — the iid standard error
    underestimates the window-mean wander of a perfectly stationary stream,
    so a small ``z`` would retrain on noise.
    """

    z: float = 8.0
    ph_delta: float = 0.005
    ph_threshold: float = 0.1
    warmup: int = 1
    _streams: Dict[str, _GateState] = field(default_factory=dict)

    def _state(self, sid: str) -> _GateState:
        st = self._streams.get(sid)
        if st is None:
            st = self._streams[sid] = _GateState(ph=self._new_ph())
        return st

    def _new_ph(self) -> PageHinkleyDetector:
        return PageHinkleyDetector(delta=self.ph_delta,
                                   threshold=self.ph_threshold)

    def decide(self, sid: str, y: np.ndarray) -> bool:
        """True -> retrain the stream on this window; False -> skip (the
        stream keeps serving its prior speed model)."""
        st = self._state(sid)
        st.seen += 1
        y = np.asarray(y, np.float64).ravel()
        if st.ref is None or st.seen <= self.warmup:
            fire = True
        else:
            fire = (window_mean_shift(st.ref, y, z=self.z)
                    or st.ph.update(float(y.mean())))
        self._record(st, y, fire)
        return fire

    def force_retrain(self, sid: str, y: np.ndarray) -> None:
        """Record a retrain the executor forced regardless of drift (e.g.
        the stream has no serving model yet because a publish is still in
        flight), so the reference window tracks what the model actually
        trained on and the stats stay consistent with the executor's
        retrain log."""
        st = self._state(sid)
        st.seen += 1
        self._record(st, np.asarray(y, np.float64).ravel(), True)

    def _record(self, st: _GateState, y: np.ndarray, fire: bool) -> None:
        if fire:
            st.retrained += 1
            st.ref = y
            st.ph = self._new_ph()
        else:
            st.skipped += 1
        st.log.append(fire)

    # -- introspection -------------------------------------------------------

    def retrain_log(self) -> Dict[str, List[bool]]:
        return {sid: list(st.log) for sid, st in self._streams.items()}

    def stats(self) -> Dict[str, object]:
        per_stream = {
            sid: {"retrained": st.retrained, "skipped": st.skipped}
            for sid, st in self._streams.items()
        }
        return {
            "retrained": sum(st.retrained for st in self._streams.values()),
            "skipped": sum(st.skipped for st in self._streams.values()),
            "per_stream": per_stream,
        }
