"""Concept drift: stationarity testing and drift detection.

* ``adf_test`` — augmented Dickey-Fuller test (the paper applies it to each
  turbine channel, Sec. 6.1.1) implemented from scratch on numpy lstsq, with
  MacKinnon (1994/2010) approximate p-values for the constant-only case.

* ``PageHinkleyDetector`` / ``window_mean_shift`` — lightweight online drift
  detectors the runtime can use to trigger extra speed re-training
  (beyond-paper extension; the paper re-trains every window regardless).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np

# MacKinnon approximate critical values (constant, no trend), 1/5/10 %
ADF_CRIT = {-1: None, 1: -3.43, 5: -2.86, 10: -2.57}

# MacKinnon (2010) response-surface coefficients for p-value interpolation
# (constant only).  tau -> p via a logistic fit on tabulated points.
_TAU_TABLE = np.array(
    [-6.0, -5.0, -4.5, -4.0, -3.6, -3.43, -3.2, -3.0, -2.86, -2.57, -2.2,
     -1.9, -1.6, -1.2, -0.8, -0.4, 0.0, 0.5, 1.0, 2.0]
)
_P_TABLE = np.array(
    [1e-8, 5e-6, 5e-5, 4e-4, 2e-3, 5e-3, 1.5e-2, 3e-2, 5e-2, 1e-1, 2e-1,
     3e-1, 4.4e-1, 5.9e-1, 7.3e-1, 8.4e-1, 9.1e-1, 9.6e-1, 9.85e-1, 9.99e-1]
)


@dataclass(frozen=True)
class ADFResult:
    statistic: float
    pvalue: float
    n_lags: int
    stationary_5pct: bool


def adf_test(y: np.ndarray, max_lag: Optional[int] = None) -> ADFResult:
    """ADF with constant; lag order by Schwert rule, p-value by interpolation
    on the MacKinnon table (adequate for the paper's reject/fail-to-reject
    usage; exact statsmodels values differ in the 3rd decimal)."""
    y = np.asarray(y, np.float64).ravel()
    n = len(y)
    if max_lag is None:
        max_lag = int(np.floor(12.0 * (n / 100.0) ** 0.25))
        max_lag = min(max_lag, n // 2 - 2)
    dy = np.diff(y)
    k = max_lag
    # regression: dy_t = c + rho*y_{t-1} + sum_i g_i dy_{t-i}
    T = len(dy) - k
    X = [np.ones(T), y[k:-1]]
    for i in range(1, k + 1):
        X.append(dy[k - i : len(dy) - i])
    X = np.stack(X, axis=1)
    target = dy[k:]
    beta, *_ = np.linalg.lstsq(X, target, rcond=None)
    resid = target - X @ beta
    dof = max(T - X.shape[1], 1)
    sigma2 = resid @ resid / dof
    cov = sigma2 * np.linalg.pinv(X.T @ X)
    se_rho = np.sqrt(max(cov[1, 1], 1e-300))
    tau = float(beta[1] / se_rho)
    p = float(np.interp(tau, _TAU_TABLE, _P_TABLE))
    return ADFResult(statistic=tau, pvalue=p, n_lags=k,
                     stationary_5pct=tau < ADF_CRIT[5])


@dataclass
class PageHinkleyDetector:
    """Page-Hinkley mean-shift detector over a scalar stream (e.g. per-window
    RMSE): alarm when the cumulative deviation exceeds ``threshold``."""

    delta: float = 0.005
    threshold: float = 0.2
    alpha: float = 0.999
    _mean: float = 0.0
    _cum: float = 0.0
    _min_cum: float = 0.0
    n: int = 0
    alarms: int = 0

    def update(self, x: float) -> bool:
        self.n += 1
        self._mean += (x - self._mean) / self.n
        self._cum = self.alpha * self._cum + (x - self._mean - self.delta)
        self._min_cum = min(self._min_cum, self._cum)
        if self._cum - self._min_cum > self.threshold:
            self.alarms += 1
            self._cum = 0.0
            self._min_cum = 0.0
            return True
        return False


def window_mean_shift(prev: np.ndarray, cur: np.ndarray, z: float = 3.0) -> bool:
    """Two-window mean-shift check (z-test on window means)."""
    prev = np.asarray(prev, np.float64).ravel()
    cur = np.asarray(cur, np.float64).ravel()
    se = np.sqrt(prev.var() / max(len(prev), 1) + cur.var() / max(len(cur), 1))
    if se == 0:
        return False
    return abs(cur.mean() - prev.mean()) / se > z
