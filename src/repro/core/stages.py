"""The hybrid learner decomposed into discrete, individually-invokable
pipeline stages (paper Sec. 4.4: the *same* module implementations run under
every deployment modality).

Each stage is a small callable object with an explicit state-in/state-out
contract: ``compute(**inputs) -> dict`` of named outputs, and ``__call__``
wraps it with a wall-clock measurement so executors can account real latency
per stage.  The seven stages mirror the paper's Fig. 4 modules:

  batch_inference   (batch_params, x)            -> pred
  speed_inference   (speed_params, x)            -> pred [+ fallback flag]
  weight_solve      (prev_preds, prev_y)         -> w_speed, w_batch
  hybrid_combine    (pred_speed, pred_batch, w*) -> pred
  speed_training    (data, speed_params, batch_params, key)
                                                 -> params, eval_preds, eval_y
  model_sync        (params, eval_preds, eval_y) -> speed model state update
  data_sync         (records_nbytes,)            -> archive handoff

An executor (``repro.runtime.executor``) decides *where and when* each stage
runs: ``InProcessExecutor`` replays the paper's synchronous per-window loop;
``BusExecutor`` schedules the stages as ``TopicBus`` subscribers according to
a ``Deployment`` placement map.

The stream dimension: every stage's state contract is *per stream*.  A
single-stream pipeline threads one stream's state through the stages
directly (the original API, unchanged); a fleet lifts the same stage
objects over a ``StreamId``-keyed axis — ``FleetState`` holds each stream's
serving-side state, ``FleetStage`` maps a single-stream stage over a
``{stream_id: kwargs}`` dict, and ``FleetSpeedTraining`` replaces the
per-stream training loop with one vmapped whole-fleet dispatch
(``repro.training.compiled.FleetForecaster``), and ``BatchRefresh`` rides
the same sharded dispatch for the queued cloud-side *batch-model* refresh
from archived drifted windows.  The fleet executors
(``InProcessFleetExecutor`` / ``FleetBusExecutor``) drive ``FleetStages``;
the single-stream executors keep driving ``PipelineStages``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.core.weighting import (
    combine,
    dwa_closed_form,
    dwa_scipy,
    static_weights,
)

Params = Any


@dataclass
class StageOutput:
    """What one stage invocation produced, plus its measured wall-clock."""

    values: Dict[str, Any]
    wall_s: float

    def __getitem__(self, key: str) -> Any:
        return self.values[key]


class Stage:
    """Base: times ``compute`` with a perf counter; subclasses are pure in the
    sense that all state enters via ``compute`` kwargs and leaves via the
    returned dict.  JAX dispatch is asynchronous, so the wrapper blocks on
    any device-array outputs before stopping the clock — otherwise the
    ``LatencyLedger`` would credit a stage for work still in flight."""

    name: str = "stage"

    def compute(self, **inputs: Any) -> Dict[str, Any]:
        raise NotImplementedError

    def __call__(self, **inputs: Any) -> StageOutput:
        import jax

        t0 = time.perf_counter()
        values = self.compute(**inputs)
        pending = [x for x in jax.tree_util.tree_leaves(values)
                   if isinstance(x, jax.Array)]
        if pending:
            jax.block_until_ready(pending)
        return StageOutput(values=values, wall_s=time.perf_counter() - t0)


class BatchInference(Stage):
    """M^b prediction on a window's supervised inputs."""

    name = "batch_inference"

    def __init__(self, forecaster):
        self.forecaster = forecaster

    def compute(self, *, batch_params: Params, x: np.ndarray) -> Dict[str, Any]:
        return {"pred": self.forecaster.predict(batch_params, x)}


class SpeedInference(Stage):
    """M^s_{t-1} prediction.  When no speed model has been synced yet (cold
    start, or the edge-centric OOM keeps training from ever publishing), the
    stage degrades to serving the batch model and flags it."""

    name = "speed_inference"

    def __init__(self, forecaster):
        self.forecaster = forecaster

    def compute(self, *, speed_params: Optional[Params], x: np.ndarray,
                fallback_params: Optional[Params] = None) -> Dict[str, Any]:
        fallback = speed_params is None
        params = fallback_params if fallback else speed_params
        if params is None:
            raise ValueError("speed_inference: no speed model and no fallback")
        return {"pred": self.forecaster.predict(params, x),
                "fallback": fallback}


class WeightSolve(Stage):
    """Algorithm 1 (dynamic) or static/degenerate weights.

    mode: "dynamic", ("static", w_speed), "speed", "batch" — identical
    semantics to the pre-refactor ``HybridStreamAnalytics._weights``.
    """

    name = "weight_solve"

    def __init__(self, mode="dynamic", dwa_solver: str = "closed_form"):
        self.mode = mode
        self.dwa_solver = dwa_solver

    def compute(self, *, prev_preds: Optional[Tuple[np.ndarray, np.ndarray]],
                prev_y: Optional[np.ndarray]) -> Dict[str, Any]:
        if isinstance(self.mode, tuple) and self.mode[0] == "static":
            ws, wb = static_weights(self.mode[1])
            return {"w_speed": ws, "w_batch": wb}
        if self.mode == "dynamic":
            if prev_preds is None:
                return {"w_speed": 0.5, "w_batch": 0.5}
            if self.dwa_solver == "scipy":
                w = dwa_scipy([prev_preds[0], prev_preds[1]], prev_y)
                ws, wb = float(w[0]), float(w[1])
            else:
                ws, wb = dwa_closed_form(prev_preds[0], prev_preds[1], prev_y)
            return {"w_speed": ws, "w_batch": wb}
        if self.mode == "speed":
            return {"w_speed": 1.0, "w_batch": 0.0}
        if self.mode == "batch":
            return {"w_speed": 0.0, "w_batch": 1.0}
        raise ValueError(f"unknown mode {self.mode!r}")

    @property
    def is_dynamic(self) -> bool:
        return self.mode == "dynamic"


class HybridCombine(Stage):
    """Pred_hybrid = W_s * Pred_speed + W_b * Pred_batch."""

    name = "hybrid_combine"

    def compute(self, *, pred_speed: np.ndarray, pred_batch: np.ndarray,
                w_speed: float, w_batch: float) -> Dict[str, Any]:
        return {"pred": combine([pred_speed, pred_batch], [w_speed, w_batch])}


class SpeedTraining(Stage):
    """Train M^s_t on window t's records and stash the Algorithm-1 inputs:
    predictions of (M^s_t, M^b) on window t, consumed when weighting window
    t+1.  ``train_wall_s`` is the forecaster-reported fit time (excludes the
    eval predictions), matching the pre-refactor ``t_speed_train``."""

    name = "speed_training"

    def __init__(self, forecaster):
        self.forecaster = forecaster

    def compute(self, *, data: Dict[str, np.ndarray],
                speed_params: Optional[Params], batch_params: Params,
                key) -> Dict[str, Any]:
        fc = self.forecaster
        if speed_params is not None:
            # the serving model may be the int8-synced tree (QTensor leaves);
            # training runs in float whatever the Forecaster implementation,
            # so dequantize at the stage boundary (no-op on a float tree)
            from repro.serving.quantize import dequantize_tree

            speed_params = dequantize_tree(speed_params)
        params, train_wall_s = fc.train(data, speed_params, key)
        x, y = data["x"], data["y"]
        eval_preds = eval_y = None
        if len(x) > 0:
            eval_preds = (fc.predict(params, x),
                          fc.predict(batch_params, x))
            eval_y = y
        return {"params": params, "train_wall_s": train_wall_s,
                "eval_preds": eval_preds, "eval_y": eval_y}


class ModelSync(Stage):
    """Install a freshly-published speed model (plus its Algorithm-1 eval
    predictions) as the serving state.  Pure pass-through compute; the cost of
    this module is the model transfer, which the executor accounts as
    communication.

    When the publish carries a ``checksum`` (CRC32 over the param tree,
    stamped by the training site), the stage verifies it on deliver before
    installing anything: a mismatch — e.g. a bit-flipped int8 ``QTensor``
    in transit — returns ``ok=False`` with no state update, increments
    ``corrupt_rejected``, and leaves re-request to the executor.  A corrupt
    model must *never* be served.

    When a ``sig_key`` is configured (the health plane's authenticated
    sync), the publish must also carry a valid HMAC-SHA256 ``signature``
    over the tree.  crc32 catches corruption but not tampering — a forger
    recomputes it over the forged params — while the HMAC requires the run
    key the forger does not hold; a bad or missing signature increments
    ``forged_rejected`` and rejects identically."""

    name = "model_sync"

    def __init__(self):
        self.verified = 0
        self.corrupt_rejected = 0
        self.forged_rejected = 0

    _REJECT = {"ok": False, "speed_params": None,
               "prev_preds": None, "prev_y": None}

    def compute(self, *, params: Params, eval_preds, eval_y,
                checksum: Optional[int] = None,
                signature: Optional[str] = None,
                sig_key: Optional[bytes] = None) -> Dict[str, Any]:
        # checksum first (integrity: bit flips in transit), signature second
        # (authenticity: a forger recomputes the crc32, so only the HMAC
        # catches it) — the counters then attribute each rejection to the
        # layer that actually caught it
        if checksum is not None:
            from repro.runtime.faults import tree_checksum

            if tree_checksum(params) != checksum:
                self.corrupt_rejected += 1
                return dict(self._REJECT)
            self.verified += 1
        if sig_key is not None:
            from repro.runtime.health import verify_tree

            if not verify_tree(params, sig_key, signature):
                self.forged_rejected += 1
                return dict(self._REJECT, forged=True)
        return {"ok": True, "speed_params": params, "prev_preds": eval_preds,
                "prev_y": eval_y}


class DataSync(Stage):
    """Raw-data archiving handoff (S3 analog); compute-free, its cost is the
    window transfer to the archiving site."""

    name = "data_sync"

    def compute(self, *, nbytes: float = 0.0) -> Dict[str, Any]:
        return {"nbytes": nbytes}


@dataclass
class PipelineStages:
    """The full stage set one executor drives.  Build with :meth:`build` so
    every executor runs literally the same stage objects."""

    batch_inference: BatchInference
    speed_inference: SpeedInference
    weight_solve: WeightSolve
    hybrid_combine: HybridCombine
    speed_training: SpeedTraining
    model_sync: ModelSync
    data_sync: DataSync

    @classmethod
    def build(cls, forecaster, mode="dynamic",
              dwa_solver: str = "closed_form") -> "PipelineStages":
        return cls(
            batch_inference=BatchInference(forecaster),
            speed_inference=SpeedInference(forecaster),
            weight_solve=WeightSolve(mode, dwa_solver),
            hybrid_combine=HybridCombine(),
            speed_training=SpeedTraining(forecaster),
            model_sync=ModelSync(),
            data_sync=DataSync(),
        )

    @property
    def mode(self):
        return self.weight_solve.mode


# ---------------------------------------------------------------------------
# The fleet dimension: StreamId-keyed state + fleet-lifted stages
# ---------------------------------------------------------------------------

StreamId = str


@dataclass
class StreamState:
    """One stream's serving-side state: the installed speed model plus the
    Algorithm-1 inputs its last retrain produced.  This is the per-stream
    unit every stage's state contract is expressed in — the pre-fleet
    executors carried exactly one of these."""

    speed_params: Optional[Params] = None
    prev_preds: Optional[Tuple[np.ndarray, np.ndarray]] = None
    prev_y: Optional[np.ndarray] = None
    window: int = -1


@dataclass
class FleetState:
    """``StreamId``-keyed serving state for a fleet of streams."""

    streams: Dict[StreamId, StreamState] = field(default_factory=dict)

    def state(self, sid: StreamId) -> StreamState:
        """The stream's state, created empty on first touch."""
        st = self.streams.get(sid)
        if st is None:
            st = self.streams[sid] = StreamState()
        return st

    def ids(self) -> List[StreamId]:
        return list(self.streams)

    def __len__(self) -> int:
        return len(self.streams)

    def handoff(self, sid: StreamId) -> float:
        """Prepare one stream's device-resident state for migration to
        another site and return its transfer size in bytes.

        A stream fresh out of fleet training holds a *lazy* params handle
        (``FleetParamView``) pointing into the training site's stacked
        device buffer — a bucket-resident view, not bytes the stream owns.
        Migration is exactly the boundary where that view must leave its
        stream-count bucket, so the handoff materializes it to a plain host
        pytree; the next fleet dispatch at the new site re-admits the stream
        into whatever bucket its new cohort hashes to."""
        import jax

        from repro.training.compiled import materialize_params

        st = self.state(sid)
        if st.speed_params is not None:
            st.speed_params = materialize_params(st.speed_params)
        nbytes = 0.0
        for part in (st.speed_params, st.prev_preds, st.prev_y):
            for leaf in jax.tree_util.tree_leaves(part):
                nbytes += float(np.asarray(leaf).nbytes)
        return nbytes


def resolve_fleet_params(batch_params: Any, ids: List[StreamId]
                         ) -> Dict[StreamId, Params]:
    """Normalize a batch-model argument to per-stream form: a mapping whose
    keys cover every stream id is already per-stream; anything else (a
    params tree — itself a dict, but keyed by layer names, not stream ids)
    is one model shared by the whole fleet.  A mapping that names *some*
    stream ids but not all is almost certainly an incomplete per-stream
    mapping — reject it loudly rather than hand every stream the whole
    stream-keyed dict as its params tree."""
    if isinstance(batch_params, Mapping):
        hits = set(ids) & set(batch_params)
        if set(ids) <= set(batch_params):
            return {sid: batch_params[sid] for sid in ids}
        if hits:
            raise ValueError(
                "per-stream batch params mapping is missing streams "
                f"{sorted(set(ids) - set(batch_params))}")
    return {sid: batch_params for sid in ids}


class FleetStage(Stage):
    """Lift a single-stream stage to a fleet: ``compute`` maps the wrapped
    stage over a ``{stream_id: kwargs}`` dict and returns per-stream
    ``StageOutput``s (each individually wall-clocked by the wrapped stage's
    own ``__call__``).  The wrapped stage object is untouched and still
    directly callable, so the single-stream API is preserved verbatim."""

    def __init__(self, stage: Stage):
        self.stage = stage
        self.name = stage.name

    def compute(self, *, fleet: Dict[StreamId, Dict[str, Any]]
                ) -> Dict[str, Any]:
        return {"fleet": {sid: self.stage(**kw) for sid, kw in fleet.items()}}


class FleetInference(Stage):
    """The batched fleet eval/inference contract: the whole fleet's
    per-stream predictions in **one** vmapped device dispatch
    (``FleetForecaster.predict_fleet``), mirroring the aggregated train
    dispatch — same ``{stream_id: kwargs}`` contract and per-stream
    ``StageOutput`` results as the per-stream :class:`FleetStage` lift it
    replaces, so executors drive it unchanged.

    Each stream's ``StageOutput`` carries the shared aggregate wall (the
    same convention the fleet training dispatch uses for
    ``t_speed_train``).  A one-stream fleet delegates to the wrapped
    single-stream stage, keeping that path byte-identical to the pre-fleet
    code.  ``kind="speed"`` resolves the per-stream batch-model fallback
    (a stream with no synced speed model serves ``fallback_params`` and is
    flagged) *before* the aggregated dispatch, so an all-fallback fleet
    predicts bit-identically to the batched batch-inference stage."""

    def __init__(self, fleet_forecaster, stage: Stage, kind: str):
        self.forecaster = fleet_forecaster
        self.stage = stage
        self.kind = kind
        self.name = stage.name
        # windows served / vmapped dispatches spent — the elastic bench
        # gates dispatches/tick == 1 across migrations (same contract as
        # ServingStage)
        self.ticks = 0
        self.dispatches = 0

    def compute(self, *, fleet: Dict[StreamId, Dict[str, Any]]
                ) -> Dict[str, Any]:
        sids = list(fleet)
        self.ticks += 1
        if len(sids) <= 1:
            self.dispatches += 1
            return {"fleet": {sid: self.stage(**kw)
                              for sid, kw in fleet.items()}}
        t0 = time.perf_counter()
        params: List[Any] = []
        fallback: Dict[StreamId, bool] = {}
        for sid in sids:
            kw = fleet[sid]
            if self.kind == "speed":
                fb = kw.get("speed_params") is None
                p = kw.get("fallback_params") if fb else kw["speed_params"]
                if p is None:
                    raise ValueError(
                        "speed_inference: no speed model and no fallback")
                fallback[sid] = fb
            else:
                p = kw["batch_params"]
            params.append(p)
        d0 = getattr(self.forecaster, "predict_dispatches", 0)
        preds = self.forecaster.predict_fleet(
            params, [fleet[sid]["x"] for sid in sids])
        d1 = getattr(self.forecaster, "predict_dispatches", 0)
        self.dispatches += (d1 - d0) if d1 > d0 else 1
        wall = time.perf_counter() - t0
        out: Dict[StreamId, StageOutput] = {}
        for sid, pred in zip(sids, preds):
            values = {"pred": pred}
            if self.kind == "speed":
                values["fallback"] = fallback[sid]
            out[sid] = StageOutput(values=values, wall_s=wall)
        return {"fleet": out}


class FleetSpeedTraining(Stage):
    """Whole-fleet speed training in one vmapped device dispatch
    (``FleetForecaster.train_fleet``), plus the per-stream Algorithm-1 eval
    predictions the single-stream ``SpeedTraining`` stashes — themselves
    aggregated into one ``predict_fleet`` dispatch per model (the fresh
    speed models read straight from the device-resident stacked fit
    output; the batch models stack per stream), instead of 2N per-stream
    predicts.  The per-stream params handles stay lazy
    (``FleetParamView``): a host pytree materializes only at a publish
    boundary.  Drift gating happens *above* this stage: the caller passes
    only the streams whose gate said retrain, and the stream-count buckets
    absorb the varying subset sizes."""

    name = "speed_training"

    def __init__(self, fleet_forecaster):
        self.forecaster = fleet_forecaster

    def compute(self, *, fleet_data: Dict[StreamId, Dict[str, np.ndarray]],
                batch_params: Any, keys: Dict[StreamId, Any]
                ) -> Dict[str, Any]:
        fc = self.forecaster
        sids = list(fleet_data)
        bp = resolve_fleet_params(batch_params, sids)
        params_list, train_wall_s = fc.train_fleet(
            [fleet_data[s] for s in sids], [keys[s] for s in sids])
        ev = [i for i, s in enumerate(sids) if len(fleet_data[s]["x"]) > 0]
        preds_speed: Dict[int, np.ndarray] = {}
        preds_batch: Dict[int, np.ndarray] = {}
        if ev:
            xs = [fleet_data[sids[i]]["x"] for i in ev]
            preds_speed = dict(zip(ev, fc.predict_fleet(
                [params_list[i] for i in ev], xs)))
            preds_batch = dict(zip(ev, fc.predict_fleet(
                [bp[sids[i]] for i in ev], xs)))
        fleet = {}
        for i, (sid, params) in enumerate(zip(sids, params_list)):
            eval_preds = eval_y = None
            if i in preds_speed:
                eval_preds = (preds_speed[i], preds_batch[i])
                eval_y = fleet_data[sid]["y"]
            fleet[sid] = {"params": params, "eval_preds": eval_preds,
                          "eval_y": eval_y}
        return {"fleet": fleet, "train_wall_s": train_wall_s}


class ServingStage(Stage):
    """The request plane's batched answer dispatch: every serving tick, the
    active queries of *all* streams predict in **one** vmapped
    ``FleetForecaster.predict_fleet`` call over the device-resident serving
    params (streams with no active query contribute a zero-row batch, so
    the executable comes from the same (stream bucket, shape bucket) cache
    the per-window inference path warms).  Shared-wall convention: the one
    measured ``__call__`` wall is the whole tick's cost, charged once by
    the executor under the serving site's worker occupancy.

    ``ticks`` / ``dispatches`` count serving ticks and the vmapped
    dispatches they cost — the bench gate asserts dispatches/tick == 1.
    A one-stream fleet delegates inside ``predict_fleet`` to the single
    path (which keeps its own trace counters); it is still one dispatch,
    counted as such here.
    """

    name = "serving"

    def __init__(self, fleet_forecaster):
        self.forecaster = fleet_forecaster
        self.ticks = 0
        self.dispatches = 0

    def compute(self, *, params_seq: List[Any], xs: List[np.ndarray]
                ) -> Dict[str, Any]:
        fc = self.forecaster
        d0 = getattr(fc, "predict_dispatches", 0)
        preds = fc.predict_fleet(params_seq, xs)
        d1 = getattr(fc, "predict_dispatches", 0)
        self.dispatches += (d1 - d0) if len(xs) > 1 else 1
        self.ticks += 1
        return {"preds": preds}


class BatchRefresh(Stage):
    """The queued cloud-side heavy-retraining path: gated *batch-model*
    refresh from archived drifted windows, riding the same sharded fleet
    dispatch as speed training.

    Every window whose drift gate fired is archived per stream (a bounded
    deque of supervised windows — drifted data is exactly what the serving
    batch model has gone stale on).  Every ``every`` windows, streams whose
    archive holds at least ``min_windows`` windows refresh together: each
    stream's archive concatenates into one training set and the whole
    cohort retrains in **one** ``FleetForecaster.train_fleet`` dispatch —
    stream-count-bucketed, mesh-sharded, donation-cached, exactly the hot
    path — instead of S sequential cloud fits.  The refreshed params
    replace that stream's batch model for every subsequent batch-inference
    dispatch and Algorithm-1 weight solve; its archive is consumed.

    Archives are capped at ``max_windows`` (most recent kept), which also
    bounds the refresh's example-count bucket so the dispatch reuses a
    handful of executables rather than compiling per archive size."""

    name = "batch_refresh"

    def __init__(self, fleet_forecaster, *, every: int = 4,
                 min_windows: int = 2, max_windows: int = 8):
        if every <= 0:
            raise ValueError(f"refresh period must be positive, got {every}")
        self.forecaster = fleet_forecaster
        self.every = every
        self.min_windows = max(min_windows, 1)
        self.max_windows = max(max_windows, self.min_windows)
        self._archive: Dict[StreamId, List[Dict[str, np.ndarray]]] = {}
        self.dispatches = 0
        self.rounds = 0
        self.refreshed: Dict[StreamId, int] = {}
        self.train_wall_s = 0.0

    def reset(self) -> None:
        """Per-run state: clear the archives and the run counters."""
        self._archive.clear()
        self.refreshed = {}
        self.dispatches = 0
        self.rounds = 0
        self.train_wall_s = 0.0

    def archive(self, sid: StreamId, data: Dict[str, np.ndarray]) -> None:
        """Queue one drifted window of stream ``sid`` for its next refresh."""
        if len(next(iter(data.values()))) == 0:
            return
        q = self._archive.setdefault(sid, [])
        q.append({k: np.asarray(v) for k, v in data.items()})
        if len(q) > self.max_windows:
            del q[: len(q) - self.max_windows]

    def due(self, t: int) -> bool:
        return (t + 1) % self.every == 0

    def ready(self) -> List[StreamId]:
        return [s for s, q in self._archive.items()
                if len(q) >= self.min_windows]

    def compute(self, *, keys: Dict[StreamId, Any]) -> Dict[str, Any]:
        fc = self.forecaster
        sids = [s for s in self.ready() if s in keys]
        if not sids:
            return {"fleet": {}, "train_wall_s": 0.0}
        datas = []
        for s in sids:
            q = self._archive[s]
            datas.append({k: np.concatenate([w[k] for w in q]) for k in q[0]})
        d0 = fc.train_dispatches
        params_list, wall = fc.train_fleet(datas, [keys[s] for s in sids])
        self.dispatches += fc.train_dispatches - d0
        self.rounds += 1
        self.train_wall_s += wall
        for s in sids:
            self._archive[s] = []
            self.refreshed[s] = self.refreshed.get(s, 0) + 1
        return {"fleet": dict(zip(sids, params_list)), "train_wall_s": wall}


@dataclass
class FleetStages:
    """The fleet-level stage set: the *same* single-stream stage objects
    (``single`` is a fully functional ``PipelineStages``) lifted per-stream
    by ``FleetStage``, plus the one-dispatch whole-fleet stages — speed
    training (``FleetSpeedTraining``) and batch/speed inference
    (``FleetInference``), each one aggregated device dispatch per window
    instead of N."""

    single: PipelineStages
    batch_inference: FleetInference
    speed_inference: FleetInference
    weight_solve: FleetStage
    hybrid_combine: FleetStage
    speed_training: FleetSpeedTraining
    model_sync: FleetStage
    data_sync: FleetStage
    serving: Optional[ServingStage] = None

    @classmethod
    def build(cls, fleet_forecaster, mode="dynamic",
              dwa_solver: str = "closed_form") -> "FleetStages":
        """``fleet_forecaster`` is a ``FleetForecaster`` (it satisfies the
        single-stream ``Forecaster`` protocol by delegation, so the wrapped
        ``PipelineStages`` serve per-stream inference unchanged)."""
        single = PipelineStages.build(fleet_forecaster, mode, dwa_solver)
        return cls(
            single=single,
            batch_inference=FleetInference(fleet_forecaster,
                                           single.batch_inference, "batch"),
            speed_inference=FleetInference(fleet_forecaster,
                                           single.speed_inference, "speed"),
            weight_solve=FleetStage(single.weight_solve),
            hybrid_combine=FleetStage(single.hybrid_combine),
            speed_training=FleetSpeedTraining(fleet_forecaster),
            model_sync=FleetStage(single.model_sync),
            data_sync=FleetStage(single.data_sync),
            serving=ServingStage(fleet_forecaster),
        )

    @property
    def mode(self):
        return self.single.mode


def split_chain(key, n: int):
    """The sequential ``key, sub = jax.random.split(key)`` chain the
    synchronous loop uses, reproduced so every executor derives identical
    per-window training keys for the same seed."""
    import jax

    subs = []
    for _ in range(n):
        key, sub = jax.random.split(key)
        subs.append(sub)
    return subs
