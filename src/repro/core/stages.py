"""The hybrid learner decomposed into discrete, individually-invokable
pipeline stages (paper Sec. 4.4: the *same* module implementations run under
every deployment modality).

Each stage is a small callable object with an explicit state-in/state-out
contract: ``compute(**inputs) -> dict`` of named outputs, and ``__call__``
wraps it with a wall-clock measurement so executors can account real latency
per stage.  The seven stages mirror the paper's Fig. 4 modules:

  batch_inference   (batch_params, x)            -> pred
  speed_inference   (speed_params, x)            -> pred [+ fallback flag]
  weight_solve      (prev_preds, prev_y)         -> w_speed, w_batch
  hybrid_combine    (pred_speed, pred_batch, w*) -> pred
  speed_training    (data, speed_params, batch_params, key)
                                                 -> params, eval_preds, eval_y
  model_sync        (params, eval_preds, eval_y) -> speed model state update
  data_sync         (records_nbytes,)            -> archive handoff

An executor (``repro.runtime.executor``) decides *where and when* each stage
runs: ``InProcessExecutor`` replays the paper's synchronous per-window loop;
``BusExecutor`` schedules the stages as ``TopicBus`` subscribers according to
a ``Deployment`` placement map.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.core.weighting import (
    combine,
    dwa_closed_form,
    dwa_scipy,
    static_weights,
)

Params = Any


@dataclass
class StageOutput:
    """What one stage invocation produced, plus its measured wall-clock."""

    values: Dict[str, Any]
    wall_s: float

    def __getitem__(self, key: str) -> Any:
        return self.values[key]


class Stage:
    """Base: times ``compute`` with a perf counter; subclasses are pure in the
    sense that all state enters via ``compute`` kwargs and leaves via the
    returned dict.  JAX dispatch is asynchronous, so the wrapper blocks on
    any device-array outputs before stopping the clock — otherwise the
    ``LatencyLedger`` would credit a stage for work still in flight."""

    name: str = "stage"

    def compute(self, **inputs: Any) -> Dict[str, Any]:
        raise NotImplementedError

    def __call__(self, **inputs: Any) -> StageOutput:
        import jax

        t0 = time.perf_counter()
        values = self.compute(**inputs)
        pending = [x for x in jax.tree_util.tree_leaves(values)
                   if isinstance(x, jax.Array)]
        if pending:
            jax.block_until_ready(pending)
        return StageOutput(values=values, wall_s=time.perf_counter() - t0)


class BatchInference(Stage):
    """M^b prediction on a window's supervised inputs."""

    name = "batch_inference"

    def __init__(self, forecaster):
        self.forecaster = forecaster

    def compute(self, *, batch_params: Params, x: np.ndarray) -> Dict[str, Any]:
        return {"pred": self.forecaster.predict(batch_params, x)}


class SpeedInference(Stage):
    """M^s_{t-1} prediction.  When no speed model has been synced yet (cold
    start, or the edge-centric OOM keeps training from ever publishing), the
    stage degrades to serving the batch model and flags it."""

    name = "speed_inference"

    def __init__(self, forecaster):
        self.forecaster = forecaster

    def compute(self, *, speed_params: Optional[Params], x: np.ndarray,
                fallback_params: Optional[Params] = None) -> Dict[str, Any]:
        fallback = speed_params is None
        params = fallback_params if fallback else speed_params
        if params is None:
            raise ValueError("speed_inference: no speed model and no fallback")
        return {"pred": self.forecaster.predict(params, x),
                "fallback": fallback}


class WeightSolve(Stage):
    """Algorithm 1 (dynamic) or static/degenerate weights.

    mode: "dynamic", ("static", w_speed), "speed", "batch" — identical
    semantics to the pre-refactor ``HybridStreamAnalytics._weights``.
    """

    name = "weight_solve"

    def __init__(self, mode="dynamic", dwa_solver: str = "closed_form"):
        self.mode = mode
        self.dwa_solver = dwa_solver

    def compute(self, *, prev_preds: Optional[Tuple[np.ndarray, np.ndarray]],
                prev_y: Optional[np.ndarray]) -> Dict[str, Any]:
        if isinstance(self.mode, tuple) and self.mode[0] == "static":
            ws, wb = static_weights(self.mode[1])
            return {"w_speed": ws, "w_batch": wb}
        if self.mode == "dynamic":
            if prev_preds is None:
                return {"w_speed": 0.5, "w_batch": 0.5}
            if self.dwa_solver == "scipy":
                w = dwa_scipy([prev_preds[0], prev_preds[1]], prev_y)
                ws, wb = float(w[0]), float(w[1])
            else:
                ws, wb = dwa_closed_form(prev_preds[0], prev_preds[1], prev_y)
            return {"w_speed": ws, "w_batch": wb}
        if self.mode == "speed":
            return {"w_speed": 1.0, "w_batch": 0.0}
        if self.mode == "batch":
            return {"w_speed": 0.0, "w_batch": 1.0}
        raise ValueError(f"unknown mode {self.mode!r}")

    @property
    def is_dynamic(self) -> bool:
        return self.mode == "dynamic"


class HybridCombine(Stage):
    """Pred_hybrid = W_s * Pred_speed + W_b * Pred_batch."""

    name = "hybrid_combine"

    def compute(self, *, pred_speed: np.ndarray, pred_batch: np.ndarray,
                w_speed: float, w_batch: float) -> Dict[str, Any]:
        return {"pred": combine([pred_speed, pred_batch], [w_speed, w_batch])}


class SpeedTraining(Stage):
    """Train M^s_t on window t's records and stash the Algorithm-1 inputs:
    predictions of (M^s_t, M^b) on window t, consumed when weighting window
    t+1.  ``train_wall_s`` is the forecaster-reported fit time (excludes the
    eval predictions), matching the pre-refactor ``t_speed_train``."""

    name = "speed_training"

    def __init__(self, forecaster):
        self.forecaster = forecaster

    def compute(self, *, data: Dict[str, np.ndarray],
                speed_params: Optional[Params], batch_params: Params,
                key) -> Dict[str, Any]:
        fc = self.forecaster
        if speed_params is not None:
            # the serving model may be the int8-synced tree (QTensor leaves);
            # training runs in float whatever the Forecaster implementation,
            # so dequantize at the stage boundary (no-op on a float tree)
            from repro.serving.quantize import dequantize_tree

            speed_params = dequantize_tree(speed_params)
        params, train_wall_s = fc.train(data, speed_params, key)
        x, y = data["x"], data["y"]
        eval_preds = eval_y = None
        if len(x) > 0:
            eval_preds = (fc.predict(params, x),
                          fc.predict(batch_params, x))
            eval_y = y
        return {"params": params, "train_wall_s": train_wall_s,
                "eval_preds": eval_preds, "eval_y": eval_y}


class ModelSync(Stage):
    """Install a freshly-published speed model (plus its Algorithm-1 eval
    predictions) as the serving state.  Pure pass-through compute; the cost of
    this module is the model transfer, which the executor accounts as
    communication."""

    name = "model_sync"

    def compute(self, *, params: Params, eval_preds, eval_y) -> Dict[str, Any]:
        return {"speed_params": params, "prev_preds": eval_preds,
                "prev_y": eval_y}


class DataSync(Stage):
    """Raw-data archiving handoff (S3 analog); compute-free, its cost is the
    window transfer to the archiving site."""

    name = "data_sync"

    def compute(self, *, nbytes: float = 0.0) -> Dict[str, Any]:
        return {"nbytes": nbytes}


@dataclass
class PipelineStages:
    """The full stage set one executor drives.  Build with :meth:`build` so
    every executor runs literally the same stage objects."""

    batch_inference: BatchInference
    speed_inference: SpeedInference
    weight_solve: WeightSolve
    hybrid_combine: HybridCombine
    speed_training: SpeedTraining
    model_sync: ModelSync
    data_sync: DataSync

    @classmethod
    def build(cls, forecaster, mode="dynamic",
              dwa_solver: str = "closed_form") -> "PipelineStages":
        return cls(
            batch_inference=BatchInference(forecaster),
            speed_inference=SpeedInference(forecaster),
            weight_solve=WeightSolve(mode, dwa_solver),
            hybrid_combine=HybridCombine(),
            speed_training=SpeedTraining(forecaster),
            model_sync=ModelSync(),
            data_sync=DataSync(),
        )

    @property
    def mode(self):
        return self.weight_solve.mode


def split_chain(key, n: int):
    """The sequential ``key, sub = jax.random.split(key)`` chain the
    synchronous loop uses, reproduced so every executor derives identical
    per-window training keys for the same seed."""
    import jax

    subs = []
    for _ in range(n):
        key, sub = jax.random.split(key)
        subs.append(sub)
    return subs
