"""The paper's primary contribution: adaptive hybrid stream analytics
(lambda-architecture batch/speed/hybrid layers + static/dynamic weighting)."""
from repro.core.hybrid import (  # noqa: F401
    Forecaster,
    HybridRunResult,
    HybridStreamAnalytics,
    WindowRecord,
    lstm_forecaster,
    pretrain_batch_model,
)
from repro.core.stages import PipelineStages, split_chain  # noqa: F401
from repro.core.weighting import (  # noqa: F401
    combine,
    dwa_closed_form,
    dwa_jax,
    dwa_scipy,
    rmse,
    static_weights,
)
from repro.core.windows import WindowedStream, WindowPlan, make_supervised  # noqa: F401
from repro.core import drift  # noqa: F401
