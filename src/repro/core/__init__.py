"""The paper's primary contribution: adaptive hybrid stream analytics
(lambda-architecture batch/speed/hybrid layers + static/dynamic weighting)."""
from repro.core.hybrid import (  # noqa: F401
    Forecaster,
    HybridRunResult,
    HybridStreamAnalytics,
    WindowRecord,
    lstm_fleet_forecaster,
    lstm_forecaster,
    pretrain_batch_model,
)
from repro.core.stages import (  # noqa: F401
    FleetStages,
    FleetState,
    PipelineStages,
    StreamId,
    StreamState,
    resolve_fleet_params,
    split_chain,
)
from repro.core.weighting import (  # noqa: F401
    combine,
    dwa_closed_form,
    dwa_jax,
    dwa_scipy,
    rmse,
    static_weights,
)
from repro.core.windows import WindowedStream, WindowPlan, make_supervised  # noqa: F401
from repro.core import drift  # noqa: F401
