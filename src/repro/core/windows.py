"""Time-window bookkeeping and supervised dataset construction.

The paper's problem statement (Sec. 5.1): with time lag n=5, predict
y^i from (y^{i-1}, ..., y^{i-n}); the stream is chopped into time windows of
>= 200 records (~30 s), the speed layer trains on window t and predicts
window t+1.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Tuple

import numpy as np


def make_supervised(series: np.ndarray, lag: int, target_col: int = 0
                    ) -> Dict[str, np.ndarray]:
    """(T, F) series -> {"x": (n, lag, F), "y": (n, 1)} with n = T - lag."""
    series = np.asarray(series, np.float32)
    if series.ndim == 1:
        series = series[:, None]
    T, F = series.shape
    n = T - lag
    if n <= 0:
        return {"x": np.zeros((0, lag, F), np.float32),
                "y": np.zeros((0, 1), np.float32)}
    idx = np.arange(lag)[None, :] + np.arange(n)[:, None]  # (n, lag)
    x = series[idx]  # (n, lag, F)
    y = series[lag:, target_col : target_col + 1]
    return {"x": x.astype(np.float32), "y": y.astype(np.float32)}


@dataclass(frozen=True)
class WindowPlan:
    n_windows: int
    records_per_window: int
    lag: int
    target_col: int = 0


class WindowedStream:
    """Iterates (window_index, window_records, supervised_data).

    Each window's supervised pairs include ``lag`` records of left context
    from the previous window so no boundary samples are lost.
    """

    def __init__(self, series: np.ndarray, plan: WindowPlan):
        self.series = np.asarray(series, np.float32)
        self.plan = plan

    def __len__(self) -> int:
        return min(self.plan.n_windows,
                   len(self.series) // self.plan.records_per_window)

    def window_records(self, t: int) -> np.ndarray:
        w = self.plan.records_per_window
        return self.series[t * w : (t + 1) * w]

    def supervised(self, t: int) -> Dict[str, np.ndarray]:
        w, lag = self.plan.records_per_window, self.plan.lag
        start = max(t * w - lag, 0)
        chunk = self.series[start : (t + 1) * w]
        return make_supervised(chunk, lag, self.plan.target_col)

    def __iter__(self) -> Iterator[Tuple[int, np.ndarray, Dict[str, np.ndarray]]]:
        for t in range(len(self)):
            yield t, self.window_records(t), self.supervised(t)
