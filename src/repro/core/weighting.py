"""Weight-combination algorithms for the hybrid layer (paper Sec. 5.3).

``Pred_hybrid = W_s * Pred_speed + W_b * Pred_batch``, ``W_s + W_b = 1``.

* ``static_weights`` — fixed (W_s, W_b), the paper evaluates 3:7, 5:5, 7:3.

* ``dwa_scipy`` — the paper's Algorithm 1 verbatim: stack the batch model and
  the previous-window speed model, collect their predictions on the previous
  window's test set, and minimize RMSE with scipy SLSQP, init 0.5 each,
  bounds [0,1], constraint sum(W)=1.

* ``dwa_closed_form`` / ``dwa_jax`` — TPU-native equivalents.  The RMSE of a
  convex combination is a least-squares problem on the simplex; for K=2 it
  has a closed form (clipped), for K>2 we run jittable projected gradient
  descent with exact simplex projection.  Tests assert these agree with
  SLSQP to ~1e-5 — no host round-trip is needed on device.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from scipy.optimize import minimize


def rmse(y: np.ndarray, pred: np.ndarray) -> float:
    """Paper Eq. 5."""
    y = np.asarray(y, np.float64).ravel()
    pred = np.asarray(pred, np.float64).ravel()
    return float(np.sqrt(np.mean((y - pred) ** 2)))


def static_weights(w_speed: float) -> Tuple[float, float]:
    """(W_s, W_b) with W_b = 1 - W_s."""
    assert 0.0 <= w_speed <= 1.0
    return w_speed, 1.0 - w_speed


def combine(preds: Sequence[np.ndarray], weights: Sequence[float]) -> np.ndarray:
    out = np.zeros_like(np.asarray(preds[0], np.float64))
    for p, w in zip(preds, weights):
        out = out + w * np.asarray(p, np.float64)
    return out


# ---------------------------------------------------------------------------
# Paper Algorithm 1 (SLSQP)
# ---------------------------------------------------------------------------


def dwa_scipy(preds: Sequence[np.ndarray], y: np.ndarray) -> np.ndarray:
    """Dynamic Weighting Algorithm, faithful to Algorithm 1.

    preds: K arrays of predictions on the previous window's test set
    (speed model M^s_{t-1} first, batch model M^b second, by convention).
    Returns the K weights.
    """
    preds = [np.asarray(p, np.float64).ravel() for p in preds]
    y = np.asarray(y, np.float64).ravel()
    K = len(preds)
    P = np.stack(preds, axis=1)  # (n, K)

    def loss(w):
        return np.sqrt(np.mean((y - P @ w) ** 2))

    w0 = np.full(K, 0.5)  # paper: initial guess 0.5
    cons = {"type": "eq", "fun": lambda w: 1.0 - np.sum(w)}
    bounds = [(0.0, 1.0)] * K
    res = minimize(loss, w0, method="SLSQP", bounds=bounds, constraints=[cons])
    w = np.clip(res.x, 0.0, 1.0)
    s = w.sum()
    return w / s if s > 0 else np.full(K, 1.0 / K)


# ---------------------------------------------------------------------------
# TPU-native equivalents
# ---------------------------------------------------------------------------


def dwa_closed_form(pred_speed: np.ndarray, pred_batch: np.ndarray,
                    y: np.ndarray) -> Tuple[float, float]:
    """K=2 exact solution.  min_w ||y - (w*ps + (1-w)*pb)||^2 over w in [0,1]
    (RMSE and MSE share the argmin):  w* = <y - pb, ps - pb> / ||ps - pb||^2.
    """
    ps = np.asarray(pred_speed, np.float64).ravel()
    pb = np.asarray(pred_batch, np.float64).ravel()
    y = np.asarray(y, np.float64).ravel()
    d = ps - pb
    denom = float(d @ d)
    if denom < 1e-18:
        return 0.5, 0.5
    w = float((y - pb) @ d / denom)
    w = min(max(w, 0.0), 1.0)
    return w, 1.0 - w


def _project_simplex(v: jax.Array) -> jax.Array:
    """Euclidean projection onto the probability simplex (sorted algorithm)."""
    K = v.shape[0]
    u = jnp.sort(v)[::-1]
    css = jnp.cumsum(u)
    idx = jnp.arange(1, K + 1, dtype=v.dtype)
    cond = u + (1.0 - css) / idx > 0
    rho = jnp.sum(cond.astype(jnp.int32))
    lam = (1.0 - css[rho - 1]) / rho.astype(v.dtype)
    return jnp.maximum(v + lam, 0.0)


def dwa_jax(preds: jax.Array, y: jax.Array, n_steps: int = 200,
            lr: float = 0.5) -> jax.Array:
    """Jittable K-model DWA: projected gradient descent on the simplex.

    preds: (K, n); y: (n,).  Minimizes MSE (same argmin as RMSE) of the
    convex combination; exact simplex projection each step.
    """
    preds = preds.astype(jnp.float32)
    y = y.astype(jnp.float32).ravel()
    K = preds.shape[0]
    # normalize scale so the fixed lr is robust
    scale = jnp.maximum(jnp.mean(preds * preds), 1e-12)

    def loss(w):
        r = y - w @ preds
        return jnp.mean(r * r)

    g = jax.grad(loss)

    def step(w, _):
        w = _project_simplex(w - lr / scale * g(w))
        return w, None

    w0 = jnp.full((K,), 1.0 / K, jnp.float32)
    w, _ = jax.lax.scan(step, w0, None, length=n_steps)
    return w


dwa_jax_jit = jax.jit(dwa_jax, static_argnames=("n_steps",))
