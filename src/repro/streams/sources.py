"""Stream data sources.

* ``wind_turbine_series`` — a stationary 5-channel temperature-like series
  standing in for the ENGIE La Haute Borne turbine data the paper uses
  (Db1t_avg, Db2t_avg, Gb1t_avg, Gb2t_avg, Ot_avg; 10-minute cadence,
  ~50k observations).  Daily + seasonal harmonics, cross-correlated AR(1)
  noise, mean-reverting — ADF-stationary like the paper's (Sec. 6.1.1).

* ``gradual_drift`` / ``abrupt_drift`` — the paper's Eq. 6 / Eq. 7 drift
  simulators: GD_i(t) = a_i*t + Y_i(t) + eps;  AD_i(t) = a_i*t*lambda + Y_i(t)
  + eps with a random abrupt parameter lambda (piecewise-constant regime
  switches).  ``seasonal_drift`` extends the menu beyond the paper: a slow
  periodic component the history never saw, which drifts away and comes
  back.

* ``apply_scenario`` — name-keyed dispatch over the drift scenarios
  ({"none", "gradual", "abrupt"} from the paper's Sec. 6.1.3, plus
  "seasonal") so launchers and benchmarks can select one from a CLI flag.

* ``turbine_fleet`` — N correlated turbines (a wind farm sharing ambient
  weather) with a per-stream drift scenario each: the multi-stream source
  the fleet executors serve.

* ``token_stream`` — a drifting Markov token source for the LLM speed-layer
  adaptation example.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import numpy as np

N_TURBINE_CHANNELS = 5
CHANNEL_NAMES = ("Db1t_avg", "Db2t_avg", "Gb1t_avg", "Gb2t_avg", "Ot_avg")


def wind_turbine_series(
    n: int = 50_000, seed: int = 0, dt_minutes: float = 10.0
) -> np.ndarray:
    """(n, 5) float32 stationary series."""
    rng = np.random.default_rng(seed)
    t = np.arange(n, dtype=np.float64)
    day = 24 * 60 / dt_minutes  # samples per day
    year = 365 * day

    base_temp = np.array([45.0, 44.0, 55.0, 54.0, 12.0])  # bearing/gearbox/outdoor
    daily_amp = np.array([2.0, 2.2, 3.0, 2.8, 5.0])
    # mild seasonal term: strong enough to exist, weak enough that a model
    # trained on history stays competitive on the (stationary) stream — the
    # paper's no-drift scenario has batch ~ speed (Fig. 8a)
    seasonal_amp = np.array([1.2, 1.2, 1.6, 1.6, 3.0])
    noise_scale = np.array([0.8, 0.8, 1.2, 1.2, 1.5])

    daily = np.sin(2 * np.pi * t / day)[:, None] * daily_amp[None]
    seasonal = np.sin(2 * np.pi * t / year + 0.5)[:, None] * seasonal_amp[None]

    # cross-correlated AR(1) noise (shared ambient component)
    shared = np.zeros(n)
    eps_s = rng.normal(0, 0.3, n)
    for i in range(1, n):
        shared[i] = 0.98 * shared[i - 1] + eps_s[i]
    own = np.zeros((n, N_TURBINE_CHANNELS))
    eps_o = rng.normal(0, 1.0, (n, N_TURBINE_CHANNELS))
    for i in range(1, n):
        own[i] = 0.95 * own[i - 1] + eps_o[i]
    noise = (own + shared[:, None]) * noise_scale[None] * 0.5

    series = base_temp[None] + daily + seasonal + noise
    return series.astype(np.float32)


def gradual_drift(
    series: np.ndarray,
    alphas: Optional[np.ndarray] = None,
    eps_scale: float = 0.2,
    seed: int = 1,
    start: int = 0,
) -> np.ndarray:
    """Paper Eq. 6: GD_i(t) = alpha_i * t + Y_i(t) + eps (after ``start``)."""
    rng = np.random.default_rng(seed)
    n, f = series.shape
    if alphas is None:
        alphas = np.full(f, 5e-4)
    t = np.maximum(np.arange(n, dtype=np.float64) - start, 0.0)
    eps = rng.normal(0, eps_scale, (n, f))
    return (series + alphas[None] * t[:, None] + eps).astype(np.float32)


def abrupt_drift(
    series: np.ndarray,
    alphas: Optional[np.ndarray] = None,
    eps_scale: float = 0.2,
    seed: int = 2,
    n_switches: int = 4,
    start: int = 0,
) -> np.ndarray:
    """Paper Eq. 7: AD_i(t) = alpha_i * t * lambda + Y_i(t) + eps, with
    lambda a random abrupt parameter — piecewise-constant regime levels that
    switch at random change points (sudden concept switches)."""
    rng = np.random.default_rng(seed)
    n, f = series.shape
    if alphas is None:
        alphas = np.full(f, 8e-4)
    switch_points = np.sort(rng.choice(np.arange(start + 1, n - 1), n_switches,
                                       replace=False))
    lam = np.zeros(n)
    current = 0.0
    prev = 0
    levels = rng.uniform(-1.5, 1.5, n_switches + 1)
    for i, sp in enumerate(list(switch_points) + [n]):
        lam[prev:sp] = levels[i]
        prev = sp
    t = np.maximum(np.arange(n, dtype=np.float64) - start, 0.0)
    eps = rng.normal(0, eps_scale, (n, f))
    drift = alphas[None] * (t * lam)[:, None]
    return (series + drift + eps).astype(np.float32)


def seasonal_drift(
    series: np.ndarray,
    amp_scale: float = 1.0,
    period: Optional[int] = None,
    eps_scale: float = 0.2,
    seed: int = 3,
    start: int = 0,
) -> np.ndarray:
    """Seasonal drift: SD_i(t) = A_i * sin(2*pi*(t - start)/P + phi_i)
    + Y_i(t) + eps — a slow periodic component the history never saw, per
    channel with its own random phase.  Unlike Eq. 6's monotone ramp it
    drifts away and comes *back*, so a model that adapts to the excursion
    is wrong again half a period later — the regime the compound chaos
    scenario was missing.  ``period`` defaults to half the post-``start``
    length (one full cycle over the live stream)."""
    rng = np.random.default_rng(seed)
    n, f = series.shape
    if period is None:
        period = max((n - start) // 2, 1)
    amps = amp_scale * series.std(axis=0)
    phases = rng.uniform(0.0, 2 * np.pi, f)
    t = np.maximum(np.arange(n, dtype=np.float64) - start, 0.0)
    wave = np.sin(2 * np.pi * t[:, None] / period + phases[None])
    # the drift only exists after start (wave(0) != 0 unless phi is 0)
    wave *= (t > 0)[:, None]
    eps = rng.normal(0, eps_scale, (n, f))
    return (series + amps[None] * wave + eps).astype(np.float32)


SCENARIOS = ("none", "gradual", "abrupt", "seasonal")


def apply_scenario(
    series: np.ndarray,
    scenario: str,
    seed: int = 1,
    alphas: Optional[np.ndarray] = None,
    start: int = 0,
) -> np.ndarray:
    """Apply one of the drift scenarios to a (stationary) series:
    ``"none"`` returns it untouched, ``"gradual"`` applies Eq. 6,
    ``"abrupt"`` applies Eq. 7, ``"seasonal"`` adds the periodic
    excursion-and-return component of :func:`seasonal_drift`."""
    if scenario == "none":
        return series
    if scenario == "gradual":
        return gradual_drift(series, alphas=alphas, seed=seed, start=start)
    if scenario == "abrupt":
        return abrupt_drift(series, alphas=alphas, seed=seed, start=start)
    if scenario == "seasonal":
        return seasonal_drift(series, seed=seed, start=start)
    raise ValueError(f"unknown scenario {scenario!r}; pick from {SCENARIOS}")


def turbine_fleet(
    n_streams: int,
    n: int,
    seed: int = 0,
    scenarios: Union[str, Sequence[str]] = "none",
    shared_frac: float = 0.35,
    alphas: Optional[np.ndarray] = None,
    drift_start: int = 0,
) -> Dict[str, np.ndarray]:
    """A fleet of N correlated turbines: ``{stream_id: (n, 5) series}``.

    Every turbine mixes a *shared* ambient component (the farm's common
    weather, weight ``shared_frac``) with its own independently-seeded
    series, so the streams are cross-correlated the way one site's turbines
    are.  ``scenarios`` is either one scenario name for the whole fleet or
    one per stream ({"none", "gradual", "abrupt"}), applied after the
    deviations-from-base mixing so each stream drifts (or doesn't) on its
    own schedule — the per-stream dynamic the drift-gated retraining policy
    exploits.

    Stream ids are ``"t00"``, ``"t01"``, ... (lexicographically ordered, so
    iteration order is deterministic)."""
    if isinstance(scenarios, str):
        scenarios = [scenarios] * n_streams
    if len(scenarios) != n_streams:
        raise ValueError(
            f"{n_streams} streams but {len(scenarios)} scenarios")
    shared = wind_turbine_series(n, seed=seed)
    shared_dev = shared - shared.mean(axis=0, keepdims=True)
    fleet: Dict[str, np.ndarray] = {}
    for i, scenario in enumerate(scenarios):
        own = wind_turbine_series(n, seed=seed + 1000 + i)
        mixed = (own + shared_frac * shared_dev).astype(np.float32)
        fleet[f"t{i:02d}"] = apply_scenario(
            mixed, scenario, seed=seed + 2000 + i, alphas=alphas,
            start=drift_start)
    return fleet


def fleet_windowed_streams(
    n_streams: int,
    n_windows: int,
    records_per_window: int,
    scenarios: Union[str, Sequence[str]] = "none",
    *,
    seed: int = 0,
    hist_len: int = 1600,
    alphas: Optional[np.ndarray] = None,
    lag: int = 5,
):
    """A :func:`turbine_fleet` split the way every fleet entrypoint consumes
    it: per stream, the first ``hist_len`` records are history, the rest is
    the windowed live stream, and each stream is min-max scaled by *its own*
    history.  Drift (when a stream's scenario has any) starts where the live
    stream does.

    Returns ``({stream_id: WindowedStream}, hist0_supervised)`` where
    ``hist0_supervised`` is the first stream's scaled history as supervised
    pairs — what the fleet's shared batch model pre-trains on.  Single
    source of truth for the launcher's ``--streams`` mode
    (``launch.edge_cloud.build_fleet_pipeline``), ``benchmarks/bench_fleet``
    and the fleet tests."""
    from repro.core.windows import WindowPlan, WindowedStream, make_supervised
    from repro.streams.normalize import MinMaxScaler

    fleet_raw = turbine_fleet(
        n_streams, hist_len + records_per_window * n_windows + lag,
        seed=seed, scenarios=scenarios, alphas=alphas, drift_start=hist_len)
    streams, hist0 = {}, None
    for sid, series in fleet_raw.items():
        hist, tail = series[:hist_len], series[hist_len:]
        scaler = MinMaxScaler.fit(hist)
        if hist0 is None:
            hist0 = make_supervised(scaler.transform(hist), lag, 0)
        streams[sid] = WindowedStream(
            scaler.transform(tail),
            WindowPlan(n_windows, records_per_window, lag=lag))
    return streams, hist0


def token_stream(
    n: int, vocab: int, seed: int = 0, drift_at: Optional[int] = None
) -> np.ndarray:
    """Markov token stream; transition matrix switches at ``drift_at``."""
    rng = np.random.default_rng(seed)

    def trans(seed2):
        r = np.random.default_rng(seed2)
        m = r.dirichlet(np.full(vocab, 0.3), size=vocab)
        return m

    m1 = trans(seed)
    m2 = trans(seed + 1)
    out = np.zeros(n, np.int32)
    s = 0
    for i in range(1, n):
        m = m1 if (drift_at is None or i < drift_at) else m2
        s = rng.choice(vocab, p=m[s])
        out[i] = s
    return out
