from repro.streams.injection import DataInjection, ThrottleConfig, stream_windows  # noqa: F401
from repro.streams.normalize import MinMaxScaler  # noqa: F401
from repro.streams import sources  # noqa: F401
