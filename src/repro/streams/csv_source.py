"""CSV stream source (ENGIE La Haute Borne format analog).

The paper streams the open wind-farm CSV (one row per 10-minute sample,
columns per sensor).  This reader is dependency-free (no pandas in this
container): it parses the header, selects the five temperature channels the
paper uses, handles missing values by forward fill, and yields either the
full array or throttled windows.  ``write_csv`` produces a compatible file
from any array (used by tests and to materialize the synthetic dataset in
the paper's format).
"""
from __future__ import annotations

import csv
import io
from typing import List, Optional, Sequence

import numpy as np

PAPER_CHANNELS = ("Db1t_avg", "Db2t_avg", "Gb1t_avg", "Gb2t_avg", "Ot_avg")


def write_csv(path: str, data: np.ndarray,
              channels: Sequence[str] = PAPER_CHANNELS,
              timestamp_col: bool = True) -> None:
    data = np.asarray(data)
    assert data.ndim == 2 and data.shape[1] == len(channels)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        hdr = (["Date_time"] if timestamp_col else []) + list(channels)
        w.writerow(hdr)
        for i, row in enumerate(data):
            ts = [f"2017-01-01T{i:06d}"] if timestamp_col else []
            w.writerow(ts + [f"{v:.4f}" for v in row])


def read_csv(
    path_or_buf,
    channels: Sequence[str] = PAPER_CHANNELS,
    max_rows: Optional[int] = None,
) -> np.ndarray:
    """Returns (n, len(channels)) float32 with forward-filled gaps."""
    close = False
    if isinstance(path_or_buf, str):
        f = open(path_or_buf, newline="")
        close = True
    else:
        f = path_or_buf
    try:
        r = csv.reader(f)
        header = next(r)
        idx = []
        for c in channels:
            if c not in header:
                raise KeyError(f"column {c!r} not in CSV header {header}")
            idx.append(header.index(c))
        rows: List[List[float]] = []
        last: Optional[List[float]] = None
        for line in r:
            vals = []
            for j in idx:
                raw = line[j].strip() if j < len(line) else ""
                if raw in ("", "NA", "NaN", "nan"):
                    vals.append(np.nan)
                else:
                    try:
                        vals.append(float(raw))
                    except ValueError:
                        vals.append(np.nan)
            if last is not None:
                vals = [last[k] if np.isnan(v) else v
                        for k, v in enumerate(vals)]
            elif any(np.isnan(v) for v in vals):
                continue  # drop leading incomplete rows
            rows.append(vals)
            last = vals
            if max_rows is not None and len(rows) >= max_rows:
                break
        return np.asarray(rows, np.float32)
    finally:
        if close:
            f.close()


def read_csv_str(text: str, **kw) -> np.ndarray:
    return read_csv(io.StringIO(text), **kw)
