"""Min-Max scaling to [0, 1] (paper Sec. 6.1.2) with inverse transform."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class MinMaxScaler:
    lo: np.ndarray
    hi: np.ndarray

    @classmethod
    def fit(cls, x: np.ndarray) -> "MinMaxScaler":
        return cls(lo=x.min(axis=0), hi=x.max(axis=0))

    def transform(self, x: np.ndarray) -> np.ndarray:
        span = np.maximum(self.hi - self.lo, 1e-12)
        return ((x - self.lo) / span).astype(np.float32)

    def inverse(self, x: np.ndarray, col: int | None = None) -> np.ndarray:
        if col is None:
            span = np.maximum(self.hi - self.lo, 1e-12)
            return x * span + self.lo
        span = max(self.hi[col] - self.lo[col], 1e-12)
        return x * span + self.lo[col]
