"""Data-injection module (paper Sec. 3 / 5.2): a transfer station that
throttles the continuous stream into per-time-window payloads.

The buffer queue "avoids the receiver from the crash when absorbing the peaks
of incoming data" — modeled here as a bounded deque with drop accounting.
The paper throttles >= 200 records per 30 s window at ~7 records/s Kafka
bandwidth.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


@dataclass
class ThrottleConfig:
    window_seconds: float = 30.0
    min_records: int = 200
    max_buffer: int = 10_000
    ingest_rate_hz: float = 7.0  # paper's measured Kafka bandwidth


@dataclass
class DataInjection:
    cfg: ThrottleConfig = field(default_factory=ThrottleConfig)
    _buffer: deque = field(default_factory=deque)
    dropped: int = 0
    emitted_windows: int = 0

    def push(self, records: np.ndarray) -> None:
        for r in np.atleast_2d(records):
            if len(self._buffer) >= self.cfg.max_buffer:
                self._buffer.popleft()
                self.dropped += 1
            self._buffer.append(r)

    def ready(self) -> bool:
        return len(self._buffer) >= self.cfg.min_records

    def emit(self) -> Optional[np.ndarray]:
        """Emit one time-window payload (all buffered records, >= min)."""
        if not self.ready():
            return None
        out = np.stack(list(self._buffer))
        self._buffer.clear()
        self.emitted_windows += 1
        return out

    def ingest_seconds(self, n_records: int) -> float:
        """Time to ingest n records at the configured bandwidth."""
        return n_records / self.cfg.ingest_rate_hz


class BusInjector:
    """Feed windowed stream payloads onto a topic bus (the data_injection
    module of the bus-scheduled pipeline): window ``w`` is published on
    ``topic`` at virtual time ``w * period_s`` from ``site``, carrying the
    window's real supervised arrays; ``nbytes`` is the actual payload size so
    link transfer times reflect the data that moves.

    With a ``stream_id``, the injector is one member of a fleet: it
    publishes on the per-stream topic ``topic/<stream_id>`` (the fleet
    executors subscribe the ``topic/+`` wildcard) and stamps the stream id
    into every payload.

    A ``fault_plane`` models the sensor itself going bad: each nominal
    window expands (via ``FaultPlane.sensor_windows``) into zero or more
    actual publishes — dropped windows, out-of-order jitter, duplicates,
    per-record dropout, Byzantine values — before the payload ever reaches
    the bus.

    A ``health_plane`` screens what the (possibly lying) sensor produced:
    its :class:`~repro.runtime.health.ByzantineGuard` gates every window's
    target values through per-stream rolling median/MAD plausibility
    checks, imputing flagged values before the window reaches the bus —
    the defense the Byzantine sensor fault exists to exercise.  Clean
    windows pass through untouched (same array objects), so a fault-free
    run is byte-identical with or without the guard."""

    def __init__(self, kernel, bus, topic: str, site: str,
                 period_s: float = 30.0, stream_id: Optional[str] = None,
                 fault_plane=None, health_plane=None):
        self.kernel = kernel
        self.bus = bus
        self.topic = topic if stream_id is None else f"{topic}/{stream_id}"
        self.site = site
        self.period_s = period_s
        self.stream_id = stream_id
        self.fault_plane = fault_plane
        self.health_plane = health_plane
        self.injected = 0

    def schedule_window(self, w: int, data: dict) -> float:
        """Schedule window ``w``'s publish; returns its *nominal* injection
        time (sensor faults may move, multiply, or remove the actual
        publishes)."""
        t = w * self.period_s
        deliveries = [(t, data)]
        sid = self.stream_id if self.stream_id is not None else ""
        if self.fault_plane is not None:
            deliveries = self.fault_plane.sensor_windows(sid, w, t, data)
        if self.health_plane is not None:
            screened = []
            for t_i, d in deliveries:
                d2, n_flagged = self.health_plane.guard.screen(sid, d, t_i)
                if n_flagged:
                    self.health_plane.observe_fault("sensor", sid, t_i)
                screened.append((t_i, d2))
            deliveries = screened
        for t_i, d in deliveries:
            payload = {"window": w, "x": d["x"], "y": d["y"]}
            if self.stream_id is not None:
                payload["stream"] = self.stream_id
            nbytes = float(d["x"].nbytes + d["y"].nbytes)
            self.kernel.at(
                t_i,
                lambda payload=payload, nbytes=nbytes: self.bus.publish(
                    self.topic, payload, nbytes, self.site))
        self.injected += 1
        return t


def stream_windows(series: np.ndarray, records_per_window: int) -> List[np.ndarray]:
    """Offline equivalent: chop a series into fixed-size time windows."""
    n = (len(series) // records_per_window) * records_per_window
    return [
        series[i : i + records_per_window]
        for i in range(0, n, records_per_window)
    ]
