"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel package has:
  kernel.py — pl.pallas_call with explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd wrapper (interpret=True on CPU so kernels validate here)
  ref.py    — pure-jnp oracle the tests assert against

Kernels are NOT used in the multi-pod dry-run HLO (Mosaic does not lower on
the CPU placeholder backend); ``ModelConfig.use_pallas`` switches the model
zoo onto them when running on real TPUs.
"""

import jax


def default_interpret() -> bool:
    """Interpret kernels unless a real TPU backend is present."""
    return jax.default_backend() != "tpu"
