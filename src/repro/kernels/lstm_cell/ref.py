"""Pure-jnp oracle for the fused LSTM kernels (per-step and full-sequence)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lstm_cell_ref(x, h, c, wx, wh, b):
    z = (
        x.astype(jnp.float32) @ wx.astype(jnp.float32)
        + h.astype(jnp.float32) @ wh.astype(jnp.float32)
        + b.astype(jnp.float32)
    )
    H = h.shape[-1]
    i, f, g, o = z[:, :H], z[:, H : 2 * H], z[:, 2 * H : 3 * H], z[:, 3 * H :]
    i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f), jax.nn.sigmoid(o)
    g = jnp.tanh(g)
    c_new = f * c.astype(jnp.float32) + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new.astype(h.dtype), c_new.astype(c.dtype)


def lstm_sequence_ref(x, wx, wh, b, return_state: bool = False):
    """Full-sequence oracle.  x: (B, T, F) -> final hidden (B, H), or the
    final ``(h, c)`` pair with ``return_state=True`` — what the fused
    sequence kernel's two outputs are asserted against."""
    B = x.shape[0]
    H = wh.shape[0]
    h = jnp.zeros((B, H), x.dtype)
    c = jnp.zeros((B, H), x.dtype)

    def step(carry, xt):
        h, c = carry
        h, c = lstm_cell_ref(xt, h, c, wx, wh, b)
        return (h, c), None

    (h, c), _ = jax.lax.scan(step, (h, c), x.transpose(1, 0, 2))
    return (h, c) if return_state else h
