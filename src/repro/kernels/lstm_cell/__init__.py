from repro.kernels.lstm_cell import kernel, ops, ref  # noqa: F401
