"""Fused LSTM kernels: one step (``lstm_cell``) and a whole sequence
(``lstm_sequence_fused``).

The paper's speed layer re-trains a small LSTM inside every 30 s window, so
the recurrence is the latency-critical inner loop.  On TPU the win is fusing
the two matmuls (x@Wx + h@Wh -> one (B, 4H) gate pre-activation) with the
gate nonlinearities and state update in one VMEM-resident kernel: the
weights (F+H, 4H) stay in VMEM and the (B, 4H) intermediate never
round-trips to HBM.

``lstm_cell`` fuses one timestep.  Scanning it over time (the old
``ops.lstm_sequence``) still paid one kernel launch per step and re-staged
the weights every launch.  ``lstm_sequence_fused`` moves the time loop
*inside* a single ``pallas_call``: the (bb, T, F) input block and both
weight blocks are resident for all T steps, the h/c carry lives in
registers/VMEM, and only the final state is written out — one launch per
batch tile for the whole sequence.

Training differentiates through the same fused recurrence:
``lstm_sequence_fwd_train`` is the forward that additionally materializes the
per-step residuals the backward needs (post-activation gates, cell and hidden
sequences), and ``lstm_sequence_bwd`` runs the reverse-time loop in one
``pallas_call`` — producing dx per batch tile and accumulating the weight
gradients (dwx, dwh, db) across the batch grid into broadcast output blocks.
``ops.lstm_sequence`` stitches the pair into a ``jax.custom_vjp`` so the
speed layer's cached train step runs fused kernels end to end instead of
autodiff-through-scan (reverse-mode AD does not lower through a compiled
Mosaic ``pallas_call`` anyway).

Tiling: grid over batch tiles; weights are broadcast blocks (index_map pins
them to block 0).  MXU alignment: for the paper model (H=40, F=5, T=5) the
shapes are tiny and the kernel is bandwidth-trivial; for wider LSTMs choose
block_b and H multiples of the 8x128 lanes.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import default_interpret


def _gates(z, h_dim, c):
    i = jax.nn.sigmoid(z[:, :h_dim])
    f = jax.nn.sigmoid(z[:, h_dim : 2 * h_dim])
    g = jnp.tanh(z[:, 2 * h_dim : 3 * h_dim])
    o = jax.nn.sigmoid(z[:, 3 * h_dim :])
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new


def _cell_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref, h_out, c_out):
    x = x_ref[...].astype(jnp.float32)
    h = h_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    wx = wx_ref[...].astype(jnp.float32)
    wh = wh_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)

    z = jnp.dot(x, wx, preferred_element_type=jnp.float32)
    z = z + jnp.dot(h, wh, preferred_element_type=jnp.float32) + b[None, :]
    h_new, c_new = _gates(z, h.shape[-1], c)
    h_out[...] = h_new.astype(h_out.dtype)
    c_out[...] = c_new.astype(c_out.dtype)


@partial(jax.jit, static_argnames=("block_b", "interpret"))
def lstm_cell(x, h, c, wx, wh, b, *, block_b: int = 128,
              interpret: bool | None = None):
    """One fused LSTM step.  x: (B, F); h, c: (B, H) -> (h', c').

    ``interpret=None`` resolves via ``repro.kernels.default_interpret()``:
    compiled Mosaic on a real TPU backend, interpreter elsewhere."""
    interpret = default_interpret() if interpret is None else interpret
    B, F = x.shape
    H = h.shape[-1]
    bb = min(block_b, B)
    grid = (pl.cdiv(B, bb),)
    return pl.pallas_call(
        _cell_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, F), lambda i: (i, 0)),
            pl.BlockSpec((bb, H), lambda i: (i, 0)),
            pl.BlockSpec((bb, H), lambda i: (i, 0)),
            pl.BlockSpec((F, 4 * H), lambda i: (0, 0)),  # weights: broadcast
            pl.BlockSpec((H, 4 * H), lambda i: (0, 0)),
            pl.BlockSpec((4 * H,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bb, H), lambda i: (i, 0)),
            pl.BlockSpec((bb, H), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H), h.dtype),
            jax.ShapeDtypeStruct((B, H), c.dtype),
        ],
        interpret=interpret,
    )(x, h, c, wx, wh, b)


def _sequence_kernel(x_ref, wx_ref, wh_ref, b_ref, h_out, c_out):
    """Whole-sequence LSTM for one batch tile: time loop inside the kernel,
    weights read once and VMEM-resident across all T steps."""
    x = x_ref[...].astype(jnp.float32)        # (bb, T, F)
    wx = wx_ref[...].astype(jnp.float32)      # (F, 4H)
    wh = wh_ref[...].astype(jnp.float32)      # (H, 4H)
    b = b_ref[...].astype(jnp.float32)        # (4H,)
    bb, T, _ = x.shape
    H = wh.shape[0]

    def step(t, carry):
        h, c = carry
        x_t = jax.lax.dynamic_slice_in_dim(x, t, 1, axis=1)[:, 0, :]
        z = jnp.dot(x_t, wx, preferred_element_type=jnp.float32)
        z = z + jnp.dot(h, wh, preferred_element_type=jnp.float32) + b[None, :]
        return _gates(z, H, c)

    h0 = jnp.zeros((bb, H), jnp.float32)
    c0 = jnp.zeros((bb, H), jnp.float32)
    h, c = jax.lax.fori_loop(0, T, step, (h0, c0))
    h_out[...] = h.astype(h_out.dtype)
    c_out[...] = c.astype(c_out.dtype)


@partial(jax.jit, static_argnames=("block_b", "interpret"))
def lstm_sequence_fused(x, wx, wh, b, *, block_b: int = 128,
                        interpret: bool | None = None):
    """Fused full-sequence LSTM.  x: (B, T, F) -> final (h, c), each (B, H).

    One ``pallas_call`` per batch-tile grid step covers all T timesteps —
    versus T launches (and T weight re-stagings) for the scanned per-cell
    kernel this replaces."""
    interpret = default_interpret() if interpret is None else interpret
    B, T, F = x.shape
    H = wh.shape[0]
    bb = min(block_b, B)
    grid = (pl.cdiv(B, bb),)
    return pl.pallas_call(
        _sequence_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, T, F), lambda i: (i, 0, 0)),
            pl.BlockSpec((F, 4 * H), lambda i: (0, 0)),  # weights: broadcast
            pl.BlockSpec((H, 4 * H), lambda i: (0, 0)),
            pl.BlockSpec((4 * H,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bb, H), lambda i: (i, 0)),
            pl.BlockSpec((bb, H), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H), x.dtype),
            jax.ShapeDtypeStruct((B, H), x.dtype),
        ],
        interpret=interpret,
    )(x, wx, wh, b)


# ---------------------------------------------------------------------------
# Training pair: residual-emitting forward + fused backward
# ---------------------------------------------------------------------------


def _sequence_train_kernel(x_ref, wx_ref, wh_ref, b_ref,
                           gates_out, c_out, h_out):
    """Forward identical to ``_sequence_kernel`` but materializing the
    backward's residuals: post-activation gates (bb, T, 4H) and the full cell
    and hidden state sequences (bb, T, H) — all f32, so the VJP reconstructs
    the recurrence without re-running any matmul."""
    x = x_ref[...].astype(jnp.float32)        # (bb, T, F)
    wx = wx_ref[...].astype(jnp.float32)
    wh = wh_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    bb, T, _ = x.shape
    H = wh.shape[0]

    def step(t, carry):
        h, c, gates, cs, hs = carry
        x_t = jax.lax.dynamic_slice_in_dim(x, t, 1, axis=1)[:, 0, :]
        z = jnp.dot(x_t, wx, preferred_element_type=jnp.float32)
        z = z + jnp.dot(h, wh, preferred_element_type=jnp.float32) + b[None, :]
        i = jax.nn.sigmoid(z[:, :H])
        f = jax.nn.sigmoid(z[:, H : 2 * H])
        g = jnp.tanh(z[:, 2 * H : 3 * H])
        o = jax.nn.sigmoid(z[:, 3 * H :])
        c_new = f * c + i * g
        h_new = o * jnp.tanh(c_new)
        g4 = jnp.concatenate([i, f, g, o], axis=-1)
        gates = jax.lax.dynamic_update_slice_in_dim(
            gates, g4[:, None, :], t, axis=1)
        cs = jax.lax.dynamic_update_slice_in_dim(
            cs, c_new[:, None, :], t, axis=1)
        hs = jax.lax.dynamic_update_slice_in_dim(
            hs, h_new[:, None, :], t, axis=1)
        return h_new, c_new, gates, cs, hs

    init = (
        jnp.zeros((bb, H), jnp.float32),
        jnp.zeros((bb, H), jnp.float32),
        jnp.zeros((bb, T, 4 * H), jnp.float32),
        jnp.zeros((bb, T, H), jnp.float32),
        jnp.zeros((bb, T, H), jnp.float32),
    )
    _, _, gates, cs, hs = jax.lax.fori_loop(0, T, step, init)
    gates_out[...] = gates
    c_out[...] = cs
    h_out[...] = hs


@partial(jax.jit, static_argnames=("block_b", "interpret"))
def lstm_sequence_fwd_train(x, wx, wh, b, *, block_b: int = 128,
                            interpret: bool | None = None):
    """Residual-emitting forward for the custom VJP.  x: (B, T, F) ->
    (gates (B, T, 4H), c_seq (B, T, H), h_seq (B, T, H)), all f32; the primal
    output is ``h_seq[:, -1]``."""
    interpret = default_interpret() if interpret is None else interpret
    B, T, F = x.shape
    H = wh.shape[0]
    bb = min(block_b, B)
    grid = (pl.cdiv(B, bb),)
    return pl.pallas_call(
        _sequence_train_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, T, F), lambda i: (i, 0, 0)),
            pl.BlockSpec((F, 4 * H), lambda i: (0, 0)),
            pl.BlockSpec((H, 4 * H), lambda i: (0, 0)),
            pl.BlockSpec((4 * H,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bb, T, 4 * H), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, T, H), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, T, H), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, 4 * H), jnp.float32),
            jax.ShapeDtypeStruct((B, T, H), jnp.float32),
            jax.ShapeDtypeStruct((B, T, H), jnp.float32),
        ],
        interpret=interpret,
    )(x, wx, wh, b)


def _sequence_bwd_kernel(x_ref, gates_ref, c_ref, h_ref, wx_ref, wh_ref,
                         dh_ref, dc_ref, dx_out, dwx_out, dwh_out, db_out):
    """Reverse-time loop for one batch tile.  dx is written per tile; the
    weight gradients are *accumulated across the batch grid*: their output
    blocks are pinned to block 0, initialized on the first grid step, and
    read-modify-written on every later one (the TPU grid is sequential, so
    revisited output blocks persist — the standard reduction pattern).

    Batch padding rows are exactly zero in every input (the ops wrapper pads
    with zeros), which makes their dz — and hence their contribution to the
    accumulated weight gradients — exactly zero too."""
    x = x_ref[...].astype(jnp.float32)        # (bb, T, F)
    gates = gates_ref[...]                    # (bb, T, 4H) f32
    cs = c_ref[...]                           # (bb, T, H) f32
    hs = h_ref[...]                           # (bb, T, H) f32
    wx = wx_ref[...].astype(jnp.float32)
    wh = wh_ref[...].astype(jnp.float32)
    dh0 = dh_ref[...].astype(jnp.float32)     # (bb, H) cotangent of final h
    dc0 = dc_ref[...].astype(jnp.float32)     # (bb, H) cotangent of final c
    bb, T, F = x.shape
    H = wh.shape[0]

    def step(s, carry):
        dh, dc, dxs, dwx, dwh, db = carry
        t = T - 1 - s
        t_prev = jnp.maximum(t - 1, 0)
        g4 = jax.lax.dynamic_slice_in_dim(gates, t, 1, axis=1)[:, 0, :]
        i, f = g4[:, :H], g4[:, H : 2 * H]
        g, o = g4[:, 2 * H : 3 * H], g4[:, 3 * H :]
        c_t = jax.lax.dynamic_slice_in_dim(cs, t, 1, axis=1)[:, 0, :]
        first = (t == 0)
        c_prev = jnp.where(
            first, 0.0,
            jax.lax.dynamic_slice_in_dim(cs, t_prev, 1, axis=1)[:, 0, :])
        h_prev = jnp.where(
            first, 0.0,
            jax.lax.dynamic_slice_in_dim(hs, t_prev, 1, axis=1)[:, 0, :])

        tanh_c = jnp.tanh(c_t)
        do = dh * tanh_c
        dct = dc + dh * o * (1.0 - tanh_c * tanh_c)
        dz = jnp.concatenate(
            [dct * g * i * (1.0 - i),            # d z_i
             dct * c_prev * f * (1.0 - f),       # d z_f
             dct * i * (1.0 - g * g),            # d z_g
             do * o * (1.0 - o)],                # d z_o
            axis=-1)                             # (bb, 4H)

        x_t = jax.lax.dynamic_slice_in_dim(x, t, 1, axis=1)[:, 0, :]
        dwx = dwx + jnp.dot(x_t.T, dz, preferred_element_type=jnp.float32)
        dwh = dwh + jnp.dot(h_prev.T, dz, preferred_element_type=jnp.float32)
        db = db + jnp.sum(dz, axis=0)
        dx_t = jnp.dot(dz, wx.T, preferred_element_type=jnp.float32)
        dxs = jax.lax.dynamic_update_slice_in_dim(
            dxs, dx_t[:, None, :], t, axis=1)
        dh = jnp.dot(dz, wh.T, preferred_element_type=jnp.float32)
        dc = dct * f
        return dh, dc, dxs, dwx, dwh, db

    init = (
        dh0, dc0,
        jnp.zeros((bb, T, F), jnp.float32),
        jnp.zeros((F, 4 * H), jnp.float32),
        jnp.zeros((H, 4 * H), jnp.float32),
        jnp.zeros((4 * H,), jnp.float32),
    )
    _, _, dxs, dwx, dwh, db = jax.lax.fori_loop(0, T, step, init)
    dx_out[...] = dxs.astype(dx_out.dtype)

    @pl.when(pl.program_id(0) == 0)
    def _init_weight_grads():
        dwx_out[...] = jnp.zeros_like(dwx_out)
        dwh_out[...] = jnp.zeros_like(dwh_out)
        db_out[...] = jnp.zeros_like(db_out)

    dwx_out[...] += dwx
    dwh_out[...] += dwh
    db_out[...] += db


@partial(jax.jit, static_argnames=("block_b", "interpret"))
def lstm_sequence_bwd(x, gates, c_seq, h_seq, wx, wh, dh, dc, *,
                      block_b: int = 128, interpret: bool | None = None):
    """Fused backward pass over the whole sequence.

    Inputs are the primal ``x`` plus the residuals ``lstm_sequence_fwd_train``
    emitted and the cotangents of the final ``(h, c)``; returns
    ``(dx (B, T, F), dwx (F, 4H), dwh (H, 4H), db (4H,))``, all f32.  The
    batch is zero-padded to a tile multiple here so padded rows contribute
    exact zeros to the grid-accumulated weight gradients."""
    interpret = default_interpret() if interpret is None else interpret
    B, T, F = x.shape
    H = wh.shape[0]
    bb = min(block_b, B)
    pad = (-B) % bb
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0), (0, 0)))
        gates = jnp.pad(gates, ((0, pad), (0, 0), (0, 0)))
        c_seq = jnp.pad(c_seq, ((0, pad), (0, 0), (0, 0)))
        h_seq = jnp.pad(h_seq, ((0, pad), (0, 0), (0, 0)))
        dh = jnp.pad(dh, ((0, pad), (0, 0)))
        dc = jnp.pad(dc, ((0, pad), (0, 0)))
    Bp = B + pad
    grid = (Bp // bb,)
    dx, dwx, dwh, db = pl.pallas_call(
        _sequence_bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, T, F), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, T, 4 * H), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, T, H), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, T, H), lambda i: (i, 0, 0)),
            pl.BlockSpec((F, 4 * H), lambda i: (0, 0)),
            pl.BlockSpec((H, 4 * H), lambda i: (0, 0)),
            pl.BlockSpec((bb, H), lambda i: (i, 0)),
            pl.BlockSpec((bb, H), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, T, F), lambda i: (i, 0, 0)),
            pl.BlockSpec((F, 4 * H), lambda i: (0, 0)),   # accumulated
            pl.BlockSpec((H, 4 * H), lambda i: (0, 0)),   # accumulated
            pl.BlockSpec((4 * H,), lambda i: (0,)),       # accumulated
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, T, F), jnp.float32),
            jax.ShapeDtypeStruct((F, 4 * H), jnp.float32),
            jax.ShapeDtypeStruct((H, 4 * H), jnp.float32),
            jax.ShapeDtypeStruct((4 * H,), jnp.float32),
        ],
        interpret=interpret,
    )(x, gates, c_seq, h_seq, wx, wh, dh, dc)
    return dx[:B], dwx, dwh, db
