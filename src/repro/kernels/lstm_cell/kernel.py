"""Fused LSTM kernels: one step (``lstm_cell``) and a whole sequence
(``lstm_sequence_fused``).

The paper's speed layer re-trains a small LSTM inside every 30 s window, so
the recurrence is the latency-critical inner loop.  On TPU the win is fusing
the two matmuls (x@Wx + h@Wh -> one (B, 4H) gate pre-activation) with the
gate nonlinearities and state update in one VMEM-resident kernel: the
weights (F+H, 4H) stay in VMEM and the (B, 4H) intermediate never
round-trips to HBM.

``lstm_cell`` fuses one timestep.  Scanning it over time (the old
``ops.lstm_sequence``) still paid one kernel launch per step and re-staged
the weights every launch.  ``lstm_sequence_fused`` moves the time loop
*inside* a single ``pallas_call``: the (bb, T, F) input block and both
weight blocks are resident for all T steps, the h/c carry lives in
registers/VMEM, and only the final state is written out — one launch per
batch tile for the whole sequence.

Tiling: grid over batch tiles; weights are broadcast blocks (index_map pins
them to block 0).  MXU alignment: for the paper model (H=40, F=5, T=5) the
shapes are tiny and the kernel is bandwidth-trivial; for wider LSTMs choose
block_b and H multiples of the 8x128 lanes.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import default_interpret


def _gates(z, h_dim, c):
    i = jax.nn.sigmoid(z[:, :h_dim])
    f = jax.nn.sigmoid(z[:, h_dim : 2 * h_dim])
    g = jnp.tanh(z[:, 2 * h_dim : 3 * h_dim])
    o = jax.nn.sigmoid(z[:, 3 * h_dim :])
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new


def _cell_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref, h_out, c_out):
    x = x_ref[...].astype(jnp.float32)
    h = h_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    wx = wx_ref[...].astype(jnp.float32)
    wh = wh_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)

    z = jnp.dot(x, wx, preferred_element_type=jnp.float32)
    z = z + jnp.dot(h, wh, preferred_element_type=jnp.float32) + b[None, :]
    h_new, c_new = _gates(z, h.shape[-1], c)
    h_out[...] = h_new.astype(h_out.dtype)
    c_out[...] = c_new.astype(c_out.dtype)


@partial(jax.jit, static_argnames=("block_b", "interpret"))
def lstm_cell(x, h, c, wx, wh, b, *, block_b: int = 128,
              interpret: bool | None = None):
    """One fused LSTM step.  x: (B, F); h, c: (B, H) -> (h', c').

    ``interpret=None`` resolves via ``repro.kernels.default_interpret()``:
    compiled Mosaic on a real TPU backend, interpreter elsewhere."""
    interpret = default_interpret() if interpret is None else interpret
    B, F = x.shape
    H = h.shape[-1]
    bb = min(block_b, B)
    grid = (pl.cdiv(B, bb),)
    return pl.pallas_call(
        _cell_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, F), lambda i: (i, 0)),
            pl.BlockSpec((bb, H), lambda i: (i, 0)),
            pl.BlockSpec((bb, H), lambda i: (i, 0)),
            pl.BlockSpec((F, 4 * H), lambda i: (0, 0)),  # weights: broadcast
            pl.BlockSpec((H, 4 * H), lambda i: (0, 0)),
            pl.BlockSpec((4 * H,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bb, H), lambda i: (i, 0)),
            pl.BlockSpec((bb, H), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H), h.dtype),
            jax.ShapeDtypeStruct((B, H), c.dtype),
        ],
        interpret=interpret,
    )(x, h, c, wx, wh, b)


def _sequence_kernel(x_ref, wx_ref, wh_ref, b_ref, h_out, c_out):
    """Whole-sequence LSTM for one batch tile: time loop inside the kernel,
    weights read once and VMEM-resident across all T steps."""
    x = x_ref[...].astype(jnp.float32)        # (bb, T, F)
    wx = wx_ref[...].astype(jnp.float32)      # (F, 4H)
    wh = wh_ref[...].astype(jnp.float32)      # (H, 4H)
    b = b_ref[...].astype(jnp.float32)        # (4H,)
    bb, T, _ = x.shape
    H = wh.shape[0]

    def step(t, carry):
        h, c = carry
        x_t = jax.lax.dynamic_slice_in_dim(x, t, 1, axis=1)[:, 0, :]
        z = jnp.dot(x_t, wx, preferred_element_type=jnp.float32)
        z = z + jnp.dot(h, wh, preferred_element_type=jnp.float32) + b[None, :]
        return _gates(z, H, c)

    h0 = jnp.zeros((bb, H), jnp.float32)
    c0 = jnp.zeros((bb, H), jnp.float32)
    h, c = jax.lax.fori_loop(0, T, step, (h0, c0))
    h_out[...] = h.astype(h_out.dtype)
    c_out[...] = c.astype(c_out.dtype)


@partial(jax.jit, static_argnames=("block_b", "interpret"))
def lstm_sequence_fused(x, wx, wh, b, *, block_b: int = 128,
                        interpret: bool | None = None):
    """Fused full-sequence LSTM.  x: (B, T, F) -> final (h, c), each (B, H).

    One ``pallas_call`` per batch-tile grid step covers all T timesteps —
    versus T launches (and T weight re-stagings) for the scanned per-cell
    kernel this replaces."""
    interpret = default_interpret() if interpret is None else interpret
    B, T, F = x.shape
    H = wh.shape[0]
    bb = min(block_b, B)
    grid = (pl.cdiv(B, bb),)
    return pl.pallas_call(
        _sequence_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, T, F), lambda i: (i, 0, 0)),
            pl.BlockSpec((F, 4 * H), lambda i: (0, 0)),  # weights: broadcast
            pl.BlockSpec((H, 4 * H), lambda i: (0, 0)),
            pl.BlockSpec((4 * H,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bb, H), lambda i: (i, 0)),
            pl.BlockSpec((bb, H), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H), x.dtype),
            jax.ShapeDtypeStruct((B, H), x.dtype),
        ],
        interpret=interpret,
    )(x, wx, wh, b)
