"""Fused LSTM cell kernel.

The paper's speed layer re-trains a small LSTM inside every 30 s window, so
the per-step cell is the latency-critical inner loop.  On TPU the win is
fusing the two matmuls (x@Wx + h@Wh -> one (B, 4H) gate pre-activation) with
the gate nonlinearities and state update in one VMEM-resident kernel: the
weights (F+H, 4H) stay in VMEM across the time scan and the (B, 4H)
intermediate never round-trips to HBM.

Tiling: grid over batch tiles; weights are broadcast blocks (index_map pins
them to block 0).  MXU alignment: for the paper model (H=40, F=5) the shapes
are tiny and the kernel is bandwidth-trivial; for wider LSTMs choose
block_b and H multiples of 8x128 lanes.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cell_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref, h_out, c_out):
    x = x_ref[...].astype(jnp.float32)
    h = h_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    wx = wx_ref[...].astype(jnp.float32)
    wh = wh_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)

    z = jnp.dot(x, wx, preferred_element_type=jnp.float32)
    z = z + jnp.dot(h, wh, preferred_element_type=jnp.float32) + b[None, :]
    H = h.shape[-1]
    i = jax.nn.sigmoid(z[:, :H])
    f = jax.nn.sigmoid(z[:, H : 2 * H])
    g = jnp.tanh(z[:, 2 * H : 3 * H])
    o = jax.nn.sigmoid(z[:, 3 * H :])
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    h_out[...] = h_new.astype(h_out.dtype)
    c_out[...] = c_new.astype(c_out.dtype)


@partial(jax.jit, static_argnames=("block_b", "interpret"))
def lstm_cell(x, h, c, wx, wh, b, *, block_b: int = 128, interpret: bool = True):
    """One fused LSTM step.  x: (B, F); h, c: (B, H) -> (h', c')."""
    B, F = x.shape
    H = h.shape[-1]
    bb = min(block_b, B)
    grid = (pl.cdiv(B, bb),)
    return pl.pallas_call(
        _cell_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, F), lambda i: (i, 0)),
            pl.BlockSpec((bb, H), lambda i: (i, 0)),
            pl.BlockSpec((bb, H), lambda i: (i, 0)),
            pl.BlockSpec((F, 4 * H), lambda i: (0, 0)),  # weights: broadcast
            pl.BlockSpec((H, 4 * H), lambda i: (0, 0)),
            pl.BlockSpec((4 * H,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bb, H), lambda i: (i, 0)),
            pl.BlockSpec((bb, H), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H), h.dtype),
            jax.ShapeDtypeStruct((B, H), c.dtype),
        ],
        interpret=interpret,
    )(x, h, c, wx, wh, b)
