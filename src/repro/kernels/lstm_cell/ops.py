"""jit'd public ops for the fused LSTM kernels."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.lstm_cell.kernel import lstm_cell, lstm_sequence_fused


def lstm_step(x_t, h, c, wx, wh, b, interpret: bool | None = None):
    interp = default_interpret() if interpret is None else interpret
    return lstm_cell(x_t, h, c, wx, wh, b, interpret=interp)


@partial(jax.jit, static_argnames=("interpret",))
def lstm_sequence(x, wx, wh, b, interpret: bool | None = None):
    """x: (B, T, F) -> final hidden (B, H).

    One fused-sequence ``pallas_call`` per batch tile: the time loop runs
    inside the kernel with the (F+H, 4H) weights VMEM-resident across all T
    steps, replacing the per-timestep kernel-launch scan."""
    interp = default_interpret() if interpret is None else interpret
    h, _ = lstm_sequence_fused(x, wx, wh, b, interpret=interp)
    return h


@partial(jax.jit, static_argnames=("interpret",))
def lstm_sequence_scan(x, wx, wh, b, interpret: bool | None = None):
    """The pre-fusion path — ``lax.scan`` over the per-step cell kernel (one
    launch per timestep).  Kept as the launch-overhead baseline the kernel
    tests and benchmarks compare the fused path against."""
    interp = default_interpret() if interpret is None else interpret
    B = x.shape[0]
    H = wh.shape[0]
    h = jnp.zeros((B, H), x.dtype)
    c = jnp.zeros((B, H), x.dtype)

    def step(carry, xt):
        h, c = carry
        h, c = lstm_cell(xt, h, c, wx, wh, b, interpret=interp)
        return (h, c), None

    (h, c), _ = jax.lax.scan(step, (h, c), x.transpose(1, 0, 2))
    return h
