"""jit'd public ops for the fused LSTM kernels.

``lstm_sequence`` is the entry point the model layer rides
(``repro.models.lstm.forward`` with ``cfg.use_pallas``): fused-sequence
forward, and — via ``jax.custom_vjp`` — a fused Pallas backward, so both
inference *and* the speed layer's cached train step
(``repro.training.compiled.CompiledForecaster``) run kernels end to end.
``lstm_sequence_scan`` is the pre-fusion baseline kept for benchmarks and
the gradient-equivalence oracle tests.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.lstm_cell.kernel import (
    lstm_cell,
    lstm_sequence_bwd,
    lstm_sequence_fused,
    lstm_sequence_fwd_train,
)


def lstm_step(x_t, h, c, wx, wh, b, interpret: bool | None = None):
    interp = default_interpret() if interpret is None else interpret
    return lstm_cell(x_t, h, c, wx, wh, b, interpret=interp)


# ---------------------------------------------------------------------------
# lstm_sequence: fused forward + fused backward under one custom VJP
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(4,))
def _lstm_sequence(x, wx, wh, b, interpret):
    h, _ = lstm_sequence_fused(x, wx, wh, b, interpret=interpret)
    return h


def _lstm_sequence_fwd(x, wx, wh, b, interpret):
    """Differentiated forward: the residual-emitting kernel.  Residuals are
    the post-activation gates and the full c/h sequences (all f32), so the
    backward kernel reconstructs the recurrence without re-running any
    matmul."""
    gates, c_seq, h_seq = lstm_sequence_fwd_train(x, wx, wh, b,
                                                  interpret=interpret)
    h = h_seq[:, -1].astype(x.dtype)
    return h, (x, gates, c_seq, h_seq, wx, wh, b)


def _lstm_sequence_bwd(interpret, res, dh):
    x, gates, c_seq, h_seq, wx, wh, b = res
    dc = jnp.zeros_like(dh, dtype=jnp.float32)  # only final h is a primal out
    dx, dwx, dwh, db = lstm_sequence_bwd(
        x, gates, c_seq, h_seq, wx, wh, dh.astype(jnp.float32), dc,
        interpret=interpret)
    return (dx.astype(x.dtype), dwx.astype(wx.dtype), dwh.astype(wh.dtype),
            db.astype(b.dtype))


_lstm_sequence.defvjp(_lstm_sequence_fwd, _lstm_sequence_bwd)


@partial(jax.jit, static_argnames=("interpret",))
def lstm_sequence(x, wx, wh, b, interpret: bool | None = None):
    """Fused full-sequence LSTM: x (B, T, F) -> final hidden (B, H).

    Shapes/dtypes: ``x`` is (batch, time, features) in f32 or bf16; ``wx`` is
    (F, 4H), ``wh`` (H, 4H), ``b`` (4H,) with Keras gate order (i, f, g, o);
    the result is (B, H) in ``x.dtype`` (compute is f32 inside the kernel).

    Forward: one fused-sequence ``pallas_call`` per batch tile — the time
    loop runs inside the kernel with the (F+H, 4H) weights VMEM-resident
    across all T steps, replacing the per-timestep kernel-launch scan.

    Backward: a ``jax.custom_vjp`` pairing ``lstm_sequence_fwd_train`` (same
    fused forward, additionally emitting gate/state residuals) with the
    fused reverse-time kernel ``lstm_sequence_bwd`` — so differentiating
    through this op (the speed layer's per-window train step) also runs one
    kernel launch per batch tile instead of autodiff-through-scan, which
    would not lower through a compiled Mosaic ``pallas_call`` at all.
    Gradients match autodiff through ``lstm_sequence_scan`` to f32 tolerance
    (oracle test in ``tests/test_kernels.py``).

    ``interpret=None`` resolves via ``repro.kernels.default_interpret()``:
    compiled Mosaic on a real TPU backend, the Pallas interpreter (kernel
    body as traced jnp on the host backend) elsewhere — semantics are
    identical, so CPU CI validates the exact TPU code path.

    Callers: ``repro.models.lstm.forward`` (``use_pallas``), and through it
    the compiled speed-layer hot path and both executors; benchmarked by
    ``benchmarks/bench_hotpath.py``.
    """
    interp = default_interpret() if interpret is None else interpret
    return _lstm_sequence(x, wx, wh, b, interp)


@partial(jax.jit, static_argnames=("interpret",))
def lstm_sequence_scan(x, wx, wh, b, interpret: bool | None = None):
    """The pre-fusion path — ``lax.scan`` over the per-step cell kernel (one
    launch per timestep).  Kept as the launch-overhead baseline the kernel
    tests and benchmarks compare the fused path against; its autodiff (in
    interpret mode) is also the gradient oracle the fused custom VJP is
    asserted against."""
    interp = default_interpret() if interpret is None else interpret
    B = x.shape[0]
    H = wh.shape[0]
    h = jnp.zeros((B, H), x.dtype)
    c = jnp.zeros((B, H), x.dtype)

    def step(carry, xt):
        h, c = carry
        h, c = lstm_cell(xt, h, c, wx, wh, b, interpret=interp)
        return (h, c), None

    (h, c), _ = jax.lax.scan(step, (h, c), x.transpose(1, 0, 2))
    return h
