"""Public op: GQA-aware flash attention in the model zoo's (B, S, H, D)
layout, dispatching to the Pallas kernel (TPU) or interpret mode (CPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.flash_attention.kernel import flash_attention


def gqa_flash(q, k, v, *, causal=True, window=0, interpret=None):
    """q: (B, S, Hq, D); k, v: (B, S, Hkv, D) -> (B, S, Hq, D).
    KV heads are repeated to Q heads (the kernel is MHA-layout)."""
    interp = default_interpret() if interpret is None else interpret
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    out = flash_attention(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=causal,
        window=window,
        interpret=interp,
    )
    return out.transpose(0, 2, 1, 3)
