"""Flash attention (TPU Pallas), causal with optional sliding window.

TPU adaptation of the flash algorithm: Q blocks ride the grid's parallel
dims, the KV loop is a ``fori_loop`` inside the kernel with running
(max, sum, acc) statistics held in f32 — the (Sq, Sk) score matrix never
exists.  Block shapes default to (128, head_dim): 128 is the MXU systolic
edge, and a (128, D) x (D, 128) product per step keeps the MXU fed while the
(block_q, D) accumulator stays in VREGs/VMEM.

Causality + sliding window are handled at *block granularity* first (skipped
blocks cost one predicate, no compute) and at element granularity inside
surviving blocks via the position mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, seq_k: int,
               causal: bool, window: int, scale: float, block_q: int):
    qi = pl.program_id(1)  # q block index
    q = q_ref[0].astype(jnp.float32) * scale  # (bq, D)
    D = q.shape[-1]
    n_kv = pl.cdiv(seq_k, block_k)

    q_start = qi * block_q
    q_pos = q_start + jax.lax.iota(jnp.int32, block_q)

    def body(j, carry):
        m, l, acc = carry
        k = k_ref[0, pl.dslice(j * block_k, block_k), :]
        v = v_ref[0, pl.dslice(j * block_k, block_k), :]
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
        kv_pos = j * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = kv_pos[None, :] < seq_k
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        if window > 0:
            mask = mask & ((q_pos[:, None] - kv_pos[None, :]) < window)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.exp(s - m_safe[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.where(m <= NEG_INF / 2, 0.0, jnp.exp(m - m_safe))
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc_new

    # block-level skipping: causal -> only blocks with kv_start <= q_end;
    # window  -> only blocks with kv_end > q_start - window
    if causal:
        hi = jnp.minimum(n_kv, (q_start + block_q + block_k - 1) // block_k)
    else:
        hi = n_kv
    if window > 0:
        lo = jnp.maximum(0, (q_start - window + 1) // block_k)
    else:
        lo = 0

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    a0 = jnp.zeros((block_q, D), jnp.float32)
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, H, Sk, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    # pad sequences to block multiples: dynamic_slice clamps OOB starts, so
    # ragged tails must be materialized as zero padding (masked via seq_k)
    pq = (-Sq) % bq
    pk = (-Sk) % bk
    qf = q.reshape(B * H, Sq, D)
    kf = k.reshape(B * H, Sk, D)
    vf = v.reshape(B * H, Sk, D)
    if pq:
        qf = jnp.pad(qf, ((0, 0), (0, pq), (0, 0)))
    if pk:
        kf = jnp.pad(kf, ((0, 0), (0, pk), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pk), (0, 0)))
    Sq_p, Sk_p = Sq + pq, Sk + pk
    grid = (B * H, pl.cdiv(Sq_p, bq))
    kern = functools.partial(
        _fa_kernel,
        block_k=bk,
        seq_k=Sk,
        causal=causal,
        window=window,
        scale=D**-0.5,
        block_q=bq,
    )
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, i: (bh, i, 0)),
            pl.BlockSpec((1, Sk_p, D), lambda bh, i: (bh, 0, 0)),
            pl.BlockSpec((1, Sk_p, D), lambda bh, i: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, i: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq_p, D), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out[:, :Sq].reshape(B, H, Sq, D)
