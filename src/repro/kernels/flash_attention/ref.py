"""Pure-jnp oracle for flash attention (materializes the score matrix)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=0):
    """q,k,v: (B, H, S, D) MHA layout."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (D**-0.5)
    q_pos = jnp.arange(Sq)[:, None]
    kv_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (kv_pos <= q_pos)
    if window > 0:
        mask = mask & ((q_pos - kv_pos) < window)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[None, None], p, 0.0)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
