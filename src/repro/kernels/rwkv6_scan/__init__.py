from repro.kernels.rwkv6_scan import kernel, ops, ref  # noqa: F401
