"""Public op: model-zoo layout wrapper for the RWKV6 WKV kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.rwkv6_scan.kernel import rwkv6_scan


def wkv(r, k, v, w, u, *, chunk: int = 128, interpret=None):
    """r,k,v,w: (B, T, H, N); u: (H, N) -> (y (B,T,H,N), state (B,H,N,N))."""
    interp = default_interpret() if interpret is None else interpret
    B, T, H, N = r.shape

    def flat(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, T, N)

    u_full = jnp.broadcast_to(u[None], (B, H, N)).reshape(B * H, N)
    y, s = rwkv6_scan(flat(r), flat(k), flat(v), flat(w), u_full,
                      chunk=chunk, interpret=interp)
    y = y.reshape(B, H, T, N).transpose(0, 2, 1, 3)
    return y, s.reshape(B, H, N, N)
