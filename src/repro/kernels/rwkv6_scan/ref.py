"""Pure-jnp oracle for the RWKV6 WKV scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_scan_ref(r, k, v, w, u, state0=None):
    """r,k,v,w: (BH, T, N); u: (BH, N).  Returns (y, final_state)."""
    BH, T, N = r.shape
    S0 = jnp.zeros((BH, N, N), jnp.float32) if state0 is None else state0

    def step(S, xs):
        rt, kt, vt, wt = xs  # (BH, N)
        kv = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bn,bnm->bm", rt, S + u[..., None] * kv)
        S = wt[..., None] * S + kv
        return S, y

    xs = tuple(
        a.transpose(1, 0, 2).astype(jnp.float32) for a in (r, k, v, w)
    )
    S, ys = jax.lax.scan(step, S0, xs)
    return ys.transpose(1, 0, 2).astype(r.dtype), S
