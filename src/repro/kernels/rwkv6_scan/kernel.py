"""RWKV6 WKV scan kernel (data-dependent decay) — TPU Pallas.

Recurrence per (batch, head), head size N:

    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

TPU adaptation of the (GPU, warp-per-head) reference kernels: the (N, N)
state lives in a VMEM scratch that persists across the *sequential* chunk
grid dimension; each grid step streams one (chunk, N) tile of r/k/v/w through
VMEM and steps the recurrence with rank-1 updates.  Head-parallelism rides
the first (parallel) grid dim instead of warps; N=64 keeps the state tile at
one 64x64 f32 block, VREG-friendly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_out, state,
                *, chunk: int, n_chunks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        state[...] = jnp.zeros_like(state)

    r = r_ref[0].astype(jnp.float32)  # (chunk, N)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)  # (N,)

    def body(t, carry):
        S, y = carry
        kt, vt, rt, wt = k[t], v[t], r[t], w[t]  # (N,)
        kv = kt[:, None] * vt[None, :]  # (N, N)
        yt = rt @ (S + u[:, None] * kv)  # (N,)
        S = wt[:, None] * S + kv
        y = jax.lax.dynamic_update_slice(y, yt[None], (t, 0))
        return S, y

    S0 = state[...]
    y0 = jnp.zeros((chunk, k.shape[-1]), jnp.float32)
    S, y = jax.lax.fori_loop(0, chunk, body, (S0, y0))
    state[...] = S
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(j == n_chunks - 1)
    def _():
        s_out[0] = S.astype(s_out.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(
    r: jax.Array,  # (BH, T, N)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,  # decays in (0,1)
    u: jax.Array,  # (BH, N) bonus
    *,
    chunk: int = 128,
    interpret: bool = True,
):
    """Returns (y (BH, T, N) f32-accurate, final state (BH, N, N) f32)."""
    BH, T, N = r.shape
    ct = min(chunk, T)
    pad = (-T) % ct
    if pad:
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
        # pad decay with ones so padded steps keep the state unchanged
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
    Tp = T + pad
    n_chunks = Tp // ct
    kern = functools.partial(_wkv_kernel, chunk=ct, n_chunks=n_chunks)
    y, s = pl.pallas_call(
        kern,
        grid=(BH, n_chunks),
        in_specs=[
            pl.BlockSpec((1, ct, N), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, ct, N), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, ct, N), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, ct, N), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, N), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, ct, N), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, N, N), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Tp, N), r.dtype),
            jax.ShapeDtypeStruct((BH, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return y[:, :T], s
