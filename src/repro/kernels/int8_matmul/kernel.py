"""Int8 weight-dequantizing matmul — TPU Pallas.

The quantized edge-serving path (repro.serving.quantize, the TFLite-on-Pi
analog) computes y = x @ (q * scale) with q int8 and a per-output-channel
f32 scale.  Fusing the dequantization into the matmul halves (vs bf16) /
quarters (vs f32) the weight HBM traffic — the dominant cost of small-batch
edge inference — and applies the scale once per output column after the
K-loop instead of once per weight.

Tiling: (block_m, block_n) output tiles on a parallel grid; the K dimension
streams through VMEM in block_k slices inside a fori_loop with an f32
accumulator.  int8 weights are converted to f32 in VREGs right before the
MXU dot (TPU int8 MXU paths need quantized activations too; weight-only
quantization keeps activations f32/bf16, which is what the forecaster
accuracy test pins).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, q_ref, s_ref, o_ref, *, block_k: int, n_k: int):
    x = x_ref[...]  # (bm, K)
    q = q_ref[...]  # (K, bn) int8
    s = s_ref[...]  # (bn,) f32

    def body(i, acc):
        xs = jax.lax.dynamic_slice_in_dim(x, i * block_k, block_k, axis=1)
        qs = jax.lax.dynamic_slice_in_dim(q, i * block_k, block_k, axis=0)
        return acc + jnp.dot(
            xs.astype(jnp.float32), qs.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )

    acc0 = jnp.zeros((x.shape[0], q.shape[1]), jnp.float32)
    acc = jax.lax.fori_loop(0, n_k, body, acc0)
    o_ref[...] = (acc * s[None, :]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def int8_matmul(
    x: jax.Array,  # (M, K) float
    q: jax.Array,  # (K, N) int8
    scale: jax.Array,  # (N,) f32
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    M, K = x.shape
    K2, N = q.shape
    assert K == K2 and scale.shape == (N,)
    bm, bn = min(block_m, M), min(block_n, N)
    bk = min(block_k, K)
    # pad every dim to its block multiple (zero padding is exact here)
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pn or pk:
        q = jnp.pad(q, ((0, pk), (0, pn)))
    if pn:
        scale = jnp.pad(scale, (0, pn))
    Mp, Kp, Np = M + pm, K + pk, N + pn
    grid = (Mp // bm, Np // bn)
    out = pl.pallas_call(
        functools.partial(_kernel, block_k=bk, n_k=Kp // bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, Kp), lambda i, j: (i, 0)),
            pl.BlockSpec((Kp, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        interpret=interpret,
    )(x, q, scale)
    return out[:M, :N]
