from repro.kernels.int8_matmul import kernel, ops, ref  # noqa: F401
