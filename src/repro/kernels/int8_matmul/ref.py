"""Pure-jnp oracle for the int8 dequant matmul."""
from __future__ import annotations

import jax.numpy as jnp


def int8_matmul_ref(x, q, scale):
    w = q.astype(jnp.float32) * scale[None, :]
    return (x.astype(jnp.float32) @ w).astype(x.dtype)
