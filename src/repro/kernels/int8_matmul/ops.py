"""Public op: QTensor-aware int8 matmul dispatching to the Pallas kernel."""
from __future__ import annotations

from repro.kernels import default_interpret
from repro.kernels.int8_matmul.kernel import int8_matmul as _kernel_mm
from repro.serving.quantize import QTensor


def qmatmul(x, qt: QTensor, interpret=None):
    """``x @ dequant(qt)`` via the fused int8 dequant-matmul kernel.

    Shapes/dtypes: ``x`` is (..., K) float (f32 or bf16); ``qt`` wraps an
    int8 weight matrix (K, N) with a per-output-channel f32 scale (N,); the
    result is (..., N) in ``x.dtype``.  Leading dims are flattened to one M
    axis for the kernel's (block_m, block_n) output tiling and restored
    after.  The scale multiplies the f32 accumulator once per output column
    after the K loop — never per weight — and weights stay int8 all the way
    into VMEM, quartering (vs f32) the weight HBM traffic that dominates
    small-batch edge inference.

    ``interpret=None`` resolves via ``repro.kernels.default_interpret()``:
    compiled Mosaic on a real TPU backend, the Pallas interpreter elsewhere,
    so CPU CI validates the exact TPU code path.

    Callers: ``repro.models.lstm._forward_int8`` — edge inference on an
    int8-synced speed model (``BusExecutor(quantized_sync=True)``, the
    paper's TFLite-on-Pi analog) — and the int8-inference timings in
    ``benchmarks/bench_hotpath.py``.
    """
    interp = default_interpret() if interpret is None else interpret
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = _kernel_mm(x2, qt.q, qt.scale.reshape(-1), interpret=interp)
    return y.reshape(*lead, -1)
