"""Public op: QTensor-aware int8 matmul dispatching to the Pallas kernel."""
from __future__ import annotations

from repro.kernels import default_interpret
from repro.kernels.int8_matmul.kernel import int8_matmul as _kernel_mm
from repro.serving.quantize import QTensor


def qmatmul(x, qt: QTensor, interpret=None):
    """x: (..., K) @ qt -> (..., N) via the fused dequant kernel."""
    interp = default_interpret() if interpret is None else interpret
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = _kernel_mm(x2, qt.q, qt.scale.reshape(-1), interpret=interp)
    return y.reshape(*lead, -1)
