"""Mamba2 selective-state scan kernel — TPU Pallas.

Per (batch, head) with head dim P and state dim N, scalar decay A per head:

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * (x_t outer B_t)
    y_t = h_t @ C_t + D_head * x_t

TPU adaptation of the Mamba2 SSD chunked algorithm: instead of the GPU's
warp-specialized chunk-state matmuls, the (P, N) state persists in VMEM
scratch across the sequential chunk grid dim, with the per-chunk work done as
rank-1 updates in VREGs.  P=64, N=64 keeps the state one (64, 64) f32 tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(x_ref, b_ref, c_ref, dt_ref, a_ref, d_ref, y_ref, s_out,
                state, *, chunk: int, n_chunks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        state[...] = jnp.zeros_like(state)

    x = x_ref[0].astype(jnp.float32)  # (chunk, P)
    b = b_ref[0].astype(jnp.float32)  # (chunk, N)
    c = c_ref[0].astype(jnp.float32)  # (chunk, N)
    dt = dt_ref[0].astype(jnp.float32)  # (chunk,)
    a = a_ref[0][0].astype(jnp.float32)  # scalar A (negative)
    dsk = d_ref[0][0].astype(jnp.float32)  # scalar skip D

    def body(t, carry):
        h, y = carry
        decay = jnp.exp(dt[t] * a)
        upd = (dt[t] * x[t])[:, None] * b[t][None, :]  # (P, N)
        h = decay * h + upd
        yt = h @ c[t] + dsk * x[t]  # (P,)
        y = jax.lax.dynamic_update_slice(y, yt[None], (t, 0))
        return h, y

    h0 = state[...]
    y0 = jnp.zeros_like(x)
    h, y = jax.lax.fori_loop(0, chunk, body, (h0, y0))
    state[...] = h
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(j == n_chunks - 1)
    def _():
        s_out[0] = h.astype(s_out.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan(
    x: jax.Array,  # (BH, T, P)
    b: jax.Array,  # (BH, T, N)
    c: jax.Array,  # (BH, T, N)
    dt: jax.Array,  # (BH, T) positive
    a: jax.Array,  # (BH,) negative scalars
    d: jax.Array,  # (BH,) skip weights
    *,
    chunk: int = 128,
    interpret: bool = True,
):
    """Returns (y (BH, T, P), final state (BH, P, N) f32)."""
    BH, T, P = x.shape
    N = b.shape[-1]
    ct = min(chunk, T)
    pad = (-T) % ct
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)))  # dt=0 -> decay 1, no update
    Tp = T + pad
    n_chunks = Tp // ct
    kern = functools.partial(_ssm_kernel, chunk=ct, n_chunks=n_chunks)
    y, s = pl.pallas_call(
        kern,
        grid=(BH, n_chunks),
        in_specs=[
            pl.BlockSpec((1, ct, P), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, ct, N), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, ct, N), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, ct), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, ct, P), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, P, N), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Tp, P), x.dtype),
            jax.ShapeDtypeStruct((BH, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, b, c, dt, a[:, None], d[:, None])
    return y[:, :T], s
