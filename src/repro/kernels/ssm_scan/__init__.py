from repro.kernels.ssm_scan import kernel, ops, ref  # noqa: F401
