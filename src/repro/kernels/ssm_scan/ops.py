"""Public op: model-zoo layout wrapper for the Mamba2 scan kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.ssm_scan.kernel import ssm_scan


def selective_scan(x, b, c, dt, a, d, *, chunk: int = 128, interpret=None):
    """x: (B,T,H,P); b,c: (B,T,N); dt: (B,T,H); a,d: (H,).
    Returns (y (B,T,H,P), state (B,H,P,N))."""
    interp = default_interpret() if interpret is None else interpret
    B, T, H, P = x.shape
    N = b.shape[-1]
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, T, P)
    bf = jnp.broadcast_to(b[:, None], (B, H, T, N)).reshape(B * H, T, N)
    cf = jnp.broadcast_to(c[:, None], (B, H, T, N)).reshape(B * H, T, N)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, T)
    af = jnp.broadcast_to(a[None], (B, H)).reshape(B * H)
    df = jnp.broadcast_to(d[None], (B, H)).reshape(B * H)
    y, s = ssm_scan(xf, bf, cf, dtf, af, df, chunk=chunk, interpret=interp)
    return (
        y.reshape(B, H, T, P).transpose(0, 2, 1, 3),
        s.reshape(B, H, P, N),
    )
