"""Pure-jnp oracle for the Mamba2 selective-state scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(x, b, c, dt, a, d, state0=None):
    """x: (BH,T,P); b,c: (BH,T,N); dt: (BH,T); a,d: (BH,).
    Returns (y (BH,T,P), final state (BH,P,N))."""
    BH, T, P = x.shape
    N = b.shape[-1]
    h0 = jnp.zeros((BH, P, N), jnp.float32) if state0 is None else state0

    def step(h, xs):
        xt, bt, ct, dtt = xs  # (BH,P), (BH,N), (BH,N), (BH,)
        decay = jnp.exp(dtt * a)  # (BH,)
        upd = (dtt[:, None] * xt)[..., None] * bt[:, None, :]
        h = decay[:, None, None] * h + upd
        y = jnp.einsum("bpn,bn->bp", h, ct) + d[:, None] * xt
        return h, y

    xs = (
        x.transpose(1, 0, 2).astype(jnp.float32),
        b.transpose(1, 0, 2).astype(jnp.float32),
        c.transpose(1, 0, 2).astype(jnp.float32),
        dt.transpose(1, 0).astype(jnp.float32),
    )
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2).astype(x.dtype), h
