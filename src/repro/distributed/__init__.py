from repro.distributed.sharding import (  # noqa: F401
    AxisRules,
    DEFAULT_RULES,
    PARAM_AXES,
    logical_to_spec,
    param_axes_for,
    param_shardings,
    shard,
    use_mesh_rules,
)
