"""Logical-axis sharding with divisibility-aware fallback.

Two planes:

* **Activations** — models call ``shard(x, "batch", "seq", "embed")`` at key
  points; inside a ``use_mesh_rules(mesh, rules)`` context this becomes a GSPMD
  sharding constraint, otherwise it is a no-op (so the same model code runs on
  one CPU device in tests).

* **Parameters** — ``param_shardings(params, mesh, rules)`` derives a
  ``NamedSharding`` pytree from parameter *names* via the ``PARAM_AXES`` table
  (every parameter in the model zoo has a registered leaf name).  ``fsdp``
  maps to the (pod, data) axes — ZeRO-3-style weight sharding, a beyond-paper
  necessity for the trillion-parameter config; ``tp`` maps to the model axis.

Resolution handles the assigned archs' awkward dimensions: a logical axis is
dropped (replicated) when the dim is not divisible by the mesh axes, and a
mesh axis is never used twice in one spec (first dim wins) — e.g. grok-1's 8
experts cannot split a 16-way model axis, so experts replicate and the expert
FFN keeps tensor parallelism; kimi-k2's 384 experts take the model axis and
its tiny per-expert FFN stays unsharded.

* **The stream mesh** — the fleet hot path (``training/compiled.py``)
  stacks S independent streams along a leading axis and shards it across
  the local devices: pure data parallelism, bitwise-identical per-stream
  numerics.  ``stream_mesh(sb)`` builds the 1-D mesh (capped at the
  largest power-of-two divisor of the stream bucket, so a 2-stream bucket
  on an 8-device host gets a 2-device mesh rather than an indivisible
  sharding), ``stream_sharding(sb)`` resolves the stacked-batch spec
  through the same divisibility-aware ``logical_to_spec``, and
  ``fleet_param_shardings`` derives the stacked params/opt-state specs
  leaf-wise (leading ``stream`` axis sharded, per-stream LSTM leaves
  replicated per ``PARAM_AXES``).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical name -> preferred mesh axis names (in priority order, used jointly
# when all divide, else greedily)
Rules = Dict[str, Tuple[str, ...]]

DEFAULT_RULES: Rules = {
    "batch": ("pod", "data"),
    "fsdp": ("pod", "data"),
    "tp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ffn": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "seq": (),  # sequence unsharded by default; hillclimb may override
    "embed": (),
    "stack": (),  # scan-stacked layer dim
    "state": (),
    # the fleet's stacked stream axis (training/compiled.py): independent
    # streams, sharded data-parallel over the 1-D stream mesh
    "stream": ("stream",),
}


@dataclass
class AxisRules:
    rules: Rules = field(default_factory=lambda: dict(DEFAULT_RULES))

    def resolve(self, name: Optional[str]) -> Tuple[str, ...]:
        if name is None:
            return ()
        return tuple(self.rules.get(name, ()))


_local = threading.local()


def _ctx():
    return getattr(_local, "ctx", None)


@contextmanager
def use_mesh_rules(mesh: Mesh, rules: Optional[AxisRules] = None):
    prev = _ctx()
    _local.ctx = (mesh, rules or AxisRules())
    try:
        yield
    finally:
        _local.ctx = prev


def logical_to_spec(
    names: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Optional[AxisRules] = None,
) -> P:
    """Map logical dim names to a PartitionSpec, enforcing divisibility and
    never reusing a mesh axis."""
    rules = rules or AxisRules()
    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))
    used: set = set()
    spec = []
    for name, dim in zip(names, shape):
        cands = [a for a in rules.resolve(name) if a in axis_size and a not in used]
        chosen: Tuple[str, ...] = ()
        if cands:
            # prefer the full joint product, else greedy prefix, else singles
            prod = 1
            joint = []
            for a in cands:
                if dim % (prod * axis_size[a]) == 0:
                    joint.append(a)
                    prod *= axis_size[a]
            if joint:
                chosen = tuple(joint)
            else:
                for a in cands:
                    if dim % axis_size[a] == 0:
                        chosen = (a,)
                        break
        used.update(chosen)
        if len(chosen) == 0:
            spec.append(None)
        elif len(chosen) == 1:
            spec.append(chosen[0])
        else:
            spec.append(tuple(chosen))
    return P(*spec)


def shard(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Activation sharding constraint (no-op outside a mesh context)."""
    ctx = _ctx()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = logical_to_spec(names, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter axes table (leaf-name keyed; trailing dims; leading stack dims of
# scan-over-layers params are padded with "stack")
# ---------------------------------------------------------------------------

PARAM_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    # embeddings / heads
    "tok_embed": ("vocab", "fsdp"),
    "out_head": ("fsdp", "vocab"),
    "pos_embed": (None, "fsdp"),
    "proj_in": (None, "fsdp"),  # modality projector (frontend_dim, embed)
    # norms (1-D, replicated)
    "attn_norm": (None,),
    "mlp_norm": (None,),
    "final_norm": (None,),
    "cross_norm": (None,),
    "norm_beta": (None,),
    # attention
    "wq": ("fsdp", "tp"),
    "wk": ("fsdp", "tp"),
    "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    "bq": ("tp",),
    "bk": ("tp",),
    "bv": ("tp",),
    # dense MLP
    "w_in": ("fsdp", "tp"),
    "w_gate": ("fsdp", "tp"),
    "w_out": ("tp", "fsdp"),
    # MoE
    "router": ("fsdp", "experts"),
    "we_in": ("experts", "fsdp", "tp"),
    "we_gate": ("experts", "fsdp", "tp"),
    "we_out": ("experts", "tp", "fsdp"),
    # RWKV6 time/channel mix
    "w_r": ("fsdp", "tp"),
    "w_k": ("fsdp", "tp"),
    "w_v": ("fsdp", "tp"),
    "w_g": ("fsdp", "tp"),
    "w_o": ("tp", "fsdp"),
    "mix_lora_a": ("fsdp", None),
    "mix_lora_b": (None, None, "fsdp"),
    "decay_lora_a": ("fsdp", None),
    "decay_lora_b": (None, "fsdp"),
    "decay_base": ("fsdp",),
    "bonus": ("heads", None),
    "mix_base": (None, "fsdp"),
    "ln_x": (None,),
    "ck_mix": (None, "fsdp"),
    "ck_in": ("fsdp", "tp"),
    "ck_out": ("tp", "fsdp"),
    "ck_rec": ("fsdp", "tp"),
    # SSM (Mamba2)
    "in_proj": ("fsdp", "tp"),
    "conv_w": (None, "tp"),
    "conv_b": ("tp",),
    "A_log": ("tp",),
    "D": ("tp",),
    "dt_bias": ("tp",),
    "ssm_norm": ("tp",),
    "out_proj": ("tp", "fsdp"),
    # zamba2 shared-block concat projector
    "shared_down": ("fsdp", None),
    # LSTM forecaster (tiny; replicated)
    "kernel": (None, None),
    "recurrent": (None, None),
    "bias": (None,),
    "dense_w": (None, None),
    "dense_b": (None,),
    "head_w": (None, None),
    "head_b": (None,),
}

_STACK_PARENTS = ("layers", "enc_layers", "dec_layers")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_axes_for(path_str: str, ndim: int) -> Tuple[Optional[str], ...]:
    leaf = path_str.split("/")[-1]
    if leaf not in PARAM_AXES:
        raise KeyError(
            f"parameter {path_str!r} has no PARAM_AXES entry; register its "
            f"leaf name {leaf!r}"
        )
    axes = PARAM_AXES[leaf]
    # pad leading stacked-layer dims
    n_lead = ndim - len(axes)
    if n_lead < 0:
        # param used unstacked somewhere (e.g. shared block): trim left pads
        axes = axes[-ndim:]
        n_lead = 0
    lead = tuple("stack" for _ in range(n_lead))
    return lead + tuple(axes)


def param_shardings(params, mesh: Mesh, rules: Optional[AxisRules] = None):
    rules = rules or AxisRules()

    def one(path, x):
        ps = _path_str(path)
        names = param_axes_for(ps, x.ndim)
        return NamedSharding(mesh, logical_to_spec(names, x.shape, mesh, rules))

    return jax.tree_util.tree_map_with_path(one, params)


def spec_tree(params, mesh: Mesh, rules: Optional[AxisRules] = None):
    """PartitionSpec pytree (for in_shardings=...)."""
    rules = rules or AxisRules()

    def one(path, x):
        ps = _path_str(path)
        names = param_axes_for(ps, x.ndim)
        return logical_to_spec(names, x.shape, mesh, rules)

    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------------------------
# The stream mesh: the fleet hot path's stacked stream axis
# ---------------------------------------------------------------------------

STREAM_AXIS = "stream"


def largest_pow2_divisor(n: int) -> int:
    """The largest power of two dividing ``n`` (n & -n)."""
    if n <= 0:
        raise ValueError(f"need a positive dim, got {n}")
    return n & -n


def _pow2_floor(n: int) -> int:
    return 1 << (max(n, 1).bit_length() - 1)


def stream_mesh_size(sb: int, n_devices: int) -> int:
    """How many local devices the stacked stream axis of bucket ``sb``
    shards over: the largest power of two that both divides ``sb`` and fits
    the device count.  Pure arithmetic so the awkward cases are unit-
    testable without reconfiguring XLA: a bucket *smaller* than the host's
    device count (2 streams on 8 devices) caps at the bucket's own pow2
    divisor instead of producing an indivisible sharding, a non-pow2
    device count (6 host cores) uses its pow2 floor, and a non-pow2 bucket
    (nothing upstream produces one today, but nothing here assumes that)
    caps at *its* pow2 divisor."""
    return min(largest_pow2_divisor(sb), _pow2_floor(n_devices))


def stream_mesh(sb: int, devices: Optional[Sequence[Any]] = None
                ) -> Optional[Mesh]:
    """The 1-D ``("stream",)`` mesh for stream bucket ``sb`` over the local
    devices (or an explicit device list), or ``None`` when it would be a
    single device (no sharding: the tests' one-CPU configuration)."""
    devs = list(devices) if devices is not None else jax.devices()
    d = stream_mesh_size(sb, len(devs))
    if d <= 1:
        return None
    return Mesh(np.asarray(devs[:d]), (STREAM_AXIS,))


def stream_batch_spec(sb: int, mesh: Mesh,
                      rules: Optional[AxisRules] = None) -> P:
    """The stacked-batch PartitionSpec for a leading stream axis of ``sb``,
    resolved through the divisibility-aware ``logical_to_spec`` (an
    indivisible bucket degrades to replicated instead of erroring);
    trailing per-stream dims replicate."""
    return logical_to_spec((STREAM_AXIS,), (sb,), mesh, rules)


def stream_sharding(sb: int, devices: Optional[Sequence[Any]] = None,
                    rules: Optional[AxisRules] = None
                    ) -> Optional[NamedSharding]:
    """The ``NamedSharding`` every stacked fleet tensor of stream bucket
    ``sb`` carries — staged batches, init/perm key rows, the donated
    opt-state carry, the fit's stacked params output, and the
    ``predict_fleet`` serving batch all resolve through this one helper —
    or ``None`` on a single device."""
    mesh = stream_mesh(sb, devices)
    if mesh is None:
        return None
    return NamedSharding(mesh, stream_batch_spec(sb, mesh, rules))


def fleet_param_shardings(stacked, mesh: Mesh,
                          rules: Optional[AxisRules] = None):
    """NamedSharding pytree for a *stacked* fleet params/opt-state tree
    (leading stream-bucket axis): the stream axis shards per the rules and
    the trailing per-stream axes resolve through ``PARAM_AXES`` (the LSTM
    forecaster's leaves are registered replicated — each stream's whole
    model lives on its shard).  Leaves without a ``PARAM_AXES`` entry (an
    optimizer's step counter, loss trajectories) replicate their trailing
    dims."""
    rules = rules or AxisRules()

    def one(path, x):
        try:
            trailing = param_axes_for(_path_str(path), x.ndim - 1)
        except KeyError:
            trailing = (None,) * (x.ndim - 1)
        names = (STREAM_AXIS,) + tuple(trailing)
        return NamedSharding(mesh, logical_to_spec(names, x.shape, mesh,
                                                   rules))

    return jax.tree_util.tree_map_with_path(one, stacked)


def fleet_rules() -> AxisRules:
    """The axis rules the fleet hot path trains/serves under (the default
    table: ``stream`` -> the stream mesh axis, model dims replicated)."""
    return AxisRules()
