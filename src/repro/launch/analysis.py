"""Post-SPMD HLO analysis: collective bytes, matmul FLOPs, memory traffic,
and the three-term roofline.

Input is ``compiled.as_text()`` — the *partitioned* HLO, so all shapes are
per-device and collectives are materialized ops.  Because layers are
scan-stacked, ops inside a while body execute ``trip_count`` times but appear
once in the text; the analyzer builds the computation call graph (while
bodies, fusions, calls), extracts each while's trip count from its condition
computation, and multiplies through.

Reported roofline terms are **seconds per step per chip**:

    compute    = dot_flops / peak_flops          (MXU term)
    memory     = traffic_bytes / hbm_bw          (HBM term)
    collective = collective_bytes / ici_bw       (ICI term)

dot_flops counts dot/convolution ops only (elementwise is never the TPU
bottleneck at these shapes); traffic_bytes approximates HBM traffic as the
sum of op output bytes (written once, read ~once downstream) plus entry
parameter bytes; collective_bytes sums the output bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.configs.base import HardwareModel, TPU_V5E

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_START = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*(?:\(.*\))?\s*->.*\{")
_OP_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$")
_CALLEE_RE = re.compile(
    r"(?:body|condition|to_apply|calls|branch_computations)=\{?%?([\w\.\-]+)"
)

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclass
class Op:
    name: str
    kind: str
    out_type: str
    rest: str  # operand list + attrs (raw)


@dataclass
class Computation:
    name: str
    is_entry: bool
    ops: List[Op] = field(default_factory=list)
    callees: List[Tuple[str, str]] = field(default_factory=list)  # (kind, name)


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        m = _COMP_START.match(line)
        if m:
            cur = Computation(name=m.group(2), is_entry=bool(m.group(1)))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        om = _OP_RE.match(line)
        if om:
            name, out_type, kind, rest = om.groups()
            op = Op(name=name, kind=kind, out_type=out_type, rest=rest)
            cur.ops.append(op)
            for cm in _CALLEE_RE.finditer(line):
                cur.callees.append((kind, cm.group(1)))
    return comps


def while_trip_count(cond: Computation) -> int:
    """Extract the trip count from a while condition computation: the
    integer constant compared against the induction variable."""
    consts = []
    for op in cond.ops:
        if op.kind == "constant" and op.out_type.strip().startswith("s32"):
            cm = re.search(r"^(\-?\d+)\)", op.rest)
            if cm:
                consts.append(int(cm.group(1)))
    # conditions are tiny: the loop bound is the (max) integer constant the
    # induction variable is compared against (the compare itself may be
    # wrapped in a fusion, so we do not require seeing direction=LT here)
    nonneg = [c for c in consts if c >= 0]
    return max(nonneg) if nonneg else 1


@dataclass
class HLOSummary:
    dot_flops: float
    traffic_bytes: float
    collective_bytes: float
    collectives: Dict[str, float]  # kind -> bytes (multiplied)
    n_while: int
    trip_counts: List[int]
    param_bytes: float
    output_bytes: float


def _operand_names(rest: str) -> List[str]:
    """Operand %names from the text following 'op(' up to the matching ')'."""
    depth = 1
    end = 0
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    args = rest[:end]
    return re.findall(r"%([\w\.\-]+)", args)


def _dot_flops(op: Op, shapes: Dict[str, str]) -> float:
    """2 * prod(result) * prod(contracting dims of lhs).

    Scheduled HLO does not inline operand types, so lhs shape is resolved
    via the computation's symbol table; falls back to inline shapes."""
    out_elems = shape_elems(op.out_type)
    lhs_type = None
    names = _operand_names(op.rest)
    if names and names[0] in shapes:
        lhs_type = shapes[names[0]]
    if lhs_type is None:
        m = _SHAPE_RE.search(op.rest)
        lhs_type = m.group(0) if m else None
    if lhs_type is None:
        return 0.0
    m = _SHAPE_RE.search(lhs_type)
    if m is None:
        return 0.0
    lhs_dims = [int(d) for d in m.group(2).split(",") if d]
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    k = 1
    if cm and cm.group(1):
        for idx in cm.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                k *= lhs_dims[i]
    return 2.0 * out_elems * k


def _conv_flops(op: Op) -> float:
    # rough: 2 * out_elems * (kernel_elems_per_output).  Parse rhs (filter).
    out_elems = shape_elems(op.out_type)
    shapes = _SHAPE_RE.findall(op.rest)
    if len(shapes) < 2:
        return 0.0
    filt = shapes[1]
    k = 1
    for d in filt[1].split(","):
        if d:
            k *= int(d)
    # divide by output features approximation is skipped; convs are
    # negligible in this zoo (zamba2 depthwise conv only)
    return 2.0 * out_elems * max(k, 1) ** 0.5


def summarize(text: str) -> HLOSummary:
    comps = parse_hlo(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    # map computation -> multiplier via BFS through the call graph
    mult: Dict[str, float] = {entry.name: 1.0}
    order = [entry.name]
    seen = {entry.name}
    trip_counts: List[int] = []
    n_while = 0
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        # group callees by op line: while ops carry (condition, body)
        for op in comp.ops:
            callees = _CALLEE_RE.findall(
                f"{op.kind}({op.rest}"
            )
            if op.kind == "while":
                n_while += 1
                cond_name = None
                body_name = None
                cm = re.search(r"condition=\{?%?([\w\.\-]+)", op.rest)
                bm = re.search(r"body=\{?%?([\w\.\-]+)", op.rest)
                if cm:
                    cond_name = cm.group(1)
                if bm:
                    body_name = bm.group(1)
                tc = 1
                if cond_name and cond_name in comps:
                    tc = while_trip_count(comps[cond_name])
                trip_counts.append(tc)
                for nm, f in ((body_name, m * tc), (cond_name, m * tc)):
                    if nm:
                        mult[nm] = max(mult.get(nm, 0.0), f)
                        if nm not in seen:
                            seen.add(nm)
                            order.append(nm)
            else:
                for nm in callees:
                    mult[nm] = max(mult.get(nm, 0.0), m)
                    if nm not in seen:
                        seen.add(nm)
                        order.append(nm)

    dot_flops = 0.0
    traffic = 0.0
    coll_bytes = 0.0
    coll_by_kind: Dict[str, float] = {}
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue  # unreachable
        shapes = {op.name: op.out_type for op in comp.ops}
        for op in comp.ops:
            ob = shape_bytes(op.out_type)
            if op.kind == "dot":
                dot_flops += m * _dot_flops(op, shapes)
                traffic += m * ob
            elif op.kind in ("convolution",):
                dot_flops += m * _conv_flops(op)
                traffic += m * ob
            elif op.kind.startswith(COLLECTIVES):
                base = op.kind
                for c in COLLECTIVES:
                    if op.kind.startswith(c):
                        base = c
                        break
                if op.kind.endswith("-done"):
                    continue  # counted at -start
                coll_bytes += m * ob
                coll_by_kind[base] = coll_by_kind.get(base, 0.0) + m * ob
                traffic += m * ob
            elif op.kind in ("fusion", "copy", "scatter", "gather",
                             "dynamic-update-slice", "dynamic-slice",
                             "custom-call", "sort", "reduce", "transpose",
                             "reshape", "broadcast", "concatenate", "select",
                             "convert", "iota", "pad", "slice"):
                traffic += m * ob

    # entry parameter/output bytes (weights in, new weights out)
    param_bytes = 0.0
    out_bytes = 0.0
    for op in entry.ops:
        if op.kind == "parameter":
            param_bytes += shape_bytes(op.out_type)
    root = entry.ops[-1] if entry.ops else None
    if root is not None:
        out_bytes = shape_bytes(root.out_type)
    traffic += param_bytes + out_bytes

    return HLOSummary(
        dot_flops=dot_flops,
        traffic_bytes=traffic,
        collective_bytes=coll_bytes,
        collectives=coll_by_kind,
        n_while=n_while,
        trip_counts=trip_counts,
        param_bytes=param_bytes,
        output_bytes=out_bytes,
    )


# ---------------------------------------------------------------------------
# Roofline
# ---------------------------------------------------------------------------


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_per_chip: float
    useful_ratio: float  # MODEL_FLOPS / (HLO flops * chips)
    collectives: Dict[str, float]

    def as_dict(self):
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "hlo_flops_per_chip": self.hlo_flops_per_chip,
            "useful_ratio": self.useful_ratio,
            "collectives": self.collectives,
        }


def roofline(summary: HLOSummary, n_chips: int, model_flops: float,
             hw: HardwareModel = TPU_V5E) -> Roofline:
    compute_s = summary.dot_flops / hw.peak_flops_bf16
    memory_s = summary.traffic_bytes / hw.hbm_bw
    collective_s = summary.collective_bytes / hw.ici_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    total_hlo = summary.dot_flops * n_chips
    return Roofline(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops,
        hlo_flops_per_chip=summary.dot_flops,
        useful_ratio=model_flops / total_hlo if total_hlo > 0 else 0.0,
        collectives=summary.collectives,
    )


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS
# ---------------------------------------------------------------------------


def count_params_analytic(cfg) -> Tuple[float, float]:
    """(total_params, active_params) — active differs for MoE."""
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab_size
    qd, kvd = cfg.q_dim, cfg.kv_dim
    attn = d * qd + 2 * d * kvd + qd * d
    gated = 3 if cfg.mlp_variant in ("swiglu", "geglu") else 2
    total = V * d * (1 if cfg.tie_embeddings else 2)
    active = total
    if cfg.family == "ssm":  # rwkv6: 5 square proj + channel mix
        per_layer = 5 * d * d + gated_ffn_params(cfg, d)
        total += L * per_layer
        active = total
        return float(total), float(active)
    for li in range(L):
        is_moe = cfg.moe is not None and li >= (cfg.moe.first_dense_layers
                                                if cfg.moe else 0)
        if cfg.family == "hybrid":
            # mamba2 backbone layer
            from repro.models import ssm as ssm_mod

            d_inner, H, xbc, d_in_proj = ssm_mod.dims(cfg)
            per = d * d_in_proj + d_inner * d
            total += per
            active += per
            continue
        if is_moe:
            e = cfg.moe
            expert = gated * d * e.d_ff_expert
            total += attn + e.n_experts * expert + d * e.n_experts
            total += e.n_shared_experts * gated * d * e.d_ff_expert
            active += attn + e.top_k * expert + d * e.n_experts
            active += e.n_shared_experts * gated * d * e.d_ff_expert
        else:
            ffn = gated_ffn_params(cfg, d)
            total += attn + ffn
            active += attn + ffn
    if cfg.family == "hybrid":
        # one shared transformer block + down-proj
        shared = attn + gated_ffn_params(cfg, d) + 2 * d * d
        total += shared
        active += shared
    if cfg.family == "audio" and cfg.encdec:
        enc = cfg.encdec.n_encoder_layers * (attn + gated_ffn_params(cfg, d))
        cross = L * (d * qd + 2 * d * kvd + qd * d)
        total += enc + cross
        active += enc + cross
    return float(total), float(active)


def gated_ffn_params(cfg, d) -> int:
    gated = 3 if cfg.mlp_variant in ("swiglu", "geglu") else 2
    return gated * d * cfg.d_ff


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N_active*D for train (fwd+bwd), 2*N_active*D for
    inference, plus the attention score/value matmuls (which dominate long
    decode and are not captured by the parametric term).  Global FLOPs."""
    total, active = count_params_analytic(cfg)
    B = shape.global_batch
    if shape.kind == "train":
        tokens, mult = B * shape.seq_len, 6.0
        sq, skv = shape.seq_len, shape.seq_len
    elif shape.kind == "prefill":
        tokens, mult = B * shape.seq_len, 2.0
        sq, skv = shape.seq_len, shape.seq_len
    else:
        tokens, mult = B, 2.0
        sq, skv = 1, shape.seq_len
    if shape.kind == "decode" and cfg.family == "audio" and cfg.encdec:
        # the encoder does not run at decode (cross K/V live in the cache)
        d = cfg.d_model
        enc_params = cfg.encdec.n_encoder_layers * (
            cfg.d_model * cfg.q_dim + 2 * cfg.d_model * cfg.kv_dim
            + cfg.q_dim * cfg.d_model + gated_ffn_params(cfg, d)
        )
        active = max(active - enc_params, 1.0)
    flops = mult * active * tokens

    # attention: per layer 4*B*Sq*Skv_eff*q_dim fwd (QK^T + PV), x3 train
    if cfg.attention != "none" and cfg.family != "lstm":
        if cfg.attention == "swa":
            skv_eff = min(skv, cfg.window_size)
        else:
            skv_eff = skv
        if sq > 1 and cfg.attention != "swa":
            skv_eff = skv_eff / 2  # causal halves the average span
        n_attn = cfg.n_layers
        if cfg.family == "hybrid" and cfg.hybrid is not None:
            n_attn = cfg.n_layers // cfg.hybrid.attn_every
        if cfg.family == "audio" and cfg.encdec is not None:
            # decoder self + cross + encoder self
            enc = cfg.encdec
            flops += (4.0 * B * sq * enc.encoder_len * cfg.q_dim
                      * (3.0 if shape.kind == "train" else 1.0)) * cfg.n_layers
            if shape.kind in ("train",):
                flops += (12.0 * B * enc.encoder_len * enc.encoder_len / 2
                          * cfg.q_dim) * enc.n_encoder_layers
        a_mult = 3.0 if shape.kind == "train" else 1.0
        flops += 4.0 * a_mult * B * sq * skv_eff * cfg.q_dim * n_attn
    return flops
