"""Serving launcher: runs the Engine on a reduced arch locally (batched
requests, prefill + decode), printing latency stats.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --batch 4 --prompt-len 64 --new-tokens 32
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models import get_model
from repro.serving import Engine


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=64)
    p.add_argument("--new-tokens", type=int, default=32)
    args = p.parse_args()

    cfg = get_config(args.arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(cfg, params, max_len=args.prompt_len + args.new_tokens)

    rng = np.random.default_rng(0)
    prompts = rng.integers(1, cfg.vocab_size, (args.batch, args.prompt_len),
                           dtype=np.int32)
    prefix = None
    if cfg.frontend is not None:
        prefix = rng.normal(
            0, 0.02,
            (args.batch, cfg.frontend.n_prefix_tokens, cfg.frontend.embed_dim),
        ).astype(np.float32)
    out, stats = engine.generate(prompts, args.new_tokens, prefix_embed=prefix)
    print(f"generated {out.shape} tokens")
    print(f"prefill: {stats.prefill_s*1e3:.1f} ms  "
          f"decode: {stats.decode_s*1e3:.1f} ms  "
          f"throughput: {stats.tokens_per_s:.1f} tok/s")


if __name__ == "__main__":
    main()
