"""Training launcher.

Two modes:

* ``--local`` — actually executes on the local device(s): trains a reduced
  variant of the chosen arch on a synthetic token stream for --steps steps
  (the end-to-end driver used by examples/ and CI).

* default — production mesh mode: builds the pjit'd train step for the full
  config on the 16x16 (or 2x16x16) mesh and compiles it (requires running
  under the dry-run's 512-device env; see repro.launch.dryrun which this
  delegates to for lowering).

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b --local \
        --steps 20 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import get_model
from repro.training import adamw, checkpoint, make_train_step, warmup_cosine


def synthetic_batch(cfg, batch, seq, key):
    tokens = jax.random.randint(key, (batch, seq + 1), 0, cfg.vocab_size)
    out = {"tokens": tokens[:, :-1], "targets": tokens[:, 1:]}
    if cfg.frontend is not None:
        out["prefix_embed"] = (
            jax.random.normal(
                key, (batch, cfg.frontend.n_prefix_tokens, cfg.frontend.embed_dim)
            )
            * 0.02
        )
    return out


def train_local(arch: str, steps: int, batch: int, seq: int, lr: float,
                ckpt_path: str | None = None, log_every: int = 10) -> dict:
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    opt = adamw(warmup_cosine(lr, warmup=max(steps // 10, 1), total=steps))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, opt))

    losses = []
    t0 = time.perf_counter()
    for i in range(steps):
        key, sub = jax.random.split(key)
        b = synthetic_batch(cfg, batch, seq, sub)
        params, opt_state, metrics = step_fn(params, opt_state, b)
        losses.append(float(metrics["loss"]))
        if log_every and (i + 1) % log_every == 0:
            print(f"step {i+1}/{steps} loss={losses[-1]:.4f} "
                  f"grad_norm={float(metrics['grad_norm']):.3f}")
    wall = time.perf_counter() - t0
    if ckpt_path:
        h = checkpoint.save(ckpt_path, params, step=steps)
        print(f"saved checkpoint {h.path} ({h.nbytes/1e6:.1f} MB)")
    return {"losses": losses, "wall_s": wall,
            "final_loss": losses[-1], "first_loss": losses[0]}


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--local", action="store_true")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--ckpt", default=None)
    args = p.parse_args()
    if args.local:
        res = train_local(args.arch, args.steps, args.batch, args.seq,
                          args.lr, args.ckpt)
        print(f"done: first_loss={res['first_loss']:.4f} "
              f"final_loss={res['final_loss']:.4f} wall={res['wall_s']:.1f}s")
        assert np.isfinite(res["final_loss"])
    else:
        print("production-mesh mode delegates to repro.launch.dryrun "
              "(lower+compile); run: python -m repro.launch.dryrun "
              f"--arch {args.arch} --shape train_4k --mesh both")


if __name__ == "__main__":
    main()
