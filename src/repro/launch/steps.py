"""Step builders: the jit-able functions the launcher runs and the dry-run
lowers, together with fully-sharded ShapeDtypeStruct input specs.

``build_step(cfg, shape, mesh)`` returns (fn, specs) such that

    with use_mesh_rules(mesh, rules):
        lowered = jax.jit(fn).lower(**specs)

compiles the exact production computation: train_step for train shapes
(fwd + bwd + AdamW update, FSDP/TP sharded), prefill_step for prefill
shapes, decode_step (one new token against a seq_len KV cache) for decode
shapes.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.distributed.sharding import (
    AxisRules,
    logical_to_spec,
    param_axes_for,
    _path_str,
)
from repro.models.model import get_model, input_specs
from repro.training.optimizer import adamw
from repro.training.train_loop import make_train_step

# logical axes of cache leaves, by leaf name (trailing dims; leading
# stacked-layer dims padded with "stack")
CACHE_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    "k": ("batch", "seq", "kv_heads", None),
    "v": ("batch", "seq", "kv_heads", None),
    "ck": ("batch", "seq", "kv_heads", None),
    "cv": ("batch", "seq", "kv_heads", None),
    "kv_pos": ("batch", None),
    "mem_pos": ("batch", None),
    "state": ("batch", "heads", None, None),
    "shift_tm": ("batch", "embed"),
    "shift_cm": ("batch", "embed"),
    "conv": ("batch", None, "tp"),
    "h": ("batch", "heads", None, None),
}

BATCH_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    "tokens": ("batch", None),
    "targets": ("batch", None),
    "mask": ("batch", None),
    "token": ("batch", None),
    "pos": ("batch",),
    "prefix_embed": ("batch", None, None),
    "x": ("batch", None, None),
    "y": ("batch", None),
}


def _with_sharding(sds_tree, axes_lookup, mesh: Mesh, rules: AxisRules):
    """Attach NamedShardings to a ShapeDtypeStruct tree."""

    def one(path, s):
        name = _path_str(path).split("/")[-1]
        axes = axes_lookup(name, path, s)
        spec = logical_to_spec(axes, s.shape, mesh, rules)
        return jax.ShapeDtypeStruct(s.shape, s.dtype,
                                    sharding=NamedSharding(mesh, spec))

    return jax.tree_util.tree_map_with_path(one, sds_tree)


def shard_batch_specs(sds_tree, mesh, rules):
    def lookup(name, path, s):
        axes = BATCH_AXES.get(name, ())
        return tuple(axes) + (None,) * (len(s.shape) - len(axes))

    return _with_sharding(sds_tree, lookup, mesh, rules)


def shard_cache_specs(sds_tree, mesh, rules):
    def lookup(name, path, s):
        axes = CACHE_AXES.get(name, (None,) * len(s.shape))
        n_lead = len(s.shape) - len(axes)
        return ("stack",) * n_lead + tuple(axes)

    return _with_sharding(sds_tree, lookup, mesh, rules)


def shard_param_specs(sds_tree, mesh, rules):
    def lookup(name, path, s):
        return param_axes_for(_path_str(path), len(s.shape))

    return _with_sharding(sds_tree, lookup, mesh, rules)


def param_opt_specs(cfg: ModelConfig, mesh: Mesh, rules: AxisRules,
                    key_seed: int = 0):
    """ShapeDtypeStruct trees (no allocation) for params and AdamW state."""
    model = get_model(cfg)
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(key_seed))
    params_sds = shard_param_specs(params_sds, mesh, rules)
    opt = adamw(1e-4, moment_dtype=cfg.opt_moment_dtype)
    opt_sds = jax.eval_shape(opt.init, params_sds)
    # moments share the params' sharding; step scalar replicated
    mu = shard_param_specs(opt_sds.mu, mesh, rules)
    nu = shard_param_specs(opt_sds.nu, mesh, rules)
    step = jax.ShapeDtypeStruct(
        (), jnp.int32, sharding=NamedSharding(mesh, P())
    )
    opt_sds = type(opt_sds)(step=step, mu=mu, nu=nu)
    return params_sds, opt_sds, opt


def build_step(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
               rules: Optional[AxisRules] = None):
    """Returns (fn, kwargs_specs).  fn signature depends on shape.kind."""
    rules = rules or AxisRules()
    model = get_model(cfg)
    raw = input_specs(cfg, shape)

    if shape.kind == "train":
        params_sds, opt_sds, opt = param_opt_specs(cfg, mesh, rules)
        step_fn = make_train_step(model, opt)
        specs = {
            "params": params_sds,
            "opt_state": opt_sds,
            "batch": shard_batch_specs(raw["batch"], mesh, rules),
        }

        def fn(params, opt_state, batch):
            return step_fn(params, opt_state, batch)

        return fn, specs

    params_sds, _, _ = param_opt_specs(cfg, mesh, rules)
    if shape.kind == "prefill":
        specs = {
            "params": params_sds,
            "batch": shard_batch_specs(raw["batch"], mesh, rules),
        }

        def fn(params, batch):
            return model.prefill(params, batch, shape.seq_len)

        return fn, specs

    if shape.kind == "decode":
        specs = {
            "params": params_sds,
            "batch": shard_batch_specs(raw["batch"], mesh, rules),
            "cache": shard_cache_specs(raw["cache"], mesh, rules),
        }

        def fn(params, batch, cache):
            return model.decode_step(params, batch, cache)

        return fn, specs

    raise ValueError(shape.kind)
