"""Edge-cloud deployment launcher: run the discrete-event runtime for a
chosen deployment modality with measured-module calibration, optionally with
int8-quantized model sync (the TFLite-analog edge path).

    PYTHONPATH=src python -m repro.launch.edge_cloud --deployment integrated
    PYTHONPATH=src python -m repro.launch.edge_cloud --deployment all \
        --windows 50 --quantized --fast
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--deployment",
                   choices=["edge", "cloud", "integrated", "all"],
                   default="all")
    p.add_argument("--windows", type=int, default=25)
    p.add_argument("--static", action="store_true",
                   help="static 5:5 weighting instead of dynamic")
    p.add_argument("--quantized", action="store_true",
                   help="int8 model sync (4x smaller transfers)")
    p.add_argument("--fast", action="store_true")
    args = p.parse_args()

    sys.path.insert(0, ".")
    from benchmarks.calibrate import calibrate
    from repro.runtime import (
        EdgeCloudSimulation,
        cloud_centric,
        edge_centric,
        edge_cloud_integrated,
        paper_topology,
    )

    cal = calibrate(fast=args.fast)
    cost = cal.cost
    if args.quantized:
        import dataclasses

        cost = dataclasses.replace(cost, model_nbytes=cost.model_nbytes / 4
                                   + 256)  # int8 weights + f32 scales

    names = {
        "edge": [edge_centric],
        "cloud": [cloud_centric],
        "integrated": [edge_cloud_integrated],
        "all": [edge_centric, cloud_centric, edge_cloud_integrated],
    }[args.deployment]

    print(f"calibration: {cal.details}")
    for factory in names:
        dep = factory()
        sim = EdgeCloudSimulation(dep, paper_topology(), cost,
                                  dynamic_weighting=not args.static)
        res = sim.run(args.windows)
        print(f"\n[{dep.name}] {args.windows} windows, "
              f"{'static' if args.static else 'dynamic'} weighting"
              f"{', int8 sync' if args.quantized else ''}")
        for m, row in res.table3().items():
            print(f"  {m:<18} comp={row['computation']:>8.3f}s "
                  f"comm={row['communication']:>8.3f}s "
                  f"total={row['total']:>8.3f}s")
        if res.failures:
            print(f"  !! {len(res.failures)} failures "
                  f"(first: {res.failures[0]})")


if __name__ == "__main__":
    main()
