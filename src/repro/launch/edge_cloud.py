"""Edge-cloud deployment launcher: run the three deployment modalities either
as the calibrated discrete-event simulation (CostModel constants) or — with
``--real`` — as actual LSTM compute scheduled on the TopicBus by the
``BusExecutor``, with per-stage wall-clock measured on this container and
rescaled to each site's hardware class.

    PYTHONPATH=src python -m repro.launch.edge_cloud --deployment integrated
    PYTHONPATH=src python -m repro.launch.edge_cloud --deployment all \
        --windows 50 --quantized --fast
    PYTHONPATH=src python -m repro.launch.edge_cloud --deployment all \
        --windows 5 --fast --real
"""
from __future__ import annotations

import argparse
import sys


def _print_table(table, e2e=None) -> None:
    for m, row in table.items():
        line = (f"  {m:<18} comp={row['computation']:>8.3f}s "
                f"comm={row['communication']:>8.3f}s ")
        if row.get("queue", 0.0) > 0:
            line += f"queue={row['queue']:>7.3f}s "
        line += f"total={row['total']:>8.3f}s"
        print(line)
    if e2e is not None:
        print(f"  {'end-to-end window':<18} {e2e:>42.3f}s")


def build_real_pipeline(n_windows: int, fast: bool = True,
                        mode="dynamic", records_per_window: int = 250,
                        verbose: bool = False, scenario: str = "gradual"):
    """The paper's experiment built for real-compute execution: returns
    (stages, batch_params, stream, cost).  Single source of truth for the
    launcher's ``--real`` mode and the benchmark's measured Table-3 path —
    history length, seeds, drift, epoch pairs and the Kafka-ingest formula
    live only here.  ``scenario`` selects the paper's drift scenario
    ({"none", "gradual", "abrupt"}, Sec. 6.1.3; default: the gradual drift
    the Table-3 runs always used)."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core import (
        PipelineStages,
        WindowPlan,
        WindowedStream,
        lstm_forecaster,
        make_supervised,
        pretrain_batch_model,
    )
    from repro.runtime import CostModel
    from repro.streams.normalize import MinMaxScaler
    from repro.streams.sources import apply_scenario, wind_turbine_series

    batch_epochs, speed_epochs = (8, 10) if fast else (50, 100)
    rpw = records_per_window
    cfg = get_config("lstm-paper")
    series = wind_turbine_series(1600 + rpw * n_windows + 5, seed=0)
    hist, stream_raw = series[:1600], series[1600:]
    alphas = np.full(5, 1.5e-3) if scenario == "gradual" else None
    stream_raw = apply_scenario(stream_raw, scenario, seed=1, alphas=alphas)
    scaler = MinMaxScaler.fit(hist)

    fc_batch = lstm_forecaster(cfg, epochs=batch_epochs, batch_size=256)
    fc_speed = lstm_forecaster(cfg, epochs=speed_epochs, batch_size=64)
    if verbose:
        print(f"pretraining batch model M^b ({batch_epochs} epochs) ...")
    bp, t_pre = pretrain_batch_model(
        fc_batch, make_supervised(scaler.transform(hist), 5, 0),
        jax.random.PRNGKey(0))
    if verbose:
        print(f"  done in {t_pre:.1f}s")

    stream = WindowedStream(scaler.transform(stream_raw),
                            WindowPlan(n_windows, rpw, lag=5))
    stages = PipelineStages.build(fc_speed, mode=mode)
    # only the unmeasurable parts come from the cost model: the Kafka ingest
    # throttle and the training-job memory footprint (capacity model)
    cost = CostModel(ingest_s=rpw / 7.0 * 0.45)
    return stages, bp, stream, cost


def build_fleet_pipeline(n_streams: int, n_windows: int, fast: bool = True,
                         mode="dynamic", records_per_window: int = 250,
                         scenario="gradual", verbose: bool = False):
    """The fleet analog of :func:`build_real_pipeline`: N correlated
    turbines (``streams.sources.turbine_fleet``), each scaled by its own
    history, all served by one shared pre-trained batch model; returns
    (fleet_stages, batch_params, {stream_id: WindowedStream}, cost).

    ``scenario`` is one drift scenario name for the whole fleet or a
    per-stream list ({"none", "gradual", "abrupt"} each) — the chaos
    suite's ``compound_drift`` mixes all three across one fleet."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core import (
        FleetStages,
        lstm_fleet_forecaster,
        lstm_forecaster,
        pretrain_batch_model,
    )
    from repro.runtime import CostModel
    from repro.streams.sources import fleet_windowed_streams

    batch_epochs, speed_epochs = (8, 10) if fast else (50, 100)
    rpw = records_per_window
    cfg = get_config("lstm-paper")
    has_gradual = ("gradual" in scenario if not isinstance(scenario, str)
                   else scenario == "gradual")
    alphas = np.full(5, 1.5e-3) if has_gradual else None
    streams, hist0 = fleet_windowed_streams(
        n_streams, n_windows, rpw, scenario, alphas=alphas)

    fc_batch = lstm_forecaster(cfg, epochs=batch_epochs, batch_size=256)
    if verbose:
        print(f"pretraining shared batch model M^b ({batch_epochs} epochs, "
              f"{n_streams} streams) ...")
    bp, t_pre = pretrain_batch_model(fc_batch, hist0, jax.random.PRNGKey(0))
    if verbose:
        print(f"  done in {t_pre:.1f}s")

    fleet_fc = lstm_fleet_forecaster(cfg, epochs=speed_epochs, batch_size=64)
    stages = FleetStages.build(fleet_fc, mode=mode)
    cost = CostModel(ingest_s=rpw / 7.0 * 0.45)
    return stages, bp, streams, cost


def run_real_fleet(args) -> None:
    """N streams on real LSTM compute through the TopicBus: per-stream
    topics under one deployment, whole-fleet speed training in one vmapped
    dispatch per window, optional drift-gated retraining."""
    import jax

    from repro.core.drift import DriftGate
    from repro.runtime import ALL_DEPLOYMENTS, FleetBusExecutor, paper_topology

    mode = ("static", 0.5) if args.static else "dynamic"
    stages, bp, streams, cost = build_fleet_pipeline(
        args.streams, args.windows, fast=args.fast, mode=mode,
        scenario=args.scenario, verbose=True)

    deps = {
        "edge": ["edge-centric"],
        "cloud": ["cloud-centric"],
        "integrated": ["edge-cloud-integrated"],
        "all": list(ALL_DEPLOYMENTS),
    }[args.deployment]

    for name in deps:
        dep = ALL_DEPLOYMENTS[name]()
        gate = DriftGate() if args.gated else None
        ex = FleetBusExecutor(stages, dep, paper_topology(), cost,
                              window_period_s=args.period, gate=gate,
                              quantized_sync=args.quantized,
                              qps=args.qps, serve_slots=args.slots,
                              elastic=args.elastic or False)
        res = ex.run(streams, bp, jax.random.PRNGKey(1))
        print(f"\n[{dep.name}] {args.streams} streams x {args.windows} "
              f"windows ({args.scenario} scenario"
              f"{', drift-gated' if args.gated else ''}"
              f"{', int8 sync' if args.quantized else ''}), measured "
              f"Table-3 breakdown:")
        _print_table(res.table3(),
                     e2e=(res.mean_e2e_s()
                          if any(res.e2e_s.values()) else None))
        if any(r.records for r in res.results.values()):
            m = res.mean_rmse()
            print(f"  fleet mean RMSE: batch={m['batch']:.4f} "
                  f"speed={m['speed']:.4f} hybrid={m['hybrid']:.4f}")
        else:
            print("  (no inference windows: window 0 only trains; "
                  "use --windows >= 2)")
        print(f"  speed training: {res.train_dispatches} device dispatches "
              f"for {res.total_retrains()} retrains "
              f"({res.skipped_retrains()} skipped)")
        if res.gate_stats is not None:
            per = res.gate_stats["per_stream"]
            gated = " ".join(
                f"{sid}:{st['retrained']}R/{st['skipped']}S"
                for sid, st in sorted(per.items()))
            print(f"  gate: {gated}")
        if res.serving is not None:
            s = res.serving
            print(f"  request plane: {s['n_answered']}/{s['n_requests']} "
                  f"answered ({s['n_starved']} starved) over "
                  f"{s['ticks']} ticks, "
                  f"{s['dispatches_per_tick']:.2f} dispatches/tick, "
                  f"{s['slots']} slots")
            print(f"    offered={s['offered_qps']:.1f} qps "
                  f"sustained={s['sustained_qps']:.1f} qps "
                  f"p50={s['p50_s']*1e3:.2f}ms p99={s['p99_s']*1e3:.2f}ms")
        if res.placement is not None:
            pl = res.placement
            ctl = pl["controller"]
            print(f"  elastic ({pl['mode']}, interval "
                  f"{pl['control_interval_s']:.1f}s): "
                  f"{ctl['migrations']} migrations, "
                  f"{ctl['scale_events']} scale events "
                  f"({ctl['proactive_scale_events']} proactive), "
                  f"{ctl['ticks']} control ticks")
            for m in pl["migrations"]:
                print(f"    t={m['t']:.1f}s {m['sid']}: {m['from']} -> "
                      f"{m['to']} ({m['state_nbytes']/1e3:.1f} KB state)")
            placed = " ".join(f"{sid}@{site}" for sid, site
                              in sorted(pl["stream_site"].items()))
            print(f"    final placement: {placed}; workers "
                  f"{pl['base_workers']} -> {pl['final_workers']}")
        if res.failures:
            print(f"  !! {len(res.failures)} capacity failures "
                  f"(first: {res.failures[0]})")


def run_real(args) -> None:
    """All three deployments on real LSTM compute through the TopicBus."""
    import jax

    from repro.runtime import ALL_DEPLOYMENTS, BusExecutor, paper_topology

    mode = ("static", 0.5) if args.static else "dynamic"
    stages, bp, stream, cost = build_real_pipeline(
        args.windows, fast=args.fast, mode=mode, verbose=True,
        scenario=args.scenario)

    deps = {
        "edge": ["edge-centric"],
        "cloud": ["cloud-centric"],
        "integrated": ["edge-cloud-integrated"],
        "all": list(ALL_DEPLOYMENTS),
    }[args.deployment]

    e2e, failures = {}, {}
    for name in deps:
        dep = ALL_DEPLOYMENTS[name]()
        ex = BusExecutor(stages, dep, paper_topology(), cost,
                         window_period_s=args.period,
                         quantized_sync=args.quantized)
        res = ex.run(stream, bp, jax.random.PRNGKey(1))
        e2e[name] = res.mean_e2e_s()
        failures[name] = res.failures
        print(f"\n[{dep.name}] {args.windows} windows, measured Table-3 "
              f"breakdown ({'static' if args.static else 'dynamic'} "
              f"weighting, real LSTM compute"
              f"{', int8 sync' if args.quantized else ''}):")
        _print_table(res.table3(),
                     e2e=res.mean_e2e_s() if res.e2e_s else None)
        if res.records:
            m = res.to_hybrid_result().mean_rmse()
            print(f"  mean RMSE: batch={m['batch']:.4f} "
                  f"speed={m['speed']:.4f} hybrid={m['hybrid']:.4f}")
        else:
            print("  (no inference windows: window 0 only trains; "
                  "use --windows >= 2)")
        if res.failures:
            print(f"  !! {len(res.failures)} capacity failures "
                  f"(first: {res.failures[0]})")

    if len(deps) == 3:
        order = sorted(e2e, key=e2e.get)
        ok = order == ["edge-cloud-integrated", "cloud-centric",
                       "edge-centric"]
        print("\n# paper-claim checks (measured)")
        print("  e2e window latency: " + " < ".join(
            f"{n} ({e2e[n]:.3f}s)" for n in order)
            + f"  [{'PASS' if ok else 'FAIL'}]")
        oom = bool(failures["edge-centric"])
        print(f"  edge-centric speed-training capacity failure: "
              f"{'PASS' if oom else 'FAIL'}")


def run_calibrated(args) -> None:
    sys.path.insert(0, ".")
    from benchmarks.calibrate import calibrate
    from repro.runtime import (
        EdgeCloudSimulation,
        cloud_centric,
        edge_centric,
        edge_cloud_integrated,
        paper_topology,
    )

    if args.scenario != "gradual":
        # the calibrated path replays measured latency constants; the drift
        # scenario shapes accuracy, not latency, so it changes nothing here
        print(f"(calibrated simulation: --scenario {args.scenario} noted, "
              "but only --real runs data through the models)")
    cal = calibrate(fast=args.fast)
    cost = cal.cost
    if args.quantized:
        import dataclasses

        cost = dataclasses.replace(cost, model_nbytes=cost.model_nbytes / 4
                                   + 256)  # int8 weights + f32 scales

    names = {
        "edge": [edge_centric],
        "cloud": [cloud_centric],
        "integrated": [edge_cloud_integrated],
        "all": [edge_centric, cloud_centric, edge_cloud_integrated],
    }[args.deployment]

    print(f"calibration: {cal.details}")
    for factory in names:
        dep = factory()
        sim = EdgeCloudSimulation(dep, paper_topology(), cost,
                                  dynamic_weighting=not args.static)
        res = sim.run(args.windows)
        print(f"\n[{dep.name}] {args.windows} windows, "
              f"{'static' if args.static else 'dynamic'} weighting"
              f"{', int8 sync' if args.quantized else ''}")
        _print_table(res.table3())
        if res.failures:
            print(f"  !! {len(res.failures)} failures "
                  f"(first: {res.failures[0]})")


def run_chaos(args) -> None:
    """One chaos scenario end to end: the fleet pipeline under the named
    fault plane, degradation envelope printed (see ``core.scenarios``)."""
    from repro.core.scenarios import ChaosHarness

    # chaos-friendly defaults where the generic flags were left untouched:
    # small fleet, short run, fast virtual period, live query load.
    n_streams = args.streams if args.streams > 1 else 3
    n_windows = args.windows if args.windows != 25 else 6
    period = args.period if args.period != 30.0 else 5.0
    qps = args.qps if args.qps > 0 else 8.0

    h = ChaosHarness(n_streams=n_streams, n_windows=n_windows,
                     records_per_window=120, period_s=period, qps=qps,
                     serve_slots=args.slots, verbose=True)
    seed = args.chaos_seed
    print(f"\n[chaos:{args.chaos}] {n_streams} streams x {n_windows} "
          f"windows, period {period}s, {qps} qps, seed {seed}")
    env, res = h.run_scenario(args.chaos, seed=seed)
    if env["unhandled_exception"] is not None:
        raise SystemExit(f"chaos run crashed: {env['unhandled_exception']}")
    if args.chaos != "fault_free":
        env_ff, _ = h.run_scenario("fault_free", seed=seed)
        ratio = env["rmse_hybrid"] / env_ff["rmse_hybrid"]
        print(f"  hybrid RMSE {env['rmse_hybrid']:.4f} "
              f"(x{ratio:.3f} vs fault-free)")
    else:
        print(f"  hybrid RMSE {env['rmse_hybrid']:.4f}")
    print(f"  answered {env['n_answered']} queries "
          f"(starved {env['n_starved']}), p99 {env['p99_latency_s']*1e3:.1f}"
          f"ms, max served staleness {env['max_staleness']}, "
          f"fallback {env['fallback_frac']:.2f}")
    print(f"  dead letters {env['dead_letters']}, quarantined "
          f"{env.get('quarantined', {})}, corrupt rejected "
          f"{env.get('corrupt_rejected', 0)}, forged rejected "
          f"{env.get('forged_rejected', 0)}, resync requests "
          f"{env.get('resync_requests', 0)}")
    stats = env.get("fault_stats", {})
    if stats:
        print("  fault events: " + ", ".join(
            f"{k}={v}" for k, v in sorted(stats.items())))
    hlt = env.get("health")
    if hlt:
        print(f"  health: {hlt['n_suspected']} suspected, "
              f"{hlt['n_site_down']} down, {hlt['n_recovered']} recovered; "
              f"byzantine {hlt['byz_flagged']}/{hlt['byz_screened']} "
              f"flagged; {hlt['threshold_adaptations']} threshold "
              f"adaptation(s)")
        if hlt.get("detection_latency_s") is not None:
            print(f"  health: fault detected "
                  f"{hlt['detection_latency_s']:.2f}s after onset "
                  f"({hlt['detection_latency_hb_intervals']:.2f} heartbeat "
                  f"intervals)")


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--deployment",
                   choices=["edge", "cloud", "integrated", "all"],
                   default="all")
    p.add_argument("--windows", type=int, default=25)
    p.add_argument("--scenario",
                   choices=["none", "gradual", "abrupt", "seasonal"],
                   default="gradual",
                   help="the paper's drift scenario (Sec. 6.1.3): stationary"
                        " stream, Eq. 6 gradual drift, or Eq. 7 abrupt "
                        "drift — plus the seasonal excursion-and-return "
                        "extension")
    p.add_argument("--streams", type=int, default=1,
                   help="fleet size: >1 multiplexes N correlated turbine "
                        "streams over per-stream topics under one "
                        "deployment, training the whole fleet's speed "
                        "models in one vmapped dispatch per window "
                        "(requires --real)")
    p.add_argument("--gated", action="store_true",
                   help="drift-gated retraining (fleet mode): stationary "
                        "streams skip their window's speed training and "
                        "keep serving the prior model")
    p.add_argument("--static", action="store_true",
                   help="static 5:5 weighting instead of dynamic")
    p.add_argument("--quantized", action="store_true",
                   help="int8 model sync: 4x smaller transfers; with --real "
                        "the edge also serves the quantized model through "
                        "the int8 dequant-matmul kernel (in fleet mode, "
                        "per-stream int8 model topics and batched int8 "
                        "fleet inference)")
    p.add_argument("--fast", action="store_true")
    p.add_argument("--real", action="store_true",
                   help="run real LSTM compute through the TopicBus "
                        "(BusExecutor) instead of the calibrated simulation")
    p.add_argument("--period", type=float, default=30.0,
                   help="virtual seconds between stream windows (--real); "
                        "shrink it below the training time to watch "
                        "stale-model inference emerge from event ordering")
    p.add_argument("--qps", type=float, default=0.0,
                   help="request plane: open-loop user-query arrival rate "
                        "across the fleet (point/horizon/what-if forecast "
                        "queries on per-stream request topics, answered by "
                        "continuous-batched serving ticks from the "
                        "device-resident fleet state; fleet mode, i.e. "
                        "--real --streams > 1)")
    p.add_argument("--slots", type=int, default=4,
                   help="request plane: fixed batch slots in the "
                        "slot-recycling continuous batcher")
    p.add_argument("--elastic", nargs="?", const="proactive", default=None,
                   choices=["reactive", "proactive"],
                   help="turn on the elastic placement plane (fleet mode): "
                        "a PlacementController migrates hot/drifting "
                        "streams to cloud and cold ones back to edge, and "
                        "scales Site.workers from queue-depth EWMAs — "
                        "'proactive' (the default when the flag is bare) "
                        "additionally scales ahead of load spikes by "
                        "forecasting the per-site backlog with a small "
                        "speed-layer LSTM")
    p.add_argument("--chaos", default=None,
                   help="run one chaos scenario from core.scenarios "
                        "(fault_free, site_crash, partitioned_sync, "
                        "sensor_chaos, corrupted_int8_sync, forged_sync, "
                        "byzantine, compound_drift) against the fleet under "
                        "a seeded fault plane with the health plane "
                        "attached, and print its degradation envelope + "
                        "health verdicts; honours --streams/"
                        "--windows/--period/--qps/--slots, with chaos-sized "
                        "defaults otherwise")
    p.add_argument("--chaos-seed", type=int, default=0,
                   help="fault-plane seed for --chaos: a different seed "
                        "draws a different (but equally reproducible) "
                        "fault schedule")
    args = p.parse_args()

    if args.chaos is not None:
        from repro.core.scenarios import SCENARIOS

        if args.chaos not in SCENARIOS:
            p.error(f"--chaos {args.chaos!r}: pick from "
                    f"{', '.join(SCENARIOS)}")
        run_chaos(args)
        return
    if args.streams > 1 and not args.real:
        p.error("--streams > 1 requires --real (the fleet executors run "
                "real compute)")
    if args.gated and args.streams <= 1:
        p.error("--gated requires --streams > 1 (drift-gated retraining is "
                "a fleet-executor policy)")
    if args.qps > 0 and not (args.real and args.streams > 1):
        p.error("--qps requires fleet mode (--real with --streams > 1): the "
                "request plane serves from the fleet executor's "
                "device-resident state")
    if args.elastic and not (args.real and args.streams > 1):
        p.error("--elastic requires fleet mode (--real with --streams > 1): "
                "placement is a per-stream fleet decision")
    if args.real and args.streams > 1:
        run_real_fleet(args)
    elif args.real:
        run_real(args)
    else:
        run_calibrated(args)


if __name__ == "__main__":
    main()
