"""Production mesh construction (TPU v5e class).

Defined as FUNCTIONS, not module-level constants, so importing this module
never touches jax device state (smoke tests must keep seeing 1 CPU device;
only the dry-run sets xla_force_host_platform_device_count=512).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh over however many local devices exist (tests)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
