import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, print memory/cost analysis, and derive roofline terms
from the partitioned HLO.

Must be run as its own process (the XLA_FLAGS line above must execute before
jax initializes devices):

    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out experiments/dryrun
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ASSIGNED, SHAPES, get_config, get_shape, shape_applicable
from repro.distributed.sharding import AxisRules, use_mesh_rules
from repro.launch import analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step


def mem_analysis_dict(ma) -> dict:
    keys = (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    )
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


# per-arch winners of the §Perf hillclimb (EXPERIMENTS.md); applied by
# --optimized.  The paper-faithful baseline is the default (no overrides).
OPTIMIZED_PRESETS = {
    "rwkv6-3b": {"scan_chunked": True, "scan_chunk": 64},
    "zamba2-1.2b": {"scan_chunked": True, "scan_chunk": 64},
    "grok-1-314b": {"moe.ep_mode": "shard_map", "moe.capacity_factor": 1.0,
                    "moe_exact_serving": False},
    "tinyllama-1.1b": {"attn_chunk": 2048},
    # capacity fix: 1T params cannot hold f32 AdamW moments in HBM
    "kimi-k2-1t-a32b": {"opt_moment_dtype": "bfloat16"},
}


def parse_overrides(items):
    """--set key=value pairs -> cfg.replace kwargs (moe.* handled)."""
    out = {}
    for it in items or []:
        k, v = it.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v in ("true", "True"):
            v = True
        if v in ("false", "False"):
            v = False
        out[k] = v
    return out


def apply_overrides(cfg, overrides: dict):
    import dataclasses as _dc

    moe_kw = {k[4:]: v for k, v in overrides.items() if k.startswith("moe.")}
    top_kw = {k: v for k, v in overrides.items() if "." not in k}
    if moe_kw and cfg.moe is not None:
        cfg = cfg.replace(moe=_dc.replace(cfg.moe, **moe_kw))
    if top_kw:
        cfg = cfg.replace(**top_kw)
    return cfg


def run_one(arch: str, shape_name: str, multi_pod: bool, remat: str = "block",
            rules: AxisRules | None = None, save_hlo: str | None = None,
            overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = apply_overrides(cfg, overrides)
    shape = get_shape(shape_name)
    ok, why = shape_applicable(cfg, shape)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "skip",
    }
    if not ok:
        rec["skip_reason"] = why
        return rec
    if shape.kind == "train" and remat:
        cfg = cfg.replace(remat=remat)

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    rules = rules or AxisRules()
    t0 = time.perf_counter()
    fn, specs = build_step(cfg, shape, mesh, rules)
    with use_mesh_rules(mesh, rules):
        lowered = jax.jit(fn).lower(**specs)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    ma = compiled.memory_analysis()
    print(f"[{arch} x {shape_name} x {rec['mesh']}] memory_analysis:")
    print(ma)
    ca = {}
    try:
        raw_ca = compiled.cost_analysis()
        if isinstance(raw_ca, (list, tuple)):
            raw_ca = raw_ca[0]
        ca = {k: float(v) for k, v in raw_ca.items()
              if isinstance(v, (int, float))}
        print(f"[{arch} x {shape_name}] cost_analysis flops="
              f"{ca.get('flops', 0):.3e} bytes={ca.get('bytes accessed', 0):.3e}")
    except Exception as e:  # noqa: BLE001
        print("cost_analysis unavailable:", e)

    hlo = compiled.as_text()
    summ = analysis.summarize(hlo)
    mf = analysis.model_flops(cfg, shape)
    rl = analysis.roofline(summ, n_chips, mf)
    print(
        f"[{arch} x {shape_name}] roofline per chip: "
        f"compute={rl.compute_s:.4e}s memory={rl.memory_s:.4e}s "
        f"collective={rl.collective_s:.4e}s dominant={rl.dominant} "
        f"useful_ratio={rl.useful_ratio:.3f}"
    )
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)

    mem = mem_analysis_dict(ma)
    rec.update(
        status="ok",
        t_lower_s=t_lower,
        t_compile_s=t_compile,
        memory_analysis=mem,
        bytes_per_device=mem.get("argument_size_in_bytes", 0)
        + mem.get("temp_size_in_bytes", 0),
        cost_analysis=ca,
        hlo_summary={
            "dot_flops_per_chip": summ.dot_flops,
            "traffic_bytes_per_chip": summ.traffic_bytes,
            "collective_bytes_per_chip": summ.collective_bytes,
            "collectives": summ.collectives,
            "n_while": summ.n_while,
            "trip_counts": summ.trip_counts,
            "param_bytes_per_chip": summ.param_bytes,
        },
        roofline=rl.as_dict(),
        n_chips=n_chips,
    )
    return rec


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--mesh", choices=["single", "multi", "both"],
                   default="single")
    p.add_argument("--all", action="store_true")
    p.add_argument("--out", default="experiments/dryrun")
    p.add_argument("--remat", default="block")
    p.add_argument("--save-hlo", default=None)
    p.add_argument("--set", action="append", dest="overrides", default=[],
                   help="config override key=value (moe.* reaches MoEConfig)")
    p.add_argument("--tag", default="", help="artifact filename suffix")
    p.add_argument("--optimized", action="store_true",
                   help="apply the per-arch §Perf winning overrides")
    args = p.parse_args()
    overrides = parse_overrides(args.overrides)

    os.makedirs(args.out, exist_ok=True)
    archs = [c.name for c in ASSIGNED] if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'multi' if mp else 'single'}"
                if args.tag:
                    tag += f"_{args.tag}"
                ov = dict(overrides)
                if args.optimized:
                    ov = {**OPTIMIZED_PRESETS.get(arch, {}), **ov}
                    tag += "_opt"
                try:
                    rec = run_one(arch, shape, mp, remat=args.remat,
                                  save_hlo=args.save_hlo, overrides=ov)
                except Exception:  # noqa: BLE001
                    traceback.print_exc()
                    rec = {
                        "arch": arch, "shape": shape,
                        "mesh": "2x16x16" if mp else "16x16",
                        "status": "fail",
                        "error": traceback.format_exc()[-2000:],
                    }
                    n_fail += 1
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=2)
                print(f"-> {tag}: {rec['status']}")
    if n_fail:
        raise SystemExit(f"{n_fail} dry-run combos failed")


if __name__ == "__main__":
    main()
