"""Training loops: the generic pjit-able train step factory, and the
small-model ``fit`` used by the paper's batch/speed layers.

``make_train_step(model, opt)`` is the function the multi-pod dry-run lowers
for the ``train_4k`` shape; ``fit`` is the real (executed) loop used for the
LSTM forecaster on CPU and by the end-to-end examples.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.training.optimizer import Optimizer, OptState, adamw

Params = Any
Batch = Dict[str, jax.Array]


def make_train_step(model: Model, opt: Optimizer):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params: Params, opt_state: OptState, batch: Batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
            params, batch
        )
        params, opt_state, opt_metrics = opt.update(grads, opt_state, params)
        return params, opt_state, {**metrics, **opt_metrics, "loss": loss}

    return train_step


def make_eval_step(model: Model):
    def eval_step(params: Params, batch: Batch):
        loss, metrics = model.loss_fn(params, batch)
        return {**metrics, "loss": loss}

    return eval_step


@dataclass
class FitResult:
    params: Params
    opt_state: OptState
    history: list
    wall_time_s: float
    steps: int


def batch_iterator(data: Dict[str, np.ndarray], batch_size: int, epochs: int,
                   key: jax.Array, shuffle: bool = True) -> Iterable[Batch]:
    """Epoch-based minibatcher over array dicts (leading dim = examples).

    Every example is yielded every epoch: the final batch is ragged when
    ``n % batch_size != 0`` (the speed layer's freshest records live in that
    tail — dropping it, as this iterator once did, starved the model of up
    to ``batch_size - 1`` of each window's newest examples).  The ragged
    shape costs the legacy path one extra compile; the compiled hot path
    (``repro.training.compiled``) avoids it by padding to shape buckets."""
    n = len(next(iter(data.values())))
    for e in range(epochs):
        if shuffle:
            key, sub = jax.random.split(key)
            perm = np.asarray(jax.random.permutation(sub, n))
        else:
            perm = np.arange(n)
        for i in range(0, n, batch_size):
            idx = perm[i : i + batch_size]
            yield {k: jnp.asarray(v[idx]) for k, v in data.items()}


def fit(
    model: Model,
    data: Dict[str, np.ndarray],
    *,
    epochs: int,
    batch_size: int,
    lr: float = 1e-3,
    params: Optional[Params] = None,
    opt: Optional[Optimizer] = None,
    key: Optional[jax.Array] = None,
    log_every: int = 0,
) -> FitResult:
    """Executed training loop (paper batch/speed training).  jit-compiled
    train step, python epoch loop — matches the paper's Keras-style setup."""
    key = key if key is not None else jax.random.PRNGKey(0)
    if params is None:
        params = model.init(key)
    opt = opt or adamw(lr)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, opt))

    history = []
    t0 = time.perf_counter()
    steps = 0
    for batch in batch_iterator(data, batch_size, epochs, key):
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        steps += 1
        if log_every and steps % log_every == 0:
            history.append({k: float(v) for k, v in metrics.items()})
    # make sure async dispatch is done before timing
    jax.block_until_ready(jax.tree_util.tree_leaves(params)[0])
    wall = time.perf_counter() - t0
    if not history:
        history.append({"loss": float(metrics["loss"])} if steps else {})
    return FitResult(params=params, opt_state=opt_state, history=history,
                     wall_time_s=wall, steps=steps)
