"""Structured metric logging: JSONL writer + in-memory aggregator used by
the training loop, the serving engine and the edge-cloud runtime.

Deliberately dependency-free (no tensorboard in this container); the JSONL
files are what the benchmarks and EXPERIMENTS.md tables are generated from.
"""
from __future__ import annotations

import json
import os
import time
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np


def _scalarize(v: Any) -> Any:
    try:
        return float(v)
    except (TypeError, ValueError):
        return str(v)


@dataclass
class MetricLogger:
    """Append-only JSONL metric stream with windowed means."""

    path: Optional[str] = None
    _rows: List[Dict[str, Any]] = field(default_factory=list)
    _fh: Any = None

    def __post_init__(self):
        if self.path:
            os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
            self._fh = open(self.path, "a")

    def log(self, step: int, **metrics: Any) -> None:
        row = {"step": int(step), "time": time.time()}
        row.update({k: _scalarize(v) for k, v in metrics.items()})
        self._rows.append(row)
        if self._fh:
            self._fh.write(json.dumps(row) + "\n")
            self._fh.flush()

    def close(self) -> None:
        if self._fh:
            self._fh.close()
            self._fh = None

    # -- aggregation ---------------------------------------------------------

    def mean(self, key: str, last_n: Optional[int] = None) -> float:
        vals = [r[key] for r in self._rows if key in r
                and isinstance(r[key], float)]
        if last_n:
            vals = vals[-last_n:]
        return float(np.mean(vals)) if vals else float("nan")

    def series(self, key: str) -> List[float]:
        return [r[key] for r in self._rows if key in r
                and isinstance(r[key], float)]

    def summary(self) -> Dict[str, Dict[str, float]]:
        cols = defaultdict(list)
        for r in self._rows:
            for k, v in r.items():
                if k in ("step", "time") or not isinstance(v, float):
                    continue
                cols[k].append(v)
        return {
            k: {"mean": float(np.mean(v)), "min": float(np.min(v)),
                "max": float(np.max(v)), "last": v[-1], "n": len(v)}
            for k, v in cols.items() if v
        }

    @classmethod
    def read(cls, path: str) -> "MetricLogger":
        ml = cls()
        with open(path) as f:
            for line in f:
                if line.strip():
                    ml._rows.append(json.loads(line))
        return ml
