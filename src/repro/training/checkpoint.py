"""npz checkpointing with path-flattened keys.

This is the artifact that the paper synchronizes edge<->cloud via a
pre-signed S3 URL: the runtime's model-sync message carries a
``CheckpointHandle`` (path + nbytes) and the link model charges
``nbytes / bandwidth`` for the transfer.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

SEP = "::"
BF16_TAG = "__bf16__"  # numpy can't persist ml_dtypes.bfloat16; store u16 view


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}

    def visit(path, x):
        keys = []
        for p in path:
            keys.append(str(getattr(p, "key", getattr(p, "idx", p))))
        arr = np.asarray(x)
        key = SEP.join(keys)
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
            key = BF16_TAG + key
        flat[key] = arr
        return x

    jax.tree_util.tree_map_with_path(visit, tree)
    return flat


def _unflatten(flat: Dict[str, np.ndarray]) -> Any:
    tree: Dict[str, Any] = {}
    for k, v in flat.items():
        if k.startswith(BF16_TAG):
            k = k[len(BF16_TAG):]
            v = jnp.asarray(v.view(jnp.bfloat16))
        parts = k.split(SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(v)
    return tree


@dataclass(frozen=True)
class CheckpointHandle:
    path: str
    nbytes: int
    step: int = 0
    meta: Optional[Dict[str, Any]] = None


def save(path: str, tree: Any, step: int = 0,
         meta: Optional[Dict[str, Any]] = None) -> CheckpointHandle:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    full = path if path.endswith(".npz") else path + ".npz"
    if meta is not None or step:
        with open(full + ".json", "w") as f:
            json.dump({"step": step, "meta": meta or {}}, f)
    nbytes = sum(v.nbytes for v in flat.values())
    return CheckpointHandle(path=full, nbytes=nbytes, step=step, meta=meta)


def load(path: str) -> Any:
    full = path if path.endswith(".npz") else path + ".npz"
    with np.load(full) as z:
        flat = {k: z[k] for k in z.files}
    return _unflatten(flat)


def nbytes_of(tree: Any) -> int:
    return sum(int(np.asarray(x).nbytes) for x in jax.tree_util.tree_leaves(tree))
