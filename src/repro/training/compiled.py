"""Compile-once training hot path for the speed layer.

The legacy ``fit`` (``train_loop.py``) rebuilds ``jax.jit(make_train_step)``
on every call, so every 30 s stream window pays a fresh XLA trace+compile,
and its Python minibatch loop pays ``epochs x steps`` device dispatches.
That is exactly the cost the paper's Table-3 latency claim says the speed
layer cannot afford: at the edge the steady-state per-window cost is the
quantity that matters, not the cold start.

``CompiledForecaster`` makes the per-window path compile exactly once and
stay dispatch-light forever after:

* **one executable per shape bucket** — windows are padded up to a small
  set of fixed shape buckets (``bucket_examples``: the next power-of-two
  multiple of ``batch_size``), with a per-example validity mask threaded
  into the model's ``loss_fn`` so padding never biases the gradient.  Every
  window of the stream therefore hits the same compiled executable, and the
  ragged final batch the legacy iterator dropped is trained on.
* **one dispatch per fit** — the whole fit (epoch permutations, minibatch
  gather, ``epochs x steps`` optimizer updates) is a single jitted
  ``lax.scan`` over a device-resident pre-permuted epoch index tensor,
  instead of a Python loop dispatching one step at a time.
* **donated buffers** — params and optimizer state are donated
  (``donate_argnums``) so the update runs in place where the backend
  supports it.
* **counted retraces** — every cache entry counts its actual traces (the
  Python body only runs when XLA traces it), so benchmarks and regression
  tests can assert that windows 2..N of a shape bucket perform zero new
  traces.
"""
from __future__ import annotations

import math
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.training.optimizer import Optimizer, adamw
from repro.training.train_loop import make_train_step

Params = Any


def bucket_examples(n: int, batch_size: int) -> int:
    """Fixed-shape bucket for an ``n``-example window: the next power-of-two
    multiple of ``batch_size``.  Buckets grow geometrically, so a stream of
    arbitrary window sizes touches only O(log n) compiled executables, and
    the paper's fixed-size windows (150/250 records) always reuse one."""
    if n <= 0:
        raise ValueError(f"cannot bucket an empty window (n={n})")
    per = max(1, math.ceil(n / batch_size))
    return batch_size * (1 << max(0, math.ceil(math.log2(per))))


def pad_to_bucket(data: Dict[str, np.ndarray], nb: int) -> Dict[str, np.ndarray]:
    """Zero-pad every array's leading dim to ``nb`` and attach a f32 validity
    ``mask`` (1 for real examples, 0 for padding)."""
    n = len(next(iter(data.values())))
    if n > nb:
        raise ValueError(f"window of {n} examples exceeds bucket {nb}")
    out = {}
    for k, v in data.items():
        v = np.asarray(v)
        if n < nb:
            pad = np.zeros((nb - n,) + v.shape[1:], v.dtype)
            v = np.concatenate([v, pad], axis=0)
        out[k] = v
    mask = np.zeros((nb,), np.float32)
    mask[:n] = 1.0
    out["mask"] = mask
    return out


def _next_pow2(n: int) -> int:
    return 1 << max(0, math.ceil(math.log2(max(n, 1))))


class CompiledForecaster:
    """Speed-layer trainer with a compile-once, dispatch-light hot path.

    Matches the ``Forecaster`` protocol (``train(data, params, key) ->
    (params, wall_s)``; ``predict(params, x) -> np.ndarray``) so it drops
    into ``SpeedTraining`` / both executors unchanged.  The jitted epoch-scan
    executable is cached per shape bucket — model, optimizer, epochs and
    batch size are fixed per instance, so the effective cache key is
    (model, optimizer, batch shape); warm and cold starts share the same
    executable.

    The model's ``loss_fn`` must honor an optional per-example ``mask`` key
    in the batch (as ``repro.models.lstm.loss_fn`` does) whenever a window
    needs padding; the first padded window of each bucket runs a one-time
    numeric check and raises if the mask is ignored, so a mask-blind model
    can never be silently biased toward its padding.
    """

    def __init__(
        self,
        model: Model,
        *,
        epochs: int,
        batch_size: int,
        lr: float = 1e-3,
        opt: Optional[Optimizer] = None,
        warm_start: bool = False,
        predict_fn: Optional[Callable[[Params, jax.Array], jax.Array]] = None,
    ):
        self.model = model
        self.epochs = int(epochs)
        self.batch_size = int(batch_size)
        self.warm_start = warm_start
        self.opt = opt or adamw(lr)
        self._fit_cache: Dict[int, Callable] = {}
        self._trace_counts: Dict[int, int] = {}
        self._mask_checked: set = set()
        self._init_fn = jax.jit(model.init)
        self._opt_init = jax.jit(self.opt.init)
        self._predict_fn = (jax.jit(predict_fn) if predict_fn is not None
                            else None)
        self.last_losses: Optional[np.ndarray] = None

    # -- compile-cache introspection ----------------------------------------

    @property
    def retrace_count(self) -> int:
        """Total XLA traces of the fit executable across all shape buckets."""
        return sum(self._trace_counts.values())

    @property
    def cache_size(self) -> int:
        return len(self._fit_cache)

    def trace_counts(self) -> Dict[int, int]:
        """Per-shape-bucket XLA trace counts."""
        return dict(self._trace_counts)

    # -- the cached fit executable ------------------------------------------

    def _fit_fn(self, nb: int) -> Callable:
        """One executable per bucket ``nb``; warm and cold starts share it
        (params enter as an argument either way)."""
        fn = self._fit_cache.get(nb)
        if fn is not None:
            return fn
        epochs, bs = self.epochs, self.batch_size
        steps = nb // bs
        train_step = make_train_step(self.model, self.opt)
        counts = self._trace_counts
        counts.setdefault(nb, 0)

        def epoch_scan_fit(params, opt_state, x, y, mask, rng):
            # executes only while XLA traces — counts real retraces
            counts[nb] += 1
            perms = jax.vmap(lambda k: jax.random.permutation(k, nb))(
                jax.random.split(rng, epochs))
            idx = perms.reshape(epochs * steps, bs)

            def body(carry, ib):
                params, opt_state = carry
                batch = {"x": x[ib], "y": y[ib], "mask": mask[ib]}
                params, opt_state, metrics = train_step(params, opt_state,
                                                        batch)
                return (params, opt_state), metrics["loss"]

            (params, opt_state), losses = jax.lax.scan(
                body, (params, opt_state), idx)
            return params, opt_state, losses

        fn = jax.jit(epoch_scan_fit, donate_argnums=(0, 1))
        self._fit_cache[nb] = fn
        return fn

    def _check_mask_honored(self, data: Dict[str, np.ndarray],
                            padded: Dict[str, np.ndarray], params: Params,
                            nb: int) -> None:
        """One-time (per bucket) guard: when a window actually needed
        padding, the masked loss on the padded batch must equal the plain
        loss on the unpadded batch.  A model whose ``loss_fn`` ignores the
        validity mask would otherwise silently average its padding rows into
        every gradient."""
        n = len(next(iter(data.values())))
        if n == nb or nb in self._mask_checked:
            return
        plain, _ = self.model.loss_fn(
            params, {k: jnp.asarray(v) for k, v in data.items()})
        masked, _ = self.model.loss_fn(
            params, {k: jnp.asarray(v) for k, v in padded.items()})
        if not np.allclose(np.asarray(plain), np.asarray(masked),
                           rtol=1e-4, atol=1e-6):
            raise ValueError(
                "model.loss_fn ignores the per-example validity 'mask': "
                f"padded-batch loss {float(masked):.6g} != unpadded loss "
                f"{float(plain):.6g}. Fixed-shape bucketing would bias "
                "training toward the padding; thread batch['mask'] into the "
                "loss as repro.models.lstm.loss_fn does.")
        self._mask_checked.add(nb)

    # -- Forecaster protocol -------------------------------------------------

    def train(self, data: Dict[str, np.ndarray], params: Optional[Params],
              key: jax.Array) -> Tuple[Params, float]:
        t0 = time.perf_counter()
        n = len(next(iter(data.values())))
        nb = bucket_examples(n, self.batch_size)
        init_key, perm_key = jax.random.split(key)
        warm = self.warm_start and params is not None
        if warm:
            # an int8-synced serving model (QTensor leaves) can seed a warm
            # start, but training runs in float: dequantize first
            from repro.serving.quantize import dequantize_tree

            params = dequantize_tree(params)
            # the fit executable donates its params buffer; the caller-held
            # tree (the serving model) must survive, so warm starts hand the
            # executable a private copy
            params = jax.tree_util.tree_map(jnp.array, params)
        else:
            params = self._init_fn(init_key)
        opt_state = self._opt_init(params)
        padded = pad_to_bucket(data, nb)
        self._check_mask_honored(data, padded, params, nb)
        params, _, losses = self._fit_fn(nb)(
            params, opt_state,
            jnp.asarray(padded["x"]), jnp.asarray(padded["y"]),
            jnp.asarray(padded["mask"]), perm_key)
        jax.block_until_ready(params)
        self.last_losses = np.asarray(losses)
        return params, time.perf_counter() - t0

    def predict(self, params: Params, x: np.ndarray) -> np.ndarray:
        if self._predict_fn is None:
            raise ValueError("CompiledForecaster built without a predict_fn")
        x = np.asarray(x)
        n = x.shape[0]
        nb = _next_pow2(n)  # bucket inference shapes too: O(log n) compiles
        if n < nb:
            x = np.concatenate(
                [x, np.zeros((nb - n,) + x.shape[1:], x.dtype)], axis=0)
        return np.asarray(self._predict_fn(params, jnp.asarray(x)))[:n]
