"""Compile-once training hot path for the speed layer.

The legacy ``fit`` (``train_loop.py``) rebuilds ``jax.jit(make_train_step)``
on every call, so every 30 s stream window pays a fresh XLA trace+compile,
and its Python minibatch loop pays ``epochs x steps`` device dispatches.
That is exactly the cost the paper's Table-3 latency claim says the speed
layer cannot afford: at the edge the steady-state per-window cost is the
quantity that matters, not the cold start.

``CompiledForecaster`` makes the per-window path compile exactly once and
stay dispatch-light forever after:

* **one executable per shape bucket** — windows are padded up to a small
  set of fixed shape buckets (``bucket_examples``: the next power-of-two
  multiple of ``batch_size``), with a per-example validity mask threaded
  into the model's ``loss_fn`` so padding never biases the gradient.  Every
  window of the stream therefore hits the same compiled executable, and the
  ragged final batch the legacy iterator dropped is trained on.
* **one dispatch per fit** — the whole fit (epoch permutations, minibatch
  gather, ``epochs x steps`` optimizer updates) is a single jitted
  ``lax.scan`` over a device-resident pre-permuted epoch index tensor,
  instead of a Python loop dispatching one step at a time.
* **donated buffers** — params and optimizer state are donated
  (``donate_argnums``) so the update runs in place where the backend
  supports it.
* **counted retraces** — every cache entry counts its actual traces (the
  Python body only runs when XLA traces it), so benchmarks and regression
  tests can assert that windows 2..N of a shape bucket perform zero new
  traces.

``FleetForecaster`` lifts the same hot path to a *fleet* of streams: the
whole fleet's speed models train in **one** device dispatch per window — a
vmapped cold-start fit over a stacked leading stream axis, cached per
(stream-count bucket, shape bucket).  Stream-count padding works exactly
like batch padding: padded stream slots carry an all-zero validity mask, so
they contribute zero loss and zero gradient and their (discarded) params
never move.
"""
from __future__ import annotations

import math
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.training.optimizer import Optimizer, adamw
from repro.training.train_loop import make_train_step

Params = Any


def bucket_examples(n: int, batch_size: int) -> int:
    """Fixed-shape bucket for an ``n``-example window: the next power-of-two
    multiple of ``batch_size``.  Buckets grow geometrically, so a stream of
    arbitrary window sizes touches only O(log n) compiled executables, and
    the paper's fixed-size windows (150/250 records) always reuse one."""
    if n <= 0:
        raise ValueError(f"cannot bucket an empty window (n={n})")
    per = max(1, math.ceil(n / batch_size))
    return batch_size * (1 << max(0, math.ceil(math.log2(per))))


def pad_to_bucket(data: Dict[str, np.ndarray], nb: int) -> Dict[str, np.ndarray]:
    """Zero-pad every array's leading dim to ``nb`` and attach a f32 validity
    ``mask`` (1 for real examples, 0 for padding)."""
    n = len(next(iter(data.values())))
    if n > nb:
        raise ValueError(f"window of {n} examples exceeds bucket {nb}")
    out = {}
    for k, v in data.items():
        v = np.asarray(v)
        if n < nb:
            pad = np.zeros((nb - n,) + v.shape[1:], v.dtype)
            v = np.concatenate([v, pad], axis=0)
        out[k] = v
    mask = np.zeros((nb,), np.float32)
    mask[:n] = 1.0
    out["mask"] = mask
    return out


def _next_pow2(n: int) -> int:
    return 1 << max(0, math.ceil(math.log2(max(n, 1))))


def bucket_streams(s: int) -> int:
    """Stream-count bucket for an ``s``-stream fleet training batch: the next
    power of two.  Like shape buckets, stream-count buckets grow
    geometrically, so a fleet of any size — or any drift-gated *subset* of
    it — touches only O(log S) compiled fleet executables."""
    if s <= 0:
        raise ValueError(f"cannot bucket an empty fleet (s={s})")
    return _next_pow2(s)


def _make_epoch_scan(model: Model, opt: Optimizer, epochs: int,
                     batch_size: int, nb: int):
    """The pure epoch-scan fit body shared by the single-stream and fleet
    trainers: the whole fit (per-epoch permutations, minibatch gather,
    ``epochs x steps`` optimizer updates) is one ``lax.scan`` over a
    device-resident pre-permuted epoch index tensor."""
    steps = nb // batch_size
    train_step = make_train_step(model, opt)

    def epoch_scan_fit(params, opt_state, x, y, mask, rng):
        perms = jax.vmap(lambda k: jax.random.permutation(k, nb))(
            jax.random.split(rng, epochs))
        idx = perms.reshape(epochs * steps, batch_size)

        def body(carry, ib):
            params, opt_state = carry
            batch = {"x": x[ib], "y": y[ib], "mask": mask[ib]}
            params, opt_state, metrics = train_step(params, opt_state,
                                                    batch)
            return (params, opt_state), metrics["loss"]

        (params, opt_state), losses = jax.lax.scan(
            body, (params, opt_state), idx)
        return params, opt_state, losses

    return epoch_scan_fit


class CompiledForecaster:
    """Speed-layer trainer with a compile-once, dispatch-light hot path.

    Matches the ``Forecaster`` protocol (``train(data, params, key) ->
    (params, wall_s)``; ``predict(params, x) -> np.ndarray``) so it drops
    into ``SpeedTraining`` / both executors unchanged.  The jitted epoch-scan
    executable is cached per shape bucket — model, optimizer, epochs and
    batch size are fixed per instance, so the effective cache key is
    (model, optimizer, batch shape); warm and cold starts share the same
    executable.

    The model's ``loss_fn`` must honor an optional per-example ``mask`` key
    in the batch (as ``repro.models.lstm.loss_fn`` does) whenever a window
    needs padding; the first padded window of each bucket runs a one-time
    numeric check and raises if the mask is ignored, so a mask-blind model
    can never be silently biased toward its padding.
    """

    def __init__(
        self,
        model: Model,
        *,
        epochs: int,
        batch_size: int,
        lr: float = 1e-3,
        opt: Optional[Optimizer] = None,
        warm_start: bool = False,
        predict_fn: Optional[Callable[[Params, jax.Array], jax.Array]] = None,
    ):
        self.model = model
        self.epochs = int(epochs)
        self.batch_size = int(batch_size)
        self.warm_start = warm_start
        self.opt = opt or adamw(lr)
        self._fit_cache: Dict[int, Callable] = {}
        self._trace_counts: Dict[int, int] = {}
        self._mask_checked: set = set()
        self._init_fn = jax.jit(model.init)
        self._opt_init = jax.jit(self.opt.init)
        self._predict_fn = (jax.jit(predict_fn) if predict_fn is not None
                            else None)
        self.last_losses: Optional[np.ndarray] = None

    # -- compile-cache introspection ----------------------------------------

    @property
    def retrace_count(self) -> int:
        """Total XLA traces of the fit executable across all shape buckets."""
        return sum(self._trace_counts.values())

    @property
    def cache_size(self) -> int:
        return len(self._fit_cache)

    def trace_counts(self) -> Dict[int, int]:
        """Per-shape-bucket XLA trace counts."""
        return dict(self._trace_counts)

    # -- the cached fit executable ------------------------------------------

    def _fit_fn(self, nb: int) -> Callable:
        """One executable per bucket ``nb``; warm and cold starts share it
        (params enter as an argument either way)."""
        fn = self._fit_cache.get(nb)
        if fn is not None:
            return fn
        scan_fit = _make_epoch_scan(self.model, self.opt, self.epochs,
                                    self.batch_size, nb)
        counts = self._trace_counts
        counts.setdefault(nb, 0)

        def epoch_scan_fit(params, opt_state, x, y, mask, rng):
            # executes only while XLA traces — counts real retraces
            counts[nb] += 1
            return scan_fit(params, opt_state, x, y, mask, rng)

        fn = jax.jit(epoch_scan_fit, donate_argnums=(0, 1))
        self._fit_cache[nb] = fn
        return fn

    def _check_mask_honored(self, data: Dict[str, np.ndarray],
                            padded: Dict[str, np.ndarray], params: Params,
                            nb: int) -> None:
        """One-time (per bucket) guard: when a window actually needed
        padding, the masked loss on the padded batch must equal the plain
        loss on the unpadded batch.  A model whose ``loss_fn`` ignores the
        validity mask would otherwise silently average its padding rows into
        every gradient."""
        n = len(next(iter(data.values())))
        if n == nb or nb in self._mask_checked:
            return
        plain, _ = self.model.loss_fn(
            params, {k: jnp.asarray(v) for k, v in data.items()})
        masked, _ = self.model.loss_fn(
            params, {k: jnp.asarray(v) for k, v in padded.items()})
        if not np.allclose(np.asarray(plain), np.asarray(masked),
                           rtol=1e-4, atol=1e-6):
            raise ValueError(
                "model.loss_fn ignores the per-example validity 'mask': "
                f"padded-batch loss {float(masked):.6g} != unpadded loss "
                f"{float(plain):.6g}. Fixed-shape bucketing would bias "
                "training toward the padding; thread batch['mask'] into the "
                "loss as repro.models.lstm.loss_fn does.")
        self._mask_checked.add(nb)

    # -- Forecaster protocol -------------------------------------------------

    def train(self, data: Dict[str, np.ndarray], params: Optional[Params],
              key: jax.Array) -> Tuple[Params, float]:
        t0 = time.perf_counter()
        n = len(next(iter(data.values())))
        nb = bucket_examples(n, self.batch_size)
        init_key, perm_key = jax.random.split(key)
        warm = self.warm_start and params is not None
        if warm:
            # an int8-synced serving model (QTensor leaves) can seed a warm
            # start, but training runs in float: dequantize first
            from repro.serving.quantize import dequantize_tree

            params = dequantize_tree(params)
            # the fit executable donates its params buffer; the caller-held
            # tree (the serving model) must survive, so warm starts hand the
            # executable a private copy
            params = jax.tree_util.tree_map(jnp.array, params)
        else:
            params = self._init_fn(init_key)
        opt_state = self._opt_init(params)
        padded = pad_to_bucket(data, nb)
        self._check_mask_honored(data, padded, params, nb)
        params, _, losses = self._fit_fn(nb)(
            params, opt_state,
            jnp.asarray(padded["x"]), jnp.asarray(padded["y"]),
            jnp.asarray(padded["mask"]), perm_key)
        jax.block_until_ready(params)
        self.last_losses = np.asarray(losses)
        return params, time.perf_counter() - t0

    def predict(self, params: Params, x: np.ndarray) -> np.ndarray:
        if self._predict_fn is None:
            raise ValueError("CompiledForecaster built without a predict_fn")
        x = np.asarray(x)
        n = x.shape[0]
        nb = _next_pow2(n)  # bucket inference shapes too: O(log n) compiles
        if n < nb:
            x = np.concatenate(
                [x, np.zeros((nb - n,) + x.shape[1:], x.dtype)], axis=0)
        return np.asarray(self._predict_fn(params, jnp.asarray(x)))[:n]


class FleetForecaster:
    """Fleet-axis trainer: one speed model per stream, the whole fleet fit
    in **one device dispatch** per window.

    Wraps a single-stream :class:`CompiledForecaster` (exposed as
    ``.single``, and via delegating ``train``/``predict`` so a
    ``FleetForecaster`` satisfies the ``Forecaster`` protocol anywhere a
    single-stream trainer is expected).  ``train_fleet`` stacks the fleet's
    padded windows along a new leading stream axis and runs a vmapped
    cold-start fit — per-stream param init, optimizer init, and the shared
    epoch-scan body — inside a single jitted executable, cached per
    (stream-count bucket, shape bucket):

    * the per-stream key derivation (``init_key, perm_key = split(key)``)
      is byte-identical to the single-stream path, so stream ``i`` of a
      fleet fit trains from the same init, with the same minibatch
      permutations, as a sequential ``CompiledForecaster.train`` given the
      same key — fleet-vs-sequential parity is a numerical (vmap batching)
      tolerance, not a semantic difference;
    * the stream axis is padded up to ``bucket_streams(s)`` with zero-data,
      all-zero-mask slots, exactly like batch padding: a padded slot's loss
      and gradient are exactly zero, so its (discarded) params never move
      and the optimizer's global-norm clip is unaffected;
    * streams whose windows fall in different *shape* buckets are grouped,
      one dispatch per group — a homogeneous fleet (the paper's fixed-size
      windows) always trains in exactly one;
    * a single-stream group (s == 1) delegates to the wrapped
      ``CompiledForecaster``, keeping the single-stream path byte-identical
      to the pre-fleet code.

    ``train_dispatches`` counts fit-executable invocations (what
    ``benchmarks/bench_fleet.py`` asserts is one per window for a
    homogeneous fleet); ``trace_counts`` exposes per-bucket XLA traces so
    the zero-retrace-after-first-window property stays testable.
    """

    def __init__(
        self,
        model: Model,
        *,
        epochs: int,
        batch_size: int,
        lr: float = 1e-3,
        opt: Optional[Optimizer] = None,
        predict_fn: Optional[Callable[[Params, jax.Array], jax.Array]] = None,
    ):
        self.single = CompiledForecaster(
            model, epochs=epochs, batch_size=batch_size, lr=lr, opt=opt,
            predict_fn=predict_fn)
        self.model = model
        self.epochs = int(epochs)
        self.batch_size = int(batch_size)
        self.opt = self.single.opt
        self._fleet_cache: Dict[Tuple[int, int], Callable] = {}
        self._trace_counts: Dict[Tuple[int, int], int] = {}
        self.train_dispatches = 0
        # per-stream minibatch-loss trajectories of the last train_fleet call
        self.last_losses: Optional[List[Optional[np.ndarray]]] = None

    # -- Forecaster protocol (the fleet's single-stream view) ----------------

    def train(self, data: Dict[str, np.ndarray], params: Optional[Params],
              key: jax.Array) -> Tuple[Params, float]:
        return self.single.train(data, params, key)

    def predict(self, params: Params, x: np.ndarray) -> np.ndarray:
        return self.single.predict(params, x)

    # -- compile-cache introspection ----------------------------------------

    @property
    def retrace_count(self) -> int:
        """Fleet-executable XLA traces across all (stream, shape) buckets
        (the delegated single-stream path counts its own)."""
        return sum(self._trace_counts.values())

    @property
    def cache_size(self) -> int:
        return len(self._fleet_cache)

    def trace_counts(self) -> Dict[Tuple[int, int], int]:
        """Per-(stream-count bucket, shape bucket) XLA trace counts."""
        return dict(self._trace_counts)

    # -- the cached fleet-fit executable ------------------------------------

    def _fleet_fit_fn(self, sb: int, nb: int) -> Callable:
        cache_key = (sb, nb)
        fn = self._fleet_cache.get(cache_key)
        if fn is not None:
            return fn
        scan_fit = _make_epoch_scan(self.model, self.opt, self.epochs,
                                    self.batch_size, nb)
        init = self.model.init
        opt_init = self.opt.init
        counts = self._trace_counts
        counts.setdefault(cache_key, 0)

        def cold_fit(init_key, perm_key, x, y, mask):
            params = init(init_key)
            opt_state = opt_init(params)
            params, _, losses = scan_fit(params, opt_state, x, y, mask,
                                         perm_key)
            return params, losses

        def fleet_fit(init_keys, perm_keys, x, y, mask):
            # executes only while XLA traces — counts real retraces
            counts[cache_key] += 1
            return jax.vmap(cold_fit)(init_keys, perm_keys, x, y, mask)

        fn = jax.jit(fleet_fit)
        self._fleet_cache[cache_key] = fn
        return fn

    # -- the fleet fit -------------------------------------------------------

    def train_fleet(self, datas: Sequence[Dict[str, np.ndarray]],
                    keys: Sequence[jax.Array]
                    ) -> Tuple[List[Params], float]:
        """Cold-start fit of one speed model per stream; returns the
        per-stream params (same order as ``datas``) and the total wall.

        ``keys[i]`` plays exactly the role ``key`` plays in
        ``CompiledForecaster.train`` for stream ``i``."""
        t0 = time.perf_counter()
        if len(datas) != len(keys):
            raise ValueError(f"{len(datas)} windows but {len(keys)} keys")
        out: List[Optional[Params]] = [None] * len(datas)
        if not datas:
            return [], 0.0
        groups: Dict[int, List[int]] = {}
        for i, d in enumerate(datas):
            n = len(next(iter(d.values())))
            groups.setdefault(bucket_examples(n, self.batch_size), []).append(i)
        losses: List[Optional[np.ndarray]] = [None] * len(datas)
        for nb, idxs in sorted(groups.items()):
            if len(idxs) == 1:
                # byte-identical single-stream path (no vmap, no S padding)
                i = idxs[0]
                out[i], _ = self.single.train(datas[i], None, keys[i])
                losses[i] = self.single.last_losses
                self.train_dispatches += 1
                continue
            for i, l in zip(idxs, self._fit_group(nb, idxs, datas, keys, out)):
                losses[i] = l
        self.last_losses = losses
        return out, time.perf_counter() - t0

    def _fit_group(self, nb: int, idxs: List[int],
                   datas: Sequence[Dict[str, np.ndarray]],
                   keys: Sequence[jax.Array],
                   out: List[Optional[Params]]) -> np.ndarray:
        s = len(idxs)
        sb = bucket_streams(s)
        split = [jax.random.split(keys[i]) for i in idxs]
        init_keys = [k[0] for k in split]
        perm_keys = [k[1] for k in split]
        padded = [pad_to_bucket(datas[i], nb) for i in idxs]
        self._check_mask_honored(datas[idxs[0]], padded[0], nb, init_keys[0])
        xs = [p["x"] for p in padded]
        ys = [p["y"] for p in padded]
        masks = [p["mask"] for p in padded]
        for j in range(sb - s):
            # stream-axis padding: zero data + all-zero validity mask, so the
            # slot's loss/grad are exactly zero (any key gives a fine inert
            # init; fold_in keeps it deterministic)
            xs.append(np.zeros_like(xs[0]))
            ys.append(np.zeros_like(ys[0]))
            masks.append(np.zeros_like(masks[0]))
            pad_key = jax.random.fold_in(keys[idxs[0]], 1 + j)
            ik, pk = jax.random.split(pad_key)
            init_keys.append(ik)
            perm_keys.append(pk)
        params_S, losses_S = self._fleet_fit_fn(sb, nb)(
            jnp.stack(init_keys), jnp.stack(perm_keys),
            jnp.asarray(np.stack(xs)), jnp.asarray(np.stack(ys)),
            jnp.asarray(np.stack(masks)))
        jax.block_until_ready(params_S)
        self.train_dispatches += 1
        for j, i in enumerate(idxs):
            out[i] = jax.tree_util.tree_map(lambda a, j=j: a[j], params_S)
        return np.asarray(losses_S)[:s]

    def _check_mask_honored(self, data: Dict[str, np.ndarray],
                            padded: Dict[str, np.ndarray], nb: int,
                            init_key: jax.Array) -> None:
        """One-time (per shape bucket) mask guard, same contract as the
        single-stream trainer's; shares its dedup set so a bucket checked by
        either path is checked once.  A window that exactly fills its
        bucket needs no padding and no check (and must not pay the
        throwaway init every window)."""
        n = len(next(iter(data.values())))
        if n == nb or nb in self.single._mask_checked:
            return
        params = self.single._init_fn(init_key)
        self.single._check_mask_honored(data, padded, params, nb)
