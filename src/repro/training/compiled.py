"""Compile-once training hot path for the speed layer.

The legacy ``fit`` (``train_loop.py``) rebuilds ``jax.jit(make_train_step)``
on every call, so every 30 s stream window pays a fresh XLA trace+compile,
and its Python minibatch loop pays ``epochs x steps`` device dispatches.
That is exactly the cost the paper's Table-3 latency claim says the speed
layer cannot afford: at the edge the steady-state per-window cost is the
quantity that matters, not the cold start.

``CompiledForecaster`` makes the per-window path compile exactly once and
stay dispatch-light forever after:

* **one executable per shape bucket** — windows are padded up to a small
  set of fixed shape buckets (``bucket_examples``: the next power-of-two
  multiple of ``batch_size``), with a per-example validity mask threaded
  into the model's ``loss_fn`` so padding never biases the gradient.  Every
  window of the stream therefore hits the same compiled executable, and the
  ragged final batch the legacy iterator dropped is trained on.
* **one dispatch per fit** — the whole fit (epoch permutations, minibatch
  gather, ``epochs x steps`` optimizer updates) is a single jitted
  ``lax.scan`` over a device-resident pre-permuted epoch index tensor,
  instead of a Python loop dispatching one step at a time.
* **donated buffers** — params and optimizer state are donated
  (``donate_argnums``) so the update runs in place where the backend
  supports it.
* **counted retraces** — every cache entry counts its actual traces (the
  Python body only runs when XLA traces it), so benchmarks and regression
  tests can assert that windows 2..N of a shape bucket perform zero new
  traces.

``FleetForecaster`` lifts the same hot path to a *fleet* of streams: the
whole fleet's speed models train in **one** device dispatch per window — a
vmapped cold-start fit over a stacked leading stream axis, cached per
(stream-count bucket, shape bucket).  Stream-count padding works exactly
like batch padding: padded stream slots carry an all-zero validity mask, so
they contribute zero loss and zero gradient and their (discarded) params
never move.

The fleet hot path is memory-resident across windows:

* **staged device buffers** — each window's examples are written into a
  persistent per-(stream bucket, shape bucket) staging buffer and shipped
  in one transfer, instead of re-padding and re-``np.stack``-ing a fresh
  fleet batch every window (``staging_allocs`` counts buffer allocations;
  after a bucket's first window it stays flat).
* **device-resident stacked params** — ``train_fleet`` returns lazy
  :class:`FleetParamView`\\ s over the stacked fit output; per-stream host
  pytrees materialize only when something actually needs one (a model-topic
  publish, a byte count), while the serving path (``predict_fleet``) reads
  the stacked tree directly with zero re-stacking.  The optimizer state is
  donated through the train step: each window's fit consumes the previous
  window's opt-state buffers in place.
* **a local device mesh** — when the process exposes more than one device
  (a TPU slice, or CPU cores surfaced via
  ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` as
  ``benchmarks/bench_fleet.py`` does), the stacked stream axis is sharded
  across the largest power-of-two device prefix that divides the stream
  bucket.  All of it — the mesh, the stacked-batch sharding, and the
  leaf-wise shardings of the donated opt-state carry — resolves through
  ``repro.distributed.sharding``'s logical-axis rules (``stream_mesh`` /
  ``stream_sharding`` / ``fleet_param_shardings``), the same
  divisibility-aware table the model zoo shards under, so staged host
  buffers, the fit executable, and ``predict_fleet`` serving all carry
  explicit shardings from one place.  Per-stream numerics are bitwise
  identical to the single-device vmap — streams never interact — but the
  fleet fit and the fleet predict run data-parallel across the mesh.
* **O(1) host dispatches per window** — the per-stream init/perm key
  derivation (``split``/``fold_in`` per stream, O(S) device round-trips)
  is one batched jitted dispatch over the stacked key rows, and per-stream
  param materialization (a publish boundary, a byte count) is one
  ``device_get`` of the stacked tree that every sibling
  :class:`FleetParamView` slices from, instead of S separate
  slice-and-transfer chains.

``predict_fleet`` is the serving-side counterpart of ``train_fleet``: the
whole fleet's per-stream predictions in **one** vmapped dispatch, cached
per (stream bucket, inference shape bucket), with the same stream/batch
padding discipline (padded slots and padded rows are sliced away before
anything observable).
"""
from __future__ import annotations

import math
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.distributed.sharding import (
    fleet_param_shardings,
    stream_mesh_size,
    stream_sharding,
)
from repro.models.model import Model
from repro.training.optimizer import Optimizer, adamw
from repro.training.train_loop import make_train_step

Params = Any


def bucket_examples(n: int, batch_size: int) -> int:
    """Fixed-shape bucket for an ``n``-example window: the next power-of-two
    multiple of ``batch_size``.  Buckets grow geometrically, so a stream of
    arbitrary window sizes touches only O(log n) compiled executables, and
    the paper's fixed-size windows (150/250 records) always reuse one."""
    if n <= 0:
        raise ValueError(f"cannot bucket an empty window (n={n})")
    per = max(1, math.ceil(n / batch_size))
    return batch_size * (1 << max(0, math.ceil(math.log2(per))))


def pad_to_bucket(data: Dict[str, np.ndarray], nb: int) -> Dict[str, np.ndarray]:
    """Zero-pad every array's leading dim to ``nb`` and attach a f32 validity
    ``mask`` (1 for real examples, 0 for padding)."""
    n = len(next(iter(data.values())))
    if n > nb:
        raise ValueError(f"window of {n} examples exceeds bucket {nb}")
    out = {}
    for k, v in data.items():
        v = np.asarray(v)
        if n < nb:
            pad = np.zeros((nb - n,) + v.shape[1:], v.dtype)
            v = np.concatenate([v, pad], axis=0)
        out[k] = v
    mask = np.zeros((nb,), np.float32)
    mask[:n] = 1.0
    out["mask"] = mask
    return out


def _next_pow2(n: int) -> int:
    return 1 << max(0, math.ceil(math.log2(max(n, 1))))


def bucket_streams(s: int) -> int:
    """Stream-count bucket for an ``s``-stream fleet training batch: the next
    power of two.  Like shape buckets, stream-count buckets grow
    geometrically, so a fleet of any size — or any drift-gated *subset* of
    it — touches only O(log S) compiled fleet executables."""
    if s <= 0:
        raise ValueError(f"cannot bucket an empty fleet (s={s})")
    return _next_pow2(s)


def stream_mesh_devices(sb: int) -> List[Any]:
    """The device prefix the fleet's stacked stream axis shards over: the
    largest power of two that both divides the stream bucket ``sb`` and
    fits the local device count (``distributed.sharding.stream_mesh_size``
    owns the arithmetic — a bucket smaller than the host's device count
    caps at its own pow2 divisor, never an indivisible sharding).  One
    device (the tests' configuration) degrades to no sharding."""
    devs = jax.devices()
    return devs[:stream_mesh_size(sb, len(devs))]


class _FleetStack:
    """Owner of one fleet fit's stacked, device-resident params pytree.
    ``stacked`` keeps a leading stream-bucket axis (possibly sharded across
    the local mesh); views slice it lazily, from a host copy materialized
    **once** for the whole bucket."""

    __slots__ = ("stacked", "_host")

    def __init__(self, stacked: Params):
        self.stacked = stacked
        self._host: Optional[Params] = None

    def dim(self) -> int:
        return int(jax.tree_util.tree_leaves(self.stacked)[0].shape[0])

    def host(self) -> Params:
        """The stacked tree on the host (cached): one ``device_get`` per
        fit output, however many of its streams materialize — the publish
        fan-out at S=1k is S numpy slice views of this copy, not S
        per-stream device slice-and-transfer chains."""
        if self._host is None:
            self._host = jax.tree_util.tree_map(
                np.asarray, jax.device_get(self.stacked))
        return self._host


class FleetParamView:
    """One stream's params inside a device-resident stacked fleet pytree.

    Semantically this *is* the per-stream params tree — it registers as a
    pytree whose flatten materializes the slice, so ``tree_map``, ``jit``,
    byte counts and ``quantize_tree`` all see the ordinary per-stream tree
    — but materialization is lazy: until a publish boundary (or any other
    consumer) flattens it, no per-stream host pytree exists, and
    ``predict_fleet`` recognizes sibling views of one stacked buffer and
    serves the whole fleet from it with zero re-stacking.

    A view keeps its owner's stacked tree alive even after materializing
    (the zero-restack serving path needs it); a long-lived straggler view
    therefore pins its fit's whole stacked tree — a deliberate trade at
    speed-model scale, where a stacked fleet tree is a few hundred KB."""

    __slots__ = ("owner", "slot", "_tree")

    def __init__(self, owner: _FleetStack, slot: int):
        self.owner = owner
        self.slot = slot
        self._tree: Optional[Params] = None

    def tree(self) -> Params:
        """The materialized per-stream params pytree (cached): host numpy
        views sliced from the owner's one batched ``device_get`` — the
        first materialization of *any* sibling pays the transfer once for
        the whole bucket."""
        if self._tree is None:
            j = self.slot
            self._tree = jax.tree_util.tree_map(lambda a: a[j],
                                                self.owner.host())
        return self._tree

    # the per-stream tree's mapping surface, for eager callers that index
    # params directly (e.g. model.loss_fn outside jit)
    def __getitem__(self, key):
        return self.tree()[key]

    def keys(self):
        return self.tree().keys()


jax.tree_util.register_pytree_node(
    FleetParamView,
    lambda v: ((v.tree(),), None),
    lambda aux, ch: ch[0],
)


def materialize_params(params: Params) -> Params:
    """Resolve a (possibly lazy) per-stream params handle to a plain
    pytree.  Plain trees pass through untouched."""
    return params.tree() if isinstance(params, FleetParamView) else params


def _staging_buffer(cache: Dict[Tuple, np.ndarray], key: Tuple,
                    shape: Tuple[int, ...], dtype) -> Tuple[np.ndarray, bool]:
    """Get-or-allocate a persistent host staging buffer; returns the buffer
    and whether this call allocated it (the caller counts allocations)."""
    buf = cache.get(key)
    if buf is not None:
        return buf, False
    buf = np.zeros(shape, dtype)
    cache[key] = buf
    return buf, True


def _make_epoch_scan(model: Model, opt: Optimizer, epochs: int,
                     batch_size: int, nb: int):
    """The pure epoch-scan fit body shared by the single-stream and fleet
    trainers: the whole fit (per-epoch permutations, minibatch gather,
    ``epochs x steps`` optimizer updates) is one ``lax.scan`` over a
    device-resident pre-permuted epoch index tensor."""
    steps = nb // batch_size
    train_step = make_train_step(model, opt)

    def epoch_scan_fit(params, opt_state, x, y, mask, rng):
        perms = jax.vmap(lambda k: jax.random.permutation(k, nb))(
            jax.random.split(rng, epochs))
        idx = perms.reshape(epochs * steps, batch_size)

        def body(carry, ib):
            params, opt_state = carry
            batch = {"x": x[ib], "y": y[ib], "mask": mask[ib]}
            params, opt_state, metrics = train_step(params, opt_state,
                                                    batch)
            return (params, opt_state), metrics["loss"]

        (params, opt_state), losses = jax.lax.scan(
            body, (params, opt_state), idx)
        return params, opt_state, losses

    return epoch_scan_fit


class CompiledForecaster:
    """Speed-layer trainer with a compile-once, dispatch-light hot path.

    Matches the ``Forecaster`` protocol (``train(data, params, key) ->
    (params, wall_s)``; ``predict(params, x) -> np.ndarray``) so it drops
    into ``SpeedTraining`` / both executors unchanged.  The jitted epoch-scan
    executable is cached per shape bucket — model, optimizer, epochs and
    batch size are fixed per instance, so the effective cache key is
    (model, optimizer, batch shape); warm and cold starts share the same
    executable.

    The model's ``loss_fn`` must honor an optional per-example ``mask`` key
    in the batch (as ``repro.models.lstm.loss_fn`` does) whenever a window
    needs padding; the first padded window of each bucket runs a one-time
    numeric check and raises if the mask is ignored, so a mask-blind model
    can never be silently biased toward its padding.
    """

    def __init__(
        self,
        model: Model,
        *,
        epochs: int,
        batch_size: int,
        lr: float = 1e-3,
        opt: Optional[Optimizer] = None,
        warm_start: bool = False,
        predict_fn: Optional[Callable[[Params, jax.Array], jax.Array]] = None,
    ):
        self.model = model
        self.epochs = int(epochs)
        self.batch_size = int(batch_size)
        self.warm_start = warm_start
        self.opt = opt or adamw(lr)
        self._fit_cache: Dict[int, Callable] = {}
        self._trace_counts: Dict[int, int] = {}
        self._mask_checked: set = set()
        self._init_fn = jax.jit(model.init)
        self._opt_init = jax.jit(self.opt.init)
        self._predict_raw = predict_fn
        self._predict_traces: Dict[int, int] = {}
        if predict_fn is not None:
            traces = self._predict_traces

            def counted_predict(params, x):
                # executes only while XLA traces — counts real retraces per
                # inference shape bucket (a new params *structure*, e.g. an
                # int8 QTensor tree, traces its bucket once more)
                traces[x.shape[0]] = traces.get(x.shape[0], 0) + 1
                return predict_fn(params, x)

            self._predict_fn: Optional[Callable] = jax.jit(counted_predict)
        else:
            self._predict_fn = None
        self._predict_bufs: Dict[Tuple, np.ndarray] = {}
        self._dequant_cache: Optional[Tuple[Any, Params]] = None
        self.staging_allocs = 0
        self.last_losses: Optional[np.ndarray] = None

    # -- compile-cache introspection ----------------------------------------

    @property
    def retrace_count(self) -> int:
        """Total XLA traces of the fit executable across all shape buckets."""
        return sum(self._trace_counts.values())

    @property
    def cache_size(self) -> int:
        return len(self._fit_cache)

    def trace_counts(self) -> Dict[int, int]:
        """Per-shape-bucket XLA trace counts."""
        return dict(self._trace_counts)

    def predict_trace_counts(self) -> Dict[int, int]:
        """Per-inference-shape-bucket XLA trace counts of the predict
        executable."""
        return dict(self._predict_traces)

    # -- the cached fit executable ------------------------------------------

    def _fit_fn(self, nb: int) -> Callable:
        """One executable per bucket ``nb``; warm and cold starts share it
        (params enter as an argument either way)."""
        fn = self._fit_cache.get(nb)
        if fn is not None:
            return fn
        scan_fit = _make_epoch_scan(self.model, self.opt, self.epochs,
                                    self.batch_size, nb)
        counts = self._trace_counts
        counts.setdefault(nb, 0)

        def epoch_scan_fit(params, opt_state, x, y, mask, rng):
            # executes only while XLA traces — counts real retraces
            counts[nb] += 1
            return scan_fit(params, opt_state, x, y, mask, rng)

        fn = jax.jit(epoch_scan_fit, donate_argnums=(0, 1))
        self._fit_cache[nb] = fn
        return fn

    def _check_mask_honored(self, data: Dict[str, np.ndarray],
                            padded: Dict[str, np.ndarray], params: Params,
                            nb: int) -> None:
        """One-time (per bucket) guard: when a window actually needed
        padding, the masked loss on the padded batch must equal the plain
        loss on the unpadded batch.  A model whose ``loss_fn`` ignores the
        validity mask would otherwise silently average its padding rows into
        every gradient."""
        n = len(next(iter(data.values())))
        if n == nb or nb in self._mask_checked:
            return
        plain, _ = self.model.loss_fn(
            params, {k: jnp.asarray(v) for k, v in data.items()})
        masked, _ = self.model.loss_fn(
            params, {k: jnp.asarray(v) for k, v in padded.items()})
        if not np.allclose(np.asarray(plain), np.asarray(masked),
                           rtol=1e-4, atol=1e-6):
            raise ValueError(
                "model.loss_fn ignores the per-example validity 'mask': "
                f"padded-batch loss {float(masked):.6g} != unpadded loss "
                f"{float(plain):.6g}. Fixed-shape bucketing would bias "
                "training toward the padding; thread batch['mask'] into the "
                "loss as repro.models.lstm.loss_fn does.")
        self._mask_checked.add(nb)

    # -- Forecaster protocol -------------------------------------------------

    def train(self, data: Dict[str, np.ndarray], params: Optional[Params],
              key: jax.Array) -> Tuple[Params, float]:
        t0 = time.perf_counter()
        n = len(next(iter(data.values())))
        nb = bucket_examples(n, self.batch_size)
        init_key, perm_key = jax.random.split(key)
        warm = self.warm_start and params is not None
        if warm:
            # an int8-synced serving model (QTensor leaves) can seed a warm
            # start, but training runs in float: dequantize first
            from repro.serving.quantize import dequantize_tree

            params = dequantize_tree(params)
            # the fit executable donates its params buffer; the caller-held
            # tree (the serving model) must survive, so warm starts hand the
            # executable a private copy
            params = jax.tree_util.tree_map(jnp.array, params)
        else:
            params = self._init_fn(init_key)
        opt_state = self._opt_init(params)
        padded = pad_to_bucket(data, nb)
        self._check_mask_honored(data, padded, params, nb)
        params, _, losses = self._fit_fn(nb)(
            params, opt_state,
            jnp.asarray(padded["x"]), jnp.asarray(padded["y"]),
            jnp.asarray(padded["mask"]), perm_key)
        jax.block_until_ready(params)
        self.last_losses = np.asarray(losses)
        return params, time.perf_counter() - t0

    def _stage_predict(self, x: np.ndarray) -> np.ndarray:
        """Pad ``x`` up to its shape bucket in a persistent per-bucket host
        staging buffer — a ragged final batch costs one row copy plus a pad
        memset, never a fresh concatenate allocation, so steady-state
        serving neither retraces nor re-stages."""
        n = x.shape[0]
        nb = _next_pow2(n)  # bucket inference shapes too: O(log n) compiles
        key = (nb,) + x.shape[1:] + (x.dtype.str,)
        buf, allocated = _staging_buffer(self._predict_bufs, key,
                                         (nb,) + x.shape[1:], x.dtype)
        self.staging_allocs += allocated
        np.copyto(buf[:n], x)
        buf[n:] = 0
        return buf

    def _serving_params(self, params: Params) -> Params:
        """The params tree the predict executable actually serves.

        On a real TPU an int8 ``QTensor`` tree serves as-is: the fused
        dequant-accumulate ``int8_matmul`` kernel is the fast path.  On an
        interpret-mode backend (CPU CI, this container) the per-scan-step
        int8 recurrent matmul runs through the Pallas interpreter and a
        quantized predict *trailed* the float one ~1.6x (the gap
        BENCH_hotpath flagged); there the sync payload is still int8 — the
        4x transfer saving is the point of quantized sync — but serving
        dequantizes once per synced model and reuses the float executable,
        so steady-state int8 predict matches float exactly.  The cache is
        identity-keyed on the params object: the serving model is stable
        between model syncs, so every predict after the first is a pure
        cache hit (``BENCH_hotpath.json`` gates the ratio)."""
        hit = self._dequant_cache
        if hit is not None and hit[0] is params:
            # steady-state serving: same installed model as last predict —
            # no leaf scan, no backend probe
            return hit[1]
        from repro.kernels import default_interpret

        if not default_interpret():
            return params
        from repro.serving.quantize import QTensor, dequantize_tree

        is_q = lambda v: isinstance(v, QTensor)
        if not any(is_q(l) for l in
                   jax.tree_util.tree_leaves(params, is_leaf=is_q)):
            return params
        deq = dequantize_tree(params)
        self._dequant_cache = (params, deq)
        return deq

    def predict(self, params: Params, x: np.ndarray) -> np.ndarray:
        if self._predict_fn is None:
            raise ValueError("CompiledForecaster built without a predict_fn")
        x = np.asarray(x)
        n = x.shape[0]
        buf = self._stage_predict(x)
        params = self._serving_params(params)
        return np.asarray(self._predict_fn(params, jnp.asarray(buf)))[:n]


class FleetForecaster:
    """Fleet-axis trainer: one speed model per stream, the whole fleet fit
    in **one device dispatch** per window.

    Wraps a single-stream :class:`CompiledForecaster` (exposed as
    ``.single``, and via delegating ``train``/``predict`` so a
    ``FleetForecaster`` satisfies the ``Forecaster`` protocol anywhere a
    single-stream trainer is expected).  ``train_fleet`` stacks the fleet's
    padded windows along a new leading stream axis and runs a vmapped
    cold-start fit — per-stream param init, optimizer init, and the shared
    epoch-scan body — inside a single jitted executable, cached per
    (stream-count bucket, shape bucket):

    * the per-stream key derivation (``init_key, perm_key = split(key)``)
      is byte-identical to the single-stream path, so stream ``i`` of a
      fleet fit trains from the same init, with the same minibatch
      permutations, as a sequential ``CompiledForecaster.train`` given the
      same key — fleet-vs-sequential parity is a numerical (vmap batching)
      tolerance, not a semantic difference;
    * the stream axis is padded up to ``bucket_streams(s)`` with zero-data,
      all-zero-mask slots, exactly like batch padding: a padded slot's loss
      and gradient are exactly zero, so its (discarded) params never move
      and the optimizer's global-norm clip is unaffected;
    * streams whose windows fall in different *shape* buckets are grouped,
      one dispatch per group — a homogeneous fleet (the paper's fixed-size
      windows) always trains in exactly one;
    * a single-stream group (s == 1) delegates to the wrapped
      ``CompiledForecaster``, keeping the single-stream path byte-identical
      to the pre-fleet code.

    ``train_dispatches`` counts fit-executable invocations (what
    ``benchmarks/bench_fleet.py`` asserts is one per window for a
    homogeneous fleet); ``trace_counts`` exposes per-bucket XLA traces so
    the zero-retrace-after-first-window property stays testable.

    The hot path is memory-resident across windows (see the module
    docstring): window data is staged into persistent stacked buffers and
    shipped in one transfer per tensor, the previous window's optimizer
    state is donated back into the fit executable, the stacked fit output
    stays device-resident behind lazy :class:`FleetParamView` handles, and
    both the fit and ``predict_fleet`` shard the stream axis across the
    local device mesh when one exists.  ``predict_fleet`` serves the whole
    fleet's per-stream predictions in one dispatch (``predict_dispatches``
    counts them; ``predict_trace_counts`` exposes the per-bucket traces).
    """

    def __init__(
        self,
        model: Model,
        *,
        epochs: int,
        batch_size: int,
        lr: float = 1e-3,
        opt: Optional[Optimizer] = None,
        predict_fn: Optional[Callable[[Params, jax.Array], jax.Array]] = None,
    ):
        self.single = CompiledForecaster(
            model, epochs=epochs, batch_size=batch_size, lr=lr, opt=opt,
            predict_fn=predict_fn)
        self.model = model
        self.epochs = int(epochs)
        self.batch_size = int(batch_size)
        self.opt = self.single.opt
        self._fleet_cache: Dict[Tuple[int, int], Callable] = {}
        self._trace_counts: Dict[Tuple[int, int], int] = {}
        self._carry_cache: Dict[int, Callable] = {}
        # persistent host staging buffers, stacked opt-state carries, and
        # stream shardings, all keyed per bucket — the device-resident state
        self._train_bufs: Dict[Tuple[int, int], Dict[str, np.ndarray]] = {}
        self._opt_carry: Dict[Tuple[int, int], Any] = {}
        self._shardings: Dict[int, Optional[NamedSharding]] = {}
        self._key_cache: Dict[int, Callable] = {}
        self._predict_cache: Dict[int, Callable] = {}
        self._predict_traces: Dict[Tuple[int, int], int] = {}
        self._predict_bufs: Dict[Tuple, np.ndarray] = {}
        self._stack_tree_cache: Dict[Tuple, Tuple] = {}
        self._staging_allocs = 0
        self.train_dispatches = 0
        self.predict_dispatches = 0
        # per-stream minibatch-loss trajectories of the last train_fleet call
        self.last_losses: Optional[List[Optional[np.ndarray]]] = None

    # -- Forecaster protocol (the fleet's single-stream view) ----------------

    def train(self, data: Dict[str, np.ndarray], params: Optional[Params],
              key: jax.Array) -> Tuple[Params, float]:
        return self.single.train(data, params, key)

    def predict(self, params: Params, x: np.ndarray) -> np.ndarray:
        return self.single.predict(params, x)

    # -- compile-cache introspection ----------------------------------------

    @property
    def retrace_count(self) -> int:
        """Fleet-executable XLA traces across all (stream, shape) buckets
        (the delegated single-stream path counts its own)."""
        return sum(self._trace_counts.values())

    @property
    def cache_size(self) -> int:
        return len(self._fleet_cache)

    def trace_counts(self) -> Dict[Tuple[int, int], int]:
        """Per-(stream-count bucket, shape bucket) XLA trace counts."""
        return dict(self._trace_counts)

    def predict_trace_counts(self) -> Dict[Tuple[int, int], int]:
        """Per-(stream bucket, inference shape bucket) XLA trace counts of
        the fleet predict executable."""
        return dict(self._predict_traces)

    @property
    def staging_allocs(self) -> int:
        """Total host staging-buffer allocations (fleet train + fleet
        predict + the wrapped single-stream trainer's predict buffers).
        Steady-state windows of a known bucket allocate nothing: data is
        re-staged into the same buffers, never re-stacked."""
        return self._staging_allocs + self.single.staging_allocs

    # -- the device mesh and the staged buffers ------------------------------

    def _stream_sharding(self, sb: int) -> Optional[NamedSharding]:
        """The stream-axis sharding for bucket ``sb`` over the local device
        mesh, or None on a single device — resolved through
        ``distributed.sharding.stream_sharding`` (the logical-axis rules
        with divisibility-aware fallback), cached per bucket.  Streams are
        independent, so sharding the stacked axis is pure data parallelism
        — bitwise the same per-stream numerics as the unsharded vmap."""
        if sb not in self._shardings:
            self._shardings[sb] = stream_sharding(sb)
        return self._shardings[sb]

    def _put(self, a: np.ndarray, sb: int):
        shard = self._stream_sharding(sb)
        return jnp.asarray(a) if shard is None else jax.device_put(a, shard)

    def _train_staging(self, sb: int, nb: int,
                       data0: Dict[str, np.ndarray],
                       key0) -> Dict[str, np.ndarray]:
        """The persistent stacked staging buffers for one (stream bucket,
        shape bucket): x/y/mask plus the per-stream base-key rows and
        pad-slot fold ids the batched key derivation consumes.  Allocated
        once per bucket (counted), refilled in place every window."""
        bufs = self._train_bufs.get((sb, nb))
        if bufs is None:
            # one bundle of arrays per bucket, counted as one allocation
            karr = np.asarray(key0)
            bufs = {"mask": np.zeros((sb, nb), np.float32),
                    "k0": np.zeros((sb,) + karr.shape, karr.dtype),
                    "fid": np.zeros((sb,), np.int32)}
            for k, v in data0.items():
                v = np.asarray(v)
                bufs[k] = np.zeros((sb, nb) + v.shape[1:], v.dtype)
            self._train_bufs[(sb, nb)] = bufs
            self._staging_allocs += 1
        return bufs

    def _key_fn(self, sb: int) -> Callable:
        """The cached batched key-derivation executable for stream bucket
        ``sb``: one jitted dispatch turns the fleet's stacked base keys
        into the per-stream (init, perm) key rows — byte-identical to the
        per-stream ``split``/``fold_in`` chain, without its O(S) device
        round-trips — laid out on the stream mesh."""
        fn = self._key_cache.get(sb)
        if fn is None:
            def derive(keys, fold_ids):
                def one(k, fid):
                    # pad slots (fid > 0) derive from the group's first key
                    # exactly as the per-stream path did: fold_in then split
                    k = jnp.where(fid > 0, jax.random.fold_in(k, fid), k)
                    ik, pk = jax.random.split(k)
                    return ik, pk

                return jax.vmap(one)(keys, fold_ids)

            shard = self._stream_sharding(sb)
            kw = ({} if shard is None
                  else {"in_shardings": shard, "out_shardings": shard})
            fn = jax.jit(derive, **kw)
            self._key_cache[sb] = fn
        return fn

    # -- the cached fleet-fit executable ------------------------------------

    def _fleet_fit_fn(self, sb: int, nb: int) -> Callable:
        cache_key = (sb, nb)
        fn = self._fleet_cache.get(cache_key)
        if fn is not None:
            return fn
        scan_fit = _make_epoch_scan(self.model, self.opt, self.epochs,
                                    self.batch_size, nb)
        init = self.model.init
        opt_init = self.opt.init
        counts = self._trace_counts
        counts.setdefault(cache_key, 0)

        def cold_fit(init_key, perm_key, x, y, mask):
            params = init(init_key)
            opt_state = opt_init(params)
            params, opt_state, losses = scan_fit(params, opt_state, x, y,
                                                 mask, perm_key)
            return params, opt_state, losses

        def fleet_fit(opt_carry, init_keys, perm_keys, x, y, mask):
            # executes only while XLA traces — counts real retraces.
            # ``opt_carry`` is the previous window's stacked opt state: its
            # value is dead (every window cold-starts from init_keys), but
            # donating it lets XLA alias this window's opt-state output into
            # the same buffers, so the optimizer state stays resident in one
            # allocation across the run.  Params are NOT donated — the
            # stacked fit output is the fleet's live serving state
            # (FleetParamView slices it lazily) and must survive the next
            # window's fit.
            counts[cache_key] += 1
            return jax.vmap(cold_fit)(init_keys, perm_keys, x, y, mask)

        # every input and output carries a leading stream-bucket axis, so on
        # a mesh ONE explicit sharding pins them all — without it, GSPMD is
        # free to lay the first window's carry out differently from the
        # fit's own opt output, forcing a second lowering at window 1
        shard = self._stream_sharding(sb)
        kw = ({} if shard is None
              else {"in_shardings": shard, "out_shardings": shard})
        fn = jax.jit(fleet_fit, donate_argnums=(0,), keep_unused=True, **kw)
        self._fleet_cache[cache_key] = fn
        return fn

    def _carry_init_fn(self, sb: int) -> Callable:
        """One-time (per stream bucket) builder of the initial stacked
        opt-state carry the donated fit consumes.  On a mesh the carry's
        leaves get explicit per-leaf shardings from the axis-rules table
        (``fleet_param_shardings``: stream axis sharded, per-stream model
        dims replicated per ``PARAM_AXES``) — the layout the fit's own opt
        output keeps, so window 1's donation never forces a relayout."""
        fn = self._carry_cache.get(sb)
        if fn is None:
            init, opt_init = self.model.init, self.opt.init
            vmapped = jax.vmap(lambda k: opt_init(init(k)))
            shard = self._stream_sharding(sb)
            if shard is None:
                kw = {}
            else:
                keys_shape = jax.eval_shape(
                    lambda: jax.random.split(jax.random.PRNGKey(0), sb))
                carry_shape = jax.eval_shape(vmapped, keys_shape)
                kw = {"out_shardings": fleet_param_shardings(
                    carry_shape, shard.mesh)}
            fn = jax.jit(vmapped, **kw)
            self._carry_cache[sb] = fn
        return fn

    # -- the fleet fit -------------------------------------------------------

    def train_fleet(self, datas: Sequence[Dict[str, np.ndarray]],
                    keys: Sequence[jax.Array]
                    ) -> Tuple[List[Params], float]:
        """Cold-start fit of one speed model per stream; returns the
        per-stream params (same order as ``datas``) and the total wall.

        Multi-stream groups return lazy :class:`FleetParamView` handles
        over the device-resident stacked fit output — semantically the
        per-stream trees (they flatten to them), materialized only when a
        consumer actually needs one; a single-stream group returns its
        plain tree from the delegated single-stream path.

        ``keys[i]`` plays exactly the role ``key`` plays in
        ``CompiledForecaster.train`` for stream ``i``."""
        t0 = time.perf_counter()
        if len(datas) != len(keys):
            raise ValueError(f"{len(datas)} windows but {len(keys)} keys")
        out: List[Optional[Params]] = [None] * len(datas)
        if not datas:
            return [], 0.0
        groups: Dict[int, List[int]] = {}
        for i, d in enumerate(datas):
            n = len(next(iter(d.values())))
            groups.setdefault(bucket_examples(n, self.batch_size), []).append(i)
        losses: List[Optional[np.ndarray]] = [None] * len(datas)
        for nb, idxs in sorted(groups.items()):
            if len(idxs) == 1:
                # byte-identical single-stream path (no vmap, no S padding)
                i = idxs[0]
                out[i], _ = self.single.train(datas[i], None, keys[i])
                losses[i] = self.single.last_losses
                self.train_dispatches += 1
                continue
            for i, l in zip(idxs, self._fit_group(nb, idxs, datas, keys, out)):
                losses[i] = l
        self.last_losses = losses
        return out, time.perf_counter() - t0

    def _fit_group(self, nb: int, idxs: List[int],
                   datas: Sequence[Dict[str, np.ndarray]],
                   keys: Sequence[jax.Array],
                   out: List[Optional[Params]]) -> np.ndarray:
        s = len(idxs)
        sb = bucket_streams(s)
        bufs = self._train_staging(sb, nb, datas[idxs[0]], keys[idxs[0]])
        for j, i in enumerate(idxs):
            d = datas[i]
            n = len(next(iter(d.values())))
            for k, v in d.items():
                bufs[k][j, :n] = np.asarray(v)
                bufs[k][j, n:] = 0
            bufs["mask"][j, :n] = 1.0
            bufs["mask"][j, n:] = 0.0
            bufs["k0"][j] = np.asarray(keys[i])
        for k in datas[idxs[0]]:
            # stream-axis padding: zero data + all-zero validity mask, so
            # the slot's loss/grad are exactly zero (any key gives a fine
            # inert init; fold_in keeps it deterministic)
            bufs[k][s:] = 0
        bufs["mask"][s:] = 0.0
        bufs["k0"][s:] = np.asarray(keys[idxs[0]])
        bufs["fid"][:s] = 0
        bufs["fid"][s:] = np.arange(1, sb - s + 1, dtype=np.int32)
        # one batched dispatch derives every stream's (init, perm) keys —
        # the same split/fold_in chain the sequential path runs per stream
        ik_d, pk_d = self._key_fn(sb)(bufs["k0"], bufs["fid"])
        padded0 = {k: bufs[k][0] for k in list(datas[idxs[0]]) + ["mask"]}
        self._check_mask_honored(datas[idxs[0]], padded0, nb, ik_d)
        carry = self._opt_carry.pop((sb, nb), None)
        if carry is None:
            carry = self._carry_init_fn(sb)(ik_d)
        params_S, opt_S, losses_S = self._fleet_fit_fn(sb, nb)(
            carry, ik_d, pk_d,
            self._put(bufs["x"], sb), self._put(bufs["y"], sb),
            self._put(bufs["mask"], sb))
        self._opt_carry[(sb, nb)] = opt_S
        jax.block_until_ready(params_S)
        self.train_dispatches += 1
        owner = _FleetStack(params_S)
        for j, i in enumerate(idxs):
            out[i] = FleetParamView(owner, j)
        return np.asarray(losses_S)[:s]

    # -- one-dispatch fleet inference ----------------------------------------

    def _predict_fleet_fn(self, sb: int) -> Callable:
        """The cached vmapped predict executable for stream bucket ``sb``
        (jit's own cache handles the inference shape buckets; the traced
        body counts real retraces per (sb, nb))."""
        fn = self._predict_cache.get(sb)
        if fn is None:
            pf = self.single._predict_raw
            traces = self._predict_traces

            def fleet_predict(params_S, x_S):
                # executes only while XLA traces — counts real retraces (a
                # new params structure, e.g. an int8 QTensor tree, traces
                # its bucket once more)
                k = (sb, x_S.shape[1])
                traces[k] = traces.get(k, 0) + 1
                return jax.vmap(pf)(params_S, x_S)

            fn = jax.jit(fleet_predict)
            self._predict_cache[sb] = fn
        return fn

    def _stack_fleet_params(self, params_seq: List[Params], sb: int
                            ) -> Tuple[Params, bool]:
        """The stacked params pytree for one fleet predict: sibling
        :class:`FleetParamView`\\ s of one stacked fit output in slot order
        are served from it directly (zero re-stacking, and already laid
        out on the stream mesh — the common ungated serving path);
        anything else stacks the materialized per-stream trees leaf-wise,
        repeating stream 0 into the padded slots (their predictions are
        sliced away).  Returns the stacked tree and whether it lives on
        the stream mesh (so the staged batch can be shipped to match)."""
        first = params_seq[0]
        if isinstance(first, FleetParamView):
            owner = first.owner
            if (all(isinstance(p, FleetParamView) and p.owner is owner
                    and p.slot == j for j, p in enumerate(params_seq))
                    and owner.dim() == sb):
                return owner.stacked, True
        # an identical params sequence (the shared batch model every window,
        # a gated fleet's unchanged serving set) reuses its stacked tree —
        # the cache holds the sequence itself, so the ids in the key stay
        # valid, and the identity re-check makes id reuse harmless
        ck = (sb,) + tuple(id(p) for p in params_seq)
        hit = self._stack_tree_cache.get(ck)
        if hit is not None and all(a is b for a, b in zip(hit[0],
                                                          params_seq)):
            return hit[1], False
        trees = [materialize_params(p) for p in params_seq]
        trees += [trees[0]] * (sb - len(trees))
        stacked = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *trees)
        if len(self._stack_tree_cache) >= 16:
            self._stack_tree_cache.clear()
        self._stack_tree_cache[ck] = (list(params_seq), stacked)
        return stacked, False

    def predict_fleet(self, params_seq: Sequence[Params],
                      xs: Sequence[np.ndarray]) -> List[np.ndarray]:
        """Per-stream predictions for the whole fleet in **one** vmapped
        device dispatch: stream ``i``'s batch ``xs[i]`` under its own
        params ``params_seq[i]``.

        Batches are padded to a common inference shape bucket (persistent
        staging buffer, padded rows sliced away per stream) and the stream
        axis to its stream bucket, exactly mirroring ``train_fleet``; the
        stacked tree and the staged batch shard across the local device
        mesh when one exists.  Per-stream results match
        ``CompiledForecaster.predict`` to vmap-batching tolerance (<=1e-6;
        ``bench_fleet`` tracks it), and a one-stream call delegates to it
        byte-identically.  Int8 ``QTensor`` trees (the fleet's quantized
        sync path) stack like any pytree and run the batched
        ``int8_matmul`` kernel under vmap."""
        if self.single._predict_raw is None:
            raise ValueError("FleetForecaster built without a predict_fn")
        params_seq = list(params_seq)
        xs = [np.asarray(x) for x in xs]
        if len(params_seq) != len(xs):
            raise ValueError(f"{len(params_seq)} param trees but "
                             f"{len(xs)} stream batches")
        S = len(xs)
        if S == 0:
            return []
        if S == 1:
            # byte-identical single-stream path (no vmap, no S padding)
            return [self.single.predict(params_seq[0], xs[0])]
        ns = [x.shape[0] for x in xs]
        nb = _next_pow2(max(max(ns), 1))
        sb = bucket_streams(S)
        stacked, on_mesh = self._stack_fleet_params(params_seq, sb)
        key = (sb, nb) + xs[0].shape[1:] + (xs[0].dtype.str,)
        buf, allocated = _staging_buffer(
            self._predict_bufs, key, (sb, nb) + xs[0].shape[1:],
            xs[0].dtype)
        self._staging_allocs += allocated
        for j, x in enumerate(xs):
            np.copyto(buf[j, :ns[j]], x)
            buf[j, ns[j]:] = 0  # only the padding tail, not the whole buffer
        buf[S:] = 0  # padded stream slots
        x_dev = self._put(buf, sb) if on_mesh else jnp.asarray(buf)
        preds = self._predict_fleet_fn(sb)(stacked, x_dev)
        self.predict_dispatches += 1
        preds = np.asarray(preds)
        return [preds[j, :ns[j]] for j in range(S)]

    def _check_mask_honored(self, data: Dict[str, np.ndarray],
                            padded: Dict[str, np.ndarray], nb: int,
                            init_keys: jax.Array) -> None:
        """One-time (per shape bucket) mask guard, same contract as the
        single-stream trainer's; shares its dedup set so a bucket checked by
        either path is checked once.  A window that exactly fills its
        bucket needs no padding and no check (and must not pay the
        throwaway init — or even slicing row 0 off the stacked key array —
        every window)."""
        n = len(next(iter(data.values())))
        if n == nb or nb in self.single._mask_checked:
            return
        params = self.single._init_fn(init_keys[0])
        self.single._check_mask_honored(data, padded, params, nb)
