from repro.training.optimizer import Optimizer, OptState, adamw, sgd, warmup_cosine  # noqa: F401
from repro.training.train_loop import fit, make_eval_step, make_train_step  # noqa: F401
from repro.training.compiled import (  # noqa: F401
    CompiledForecaster,
    FleetForecaster,
    bucket_examples,
    bucket_streams,
    pad_to_bucket,
)
from repro.training import checkpoint  # noqa: F401
