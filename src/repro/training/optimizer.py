"""Pure-JAX optimizers (no optax in this container).

AdamW with optional cosine/linear warmup schedules, gradient clipping by
global norm, and f32 moment accumulators regardless of param dtype (the
moments are the FSDP-sharded bulk of optimizer memory at kimi-k2 scale).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

Params = Any
Schedule = Callable[[jax.Array], jax.Array]


class OptState(NamedTuple):
    step: jax.Array
    mu: Params
    nu: Params


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], OptState]
    update: Callable[[Params, OptState, Params], Tuple[Params, OptState, Dict]]


def constant(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(lr: float, warmup: int, total: int, final_frac: float = 0.1
                  ) -> Schedule:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = lr * jnp.minimum(step / max(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac * lr + (1 - final_frac) * lr * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)

    return sched


def global_norm(tree: Params) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in leaves))


def adamw(
    lr: Union[float, Schedule],
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    clip_norm: Optional[float] = 1.0,
    moment_dtype=jnp.float32,
) -> Optimizer:
    """moment_dtype=bfloat16 halves optimizer HBM — the capacity fix that
    lets the 1T-param MoE config hold AdamW state (EXPERIMENTS.md §Dry-run);
    the update math still runs in f32."""
    sched: Schedule = lr if callable(lr) else constant(lr)
    mdt = jnp.dtype(moment_dtype)

    def init(params: Params) -> OptState:
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, mdt), params
        )
        return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                        nu=jax.tree_util.tree_map(jnp.copy, zeros))

    def update(grads: Params, state: OptState, params: Params):
        step = state.step + 1
        gnorm = global_norm(grads)
        scale = jnp.ones((), jnp.float32)
        if clip_norm is not None:
            scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m2 = b1 * m.astype(jnp.float32) + (1 - b1) * g
            v2 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
            mhat = m2 / (1 - b1 ** step.astype(jnp.float32))
            vhat = v2 / (1 - b2 ** step.astype(jnp.float32))
            delta = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay > 0:
                delta = delta + weight_decay * p.astype(jnp.float32)
            p2 = p.astype(jnp.float32) - sched(step) * delta
            return p2.astype(p.dtype), m2.astype(mdt), v2.astype(mdt)

        out = jax.tree_util.tree_map(upd, grads, state.mu, state.nu, params)
        params2 = jax.tree_util.tree_map(lambda t: t[0], out,
                                         is_leaf=lambda t: isinstance(t, tuple))
        mu2 = jax.tree_util.tree_map(lambda t: t[1], out,
                                     is_leaf=lambda t: isinstance(t, tuple))
        nu2 = jax.tree_util.tree_map(lambda t: t[2], out,
                                     is_leaf=lambda t: isinstance(t, tuple))
        metrics = {"grad_norm": gnorm, "lr": sched(step)}
        return params2, OptState(step, mu2, nu2), metrics

    return Optimizer(init=init, update=update)


def sgd(lr: Union[float, Schedule], momentum: float = 0.0) -> Optimizer:
    sched: Schedule = lr if callable(lr) else constant(lr)

    def init(params: Params) -> OptState:
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        return OptState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=zeros)

    def update(grads: Params, state: OptState, params: Params):
        step = state.step + 1

        def upd(g, m, p):
            g = g.astype(jnp.float32)
            m2 = momentum * m + g
            p2 = p.astype(jnp.float32) - sched(step) * m2
            return p2.astype(p.dtype), m2

        out = jax.tree_util.tree_map(upd, grads, state.mu, params)
        params2 = jax.tree_util.tree_map(lambda t: t[0], out,
                                         is_leaf=lambda t: isinstance(t, tuple))
        mu2 = jax.tree_util.tree_map(lambda t: t[1], out,
                                     is_leaf=lambda t: isinstance(t, tuple))
        return params2, OptState(step, mu2, state.nu), {"grad_norm": global_norm(grads)}

    return Optimizer(init=init, update=update)
