"""Encoder-decoder backbone (SeamlessM4T-medium text decoder + speech
encoder) [arXiv:2308.11596].

The speech frontend (mel + conv feature extractor) is a stub per the modality
carve-out: the encoder consumes precomputed frame embeddings
(batch, frames, embed_dim) provided by ``input_specs``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks, nn

Params = Dict[str, Any]


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    ne = cfg.encdec.n_encoder_layers
    nd = cfg.n_layers
    p: Params = {
        **blocks.init_embed(key, cfg),
        "final_norm": nn.ones((d,), dt),
        "proj_in": nn.dense_init(key, "proj_in", cfg.frontend.embed_dim, d, dt),
        "enc_norm": {"final_norm": nn.ones((d,), dt)},
        "enc_layers": {
            "attn_norm": nn.ones((ne, d), dt),
            "mlp_norm": nn.ones((ne, d), dt),
            **blocks.init_attn(key, "enc_layers/attn", cfg, n_stack=ne),
            **blocks.init_mlp(key, "enc_layers/mlp", cfg, n_stack=ne),
        },
        "dec_layers": {
            "attn_norm": nn.ones((nd, d), dt),
            "cross_norm": nn.ones((nd, d), dt),
            "mlp_norm": nn.ones((nd, d), dt),
            "self": blocks.init_attn(key, "dec_layers/self", cfg, n_stack=nd),
            "cross": blocks.init_attn(key, "dec_layers/cross", cfg, n_stack=nd),
            **blocks.init_mlp(key, "dec_layers/mlp", cfg, n_stack=nd),
        },
    }
    return p


def encode(cfg: ModelConfig, p: Params, prefix_embed: jax.Array) -> jax.Array:
    """Frame embeddings -> encoder memory (B, M, d)."""
    x = nn.dense(prefix_embed.astype(jnp.dtype(cfg.dtype)), p["proj_in"])
    B, M, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(M, dtype=jnp.int32), (B, M))

    def step(carry, lp):
        xx = carry
        h = nn.rms_norm(xx, lp["attn_norm"], cfg.norm_eps)
        xx = xx + blocks.self_attention(cfg, lp, h, positions, causal=False)
        h = nn.rms_norm(xx, lp["mlp_norm"], cfg.norm_eps)
        return xx + blocks.apply_mlp(cfg, lp, h), None

    x, _ = jax.lax.scan(step, x, p["enc_layers"])
    return nn.rms_norm(x, p["enc_norm"]["final_norm"], cfg.norm_eps)


def _decoder_seq(cfg, p, tokens, memory, collect_kv: bool = False):
    B, S = tokens.shape
    x = blocks.embed_tokens(cfg, p, tokens)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    M = memory.shape[1]
    mem_pos = jnp.broadcast_to(jnp.arange(M, dtype=jnp.int32), (B, M))

    def step(carry, lp):
        xx = carry
        h = nn.rms_norm(xx, lp["attn_norm"], cfg.norm_eps)
        q, k, v = blocks.attn_qkv(cfg, lp["self"], h, positions)
        from repro.models.attention import attend

        o = attend(q, k, v, positions, positions, causal=True, chunk=cfg.attn_chunk)
        xx = xx + nn.dense(o.reshape(B, S, cfg.q_dim), lp["self"]["wo"])
        h = nn.rms_norm(xx, lp["cross_norm"], cfg.norm_eps)
        mk, mv = blocks.project_memory(cfg, lp["cross"], memory)
        xx = xx + blocks.cross_attention(cfg, lp["cross"], h, mk, mv, mem_pos)
        h = nn.rms_norm(xx, lp["mlp_norm"], cfg.norm_eps)
        xx = xx + blocks.apply_mlp(cfg, lp, h)
        ys = (k, v, mk, mv) if collect_kv else None
        return xx, ys

    x, kv = jax.lax.scan(step, x, p["dec_layers"])
    x = nn.rms_norm(x, p["final_norm"], cfg.norm_eps)
    return x, kv


def loss_fn(cfg: ModelConfig, p: Params, batch: Dict[str, jax.Array]):
    memory = encode(cfg, p, batch["prefix_embed"])
    h, _ = _decoder_seq(cfg, p, batch["tokens"], memory)
    logits = blocks.logits_fn(cfg, p, h)
    loss = blocks.token_xent(logits, batch["targets"], batch.get("mask"))
    return loss, {"xent": loss}


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    c = blocks.init_attn_cache(cfg, cfg.n_layers, batch, max_len)
    M = cfg.encdec.encoder_len
    D = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    c["ck"] = jnp.zeros((cfg.n_layers, batch, M, cfg.n_kv_heads, D), dt)
    c["cv"] = jnp.zeros_like(c["ck"])
    c["mem_pos"] = jnp.zeros((batch, M), jnp.int32)
    return c


def prefill(cfg: ModelConfig, p: Params, batch: Dict[str, jax.Array],
            max_len: Optional[int] = None):
    """Encode audio + run the prompt through the decoder, build all caches."""
    memory = encode(cfg, p, batch["prefix_embed"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    max_len = max_len or S
    h, kv = _decoder_seq(cfg, p, tokens, memory, collect_kv=True)
    k_all, v_all, ck, cv = kv  # (L,B,S,H,D), cross: (L,B,M,H,D)
    logits = blocks.logits_fn(cfg, p, h[:, -1:])[:, 0]
    Smax = max_len
    take = min(S, Smax)
    pad = Smax - take
    kc = jnp.pad(k_all[:, :, S - take:], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    vc = jnp.pad(v_all[:, :, S - take:], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    kv_pos = jnp.concatenate(
        [
            jnp.broadcast_to(jnp.arange(take, dtype=jnp.int32), (B, take)),
            jnp.full((B, pad), -1, jnp.int32),
        ],
        axis=1,
    )
    M = memory.shape[1]
    cache = {
        "k": kc, "v": vc, "kv_pos": kv_pos,
        "ck": ck, "cv": cv,
        "mem_pos": jnp.broadcast_to(jnp.arange(M, dtype=jnp.int32), (B, M)),
    }
    return logits, cache


def decode_step(cfg: ModelConfig, p: Params, batch: Dict[str, jax.Array],
                cache: Params):
    token, pos = batch["token"], batch["pos"]
    B = token.shape[0]
    x = blocks.embed_tokens(cfg, p, token)
    Smax = cache["k"].shape[2]
    slot = blocks.cache_slot(cfg, pos, Smax)
    kv_pos = blocks.update_kv_pos(cache["kv_pos"], pos, slot)

    def step(carry, xs):
        xx = carry
        lp, kc, vc, ck, cv = xs
        h = nn.rms_norm(xx, lp["attn_norm"], cfg.norm_eps)
        o, kc, vc = blocks.cached_attention_step(
            cfg, lp["self"], h, pos, slot, kv_pos, kc, vc
        )
        xx = xx + o
        h = nn.rms_norm(xx, lp["cross_norm"], cfg.norm_eps)
        xx = xx + blocks.cross_attention(cfg, lp["cross"], h, ck, cv, cache["mem_pos"])
        h = nn.rms_norm(xx, lp["mlp_norm"], cfg.norm_eps)
        xx = xx + blocks.apply_mlp(cfg, lp, h)
        return xx, (kc, vc)

    x, (k2, v2) = jax.lax.scan(
        step, x, (p["dec_layers"], cache["k"], cache["v"], cache["ck"], cache["cv"])
    )
    x = nn.rms_norm(x, p["final_norm"], cfg.norm_eps)
    logits = blocks.logits_fn(cfg, p, x)[:, 0]
    cache = dict(cache, k=k2, v=v2, kv_pos=kv_pos)
    return logits, cache
