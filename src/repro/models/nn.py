"""Minimal functional NN substrate (no flax/haiku in this container).

Params are nested dicts of jnp arrays.  Initializers take an explicit key
derived by folding the parameter path into the root key, so adding parameters
never reshuffles existing ones.
"""
from __future__ import annotations

import hashlib
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def _path_key(key: jax.Array, path: str) -> jax.Array:
    """Deterministic per-path key: fold a stable hash of the path string."""
    h = int.from_bytes(hashlib.md5(path.encode()).digest()[:4], "little")
    return jax.random.fold_in(key, h)


def dense_init(
    key: jax.Array,
    path: str,
    in_dim: int,
    out_dim: int,
    dtype: jnp.dtype,
    scale: Optional[float] = None,
) -> jax.Array:
    """Truncated-normal fan-in init (the standard transformer choice)."""
    std = scale if scale is not None else in_dim**-0.5
    w = jax.random.truncated_normal(
        _path_key(key, path), -2.0, 2.0, (in_dim, out_dim), jnp.float32
    )
    return (w * std).astype(dtype)


def stacked_dense_init(
    key: jax.Array,
    path: str,
    n: int,
    in_dim: int,
    out_dim: int,
    dtype: jnp.dtype,
    scale: Optional[float] = None,
) -> jax.Array:
    """(n, in, out) stacked weights for scan-over-layers / experts."""
    std = scale if scale is not None else in_dim**-0.5
    w = jax.random.truncated_normal(
        _path_key(key, path), -2.0, 2.0, (n, in_dim, out_dim), jnp.float32
    )
    return (w * std).astype(dtype)


def embed_init(
    key: jax.Array, path: str, vocab: int, dim: int, dtype: jnp.dtype
) -> jax.Array:
    w = jax.random.normal(_path_key(key, path), (vocab, dim), jnp.float32)
    return (w * dim**-0.5).astype(dtype)


def zeros(shape: Sequence[int], dtype: jnp.dtype) -> jax.Array:
    return jnp.zeros(shape, dtype)


def ones(shape: Sequence[int], dtype: jnp.dtype) -> jax.Array:
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# Ops
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float) -> jax.Array:
    """RMSNorm in f32, cast back to input dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(x.dtype)


def dense(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None) -> jax.Array:
    y = jnp.einsum("...i,io->...o", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def mlp_act(h_in: jax.Array, variant: str, gate: Optional[jax.Array] = None) -> jax.Array:
    """Activation for the MLP hidden.  Gated variants consume ``gate``."""
    if variant == "swiglu":
        assert gate is not None
        return jax.nn.silu(gate) * h_in
    if variant == "geglu":
        assert gate is not None
        return jax.nn.gelu(gate, approximate=True) * h_in
    if variant == "squared_relu":
        r = jax.nn.relu(h_in)
        return r * r
    if variant == "relu":
        return jax.nn.relu(h_in)
    if variant == "gelu":
        return jax.nn.gelu(h_in, approximate=True)
    raise ValueError(f"unknown mlp variant {variant!r}")


def is_gated(variant: str) -> bool:
    return variant in ("swiglu", "geglu")


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim//2,) inverse frequencies, f32."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate pairs. x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = rope_freqs(d, theta)  # (half,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def count_params(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


def tree_cast(params: Params, dtype) -> Params:
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        params,
    )
