"""The paper's forecaster (Sec. 6.1.2 / Fig. 6): LSTM(40) -> Dense(10, ReLU)
-> Dense(1), lag n=5, 5 input features; 10,981 parameters.

This is the batch-layer and speed-layer model of the faithful reproduction.
``cell_step`` is the math the Pallas ``lstm_cell`` kernel fuses on TPU; the
pure-jnp path here doubles as its oracle.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import nn

Params = Dict[str, Any]


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    c = cfg.lstm
    dt = jnp.dtype(cfg.param_dtype)
    H, F = c.hidden, c.n_features
    return {
        "lstm": {
            "kernel": nn.dense_init(key, "lstm/kernel", F, 4 * H, dt),
            "recurrent": nn.dense_init(key, "lstm/recurrent", H, 4 * H, dt,
                                       scale=H**-0.5),
            "bias": _forget_bias(H, dt),
        },
        "dense": {
            "dense_w": nn.dense_init(key, "dense/dense_w", H, c.dense, dt),
            "dense_b": nn.zeros((c.dense,), dt),
        },
        "head": {
            "head_w": nn.dense_init(key, "head/head_w", c.dense, c.out_dim, dt),
            "head_b": nn.zeros((c.out_dim,), dt),
        },
    }


def _forget_bias(H: int, dt) -> jax.Array:
    """Keras-style unit forget-gate bias (gate order i, f, g, o)."""
    b = jnp.zeros((4 * H,), jnp.float32)
    return b.at[H : 2 * H].set(1.0).astype(dt)


def cell_step(p: Params, x_t: jax.Array, h: jax.Array, c: jax.Array):
    """One LSTM cell step.  x_t: (B, F); h, c: (B, H)."""
    H = h.shape[-1]
    z = x_t @ p["kernel"] + h @ p["recurrent"] + p["bias"]
    i, f, g, o = jnp.split(z, 4, axis=-1)
    i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f), jax.nn.sigmoid(o)
    g = jnp.tanh(g)
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new


def _has_qtensor(p: Params) -> bool:
    from repro.serving.quantize import QTensor

    return any(isinstance(leaf, QTensor) for leaf in
               jax.tree_util.tree_leaves(
                   p, is_leaf=lambda x: isinstance(x, QTensor)))


def _mm(x: jax.Array, w) -> jax.Array:
    """x @ w, dispatching the fused int8 dequant-matmul kernel when ``w`` is
    a quantized ``QTensor`` leaf (float leaves multiply as usual, so a
    partially-quantized tree — tiny heads kept in float — still works)."""
    from repro.serving.quantize import QTensor

    if isinstance(w, QTensor):
        from repro.kernels.int8_matmul.ops import qmatmul

        return qmatmul(x, w)
    return x @ w


def _forward_int8(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    """Edge inference on an int8-synced speed model (the TFLite-on-Pi
    analog): every quantized weight matrix dispatches ``qmatmul`` — the
    whole-sequence input projection in one kernel call, the recurrent
    projection once per step inside the scan — and activations stay float
    (weight-only quantization, what the accuracy test pins)."""
    c = cfg.lstm
    B, T, _ = x.shape
    lp = p["lstm"]
    zx = _mm(x.reshape(B * T, -1), lp["kernel"]).reshape(B, T, 4 * c.hidden)
    h0 = jnp.zeros((B, c.hidden), x.dtype)
    c0 = jnp.zeros((B, c.hidden), x.dtype)
    bias = lp["bias"]

    def step(carry, z_t):
        h, cc = carry
        z = z_t + _mm(h, lp["recurrent"]) + bias
        i, f, g, o = jnp.split(z, 4, axis=-1)
        i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f), jax.nn.sigmoid(o)
        g = jnp.tanh(g)
        c_new = f * cc + i * g
        h_new = o * jnp.tanh(c_new)
        return (h_new, c_new), None

    (h, _), _ = jax.lax.scan(step, (h0, c0), zx.transpose(1, 0, 2))
    d = jax.nn.relu(_mm(h, p["dense"]["dense_w"]) + p["dense"]["dense_b"])
    return _mm(d, p["head"]["head_w"]) + p["head"]["head_b"]


def forward(cfg: ModelConfig, p: Params, x: jax.Array,
            use_pallas: Optional[bool] = None) -> jax.Array:
    """x: (B, lag, F) -> prediction (B, out_dim).

    A params tree containing ``QTensor`` leaves (an int8-synced speed model)
    routes to the quantized inference path regardless of ``use_pallas``."""
    c = cfg.lstm
    B = x.shape[0]
    if _has_qtensor(p):
        return _forward_int8(cfg, p, x)
    use_pallas = cfg.use_pallas if use_pallas is None else use_pallas
    if use_pallas:
        from repro.kernels.lstm_cell import ops as lstm_ops

        h = lstm_ops.lstm_sequence(
            x, p["lstm"]["kernel"], p["lstm"]["recurrent"], p["lstm"]["bias"]
        )
    else:
        h0 = jnp.zeros((B, c.hidden), x.dtype)
        c0 = jnp.zeros((B, c.hidden), x.dtype)

        def step(carry, x_t):
            h, cc = carry
            h, cc = cell_step(p["lstm"], x_t, h, cc)
            return (h, cc), None

        (h, _), _ = jax.lax.scan(step, (h0, c0), x.transpose(1, 0, 2))
    d = jax.nn.relu(h @ p["dense"]["dense_w"] + p["dense"]["dense_b"])
    return d @ p["head"]["head_w"] + p["head"]["head_b"]


def loss_fn(cfg: ModelConfig, p: Params, batch: Dict[str, jax.Array]):
    """MSE regression loss.  batch: {"x": (B,lag,F), "y": (B,out)} plus an
    optional per-example validity "mask" (B,) — 1 for real examples, 0 for
    the padding the fixed-shape-bucket trainer adds.  A masked batch yields
    exactly the unpadded mean, so every shape bucket trains the same loss."""
    pred = forward(cfg, p, batch["x"])
    err = (pred - batch["y"]).astype(jnp.float32)
    sq = err * err
    mask = batch.get("mask")
    if mask is None:
        loss = jnp.mean(sq)
    else:
        m = mask.astype(jnp.float32)[:, None]
        denom = jnp.maximum(jnp.sum(m), 1.0) * sq.shape[-1]
        loss = jnp.sum(sq * m) / denom
    return loss, {"mse": loss, "rmse": jnp.sqrt(loss)}


def predict(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    return forward(cfg, p, x)
