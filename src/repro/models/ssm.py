"""Mamba2 state-space block (used by zamba2's backbone) [arXiv:2405.21060
SSD form; zamba2 per arXiv:2411.15242].

Per head (head dim P, state dim N), scalar decay A per head:

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * (x_t  outer  B_t)
    y_t = h_t @ C_t + D * x_t

with a causal depthwise conv on (x, B, C), softplus dt, and a gated RMSNorm
(silu(z)) before the output projection.  The sequence form below scans time
steps (XLA path); the Pallas chunked-SSD kernel is the TPU-optimized
equivalent (repro.kernels.ssm_scan).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models import nn

Params = Dict[str, Any]


def dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    xbc_dim = d_inner + 2 * s.state_dim  # x, B, C (single group)
    d_in_proj = 2 * d_inner + 2 * s.state_dim + H  # z, x, B, C, dt
    return d_inner, H, xbc_dim, d_in_proj


def init_block(key, path: str, cfg: ModelConfig, n: int) -> Params:
    dt_ = jnp.dtype(cfg.param_dtype)
    s = cfg.ssm
    d_inner, H, xbc_dim, d_in_proj = dims(cfg)

    def mk(name, i, o):
        return nn.stacked_dense_init(key, f"{path}/{name}", n, i, o, dt_)

    return {
        "in_proj": mk("in_proj", cfg.d_model, d_in_proj),
        "conv_w": (
            jax.random.normal(
                nn._path_key(key, f"{path}/conv_w"), (n, s.conv_dim, xbc_dim),
                jnp.float32,
            )
            * (s.conv_dim**-0.5)
        ).astype(dt_),
        "conv_b": nn.zeros((n, xbc_dim), dt_),
        "A_log": nn.zeros((n, H), jnp.float32),
        "D": nn.ones((n, H), jnp.float32),
        "dt_bias": nn.zeros((n, H), jnp.float32),
        "ssm_norm": nn.ones((n, d_inner), dt_),
        "out_proj": mk("out_proj", d_inner, cfg.d_model),
    }


def _conv_scan(xbc: jax.Array, conv_state: jax.Array, w: jax.Array, b: jax.Array):
    """Causal depthwise conv.  xbc: (B,T,C); conv_state: (B,W-1,C) history."""
    W = w.shape[0]
    full = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
    out = jnp.zeros_like(xbc)
    for i in range(W):
        out = out + full[:, i : i + xbc.shape[1]] * w[i].astype(xbc.dtype)
    out = out + b.astype(xbc.dtype)
    new_state = full[:, full.shape[1] - (W - 1) :]
    return jax.nn.silu(out), new_state


def apply_block(
    cfg: ModelConfig,
    lp: Params,
    x: jax.Array,  # (B, T, d)
    conv_state: jax.Array,  # (B, W-1, xbc_dim)
    h_state: jax.Array,  # (B, H, P, N) f32
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    s = cfg.ssm
    B, T, _ = x.shape
    d_inner, H, xbc_dim, _ = dims(cfg)
    P, N = s.head_dim, s.state_dim

    zxbcdt = nn.dense(x, lp["in_proj"])
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, d_inner + xbc_dim], axis=-1)
    xbc, conv_state = _conv_scan(xbc, conv_state, lp["conv_w"], lp["conv_b"])
    xs, Bmat, Cmat = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])  # (B,T,H)
    A = -jnp.exp(lp["A_log"])  # (H,)
    xs_h = xs.reshape(B, T, H, P).astype(jnp.float32)
    Bf = Bmat.astype(jnp.float32)  # (B,T,N)
    Cf = Cmat.astype(jnp.float32)

    if cfg.scan_chunked and T > 1:
        ys, h_state = ssd_chunked(xs_h, Bf, Cf, dt, A, h_state,
                                  chunk=cfg.scan_chunk)
    else:
        ys, h_state = ssd_stepwise(xs_h, Bf, Cf, dt, A, h_state)
    y = ys + lp["D"][None, None, :, None] * xs_h
    y = y.reshape(B, T, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = nn.rms_norm(y, lp["ssm_norm"], cfg.norm_eps)
    out = nn.dense(y, lp["out_proj"])
    return shard(out, "batch", "seq", "embed"), conv_state, h_state


def ssd_stepwise(x, b, c, dt, A, h0):
    """Per-timestep selective scan (baseline XLA path).
    x: (B,T,H,P) f32; b,c: (B,T,N); dt: (B,T,H); A: (H,); h0: (B,H,P,N).
    Returns (y (B,T,H,P), h_final)."""

    def step(h, xs_t):
        xt, bt, ct, dtt = xs_t  # (B,H,P), (B,N), (B,N), (B,H)
        decay = jnp.exp(dtt * A[None])  # (B,H)
        upd = (dtt[..., None, None] * xt[..., None]) * bt[:, None, None, :]
        h = decay[..., None, None] * h + upd  # (B,H,P,N)
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    xs_t = (
        x.transpose(1, 0, 2, 3),
        b.transpose(1, 0, 2),
        c.transpose(1, 0, 2),
        dt.transpose(1, 0, 2),
    )
    h, ys = jax.lax.scan(step, h0, xs_t)  # ys: (T,B,H,P)
    return ys.transpose(1, 0, 2, 3), h


def ssd_chunked(x, b, c, dt, A, h0, chunk: int = 64):
    """Chunked SSD (Mamba2's own blocked algorithm, XLA form; §Perf path).

    The decay is a SCALAR per head, so the intra-chunk interaction matrix
    M[t,s] = exp(L_t - L_s) * dt_s * (B_s . C_t)  (s <= t, inclusive)
    is (C, C) per (batch, head) — one masked matmul replaces C sequential
    rank-1 state updates; the cross-chunk carry is a single einsum.
    """
    B, T, H, P = x.shape
    N = b.shape[-1]
    C = min(chunk, T)
    pad = (-T) % C
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))  # dt=0: identity step
    nC = (T + pad) // C

    def chunk_step(h, xs):
        xc, bc, cc, dtc = xs  # (B,C,H,P), (B,C,N), (B,C,N), (B,C,H)
        la = dtc * A[None, None]  # (B,C,H), <= 0
        L = jnp.cumsum(la, axis=1)  # inclusive
        # inter: decayed initial state read out by C_t
        y_inter = jnp.exp(L)[..., None] * jnp.einsum("bhpn,btn->bthp", h, cc)
        # intra: scalar decays -> (B,t,s,H) matrix, mask s<=t
        Dm = L[:, :, None] - L[:, None, :]  # (B,t,s,H)
        Dm = jnp.minimum(Dm, 0.0)
        bcct = jnp.einsum("bsn,btn->bts", bc, cc)  # (B,t,s)
        M = jnp.exp(Dm) * dtc[:, None, :, :] * bcct[..., None]
        mask = jnp.tril(jnp.ones((C, C), bool))  # inclusive
        M = jnp.where(mask[None, :, :, None], M, 0.0)
        y_intra = jnp.einsum("btsh,bshp->bthp", M, xc)
        # state update
        decay_all = jnp.exp(L[:, -1][:, None] - L)  # (B,C,H) <= 1
        upd = jnp.einsum(
            "bsh,bshp,bsn->bhpn", decay_all * dtc, xc, bc
        )
        h = jnp.exp(L[:, -1])[..., None, None] * h + upd
        return h, y_inter + y_intra

    xs = (
        x.reshape(B, nC, C, H, P).transpose(1, 0, 2, 3, 4),
        b.reshape(B, nC, C, N).transpose(1, 0, 2, 3),
        c.reshape(B, nC, C, N).transpose(1, 0, 2, 3),
        dt.reshape(B, nC, C, H).transpose(1, 0, 2, 3),
    )
    h, ys = jax.lax.scan(chunk_step, h0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, nC * C, H, P)
    return y[:, :T], h


def init_block_cache(cfg: ModelConfig, n: int, batch: int):
    s = cfg.ssm
    d_inner, H, xbc_dim, _ = dims(cfg)
    return {
        "conv": jnp.zeros((n, batch, s.conv_dim - 1, xbc_dim), jnp.dtype(cfg.dtype)),
        "h": jnp.zeros((n, batch, H, s.head_dim, s.state_dim), jnp.float32),
    }
