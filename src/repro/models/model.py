"""Model dispatcher: one uniform interface over the whole zoo.

``get_model(cfg)`` returns a ``Model`` whose functions have the signatures the
launcher, dry-run, serving engine and hybrid-learning core all consume:

    init(key)                        -> params
    loss_fn(params, batch)           -> (loss, metrics)
    prefill(params, batch, max_len)  -> (last_logits, cache)
    decode_step(params, batch, cache)-> (logits, cache)
    init_cache(batch_size, max_len)  -> cache pytree

``input_specs(cfg, shape)`` emits jax.ShapeDtypeStruct stand-ins for every
model input of a given input shape — weak-type-correct, shardable, no device
allocation — exactly what ``jax.jit(...).lower(**specs)`` needs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig

Params = Dict[str, Any]
Batch = Dict[str, jax.Array]


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[[jax.Array], Params]
    loss_fn: Callable[[Params, Batch], Tuple[jax.Array, Dict[str, jax.Array]]]
    prefill: Optional[Callable[..., Tuple[jax.Array, Params]]]
    decode_step: Optional[Callable[[Params, Batch, Params], Tuple[jax.Array, Params]]]
    init_cache: Optional[Callable[[int, int], Params]]


def get_model(cfg: ModelConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        from repro.models import transformer as m

        return Model(
            cfg=cfg,
            init=lambda key: m.init_params(cfg, key),
            loss_fn=lambda p, b: m.loss_fn(cfg, p, b),
            prefill=lambda p, b, max_len=None: m.prefill(cfg, p, b, max_len),
            decode_step=lambda p, b, c: m.decode_step(cfg, p, b, c),
            init_cache=lambda bsz, ml: m.init_cache(cfg, bsz, ml),
        )
    if fam == "ssm":  # rwkv6
        from repro.models import rwkv as m

        return Model(
            cfg=cfg,
            init=lambda key: m.init_params(cfg, key),
            loss_fn=lambda p, b: m.loss_fn(cfg, p, b),
            prefill=lambda p, b, max_len=None: m.prefill(cfg, p, b, max_len),
            decode_step=lambda p, b, c: m.decode_step(cfg, p, b, c),
            init_cache=lambda bsz, ml: m.init_cache(cfg, bsz, ml),
        )
    if fam == "hybrid":
        from repro.models import hybrid_arch as m

        return Model(
            cfg=cfg,
            init=lambda key: m.init_params(cfg, key),
            loss_fn=lambda p, b: m.loss_fn(cfg, p, b),
            prefill=lambda p, b, max_len=None: m.prefill(cfg, p, b, max_len),
            decode_step=lambda p, b, c: m.decode_step(cfg, p, b, c),
            init_cache=lambda bsz, ml: m.init_cache(cfg, bsz, ml),
        )
    if fam == "audio":
        from repro.models import encdec as m

        return Model(
            cfg=cfg,
            init=lambda key: m.init_params(cfg, key),
            loss_fn=lambda p, b: m.loss_fn(cfg, p, b),
            prefill=lambda p, b, max_len=None: m.prefill(cfg, p, b, max_len),
            decode_step=lambda p, b, c: m.decode_step(cfg, p, b, c),
            init_cache=lambda bsz, ml: m.init_cache(cfg, bsz, ml),
        )
    if fam == "lstm":
        from repro.models import lstm as m

        return Model(
            cfg=cfg,
            init=lambda key: m.init_params(cfg, key),
            loss_fn=lambda p, b: m.loss_fn(cfg, p, b),
            prefill=None,
            decode_step=None,
            init_cache=None,
        )
    raise ValueError(f"unknown family {fam!r}")


# ---------------------------------------------------------------------------
# ShapeDtypeStruct input specs (the dry-run pattern)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the step function of this input shape.

    train   -> kwargs of loss/train step: {"batch": {...}}
    prefill -> kwargs of prefill step:    {"batch": {...}}
    decode  -> kwargs of decode step:     {"batch": {...}, "cache": {...}}
    """
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "lstm":
        c = cfg.lstm
        return {
            "batch": {
                "x": _sds((B, c.lag, c.n_features), cfg.dtype),
                "y": _sds((B, c.out_dim), cfg.dtype),
            }
        }

    def token_batch(seq_len):
        b: Dict[str, Any] = {"tokens": _sds((B, seq_len), jnp.int32)}
        if cfg.frontend is not None:
            fe = cfg.frontend
            b["prefix_embed"] = _sds((B, fe.n_prefix_tokens, fe.embed_dim), cfg.dtype)
        return b

    if shape.kind == "train":
        # VLM prefix counts toward the sequence budget
        text_len = S - (cfg.frontend.n_prefix_tokens
                        if cfg.family == "vlm" and cfg.frontend else 0)
        b = token_batch(text_len)
        b["targets"] = _sds((B, text_len), jnp.int32)
        return {"batch": b}

    if shape.kind == "prefill":
        text_len = S - (cfg.frontend.n_prefix_tokens
                        if cfg.family == "vlm" and cfg.frontend else 0)
        return {"batch": token_batch(text_len)}

    if shape.kind == "decode":
        model = get_model(cfg)
        cache = jax.eval_shape(lambda: model.init_cache(B, S))
        batch = {"token": _sds((B, 1), jnp.int32), "pos": _sds((B,), jnp.int32)}
        if cfg.family == "audio":
            # cross K/V + memory positions live in the cache already
            pass
        return {"batch": batch, "cache": cache}

    raise ValueError(shape.kind)
