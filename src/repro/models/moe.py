"""Mixture-of-experts layer with two expert-parallel strategies.

* ``onehot`` — Switch-Transformer capacity dispatch via one-hot einsums over
  sequence sub-groups.  GSPMD-friendly, differentiable, memory O(tokens * E *
  C / groups); the right choice for coarse MoE (grok-1: 8 experts) and all
  reduced/smoke configs.

* ``shard_map`` — fine-grained expert parallelism for large expert counts
  (kimi-k2: 384 experts).  Experts are sharded over the ``model`` mesh axis;
  tokens (batch-sharded over pod/data, replicated over model) are dispatched
  locally with a sort + capacity scatter, each device computes only its local
  experts, and a ``psum`` over ``model`` recombines the per-token expert sums.
  Expert weights are additionally FSDP-sharded over (pod, data) and gathered
  per layer inside the shard_map body (ZeRO-3) — without this the 1T-param
  config cannot even hold its weights.

Both paths compute the Switch load-balancing auxiliary loss.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shd
from repro.models import nn

Params = Dict[str, Any]


def init_moe(key, path: str, cfg: ModelConfig, n_stack: Optional[int] = None) -> Params:
    moe = cfg.moe
    dt = jnp.dtype(cfg.param_dtype)
    d, f, E = cfg.d_model, moe.d_ff_expert, moe.n_experts

    def mk(name, *shape_dims):
        lead = () if n_stack is None else (n_stack,)
        # use stacked_dense_init-compatible normal init
        std = shape_dims[-2] ** -0.5
        w = jax.random.truncated_normal(
            nn._path_key(key, f"{path}/{name}"), -2.0, 2.0,
            lead + shape_dims, jnp.float32,
        )
        return (w * std).astype(dt)

    p = {
        "router": mk("router", d, E),
        "we_in": mk("we_in", E, d, f),
        "we_out": mk("we_out", E, f, d),
    }
    if nn.is_gated(cfg.mlp_variant):
        p["we_gate"] = mk("we_gate", E, d, f)
    if moe.n_shared_experts > 0:
        fs = f * moe.n_shared_experts
        p["w_in"] = mk("w_in", d, fs)
        p["w_out"] = mk("w_out", fs, d)
        if nn.is_gated(cfg.mlp_variant):
            p["w_gate"] = mk("w_gate", d, fs)
    return p


def _route(cfg: ModelConfig, router_w: jax.Array, x: jax.Array):
    """Router probabilities and top-k selection.  x: (..., d)."""
    moe = cfg.moe
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_idx = jax.lax.top_k(probs, moe.top_k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)
    return probs, top_p, top_idx


def _aux_loss(cfg: ModelConfig, probs: jax.Array, top_idx: jax.Array) -> jax.Array:
    """Switch load-balance loss: E * sum_e f_e * P_e."""
    E = cfg.moe.n_experts
    sel = jax.nn.one_hot(top_idx, E, dtype=jnp.float32)  # (..., k, E)
    frac_tokens = jnp.mean(jnp.sum(sel, axis=-2).reshape(-1, E), axis=0)
    mean_prob = jnp.mean(probs.reshape(-1, E), axis=0)
    return E * jnp.sum(frac_tokens * mean_prob)


def _expert_ffn(cfg: ModelConfig, p: Params, xe: jax.Array) -> jax.Array:
    """Dense per-expert FFN.  xe: (E, C, d) -> (E, C, d)."""
    h = jnp.einsum("ecd,edf->ecf", xe, p["we_in"].astype(xe.dtype))
    gate = None
    if "we_gate" in p:
        gate = jnp.einsum("ecd,edf->ecf", xe, p["we_gate"].astype(xe.dtype))
    h = nn.mlp_act(h, cfg.mlp_variant, gate)
    return jnp.einsum("ecf,efd->ecd", h, p["we_out"].astype(xe.dtype))


# ---------------------------------------------------------------------------
# Path 1: one-hot capacity dispatch (GSPMD)
# ---------------------------------------------------------------------------


def moe_onehot(cfg: ModelConfig, p: Params, x: jax.Array,
               group: int = 0, no_drop: bool = False
               ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).

    no_drop=True sets capacity to the worst case (every token in the group
    routed to one expert), making the layer composition-independent — used by
    the inference paths so decode == prefill == full forward exactly.
    Training keeps the Switch capacity factor (drops are faithful behavior).
    """
    moe = cfg.moe
    B, S, d = x.shape
    E, k = moe.n_experts, moe.top_k
    g = min(group or moe.dispatch_group, S)
    nG = S // g if S % g == 0 else 1
    if S % g != 0:
        g = S
    if no_drop:
        cap = g * k
    else:
        cap = max(1, int(g * k * moe.capacity_factor / E))

    xg = x.reshape(B * nG, g, d)
    probs, top_p, top_idx = _route(cfg, p["router"], xg)  # (N, g, k)
    aux = _aux_loss(cfg, probs, top_idx)

    sel = jax.nn.one_hot(top_idx, E, dtype=jnp.float32)  # (N, g, k, E)
    # position of each (token, slot) within its expert queue
    pos_in_e = jnp.cumsum(sel.reshape(B * nG, g * k, E), axis=1) - 1.0
    pos_in_e = pos_in_e.reshape(B * nG, g, k, E)
    keep = (pos_in_e < cap) & (sel > 0)
    cap_oh = jax.nn.one_hot(pos_in_e.astype(jnp.int32), cap, dtype=jnp.float32)
    # dispatch: (N, g, E, C)
    dispatch = jnp.einsum("ngke,ngkec->ngec", sel * keep, cap_oh)
    combine = jnp.einsum("ngk,ngke,ngkec->ngec", top_p, sel * keep, cap_oh)
    dispatch = shd.shard(dispatch, "batch", None, "experts", None)

    xe = jnp.einsum("ngec,ngd->necd", dispatch, xg.astype(jnp.float32))
    xe = xe.reshape(B * nG * E, cap, d)  # flatten for expert matmul grouping
    xe = xe.reshape(B * nG, E, cap, d).astype(x.dtype)
    # merge group dim into capacity for a single (E, N*C, d) expert matmul
    xe2 = xe.transpose(1, 0, 2, 3).reshape(E, B * nG * cap, d)
    xe2 = shd.shard(xe2, "experts", None, None)
    ye2 = _expert_ffn(cfg, p, xe2)
    ye = ye2.reshape(E, B * nG, cap, d).transpose(1, 0, 2, 3)

    out = jnp.einsum("ngec,necd->ngd", combine, ye.astype(jnp.float32))
    out = out.reshape(B, S, d).astype(x.dtype)
    return out, aux


# ---------------------------------------------------------------------------
# Path 2: shard_map expert parallelism (fine-grained MoE)
# ---------------------------------------------------------------------------

# jax >= 0.6 exposes shard_map at the top level with the replication check
# renamed check_vma; 0.4.x only has jax.experimental.shard_map with check_rep
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _SHARD_MAP_NOCHECK = {"check_vma": False}
else:
    from jax.experimental.shard_map import shard_map as _shard_map

    _SHARD_MAP_NOCHECK = {"check_rep": False}


def _axis_size(name: str) -> int:
    # jax.lax.axis_size is also a >= 0.6 addition; psum of a literal 1 is
    # constant-folded to the static axis size on older versions
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def _local_ep_body(cfg: ModelConfig, model_axis: str, fsdp_axes, x, p):
    """Per-device body. x: (B_loc, S, d) local tokens (replicated over model).

    Two partitionings of the expert compute over the model axis:
    * fine-grained (E >= n_shards, divisible): experts sharded — each rank
      holds E/n_shards experts and scatters only its own tokens' slots
      (kimi-k2: 384 experts / 16 ranks).
    * coarse (E < n_shards): experts replicated, the expert FFN dim is
      sharded — every rank processes all E experts on an f-slice and the
      closing psum combines partial FFN sums (grok-1: 8 experts, 16 ranks).
      This is the sort-scatter replacement for the one-hot dispatch einsum
      (see EXPERIMENTS.md §Perf).
    Either way weight d/f dims are additionally FSDP-sharded over fsdp_axes
    and gathered here per layer (ZeRO-3).
    """
    moe = cfg.moe
    B, S, d = x.shape
    k = moe.top_k
    E = moe.n_experts
    E_loc = p["we_in"].shape[0]
    experts_sharded = E_loc < E
    n_shards = _axis_size(model_axis)
    my_shard = jax.lax.axis_index(model_axis)

    # gather FSDP-sharded expert weights for this layer (ZeRO-3 gather)
    def gather(w):
        if fsdp_axes:
            w = jax.lax.all_gather(w, fsdp_axes, axis=1, tiled=True)
        return w

    we_in = gather(p["we_in"])
    we_out = p["we_out"]
    if fsdp_axes:
        we_out = jax.lax.all_gather(we_out, fsdp_axes, axis=2, tiled=True)
    we_gate = gather(p["we_gate"]) if "we_gate" in p else None
    router_w = p["router"]
    if fsdp_axes:
        router_w = jax.lax.all_gather(router_w, fsdp_axes, axis=0, tiled=True)

    probs, top_p, top_idx = _route(cfg, router_w, x)  # (B, S, k)
    aux = _aux_loss(cfg, probs, top_idx)

    T = B * S
    flat_idx = top_idx.reshape(T * k)
    flat_w = top_p.reshape(T * k)
    tok_of_slot = jnp.arange(T * k, dtype=jnp.int32) // k

    # rank of each slot within its expert via sort
    order = jnp.argsort(flat_idx)
    sorted_e = flat_idx[order]
    counts = jnp.bincount(flat_idx, length=E)
    starts = jnp.cumsum(counts) - counts
    rank_sorted = jnp.arange(T * k, dtype=jnp.int32) - starts[sorted_e]
    rank = jnp.zeros((T * k,), jnp.int32).at[order].set(rank_sorted)

    cap = max(1, int(T * k * moe.capacity_factor / E))
    if experts_sharded:
        local_e = flat_idx - my_shard * E_loc  # expert index on this shard
        mine = (local_e >= 0) & (local_e < E_loc) & (rank < cap)
    else:
        local_e = flat_idx  # all experts local (f-dim is sharded instead)
        mine = rank < cap
    # scatter local tokens into (E_loc, cap, d)
    xf = x.reshape(T, d)
    src = jnp.take(xf, tok_of_slot, axis=0)  # (T*k, d)
    buf = jnp.zeros((E_loc, cap, d), x.dtype)
    e_idx = jnp.where(mine, local_e, 0)
    c_idx = jnp.where(mine, rank, 0)
    src = jnp.where(mine[:, None], src, 0)
    buf = buf.at[e_idx, c_idx].add(src)

    pp = {"we_in": we_in, "we_out": we_out}
    if we_gate is not None:
        pp["we_gate"] = we_gate
    ye = _expert_ffn(cfg, pp, buf)  # (E_loc, cap, d)

    # gather back: each slot reads its expert output if local, weighted
    out_slot = ye[e_idx, c_idx] * jnp.where(mine, flat_w, 0.0)[:, None].astype(ye.dtype)
    out = jnp.zeros((T, d), jnp.float32).at[tok_of_slot].add(
        out_slot.astype(jnp.float32)
    )
    # combine expert contributions across model shards
    out = jax.lax.psum(out, model_axis)
    aux = jax.lax.pmean(aux, model_axis)
    return out.reshape(B, S, d).astype(x.dtype), aux


def moe_shard_map(cfg: ModelConfig, p: Params, x: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE via shard_map; requires an active mesh context."""
    ctx = shd._ctx()
    if ctx is None:
        return moe_onehot(cfg, p, x)
    mesh, rules = ctx
    axis_names = mesh.axis_names
    model_axis = "model" if "model" in axis_names else axis_names[-1]
    fsdp_axes = tuple(a for a in ("pod", "data") if a in axis_names)
    E = cfg.moe.n_experts
    n_model = mesh.devices.shape[axis_names.index(model_axis)]
    f = cfg.moe.d_ff_expert
    if E % n_model == 0:
        # fine-grained: experts over the model axis
        w_in_spec = P(model_axis, fsdp_axes or None, None)
        w_out_spec = P(model_axis, None, fsdp_axes or None)
    elif f % n_model == 0:
        # coarse: experts replicated, expert-FFN dim over the model axis
        w_in_spec = P(None, fsdp_axes or None, model_axis)
        w_out_spec = P(None, model_axis, fsdp_axes or None)
    else:
        return moe_onehot(cfg, p, x)

    batch_spec = P(fsdp_axes if fsdp_axes else None, None, None)
    in_specs = (
        batch_spec,
        {
            "router": P(fsdp_axes or None, None),
            "we_in": w_in_spec,
            "we_out": w_out_spec,
            **({"we_gate": w_in_spec} if "we_gate" in p else {}),
        },
    )
    out_specs = (batch_spec, P())
    pp = {kk: p[kk] for kk in ("router", "we_in", "we_out", "we_gate") if kk in p}

    fn = _shard_map(
        lambda xx, params: _local_ep_body(cfg, model_axis, fsdp_axes, xx, params),
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        **_SHARD_MAP_NOCHECK,
    )
    out, aux = fn(x, pp)
    return out, aux


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def apply_moe(cfg: ModelConfig, p: Params, x: jax.Array,
              ep_mode: Optional[str] = None, no_drop: bool = False
              ) -> Tuple[jax.Array, jax.Array]:
    """Dispatch to the right EP strategy; adds the shared-expert path."""
    moe = cfg.moe
    mode = ep_mode
    if mode is None:
        if moe.ep_mode != "auto" and x.shape[1] > 1:
            mode = moe.ep_mode
        else:
            mode = ("shard_map" if moe.n_experts > 16 and x.shape[1] > 1
                    else "onehot")
    # exact (no-drop) one-hot dispatch is only feasible for coarse MoE;
    # fine-grained MoE serving stays capacity-based (documented drop risk)
    if no_drop and moe.n_experts > 64:
        no_drop = False
    if no_drop:
        mode = "onehot"
    if mode == "shard_map":
        out, aux = moe_shard_map(cfg, p, x)
    else:
        out, aux = moe_onehot(cfg, p, x, no_drop=no_drop)
    if moe.n_shared_experts > 0:
        h = nn.dense(x, p["w_in"])
        gate = nn.dense(x, p["w_gate"]) if "w_gate" in p else None
        h = nn.mlp_act(h, cfg.mlp_variant, gate)
        out = out + nn.dense(h, p["w_out"])
    return out, aux * moe.router_aux_loss
