"""Zamba2-style hybrid [arXiv:2411.15242]: Mamba2 backbone with a single
*shared-weight* transformer block applied every ``attn_every`` layers.

Faithful-to-spirit adaptation (recorded in DESIGN.md): the shared block input
is concat(hidden, original embedding) projected 2d->d (``shared_down``) and
the block then runs at d_model width; real Zamba2 runs the shared block at 2d
with per-application LoRAs, which we omit.

The backbone is grouped into ``n_super`` super-layers of ``attn_every`` Mamba
blocks each (scan over super-layers, inner scan over the group), plus a
remainder tail; the shared block closes each super-layer.  SSM state decode is
O(1) in sequence length apart from the shared block's KV cache -> long_500k
runs for this arch.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks, nn, ssm

Params = Dict[str, Any]


def _split(cfg: ModelConfig) -> Tuple[int, int, int]:
    k = cfg.hybrid.attn_every
    n_super = cfg.n_layers // k
    rem = cfg.n_layers - n_super * k
    return k, n_super, rem


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    p: Params = {
        **blocks.init_embed(key, cfg),
        "final_norm": nn.ones((d,), dt),
        "mamba": ssm.init_block(key, "mamba", cfg, cfg.n_layers),
        "shared": {
            "attn_norm": nn.ones((d,), dt),
            "mlp_norm": nn.ones((d,), dt),
            **blocks.init_attn(key, "shared/attn", cfg),
            **blocks.init_mlp(key, "shared/mlp", cfg),
            "shared_down": nn.dense_init(key, "shared/shared_down", 2 * d, d, dt),
        },
    }
    return p


def _take_group(stack: Params, start: int, n: int) -> Params:
    return jax.tree_util.tree_map(
        lambda x: jax.lax.dynamic_slice_in_dim(x, start, n, axis=0), stack
    )


def _mamba_group_scan(cfg, group_params, x, conv_states, h_states):
    """Scan ``n`` mamba blocks.  group_params leaves: (n, ...)."""

    def step(carry, xs):
        xx = carry
        lp, cs, hs = xs
        o, cs2, hs2 = ssm.apply_block(cfg, lp, xx, cs, hs)
        return xx + o, (cs2, hs2)

    if cfg.remat == "block":
        step = jax.checkpoint(step, prevent_cse=False)
    x, (conv2, h2) = jax.lax.scan(step, x, (group_params, conv_states, h_states))
    return x, conv2, h2


def _shared_block_seq(cfg, sp: Params, x, embed0, positions):
    """Full-sequence shared attention block (train/prefill).  Returns
    (x, (k, v)) with k/v for the cache."""
    h_in = jnp.concatenate([x, embed0], axis=-1)
    h = nn.dense(h_in, sp["shared_down"])
    hn = nn.rms_norm(h, sp["attn_norm"], cfg.norm_eps)
    q, k, v = blocks.attn_qkv(cfg, sp, hn, positions)
    from repro.models.attention import attend

    o = attend(q, k, v, positions, positions, causal=True, chunk=cfg.attn_chunk)
    o = o.reshape(*h.shape[:2], cfg.q_dim)
    h = h + nn.dense(o, sp["wo"])
    hm = nn.rms_norm(h, sp["mlp_norm"], cfg.norm_eps)
    h = h + blocks.apply_mlp(cfg, sp, hm)
    return x + h, (k, v)


def _shared_block_step(cfg, sp: Params, x, embed0, pos, slot, kv_pos, kc, vc):
    h_in = jnp.concatenate([x, embed0], axis=-1)
    h = nn.dense(h_in, sp["shared_down"])
    hn = nn.rms_norm(h, sp["attn_norm"], cfg.norm_eps)
    o, kc, vc = blocks.cached_attention_step(cfg, sp, hn, pos, slot, kv_pos, kc, vc)
    h = h + o
    hm = nn.rms_norm(h, sp["mlp_norm"], cfg.norm_eps)
    h = h + blocks.apply_mlp(cfg, sp, hm)
    return x + h, kc, vc


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    k, n_super, rem = _split(cfg)
    c = ssm.init_block_cache(cfg, cfg.n_layers, batch)
    attn_c = blocks.init_attn_cache(cfg, n_super, batch, max_len)
    return {**c, **attn_c}


def forward(cfg: ModelConfig, p: Params, batch: Dict[str, jax.Array],
            cache: Optional[Params] = None, positions=None):
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = blocks.embed_tokens(cfg, p, tokens)
    embed0 = x
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    k, n_super, rem = _split(cfg)
    if cache is None:
        conv = ssm.init_block_cache(cfg, cfg.n_layers, B)
        conv_states, h_states = conv["conv"], conv["h"]
    else:
        conv_states, h_states = cache["conv"], cache["h"]

    def reshape_group(stack, n0, n1):
        return jax.tree_util.tree_map(
            lambda t: t[: n0 * n1].reshape((n0, n1) + t.shape[1:]), stack
        )

    main = reshape_group(p["mamba"], n_super, k)
    conv_main = conv_states[: n_super * k].reshape((n_super, k) + conv_states.shape[1:])
    h_main = h_states[: n_super * k].reshape((n_super, k) + h_states.shape[1:])

    def super_step(carry, xs):
        xx = carry
        gp, cs, hs = xs
        xx, cs2, hs2 = _mamba_group_scan(cfg, gp, xx, cs, hs)
        xx, (kk, vv) = _shared_block_seq(cfg, p["shared"], xx, embed0, positions)
        return xx, (cs2, hs2, kk, vv)

    x, (conv2, h2, k_all, v_all) = jax.lax.scan(
        super_step, x, (main, conv_main, h_main)
    )
    conv_new = conv2.reshape((n_super * k,) + conv_states.shape[1:])
    h_new = h2.reshape((n_super * k,) + h_states.shape[1:])
    if rem > 0:
        tail = _take_group(p["mamba"], n_super * k, rem)
        x, conv_t, h_t = _mamba_group_scan(
            cfg, tail, x, conv_states[n_super * k :], h_states[n_super * k :]
        )
        conv_new = jnp.concatenate([conv_new, conv_t], axis=0)
        h_new = jnp.concatenate([h_new, h_t], axis=0)

    x = nn.rms_norm(x, p["final_norm"], cfg.norm_eps)
    return x, (conv_new, h_new, k_all, v_all)


def loss_fn(cfg: ModelConfig, p: Params, batch: Dict[str, jax.Array]):
    h, _ = forward(cfg, p, batch)
    logits = blocks.logits_fn(cfg, p, h)
    loss = blocks.token_xent(logits, batch["targets"], batch.get("mask"))
    return loss, {"xent": loss}


def prefill(cfg: ModelConfig, p: Params, batch: Dict[str, jax.Array],
            max_len: Optional[int] = None):
    tokens = batch["tokens"]
    B, S = tokens.shape
    max_len = max_len or S
    h, (conv, hst, k_all, v_all) = forward(cfg, p, batch)
    logits = blocks.logits_fn(cfg, p, h[:, -1:])[:, 0]
    # place shared-block KV into the fixed cache
    Smax = max_len
    take = min(S, Smax)
    pad = Smax - take
    kc = jnp.pad(k_all[:, :, S - take:], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    vc = jnp.pad(v_all[:, :, S - take:], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    kv_pos = jnp.concatenate(
        [
            jnp.broadcast_to(jnp.arange(take, dtype=jnp.int32), (B, take)),
            jnp.full((B, pad), -1, jnp.int32),
        ],
        axis=1,
    )
    cache = {"conv": conv, "h": hst, "k": kc, "v": vc, "kv_pos": kv_pos}
    return logits, cache


def decode_step(cfg: ModelConfig, p: Params, batch: Dict[str, jax.Array],
                cache: Params):
    token, pos = batch["token"], batch["pos"]
    B = token.shape[0]
    x = blocks.embed_tokens(cfg, p, token)
    embed0 = x
    k, n_super, rem = _split(cfg)
    Smax = cache["k"].shape[2]
    slot = blocks.cache_slot(cfg, pos, Smax)
    kv_pos = blocks.update_kv_pos(cache["kv_pos"], pos, slot)

    conv_states, h_states = cache["conv"], cache["h"]
    main = jax.tree_util.tree_map(
        lambda t: t[: n_super * k].reshape((n_super, k) + t.shape[1:]), p["mamba"]
    )
    conv_main = conv_states[: n_super * k].reshape((n_super, k) + conv_states.shape[1:])
    h_main = h_states[: n_super * k].reshape((n_super, k) + h_states.shape[1:])

    def super_step(carry, xs):
        xx = carry
        gp, cs, hs, kc, vc = xs
        xx, cs2, hs2 = _mamba_group_scan(cfg, gp, xx, cs, hs)
        xx, kc2, vc2 = _shared_block_step(
            cfg, p["shared"], xx, embed0, pos, slot, kv_pos, kc, vc
        )
        return xx, (cs2, hs2, kc2, vc2)

    x, (conv2, h2, k2, v2) = jax.lax.scan(
        super_step, x, (main, conv_main, h_main, cache["k"], cache["v"])
    )
    conv_new = conv2.reshape((n_super * k,) + conv_states.shape[1:])
    h_new = h2.reshape((n_super * k,) + h_states.shape[1:])
    if rem > 0:
        tail = _take_group(p["mamba"], n_super * k, rem)
        x, conv_t, h_t = _mamba_group_scan(
            cfg, tail, x, conv_states[n_super * k :], h_states[n_super * k :]
        )
        conv_new = jnp.concatenate([conv_new, conv_t], axis=0)
        h_new = jnp.concatenate([h_new, h_t], axis=0)

    x = nn.rms_norm(x, p["final_norm"], cfg.norm_eps)
    logits = blocks.logits_fn(cfg, p, x)[:, 0]
    cache = {"conv": conv_new, "h": h_new, "k": k2, "v": v2, "kv_pos": kv_pos}
    return logits, cache
