"""Shared transformer building blocks: GQA attention (with KV caches and
sliding windows), MLP variants, embeddings and the token loss.

All block params are created either per-layer-stacked (leading L dim, consumed
by ``lax.scan`` over layers) or flat (shared blocks / encoders).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models import nn
from repro.models.attention import attend

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def init_attn(key, path: str, cfg: ModelConfig, n_stack: Optional[int] = None) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim

    def mk(name, i, o):
        if n_stack is None:
            return nn.dense_init(key, f"{path}/{name}", i, o, dt)
        return nn.stacked_dense_init(key, f"{path}/{name}", n_stack, i, o, dt)

    p = {
        "wq": mk("wq", d, qd),
        "wk": mk("wk", d, kvd),
        "wv": mk("wv", d, kvd),
        "wo": mk("wo", qd, d),
    }
    if cfg.qkv_bias:
        shape = (qd,) if n_stack is None else (n_stack, qd)
        kshape = (kvd,) if n_stack is None else (n_stack, kvd)
        p["bq"] = nn.zeros(shape, dt)
        p["bk"] = nn.zeros(kshape, dt)
        p["bv"] = nn.zeros(kshape, dt)
    return p


def attn_qkv(
    cfg: ModelConfig, p: Params, x: jax.Array, positions: jax.Array, rope: bool = True
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Project + rope.  x: (B,S,d) -> q (B,S,Hq,D), k/v (B,S,Hkv,D)."""
    B, S, _ = x.shape
    D = cfg.resolved_head_dim
    q = nn.dense(x, p["wq"], p.get("bq")).reshape(B, S, cfg.n_heads, D)
    k = nn.dense(x, p["wk"], p.get("bk")).reshape(B, S, cfg.n_kv_heads, D)
    v = nn.dense(x, p["wv"], p.get("bv")).reshape(B, S, cfg.n_kv_heads, D)
    if rope:
        q = nn.apply_rope(q, positions, cfg.rope_theta)
        k = nn.apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    return q, k, v


def self_attention(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,
    positions: jax.Array,  # (B, S)
    *,
    causal: bool = True,
) -> jax.Array:
    """Full-sequence self attention (train / prefill)."""
    q, k, v = attn_qkv(cfg, p, x, positions)
    window = cfg.window_size if cfg.attention == "swa" else 0
    p_dtype = (jnp.dtype(cfg.attn_p_dtype)
               if cfg.attn_p_dtype != "float32" else None)

    def att(qq, pos_q):
        return attend(
            qq, k, v, pos_q, positions, causal=causal, window=window,
            chunk=cfg.attn_chunk, p_dtype=p_dtype,
        )

    qc = cfg.attn_q_chunk
    S = q.shape[1]
    if qc and S > qc and S % qc == 0:
        # block queries too: bounds the live (bq, Sk) score working set so
        # long-sequence training fits HBM (see EXPERIMENTS.md §Perf)
        nq = S // qc
        qs = q.reshape(q.shape[0], nq, qc, *q.shape[2:]).transpose(1, 0, 2, 3, 4)
        ps = positions.reshape(positions.shape[0], nq, qc).transpose(1, 0, 2)
        o = jax.lax.map(lambda ab: att(ab[0], ab[1]), (qs, ps))
        o = o.transpose(1, 0, 2, 3, 4).reshape(*q.shape)
    else:
        o = att(q, positions)
    o = o.reshape(*x.shape[:2], cfg.q_dim)
    return shard(nn.dense(o, p["wo"]), "batch", "seq", "embed")


def cache_slot(cfg: ModelConfig, pos: jax.Array, Smax: int) -> jax.Array:
    """Write slot for the current position ((B,) int32)."""
    if cfg.attention == "swa":
        return pos % Smax  # ring buffer
    return jnp.minimum(pos, Smax - 1)


def update_kv_pos(kv_pos: jax.Array, pos: jax.Array, slot: jax.Array) -> jax.Array:
    """Record the absolute position written into each cache slot (shared
    across layers, so this is done once per decode step)."""
    return jax.vmap(
        lambda buf, val, i: jax.lax.dynamic_update_slice(buf, val, (i,))
    )(kv_pos, pos[:, None], slot)


def cached_attention_step(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,  # (B, 1, d)
    pos: jax.Array,  # (B,) current absolute position
    slot: jax.Array,  # (B,) precomputed write slot
    kv_pos: jax.Array,  # (B, Smax) already updated for this step
    k_cache: jax.Array,  # (B, Smax, Hkv, D)
    v_cache: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step against a (possibly ring-buffer) KV cache."""
    B = x.shape[0]
    q, k_new, v_new = attn_qkv(cfg, p, x, pos[:, None])

    def write(buf, val, i):
        return jax.lax.dynamic_update_slice(buf, val, (i, 0, 0))

    k_cache = jax.vmap(write)(k_cache, k_new, slot)
    v_cache = jax.vmap(write)(v_cache, v_new, slot)

    window = cfg.window_size if cfg.attention == "swa" else 0
    o = attend(
        q,
        k_cache,
        v_cache,
        pos[:, None],
        kv_pos,
        causal=True,
        window=window,
        chunk=cfg.attn_chunk,
    )
    o = o.reshape(B, 1, cfg.q_dim)
    out = nn.dense(o, p["wo"])
    return out, k_cache, v_cache


def cross_attention(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,  # (B, S, d)
    mem_k: jax.Array,  # (B, M, Hkv, D) precomputed
    mem_v: jax.Array,
    mem_pos: jax.Array,  # (B, M)
) -> jax.Array:
    B, S, _ = x.shape
    D = cfg.resolved_head_dim
    q = nn.dense(x, p["wq"], p.get("bq")).reshape(B, S, cfg.n_heads, D)
    q_pos = jnp.zeros((B, S), jnp.int32)  # non-causal: positions unused
    o = attend(
        q, mem_k, mem_v, q_pos, mem_pos, causal=False, window=0, chunk=cfg.attn_chunk
    )
    o = o.reshape(B, S, cfg.q_dim)
    return nn.dense(o, p["wo"])


def project_memory(cfg: ModelConfig, p: Params, mem: jax.Array):
    """K/V projection of encoder memory for cross attention."""
    B, M, _ = mem.shape
    D = cfg.resolved_head_dim
    k = nn.dense(mem, p["wk"], p.get("bk")).reshape(B, M, cfg.n_kv_heads, D)
    v = nn.dense(mem, p["wv"], p.get("bv")).reshape(B, M, cfg.n_kv_heads, D)
    return k, v


def init_attn_cache(cfg: ModelConfig, n_layers: int, batch: int, max_len: int):
    """Stacked (L, B, Smax, Hkv, D) KV cache; kv_pos -1 = unwritten."""
    Smax = min(max_len, cfg.window_size) if cfg.attention == "swa" else max_len
    D = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    return {
        "k": jnp.zeros((n_layers, batch, Smax, cfg.n_kv_heads, D), dt),
        "v": jnp.zeros((n_layers, batch, Smax, cfg.n_kv_heads, D), dt),
        "kv_pos": jnp.full((batch, Smax), -1, jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key, path: str, cfg: ModelConfig, n_stack: Optional[int] = None,
             d_ff: Optional[int] = None) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    d, f = cfg.d_model, d_ff or cfg.d_ff

    def mk(name, i, o):
        if n_stack is None:
            return nn.dense_init(key, f"{path}/{name}", i, o, dt)
        return nn.stacked_dense_init(key, f"{path}/{name}", n_stack, i, o, dt)

    p = {"w_in": mk("w_in", d, f), "w_out": mk("w_out", f, d)}
    if nn.is_gated(cfg.mlp_variant):
        p["w_gate"] = mk("w_gate", d, f)
    return p


def apply_mlp(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    h = nn.dense(x, p["w_in"])
    gate = nn.dense(x, p["w_gate"]) if "w_gate" in p else None
    h = shard(nn.mlp_act(h, cfg.mlp_variant, gate), "batch", "seq", "ffn")
    return shard(nn.dense(h, p["w_out"]), "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Embedding / loss
# ---------------------------------------------------------------------------


def init_embed(key, cfg: ModelConfig) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    p = {"tok_embed": nn.embed_init(key, "tok_embed", cfg.vocab_size, cfg.d_model, dt)}
    if not cfg.tie_embeddings:
        p["out_head"] = nn.dense_init(
            key, "out_head", cfg.d_model, cfg.vocab_size, dt
        )
    return p


def embed_tokens(cfg: ModelConfig, p: Params, tokens: jax.Array) -> jax.Array:
    x = jnp.take(p["tok_embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    if cfg.tie_embeddings:
        x = x * (cfg.d_model**0.5)  # gemma-style scaling with tied embeddings
    return shard(x, "batch", "seq", "embed")


def logits_fn(cfg: ModelConfig, p: Params, h: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        w = p["tok_embed"].astype(h.dtype)
        logits = jnp.einsum("...d,vd->...v", h, w)
    else:
        logits = nn.dense(h, p["out_head"])
    logits = logits.astype(jnp.float32)
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return shard(logits, "batch", "seq", "vocab")


def token_xent(logits: jax.Array, targets: jax.Array, mask: Optional[jax.Array] = None):
    """Mean masked cross entropy; logits f32 (B,S,V), targets (B,S)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
