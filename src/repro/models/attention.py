"""Chunked online-softmax attention (XLA path).

This is the memory-safe attention used for lowering/compiling everywhere:
it never materializes the (Sq, Sk) score matrix, instead scanning KV chunks
with flash-style running (max, sum, acc) statistics in f32.  The Pallas
flash-attention kernel (repro.kernels.flash_attention) is the TPU-optimized
version of exactly this computation and is validated against the same oracle.

Positions are explicit: ``kv_pos`` carries -1 for invalid (unwritten cache)
slots, which uniformly handles causal masks, sliding windows, ring-buffer
caches and padded chunks.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _pad_to_multiple(x: jax.Array, mult: int, axis: int, pad_value=0):
    n = x.shape[axis]
    rem = (-n) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads, constant_values=pad_value)


def attend(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Sk, Hkv, D)
    v: jax.Array,  # (B, Sk, Hkv, D)
    q_pos: jax.Array,  # (B, Sq) int32
    kv_pos: jax.Array,  # (B, Sk) int32; -1 marks invalid slots
    *,
    causal: bool = True,
    window: int = 0,  # >0 -> sliding window of this width
    chunk: int = 1024,
    scale: Optional[float] = None,
    p_dtype: Optional[jnp.dtype] = None,  # prob dtype for the PV matmul
) -> jax.Array:
    """Grouped-query chunked attention; returns (B, Sq, Hq, D) in q.dtype."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    scale = D**-0.5 if scale is None else scale

    qg = q.reshape(B, Sq, Hkv, G, D)

    chunk = min(chunk, Sk)
    kp = _pad_to_multiple(k, chunk, axis=1)
    vp = _pad_to_multiple(v, chunk, axis=1)
    pp = _pad_to_multiple(kv_pos, chunk, axis=1, pad_value=-1)
    n_chunks = kp.shape[1] // chunk

    # (n_chunks, B, C, Hkv, D)
    kc = kp.reshape(B, n_chunks, chunk, Hkv, D).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(B, n_chunks, chunk, Hkv, D).transpose(1, 0, 2, 3, 4)
    pc = pp.reshape(B, n_chunks, chunk).transpose(1, 0, 2)

    m0 = jnp.full((B, Sq, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, Hkv, G, D), jnp.float32)

    def step(carry, xs):
        m, l, acc = carry
        kk, vv, pos = xs
        # scores: (B, Sq, Hkv, G, C) in f32
        s = jnp.einsum(
            "bqhgd,bchd->bqhgc", qg.astype(jnp.float32), kk.astype(jnp.float32)
        ) * scale
        valid = pos[:, None, :] >= 0  # (B, 1, C)
        mask = valid
        if causal:
            mask = mask & (pos[:, None, :] <= q_pos[:, :, None])
        if window > 0:
            mask = mask & ((q_pos[:, :, None] - pos[:, None, :]) < window)
        s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)

        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows: keep m finite for exp
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[:, :, None, None, :], p, 0.0)
        corr = jnp.exp(jnp.where(m <= NEG_INF / 2, NEG_INF, m) - m_safe)
        corr = jnp.where(m <= NEG_INF / 2, 0.0, corr)
        l_new = l * corr + jnp.sum(p, axis=-1)
        if p_dtype is not None:
            # perf: halve P-matrix traffic; accumulate in f32 regardless
            pv = jnp.einsum(
                "bqhgc,bchd->bqhgd", p.astype(p_dtype), vv.astype(p_dtype),
                preferred_element_type=jnp.float32,
            )
        else:
            pv = jnp.einsum("bqhgc,bchd->bqhgd", p, vv.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


def attend_full_ref(
    q, k, v, q_pos, kv_pos, *, causal=True, window=0, scale=None
) -> jax.Array:
    """O(Sq*Sk) reference used by tests (small shapes only)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = D**-0.5 if scale is None else scale
    qg = q.reshape(B, Sq, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k.astype(jnp.float32)) * scale
    mask = kv_pos[:, None, :] >= 0
    if causal:
        mask = mask & (kv_pos[:, None, :] <= q_pos[:, :, None])
    if window > 0:
        mask = mask & ((q_pos[:, :, None] - kv_pos[:, None, :]) < window)
    s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[:, :, None, None, :], p, 0.0)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)
