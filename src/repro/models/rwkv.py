"""RWKV-6 "Finch" [arXiv:2404.05892] — attention-free time-mix with
data-dependent decay.

Per head (head_size N): with receptance r_t, key k_t, value v_t, decay
w_t in (0,1)^N (data-dependent via a LoRA on the token-shifted input) and
bonus u:

    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

Token-shift mixing uses the RWKV6 data-dependent lerp (ddlerp): a shared
first-stage mix plus a 5-way LoRA producing per-projection mix coefficients
for (r, k, v, g, w).

Adaptations noted in DESIGN.md: RMSNorm instead of LayerNorm (gamma-only),
group-norm on the time-mix output approximated per-head by RMS.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models import blocks, nn

Params = Dict[str, Any]

N_MIX = 5  # r, k, v, g, w


def init_layer_stack(key, cfg: ModelConfig, n: int) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    r = cfg.rwkv.decay_lora
    H = d // cfg.rwkv.head_size
    N = cfg.rwkv.head_size

    def mk(name, i, o):
        return nn.stacked_dense_init(key, f"layers/{name}", n, i, o, dt)

    p = {
        "attn_norm": nn.ones((n, d), dt),
        "mlp_norm": nn.ones((n, d), dt),
        # time-mix projections
        "w_r": mk("w_r", d, d),
        "w_k": mk("w_k", d, d),
        "w_v": mk("w_v", d, d),
        "w_g": mk("w_g", d, d),
        "w_o": mk("w_o", d, d),
        # ddlerp token-shift mixing
        "mix_base": nn.zeros((n, N_MIX + 1, d), dt),
        "mix_lora_a": mk("mix_lora_a", d, N_MIX * 32),
        "mix_lora_b": (
            jax.random.normal(
                nn._path_key(key, "layers/mix_lora_b"), (n, N_MIX, 32, d), jnp.float32
            )
            * 0.01
        ).astype(dt),
        # data-dependent decay
        "decay_base": nn.zeros((n, d), dt),
        "decay_lora_a": mk("decay_lora_a", d, r),
        "decay_lora_b": mk("decay_lora_b", r, d),
        "bonus": nn.zeros((n, H, N), dt),
        "ln_x": nn.ones((n, d), dt),
        # channel-mix
        "ck_mix": nn.zeros((n, 2, d), dt),
        "ck_in": mk("ck_in", d, cfg.d_ff),
        "ck_out": mk("ck_out", cfg.d_ff, d),
        "ck_rec": mk("ck_rec", d, d),
    }
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    return {
        **blocks.init_embed(key, cfg),
        "final_norm": nn.ones((cfg.d_model,), dt),
        "layers": init_layer_stack(key, cfg, cfg.n_layers),
    }


# ---------------------------------------------------------------------------
# time mix
# ---------------------------------------------------------------------------


def _ddlerp(lp: Params, x: jax.Array, x_prev: jax.Array):
    """RWKV6 data-dependent token-shift mix -> (xr, xk, xv, xg, xw)."""
    xx = x_prev - x
    mu = lp["mix_base"].astype(x.dtype)  # (6, d)
    xxx = x + xx * mu[0]
    lora = jnp.tanh(nn.dense(xxx, lp["mix_lora_a"]))  # (B,T,5*32)
    B_, T_ = x.shape[:2]
    lora = lora.reshape(B_, T_, N_MIX, 32)
    mix = mu[1:] + jnp.einsum("btnr,nrd->btnd", lora, lp["mix_lora_b"].astype(x.dtype))
    outs = [x + xx * mix[:, :, i] for i in range(N_MIX)]
    return outs


def wkv_stepwise(r, k, v, w, u, state):
    """Per-timestep WKV scan (baseline XLA path).  r/k/v/w: (B,T,H,N) f32;
    u: (H,N); state: (B,H,N,N) f32.  Returns (y (B,T,H,N), state)."""

    def step(S, xs):
        rt, kt, vt, wt = xs  # (B,H,N) each
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,N,N)
        y = jnp.einsum("bhn,bhnm->bhm", rt, S + u[None, :, :, None] * kv)
        S = wt[..., None] * S + kv
        return S, y

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, xs)  # ys: (T,B,H,N)
    return ys.transpose(1, 0, 2, 3), state


def wkv_chunked(r, k, v, w, u, state, chunk: int = 64):
    """Chunked-parallel WKV (perf path; see EXPERIMENTS.md §Perf).

    Mathematically identical to ``wkv_stepwise``: within a chunk of C steps
    the intra-chunk interaction is one masked (C, C) matrix per head built
    from pairwise decay products exp(L_{t-1} - L_s) (computed in log space,
    always <= 1 so no overflow), and the cross-chunk carry is a single
    matmul-style state update.  Replaces T sequential tiny-op iterations by
    T/C iterations of large fused ops — an order-of-magnitude HBM-traffic
    reduction in the XLA-lowered while loop.
    """
    B, T, H, N = r.shape
    C = min(chunk, T)
    pad = (-T) % C
    if pad:
        zr = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zr(r), zr(k), zr(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    nC = (T + pad) // C

    def chunk_step(S, xs):
        rc, kc, vc, wc = xs  # (B,C,H,N)
        # floor must be a NORMAL f32 (subnormals flush to zero on XLA:CPU)
        lw = jnp.log(jnp.maximum(wc, 1e-30))  # (B,C,H,N), <= 0
        L = jnp.cumsum(lw, axis=1)  # inclusive
        L_excl = L - lw  # exclusive: L_{t-1}
        # inter: state contribution, decayed on the key channel
        r_dec = rc * jnp.exp(L_excl)
        y_inter = jnp.einsum("bthn,bhnm->bthm", r_dec, S)
        # intra: A[t,s] = sum_n r_t k_s exp(L_{t-1,n} - L_{s,n}) for s < t
        D = L_excl[:, :, None] - L[:, None, :]  # (B,t,s,H,N); <=0 for s<t
        D = jnp.minimum(D, 0.0)  # padded/invalid region clamped
        # NOTE: a bf16 cast of exp(D) was tried and REFUTED (+31% traffic:
        # the converts materialize extra tensors and block fusion — see
        # EXPERIMENTS.md §Perf); keep f32 end-to-end here.
        A = jnp.einsum("bthn,bshn,btshn->btsh", rc, kc, jnp.exp(D))
        mask = jnp.tril(jnp.ones((C, C), bool), k=-1)
        A = jnp.where(mask[None, :, :, None], A, 0.0)
        y_intra = jnp.einsum("btsh,bshn->bthn", A, vc)
        # current-step bonus term
        y_diag = jnp.einsum("bthn,hn,bthn->bth", rc, u, kc)[..., None] * vc
        # state update: S' = diag(exp(L_C)) S + sum_s (k_s exp(L_C - L_s)) v_s^T
        decay_all = jnp.exp(L[:, -1][:, None] - L)  # (B,C,H,N), <= 1
        k_dec = kc * decay_all
        S = jnp.exp(L[:, -1])[..., None] * S + jnp.einsum(
            "bshn,bshm->bhnm", k_dec, vc
        )
        return S, y_inter + y_intra + y_diag

    xs = tuple(a.reshape(B, nC, C, H, N).transpose(1, 0, 2, 3, 4)
               for a in (r, k, v, w))
    state, ys = jax.lax.scan(chunk_step, state, xs)  # (nC,B,C,H,N)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, nC * C, H, N)
    return y[:, :T], state


def time_mix_scan(cfg: ModelConfig, lp: Params, x: jax.Array, x_last: jax.Array,
                  state: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Sequence form.  x: (B,T,d); x_last: (B,d) shift state;
    state: (B,H,N,N) f32.  Returns (out, new_x_last, new_state)."""
    B, T, d = x.shape
    N = cfg.rwkv.head_size
    H = d // N
    x_prev = jnp.concatenate([x_last[:, None], x[:, :-1]], axis=1)
    xr, xk, xv, xg, xw = _ddlerp(lp, x, x_prev)

    r = nn.dense(xr, lp["w_r"]).reshape(B, T, H, N)
    k = nn.dense(xk, lp["w_k"]).reshape(B, T, H, N)
    v = nn.dense(xv, lp["w_v"]).reshape(B, T, H, N)
    g = jax.nn.silu(nn.dense(xg, lp["w_g"]))
    dw = jnp.tanh(nn.dense(xw, lp["decay_lora_a"]))
    dw = nn.dense(dw, lp["decay_lora_b"]) + lp["decay_base"].astype(x.dtype)
    w = jnp.exp(-jnp.exp(dw.astype(jnp.float32))).reshape(B, T, H, N)
    u = lp["bonus"].astype(jnp.float32)  # (H, N)

    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    if cfg.scan_chunked and T > 1:
        ys, state = wkv_chunked(rf, kf, vf, w, u, state, chunk=cfg.scan_chunk)
    else:
        ys, state = wkv_stepwise(rf, kf, vf, w, u, state)
    y = ys.reshape(B, T, d).astype(x.dtype)
    # per-head RMS (group-norm stand-in), then gate and output proj
    y = nn.rms_norm(y, lp["ln_x"], cfg.norm_eps)
    out = nn.dense(y * g, lp["w_o"])
    return shard(out, "batch", "seq", "embed"), x[:, -1], state


# ---------------------------------------------------------------------------
# channel mix
# ---------------------------------------------------------------------------


def channel_mix(cfg: ModelConfig, lp: Params, x: jax.Array, x_last: jax.Array):
    B, T, d = x.shape
    x_prev = jnp.concatenate([x_last[:, None], x[:, :-1]], axis=1)
    mu = lp["ck_mix"].astype(x.dtype)  # (2, d)
    xk = x + (x_prev - x) * mu[0]
    xr = x + (x_prev - x) * mu[1]
    kk = jax.nn.relu(nn.dense(xk, lp["ck_in"]))
    kk = shard(kk * kk, "batch", "seq", "ffn")
    vv = nn.dense(kk, lp["ck_out"])
    rr = jax.nn.sigmoid(nn.dense(xr, lp["ck_rec"]))
    return shard(rr * vv, "batch", "seq", "embed"), x[:, -1]


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------


def _layer(cfg, lp, x, shift_tm, shift_cm, state):
    h = nn.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    o, shift_tm, state = time_mix_scan(cfg, lp, h, shift_tm, state)
    x = x + o
    h = nn.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    o, shift_cm = channel_mix(cfg, lp, h, shift_cm)
    return x + o, shift_tm, shift_cm, state


def forward(cfg: ModelConfig, p: Params, batch: Dict[str, jax.Array],
            cache=None):
    """Full-sequence forward; returns (hidden, aux=0, new_cache)."""
    x = blocks.embed_tokens(cfg, p, batch["tokens"])
    B, T, d = x.shape
    N = cfg.rwkv.head_size
    H = d // N
    L = cfg.n_layers
    if cache is None:
        cache = init_cache(cfg, B, 0)

    def step(carry, xs):
        x = carry
        lp, st_tm, st_cm, st = xs
        # note: norm state handled inside _layer with pre-norm inputs
        h = nn.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        o, st_tm2, st2 = time_mix_scan(cfg, lp, h, st_tm, st)
        x = x + o
        h2 = nn.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
        o2, st_cm2 = channel_mix(cfg, lp, h2, st_cm)
        return x + o2, (st_tm2, st_cm2, st2)

    if cfg.remat == "block":
        step = jax.checkpoint(step, prevent_cse=False)

    x, (shift_tm, shift_cm, states) = jax.lax.scan(
        step, x, (p["layers"], cache["shift_tm"], cache["shift_cm"], cache["state"])
    )
    x = nn.rms_norm(x, p["final_norm"], cfg.norm_eps)
    new_cache = {"shift_tm": shift_tm, "shift_cm": shift_cm, "state": states}
    return x, jnp.zeros((), jnp.float32), new_cache


def loss_fn(cfg: ModelConfig, p: Params, batch: Dict[str, jax.Array]):
    h, aux, _ = forward(cfg, p, batch)
    logits = blocks.logits_fn(cfg, p, h)
    loss = blocks.token_xent(logits, batch["targets"], batch.get("mask"))
    return loss, {"xent": loss, "aux": aux}


def init_cache(cfg: ModelConfig, batch: int, max_len: int = 0) -> Params:
    """RWKV decode state is O(1) in sequence length (hence long_500k runs)."""
    d = cfg.d_model
    N = cfg.rwkv.head_size
    H = d // N
    L = cfg.n_layers
    return {
        "state": jnp.zeros((L, batch, H, N, N), jnp.float32),
        "shift_tm": jnp.zeros((L, batch, d), jnp.dtype(cfg.dtype)),
        "shift_cm": jnp.zeros((L, batch, d), jnp.dtype(cfg.dtype)),
    }


def prefill(cfg: ModelConfig, p: Params, batch: Dict[str, jax.Array],
            max_len=None):
    h, _, cache = forward(cfg, p, batch)
    logits = blocks.logits_fn(cfg, p, h[:, -1:])[:, 0]
    return logits, cache


def decode_step(cfg: ModelConfig, p: Params, batch: Dict[str, jax.Array],
                cache: Params):
    tokens = batch["token"]  # (B,1)
    h, _, cache = forward(cfg, p, {"tokens": tokens}, cache=cache)
    logits = blocks.logits_fn(cfg, p, h)[:, 0]
    return logits, cache
