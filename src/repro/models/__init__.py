from repro.models.model import Model, get_model, input_specs  # noqa: F401
