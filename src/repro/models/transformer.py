"""Decoder-only transformer covering the dense and MoE families
(tinyllama / codeqwen / danube / nemotron / grok / kimi and the gemma
backbone of paligemma).

Layers are scan-stacked: params carry a leading L dim and the forward pass is
a single ``lax.scan`` whose body is one block (optionally ``jax.checkpoint``'d
when cfg.remat == "block").  MoE configs may reserve the first
``first_dense_layers`` layers as plain dense blocks (kimi-k2 style) — those
get their own (smaller) scan.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models import blocks, moe as moe_mod, nn

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _n_moe_layers(cfg: ModelConfig) -> Tuple[int, int]:
    """(n_dense_layers, n_moe_layers) of the stack."""
    if cfg.moe is None:
        return cfg.n_layers, 0
    nd = min(cfg.moe.first_dense_layers, cfg.n_layers)
    return nd, cfg.n_layers - nd


def init_layer_stack(key, path: str, cfg: ModelConfig, n: int, use_moe: bool) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "attn_norm": nn.ones((n, cfg.d_model), dt),
        "mlp_norm": nn.ones((n, cfg.d_model), dt),
        **blocks.init_attn(key, f"{path}/attn", cfg, n_stack=n),
    }
    if use_moe:
        p.update(moe_mod.init_moe(key, f"{path}/moe", cfg, n_stack=n))
    else:
        p.update(blocks.init_mlp(key, f"{path}/mlp", cfg, n_stack=n))
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    nd, nm = _n_moe_layers(cfg)
    p: Params = {**blocks.init_embed(key, cfg), "final_norm": nn.ones((cfg.d_model,), dt)}
    if nd > 0:
        p["layers"] = init_layer_stack(key, "layers", cfg, nd, use_moe=False)
    if nm > 0:
        p["moe_layers"] = init_layer_stack(key, "moe_layers", cfg, nm, use_moe=True)
    if cfg.frontend is not None:
        p["proj_in"] = nn.dense_init(
            key, "proj_in", cfg.frontend.embed_dim, cfg.d_model, dt
        )
    return p


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _block(cfg: ModelConfig, lp: Params, x, positions, use_moe: bool, ep_mode):
    h = nn.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    x = x + blocks.self_attention(cfg, lp, h, positions)
    h = nn.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    if use_moe:
        y, aux = moe_mod.apply_moe(cfg, lp, h, ep_mode=ep_mode)
    else:
        y, aux = blocks.apply_mlp(cfg, lp, h), jnp.zeros((), jnp.float32)
    return x + y, aux


def _scan_blocks(cfg: ModelConfig, stack: Params, x, positions, use_moe: bool,
                 ep_mode: Optional[str]):
    body = partial(_block, cfg, use_moe=use_moe, ep_mode=ep_mode)

    def step(carry, lp):
        y, aux = body(lp, carry, positions=positions)
        return y, aux

    if cfg.remat == "block":
        step = jax.checkpoint(step, prevent_cse=False)
    elif cfg.remat == "dots":
        # save matmul outputs, recompute only cheap elementwise work
        step = jax.checkpoint(
            step, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        )
    x, auxes = jax.lax.scan(step, x, stack)
    return x, jnp.sum(auxes)


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------


def embed_inputs(cfg: ModelConfig, p: Params, batch: Dict[str, jax.Array]):
    """Token embeddings, with optional modality prefix (VLM carve-out)."""
    x = blocks.embed_tokens(cfg, p, batch["tokens"])
    B, S = batch["tokens"].shape
    if cfg.frontend is not None and "prefix_embed" in batch:
        pe = nn.dense(batch["prefix_embed"].astype(x.dtype), p["proj_in"])
        x = jnp.concatenate([pe, x], axis=1)
        S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    return x, positions


def forward(cfg: ModelConfig, p: Params, batch: Dict[str, jax.Array],
            ep_mode: Optional[str] = None) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  Returns (hidden (B,S,d), aux_loss)."""
    x, positions = embed_inputs(cfg, p, batch)
    aux = jnp.zeros((), jnp.float32)
    if "layers" in p:
        x, a = _scan_blocks(cfg, p["layers"], x, positions, use_moe=False,
                            ep_mode=ep_mode)
        aux = aux + a
    if "moe_layers" in p:
        x, a = _scan_blocks(cfg, p["moe_layers"], x, positions, use_moe=True,
                            ep_mode=ep_mode)
        aux = aux + a
    x = nn.rms_norm(x, p["final_norm"], cfg.norm_eps)
    return x, aux


def loss_fn(cfg: ModelConfig, p: Params, batch: Dict[str, jax.Array]):
    h, aux = forward(cfg, p, batch)
    n_prefix = h.shape[1] - batch["tokens"].shape[1]
    if n_prefix > 0:
        h = h[:, n_prefix:]  # loss only over text positions
    logits = blocks.logits_fn(cfg, p, h)
    loss = blocks.token_xent(logits, batch["targets"], batch.get("mask"))
    metrics = {"xent": loss, "aux": aux}
    return loss + aux, metrics


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    return blocks.init_attn_cache(cfg, cfg.n_layers, batch, max_len)


def prefill(cfg: ModelConfig, p: Params, batch: Dict[str, jax.Array],
            max_len: Optional[int] = None):
    """Run the prompt, return (last-position logits, populated cache)."""
    x, positions = embed_inputs(cfg, p, batch)
    B, S = x.shape[:2]
    max_len = max_len or S
    Smax = min(max_len, cfg.window_size) if cfg.attention == "swa" else max_len
    nd, nm = _n_moe_layers(cfg)

    kv_list = []

    def make_step(use_moe):
        def step(carry, lp):
            xx = carry
            h = nn.rms_norm(xx, lp["attn_norm"], cfg.norm_eps)
            q, k, v = blocks.attn_qkv(cfg, lp, h, positions)
            window = cfg.window_size if cfg.attention == "swa" else 0
            from repro.models.attention import attend

            o = attend(q, k, v, positions, positions, causal=True,
                       window=window, chunk=cfg.attn_chunk)
            o = o.reshape(B, S, cfg.q_dim)
            xx = xx + nn.dense(o, lp["wo"])
            h = nn.rms_norm(xx, lp["mlp_norm"], cfg.norm_eps)
            if use_moe:
                y, _ = moe_mod.apply_moe(cfg, lp, h,
                                         no_drop=cfg.moe_exact_serving)
            else:
                y = blocks.apply_mlp(cfg, lp, h)
            return xx + y, (k, v)

        return step

    x_out = x
    for name, use_moe in (("layers", False), ("moe_layers", True)):
        if name in p:
            x_out, kv = jax.lax.scan(make_step(use_moe), x_out, p[name])
            kv_list.append(kv)

    k_all = jnp.concatenate([kv[0] for kv in kv_list], axis=0)  # (L,B,S,H,D)
    v_all = jnp.concatenate([kv[1] for kv in kv_list], axis=0)

    # place into fixed cache (keep the last Smax positions for SWA)
    take = min(S, Smax)
    k_keep = k_all[:, :, S - take:]
    v_keep = v_all[:, :, S - take:]
    if cfg.attention == "swa":
        # ring layout: position pos lives in slot pos % Smax
        pos_keep = jnp.arange(S - take, S, dtype=jnp.int32)
        slots = pos_keep % Smax
        L = k_all.shape[0]
        kc = jnp.zeros((L, B, Smax, cfg.n_kv_heads, cfg.resolved_head_dim), k_all.dtype)
        vc = jnp.zeros_like(kc)
        kc = kc.at[:, :, slots].set(k_keep)
        vc = vc.at[:, :, slots].set(v_keep)
        kv_pos = jnp.full((B, Smax), -1, jnp.int32).at[:, slots].set(pos_keep[None])
    else:
        pad = Smax - take
        kc = jnp.pad(k_keep, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v_keep, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.concatenate(
            [
                jnp.broadcast_to(jnp.arange(take, dtype=jnp.int32), (B, take)),
                jnp.full((B, pad), -1, jnp.int32),
            ],
            axis=1,
        )

    x_out = nn.rms_norm(x_out, p["final_norm"], cfg.norm_eps)
    logits = blocks.logits_fn(cfg, p, x_out[:, -1:])[:, 0]
    return logits, {"k": kc, "v": vc, "kv_pos": kv_pos}


def decode_step(cfg: ModelConfig, p: Params, batch: Dict[str, jax.Array],
                cache: Params):
    """One token step.  batch: {"token": (B,1), "pos": (B,)}."""
    token, pos = batch["token"], batch["pos"]
    B = token.shape[0]
    x = blocks.embed_tokens(cfg, p, token)
    Smax = cache["k"].shape[2]
    slot = blocks.cache_slot(cfg, pos, Smax)
    kv_pos = blocks.update_kv_pos(cache["kv_pos"], pos, slot)

    nd, nm = _n_moe_layers(cfg)
    offsets = {"layers": 0, "moe_layers": nd}

    def make_step(use_moe):
        def step(carry, xs):
            xx = carry
            lp, kc, vc = xs
            h = nn.rms_norm(xx, lp["attn_norm"], cfg.norm_eps)
            o, kc, vc = blocks.cached_attention_step(
                cfg, lp, h, pos, slot, kv_pos, kc, vc
            )
            xx = xx + o
            h = nn.rms_norm(xx, lp["mlp_norm"], cfg.norm_eps)
            if use_moe:
                y, _ = moe_mod.apply_moe(cfg, lp, h, ep_mode="onehot",
                                         no_drop=cfg.moe_exact_serving)
            else:
                y = blocks.apply_mlp(cfg, lp, h)
            return xx + y, (kc, vc)

        return step

    x_out = x
    new_k, new_v = [], []
    for name, use_moe in (("layers", False), ("moe_layers", True)):
        if name in p:
            n = p[name]["attn_norm"].shape[0]
            off = offsets[name]
            kc = jax.lax.dynamic_slice_in_dim(cache["k"], off, n, axis=0)
            vc = jax.lax.dynamic_slice_in_dim(cache["v"], off, n, axis=0)
            x_out, (k2, v2) = jax.lax.scan(
                make_step(use_moe), x_out, (p[name], kc, vc)
            )
            new_k.append(k2)
            new_v.append(v2)

    x_out = nn.rms_norm(x_out, p["final_norm"], cfg.norm_eps)
    logits = blocks.logits_fn(cfg, p, x_out)[:, 0]
    cache = {
        "k": jnp.concatenate(new_k, axis=0),
        "v": jnp.concatenate(new_v, axis=0),
        "kv_pos": kv_pos,
    }
    return logits, cache
