"""The paper's three deployment modalities (Sec. 4, Fig. 3): module -> site
placement maps.  The same module implementations run anywhere (Sec. 4.4's
"same modules and implementations reused when switching deployments")."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

MODULES = (
    "data_injection",
    "batch_inference",
    "speed_inference",
    "hybrid_inference",
    "model_sync",
    "data_sync",
    "speed_training",
    "archiving",
)

# Modules whose placement is meaningful *per stream*: the inference chain a
# fleet stream rides every window plus its model-sync install.  The elastic
# placement controller migrates exactly these; data_injection stays at the
# sensor and training/archiving stay fleet-global.
STREAM_MODULES = (
    "batch_inference",
    "speed_inference",
    "hybrid_inference",
    "model_sync",
)


@dataclass(frozen=True)
class Deployment:
    """Module -> site placement, plus an optional per-stream overlay.

    ``stream_placement`` maps a stream id to a site name; for the modules in
    :data:`STREAM_MODULES` it overrides the fleet-wide placement for that
    stream.  The dataclass stays frozen (the *identity* of a deployment never
    changes) but the overlay dict is mutable: ``pin_stream`` /
    ``unpin_stream`` are how static per-stream pins are expressed, and the
    elastic executor reads it as the *initial* placement — runtime migrations
    are tracked executor-side so one Deployment object can be reused across
    runs."""

    name: str
    placement: Dict[str, str]  # module -> site name
    stream_placement: Dict[str, str] = field(default_factory=dict)

    def site_of(self, module: str, stream: Optional[str] = None) -> str:
        if (stream is not None and module in STREAM_MODULES
                and stream in self.stream_placement):
            return self.stream_placement[stream]
        return self.placement[module]

    def pin_stream(self, stream: str, site: str) -> None:
        self.stream_placement[stream] = site

    def unpin_stream(self, stream: str) -> None:
        self.stream_placement.pop(stream, None)


def edge_centric() -> Deployment:
    """Everything on the edge (whole-cloud-unavailable scenario, Fig. 3a).
    Speed training on the Pi exceeds its capacity -> CapacityError, which is
    the paper's measured OOM result."""
    return Deployment(
        "edge-centric", {m: "edge" for m in MODULES}
    )


def cloud_centric() -> Deployment:
    """Edge only senses + forwards; all processing in the cloud (Fig. 3b)."""
    p = {m: "cloud" for m in MODULES}
    p["data_injection"] = "edge"  # sensing stays physically at the source
    return Deployment("cloud-centric", p)


def edge_cloud_integrated() -> Deployment:
    """Inference + sync on edge; speed training + archiving on cloud
    (Fig. 3c) — the paper's recommended deployment."""
    return Deployment(
        "edge-cloud-integrated",
        {
            "data_injection": "edge",
            "batch_inference": "edge",
            "speed_inference": "edge",
            "hybrid_inference": "edge",
            "model_sync": "edge",
            "data_sync": "edge",
            "speed_training": "cloud",
            "archiving": "cloud",
        },
    )


ALL_DEPLOYMENTS = {
    "edge-centric": edge_centric,
    "cloud-centric": cloud_centric,
    "edge-cloud-integrated": edge_cloud_integrated,
}
