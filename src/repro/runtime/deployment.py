"""The paper's three deployment modalities (Sec. 4, Fig. 3): module -> site
placement maps.  The same module implementations run anywhere (Sec. 4.4's
"same modules and implementations reused when switching deployments")."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

MODULES = (
    "data_injection",
    "batch_inference",
    "speed_inference",
    "hybrid_inference",
    "model_sync",
    "data_sync",
    "speed_training",
    "archiving",
)


@dataclass(frozen=True)
class Deployment:
    name: str
    placement: Dict[str, str]  # module -> site name

    def site_of(self, module: str) -> str:
        return self.placement[module]


def edge_centric() -> Deployment:
    """Everything on the edge (whole-cloud-unavailable scenario, Fig. 3a).
    Speed training on the Pi exceeds its capacity -> CapacityError, which is
    the paper's measured OOM result."""
    return Deployment(
        "edge-centric", {m: "edge" for m in MODULES}
    )


def cloud_centric() -> Deployment:
    """Edge only senses + forwards; all processing in the cloud (Fig. 3b)."""
    p = {m: "cloud" for m in MODULES}
    p["data_injection"] = "edge"  # sensing stays physically at the source
    return Deployment("cloud-centric", p)


def edge_cloud_integrated() -> Deployment:
    """Inference + sync on edge; speed training + archiving on cloud
    (Fig. 3c) — the paper's recommended deployment."""
    return Deployment(
        "edge-cloud-integrated",
        {
            "data_injection": "edge",
            "batch_inference": "edge",
            "speed_inference": "edge",
            "hybrid_inference": "edge",
            "model_sync": "edge",
            "data_sync": "edge",
            "speed_training": "cloud",
            "archiving": "cloud",
        },
    )


ALL_DEPLOYMENTS = {
    "edge-centric": edge_centric,
    "cloud-centric": cloud_centric,
    "edge-cloud-integrated": edge_cloud_integrated,
}
