"""The fault plane: seeded, deterministic fault injection for the bus runtime.

A :class:`FaultPlane` interposes on the deterministic runtime at three
seams, so every robustness claim can be *exercised* instead of assumed:

* **message faults** (``TopicBus.publish`` per-subscriber delivery):
  drop, delay, duplicate, reorder (seeded delivery jitter) and payload
  corruption — including bit-flipped int8 ``QTensor`` model publishes —
  selected by fnmatch topic patterns over an active time window.
* **site faults** (``EventKernel`` scheduling + delivery): a site is down
  over ``[t_down, t_up)`` — publishes from it are lost, deliveries to it
  are lost, and in-flight stage work that would finish while it is down is
  lost (the executors check :meth:`site_down` at stage completion).  At
  ``t_up`` the plane fires registered restart hooks so executors can model
  a cold restart (reset worker pools, drop cached serving state).
* **WAN partition/heal** between two sites: deliveries crossing the cut are
  either queued until ``t_heal`` (delayed model sync) or dead-lettered.
* **sensor faults** (``streams.injection.BusInjector``): whole-window
  dropout, duplicate windows, out-of-order (jittered) windows, per-record
  dropout, and Byzantine values (plausible-but-wrong target readings —
  the case ``runtime.health.ByzantineGuard`` exists to catch), applied
  before the window ever reaches the bus.

Determinism: all probabilistic draws come from RNGs derived from
``(seed, category, spec index[, stream, window])``, so the same seed and
scenario reproduce the identical fault schedule — byte-identical bus logs,
ledgers and forecasts — while different seeds produce different schedules.
``reset()`` rewinds the sequential per-spec RNGs so one plane can drive
repeated runs reproducibly.

Every fault action is recorded in ``events`` (time, kind, detail) — the
fault schedule — and tallied in ``stats``.
"""
from __future__ import annotations

import zlib
from collections import Counter
from dataclasses import dataclass, field
from fnmatch import fnmatchcase
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

INF = float("inf")

MESSAGE_FAULT_KINDS = ("drop", "delay", "duplicate", "reorder", "corrupt",
                       "forge")


@dataclass(frozen=True)
class MessageFault:
    """One message-level fault rule: applies ``kind`` with probability
    ``p`` to every delivery whose topic matches ``topic`` (fnmatch pattern,
    e.g. ``"model/latest/*"``) published in ``[start, end)``."""

    topic: str
    kind: str  # drop | delay | duplicate | reorder | corrupt | forge
    p: float = 1.0
    delay_s: float = 0.0  # delay: added latency; duplicate: copy offset
    jitter_s: float = 0.0  # reorder: uniform extra delay in [0, jitter_s)
    start: float = 0.0
    end: float = INF

    def __post_init__(self):
        if self.kind not in MESSAGE_FAULT_KINDS:
            raise ValueError(f"unknown message fault kind {self.kind!r}; "
                             f"pick from {MESSAGE_FAULT_KINDS}")

    def active(self, topic: str, t: float) -> bool:
        return self.start <= t < self.end and fnmatchcase(topic, self.topic)


@dataclass(frozen=True)
class SiteFault:
    """Site ``site`` crashes at ``t_down`` (losing in-flight work and every
    delivery addressed to it) and restarts cold at ``t_up`` (never, when
    infinite)."""

    site: str
    t_down: float
    t_up: float = INF

    def down(self, t: float) -> bool:
        return self.t_down <= t < self.t_up


@dataclass(frozen=True)
class PartitionFault:
    """The link between sites ``a`` and ``b`` is cut over
    ``[t_start, t_heal)``.  ``mode="queue"`` holds crossing deliveries and
    releases them at heal time (the delayed-model-sync scenario);
    ``mode="drop"`` dead-letters them."""

    a: str
    b: str
    t_start: float
    t_heal: float = INF
    mode: str = "queue"  # "queue" | "drop"

    def cuts(self, x: str, y: str, t: float) -> bool:
        return ({x, y} == {self.a, self.b}
                and self.t_start <= t < self.t_heal)


@dataclass(frozen=True)
class SensorFault:
    """Injection-layer chaos for streams matching ``stream`` (fnmatch):
    per-window drop/duplicate/out-of-order probabilities plus per-record
    dropout, active while the window's nominal injection time is in
    ``[start, end)``."""

    stream: str = "*"
    p_drop_window: float = 0.0
    p_dup_window: float = 0.0
    p_reorder: float = 0.0
    reorder_jitter_s: float = 1.0
    p_drop_record: float = 0.0
    # Byzantine values: with probability p_byzantine a window has
    # byzantine_frac of its target readings offset by byzantine_scale
    # robust-sigmas — plausible magnitudes (not NaNs or 1e9s) that sail
    # past range checks and straight into training unless a plausibility
    # gate (runtime.health.ByzantineGuard) screens them.
    p_byzantine: float = 0.0
    byzantine_frac: float = 0.25
    byzantine_scale: float = 8.0
    start: float = 0.0
    end: float = INF


def tree_checksum(tree: Any) -> int:
    """CRC32 over every leaf's bytes of a params pytree (QTensor leaves
    flatten to their int8 ``q`` + f32 ``scale`` children, so a single
    bit-flip anywhere in an int8 publish changes the checksum).  Used by
    the checksummed model-sync protocol: the training site stamps the
    publish, ``ModelSync`` verifies on deliver."""
    import jax

    c = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        a = np.ascontiguousarray(np.asarray(leaf))
        # shape and dtype are part of the digest: two leaves with the same
        # bytes but different shapes/dtypes (a transposed (m,n)/(n,m) pair,
        # an int8/uint8 reinterpretation) must not collide
        c = zlib.crc32(repr((a.shape, a.dtype.str)).encode(), c)
        c = zlib.crc32(a.tobytes(), c)
    return c


def corrupt_tree(tree: Any, rng: np.random.Generator) -> Any:
    """Flip one random bit in one random array leaf of a pytree copy (the
    original is untouched).  On an int8 ``QTensor`` tree this is exactly a
    bit-flipped quantized weight in transit."""
    import jax

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    idx = [i for i, l in enumerate(leaves)
           if hasattr(l, "dtype") and np.asarray(l).size > 0]
    if not idx:
        return tree
    i = idx[int(rng.integers(len(idx)))]
    arr = np.array(leaves[i], copy=True)
    flat = arr.reshape(-1).view(np.uint8)
    flat[int(rng.integers(flat.size))] ^= np.uint8(1 << int(rng.integers(8)))
    leaves = list(leaves)
    leaves[i] = arr
    return jax.tree_util.tree_unflatten(treedef, leaves)


def forge_tree(tree: Any, rng: np.random.Generator) -> Any:
    """A *plausible* tampered copy of a params pytree: one float leaf is
    nudged by small centered noise (~5% of its scale), one int leaf by ±1s
    — no NaNs, no flipped sign bits, nothing a range check would flag.
    Unlike :func:`corrupt_tree` (a damaged transfer), this models an
    adversary in the sync path shipping a wrong-but-well-formed model."""
    import jax

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    idx = [i for i, l in enumerate(leaves)
           if hasattr(l, "dtype") and np.asarray(l).size > 0]
    if not idx:
        return tree
    i = idx[int(rng.integers(len(idx)))]
    arr = np.array(leaves[i], copy=True)
    if np.issubdtype(arr.dtype, np.floating):
        scale = 0.05 * (float(np.abs(arr).mean()) + 1e-6)
        arr = arr + rng.normal(0.0, scale, size=arr.shape).astype(arr.dtype)
    else:
        lo = np.iinfo(arr.dtype)
        arr = np.clip(arr.astype(np.int64)
                      + rng.integers(-1, 2, size=arr.shape),
                      lo.min, lo.max).astype(arr.dtype)
    leaves = list(leaves)
    leaves[i] = arr
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _forge_payload(payload: Any, rng: np.random.Generator) -> Any:
    """Forge a model publish *copy*: tamper with the params plausibly and —
    the attack that motivates authenticated sync — recompute the crc32
    checksum over the forged tree, so checksum-only verification accepts
    it.  Any ``sig`` field is left stale (the forger has no run key), so an
    HMAC-verifying receiver still rejects.  Non-model payloads pass
    through untouched."""
    if isinstance(payload, dict) and payload.get("params") is not None:
        out = dict(payload)
        out["params"] = forge_tree(out["params"], rng)
        if "checksum" in out:
            out["checksum"] = tree_checksum(out["params"])
        return out
    return payload


def _corrupt_payload(payload: Any, rng: np.random.Generator) -> Any:
    """Corrupt a bus payload *copy*: the model tree when the payload carries
    one (``params``), else its data arrays (``x``); routing metadata
    (stream/window keys) is never touched — corruption models a damaged
    transfer, not a misrouted one."""
    if isinstance(payload, dict):
        out = dict(payload)
        if "params" in out and out["params"] is not None:
            out["params"] = corrupt_tree(out["params"], rng)
        elif "x" in out:
            out["x"] = corrupt_tree(np.asarray(out["x"]), rng)
        return out
    return payload


def _sid_key(sid: str) -> int:
    return zlib.crc32(sid.encode("utf-8"))


class FaultPlane:
    """Seeded fault injector for one (or more, via :meth:`reset`) runs.

    Attach to a run by passing it to ``FleetBusExecutor(fault_plane=...)``
    (which wires it into the ``TopicBus``, installs its restart events on
    the kernel, and consults it at stage completion), or manually by
    setting ``bus.fault_plane`` and calling :meth:`install`.
    """

    def __init__(
        self,
        seed: int = 0,
        *,
        message_faults: Sequence[MessageFault] = (),
        site_faults: Sequence[SiteFault] = (),
        partitions: Sequence[PartitionFault] = (),
        sensor_faults: Sequence[SensorFault] = (),
    ):
        self.seed = int(seed)
        self.message_faults = tuple(message_faults)
        self.site_faults = tuple(site_faults)
        self.partitions = tuple(partitions)
        self.sensor_faults = tuple(sensor_faults)
        self.reset()

    # -- lifecycle -----------------------------------------------------------

    def reset(self) -> None:
        """Rewind to a pristine pre-run state: fresh per-spec RNGs (so a
        second run under the same seed replays the identical fault
        schedule), empty stats/event log, no restart hooks."""
        self._rng_msg = [np.random.default_rng([self.seed, 3, i])
                         for i in range(len(self.message_faults))]
        self.stats: Counter = Counter()
        self.events: List[Tuple[float, str, str]] = []
        self._restart_hooks: List[Callable[[str], None]] = []

    def install(self, kernel) -> None:
        """Schedule the plane's own events on a run's kernel: crash markers
        and the restart firings that invoke registered hooks."""
        for f in self.site_faults:
            self.note("site_crash_scheduled", f.t_down, f.site)
            if f.t_up != INF:
                kernel.at(f.t_up,
                          lambda s=f.site, t=f.t_up: self._fire_restart(s, t))

    def on_restart(self, hook: Callable[[str], None]) -> None:
        """Register a cold-restart hook; called with the site name when a
        crashed site comes back up."""
        self._restart_hooks.append(hook)

    def _fire_restart(self, site: str, t: float) -> None:
        self.note("site_restart", t, site)
        for hook in self._restart_hooks:
            hook(site)

    # -- bookkeeping ---------------------------------------------------------

    def note(self, kind: str, t: float, detail: str = "") -> None:
        self.stats[kind] += 1
        self.events.append((float(t), kind, detail))

    def schedule_signature(self) -> List[Tuple[float, str, str]]:
        """The realized fault schedule — what the determinism contract
        compares across runs and seeds."""
        return list(self.events)

    # -- site faults ---------------------------------------------------------

    def site_down(self, site: str, t: float) -> bool:
        return any(f.site == site and f.down(t) for f in self.site_faults)

    def partitioned(self, a: str, b: str, t: float
                    ) -> Optional[PartitionFault]:
        for p in self.partitions:
            if p.cuts(a, b, t):
                return p
        return None

    # -- message faults (TopicBus.publish interposition) ---------------------

    def plan_deliveries(self, topic: str, payload: Any, src: str, dst: str,
                        t_pub: float, dt: float, bus
                        ) -> List[Tuple[float, Any]]:
        """Turn one (publish, subscriber) pair into its faulted delivery
        list: ``[(deliver_time, payload), ...]`` — empty when dropped/lost,
        two entries when duplicated, a corrupted payload copy when
        corrupted.  ``bus`` receives dead letters for hard partitions."""
        from repro.runtime.bus import DeadLetter

        if self.site_down(src, t_pub):
            self.note("lost_publish_site_down", t_pub, f"{src}:{topic}")
            return []
        t_del = t_pub + dt
        part = self.partitioned(src, dst, t_pub)
        if part is not None:
            if part.mode == "drop" or part.t_heal == INF:
                bus.dead_letters.append(DeadLetter(
                    topic=topic, src=src, dst=dst, t=t_pub,
                    reason="partitioned"))
                self.note("partition_drop", t_pub, f"{src}->{dst}:{topic}")
                return []
            # queue mode: the transfer re-sends after the heal
            t_del = part.t_heal + dt
            self.note("partition_queued", t_pub, f"{src}->{dst}:{topic}")

        out: List[Tuple[float, Any]] = [(t_del, payload)]
        for i, mf in enumerate(self.message_faults):
            if not mf.active(topic, t_pub):
                continue
            rng = self._rng_msg[i]
            nxt: List[Tuple[float, Any]] = []
            for t_i, pl in out:
                if rng.random() >= mf.p:
                    nxt.append((t_i, pl))
                    continue
                if mf.kind == "drop":
                    self.note("msg_drop", t_pub, f"{topic}->{dst}")
                elif mf.kind == "delay":
                    self.note("msg_delay", t_pub, f"{topic}->{dst}")
                    nxt.append((t_i + mf.delay_s, pl))
                elif mf.kind == "reorder":
                    j = float(rng.uniform(0.0, mf.jitter_s))
                    self.note("msg_reorder", t_pub, f"{topic}->{dst}")
                    nxt.append((t_i + j, pl))
                elif mf.kind == "duplicate":
                    self.note("msg_duplicate", t_pub, f"{topic}->{dst}")
                    off = mf.delay_s if mf.delay_s > 0 else 1e-3
                    nxt.append((t_i, pl))
                    nxt.append((t_i + off, pl))
                elif mf.kind == "corrupt":
                    self.note("msg_corrupt", t_pub, f"{topic}->{dst}")
                    nxt.append((t_i, _corrupt_payload(pl, rng)))
                elif mf.kind == "forge":
                    self.note("msg_forge", t_pub, f"{topic}->{dst}")
                    nxt.append((t_i, _forge_payload(pl, rng)))
            out = nxt
        return out

    # -- sensor faults (injection-layer interposition) -----------------------

    def sensor_windows(self, sid: str, w: int, t: float,
                       data: Dict[str, np.ndarray]
                       ) -> List[Tuple[float, Dict[str, np.ndarray]]]:
        """Turn one nominal window injection into its faulted delivery
        list of ``(inject_time, data)`` — possibly empty (window dropped),
        jittered (out-of-order), duplicated, or with rows removed (record
        dropout).  The RNG derives from (seed, spec, stream, window), so
        the schedule is independent of call order."""
        out: List[Tuple[float, Dict[str, np.ndarray]]] = [(t, data)]
        for i, sf in enumerate(self.sensor_faults):
            if not fnmatchcase(sid, sf.stream) or not (sf.start <= t < sf.end):
                continue
            rng = np.random.default_rng([self.seed, 7, i, _sid_key(sid), w])
            if sf.p_drop_record > 0.0:
                nxt = []
                for t_i, d in out:
                    keep = rng.random(len(d["x"])) >= sf.p_drop_record
                    if not keep.any():
                        keep[0] = True  # a sensor glitch, not a dead window
                    if not keep.all():
                        self.note("sensor_record_dropout", t,
                                  f"{sid}/w{w}:{int((~keep).sum())}")
                        d = {"x": d["x"][keep], "y": d["y"][keep]}
                    nxt.append((t_i, d))
                out = nxt
            if sf.p_byzantine > 0.0 and rng.random() < sf.p_byzantine:
                nxt = []
                for t_i, d in out:
                    y = np.asarray(d["y"])
                    n = y.shape[0]
                    k = max(1, int(round(sf.byzantine_frac * n)))
                    rows = rng.choice(n, size=min(k, n), replace=False)
                    med = float(np.median(y))
                    sigma = 1.4826 * float(np.median(np.abs(y - med))) + 1e-6
                    off = (sigma * sf.byzantine_scale
                           * rng.choice([-1.0, 1.0], size=(len(rows), 1))
                           * (1.0 + 0.25 * rng.random(size=(len(rows), 1))))
                    y2 = np.array(y, copy=True)
                    y2[rows] = y2[rows] + off.astype(y2.dtype)
                    self.note("sensor_byzantine", t,
                              f"{sid}/w{w}:{len(rows)}")
                    nxt.append((t_i, {"x": d["x"], "y": y2}))
                out = nxt
            if rng.random() < sf.p_drop_window:
                self.note("sensor_window_drop", t, f"{sid}/w{w}")
                return []
            if rng.random() < sf.p_reorder:
                out = [(t_i + float(rng.uniform(0.0, sf.reorder_jitter_s)), d)
                       for t_i, d in out]
                self.note("sensor_window_reorder", t, f"{sid}/w{w}")
            if rng.random() < sf.p_dup_window:
                out = out + [(t_i + 1e-3, d) for t_i, d in out]
                self.note("sensor_window_duplicate", t, f"{sid}/w{w}")
        return out
