"""Elastic fleet placement: queue-driven migration + predictive autoscaling.

Since the fleet executors made placement a *per-stream* decision (per-stream
``stream/window/<sid>`` topics under a ``Deployment``), every stream has
nevertheless lived wherever the deployment statically pinned it.  This module
closes the loop: a :class:`PlacementController` runs as a periodic bus
subscriber inside ``FleetBusExecutor`` and makes three decisions per control
interval, from signals the runtime already produces:

* **per-stream migration** — hot streams (drifting per the ``DriftGate``
  retrain log, or queued behind a saturated site per the ``LatencyLedger``
  backlog series) are pinned to a cloud site; cold/stationary streams are
  demoted back to edge.  The executor applies a migration by republishing
  the stream's topic subscriptions at the new site and handing its
  device-resident state across stream-count buckets
  (``FleetState.handoff``) — the aggregated one-dispatch-per-window
  train/predict path is untouched because aggregation happens *above*
  placement.
* **reactive scaling** — ``Site.workers`` grows/shrinks from an EWMA of
  per-worker queue backlog, with hysteresis: separate up/down thresholds,
  a persistence requirement, and a cooldown between changes, so an
  oscillating load cannot flap the worker count.
* **proactive scaling** — the recent per-site load series feeds a small
  speed-layer :class:`LoadForecaster` (the same compile-once
  ``CompiledForecaster`` hot path the fleet trains on, one feature wide);
  when the *forecast* backlog crosses the scale-up threshold the site
  scales ahead of the spike instead of after it.

The controller is a pure policy object: ``step(t, sites, streams)`` consumes
:class:`SiteSignal`/:class:`StreamSignal` snapshots and returns a
:class:`PlacementDecision`; the executor owns signal collection and decision
application.  Everything is deterministic — decisions depend only on the
signal history and a fixed PRNG key — so elastic runs replay byte-for-byte.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np


# ---------------------------------------------------------------------------
# Signals (executor -> controller) and decisions (controller -> executor)
# ---------------------------------------------------------------------------


@dataclass
class SiteSignal:
    """One site's load snapshot at a control tick."""

    name: str
    kind: str  # "edge" | "cloud"
    workers: int
    base_workers: int
    backlog_s: float  # seconds of admitted-but-unfinished work on the site


@dataclass
class StreamSignal:
    """One stream's placement-relevant snapshot at a control tick."""

    sid: str
    site: str  # site currently serving the stream's inference chain
    drift_hot: float  # fraction of recent windows the DriftGate retrained
    queue_s: float  # backlog at the stream's site (per-stream queue proxy)


@dataclass
class PlacementDecision:
    """What one control tick decided.  Empty dicts mean steady state."""

    t: float
    migrations: Dict[str, str] = field(default_factory=dict)  # sid -> site
    workers: Dict[str, int] = field(default_factory=dict)  # site -> count
    notes: List[str] = field(default_factory=list)

    def empty(self) -> bool:
        return not self.migrations and not self.workers


# ---------------------------------------------------------------------------
# Proactive load forecasting with the speed layer itself
# ---------------------------------------------------------------------------


class LoadForecaster:
    """Forecast the next per-site load sample with the speed layer itself: a
    small LSTM ridden through the compile-once ``CompiledForecaster`` hot
    path (one shape bucket — the history length is clamped — so the fit is
    one cached dispatch, exactly like a fleet stream's speed model).

    The LSTM fit is floored by a linear trend extrapolation: a ramp the tiny
    model has not yet learned must still be seen coming, which is the whole
    point of scaling *ahead*.  ``forecast`` is deterministic: cold-init fits
    from a fixed key, on data alone."""

    def __init__(self, *, lag: int = 4, hidden: int = 8, epochs: int = 6,
                 history: int = 16, horizon: int = 2, seed: int = 0):
        self.lag = int(lag)
        self.hidden = int(hidden)
        self.epochs = int(epochs)
        self.history = int(history)
        self.horizon = int(horizon)
        self.seed = int(seed)
        self.fits = 0
        self._fc = None  # built lazily so policy-only users never touch jax

    # -- internals ----------------------------------------------------------

    def _forecaster(self):
        if self._fc is None:
            from repro.configs import get_config
            from repro.configs.base import LSTMConfig
            from repro.core.hybrid import lstm_forecaster

            cfg = get_config("lstm-paper").replace(
                name="lstm-load",
                lstm=LSTMConfig(hidden=self.hidden, dense=4, n_features=1,
                                lag=self.lag, out_dim=1))
            self._fc = lstm_forecaster(cfg, epochs=self.epochs,
                                       batch_size=16)
        return self._fc

    @staticmethod
    def _trend(series: np.ndarray, horizon: int) -> float:
        """Least-squares linear extrapolation ``horizon`` steps ahead."""
        n = len(series)
        t = np.arange(n, dtype=np.float64)
        slope, intercept = np.polyfit(t, np.asarray(series, np.float64), 1)
        return float(intercept + slope * (n - 1 + horizon))

    # -- API ----------------------------------------------------------------

    def min_history(self) -> int:
        return self.lag + 2

    def forecast(self, series: Sequence[float]) -> float:
        """Predicted load ``horizon`` control ticks ahead (clamped >= 0)."""
        import jax

        from repro.core.windows import make_supervised

        s = np.asarray(series, np.float32)[-self.history:]
        if len(s) < self.min_history():
            return float(s[-1]) if len(s) else 0.0
        scale = float(np.max(np.abs(s)))
        trend = self._trend(s, self.horizon)
        if scale <= 1e-9:
            return max(0.0, trend)
        data = make_supervised(s[:, None] / scale, self.lag)
        fc = self._forecaster()
        params, _ = fc.train(data, None, jax.random.PRNGKey(self.seed))
        x = (s[-self.lag:, None] / scale)[None, :, :]
        pred = float(np.asarray(fc.predict(params, x)).reshape(-1)[0]) * scale
        self.fits += 1
        return max(0.0, max(pred, trend))


# ---------------------------------------------------------------------------
# The controller
# ---------------------------------------------------------------------------


@dataclass
class _SiteCtl:
    ewma: float = 0.0
    up_streak: int = 0
    down_streak: int = 0
    last_change: int = -(10 ** 9)
    history: List[float] = field(default_factory=list)


@dataclass
class _StreamCtl:
    hot_streak: int = 0
    cold_streak: int = 0
    last_move: int = -(10 ** 9)


class PlacementController:
    """Three decisions per control tick: migrate, scale reactively, scale
    proactively.  All thresholds are on *per-worker backlog seconds* (site
    backlog divided by worker count), so a site that scales up immediately
    looks less loaded to every later decision.

    Hysteresis constants (the no-flapping contract):

    * ``scale_up_s`` > ``scale_down_s`` — a dead band between the grow and
      shrink thresholds;
    * ``persistence`` — the threshold must hold for this many consecutive
      ticks before anything moves;
    * ``cooldown`` — minimum ticks between two worker changes on one site;
    * ``min_residency`` — minimum ticks a stream stays put after migrating.
    """

    def __init__(self, *, proactive: bool = True,
                 ewma_alpha: float = 0.5,
                 scale_up_s: float = 0.5, scale_down_s: float = 0.05,
                 persistence: int = 2, cooldown: int = 2,
                 max_workers: int = 8,
                 migrate_up_s: float = 0.5, migrate_down_s: float = 0.05,
                 hot_drift_frac: float = 0.6, cold_drift_frac: float = 0.2,
                 min_residency: int = 4,
                 max_migrations_per_tick: int = 2,
                 forecaster: Optional[LoadForecaster] = None,
                 seed: int = 0):
        if scale_up_s <= scale_down_s or migrate_up_s <= migrate_down_s:
            raise ValueError("hysteresis requires up threshold > down")
        self.proactive = proactive
        self.ewma_alpha = ewma_alpha
        self.scale_up_s = scale_up_s
        self.scale_down_s = scale_down_s
        self.persistence = max(1, int(persistence))
        self.cooldown = max(0, int(cooldown))
        self.max_workers = int(max_workers)
        self.migrate_up_s = migrate_up_s
        self.migrate_down_s = migrate_down_s
        self.hot_drift_frac = hot_drift_frac
        self.cold_drift_frac = cold_drift_frac
        self.min_residency = max(0, int(min_residency))
        self.max_migrations_per_tick = int(max_migrations_per_tick)
        self.forecaster = (LoadForecaster(seed=seed) if proactive
                           and forecaster is None else forecaster)
        self.tick = 0
        self.events: List[Dict[str, Any]] = []
        self._sites: Dict[str, _SiteCtl] = {}
        self._streams: Dict[str, _StreamCtl] = {}

    # -- helpers ------------------------------------------------------------

    @staticmethod
    def _target(sites: Sequence[SiteSignal], kind: str) -> Optional[str]:
        for s in sites:
            if s.kind == kind:
                return s.name
        return None

    def _note(self, t: float, kind: str, **detail) -> None:
        self.events.append({"t": float(t), "event": kind, **detail})

    # -- the policy ---------------------------------------------------------

    def step(self, t: float, sites: Sequence[SiteSignal],
             streams: Sequence[StreamSignal]) -> PlacementDecision:
        self.tick += 1
        dec = PlacementDecision(t=t)

        # --- per-site load bookkeeping + scaling -------------------------
        per_worker: Dict[str, float] = {}
        for s in sites:
            ctl = self._sites.setdefault(s.name, _SiteCtl())
            load = s.backlog_s / max(s.workers, 1)
            per_worker[s.name] = load
            a = self.ewma_alpha
            ctl.ewma = (1.0 - a) * ctl.ewma + a * load
            ctl.history.append(load)
            ctl.up_streak = ctl.up_streak + 1 if ctl.ewma > self.scale_up_s \
                else 0
            ctl.down_streak = (ctl.down_streak + 1
                               if ctl.ewma < self.scale_down_s else 0)

            cooled = self.tick - ctl.last_change >= self.cooldown
            new_workers = s.workers
            trigger = None
            if (ctl.up_streak >= self.persistence and cooled
                    and s.workers < self.max_workers):
                new_workers, trigger = s.workers + 1, "reactive-up"
            elif (self.proactive and self.forecaster is not None and cooled
                    and s.workers < self.max_workers
                    and len(ctl.history)
                    >= self.forecaster.min_history()):
                fcast = self.forecaster.forecast(ctl.history)
                if fcast > self.scale_up_s:
                    new_workers, trigger = s.workers + 1, "proactive-up"
                    self._note(t, "forecast", site=s.name, value=fcast)
            if (trigger is None and ctl.down_streak >= self.persistence
                    and cooled and s.workers > s.base_workers):
                new_workers, trigger = s.workers - 1, "reactive-down"
            if trigger is not None:
                dec.workers[s.name] = new_workers
                ctl.last_change = self.tick
                self._note(t, "scale", site=s.name, workers_from=s.workers,
                           workers_to=new_workers, trigger=trigger,
                           ewma=round(ctl.ewma, 6))

        # --- per-stream migration ----------------------------------------
        # deepest per-stream queue first: when the per-tick migration cap
        # bites, the streams actually responsible for the backlog move
        # first (stable sort keeps fleet order on ties — deterministic)
        cloud = self._target(sites, "cloud")
        edge = self._target(sites, "edge")
        for st in sorted(streams, key=lambda s: -s.queue_s):
            ctl = self._streams.setdefault(st.sid, _StreamCtl())
            site_ewma = self._sites.setdefault(st.site, _SiteCtl()).ewma
            hot = (st.drift_hot >= self.hot_drift_frac
                   or site_ewma > self.migrate_up_s)
            cold = (st.drift_hot <= self.cold_drift_frac
                    and site_ewma <= self.migrate_down_s)
            ctl.hot_streak = ctl.hot_streak + 1 if hot else 0
            ctl.cold_streak = ctl.cold_streak + 1 if cold else 0
            if len(dec.migrations) >= self.max_migrations_per_tick:
                continue
            resident = self.tick - ctl.last_move >= self.min_residency
            target = None
            if (hot and cloud is not None and st.site != cloud
                    and ctl.hot_streak >= self.persistence and resident):
                target, why = cloud, "hot"
            elif (cold and edge is not None and st.site != edge
                    and ctl.cold_streak >= self.persistence and resident
                    and self._sites.setdefault(edge, _SiteCtl()).ewma
                    <= self.migrate_down_s):
                target, why = edge, "cold"
            if target is not None:
                dec.migrations[st.sid] = target
                ctl.last_move = self.tick
                ctl.hot_streak = ctl.cold_streak = 0
                self._note(t, "migrate", sid=st.sid, site_from=st.site,
                           site_to=target, reason=why,
                           drift_hot=round(st.drift_hot, 4),
                           queue_s=round(st.queue_s, 6))
        return dec

    # -- reporting ----------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        mig = [e for e in self.events if e["event"] == "migrate"]
        sca = [e for e in self.events if e["event"] == "scale"]
        return {
            "ticks": self.tick,
            "migrations": len(mig),
            "scale_events": len(sca),
            "proactive_scale_events": len(
                [e for e in sca if e["trigger"] == "proactive-up"]),
            "forecaster_fits": (self.forecaster.fits
                                if self.forecaster is not None else 0),
            "events": self.events,
        }
