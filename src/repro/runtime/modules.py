r"""The six paper modules wired onto the topic bus, plus the cloud back-end
(speed training + archiving), reproducing Fig. 4's orchestration:

  stream -> data_injection --(stream topic)--> batch/speed inference (async)
                               |                    \-> hybrid inference
                               |--> data_sync -> archiving (cloud)
                               \--> speed_training -> model publish
  model publish --(model topic)--> model_sync (edge) -> next-window speed model

Latency is accounted per module as (computation, communication) exactly like
the paper's Table 3; speed training placed on a site with insufficient
memory raises ``CapacityError`` (the Pi OOM result).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.runtime.bus import (
    CapacityError,
    EventKernel,
    Message,
    Topology,
    TopicBus,
)
from repro.runtime.deployment import Deployment
from repro.runtime.latency import CostModel, LatencyLedger

T_STREAM = "stream/window"
T_BATCH = "results/batch"
T_SPEED = "results/speed"
T_HYBRID = "results/hybrid"
T_MODEL = "model/latest"
T_ARCHIVE = "archive/put"
T_REQUEST = "serve/request"
T_RESPONSE = "serve/response"
T_RESYNC = "model/rerequest"
T_CTRL = "ctrl/tick"  # the elastic placement controller's control-plane beat
T_HEALTH_HB = "health/hb"  # per-site heartbeats: health/hb/<site>
T_HEALTH_CHECK = "health/check"  # per-site monitor beats: health/check/<site>


def stream_topic(base: str, stream_id: str) -> str:
    """Per-stream multiplexing of a base topic: ``stream/window`` ->
    ``stream/window/t03``.  Fleet executors subscribe ``base + "/+"`` (the
    bus's single-level wildcard) to receive every stream of a fleet with
    one handler."""
    return f"{base}/{stream_id}"


@dataclass
class SimulationResult:
    ledger: LatencyLedger
    failures: List[str]
    n_windows: int
    message_log: List[Message]

    def table3(self) -> Dict[str, Dict[str, float]]:
        return self.ledger.table()


class EdgeCloudSimulation:
    """One deployment modality driven for ``n_windows`` stream windows."""

    def __init__(
        self,
        deployment: Deployment,
        topo: Topology,
        cost: CostModel,
        *,
        dynamic_weighting: bool = True,
        window_period_s: float = 30.0,
        strict_capacity: bool = False,
    ):
        self.dep = deployment
        self.topo = topo
        self.cost = cost
        self.dynamic = dynamic_weighting
        self.period = window_period_s
        self.strict = strict_capacity
        self.kernel = EventKernel()
        self.bus = TopicBus(self.kernel, topo)
        self.ledger = LatencyLedger()
        self.failures: List[str] = []
        self._pending_hybrid: Dict[int, Dict[str, Message]] = {}
        self._wire()

    # -- helpers -------------------------------------------------------------

    def _site(self, module: str):
        return self.topo.sites[self.dep.site_of(module)]

    def _compute(self, module: str, seconds: float) -> float:
        site = self._site(module)
        t = self.cost.on(site.compute_scale, seconds)
        # resource contention (paper Table 3: edge-centric inference is much
        # slower than integrated despite identical placement — the per-window
        # speed training job steals the Pi's cores)
        if (
            module != "speed_training"
            and site.kind == "edge"
            and self._site("speed_training").name == site.name
        ):
            # the attempt alone thrashes the Pi, whether or not it OOMs
            t *= 1.5
        return t

    # -- module handlers -----------------------------------------------------

    def _wire(self) -> None:
        dep = self.dep
        self.bus.subscribe(T_STREAM, dep.site_of("batch_inference"), self._on_batch)
        self.bus.subscribe(T_STREAM, dep.site_of("speed_inference"), self._on_speed)
        self.bus.subscribe(T_STREAM, dep.site_of("speed_training"), self._on_train)
        self.bus.subscribe(T_STREAM, dep.site_of("data_sync"), self._on_data_sync)
        self.bus.subscribe(T_BATCH, dep.site_of("hybrid_inference"), self._on_part)
        self.bus.subscribe(T_SPEED, dep.site_of("hybrid_inference"), self._on_part)
        self.bus.subscribe(T_HYBRID, dep.site_of("archiving"), self._on_archive)
        self.bus.subscribe(T_MODEL, dep.site_of("model_sync"), self._on_model_sync)

    def _on_batch(self, msg: Message) -> None:
        comm_in = msg.deliver_time - msg.publish_time + self.cost.ingest_s
        dur = self._compute("batch_inference", self.cost.batch_infer_s)
        w = msg.payload["window"]

        def done():
            self.ledger.add("batch_inference", comp_s=dur, comm_s=comm_in)
            self.bus.publish(T_BATCH, {"window": w, "kind": "batch"},
                             self.cost.result_nbytes,
                             self.dep.site_of("batch_inference"))

        self.kernel.after(dur, done)

    def _on_speed(self, msg: Message) -> None:
        comm_in = msg.deliver_time - msg.publish_time + self.cost.ingest_s
        dur = self._compute("speed_inference", self.cost.speed_infer_s)
        w = msg.payload["window"]

        def done():
            self.ledger.add("speed_inference", comp_s=dur, comm_s=comm_in)
            self.bus.publish(T_SPEED, {"window": w, "kind": "speed"},
                             self.cost.result_nbytes,
                             self.dep.site_of("speed_inference"))

        self.kernel.after(dur, done)

    def _on_part(self, msg: Message) -> None:
        w = msg.payload["window"]
        parts = self._pending_hybrid.setdefault(w, {})
        parts[msg.payload["kind"]] = msg
        if len(parts) < 2:
            return
        comm_in = max(m.deliver_time - m.publish_time for m in parts.values())
        secs = self.cost.hybrid_combine_s + (
            self.cost.weight_solve_s if self.dynamic else 0.0
        )
        dur = self._compute("hybrid_inference", secs)

        def done():
            self.ledger.add("hybrid_inference", comp_s=dur, comm_s=comm_in)
            self.bus.publish(T_HYBRID, {"window": w},
                             self.cost.result_nbytes,
                             self.dep.site_of("hybrid_inference"))

        self.kernel.after(dur, done)

    def _on_archive(self, msg: Message) -> None:
        comm_in = msg.deliver_time - msg.publish_time
        self.ledger.add("archiving", comp_s=0.0, comm_s=comm_in)

    def _on_data_sync(self, msg: Message) -> None:
        # raw-data archiving to object storage (S3 analog)
        link = self.topo.link(self.dep.site_of("data_sync"),
                              self.dep.site_of("archiving"))
        self.ledger.add("data_sync", comp_s=0.0,
                        comm_s=link.transfer_time(self.cost.window_nbytes))

    def _on_train(self, msg: Message) -> None:
        comm_in = msg.deliver_time - msg.publish_time
        site = self._site("speed_training")
        if self.cost.train_memory_bytes > site.memory_bytes:
            self.failures.append(
                f"speed_training OOM on {site.name}: needs "
                f"{self.cost.train_memory_bytes/1e9:.1f} GB > "
                f"{site.memory_bytes/1e9:.1f} GB"
            )
            if self.strict:
                raise CapacityError(self.failures[-1])
            return
        dur = self._compute("speed_training", self.cost.speed_train_s)
        w = msg.payload["window"]

        def done():
            self.ledger.add("speed_training", comp_s=dur, comm_s=comm_in)
            self.bus.publish(T_MODEL, {"window": w}, self.cost.model_nbytes,
                             self.dep.site_of("speed_training"))

        self.kernel.after(dur, done)

    def _on_model_sync(self, msg: Message) -> None:
        # pre-signed-URL download of the fresh speed model to the edge
        self.ledger.add("model_sync", comp_s=0.0,
                        comm_s=msg.deliver_time - msg.publish_time)

    # -- driver ----------------------------------------------------------------

    def run(self, n_windows: int) -> SimulationResult:
        inj_site = self.dep.site_of("data_injection")
        for w in range(n_windows):
            self.kernel.at(
                w * self.period,
                lambda w=w: self.bus.publish(
                    T_STREAM, {"window": w}, self.cost.window_nbytes, inj_site
                ),
            )
        self.kernel.run()
        return SimulationResult(
            ledger=self.ledger,
            failures=self.failures,
            n_windows=n_windows,
            message_log=self.bus.log,
        )
