"""Deterministic discrete-event runtime: sites, links, and an MQTT-style
topic bus.

This is the JAX-native stand-in for the paper's AWS wiring (IoT Core MQTT,
Greengrass, Lambda triggers): a heapq event kernel delivers published
payloads to subscribers after ``link.latency + bytes / link.bandwidth``
seconds; modules schedule compute work on their site with explicit durations.
Everything is deterministic so tests can assert exact orderings.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple


class CapacityError(RuntimeError):
    """A module exceeded its site's memory budget (the paper's edge-centric
    speed-training OOM, Sec. 6.2)."""


@dataclass
class Site:
    """A compute location.

    ``compute_scale`` rescales *measured-on-this-container* wall-times to the
    site's hardware class (e.g. Raspberry Pi 4 ~0.25x of a c5 vCPU);
    ``memory_bytes`` is the capacity model used for the OOM reproduction.
    ``workers`` is how many modules the site can execute concurrently
    (``BusExecutor`` site occupancy; the calibrated simulation ignores it).
    ``workers`` is mutable: the elastic placement controller grows and
    shrinks it at runtime, and executors resize their worker pools lazily.
    """

    name: str
    kind: str  # "edge" | "cloud"
    compute_scale: float = 1.0
    memory_bytes: float = 4e9
    workers: int = 1


@dataclass(frozen=True)
class Link:
    latency_s: float
    bandwidth_Bps: float

    def transfer_time(self, nbytes: float) -> float:
        return self.latency_s + nbytes / self.bandwidth_Bps


@dataclass
class Topology:
    sites: Dict[str, Site]
    links: Dict[Tuple[str, str], Link]
    loopback: Link = field(default_factory=lambda: Link(1e-4, 1e10))

    def link(self, src: str, dst: str) -> Link:
        if src == dst:
            return self.loopback
        if (src, dst) in self.links:
            return self.links[(src, dst)]
        if (dst, src) in self.links:
            return self.links[(dst, src)]
        raise KeyError(f"no link {src} <-> {dst}")


def paper_topology() -> Topology:
    """Raspberry Pi 4 edge + AWS cloud (c5.4xlarge EC2, Lambda, S3) with a
    WAN link calibrated to the paper's latency regime."""
    # Pi inference runs near-parity with the c5 for the tiny TFLite LSTM
    # (paper Table 3: edge comp 10.25 s vs cloud 8.82 s); the Pi penalty
    # shows up in *training* (OOM) and in contention (see modules.py)
    # any one of our JAX/TF jobs saturates the Pi's 4 small cores (workers=1)
    # while the 16-vCPU c5.4xlarge overlaps training with inference
    sites = {
        "edge": Site("edge", "edge", compute_scale=0.85, memory_bytes=4e9,
                     workers=1),
        "cloud": Site("cloud", "cloud", compute_scale=2.0, memory_bytes=32e9,
                      workers=4),
    }
    links = {
        ("edge", "cloud"): Link(latency_s=0.045, bandwidth_Bps=2.5e6),
    }
    return Topology(sites=sites, links=links)


@dataclass
class Message:
    topic: str
    payload: Any
    nbytes: float
    src: str
    publish_time: float
    deliver_time: float = 0.0


@dataclass
class DeadLetter:
    """A publish that could not be delivered: no link between the sites, or
    a hard (drop-mode) partition in between.  Recorded instead of raising,
    so a partitioned topology is a scenario, not a crash."""

    topic: str
    src: str
    dst: str
    t: float
    reason: str


class EventKernel:
    def __init__(self) -> None:
        self._q: List[Tuple[float, int, Callable[[], None]]] = []
        self._seq = itertools.count()
        self.now = 0.0

    def at(self, t: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._q, (t, next(self._seq), fn))

    def after(self, dt: float, fn: Callable[[], None]) -> None:
        self.at(self.now + dt, fn)

    def run(self, until: Optional[float] = None) -> float:
        while self._q:
            if until is not None and self._q[0][0] > until:
                # peek, don't pop: re-pushing with a fresh sequence number
                # would silently reorder same-timestamp events across a
                # pause/resume — the chaos suite relies on exact replay
                break
            t, _, fn = heapq.heappop(self._q)
            self.now = max(self.now, t)
            fn()
        return self.now


def topic_matches(pattern: str, topic: str) -> bool:
    """MQTT single-level wildcard matching: ``+`` matches exactly one
    ``/``-separated level, at any position.  Segment counts must agree —
    ``a/+`` matches ``a/b`` but never ``a`` or ``a/b/c``."""
    ps = pattern.split("/")
    ts = topic.split("/")
    return len(ps) == len(ts) and all(
        p == "+" or p == t for p, t in zip(ps, ts))


class TopicBus:
    """MQTT-like pub/sub across sites with link-cost delivery.

    Topics are ``/``-separated names.  A subscription may end in the MQTT
    single-level wildcard ``+``: ``"stream/window/+"`` receives every
    publish one level below ``stream/window`` — how a fleet executor
    subscribes one handler to all of its per-stream topics
    (``stream/window/t00``, ``stream/window/t01``, ...) under one
    ``Deployment``.

    A publish to a site with no link from the source is not an error: it is
    dropped and recorded in ``dead_letters`` (topic/src/dst/reason), so a
    partitioned topology degrades instead of crashing.

    An optional ``fault_plane`` (:class:`repro.runtime.faults.FaultPlane`)
    interposes on every per-subscriber delivery: it can drop, delay,
    duplicate, reorder or corrupt the delivery, queue it behind a WAN
    partition, or lose it to a crashed site.  With no plane attached the
    publish path is byte-identical to the pre-fault code."""

    def __init__(self, kernel: EventKernel, topo: Topology,
                 fault_plane: Optional[Any] = None):
        self.kernel = kernel
        self.topo = topo
        self.fault_plane = fault_plane
        self._subs: Dict[str, List[Tuple[str, Callable[[Message], None]]]] = {}
        # patterns with a non-leaf "+" can't be dict-looked-up; they are the
        # rare case, kept in a scan list (pattern, site, fn)
        self._wild: List[Tuple[str, str, Callable[[Message], None]]] = []
        self.log: List[Message] = []
        self.dead_letters: List[DeadLetter] = []

    @staticmethod
    def _is_scan_pattern(topic: str) -> bool:
        return "+" in topic.split("/")[:-1]

    def subscribe(self, topic: str, site: str, fn: Callable[[Message], None]):
        if self._is_scan_pattern(topic):
            self._wild.append((topic, site, fn))
        else:
            self._subs.setdefault(topic, []).append((site, fn))

    def unsubscribe(self, topic: str, site: str,
                    fn: Callable[[Message], None]) -> bool:
        """Remove one (site, fn) registration for ``topic``; returns whether
        anything was removed.  Migration republishes a stream's topics by
        unsubscribing the handler at the old site and re-subscribing it at
        the new one — in-flight deliveries already scheduled keep the
        handler they were matched to at publish time."""
        if self._is_scan_pattern(topic):
            for i, (pat, s, f) in enumerate(self._wild):
                if pat == topic and s == site and f == fn:
                    del self._wild[i]
                    return True
            return False
        subs = self._subs.get(topic, [])
        for i, (s, f) in enumerate(subs):
            if s == site and f == fn:
                del subs[i]
                return True
        return False

    def _matches(self, topic: str) -> List[Tuple[str, Callable[[Message], None]]]:
        subs = list(self._subs.get(topic, []))
        head, _, leaf = topic.rpartition("/")
        if leaf != "+":
            subs += self._subs.get((head + "/+") if head else "+", [])
        if self._wild:
            subs += [(s, f) for pat, s, f in self._wild
                     if topic_matches(pat, topic)]
        return subs

    def publish(self, topic: str, payload: Any, nbytes: float, src: str) -> None:
        msg_t = self.kernel.now
        fp = self.fault_plane
        for site, fn in self._matches(topic):
            try:
                link = self.topo.link(src, site)
            except KeyError:
                self.dead_letters.append(
                    DeadLetter(topic=topic, src=src, dst=site, t=msg_t,
                               reason="no-link"))
                continue
            dt = link.transfer_time(nbytes)
            if fp is None:
                msg = Message(topic=topic, payload=payload, nbytes=nbytes,
                              src=src, publish_time=msg_t,
                              deliver_time=msg_t + dt)
                self.log.append(msg)
                self.kernel.at(msg_t + dt, lambda fn=fn, msg=msg: fn(msg))
                continue
            for t_del, pl in fp.plan_deliveries(topic, payload, src, site,
                                                msg_t, dt, self):
                msg = Message(topic=topic, payload=pl, nbytes=nbytes, src=src,
                              publish_time=msg_t, deliver_time=t_del)
                self.log.append(msg)
                self.kernel.at(
                    t_del,
                    lambda fn=fn, msg=msg, site=site:
                        self._deliver(fn, msg, site))

    def _deliver(self, fn: Callable[[Message], None], msg: Message,
                 site: str) -> None:
        """Fault-aware delivery: a message addressed to a site that is down
        *at delivery time* is lost (the site may have crashed after the
        publish was already in flight)."""
        fp = self.fault_plane
        if fp is not None and fp.site_down(site, self.kernel.now):
            fp.note("lost_delivery_site_down", self.kernel.now,
                    f"{msg.topic}->{site}")
            return
        fn(msg)
