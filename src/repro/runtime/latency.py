"""Compute-cost calibration and per-window latency accounting.

``CostModel`` holds *measured* wall-times of the real JAX modules on this
container (LSTM batch/speed inference, speed training, weight solve) and
rescales them by each site's ``compute_scale``; big-arch costs can instead be
derived from the roofline terms of the compiled dry-run.  The accounting
separates computation vs communication per module, which is exactly the
structure of the paper's Table 3.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np


@dataclass
class CostModel:
    """Seconds, measured on the container at compute_scale=1.0."""

    batch_infer_s: float = 0.05
    speed_infer_s: float = 0.05
    hybrid_combine_s: float = 0.005
    weight_solve_s: float = 0.01  # dynamic only
    speed_train_s: float = 2.0
    ingest_s: float = 0.0  # Kafka data-injection throttle time charged as
    # communication on every stream consumer (paper: ~7 records/s)
    model_nbytes: float = 50_000.0  # checkpoint size (10,981 params ~ 44 KB)
    window_nbytes: float = 200 * 5 * 4  # records/window * features * f32
    result_nbytes: float = 200 * 4
    # memory footprint of a training job (for the capacity model)
    train_memory_bytes: float = 6e9  # TF/Spark stack on the Pi blows 4 GB
    infer_memory_bytes: float = 0.5e9
    # how long an over-capacity training attempt thrashes its site before
    # the OOM kill (swap-paging the overshoot on Pi-class storage).  Modeled,
    # not measured: this container cannot OOM a real Pi, and the *successful*
    # training wall is no proxy for it — the compiled hot path dropped that
    # wall to milliseconds while a thrashing attempt still takes seconds.
    oom_thrash_s: float = 4.0

    def on(self, site_scale: float, seconds: float) -> float:
        return seconds / max(site_scale, 1e-9)


@dataclass
class LatencyLedger:
    """Accumulates (computation, communication, queue) seconds per (module,
    window).  ``queue`` is the time a stage waited for a free worker on its
    site (only the measured ``BusExecutor`` path produces nonzero queueing;
    the calibrated simulation does not model site occupancy).

    ``depth`` is a per-*site* backlog time series — ``(t, backlog_s)``
    samples of how many seconds of already-admitted work sit in front of a
    fresh arrival.  Executors sample it both at stage entry *and* at publish
    (stage-exit) time: entry-only sampling aliased inter-window queue growth
    to zero, which starved the placement controller (and BENCH_serving) of
    the very signal scaling decisions are made from."""

    comp: Dict[str, list] = field(default_factory=dict)
    comm: Dict[str, list] = field(default_factory=dict)
    queue: Dict[str, list] = field(default_factory=dict)
    depth: Dict[str, list] = field(default_factory=dict)

    def add(self, module: str, comp_s: float = 0.0, comm_s: float = 0.0,
            queue_s: float = 0.0):
        self.comp.setdefault(module, []).append(comp_s)
        self.comm.setdefault(module, []).append(comm_s)
        self.queue.setdefault(module, []).append(queue_s)

    def sample_depth(self, site: str, t: float, backlog_s: float) -> None:
        """Record one (virtual-time, backlog-seconds) queue-depth sample for
        ``site``."""
        self.depth.setdefault(site, []).append((float(t), float(backlog_s)))

    def depth_series(self, site: str) -> list:
        return self.depth.get(site, [])

    def depth_ewma(self, site: str, alpha: float = 0.3) -> float:
        """EWMA of the site's backlog samples (most recent weighted by
        ``alpha``); 0.0 when no samples exist."""
        ewma = 0.0
        for _, b in self.depth.get(site, []):
            ewma = (1.0 - alpha) * ewma + alpha * b
        return ewma

    def table(self) -> Dict[str, Dict[str, float]]:
        out = {}
        mods = set(self.comp) | set(self.comm)
        for m in sorted(mods):
            c = float(np.mean(self.comp.get(m, [0.0])))
            x = float(np.mean(self.comm.get(m, [0.0])))
            q = float(np.mean(self.queue.get(m, [0.0])))
            out[m] = {"computation": c, "communication": x, "queue": q,
                      "total": c + x + q}
        return out
