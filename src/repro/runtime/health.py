"""The health plane: the *self-diagnosing* half of fault tolerance.

PR 7's chaos plane proved the runtime rides out partitions, crashes and
corrupted sync; this module makes it *name* them.  A :class:`HealthPlane`
runs inside the bus runtime (attached via
``FleetBusExecutor(health_plane=...)``) and turns the FaultPlane's injected
failures into detected, attributed, adaptively-handled failures — four
pieces:

* **goldpinger-style partition detection** — every site publishes periodic
  heartbeats on ``health/hb/<site>``; every site also runs a
  :class:`SiteMonitor` (a ``ctrl/tick``-style subscriber, the
  ``PlacementController`` pattern) that tracks inter-arrival times per peer
  with a phi-accrual-style suspicion score and emits
  ``partition_suspected`` / ``site_down`` / ``recovered`` verdicts.  With
  two sites a monitor cannot locally distinguish "the WAN is cut" from
  "the peer died" — so suspicion escalates (suspected, then down) and the
  verdict log records who observed whom, which is exactly what goldpinger's
  all-to-all probe matrix gives an operator.
* **authenticated model sync** — HMAC-SHA256 signatures
  (:func:`sign_tree`, keyed per run via :func:`derive_sync_key`) over the
  same shape/dtype-aware serialization as
  :func:`~repro.runtime.faults.tree_checksum`.  crc32 detects *corruption*;
  it cannot detect *tampering* — a forger recomputes the checksum
  (``MessageFault(kind="forge")`` does exactly that).  The HMAC can only be
  produced by a holder of the run key, so ``ModelSync`` rejects 100% of
  forged publishes and the executor's existing re-request path recovers.
* **Byzantine-value defense** — :class:`ByzantineGuard`, a per-stream
  rolling median/MAD plausibility gate in the injection path: sensor values
  that are *plausible but wrong* (``SensorFault.p_byzantine``) are flagged
  and imputed with the rolling median before the window ever reaches the
  bus.  Clean data passes through byte-identically (the gate returns the
  original arrays untouched when nothing is flagged).
* **adaptive fault thresholds** — :class:`FaultRateEstimator` keeps an
  exponentially-decayed fault count per link and per stream from every
  detection above; ``quarantine_after`` and the staleness-watchdog bound
  become functions of that pressure instead of fixed constructor knobs.
  Calm runs see exactly the base values (bit-identical behavior to static
  thresholds); rising fault rates tighten both so the runtime reacts
  faster precisely where faults cluster.

Everything here is deterministic — no RNG, virtual-time arithmetic only —
so health-plane runs replay byte-for-byte under one fault seed like every
other chaos property.
"""
from __future__ import annotations

import hashlib
import hmac
import math
from collections import Counter, deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

LN2 = math.log(2.0)


def derive_sync_key(seed: int) -> bytes:
    """The run's model-sync signing key.  Deterministically derived from the
    run seed so reruns replay byte-for-byte; in a real deployment this is
    the provisioning secret both ends of the sync channel hold (the fault
    plane's forger, by construction, does not)."""
    return hashlib.sha256(f"model-sync-key:{int(seed)}".encode()).digest()


def sign_tree(tree: Any, key: bytes) -> str:
    """HMAC-SHA256 over a params pytree: every leaf's shape, dtype and bytes
    in flatten order — the authenticated analog of ``tree_checksum``, safe
    for int8 ``QTensor`` trees (their ``q``/``scale`` children are ordinary
    leaves).  Unlike crc32, a forger cannot recompute this without ``key``."""
    import jax

    mac = hmac.new(key, digestmod=hashlib.sha256)
    for leaf in jax.tree_util.tree_leaves(tree):
        a = np.ascontiguousarray(np.asarray(leaf))
        mac.update(repr((a.shape, a.dtype.str)).encode())
        mac.update(a.tobytes())
    return mac.hexdigest()


def verify_tree(tree: Any, key: bytes, signature: Optional[str]) -> bool:
    if not signature:
        return False
    return hmac.compare_digest(sign_tree(tree, key), signature)


@dataclass
class HealthConfig:
    """Health-plane knobs.  Defaults are sized so a calm run is
    byte-identical to a no-health run and detection stays within two
    heartbeat intervals of an injected partition."""

    # heartbeat cadence; None -> the executor uses 0.5 * window period
    hb_interval_s: Optional[float] = None
    # phi-accrual-style suspicion thresholds: elapsed / mean inter-arrival
    phi_suspect: float = 1.4
    phi_down: float = 3.2
    interarrival_window: int = 16
    # Byzantine guard: flag |y - median| > byz_z * MAD-sigma of the rolling
    # per-stream history; engage only once min_history values are seen
    byz_z: float = 5.0
    byz_history: int = 720
    byz_min_history: int = 48
    # authenticated sync: HMAC-SHA256 over every model publish
    signed_sync: bool = True
    # adaptive thresholds: decayed-fault-count halflife (seconds; None ->
    # 2 * window period) and the pressure below which base values apply
    adaptive: bool = True
    rate_halflife_s: Optional[float] = None
    # decayed-fault-count level below which base thresholds apply exactly;
    # 1.5 means one isolated fault never tightens anything — it takes a
    # second fault inside the halflife to register as a *rate*
    calm_pressure: float = 1.5
    staleness_floor: int = 0
    quarantine_floor: int = 1


class FaultRateEstimator:
    """Exponentially-decayed fault counter: ``count(t) = sum over observed
    faults of 0.5 ** ((t - t_i) / halflife)`` — the health plane's fault-
    rate estimate (EWMA in count units, so thresholds read naturally as
    "recent faults")."""

    def __init__(self, halflife_s: float):
        self.halflife = float(halflife_s)
        self._count = 0.0
        self._t = 0.0

    def _decay_to(self, t: float) -> None:
        dt = max(0.0, t - self._t)
        if dt > 0.0 and self._count > 0.0:
            self._count *= math.exp(-LN2 * dt / self.halflife)
        self._t = max(self._t, t)

    def observe(self, t: float, n: float = 1.0) -> None:
        self._decay_to(t)
        self._count += n

    def pressure(self, t: float) -> float:
        self._decay_to(t)
        return self._count


class PhiAccrual:
    """Per-peer inter-arrival tracker.  ``phi(t) = elapsed / mean`` where
    ``mean`` is the windowed mean inter-arrival time (falling back to the
    expected heartbeat interval until a sample exists).  Intervals observed
    while the peer is suspected/down — and burst arrivals released together
    by a healing partition — are excluded from the mean, so an outage never
    poisons the baseline it is judged against."""

    def __init__(self, expected_s: float, window: int):
        self.expected = float(expected_s)
        self.intervals: deque = deque(maxlen=window)
        self.last_seen: Optional[float] = None

    def mean(self) -> float:
        if not self.intervals:
            return self.expected
        return float(sum(self.intervals) / len(self.intervals))

    def arrive(self, t: float, healthy: bool) -> None:
        if self.last_seen is not None and healthy:
            gap = t - self.last_seen
            # burst arrivals (a healed partition releasing the queue) and
            # the outage gap itself are not cadence samples
            if 0.25 * self.expected <= gap <= 2.0 * self.expected:
                self.intervals.append(gap)
        self.last_seen = max(self.last_seen or t, t)

    def phi(self, t: float) -> float:
        if self.last_seen is None:
            return 0.0
        return max(0.0, t - self.last_seen) / max(self.mean(), 1e-9)


class SiteMonitor:
    """One site's view of every peer — the goldpinger node.  State machine
    per peer: ok -> suspected -> down, back to ok on the next heartbeat
    (emitting ``recovered``).  A monitor that itself went dark (its check
    beat did not run — its site was down) re-baselines instead of blaming
    peers for heartbeats it was not alive to receive."""

    def __init__(self, observer: str, peers: List[str], cfg: HealthConfig,
                 hb_interval_s: float, plane: "HealthPlane"):
        self.observer = observer
        self.cfg = cfg
        self.hb = hb_interval_s
        self.plane = plane
        self.trackers: Dict[str, PhiAccrual] = {
            p: PhiAccrual(hb_interval_s, cfg.interarrival_window)
            for p in peers if p != observer}
        self.state: Dict[str, str] = {p: "ok" for p in self.trackers}
        self.last_check: Optional[float] = None

    def observe_heartbeat(self, peer: str, t: float) -> None:
        tr = self.trackers.get(peer)
        if tr is None:
            return
        healthy = self.state[peer] == "ok"
        tr.arrive(t, healthy)
        if not healthy:
            self.state[peer] = "ok"
            self.plane.verdict(t, "recovered", self.observer, peer,
                               f"hb after {self.state}")

    def check(self, t: float) -> None:
        if self.last_check is not None and t - self.last_check > 1.5 * self.hb:
            # the monitor itself was dark (its site was down): re-baseline
            # every peer instead of emitting stale-evidence verdicts
            self.plane.verdict(t, "monitor_gap", self.observer, self.observer,
                               f"{t - self.last_check:.3f}s without checks")
            for tr in self.trackers.values():
                tr.last_seen = t
            self.last_check = t
            return
        self.last_check = t
        for peer, tr in self.trackers.items():
            phi = tr.phi(t)
            st = self.state[peer]
            if st == "ok" and phi >= self.cfg.phi_suspect:
                self.state[peer] = "suspected"
                self.plane.verdict(t, "partition_suspected", self.observer,
                                   peer, f"phi={phi:.2f}")
            if st in ("ok", "suspected") and phi >= self.cfg.phi_down:
                self.state[peer] = "down"
                self.plane.verdict(t, "site_down", self.observer, peer,
                                   f"phi={phi:.2f}")


class ByzantineGuard:
    """Per-stream robust plausibility gate for sensor target values: flag
    ``|y - median| > z * (1.4826 * MAD)`` of the stream's rolling accepted
    history and impute the rolling median.  History updates with the
    *imputed* values, so admitted Byzantine values cannot drag the baseline
    toward themselves.  Returns the original arrays untouched when nothing
    is flagged — calm-path byte-identity."""

    def __init__(self, cfg: HealthConfig):
        self.cfg = cfg
        self._hist: Dict[str, deque] = {}
        self.flagged: Counter = Counter()
        self.screened = 0

    def screen(self, sid: str, data: Dict[str, np.ndarray], t: float
               ) -> Tuple[Dict[str, np.ndarray], int]:
        y = np.asarray(data["y"])
        hist = self._hist.setdefault(sid, deque(maxlen=self.cfg.byz_history))
        self.screened += int(y.size)
        n_flagged = 0
        if len(hist) >= self.cfg.byz_min_history and y.size > 0:
            h = np.asarray(hist, np.float64)
            med = float(np.median(h))
            sigma = 1.4826 * float(np.median(np.abs(h - med))) + 1e-9
            dev = np.abs(y.reshape(-1) - med) / sigma
            bad = dev > self.cfg.byz_z
            n_flagged = int(bad.sum())
            if n_flagged:
                self.flagged[sid] += n_flagged
                y2 = np.array(y, copy=True)
                y2.reshape(-1)[bad] = np.float32(med)
                hist.extend(float(v) for v in y2.reshape(-1))
                return {"x": data["x"], "y": y2}, n_flagged
        hist.extend(float(v) for v in y.reshape(-1))
        return data, 0


class HealthPlane:
    """The umbrella object the executor attaches: per-site monitors, the
    Byzantine guard, the fault-rate estimators and the adaptive-threshold
    policy, plus the signed-sync configuration.  ``reset()`` (called by the
    executor per run, like ``FaultPlane.reset``) rewinds all of it so one
    plane instance drives repeated byte-identical runs."""

    def __init__(self, cfg: Optional[HealthConfig] = None):
        self.cfg = cfg or HealthConfig()
        self._bound = False
        self.reset()

    # -- lifecycle -----------------------------------------------------------

    def reset(self) -> None:
        self.monitors: Dict[str, SiteMonitor] = {}
        self.guard = ByzantineGuard(self.cfg)
        self.verdicts: List[Tuple[float, str, str, str, str]] = []
        self.verdict_stats: Counter = Counter()
        self._rates: Dict[Tuple[str, str], FaultRateEstimator] = {}
        self.adaptations: List[Tuple[float, str, str, int, int]] = []
        self._last_eff: Dict[Tuple[str, str], int] = {}
        self.sync_key: Optional[bytes] = None
        self._hb = 0.0
        self._halflife = 1.0
        self._base_quarantine = 0
        self._base_staleness = 0

    def bind(self, *, sites: List[str], hb_interval_s: float,
             halflife_s: float, quarantine_after: int,
             staleness_bound: int, sync_seed: int) -> None:
        """Per-run wiring (executor ``_reset`` time): build one monitor per
        site over the run's topology, fix the decay clock, and remember the
        executor's base thresholds — the values calm runs must reproduce
        exactly."""
        self._hb = float(hb_interval_s)
        self._halflife = float(halflife_s)
        self._base_quarantine = int(quarantine_after)
        self._base_staleness = int(staleness_bound)
        self.monitors = {
            s: SiteMonitor(s, sites, self.cfg, self._hb, self)
            for s in sites}
        self.sync_key = (derive_sync_key(sync_seed)
                         if self.cfg.signed_sync else None)

    # -- detection -----------------------------------------------------------

    def verdict(self, t: float, kind: str, observer: str, subject: str,
                detail: str = "") -> None:
        self.verdicts.append((float(t), kind, observer, subject, detail))
        self.verdict_stats[kind] += 1
        if kind in ("partition_suspected", "site_down"):
            self.observe_fault("link", subject, t)

    def observe_heartbeat(self, observer: str, peer: str, t: float) -> None:
        mon = self.monitors.get(observer)
        if mon is not None:
            mon.observe_heartbeat(peer, t)

    def check(self, observer: str, t: float) -> None:
        mon = self.monitors.get(observer)
        if mon is not None:
            mon.check(t)

    def first_verdict_t(self, kind: str) -> Optional[float]:
        for t, k, _, _, _ in self.verdicts:
            if k == kind:
                return t
        return None

    # -- fault pressure + adaptive thresholds --------------------------------

    def observe_fault(self, kind: str, key: str, t: float) -> None:
        """Feed one detected fault into the rate estimate: ``kind`` in
        {"link", "sync", "sensor"}, ``key`` the subject site or stream."""
        est = self._rates.get((kind, key))
        if est is None:
            est = self._rates[(kind, key)] = FaultRateEstimator(
                self._halflife)
        est.observe(t)

    def pressure(self, kind: str, key: str, t: float) -> float:
        est = self._rates.get((kind, key))
        return est.pressure(t) if est is not None else 0.0

    def _adapt(self, t: float, which: str, key: str, base: int,
               pressure: float, floor: int) -> int:
        if not self.cfg.adaptive or pressure < self.cfg.calm_pressure:
            return base
        eff = max(floor, base - int(pressure / self.cfg.calm_pressure))
        if eff != base and self._last_eff.get((which, key)) != eff:
            self._last_eff[(which, key)] = eff
            self.adaptations.append((float(t), which, key, base, eff))
        return eff

    def quarantine_after(self, sid: str, t: float) -> int:
        """How many consecutive missed training flushes quarantine ``sid``
        right now: the base knob under calm pressure, tightened (never
        below ``quarantine_floor``) as this stream's detected sensor+sync
        fault pressure rises — a flaky sensor is cut out of the aggregation
        path faster than a healthy fleet's worst-case straggler would be."""
        p = (self.pressure("sensor", sid, t)
             + self.pressure("sync", sid, t))
        return self._adapt(t, "quarantine_after", sid,
                           self._base_quarantine, p,
                           self.cfg.quarantine_floor)

    def staleness_bound(self, sid: str, t: float) -> int:
        """The serving watchdog's model-lag bound for ``sid`` right now:
        the base bound under calm pressure, tightened toward
        ``staleness_floor`` when the sync path is visibly failing (link
        suspicion anywhere, or this stream's sync rejections) — serving
        flips to the batch fallback sooner exactly when fresh models are
        least likely to arrive."""
        link_p = max((est.pressure(t)
                      for (k, _), est in self._rates.items() if k == "link"),
                     default=0.0)
        p = link_p + self.pressure("sync", sid, t)
        return self._adapt(t, "staleness_bound", sid,
                           self._base_staleness, p,
                           self.cfg.staleness_floor)

    # -- reporting -----------------------------------------------------------

    def summary(self) -> Dict[str, Any]:
        """The run's health verdict, attached as
        ``FleetBusRunResult.health``."""
        min_q: Dict[str, int] = {}
        min_s: Dict[str, int] = {}
        for _, which, key, _, eff in self.adaptations:
            d = min_q if which == "quarantine_after" else min_s
            d[key] = min(d.get(key, eff), eff)
        return {
            "hb_interval_s": self._hb,
            "signed_sync": self.cfg.signed_sync,
            "adaptive": self.cfg.adaptive,
            "verdicts": [list(v) for v in self.verdicts],
            "verdict_stats": dict(self.verdict_stats),
            "n_suspected": self.verdict_stats.get("partition_suspected", 0),
            "n_site_down": self.verdict_stats.get("site_down", 0),
            "n_recovered": self.verdict_stats.get("recovered", 0),
            "first_suspect_t": self.first_verdict_t("partition_suspected"),
            "byz_screened": self.guard.screened,
            "byz_flagged": sum(self.guard.flagged.values()),
            "byz_flagged_per_stream": dict(self.guard.flagged),
            "threshold_adaptations": len(self.adaptations),
            "adapted_quarantine_after": min_q,
            "adapted_staleness_bound": min_s,
            "base_quarantine_after": self._base_quarantine,
            "base_staleness_bound": self._base_staleness,
        }
