from repro.runtime.bus import (  # noqa: F401
    CapacityError,
    DeadLetter,
    EventKernel,
    Link,
    Message,
    Site,
    TopicBus,
    Topology,
    paper_topology,
    topic_matches,
)
from repro.runtime.faults import (  # noqa: F401
    FaultPlane,
    MessageFault,
    PartitionFault,
    SensorFault,
    SiteFault,
    corrupt_tree,
    forge_tree,
    tree_checksum,
)
from repro.runtime.health import (  # noqa: F401
    ByzantineGuard,
    FaultRateEstimator,
    HealthConfig,
    HealthPlane,
    derive_sync_key,
    sign_tree,
    verify_tree,
)
from repro.runtime.deployment import (  # noqa: F401
    ALL_DEPLOYMENTS,
    STREAM_MODULES,
    Deployment,
    cloud_centric,
    edge_centric,
    edge_cloud_integrated,
)
from repro.runtime.placement import (  # noqa: F401
    LoadForecaster,
    PlacementController,
    PlacementDecision,
    SiteSignal,
    StreamSignal,
)
from repro.runtime.executor import (  # noqa: F401
    BusExecutor,
    BusRunResult,
    FleetBusExecutor,
    FleetBusRunResult,
    FleetRunResult,
    InProcessExecutor,
    InProcessFleetExecutor,
    fleet_key_chains,
)
from repro.runtime.latency import CostModel, LatencyLedger  # noqa: F401
from repro.runtime.modules import EdgeCloudSimulation, SimulationResult  # noqa: F401
