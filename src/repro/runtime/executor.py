"""Executors: schedule the hybrid learner's pipeline stages (``core.stages``)
under a deployment placement.

``InProcessExecutor`` replays the paper's synchronous per-window loop — the
pre-refactor ``HybridStreamAnalytics.run`` — over the extracted stages, with
identical results, records and key derivation.

``BusExecutor`` runs the *same stage objects* as ``TopicBus`` subscribers
placed per a ``Deployment`` map: windows are injected onto the stream topic,
each stage's real wall-clock is measured on this container, rescaled by its
site's ``compute_scale``, and accounted in the ``LatencyLedger`` — measured
latency, not ``CostModel`` constants.  Stage completions advance virtual
time, so the paper's M^s_{t-1} semantics (stale-model inference while speed
training is in flight) emerge from event ordering: speed training publishes
fresh params on the model topic whenever it finishes, and inference simply
uses whatever model ``model_sync`` has installed by the time a window
arrives.

Site occupancy is modeled with a per-site worker pool (``Site.workers``): the
Pi executes one module at a time, so a co-located training attempt delays the
inference chain — the paper's edge-centric contention — while the c5-class
cloud site overlaps training with inference.  A stage fired at virtual time
``d`` computes immediately (host time) on inputs snapshotted at ``d``, but
its *virtual* completion is queued behind earlier work on its site; the gap
is accounted in the ledger's ``queue`` column.

Capacity is still a model (we cannot OOM a real Pi from this container):
placing speed training on a site whose ``memory_bytes`` cannot hold
``CostModel.train_memory_bytes`` records a failure, charges the modeled
thrash time of the attempt (``CostModel.oom_thrash_s``), and never publishes
a model — so the edge-centric speed layer degrades to serving the batch
model, exactly the paper's Sec. 6.2 outcome.

The fleet executors lift both modalities to N streams under one deployment:
``InProcessFleetExecutor`` is the synchronous loop over a ``FleetStages``
set, and ``FleetBusExecutor`` multiplexes the bus topics per stream
(``stream/window/t03``, one wildcard subscription per module).  The fleet
hot path is one device dispatch per stage per window: whole-fleet speed
training (vmapped ``train_fleet``) *and* whole-fleet batch/speed inference
(vmapped ``predict_fleet`` via ``FleetInference``) — the bus executor
aggregates every stream's window-``t`` payload per stage before firing,
then fans the per-stream results back onto their own topics.  Both consult
an optional ``DriftGate`` so stationary streams skip their retrain and keep
serving the prior model; ``FleetBusExecutor(quantized_sync=True)`` ships
each retrained stream's model as an int8 ``QTensor`` tree on its own model
topic and serves the fleet through the batched int8 kernel.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple, Union

import numpy as np

from repro.core.drift import DriftGate
from repro.core.hybrid import HybridRunResult, WindowRecord
from repro.core.stages import (
    BatchRefresh,
    FleetStages,
    FleetState,
    PipelineStages,
    StreamId,
    resolve_fleet_params,
    split_chain,
)
from repro.core.weighting import rmse
from repro.core.windows import WindowedStream
from repro.runtime.bus import (
    CapacityError,
    EventKernel,
    Message,
    TopicBus,
    Topology,
)
from repro.runtime.deployment import STREAM_MODULES, Deployment
from repro.runtime.latency import CostModel, LatencyLedger
from repro.runtime.modules import (
    T_BATCH,
    T_CTRL,
    T_HEALTH_CHECK,
    T_HEALTH_HB,
    T_HYBRID,
    T_MODEL,
    T_REQUEST,
    T_RESPONSE,
    T_RESYNC,
    T_SPEED,
    T_STREAM,
    stream_topic,
)
from repro.runtime.placement import (
    PlacementController,
    SiteSignal,
    StreamSignal,
)
from repro.serving.query_plane import (
    QueryPlane,
    latency_stats,
    open_loop_trace,
)

Params = Any


def _nbytes(tree: Any) -> float:
    """Real byte size of a pytree of arrays (measured model/result sizes)."""
    import jax

    return float(sum(np.asarray(x).nbytes for x in jax.tree_util.tree_leaves(tree)))


def _gate_decision(gate: Optional[DriftGate], sid: StreamId, y: np.ndarray,
                   must: bool) -> bool:
    """One stream's retrain decision.  A stream with no serving model must
    retrain regardless of drift; the gate is told (``force_retrain``) so its
    reference window keeps tracking what the model actually trained on and
    its stats stay consistent with the executor's retrain log."""
    if gate is None:
        return True
    if must:
        gate.force_retrain(sid, y)
        return True
    return gate.decide(sid, y)


def fleet_key_chains(key: Any, ids: List[StreamId], n: int
                     ) -> Dict[StreamId, List[Any]]:
    """Per-stream training-key chains.  A mapping gives each stream's root
    key explicitly; a single key derives stream ``i``'s root as
    ``fold_in(key, i)`` in fleet order.  Each root then runs the same
    ``split_chain`` the single-stream executors use, so stream ``i`` of a
    fleet run trains with byte-identical keys to a single-stream run seeded
    with that root.

    The whole fleet's chains derive *batched*: one vmapped ``fold_in``
    dispatch for the roots and one vmapped ``split`` per chain step —
    O(n) device round-trips for the fleet instead of O(S·n), which at a
    thousand streams is the difference between milliseconds and seconds of
    setup.  The values are bitwise identical to the per-stream chain
    (``fold_in``/``split`` are deterministic integer hashing; vmap doesn't
    change them)."""
    import jax
    import jax.numpy as jnp

    if isinstance(key, Mapping):
        roots = np.stack([np.asarray(key[sid]) for sid in ids])
    else:
        roots = jax.vmap(lambda i: jax.random.fold_in(key, i))(
            jnp.arange(len(ids)))
    if n <= 0:
        return {sid: [] for sid in ids}
    cur = jnp.asarray(roots)
    split2 = jax.vmap(jax.random.split)
    subs = []
    for _ in range(n):
        both = split2(cur)  # (S, 2, key)
        cur = both[:, 0]
        subs.append(both[:, 1])
    host = np.asarray(jnp.stack(subs, axis=1))  # (S, n, key)
    return {sid: [host[i, w] for w in range(n)]
            for i, sid in enumerate(ids)}


_REFRESH_SALT = 0x0BA7C4  # folds the refresh chains away from training keys


def refresh_key_chains(key: Any, ids: List[StreamId], n: int
                       ) -> Dict[StreamId, List[Any]]:
    """Per-stream key chains for the batch-model refresh path: the same
    batched derivation as :func:`fleet_key_chains`, from roots salted with
    a fixed ``fold_in`` constant so a refresh at window ``t`` never reuses
    (or perturbs) the speed-training key for that window."""
    import jax
    import jax.numpy as jnp

    if isinstance(key, Mapping):
        roots = jnp.stack([jnp.asarray(key[sid]) for sid in ids])
        salted = np.asarray(jax.vmap(
            lambda k: jax.random.fold_in(k, _REFRESH_SALT))(roots))
        return fleet_key_chains(
            {sid: salted[i] for i, sid in enumerate(ids)}, ids, n)
    return fleet_key_chains(jax.random.fold_in(key, _REFRESH_SALT), ids, n)


# ---------------------------------------------------------------------------
# Synchronous path
# ---------------------------------------------------------------------------


class InProcessExecutor:
    """The paper's synchronous loop over the extracted stages.

    Backward-compatible with the pre-refactor ``HybridStreamAnalytics.run``:
    same key chain, same window bookkeeping, same ``WindowRecord`` timing
    conventions (``t_weight_solve`` counts only the dynamic solve)."""

    def __init__(self, stages: PipelineStages, start_window: int = 1):
        self.stages = stages
        self.start_window = start_window

    def run(self, stream: WindowedStream, batch_params: Params, key,
            n_windows: Optional[int] = None) -> HybridRunResult:
        st = self.stages
        n = len(stream) if n_windows is None else min(n_windows, len(stream))
        keys = split_chain(key, n)
        records: List[WindowRecord] = []
        speed_params: Optional[Params] = None
        prev_preds = prev_y = None

        for t in range(n):
            data = stream.supervised(t)
            x, y = data["x"], data["y"]
            if t >= self.start_window and speed_params is not None and len(x) > 0:
                b = st.batch_inference(batch_params=batch_params, x=x)
                s = st.speed_inference(speed_params=speed_params, x=x)
                w = st.weight_solve(prev_preds=prev_preds, prev_y=prev_y)
                t_w = (w.wall_s if st.weight_solve.is_dynamic
                       and prev_preds is not None else 0.0)
                h = st.hybrid_combine(
                    pred_speed=s["pred"], pred_batch=b["pred"],
                    w_speed=w["w_speed"], w_batch=w["w_batch"])
                records.append(WindowRecord(
                    window=t,
                    rmse_batch=rmse(y, b["pred"]),
                    rmse_speed=rmse(y, s["pred"]),
                    rmse_hybrid=rmse(y, h["pred"]),
                    w_speed=w["w_speed"],
                    w_batch=w["w_batch"],
                    t_batch_infer=b.wall_s,
                    t_speed_infer=s.wall_s,
                    t_hybrid_infer=h.wall_s + t_w,
                    t_weight_solve=t_w,
                ))
            # training phase: speed model for the next window
            tr = st.speed_training(data=data, speed_params=speed_params,
                                   batch_params=batch_params, key=keys[t])
            if records and records[-1].window == t:
                records[-1].t_speed_train = tr["train_wall_s"]
            if tr["eval_preds"] is not None:
                prev_preds, prev_y = tr["eval_preds"], tr["eval_y"]
            speed_params = tr["params"]
        return HybridRunResult(records=records, mode=str(st.mode))


# ---------------------------------------------------------------------------
# Bus-scheduled path
# ---------------------------------------------------------------------------


@dataclass
class BusRunResult:
    """What one ``BusExecutor`` run produced: real per-window accuracy records
    plus the measured (rescaled) latency ledger and per-window end-to-end
    latency (window injected -> hybrid result delivered back to the
    injection site)."""

    records: List[WindowRecord]
    ledger: LatencyLedger
    failures: List[str]
    n_windows: int
    e2e_s: Dict[int, float]
    message_log: List[Message]
    mode: str

    def table3(self) -> Dict[str, Dict[str, float]]:
        return self.ledger.table()

    def mean_e2e_s(self) -> float:
        if not self.e2e_s:
            return float("nan")
        return float(np.mean(list(self.e2e_s.values())))

    def to_hybrid_result(self) -> HybridRunResult:
        return HybridRunResult(records=self.records, mode=self.mode)


@dataclass
class _ModelState:
    """The serving-side speed model installed by model_sync."""

    params: Optional[Params] = None
    prev_preds: Optional[tuple] = None
    prev_y: Optional[np.ndarray] = None
    window: int = -1


class _BusRuntime:
    """Shared machinery of the bus-driven executors: the event kernel +
    topic bus + latency ledger lifecycle, the site scheduler that rescales
    measured walls to a site's hardware class and queues work behind
    earlier work on the site's worker pool, the training capacity model,
    and the stage-agnostic handlers.  Subclasses provide ``dep``, ``topo``,
    ``cost``, ``strict`` and ``_single_stages``."""

    dep: Deployment
    topo: Topology
    cost: CostModel
    strict: bool

    def _init_runtime(self) -> None:
        self.kernel = EventKernel()
        self.bus = TopicBus(self.kernel, self.topo,
                            fault_plane=getattr(self, "fault_plane", None))
        self.ledger = LatencyLedger()
        self.failures: List[str] = []
        self._free: Dict[str, List[float]] = {}

    @property
    def _single_stages(self) -> PipelineStages:
        raise NotImplementedError

    def _site(self, module: str):
        return self.topo.sites[self.dep.site_of(module)]

    def _train_fits_site(self, comm_s: float) -> bool:
        """The capacity model: True when the training site can hold the
        job.  Otherwise record the paper's OOM failure, charge the modeled
        thrash of the attempt (``CostModel.oom_thrash_s`` — the successful
        training wall is no proxy now that the compiled hot path runs in
        milliseconds), and never let a model publish."""
        site = self._site("speed_training")
        if self.cost.train_memory_bytes <= site.memory_bytes:
            return True
        self.failures.append(
            f"speed_training OOM on {site.name}: needs "
            f"{self.cost.train_memory_bytes/1e9:.1f} GB > "
            f"{site.memory_bytes/1e9:.1f} GB")
        if self.strict:
            raise CapacityError(self.failures[-1])
        self._schedule("speed_training", self.cost.oom_thrash_s, comm_s)
        return False

    def _on_data_sync(self, msg: Message) -> None:
        out = self._single_stages.data_sync(nbytes=msg.nbytes)
        link = self.topo.link(self.dep.site_of("data_sync"),
                              self.dep.site_of("archiving"))
        self._schedule("data_sync", out.wall_s,
                       link.transfer_time(out["nbytes"]))

    def _on_archive(self, msg: Message) -> None:
        self.ledger.add("archiving", comp_s=0.0,
                        comm_s=msg.deliver_time - msg.publish_time)

    def _pool(self, site) -> List[float]:
        """The site's busy-until worker pool, lazily resized when the
        elastic controller changed ``site.workers``: grown workers start
        idle now; a shrink drops idle entries only (a busy worker finishes
        what it admitted — the pool just stops assigning to it)."""
        now = self.kernel.now
        pool = self._free.setdefault(site.name, [now] * max(site.workers, 1))
        want = max(site.workers, 1)
        if len(pool) < want:
            pool.extend([now] * (want - len(pool)))
        elif len(pool) > want:
            for i in range(len(pool) - 1, -1, -1):
                if len(pool) <= want:
                    break
                if pool[i] <= now:
                    del pool[i]
        return pool

    def _backlog_s(self, site_name: str) -> float:
        """Seconds of admitted-but-unfinished work queued on the site."""
        now = self.kernel.now
        return sum(max(0.0, p - now) for p in self._free.get(site_name, []))

    def _schedule(self, module: str, wall_s: float, comm_s: float,
                  done: Optional[Callable[[], None]] = None,
                  site_name: Optional[str] = None) -> None:
        """Account a stage that took ``wall_s`` real seconds: rescale to the
        site's hardware class, queue it behind earlier work on the site's
        worker pool, and fire ``done`` at its virtual completion.

        ``site_name`` overrides the deployment's placement for the module —
        the elastic fleet path schedules a migrated stream's stages on the
        stream's current site, not the static one.

        An optional ``stage_costs`` map (module -> wall seconds) replaces
        the measured wall with a fixed virtual cost — the chaos suite uses
        it so two runs under the same fault seed produce *byte-identical*
        ledgers and schedules (perf-counter walls would differ per run).

        If the module's site is down (``fault_plane.site_down``) when the
        stage would complete, the in-flight work is lost: no ledger entry,
        no completion callback — a crash loses whatever was computing.

        The site's queue depth is sampled twice per stage — at entry
        (backlog in front of this work) and again at completion/publish
        time — so the ledger's depth series sees queue growth *between*
        stage entries instead of aliasing it to zero."""
        site = (self.topo.sites[site_name] if site_name is not None
                else self._site(module))
        sc = getattr(self, "stage_costs", None)
        if sc is not None and module in sc:
            wall_s = sc[module]
        scaled = wall_s / max(site.compute_scale, 1e-9)
        pool = self._pool(site)
        self.ledger.sample_depth(site.name, self.kernel.now,
                                 self._backlog_s(site.name))
        i = min(range(len(pool)), key=pool.__getitem__)
        start = max(self.kernel.now, pool[i])
        queue_s = start - self.kernel.now
        pool[i] = start + scaled

        def finish():
            fp = getattr(self, "fault_plane", None)
            if fp is not None and fp.site_down(site.name, self.kernel.now):
                fp.note("lost_inflight_work", self.kernel.now,
                        f"{module}@{site.name}")
                return
            self.ledger.add(module, comp_s=scaled, comm_s=comm_s,
                            queue_s=queue_s)
            self.ledger.sample_depth(site.name, self.kernel.now,
                                     self._backlog_s(site.name))
            if done is not None:
                done()

        self.kernel.at(start + scaled, finish)


class BusExecutor(_BusRuntime):
    """Drive the stages as topic-bus subscribers under a placement map.

    The ``CostModel`` is consulted only for what cannot be measured from this
    container: the Kafka ingest throttle (``ingest_s``, charged as
    communication on stream consumers) and the training-job memory footprint
    (``train_memory_bytes``, the capacity model).  All compute is measured;
    all transfer sizes are the real array/parameter byte counts.

    ``quantized_sync=True`` turns on the int8 model-sync path (the paper's
    TFLite-on-Pi analog): the training site quantizes the fresh speed model
    (``serving.quantize.quantize_tree``) before publishing it, the model
    topic carries the ~4x smaller int8 byte count, and the serving side runs
    quantized inference (``models.lstm`` dispatches the fused
    ``int8_matmul`` kernel on ``QTensor`` leaves).
    """

    def __init__(
        self,
        stages: PipelineStages,
        deployment: Deployment,
        topo: Topology,
        cost: Optional[CostModel] = None,
        *,
        start_window: int = 1,
        window_period_s: float = 30.0,
        strict_capacity: bool = False,
        quantized_sync: bool = False,
        quant_min_size: int = 64,
    ):
        self.stages = stages
        self.dep = deployment
        self.topo = topo
        self.cost = cost or CostModel()
        self.start_window = start_window
        self.period = window_period_s
        self.strict = strict_capacity
        self.quantized_sync = quantized_sync
        self.quant_min_size = quant_min_size

    @property
    def _single_stages(self) -> PipelineStages:
        return self.stages

    # -- per-run state -------------------------------------------------------

    def _reset(self) -> None:
        self._init_runtime()
        self._model = _ModelState()
        self._records: Dict[int, WindowRecord] = {}
        self._train_walls: Dict[int, float] = {}
        self._pending: Dict[int, Dict[str, Message]] = {}
        self._inject_t: Dict[int, float] = {}
        self.e2e_s: Dict[int, float] = {}
        self._wire()

    def _wire(self) -> None:
        dep, bus = self.dep, self.bus
        bus.subscribe(T_STREAM, dep.site_of("batch_inference"), self._on_batch)
        bus.subscribe(T_STREAM, dep.site_of("speed_inference"), self._on_speed)
        bus.subscribe(T_STREAM, dep.site_of("speed_training"), self._on_train)
        bus.subscribe(T_STREAM, dep.site_of("data_sync"), self._on_data_sync)
        bus.subscribe(T_BATCH, dep.site_of("hybrid_inference"), self._on_part)
        bus.subscribe(T_SPEED, dep.site_of("hybrid_inference"), self._on_part)
        bus.subscribe(T_HYBRID, dep.site_of("archiving"), self._on_archive)
        bus.subscribe(T_HYBRID, dep.site_of("data_injection"), self._on_user)
        bus.subscribe(T_MODEL, dep.site_of("model_sync"), self._on_model_sync)

    # -- handlers ------------------------------------------------------------

    def _on_batch(self, msg: Message) -> None:
        w = msg.payload["window"]
        if w < self.start_window:
            return
        comm = msg.deliver_time - msg.publish_time + self.cost.ingest_s
        out = self.stages.batch_inference(
            batch_params=self._batch_params, x=msg.payload["x"])
        self._schedule(
            "batch_inference", out.wall_s, comm,
            lambda: self.bus.publish(
                T_BATCH,
                {"window": w, "kind": "batch", "pred": out["pred"],
                 "wall_s": out.wall_s, "fallback": False},
                _nbytes(out["pred"]), self.dep.site_of("batch_inference")))

    def _on_speed(self, msg: Message) -> None:
        w = msg.payload["window"]
        if w < self.start_window:
            return
        comm = msg.deliver_time - msg.publish_time + self.cost.ingest_s
        out = self.stages.speed_inference(
            speed_params=self._model.params, x=msg.payload["x"],
            fallback_params=self._batch_params)
        self._schedule(
            "speed_inference", out.wall_s, comm,
            lambda: self.bus.publish(
                T_SPEED,
                {"window": w, "kind": "speed", "pred": out["pred"],
                 "wall_s": out.wall_s, "fallback": out["fallback"]},
                _nbytes(out["pred"]), self.dep.site_of("speed_inference")))

    def _on_part(self, msg: Message) -> None:
        w = msg.payload["window"]
        parts = self._pending.setdefault(w, {})
        parts[msg.payload["kind"]] = msg
        if len(parts) < 2:
            return
        st = self.stages
        bmsg, smsg = parts["batch"], parts["speed"]
        comm = max(m.deliver_time - m.publish_time for m in parts.values())
        wsol = st.weight_solve(prev_preds=self._model.prev_preds,
                               prev_y=self._model.prev_y)
        t_w = (wsol.wall_s if st.weight_solve.is_dynamic
               and self._model.prev_preds is not None else 0.0)
        hc = st.hybrid_combine(
            pred_speed=smsg.payload["pred"], pred_batch=bmsg.payload["pred"],
            w_speed=wsol["w_speed"], w_batch=wsol["w_batch"])
        y = self._ys[w]
        rec = WindowRecord(
            window=w,
            rmse_batch=rmse(y, bmsg.payload["pred"]),
            rmse_speed=rmse(y, smsg.payload["pred"]),
            rmse_hybrid=rmse(y, hc["pred"]),
            w_speed=wsol["w_speed"],
            w_batch=wsol["w_batch"],
            t_speed_train=self._train_walls.get(w, 0.0),
            t_batch_infer=bmsg.payload["wall_s"],
            t_speed_infer=smsg.payload["wall_s"],
            t_hybrid_infer=hc.wall_s + t_w,
            t_weight_solve=t_w,
        )
        self._records[w] = rec
        self._schedule(
            "hybrid_inference", wsol.wall_s + hc.wall_s, comm,
            lambda: self.bus.publish(
                T_HYBRID,
                {"window": w, "rmse_hybrid": rec.rmse_hybrid,
                 "w_speed": rec.w_speed},
                _nbytes(hc["pred"]), self.dep.site_of("hybrid_inference")))

    def _on_train(self, msg: Message) -> None:
        w = msg.payload["window"]
        comm = msg.deliver_time - msg.publish_time
        if not self._train_fits_site(comm):
            return
        out = self.stages.speed_training(
            data={"x": msg.payload["x"], "y": msg.payload["y"]},
            speed_params=self._model.params,
            batch_params=self._batch_params, key=self._keys[w])
        self._train_walls[w] = out["train_wall_s"]
        if w in self._records:
            self._records[w].t_speed_train = out["train_wall_s"]
        params_pub = out["params"]
        if self.quantized_sync:
            # int8 sync (the paper's TFLite-conversion analog): the training
            # site quantizes before the transfer, so the model topic carries
            # ~4x fewer bytes — QTensor is a pytree, so _nbytes measures the
            # real int8+scale size — and the edge serves the quantized model
            # (lstm.forward dispatches the int8 kernel on QTensor leaves)
            from repro.serving.quantize import quantize_tree

            params_pub = quantize_tree(out["params"],
                                       min_size=self.quant_min_size)
        from repro.runtime.faults import tree_checksum

        pub_checksum = tree_checksum(params_pub)
        self._schedule(
            "speed_training", out.wall_s, comm,
            lambda: self.bus.publish(
                T_MODEL,
                {"window": w, "params": params_pub,
                 "eval_preds": out["eval_preds"], "eval_y": out["eval_y"],
                 "checksum": pub_checksum},
                _nbytes(params_pub), self.dep.site_of("speed_training")))

    def _on_model_sync(self, msg: Message) -> None:
        out = self.stages.model_sync(
            params=msg.payload["params"], eval_preds=msg.payload["eval_preds"],
            eval_y=msg.payload["eval_y"],
            checksum=msg.payload.get("checksum"))
        if not out["ok"]:
            # corrupted in transit: the transfer happened, the model is
            # never installed — serving stays on the previous/batch model
            self.ledger.add("model_sync", comp_s=0.0,
                            comm_s=msg.deliver_time - msg.publish_time)
            return
        if msg.payload["window"] <= self._model.window:
            # out-of-order publish (overlapping trainings on a multi-worker
            # site): the transfer happened, but never install an older model
            # over a newer one
            self.ledger.add("model_sync", comp_s=0.0,
                            comm_s=msg.deliver_time - msg.publish_time)
            return
        self._model = _ModelState(
            params=out["speed_params"], prev_preds=out["prev_preds"],
            prev_y=out["prev_y"], window=msg.payload["window"])
        self._schedule("model_sync", out.wall_s,
                       msg.deliver_time - msg.publish_time)

    def _on_user(self, msg: Message) -> None:
        w = msg.payload["window"]
        if w in self._inject_t:
            self.e2e_s[w] = msg.deliver_time - self._inject_t[w]

    # -- driver --------------------------------------------------------------

    def _warmup(self, stream: WindowedStream, batch_params: Params, key) -> None:
        """Compile every jit path once, so the measured windows are the
        paper's steady-state windows (on the compiled forecaster this also
        populates the shape-bucket train-step cache).  With int8 sync on,
        that includes the QTensor-structured predict — a pytree structure
        jit has never traced — so the first measured speed_inference on a
        quantized model doesn't swallow its compile."""
        import jax

        data = stream.supervised(0)
        tr = self.stages.speed_training(
            data=data, speed_params=None, batch_params=batch_params,
            key=jax.random.fold_in(key, 0))
        self.stages.batch_inference(batch_params=batch_params, x=data["x"])
        if self.quantized_sync and len(data["x"]) > 0:
            from repro.serving.quantize import quantize_tree

            self.stages.speed_inference(
                speed_params=quantize_tree(tr["params"],
                                           min_size=self.quant_min_size),
                x=data["x"])

    def run(self, stream: WindowedStream, batch_params: Params, key,
            n_windows: Optional[int] = None) -> BusRunResult:
        from repro.streams.injection import BusInjector

        self._reset()
        n = len(stream) if n_windows is None else min(n_windows, len(stream))
        self._batch_params = batch_params
        self._keys = split_chain(key, n)
        self._ys = {}
        self._warmup(stream, batch_params, key)

        injector = BusInjector(self.kernel, self.bus, T_STREAM,
                               self.dep.site_of("data_injection"),
                               period_s=self.period)
        for w in range(n):
            data = stream.supervised(w)
            self._ys[w] = data["y"]
            self._inject_t[w] = injector.schedule_window(w, data)
        self.kernel.run()
        return BusRunResult(
            records=[self._records[w] for w in sorted(self._records)],
            ledger=self.ledger,
            failures=self.failures,
            n_windows=n,
            e2e_s=dict(self.e2e_s),
            message_log=self.bus.log,
            mode=str(self.stages.mode),
        )


# ---------------------------------------------------------------------------
# Fleet executors: N streams, one deployment, one train dispatch per window
# ---------------------------------------------------------------------------


@dataclass
class FleetRunResult:
    """What a fleet run produced: per-stream window records plus the
    fleet-level training accounting (how many device dispatches the whole
    fleet's speed training cost, and which windows each stream's drift gate
    skipped)."""

    results: Dict[StreamId, HybridRunResult]
    train_dispatches: int
    retrain_log: Dict[StreamId, List[bool]]
    gate_stats: Optional[Dict[str, Any]]
    n_windows: int
    mode: str
    # the batch-model refresh plane, when the run had a BatchRefresh stage:
    # rounds fired, fleet dispatches spent, per-stream refresh counts, and
    # the total refresh training wall
    refresh: Optional[Dict[str, Any]] = None

    def skipped_retrains(self) -> int:
        return sum(not fired for log in self.retrain_log.values()
                   for fired in log)

    def total_retrains(self) -> int:
        return sum(fired for log in self.retrain_log.values()
                   for fired in log)

    def mean_rmse(self) -> Dict[str, float]:
        """Fleet mean of the per-stream mean RMSEs (nan when no stream has
        inference records yet, e.g. a one-window run)."""
        per = [r.mean_rmse() for r in self.results.values() if r.records]
        if not per:
            return {k: float("nan") for k in ("batch", "speed", "hybrid")}
        return {k: float(np.mean([p[k] for p in per]))
                for k in ("batch", "speed", "hybrid")}


@dataclass
class FleetBusRunResult(FleetRunResult):
    """Fleet run under the topic bus: adds the measured latency ledger,
    capacity failures, and per-stream end-to-end window latency."""

    ledger: LatencyLedger = field(default_factory=LatencyLedger)
    failures: List[str] = field(default_factory=list)
    e2e_s: Dict[StreamId, Dict[int, float]] = field(default_factory=dict)
    message_log: List[Message] = field(default_factory=list)
    # the request plane (when the run served queries): every query object
    # (answers + admission/finish stamps filled in) and the aggregate
    # latency/QPS/dispatch stats
    queries: List[Any] = field(default_factory=list)
    serving: Optional[Dict[str, Any]] = None
    # the fault plane's ledger (when a FaultPlane drove the run): realized
    # fault counts, rejections, quarantines, re-requests — plus every
    # undeliverable publish
    dead_letters: List[Any] = field(default_factory=list)
    chaos: Optional[Dict[str, Any]] = None
    # the elastic placement plane (when the run had a controller): controller
    # decisions, realized migrations, final per-stream site map, worker-count
    # history — plus per-stage fleet-inference dispatch accounting and each
    # stream's final (materialized) speed-model params, which the
    # determinism regression compares byte-for-byte
    placement: Optional[Dict[str, Any]] = None
    infer_dispatches: Optional[Dict[str, Dict[str, int]]] = None
    final_params: Optional[Dict[StreamId, Any]] = None
    # the health plane's run verdict (when a HealthPlane drove the run):
    # partition/site-down/recovered verdicts with times, signed-sync and
    # Byzantine-guard counters, and every adaptive-threshold tightening
    health: Optional[Dict[str, Any]] = None

    def table3(self) -> Dict[str, Dict[str, float]]:
        return self.ledger.table()

    def mean_e2e_s(self) -> float:
        vals = [v for per in self.e2e_s.values() for v in per.values()]
        return float(np.mean(vals)) if vals else float("nan")


class InProcessFleetExecutor:
    """The paper's synchronous per-window loop lifted to a fleet of streams.

    Per window ``t``: per-stream inference through the fleet-lifted stages
    (the same single-stream stage math and timing conventions as
    ``InProcessExecutor`` — a one-stream fleet reproduces its records
    exactly), then **one** whole-fleet speed-training dispatch
    (``FleetSpeedTraining`` -> ``FleetForecaster.train_fleet``) covering the
    streams whose drift gate said retrain — all of them when no gate is
    given, the paper's every-window policy.  Skipped streams keep serving
    their prior speed model and their prior Algorithm-1 eval predictions.

    With a :class:`BatchRefresh` stage, every gate-fired window is also
    archived, and the refresh cadence periodically retrains the *batch*
    models of streams with enough archived drifted windows — one extra
    sharded fleet dispatch per refresh round, replacing those streams'
    batch params for all subsequent windows."""

    def __init__(self, stages: FleetStages, *, start_window: int = 1,
                 gate: Optional[DriftGate] = None,
                 batch_refresh: Optional[BatchRefresh] = None):
        self.stages = stages
        self.start_window = start_window
        self.gate = gate
        self.batch_refresh = batch_refresh

    def run(self, streams: Dict[StreamId, WindowedStream], batch_params: Any,
            key, n_windows: Optional[int] = None) -> FleetRunResult:
        st = self.stages
        ids = list(streams)
        n = min(len(s) for s in streams.values())
        if n_windows is not None:
            n = min(n, n_windows)
        keys = fleet_key_chains(key, ids, n)
        rf = self.batch_refresh
        rkeys = refresh_key_chains(key, ids, n) if rf is not None else {}
        if rf is not None:
            rf.reset()
        bp = resolve_fleet_params(batch_params, ids)
        fleet = FleetState()
        records: Dict[StreamId, List[WindowRecord]] = {sid: [] for sid in ids}
        retrain_log: Dict[StreamId, List[bool]] = {sid: [] for sid in ids}
        fc = st.speed_training.forecaster
        dispatches0 = fc.train_dispatches

        for t in range(n):
            data = {sid: streams[sid].supervised(t) for sid in ids}
            infer = [sid for sid in ids
                     if t >= self.start_window
                     and fleet.state(sid).speed_params is not None
                     and len(data[sid]["x"]) > 0]
            if infer:
                b = st.batch_inference(fleet={
                    sid: dict(batch_params=bp[sid], x=data[sid]["x"])
                    for sid in infer})["fleet"]
                s = st.speed_inference(fleet={
                    sid: dict(speed_params=fleet.state(sid).speed_params,
                              x=data[sid]["x"])
                    for sid in infer})["fleet"]
                w = st.weight_solve(fleet={
                    sid: dict(prev_preds=fleet.state(sid).prev_preds,
                              prev_y=fleet.state(sid).prev_y)
                    for sid in infer})["fleet"]
                h = st.hybrid_combine(fleet={
                    sid: dict(pred_speed=s[sid]["pred"],
                              pred_batch=b[sid]["pred"],
                              w_speed=w[sid]["w_speed"],
                              w_batch=w[sid]["w_batch"])
                    for sid in infer})["fleet"]
                for sid in infer:
                    y = data[sid]["y"]
                    t_w = (w[sid].wall_s
                           if st.single.weight_solve.is_dynamic
                           and fleet.state(sid).prev_preds is not None
                           else 0.0)
                    records[sid].append(WindowRecord(
                        window=t,
                        rmse_batch=rmse(y, b[sid]["pred"]),
                        rmse_speed=rmse(y, s[sid]["pred"]),
                        rmse_hybrid=rmse(y, h[sid]["pred"]),
                        w_speed=w[sid]["w_speed"],
                        w_batch=w[sid]["w_batch"],
                        t_batch_infer=b[sid].wall_s,
                        t_speed_infer=s[sid].wall_s,
                        t_hybrid_infer=h[sid].wall_s + t_w,
                        t_weight_solve=t_w,
                    ))
            # training phase: drift-gated whole-fleet dispatch
            train_ids = []
            for sid in ids:
                fire = _gate_decision(
                    self.gate, sid, data[sid]["y"],
                    must=fleet.state(sid).speed_params is None)
                retrain_log[sid].append(fire)
                if fire:
                    train_ids.append(sid)
                    if rf is not None:
                        rf.archive(sid, data[sid])
            if train_ids:
                tr = st.speed_training(
                    fleet_data={sid: data[sid] for sid in train_ids},
                    batch_params={sid: bp[sid] for sid in train_ids},
                    keys={sid: keys[sid][t] for sid in train_ids})
                for sid in train_ids:
                    out = tr["fleet"][sid]
                    ss = fleet.state(sid)
                    ss.speed_params = out["params"]
                    ss.window = t
                    if out["eval_preds"] is not None:
                        ss.prev_preds = out["eval_preds"]
                        ss.prev_y = out["eval_y"]
                    if records[sid] and records[sid][-1].window == t:
                        records[sid][-1].t_speed_train = tr["train_wall_s"]
            # cloud-side heavy retraining: the queued gated batch-model
            # refresh rides the same sharded fleet dispatch on its cadence
            if rf is not None and rf.due(t):
                ref = rf(keys={sid: rkeys[sid][t] for sid in ids})
                for sid, p in ref["fleet"].items():
                    bp[sid] = p

        return FleetRunResult(
            results={sid: HybridRunResult(records=records[sid],
                                          mode=str(st.mode))
                     for sid in ids},
            # refresh dispatches ride the same forecaster counter; report
            # them under ``refresh`` so this stays speed-training-only
            train_dispatches=(fc.train_dispatches - dispatches0
                              - (rf.dispatches if rf is not None else 0)),
            retrain_log=retrain_log,
            gate_stats=self.gate.stats() if self.gate is not None else None,
            n_windows=n,
            mode=str(st.mode),
            refresh=(None if rf is None else {
                "rounds": rf.rounds,
                "dispatches": rf.dispatches,
                "refreshed": dict(rf.refreshed),
                "train_wall_s": rf.train_wall_s,
            }),
        )


class FleetBusExecutor(_BusRuntime):
    """``BusExecutor`` lifted to a fleet: N streams multiplexed over
    per-stream topics (``stream/window/<sid>`` etc., one wildcard
    subscription per module) under **one** ``Deployment``, per-stream
    serving state in a ``FleetState``, and every stream's window-``t``
    payload aggregated into one whole-fleet dispatch per stage — speed
    training *and* batch/speed inference (``FleetInference`` -> vmapped
    ``predict_fleet``): once the window's last stream message reaches a
    module's site, the whole fleet computes in one device dispatch and the
    per-stream results fan back out onto their own topics.

    Fresh models publish per stream on ``model/latest/<sid>`` carrying that
    stream's real parameter byte count, so the sync-transfer accounting
    scales with how many streams actually retrained — with a ``DriftGate``,
    stationary streams neither train nor transfer, while their inference
    chain keeps serving the prior model (the per-stream dynamic-learning
    policy the paper applies globally).

    ``quantized_sync=True`` extends the int8 sync path to the fleet: each
    retrained stream's params materialize at the publish boundary, quantize
    (``serving.quantize.quantize_tree``), and ship as an int8 ``QTensor``
    tree on that stream's model topic with its real int8 byte count; the
    serving side then runs the *batched* int8 fleet inference — stacked
    ``QTensor`` trees through the ``int8_matmul`` kernel under vmap.

    ``qps > 0`` (or an explicit ``query_trace``) turns on the request
    plane: user queries arrive open-loop on ``serve/request/<sid>``, a
    slot-recycling :class:`~repro.serving.query_plane.QueryPlane` admits
    them into ``serve_slots`` fixed batch slots, and every serving tick
    answers all active slots across all streams in **one** vmapped
    ``predict_fleet`` dispatch over the device-resident serving params —
    interleaved with the training windows under the serving site's worker
    occupancy, answers published on ``serve/response/<sid>``, per-request
    latency and sustained QPS reported in ``FleetBusRunResult.serving``.

    The robustness layer (exercised by ``core.scenarios`` under a
    ``fault_plane``, but always on):

    * **checksummed model sync** — every model publish carries a CRC32 of
      its param tree; ``ModelSync`` verifies on deliver, a corrupt publish
      (e.g. a bit-flipped int8 ``QTensor``) is never installed, and the
      sync site re-requests it on ``model/rerequest/<sid>`` (the training
      site re-publishes its cached last model, at most ``max_resync``
      times per (stream, window)).
    * **staleness watchdog** — serving falls back to the batch model for
      any stream whose installed ``model_window`` lags the stream's
      context window by more than ``staleness_bound`` (answers stamp
      ``served_fallback``), so the PR-6 ≤1-window staleness bound is now
      *enforced*, not just observed.
    * **per-stream quarantine** — the aggregated one-dispatch-per-window
      contract waits for every stream; under a fault plane each
      aggregation arms an ``agg_timeout_s`` flush that dispatches the
      streams that showed up, and a stream missing ``quarantine_after``
      consecutive training windows is quarantined (dispatches stop
      waiting for it) until its sensor delivers again — one poisoned
      stream cannot stall the fleet.
    * **crash semantics** — in-flight stage work on a site that is down at
      completion time is lost; when the site restarts the plane fires
      ``_on_site_restart`` (cold worker pool; serving state reset if the
      sync site crashed).

    ``stage_costs`` (module -> wall seconds) replaces measured stage walls
    with fixed virtual costs so chaos runs are byte-identically replayable
    under one fault seed.

    ``elastic=True`` (or ``"reactive"``/``"proactive"``) turns on the
    placement plane: per-stream (exact-topic) subscriptions instead of the
    one-wildcard-per-module wiring, and a
    :class:`~repro.runtime.placement.PlacementController` driven by a
    periodic ``ctrl/tick`` bus subscription that migrates hot/drifting
    streams to cloud (republishing their subscriptions and handing their
    device-resident state across — ``FleetState.handoff``), demotes cold
    ones back to edge, and grows/shrinks ``Site.workers`` reactively from
    queue-depth EWMAs and proactively from a speed-layer load forecast.
    The aggregated one-dispatch-per-window train/predict path is untouched:
    aggregation happens above placement, so migration only changes where
    occupancy is charged and results fan out from."""

    def __init__(
        self,
        stages: FleetStages,
        deployment: Deployment,
        topo: Topology,
        cost: Optional[CostModel] = None,
        *,
        start_window: int = 1,
        window_period_s: float = 30.0,
        strict_capacity: bool = False,
        gate: Optional[DriftGate] = None,
        quantized_sync: bool = False,
        quant_min_size: int = 64,
        qps: float = 0.0,
        serve_slots: int = 4,
        query_trace: Optional[List[Any]] = None,
        query_seed: int = 0,
        fault_plane: Optional[Any] = None,
        health_plane: Optional[Any] = None,
        stage_costs: Optional[Dict[str, float]] = None,
        staleness_bound: int = 1,
        agg_timeout_s: Optional[float] = None,
        quarantine_after: int = 2,
        max_resync: int = 3,
        elastic: Union[bool, str] = False,
        controller_factory: Optional[
            Callable[[], PlacementController]] = None,
        control_interval_s: Optional[float] = None,
        batch_refresh: Optional[BatchRefresh] = None,
    ):
        self.stages = stages
        self.dep = deployment
        self.topo = topo
        self.cost = cost or CostModel()
        self.start_window = start_window
        self.period = window_period_s
        self.strict = strict_capacity
        self.gate = gate
        self.quantized_sync = quantized_sync
        self.quant_min_size = quant_min_size
        self.qps = qps
        self.serve_slots = serve_slots
        self.query_trace = query_trace
        self.query_seed = query_seed
        self.fault_plane = fault_plane
        # the self-diagnosing half of fault tolerance (runtime.health): a
        # goldpinger-style heartbeat/monitor mesh over the topology's sites,
        # HMAC-signed model sync, the Byzantine sensor-value guard in the
        # injection path, and fault-rate-adaptive quarantine/staleness
        # thresholds (the constructor knobs below become the *base* values)
        self.health_plane = health_plane
        self.stage_costs = stage_costs
        self.staleness_bound = staleness_bound
        self.agg_timeout_s = (agg_timeout_s if agg_timeout_s is not None
                              else 0.25 * window_period_s)
        self.quarantine_after = quarantine_after
        self.max_resync = max_resync
        # the elastic placement plane: False (static), True/"proactive"
        # (reactive + forecast-ahead scaling), or "reactive".  A fresh
        # controller is built per run (``controller_factory`` for custom
        # thresholds) so repeated runs replay identically.
        self.elastic = elastic
        self.controller_factory = controller_factory
        self.control_interval_s = control_interval_s
        self.controller: Optional[PlacementController] = None
        # the cloud-side batch-model refresh plane (same contract as the
        # in-process fleet executor): archives gate-fired windows at the
        # training site, retrains batch models on its cadence
        self.batch_refresh = batch_refresh

    @property
    def _single_stages(self) -> PipelineStages:
        return self.stages.single

    @property
    def _serving_enabled(self) -> bool:
        return (self.qps > 0 or self.query_trace is not None) \
            and self.stages.serving is not None

    def _serving_site_name(self) -> str:
        """Where serving ticks run: an explicit ``serving`` placement when
        the deployment names one, else co-located with speed inference (the
        paper's edge serving role) — so serving contends for the same
        ``Site.workers`` pool as the inference chain."""
        try:
            return self.dep.site_of("serving")
        except KeyError:
            return self.dep.site_of("speed_inference")

    def _site(self, module: str):
        if module == "serving":
            return self.topo.sites[self._serving_site_name()]
        return super()._site(module)

    # -- per-run state -------------------------------------------------------

    def _reset(self, ids: List[StreamId]) -> None:
        self._init_runtime()
        self.ids = list(ids)
        self._fleet = FleetState()
        self._records: Dict[Tuple[StreamId, int], WindowRecord] = {}
        self._train_walls: Dict[Tuple[StreamId, int], float] = {}
        self._pending: Dict[Tuple[StreamId, int], Dict[str, Message]] = {}
        # per-stage aggregation: (kind, window) -> arrived stream messages;
        # kind in {"batch", "speed", "train"}
        self._pending_agg: Dict[Tuple[str, int], Dict[StreamId, Message]] = {}
        self._dispatched: set = set()
        self._flush_armed: set = set()
        self._quarantined: Dict[StreamId, int] = {}
        self._miss: Dict[StreamId, int] = {sid: 0 for sid in ids}
        self._last_model_pub: Dict[StreamId, Tuple[Dict[str, Any], float]] = {}
        self._resync_sent: Dict[Tuple[StreamId, int], int] = {}
        self._retrain_log: Dict[StreamId, List[bool]] = {
            sid: [] for sid in ids}
        self._inject_t: Dict[Tuple[StreamId, int], float] = {}
        self.e2e_s: Dict[StreamId, Dict[int, float]] = {sid: {} for sid in ids}
        self._ys: Dict[Tuple[StreamId, int], np.ndarray] = {}
        self._qplane: Optional[QueryPlane] = (
            QueryPlane(ids, self.serve_slots)
            if self._serving_enabled else None)
        self.queries: List[Any] = []
        self._query_lat: Dict[int, float] = {}
        self._tick_pending = False
        self._squant_bp: Dict[StreamId, Any] = {}
        # the elastic placement plane's per-run state: current per-stream
        # site (seeded from the deployment's static pins), the live topic
        # registrations per stream (so a migration can unsubscribe exactly
        # what it subscribed), realized migrations, and base worker counts
        # (restored after the run so one topology object is reusable)
        self._stream_site: Dict[StreamId, str] = dict(
            self.dep.stream_placement)
        self._stream_subs: Dict[StreamId, List[Tuple[str, str, Any]]] = {}
        self._migrations: List[Dict[str, Any]] = []
        self._base_workers: Dict[str, int] = {
            name: s.workers for name, s in self.topo.sites.items()}
        self._controller = None
        if self.elastic:
            if self.controller_factory is not None:
                self._controller = self.controller_factory()
            else:
                self._controller = PlacementController(
                    proactive=(self.elastic != "reactive"))
            self.controller = self._controller
        self._wire()

    def _module_site(self, module: str, sid: Optional[StreamId] = None) -> str:
        """Where ``module`` runs for stream ``sid``: the stream's current
        elastic placement when it has one and the module is per-stream
        migratable, else the deployment's static site."""
        if (sid is not None and module in STREAM_MODULES
                and sid in self._stream_site):
            return self._stream_site[sid]
        return self.dep.site_of(module, sid)

    def _subscribe_stream(self, sid: StreamId) -> None:
        """Register the stream's per-stream topic subscriptions at its
        *current* site (the elastic path's replacement for the one-wildcard-
        per-module wiring); remembers each registration so a migration can
        republish them elsewhere."""
        regs: List[Tuple[str, str, Any]] = []
        for base, module, fn in (
                (T_STREAM, "batch_inference", self._on_batch),
                (T_STREAM, "speed_inference", self._on_speed),
                (T_BATCH, "hybrid_inference", self._on_part),
                (T_SPEED, "hybrid_inference", self._on_part),
                (T_MODEL, "model_sync", self._on_model_sync)):
            topic = stream_topic(base, sid)
            site = self._module_site(module, sid)
            self.bus.subscribe(topic, site, fn)
            regs.append((topic, site, fn))
        self._stream_subs[sid] = regs

    def _wire(self) -> None:
        dep, bus = self.dep, self.bus
        sub = lambda base, module, fn: bus.subscribe(
            base + "/+", dep.site_of(module), fn)
        if self.elastic:
            # per-stream (exact-topic) subscriptions for the migratable
            # inference chain: delivery order per stream message is the same
            # as the wildcard path (batch, speed, then the wildcard subs
            # below), but each stream's handlers live at *its* site and can
            # be republished on migration
            for sid in self.ids:
                self._subscribe_stream(sid)
        else:
            sub(T_STREAM, "batch_inference", self._on_batch)
            sub(T_STREAM, "speed_inference", self._on_speed)
            sub(T_BATCH, "hybrid_inference", self._on_part)
            sub(T_SPEED, "hybrid_inference", self._on_part)
            sub(T_MODEL, "model_sync", self._on_model_sync)
        sub(T_STREAM, "speed_training", self._on_train)
        sub(T_STREAM, "data_sync", self._on_data_sync)
        sub(T_HYBRID, "archiving", self._on_archive)
        sub(T_HYBRID, "data_injection", self._on_user)
        # checksum-failure recovery: the sync site asks the training site to
        # re-publish a corrupted model
        sub(T_RESYNC, "speed_training", self._on_resync)
        if self._controller is not None:
            bus.subscribe(T_CTRL, self._ctrl_site_name(), self._on_ctrl_tick)
        if self.health_plane is not None:
            # the goldpinger mesh: every site monitors every other — each
            # site subscribes the heartbeat wildcard (deliveries from peers
            # ride the real links, so partitions and crashes cut them) and
            # its own exact-topic check beat (loopback publish from itself:
            # a down site's monitor goes silent, exactly like a down
            # goldpinger pod).  Handlers are pure bookkeeping — they never
            # occupy a pool worker, so the data plane is unperturbed.
            hp = self.health_plane
            for name in self.topo.sites:
                bus.subscribe(
                    T_HEALTH_HB + "/+", name,
                    lambda msg, obs=name: hp.observe_heartbeat(
                        obs, msg.payload["site"], msg.deliver_time))
                bus.subscribe(
                    stream_topic(T_HEALTH_CHECK, name), name,
                    lambda msg, obs=name: hp.check(obs, msg.deliver_time))
        if self._serving_enabled:
            # the request plane: stream windows feed the serving contexts,
            # request topics feed the admission queue, responses land back
            # at the user-facing injection site
            serve_site = self._serving_site_name()
            bus.subscribe(T_STREAM + "/+", serve_site, self._on_serve_ctx)
            bus.subscribe(T_REQUEST + "/+", serve_site, self._on_request)
            bus.subscribe(T_RESPONSE + "/+", dep.site_of("data_injection"),
                          self._on_response)

    # -- handlers ------------------------------------------------------------

    def _gather(self, kind: str, msg: Message
                ) -> Optional[Dict[StreamId, Message]]:
        """Collect window ``w``'s per-stream messages for one aggregated
        stage dispatch (``kind`` in batch/speed/train).  Returns the
        complete set — every *non-quarantined* stream arrived — else None.

        Under a fault plane, sensors lie: windows drop, duplicate, arrive
        late.  So (a) a delivery from a quarantined stream revives it, (b) a
        delivery for an already-dispatched (kind, window) is a late
        straggler and is dropped, and (c) the first delivery arms a flush
        timer (``agg_timeout_s``) so the fleet dispatches whoever showed up
        instead of waiting forever (see :meth:`_flush`)."""
        sid, w = msg.payload["stream"], msg.payload["window"]
        fp = self.fault_plane
        # the delivered window's y is ground truth for this (sid, w) from
        # here on — under record dropout it is shorter than the pre-stored
        # nominal y, and the preds must score against what actually arrived
        self._ys[(sid, w)] = msg.payload["y"]
        if sid in self._quarantined:
            del self._quarantined[sid]
            self._miss[sid] = 0
            if fp is not None:
                fp.note("quarantine_revived", self.kernel.now, sid)
        key = (kind, w)
        if key in self._dispatched:
            if fp is not None:
                fp.note("late_straggler_dropped", self.kernel.now,
                        f"{kind}:{sid}/w{w}")
            return None
        pend = self._pending_agg.setdefault(key, {})
        pend[sid] = msg
        self._miss[sid] = 0
        expected = [s for s in self.ids if s not in self._quarantined]
        if all(s in pend for s in expected):
            self._dispatched.add(key)
            return self._pending_agg.pop(key)
        if fp is not None and key not in self._flush_armed:
            self._flush_armed.add(key)
            self.kernel.after(self.agg_timeout_s,
                              lambda: self._flush(kind, w))
        return None

    def _flush(self, kind: str, w: int) -> None:
        """Aggregation timeout: dispatch the streams whose window arrived.
        Streams that missed ``quarantine_after`` consecutive *training*
        flushes are quarantined — later aggregations stop waiting for them,
        so one dead sensor cannot stall the fleet's one-dispatch window."""
        key = (kind, w)
        if key in self._dispatched:
            return
        self._dispatched.add(key)
        pend = self._pending_agg.pop(key, {})
        fp = self.fault_plane
        if fp is not None:
            fp.note("agg_flush", self.kernel.now,
                    f"{kind}/w{w}:{len(pend)}/{len(self.ids)}")
        hp = self.health_plane
        if kind == "train":
            for s in self.ids:
                if s in pend or s in self._quarantined:
                    continue
                self._miss[s] += 1
                if hp is not None:
                    # a missed training flush is a detected sensor fault:
                    # feed the stream's fault-rate estimate, then read the
                    # (possibly tightened) threshold back.  Calm pressure
                    # returns the base knob — static-run byte-identity.
                    hp.observe_fault("sensor", s, self.kernel.now)
                    q_after = hp.quarantine_after(s, self.kernel.now)
                else:
                    q_after = self.quarantine_after
                if self._miss[s] >= q_after:
                    self._quarantined[s] = w
                    if fp is not None:
                        fp.note("stream_quarantined", self.kernel.now,
                                f"{s}@w{w}")
        if not pend:
            return
        if kind == "train":
            self._dispatch_train(w, pend)
        else:
            self._dispatch_infer(kind, w, pend)

    def _on_batch(self, msg: Message) -> None:
        w = msg.payload["window"]
        if w < self.start_window:
            return
        pend = self._gather("batch", msg)
        if pend is not None:
            self._dispatch_infer("batch", w, pend)

    def _on_speed(self, msg: Message) -> None:
        w = msg.payload["window"]
        if w < self.start_window:
            return
        pend = self._gather("speed", msg)
        if pend is not None:
            self._dispatch_infer("speed", w, pend)

    def _dispatch_infer(self, kind: str, w: int,
                        pend: Dict[StreamId, Message]) -> None:
        # the window's arrived streams are at the inference site: one
        # aggregated vmapped dispatch, per-stream results fan back out
        sids = [s for s in self.ids if s in pend]
        if kind == "batch":
            stage, topic = self.stages.batch_inference, T_BATCH
            out = stage(fleet={
                sid: dict(batch_params=self._bp[sid],
                          x=pend[sid].payload["x"])
                for sid in sids})["fleet"]
        else:
            stage, topic = self.stages.speed_inference, T_SPEED
            out = stage(fleet={
                sid: dict(speed_params=self._fleet.state(sid).speed_params,
                          x=pend[sid].payload["x"],
                          fallback_params=self._bp[sid])
                for sid in sids})["fleet"]
        wall = out[sids[0]].wall_s
        module = "batch_inference" if kind == "batch" else "speed_inference"

        # fan the per-stream results back out from each stream's *current*
        # site: under elastic placement the one aggregated dispatch is
        # unchanged (aggregation happens above placement), but occupancy and
        # result publishing are accounted per placement group — each group
        # carries the shared aggregate wall, the same convention the fleet
        # stages use per stream.  A static run is a single group, identical
        # to the pre-elastic path.
        groups: Dict[str, List[StreamId]] = {}
        for sid in sids:
            groups.setdefault(self._module_site(module, sid), []).append(sid)
        for site_name, gsids in groups.items():
            comm = max(pend[s].deliver_time - pend[s].publish_time
                       for s in gsids) + self.cost.ingest_s

            def publish_preds(gsids=gsids, site_name=site_name):
                for sid in gsids:
                    o = out[sid]
                    self.bus.publish(
                        stream_topic(topic, sid),
                        {"stream": sid, "window": w, "kind": kind,
                         "pred": o["pred"], "wall_s": o.wall_s,
                         "fallback": o.values.get("fallback", False)},
                        _nbytes(o["pred"]), site_name)

            self._schedule(module, wall, comm, publish_preds,
                           site_name=site_name)

    def _on_part(self, msg: Message) -> None:
        sid, w = msg.payload["stream"], msg.payload["window"]
        parts = self._pending.setdefault((sid, w), {})
        parts[msg.payload["kind"]] = msg
        if len(parts) < 2:
            return
        st = self.stages.single
        state = self._fleet.state(sid)
        bmsg, smsg = parts["batch"], parts["speed"]
        comm = max(m.deliver_time - m.publish_time for m in parts.values())
        wsol = st.weight_solve(prev_preds=state.prev_preds,
                               prev_y=state.prev_y)
        t_w = (wsol.wall_s if st.weight_solve.is_dynamic
               and state.prev_preds is not None else 0.0)
        hc = st.hybrid_combine(
            pred_speed=smsg.payload["pred"], pred_batch=bmsg.payload["pred"],
            w_speed=wsol["w_speed"], w_batch=wsol["w_batch"])
        y = self._ys[(sid, w)]
        rec = WindowRecord(
            window=w,
            rmse_batch=rmse(y, bmsg.payload["pred"]),
            rmse_speed=rmse(y, smsg.payload["pred"]),
            rmse_hybrid=rmse(y, hc["pred"]),
            w_speed=wsol["w_speed"],
            w_batch=wsol["w_batch"],
            t_speed_train=self._train_walls.get((sid, w), 0.0),
            t_batch_infer=bmsg.payload["wall_s"],
            t_speed_infer=smsg.payload["wall_s"],
            t_hybrid_infer=hc.wall_s + t_w,
            t_weight_solve=t_w,
        )
        self._records[(sid, w)] = rec
        hy_site = self._module_site("hybrid_inference", sid)
        self._schedule(
            "hybrid_inference", wsol.wall_s + hc.wall_s, comm,
            lambda: self.bus.publish(
                stream_topic(T_HYBRID, sid),
                {"stream": sid, "window": w, "rmse_hybrid": rec.rmse_hybrid,
                 "w_speed": rec.w_speed},
                _nbytes(hc["pred"]), hy_site),
            site_name=hy_site)

    def _on_train(self, msg: Message) -> None:
        w = msg.payload["window"]
        pend = self._gather("train", msg)
        if pend is not None:
            self._dispatch_train(w, pend)

    def _dispatch_train(self, w: int, pend: Dict[StreamId, Message]) -> None:
        # the window's arrived streams are at the training site: one
        # drift-gated, stream-count-bucketed fleet dispatch
        comm = max(m.deliver_time - m.publish_time for m in pend.values())
        if not self._train_fits_site(comm):
            return
        train_ids = []
        for s in self.ids:
            if s not in pend:
                continue
            fire = _gate_decision(
                self.gate, s, pend[s].payload["y"],
                must=self._fleet.state(s).speed_params is None)
            self._retrain_log[s].append(fire)
            if fire:
                train_ids.append(s)
                if self.batch_refresh is not None:
                    self.batch_refresh.archive(
                        s, {"x": pend[s].payload["x"],
                            "y": pend[s].payload["y"]})
        self._maybe_refresh(w)
        if not train_ids:
            return
        out = self.stages.speed_training(
            fleet_data={s: {"x": pend[s].payload["x"],
                            "y": pend[s].payload["y"]} for s in train_ids},
            batch_params={s: self._bp[s] for s in train_ids},
            keys={s: self._keys[s][w] for s in train_ids})
        for s in train_ids:
            # the shared fleet dispatch's wall, charged only to the streams
            # that actually trained — a gate-skipped stream's window record
            # keeps t_speed_train = 0
            self._train_walls[(s, w)] = out["train_wall_s"]
            if (s, w) in self._records:
                self._records[(s, w)].t_speed_train = out["train_wall_s"]

        def publish_models():
            from repro.runtime.faults import tree_checksum

            pubs = [out["fleet"][s]["params"] for s in train_ids]
            if self.quantized_sync:
                # the publish boundary: the bucket's stacked fit output
                # materializes and quantizes in one batched pass
                # (``quantize_fleet`` — one device_get + one vectorized
                # int8 pass per stream bucket, not S per-stream chains),
                # the per-stream model topics carry the real int8 byte
                # counts, and the edge then serves the whole fleet through
                # the batched int8 kernel
                from repro.serving.quantize import quantize_fleet

                pubs = quantize_fleet(pubs, min_size=self.quant_min_size)
            hp = self.health_plane
            for s, params_pub in zip(train_ids, pubs):
                o = out["fleet"][s]
                payload = {"stream": s, "window": w, "params": params_pub,
                           "eval_preds": o["eval_preds"],
                           "eval_y": o["eval_y"],
                           "checksum": tree_checksum(params_pub)}
                if hp is not None and hp.sync_key is not None:
                    # authenticated sync: the crc32 above catches damage in
                    # transit, the HMAC catches tampering — a forger can
                    # recompute the checksum but not the keyed signature
                    from repro.runtime.health import sign_tree

                    payload["sig"] = sign_tree(params_pub, hp.sync_key)
                nbytes = _nbytes(params_pub)
                # keep the last publish so a corruption-triggered re-request
                # can re-send without retraining
                self._last_model_pub[s] = (payload, nbytes)
                self.bus.publish(stream_topic(T_MODEL, s), payload, nbytes,
                                 self.dep.site_of("speed_training"))

        self._schedule("speed_training", out.wall_s, comm, publish_models)

    def _maybe_refresh(self, w: int) -> None:
        """The training site's queued batch-model refresh: when due, one
        extra sharded fleet dispatch retrains the batch models of the
        streams with enough archived drifted windows.  The refreshed params
        install at the scheduled completion (virtual time) — the same
        convention as a model publish — and serve every later batch
        inference and Algorithm-1 weight solve."""
        rf = self.batch_refresh
        if rf is None or not rf.due(w) or not rf.ready():
            return
        out = rf(keys={s: self._rkeys[s][w] for s in self.ids})

        def install():
            for s, p in out["fleet"].items():
                self._bp[s] = p

        self._schedule("speed_training", out.wall_s, 0.0, install)

    def _on_model_sync(self, msg: Message) -> None:
        sid = msg.payload["stream"]
        state = self._fleet.state(sid)
        hp = self.health_plane
        # verify BEFORE the ordering guard: every corrupted delivery is
        # detected and counted, whether or not it would have installed
        out = self.stages.single.model_sync(
            params=msg.payload["params"],
            eval_preds=msg.payload["eval_preds"],
            eval_y=msg.payload["eval_y"],
            checksum=msg.payload.get("checksum"),
            signature=msg.payload.get("sig"),
            sig_key=hp.sync_key if hp is not None else None)
        if not out["ok"]:
            # checksum or signature mismatch — the transfer happened but a
            # corrupt/forged model is never served; ask the training site
            # to re-send (its cached publish carries a valid signature)
            self.ledger.add("model_sync", comp_s=0.0,
                            comm_s=msg.deliver_time - msg.publish_time)
            if hp is not None:
                hp.observe_fault("sync", sid, self.kernel.now)
            if out.values.get("forged") and self.fault_plane is not None:
                self.fault_plane.note("sync_sig_rejected", self.kernel.now,
                                      f"{sid}/w{msg.payload['window']}")
            self._request_resync(sid, msg.payload["window"])
            return
        if msg.payload["window"] <= state.window:
            # never install an older model over a newer one (out-of-order
            # publishes on a multi-worker training site)
            self.ledger.add("model_sync", comp_s=0.0,
                            comm_s=msg.deliver_time - msg.publish_time)
            return
        state.speed_params = out["speed_params"]
        state.prev_preds = out["prev_preds"]
        state.prev_y = out["prev_y"]
        state.window = msg.payload["window"]
        self._schedule("model_sync", out.wall_s,
                       msg.deliver_time - msg.publish_time,
                       site_name=self._module_site("model_sync", sid))

    def _request_resync(self, sid: StreamId, w: int) -> None:
        sent = self._resync_sent.get((sid, w), 0)
        if sent >= self.max_resync:
            if self.fault_plane is not None:
                self.fault_plane.note("resync_gave_up", self.kernel.now,
                                      f"{sid}/w{w}")
            return
        self._resync_sent[(sid, w)] = sent + 1
        self.bus.publish(stream_topic(T_RESYNC, sid),
                         {"stream": sid, "window": w}, 64.0,
                         self._module_site("model_sync", sid))

    def _on_resync(self, msg: Message) -> None:
        cached = self._last_model_pub.get(msg.payload["stream"])
        if cached is None:
            return
        payload, nbytes = cached
        if payload["window"] < msg.payload["window"]:
            return
        self.bus.publish(stream_topic(T_MODEL, payload["stream"]), payload,
                         nbytes, self.dep.site_of("speed_training"))

    def _on_site_restart(self, site_name: str) -> None:
        """Cold restart after a crash: the worker pool forgets its queue (a
        restarted box has no backlog), and if the model-sync module lived
        there its installed serving state is gone — every stream falls back
        to the batch model until the next sync lands."""
        self._free.pop(site_name, None)
        for sid in self.ids:
            if self._module_site("model_sync", sid) != site_name:
                continue
            st = self._fleet.state(sid)
            st.speed_params = None
            st.prev_preds = None
            st.prev_y = None
            st.window = -1

    def _on_user(self, msg: Message) -> None:
        sid, w = msg.payload["stream"], msg.payload["window"]
        if (sid, w) in self._inject_t:
            self.e2e_s[sid][w] = msg.deliver_time - self._inject_t[(sid, w)]

    # -- the elastic placement plane -----------------------------------------

    def _ctrl_site_name(self) -> str:
        """Where the placement controller runs: the training site — the one
        place with a fleet-global view (and, under the integrated
        deployment, the cloud)."""
        return self.dep.site_of("speed_training")

    def _drift_hotness(self, sid: StreamId, recent: int = 4) -> float:
        """Fraction of the stream's recent training windows the DriftGate
        actually retrained.  Without a gate there is no drift *signal* — the
        fleet retrains unconditionally — so hotness is 0, not 1: migration
        then keys off queue depth alone."""
        if self.gate is None:
            return 0.0
        log = self._retrain_log.get(sid, [])[-recent:]
        return float(np.mean(log)) if log else 0.0

    def _serving_queue_s(self) -> Dict[StreamId, float]:
        """Seconds of serving work queued in the request plane, per stream:
        each submitted-but-unadmitted query costs one slot-share of a
        serving tick's wall (the last measured/fixed tick).  This is the
        queue the site worker pool cannot see — the request plane admits at
        tick boundaries (one tick in flight), so a saturated serving site
        piles its backlog up *here* first, not in the pool."""
        out: Dict[StreamId, float] = {sid: 0.0 for sid in self.ids}
        if not self._serving_enabled:
            return out
        walls = self.ledger.comp.get("serving", [])
        per_q = (walls[-1] if walls else 0.0) / max(self.serve_slots, 1)
        for q in self._qplane.sched.queue:
            out[q.stream] = out.get(q.stream, 0.0) + per_q
        return out

    def _on_ctrl_tick(self, msg: Message) -> None:
        """One control interval: snapshot site/stream signals, run the
        controller policy, apply worker scaling and migrations.  Controller
        compute is accounted straight to the ledger (``stage_costs`` can fix
        it for byte-identical replay) without occupying a pool worker — the
        control plane must not perturb the data plane it is observing."""
        ctl = self._controller
        if ctl is None:
            return
        t = self.kernel.now
        qdepth = self._serving_queue_s()
        serve_site = (self._serving_site_name() if self._serving_enabled
                      else None)
        sites = [SiteSignal(name=s.name, kind=s.kind, workers=s.workers,
                            base_workers=self._base_workers[s.name],
                            backlog_s=self._backlog_s(s.name)
                            + (sum(qdepth.values())
                               if s.name == serve_site else 0.0))
                 for s in self.topo.sites.values()]
        for s in sites:
            self.ledger.sample_depth(s.name, t, s.backlog_s)
        streams = []
        for sid in self.ids:
            site = self._module_site("speed_inference", sid)
            streams.append(StreamSignal(
                sid=sid, site=site, drift_hot=self._drift_hotness(sid),
                queue_s=self._backlog_s(site) + qdepth[sid]))
        t0 = time.perf_counter()
        dec = ctl.step(t, sites, streams)
        wall = time.perf_counter() - t0
        sc = self.stage_costs or {}
        self.ledger.add("placement_controller",
                        comp_s=sc.get("placement_controller", wall))
        for name, workers in dec.workers.items():
            self.topo.sites[name].workers = workers
        for sid, target in dec.migrations.items():
            self._migrate(sid, target, t)

    def _migrate(self, sid: StreamId, target: str, t: float) -> None:
        """Move one stream's inference chain to ``target``: republish its
        per-stream topic subscriptions at the new site and hand its
        device-resident state across (``FleetState.handoff`` materializes
        the lazy bucket-resident params view into bytes the new site owns;
        the transfer rides the inter-site link in the ledger).  In-flight
        messages matched before the move still run their handler — nothing
        is dropped; new publishes route to the new site."""
        old = self._module_site("speed_inference", sid)
        if target == old:
            return
        nbytes = self._fleet.handoff(sid)
        for topic, site, fn in self._stream_subs.get(sid, []):
            self.bus.unsubscribe(topic, site, fn)
        self._stream_site[sid] = target
        self._subscribe_stream(sid)
        self.ledger.add("placement_migration", comp_s=0.0,
                        comm_s=self.topo.link(old, target)
                        .transfer_time(nbytes))
        self._migrations.append({"t": t, "sid": sid, "from": old,
                                 "to": target, "state_nbytes": nbytes})

    # -- the request plane ---------------------------------------------------

    def _serving_fallback(self, sid: StreamId) -> Params:
        """What a stream serves before its first model sync: the batch
        model — quantized once (and cached) under int8 sync, so the fleet's
        stacked serving tree stays structurally homogeneous whatever mix of
        synced/unsynced streams a tick catches."""
        if not self.quantized_sync:
            return self._bp[sid]
        p = self._squant_bp.get(sid)
        if p is None:
            from repro.serving.quantize import quantize_tree

            p = self._squant_bp[sid] = quantize_tree(
                self._bp[sid], min_size=self.quant_min_size)
        return p

    def _serving_params(self) -> Tuple[List[Params], Dict[StreamId, int],
                                       Dict[StreamId, bool]]:
        """The device-resident serving set, read in fleet order with zero
        host round-trip: each stream's installed speed model (a lazy
        ``FleetParamView`` handle into the stacked fit output under float
        sync, an int8 ``QTensor`` tree under quantized sync) or its batch
        fallback, plus the training window each model came from — the
        staleness stamp every answer carries.

        The staleness watchdog enforces the bound the request plane used to
        merely observe: a stream whose installed model lags its freshest
        context window by more than ``staleness_bound`` training windows
        (sync delayed by a partition, publishes dropped, training site
        down) serves the batch-model fallback instead of an ever-staler
        speed model.  The returned fallback map stamps the answers."""
        params: List[Params] = []
        windows: Dict[StreamId, int] = {}
        fallback: Dict[StreamId, bool] = {}
        hp = self.health_plane
        for sid in self.ids:
            st = self._fleet.state(sid)
            ctxw = self._qplane.context_window(sid)
            # under a health plane the watchdog bound adapts: link suspicion
            # or sync rejections tighten it toward the floor, so serving
            # flips to the fallback sooner exactly when fresh models are
            # least likely to arrive.  Calm pressure returns the base knob.
            bound = (hp.staleness_bound(sid, self.kernel.now)
                     if hp is not None else self.staleness_bound)
            stale = (st.window >= 0 and ctxw - st.window > bound)
            use_fb = st.speed_params is None or stale
            if stale and self.fault_plane is not None:
                self.fault_plane.note(
                    "staleness_fallback", self.kernel.now,
                    f"{sid}:ctx w{ctxw} vs model w{st.window}")
            params.append(self._serving_fallback(sid) if use_fb
                          else st.speed_params)
            windows[sid] = st.window
            fallback[sid] = use_fb
        return params, windows, fallback

    def _on_serve_ctx(self, msg: Message) -> None:
        self._qplane.observe_window(
            msg.payload["stream"], msg.payload["x"], msg.payload["window"])
        self._maybe_tick()

    def _on_request(self, msg: Message) -> None:
        q = msg.payload["query"]
        self._qplane.submit(q)
        self.queries.append(q)
        self._maybe_tick()

    def _on_response(self, msg: Message) -> None:
        q = msg.payload["query"]
        self._query_lat[q.uid] = msg.deliver_time - q.arrived_at

    def _maybe_tick(self) -> None:
        """Start a serving tick unless one is already in flight (slots stay
        occupied until the running tick's virtual completion — the
        continuous-batching invariant: admit/retire happen at tick
        boundaries, never mid-dispatch)."""
        if not self._serving_enabled or self._tick_pending:
            return
        plane = self._qplane
        plane.admit(self.kernel.now)
        batch = plane.build_batch()
        if batch is None:
            return
        by_stream, xs = batch
        self._tick_pending = True
        params_seq, model_windows, fallback = self._serving_params()
        out = self.stages.serving(params_seq=params_seq, xs=xs)
        plane.apply(by_stream, out["preds"], model_windows,
                    fallback=fallback)
        serve_site = self._serving_site_name()

        def finish():
            self._tick_pending = False
            for q in plane.retire(self.kernel.now):
                self.bus.publish(
                    stream_topic(T_RESPONSE, q.stream),
                    {"stream": q.stream, "query": q},
                    _nbytes(np.asarray(q.answer, np.float32)), serve_site)
            self._maybe_tick()

        self._schedule("serving", out.wall_s, 0.0, finish)

    # -- driver --------------------------------------------------------------

    def _warmup(self, streams: Dict[StreamId, WindowedStream]) -> None:
        """Compile every jit path once (the full-fleet train bucket and the
        aggregated inference dispatches — with int8 sync on, also the
        QTensor-structured fleet predict), so measured windows are
        steady-state windows.  Runs outside the event loop; the drift gate
        never sees it, and the dispatch counter is snapshotted after it."""
        data = {sid: streams[sid].supervised(0) for sid in self.ids}
        tr = self.stages.speed_training(
            fleet_data=data, batch_params=self._bp,
            keys={sid: self._keys[sid][0] for sid in self.ids})
        if all(len(data[sid]["x"]) > 0 for sid in self.ids):
            self.stages.batch_inference(fleet={
                sid: dict(batch_params=self._bp[sid], x=data[sid]["x"])
                for sid in self.ids})
            sp_list = [tr["fleet"][sid]["params"] for sid in self.ids]
            if self.quantized_sync:
                from repro.serving.quantize import quantize_fleet

                sp_list = quantize_fleet(sp_list,
                                         min_size=self.quant_min_size)
            sp = dict(zip(self.ids, sp_list))
            self.stages.speed_inference(fleet={
                sid: dict(speed_params=sp[sid], x=data[sid]["x"],
                          fallback_params=self._bp[sid])
                for sid in self.ids})

    def _warmup_serving(self, streams: Dict[StreamId, WindowedStream]) -> None:
        """Pre-compile the serving tick's row buckets (1..slots, pow2) so
        measured ticks never swallow an XLA trace: a tick batches at most
        ``serve_slots`` rows per stream, and the zero-row streams ride the
        same (stream bucket, shape bucket) executable.  Counters are
        snapshotted after this, like the training warmup."""
        ref = None
        for sid in self.ids:
            x = np.asarray(streams[sid].supervised(0)["x"])
            if len(x) > 0:
                ref = np.asarray(x[-1])
                break
        if ref is None:
            return
        params_seq = [self._serving_fallback(sid) for sid in self.ids]
        k = 1
        while k <= max(self.serve_slots, 1):
            xs = [np.repeat(ref[None], k, axis=0)] + [
                np.zeros((0,) + ref.shape, ref.dtype)
                for _ in range(len(self.ids) - 1)]
            self.stages.serving(params_seq=params_seq, xs=xs)
            k *= 2

    def run(self, streams: Dict[StreamId, WindowedStream], batch_params: Any,
            key, n_windows: Optional[int] = None) -> FleetBusRunResult:
        from repro.streams.injection import BusInjector

        ids = list(streams)
        fp = self.fault_plane
        if fp is not None:
            # rewind the plane so repeated runs under one seed replay the
            # identical fault schedule, then wire it into the run
            fp.reset()
            fp.on_restart(self._on_site_restart)
        self._reset(ids)
        if fp is not None:
            fp.install(self.kernel)
        hp = self.health_plane
        if hp is not None:
            # rewind like the fault plane (byte-identical reruns), then
            # wire this run's topology, cadence, base thresholds and the
            # seed-derived signing key
            hp.reset()
            hp.bind(sites=list(self.topo.sites),
                    hb_interval_s=hp.cfg.hb_interval_s or 0.5 * self.period,
                    halflife_s=hp.cfg.rate_halflife_s or 2.0 * self.period,
                    quarantine_after=self.quarantine_after,
                    staleness_bound=self.staleness_bound,
                    sync_seed=fp.seed if fp is not None else 0)
        n = min(len(s) for s in streams.values())
        if n_windows is not None:
            n = min(n, n_windows)
        self._bp = resolve_fleet_params(batch_params, ids)
        self._keys = fleet_key_chains(key, ids, n)
        if self.batch_refresh is not None:
            self.batch_refresh.reset()
            self._rkeys = refresh_key_chains(key, ids, n)
        ms = self.stages.single.model_sync
        rejected0, verified0 = ms.corrupt_rejected, ms.verified
        forged0 = ms.forged_rejected
        self._warmup(streams)
        trace: List[Any] = []
        if self._serving_enabled:
            self._warmup_serving(streams)
            trace = self.query_trace
            if trace is None:
                # open-loop load for the whole run past the first window
                # (serving needs a context, so arrivals start at period)
                n_req = max(1, int(round(self.qps * self.period
                                         * max(n - 1, 1))))
                trace = open_loop_trace(ids, self.qps, n_req,
                                        start=self.period,
                                        seed=self.query_seed)
            inj_site = self.dep.site_of("data_injection")
            for q in trace:
                self.kernel.at(q.arrived_at, lambda q=q: self.bus.publish(
                    stream_topic(T_REQUEST, q.stream),
                    {"stream": q.stream, "query": q}, 256.0, inj_site))
        fc = self.stages.speed_training.forecaster
        dispatches0 = fc.train_dispatches
        srv = self.stages.serving
        ticks0 = srv.ticks if srv is not None else 0
        sdisp0 = srv.dispatches if srv is not None else 0
        bi, si = self.stages.batch_inference, self.stages.speed_inference
        infer0 = {"batch": (bi.ticks, bi.dispatches),
                  "speed": (si.ticks, si.dispatches)}

        if self._controller is not None:
            # the control-plane beat: a periodic ctrl/tick publish the
            # controller subscribes to at its site (loopback delivery), for
            # the duration of the run
            interval = self.control_interval_s or 0.5 * self.period
            ctrl_site = self._ctrl_site_name()
            k = 1
            while k * interval <= n * self.period + interval:
                self.kernel.at(
                    k * interval,
                    lambda k=k: self.bus.publish(
                        T_CTRL, {"tick": k}, 64.0, ctrl_site))
                k += 1

        if hp is not None:
            # the health-plane beats: every site publishes heartbeats on
            # health/hb/<site> (cross-site deliveries ride the real links —
            # a partition or crash silences them), and its own loopback
            # check beat half an interval later, when every healthy peer's
            # heartbeat has had time to arrive.  Publishes from a down site
            # are lost by the fault plane, so a dead site's monitor goes
            # quiet with it.
            hb = hp.cfg.hb_interval_s or 0.5 * self.period
            horizon = n * self.period + hb
            for name in self.topo.sites:
                k = 1
                while k * hb <= horizon:
                    self.kernel.at(
                        k * hb,
                        lambda name=name, k=k: self.bus.publish(
                            stream_topic(T_HEALTH_HB, name),
                            {"site": name, "k": k}, 32.0, name))
                    self.kernel.at(
                        (k + 0.5) * hb,
                        lambda name=name: self.bus.publish(
                            stream_topic(T_HEALTH_CHECK, name), {},
                            32.0, name))
                    k += 1

        for sid in ids:
            injector = BusInjector(self.kernel, self.bus, T_STREAM,
                                   self.dep.site_of("data_injection"),
                                   period_s=self.period, stream_id=sid,
                                   fault_plane=fp, health_plane=hp)
            for w in range(n):
                data = streams[sid].supervised(w)
                self._ys[(sid, w)] = data["y"]
                self._inject_t[(sid, w)] = injector.schedule_window(w, data)
        self.kernel.run()

        serving_stats = None
        if self._serving_enabled and trace:
            lat = self._query_lat
            answered = [q for q in trace if q.uid in lat]
            arr = [q.arrived_at for q in trace]
            offered = ((len(trace) - 1) / (max(arr) - min(arr))
                       if len(trace) > 1 and max(arr) > min(arr)
                       else float("inf"))
            if answered:
                span = (max(q.arrived_at + lat[q.uid] for q in answered)
                        - min(arr))
                sustained = (len(answered) / span if span > 0
                             else float("inf"))
            else:
                sustained = 0.0
            ticks = srv.ticks - ticks0
            sdisp = srv.dispatches - sdisp0
            staleness = [q.context_window - q.model_window for q in answered
                         if not q.served_fallback and q.model_window >= 0
                         and q.context_window >= 0]
            serving_stats = {
                "n_requests": len(trace),
                "n_answered": len(answered),
                "n_starved": len(trace) - len(answered),
                "ticks": ticks,
                "dispatches": sdisp,
                "dispatches_per_tick": (sdisp / ticks if ticks
                                        else float("nan")),
                "offered_qps": offered,
                "sustained_qps": sustained,
                "slots": self.serve_slots,
                # the watchdog's envelope: how often serving fell back to
                # the batch model, and the worst model lag actually served
                # from a speed model (fallback answers excluded — they are
                # the bound *working*)
                "fallback_frac": (sum(q.served_fallback for q in answered)
                                  / len(answered) if answered else 0.0),
                "max_staleness": max(staleness, default=0),
                **latency_stats([lat[q.uid] for q in answered]),
            }

        results = {}
        for sid in ids:
            recs = [self._records[(s, w)]
                    for (s, w) in sorted(self._records) if s == sid]
            results[sid] = HybridRunResult(records=recs,
                                           mode=str(self.stages.mode))

        placement = None
        if self._controller is not None:
            # report the realized worker history, then restore the base
            # counts so one Topology object can host the next run unchanged
            final_workers = {name: s.workers
                             for name, s in self.topo.sites.items()}
            for name, wk in self._base_workers.items():
                self.topo.sites[name].workers = wk
            placement = {
                "mode": ("reactive" if self.elastic == "reactive"
                         else "proactive"),
                "control_interval_s": (self.control_interval_s
                                       or 0.5 * self.period),
                "controller": self._controller.stats(),
                "migrations": list(self._migrations),
                "stream_site": {
                    sid: self._module_site("speed_inference", sid)
                    for sid in ids},
                "base_workers": dict(self._base_workers),
                "final_workers": final_workers,
            }
        from repro.training.compiled import materialize_params
        final_params = {}
        for sid in ids:
            p = self._fleet.state(sid).speed_params
            final_params[sid] = (materialize_params(p) if p is not None
                                 else None)
        infer_dispatches = {
            kind: {"ticks": st.ticks - infer0[kind][0],
                   "dispatches": st.dispatches - infer0[kind][1]}
            for kind, st in (("batch", bi), ("speed", si))}
        chaos = None
        if fp is not None:
            chaos = {
                "fault_stats": dict(fp.stats),
                "n_fault_events": len(fp.events),
                "dead_letters": len(self.bus.dead_letters),
                "quarantined": dict(self._quarantined),
                "corrupt_rejected": ms.corrupt_rejected - rejected0,
                "checksum_verified": ms.verified - verified0,
                "forged_rejected": ms.forged_rejected - forged0,
                "resync_requests": sum(self._resync_sent.values()),
            }
        rf = self.batch_refresh
        return FleetBusRunResult(
            results=results,
            # refresh dispatches share the forecaster counter; reported
            # under ``refresh`` so this stays speed-training-only
            train_dispatches=(fc.train_dispatches - dispatches0
                              - (rf.dispatches if rf is not None else 0)),
            retrain_log={sid: list(log)
                         for sid, log in self._retrain_log.items()},
            gate_stats=self.gate.stats() if self.gate is not None else None,
            n_windows=n,
            mode=str(self.stages.mode),
            refresh=(None if rf is None else {
                "rounds": rf.rounds,
                "dispatches": rf.dispatches,
                "refreshed": dict(rf.refreshed),
                "train_wall_s": rf.train_wall_s,
            }),
            ledger=self.ledger,
            failures=self.failures,
            e2e_s={sid: dict(per) for sid, per in self.e2e_s.items()},
            message_log=self.bus.log,
            queries=list(self.queries),
            serving=serving_stats,
            dead_letters=list(self.bus.dead_letters),
            chaos=chaos,
            placement=placement,
            infer_dispatches=infer_dispatches,
            final_params=final_params,
            health=hp.summary() if hp is not None else None,
        )
