from repro.serving.batching import BatchScheduler, Request, Slot  # noqa: F401
from repro.serving.engine import Engine, ServeStats, greedy_sample  # noqa: F401
