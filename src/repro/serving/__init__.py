from repro.serving.batching import BatchScheduler, Request, Slot  # noqa: F401
from repro.serving.engine import Engine, ServeStats, greedy_sample  # noqa: F401
from repro.serving.query_plane import (  # noqa: F401
    ForecastQuery,
    QueryPlane,
    answer_query_unbatched,
    latency_stats,
    open_loop_trace,
)
