"""Int8 weight quantization for edge inference.

The paper deploys its edge models through TFLite on the Raspberry Pi — the
production reason that works is quantization.  This module is the JAX analog:
symmetric per-output-channel int8 weight quantization with dequantizing
matmul, applied to a params pytree (2-D+ floating leaves; norms, biases and
tiny leaves stay in float).

    qparams = quantize_tree(params)           # ~4x smaller checkpoints
    params8 = dequantize_tree(qparams)        # back to float for the model
    y = int8_matmul(x, qp)                    # fused dequant matmul

Quantized checkpoints also shrink the paper's per-window model-sync transfer
(model_nbytes) by ~4x.  ``QTensor`` is registered as a JAX pytree, so a
quantized params tree flows through ``jax.jit``, ``tree_map`` and the
executors' real byte-count accounting unchanged: the ``BusExecutor``'s
int8 sync path (``quantized_sync=True``) publishes ``quantize_tree`` output
on the model topic and the measured transfer size is the int8 size, while
``repro.models.lstm.forward`` detects QTensor leaves and dispatches the
fused ``int8_matmul`` kernel for edge inference.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Any

# leaves smaller than this stay float (norm gains, biases, scalars)
MIN_QUANT_SIZE = 1024


@dataclass(frozen=True)
class QTensor:
    """Symmetric per-channel int8 tensor: w ~ q * scale (last dim = out).

    Registered as a pytree node (children: ``q``, ``scale``; static aux:
    ``orig_dtype``), so quantized trees jit, tree_map and byte-count like any
    other params pytree."""

    q: jax.Array  # int8, same shape as the original
    scale: jax.Array  # f32, shape = original.shape[-1:]
    orig_dtype: str

    @property
    def nbytes(self) -> int:
        return int(self.q.size) + int(self.scale.size) * 4


jax.tree_util.register_pytree_node(
    QTensor,
    lambda qt: ((qt.q, qt.scale), qt.orig_dtype),
    lambda aux, ch: QTensor(q=ch[0], scale=ch[1], orig_dtype=aux),
)


def quantize(w: jax.Array) -> QTensor:
    wf = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=tuple(range(w.ndim - 1)), keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(wf / scale), -127, 127).astype(jnp.int8)
    return QTensor(q=q, scale=scale[..., 0, :] if w.ndim > 1 else scale,
                   orig_dtype=str(w.dtype))


def dequantize(qt: QTensor) -> jax.Array:
    scale = qt.scale
    while scale.ndim < qt.q.ndim:
        scale = scale[None]
    return (qt.q.astype(jnp.float32) * scale).astype(jnp.dtype(qt.orig_dtype))


def int8_matmul(x: jax.Array, qt: QTensor) -> jax.Array:
    """x @ dequant(w) with the scale applied after the integer-side matmul
    (one multiply per output column instead of per weight)."""
    acc = jnp.einsum("...i,io->...o", x.astype(jnp.float32),
                     qt.q.astype(jnp.float32))
    return (acc * qt.scale.reshape((1,) * (acc.ndim - 1) + (-1,))).astype(x.dtype)


def _is_quantizable(x, min_size: int = MIN_QUANT_SIZE) -> bool:
    return (
        hasattr(x, "dtype")
        and jnp.issubdtype(x.dtype, jnp.floating)
        and x.ndim >= 2
        and x.size >= min_size
    )


def quantize_tree(params: Params, min_size: int = MIN_QUANT_SIZE) -> Params:
    """Quantize every floating matrix leaf of at least ``min_size`` elements;
    smaller leaves (and all 1-D leaves: biases, norm gains) pass through in
    float.  The default threshold suits LLM-scale trees; the speed-layer sync
    path lowers it so the paper's tiny LSTM (10,981 params) quantizes too."""
    return jax.tree_util.tree_map(
        lambda x: quantize(x) if _is_quantizable(x, min_size) else x, params
    )


def quantize_fleet(params_seq, min_size: int = MIN_QUANT_SIZE) -> list:
    """Per-stream int8 quantization of a whole fleet's params, batched per
    stream bucket.

    The fleet sync boundary used to pay S separate ``quantize_tree`` calls
    — each one materializing its stream's params and dispatching per-leaf
    device work.  :class:`FleetParamView` handles are grouped by their
    stacked fit output and each group quantizes in one vectorized pass
    over its stacked host tree (itself one ``device_get``); every stream's
    ``QTensor`` leaves are numpy views sliced from the stacked result —
    bitwise the same q/scale as per-stream ``quantize``.  Plain trees fall
    back to per-stream ``quantize_tree``."""
    seq = list(params_seq)
    from repro.training.compiled import FleetParamView

    out: list = [None] * len(seq)
    groups: Dict[int, Tuple[Any, list]] = {}
    for i, p in enumerate(seq):
        if isinstance(p, FleetParamView):
            groups.setdefault(id(p.owner), (p.owner, []))[1].append(i)
        else:
            out[i] = quantize_tree(p, min_size)
    for owner, idxs in groups.values():
        leaves, treedef = jax.tree_util.tree_flatten(owner.host())
        staged = []
        for x in leaves:
            # quantizability is a *per-stream* property: skip the stream axis
            if not _is_quantizable(x[0], min_size):
                staged.append((None, x))
                continue
            wf = np.asarray(x, np.float32)
            amax = np.max(np.abs(wf), axis=tuple(range(1, wf.ndim - 1)),
                          keepdims=True)
            scale = np.maximum(amax, np.float32(1e-12)) / np.float32(127.0)
            q = np.clip(np.round(wf / scale), -127, 127).astype(np.int8)
            staged.append((q, scale))
        for i in idxs:
            j = seq[i].slot
            per = []
            for (q, payload), x in zip(staged, leaves):
                if q is None:
                    per.append(payload[j])
                else:
                    per.append(QTensor(q=q[j], scale=payload[j][..., 0, :],
                                       orig_dtype=str(x.dtype)))
            out[i] = jax.tree_util.tree_unflatten(treedef, per)
    return out


def dequantize_tree(qparams: Params) -> Params:
    return jax.tree_util.tree_map(
        lambda x: dequantize(x) if isinstance(x, QTensor) else x,
        qparams,
        is_leaf=lambda x: isinstance(x, QTensor),
    )


def tree_nbytes(params: Params) -> int:
    total = 0
    for x in jax.tree_util.tree_leaves(
        params, is_leaf=lambda x: isinstance(x, QTensor)
    ):
        if isinstance(x, QTensor):
            total += x.nbytes
        else:
            total += int(np.asarray(x).nbytes)
    return total


def quantization_error(params: Params) -> Dict[str, float]:
    """Max relative error per quantized leaf (diagnostics)."""
    out = {}

    def visit(path, x):
        if _is_quantizable(x):
            qt = quantize(x)
            back = dequantize(qt).astype(jnp.float32)
            denom = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-12)
            key = "/".join(str(getattr(p, "key", p)) for p in path)
            out[key] = float(jnp.max(jnp.abs(back - x.astype(jnp.float32))) / denom)
        return x

    jax.tree_util.tree_map_with_path(visit, params)
    return out
