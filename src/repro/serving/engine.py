"""Serving engine: jit'd prefill + decode over the model zoo with a shared
KV cache, plus a simple generate() loop and a continuous-batching driver.

``prefill_step`` / ``decode_step`` are exactly the functions the multi-pod
dry-run lowers for the prefill_32k / decode_32k / long_500k shapes.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import Model, get_model
from repro.serving.batching import BatchScheduler, Request

Params = Any


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(logits: jax.Array, key: jax.Array, temp: float = 1.0):
    return jax.random.categorical(key, logits / max(temp, 1e-6)).astype(jnp.int32)


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens_out: int = 0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / self.decode_s if self.decode_s > 0 else 0.0


class Engine:
    """Single-model serving engine (the paper's edge-inference role)."""

    def __init__(self, cfg: ModelConfig, params: Params, max_len: int = 2048):
        self.cfg = cfg
        self.model = get_model(cfg)
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, max_len)
        )
        self._decode = jax.jit(self.model.decode_step)
        self._batch_axes: Any = None

    def generate(
        self,
        prompts: np.ndarray,  # (B, S) int32
        max_new_tokens: int,
        prefix_embed: Optional[np.ndarray] = None,
        greedy: bool = True,
        key: Optional[jax.Array] = None,
    ) -> Tuple[np.ndarray, ServeStats]:
        stats = ServeStats()
        B, S = prompts.shape
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        n_prefix = 0
        if prefix_embed is not None:
            batch["prefix_embed"] = jnp.asarray(prefix_embed)
            if self.cfg.family == "vlm":
                n_prefix = self.cfg.frontend.n_prefix_tokens
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch)
        logits = jax.block_until_ready(logits)
        stats.prefill_s = time.perf_counter() - t0

        key = key if key is not None else jax.random.PRNGKey(0)
        tok = greedy_sample(logits) if greedy else temperature_sample(logits, key)
        out = [np.asarray(tok)]
        pos = jnp.full((B,), S + n_prefix, jnp.int32)
        t0 = time.perf_counter()
        for i in range(max_new_tokens - 1):
            logits, cache = self._decode(
                self.params, {"token": tok[:, None], "pos": pos + i}, cache
            )
            if greedy:
                tok = greedy_sample(logits)
            else:
                key, sub = jax.random.split(key)
                tok = temperature_sample(logits, sub)
            out.append(np.asarray(tok))
        jax.block_until_ready(tok)
        stats.decode_s = time.perf_counter() - t0
        stats.tokens_out = B * max_new_tokens
        return np.stack(out, axis=1), stats

    # -- continuous batching ------------------------------------------------

    def _cache_batch_axes(self, n_slots: int) -> Any:
        """Per-leaf batch axis of the KV-cache pytree, probed once from
        ``init_cache`` shape structure (the axis whose extent changes with
        the batch size) — so the slot scatter works over any model family's
        cache layout without hard-coding it."""
        if self._batch_axes is None:
            if self.model.init_cache is None:
                raise ValueError(
                    f"{self.cfg.family} model exposes no init_cache; "
                    "serve() needs one to recycle batch slots")
            a = jax.eval_shape(
                lambda: self.model.init_cache(n_slots, self.max_len))
            b = jax.eval_shape(
                lambda: self.model.init_cache(n_slots + 1, self.max_len))

            def axis(sa, sb):
                for i, (x, y) in enumerate(zip(sa.shape, sb.shape)):
                    if x != y:
                        return i
                raise ValueError(
                    f"cache leaf {sa.shape} has no batch axis")

            self._batch_axes = jax.tree_util.tree_map(axis, a, b)
        return self._batch_axes

    @staticmethod
    def _scatter_slots(cache: Any, new_cache: Any, axes: Any,
                       ids: np.ndarray) -> Any:
        """Overwrite the admitted slots' rows of the persistent cache with
        the fresh prefill's rows, leaving every other slot's decode state
        untouched."""
        idx = jnp.asarray(ids)

        def put(c, n, ax):
            sel = (slice(None),) * ax + (idx,)
            return c.at[sel].set(n[sel])

        return jax.tree_util.tree_map(put, cache, new_cache, axes)

    def serve(self, requests: List[Request], n_slots: int = 4,
              pad_id: int = 0) -> List[Request]:
        """Slot-recycling continuous batching: admit into free slots every
        tick, one batched decode dispatch per tick, retire and refill
        without draining a wave.

        Each tick: (1) queued requests FIFO-admit into free slots — their
        prompts left-pad to a pow2-bucketed length and prefill at the fixed
        ``(n_slots, Lb)`` shape (non-admitted rows carry pads), the fresh
        cache rows scattering into the persistent shared cache so live
        slots' decode state is untouched; (2) one ``(n_slots, 1)`` decode
        dispatch advances *every* active slot — per-slot ``pos`` carries
        each request's own position, so requests admitted at different
        ticks interleave in the same batch; (3) finished requests retire
        immediately and their slots refill next tick.  A short request
        therefore never waits for a long co-batched one (the wave-batching
        failure mode this replaces), and steady-state cost is one decode
        dispatch per tick regardless of arrival pattern.  The tick index is
        the clock threaded into ``admitted_at``/``finished_at``."""
        sched = BatchScheduler(n_slots)
        for r in requests:
            sched.submit(r)
        finished: List[Request] = []
        cache: Any = None
        axes: Any = None
        cur_tok = np.full((n_slots,), pad_id, np.int32)
        pos = np.zeros((n_slots,), np.int32)
        tick = 0
        while not sched.idle:
            progress = False
            admitted = sched.admit(now=float(tick))
            if admitted:
                progress = True
                reqs = [sched.slots[i].request for i in admitted]
                lb = max(len(r.prompt) for r in reqs)
                lb = 1 << max(0, (lb - 1).bit_length())  # pow2 bucket
                toks = np.full((n_slots, lb), pad_id, np.int32)
                for i, r in zip(admitted, reqs):
                    toks[i, lb - len(r.prompt):] = r.prompt  # left-pad
                logits, new_cache = self._prefill(
                    self.params, {"tokens": jnp.asarray(toks)})
                first = np.asarray(greedy_sample(logits))
                if cache is None:
                    cache = new_cache
                else:
                    if axes is None:
                        axes = self._cache_batch_axes(n_slots)
                    cache = self._scatter_slots(
                        cache, new_cache, axes,
                        np.asarray(admitted, np.int32))
                for i, r in zip(admitted, reqs):
                    r.generated.append(int(first[i]))  # prefill's token
                    cur_tok[i] = first[i]
                    pos[i] = lb
                    sched.slots[i].pos = lb
            finished.extend(sched.retire_finished(now=float(tick)))
            active = sched.active()
            if active:
                progress = True
                logits, cache = self._decode(
                    self.params,
                    {"token": jnp.asarray(cur_tok[:, None]),
                     "pos": jnp.asarray(pos)},
                    cache)
                tok = np.asarray(greedy_sample(logits))
                for i in active:
                    r = sched.slots[i].request
                    r.generated.append(int(tok[i]))
                    cur_tok[i] = tok[i]
                    pos[i] += 1
                    sched.slots[i].pos = int(pos[i])
                finished.extend(sched.retire_finished(now=float(tick)))
            if not progress:  # defensive: avoid a silent spin
                raise RuntimeError("serve() made no progress")
            tick += 1
        return finished
