"""Serving engine: jit'd prefill + decode over the model zoo with a shared
KV cache, plus a simple generate() loop and a continuous-batching driver.

``prefill_step`` / ``decode_step`` are exactly the functions the multi-pod
dry-run lowers for the prefill_32k / decode_32k / long_500k shapes.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import Model, get_model
from repro.serving.batching import BatchScheduler, Request

Params = Any


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(logits: jax.Array, key: jax.Array, temp: float = 1.0):
    return jax.random.categorical(key, logits / max(temp, 1e-6)).astype(jnp.int32)


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens_out: int = 0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / self.decode_s if self.decode_s > 0 else 0.0


class Engine:
    """Single-model serving engine (the paper's edge-inference role)."""

    def __init__(self, cfg: ModelConfig, params: Params, max_len: int = 2048):
        self.cfg = cfg
        self.model = get_model(cfg)
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, max_len)
        )
        self._decode = jax.jit(self.model.decode_step)

    def generate(
        self,
        prompts: np.ndarray,  # (B, S) int32
        max_new_tokens: int,
        prefix_embed: Optional[np.ndarray] = None,
        greedy: bool = True,
        key: Optional[jax.Array] = None,
    ) -> Tuple[np.ndarray, ServeStats]:
        stats = ServeStats()
        B, S = prompts.shape
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        n_prefix = 0
        if prefix_embed is not None:
            batch["prefix_embed"] = jnp.asarray(prefix_embed)
            if self.cfg.family == "vlm":
                n_prefix = self.cfg.frontend.n_prefix_tokens
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch)
        logits = jax.block_until_ready(logits)
        stats.prefill_s = time.perf_counter() - t0

        key = key if key is not None else jax.random.PRNGKey(0)
        tok = greedy_sample(logits) if greedy else temperature_sample(logits, key)
        out = [np.asarray(tok)]
        pos = jnp.full((B,), S + n_prefix, jnp.int32)
        t0 = time.perf_counter()
        for i in range(max_new_tokens - 1):
            logits, cache = self._decode(
                self.params, {"token": tok[:, None], "pos": pos + i}, cache
            )
            if greedy:
                tok = greedy_sample(logits)
            else:
                key, sub = jax.random.split(key)
                tok = temperature_sample(logits, sub)
            out.append(np.asarray(tok))
        jax.block_until_ready(tok)
        stats.decode_s = time.perf_counter() - t0
        stats.tokens_out = B * max_new_tokens
        return np.stack(out, axis=1), stats

    # -- continuous batching ------------------------------------------------

    def serve(self, requests: List[Request], n_slots: int = 4,
              pad_id: int = 0) -> List[Request]:
        """Drive a wave-batching loop until all requests finish.

        Each admission wave left-pads the admitted prompts to a common
        length, prefills once, and decodes to the wave's longest request
        (shorter requests are truncated to their own max_new_tokens).  Waves
        repeat until the queue drains — simple, deterministic semantics the
        runtime simulator can reason about; slot-level interleaving would be
        the next refinement on real hardware.
        """
        sched = BatchScheduler(n_slots)
        for r in requests:
            sched.submit(r)
        finished: List[Request] = []
        while not sched.idle:
            admitted = sched.admit()
            if admitted:
                reqs = [sched.slots[i].request for i in admitted]
                maxlen = max(len(r.prompt) for r in reqs)
                toks = np.full((len(reqs), maxlen), pad_id, np.int32)
                for j, r in enumerate(reqs):
                    toks[j, maxlen - len(r.prompt):] = r.prompt  # left-pad
                out, _ = self.generate(toks, max_new_tokens=max(
                    r.max_new_tokens for r in reqs))
                for j, r in enumerate(reqs):
                    r.generated = list(out[j][: r.max_new_tokens])
            done = sched.retire_finished()
            if not admitted and not done:  # defensive: avoid a silent spin
                raise RuntimeError("serve() made no progress")
            finished.extend(done)
        return finished
