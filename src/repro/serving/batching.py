"""Request batching for the serving engine.

The paper's edge performs per-window batched inference; a production serving
plane needs continuous batching: requests arrive asynchronously, are admitted
into fixed slots, and finished slots are recycled.  This scheduler is
deterministic (driven by the runtime simulator's clock or by arrival order).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


@dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    arrived_at: float = 0.0
    # filled by the engine
    generated: List[int] = field(default_factory=list)
    finished_at: Optional[float] = None

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


@dataclass
class Slot:
    request: Optional[Request] = None
    pos: int = 0  # next decode position (absolute)

    @property
    def free(self) -> bool:
        return self.request is None


class BatchScheduler:
    """Fixed-slot continuous batcher."""

    def __init__(self, n_slots: int):
        self.slots = [Slot() for _ in range(n_slots)]
        self.queue: List[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def admit(self) -> List[int]:
        """Move queued requests into free slots; returns slot ids admitted
        (these need a prefill before decoding)."""
        admitted = []
        for i, s in enumerate(self.slots):
            if s.free and self.queue:
                s.request = self.queue.pop(0)
                s.pos = len(s.request.prompt)
                admitted.append(i)
        return admitted

    def active(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if not s.free]

    def retire_finished(self, now: float = 0.0) -> List[Request]:
        done = []
        for s in self.slots:
            if s.request is not None and s.request.done:
                s.request.finished_at = now
                done.append(s.request)
                s.request = None
                s.pos = 0
        return done

    @property
    def idle(self) -> bool:
        return not self.queue and all(s.free for s in self.slots)
