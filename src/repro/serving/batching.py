"""Request batching for the serving engine.

The paper's edge performs per-window batched inference; a production serving
plane needs continuous batching: requests arrive asynchronously, are admitted
into fixed slots, and finished slots are recycled.  This scheduler is
deterministic (driven by the runtime simulator's clock or by arrival order).

The scheduler is generic over the *request* type: anything with ``done``
(finished predicate), ``prefill_len`` (how many positions its admission
prefill consumes — token prompts report their prompt length, forecast
queries report 0), ``admitted_at`` and ``finished_at`` stamp fields works.
``repro.serving.engine.Engine.serve`` drives it with token :class:`Request`s;
``repro.serving.query_plane.QueryPlane`` drives it with ``ForecastQuery``s.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional

import numpy as np


@dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    arrived_at: float = 0.0
    # filled by the engine
    generated: List[int] = field(default_factory=list)
    admitted_at: Optional[float] = None
    finished_at: Optional[float] = None

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens

    @property
    def prefill_len(self) -> int:
        return len(self.prompt)


@dataclass
class Slot:
    request: Optional[Request] = None
    pos: int = 0  # next decode position (absolute)

    @property
    def free(self) -> bool:
        return self.request is None


class BatchScheduler:
    """Fixed-slot continuous batcher.

    The queue is a deque, so FIFO admission of ``k`` requests costs O(k)
    ``popleft``s instead of the O(queue) list-head pops a ``list.pop(0)``
    queue pays per admission.
    """

    def __init__(self, n_slots: int):
        self.slots = [Slot() for _ in range(n_slots)]
        self.queue: Deque = deque()

    def submit(self, req) -> None:
        self.queue.append(req)

    def admit(self, now: Optional[float] = None) -> List[int]:
        """Move queued requests into free slots in strict FIFO order;
        returns the slot ids admitted (these need a prefill before
        decoding).  ``now`` stamps each admitted request's ``admitted_at``
        when the caller threads a clock through (the runtime executors do;
        clockless callers may omit it)."""
        admitted = []
        for i, s in enumerate(self.slots):
            if s.free and self.queue:
                s.request = self.queue.popleft()
                s.pos = s.request.prefill_len
                if now is not None:
                    s.request.admitted_at = now
                admitted.append(i)
        return admitted

    def active(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if not s.free]

    def retire_finished(self, now: float) -> List:
        """Free every slot whose request is done, stamping ``finished_at``
        with the caller's clock — ``now`` is required, so latency accounting
        can never silently default to 0.0."""
        done = []
        for s in self.slots:
            if s.request is not None and s.request.done:
                s.request.finished_at = now
                done.append(s.request)
                s.request = None
                s.pos = 0
        return done

    @property
    def idle(self) -> bool:
        return not self.queue and all(s.free for s in self.slots)
