"""The request plane: user queries answered from the fleet's device-resident
serving state.

Three query kinds against a stream's freshest lag-window context (the last
supervised input row the serving site has seen):

* ``point``   — one-step-ahead forecast from the current context.
* ``horizon`` — an ``h``-step autoregressive forecast: each step's scalar
  prediction is written into the target column of the rolled context window
  (the ``make_supervised`` feedback convention), and the query occupies its
  batch slot for ``h`` serving ticks.
* ``whatif``  — a scenario query: the context is perturbed once at admission
  (``x' = x * perturb_scale + perturb_offset``) and forecast one step ahead.

Queries arrive on per-stream request topics (``serve/request/<sid>``), are
admitted into fixed batch slots by the slot-recycling
:class:`~repro.serving.batching.BatchScheduler`, and every serving tick
answers *all* active slots across *all* streams in **one** vmapped
``FleetForecaster.predict_fleet`` dispatch — the same (stream bucket, shape
bucket) executable cache the per-window inference path uses, reading the
stacked fit output the training plane left on the device.  Answers publish
back on ``serve/response/<sid>``.

The open-loop load generator (:func:`open_loop_trace`) emits a deterministic
arrival trace — uniform ``1/qps`` spacing, seeded kind/horizon mix — so a
run is exactly replayable and the offered rate is exact by construction.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.batching import BatchScheduler

QUERY_KINDS = ("point", "horizon", "whatif")


@dataclass
class ForecastQuery:
    """One user request against one stream's serving model.

    ``answer`` fills with one float per serving tick (``horizon`` of them);
    ``model_window`` records which training window produced the serving
    params that answered — the staleness bound: under the paper's
    M^s_{t-1} semantics it trails the newest injected window by at most
    one training window (plus any sync still in flight)."""

    uid: int
    stream: str
    kind: str = "point"
    horizon: int = 1
    perturb_scale: float = 1.0
    perturb_offset: float = 0.0
    arrived_at: float = 0.0
    admitted_at: Optional[float] = None
    finished_at: Optional[float] = None
    answer: List[float] = field(default_factory=list)
    model_window: int = -1
    context_window: int = -1
    # True when any tick of this query was answered by the batch-model
    # fallback (cold start, or the staleness watchdog tripping because the
    # speed model lagged past the executor's bound)
    served_fallback: bool = False
    # the query's working (lag, F) context; set at admission, rolled by
    # horizon feedback
    ctx: Optional[np.ndarray] = None

    def __post_init__(self):
        if self.kind not in QUERY_KINDS:
            raise ValueError(f"unknown query kind {self.kind!r}")
        if self.kind != "horizon":
            self.horizon = 1
        if self.horizon < 1:
            raise ValueError(f"horizon must be >= 1, got {self.horizon}")

    @property
    def done(self) -> bool:
        return len(self.answer) >= self.horizon

    @property
    def prefill_len(self) -> int:
        # forecast queries carry no token prompt; admission consumes no
        # decode positions (BatchScheduler genericity contract)
        return 0


def open_loop_trace(ids: Sequence[str], qps: float, n_requests: int, *,
                    start: float = 0.0, seed: int = 0,
                    kinds: Sequence[str] = QUERY_KINDS,
                    max_horizon: int = 3) -> List[ForecastQuery]:
    """A deterministic open-loop arrival trace: ``n_requests`` queries at
    exactly uniform ``1/qps`` spacing from ``start``, round-robin over the
    streams, with a seeded kind/horizon/perturbation mix.  Same arguments
    -> byte-identical trace, so a run replays exactly."""
    if qps <= 0:
        raise ValueError(f"qps must be > 0, got {qps}")
    rng = np.random.default_rng(seed)
    out: List[ForecastQuery] = []
    for i in range(n_requests):
        kind = kinds[int(rng.integers(len(kinds)))]
        horizon = (int(rng.integers(2, max_horizon + 1))
                   if kind == "horizon" else 1)
        scale, offset = 1.0, 0.0
        if kind == "whatif":
            scale = float(1.0 + 0.1 * rng.standard_normal())
            offset = float(0.05 * rng.standard_normal())
        out.append(ForecastQuery(
            uid=i, stream=ids[i % len(ids)], kind=kind, horizon=horizon,
            perturb_scale=scale, perturb_offset=offset,
            arrived_at=start + i / qps))
    return out


class QueryPlane:
    """Admission + context bookkeeping between the request topics and the
    batched serving dispatch.

    The serving site calls :meth:`observe_window` as stream windows arrive
    (keeping each stream's freshest lag-window context), :meth:`submit` as
    requests arrive, and then, per serving tick: :meth:`admit` (strict FIFO
    into free slots; a query whose stream has produced no window yet waits
    at the queue head), :meth:`build_batch` (per-stream slot contexts
    stacked into one fleet batch, aligned to the fleet order), and — after
    the one vmapped dispatch — :meth:`apply` (answers appended, horizon
    contexts rolled) and :meth:`retire` (finished slots recycled)."""

    def __init__(self, ids: Sequence[str], n_slots: int,
                 target_col: int = 0):
        self.ids = list(ids)
        self.sched = BatchScheduler(n_slots)
        self.target_col = target_col
        self._ctx: Dict[str, np.ndarray] = {}
        self._ctx_window: Dict[str, int] = {}
        self.submitted = 0

    # -- context + request intake --------------------------------------------

    def observe_window(self, sid: str, x: np.ndarray, window: int) -> None:
        """Record stream ``sid``'s freshest context: the last supervised
        input row of window ``window`` (a (lag, F) array)."""
        x = np.asarray(x)
        if len(x) == 0 or window < self._ctx_window.get(sid, -1):
            return
        self._ctx[sid] = np.array(x[-1], copy=True)
        self._ctx_window[sid] = window

    def has_context(self, sid: str) -> bool:
        return sid in self._ctx

    def context_window(self, sid: str) -> int:
        """The freshest window this stream's context came from (-1 before
        the first window lands) — what the staleness watchdog compares the
        served ``model_window`` against."""
        return self._ctx_window.get(sid, -1)

    def submit(self, query: ForecastQuery) -> None:
        self.sched.submit(query)
        self.submitted += 1

    # -- the serving tick -----------------------------------------------------

    def admit(self, now: float) -> List[int]:
        """FIFO admission into free slots, initializing each admitted
        query's working context (perturbed once here for what-if queries).
        A queue-head query whose stream has no context yet blocks admission
        — strict FIFO, no reordering — until its stream's first window
        lands."""
        admitted = []
        for i, s in enumerate(self.sched.slots):
            if not s.free or not self.sched.queue:
                continue
            q = self.sched.queue[0]
            if q.stream not in self._ctx:
                break
            self.sched.queue.popleft()
            s.request = q
            s.pos = q.prefill_len
            q.admitted_at = now
            ctx = np.array(self._ctx[q.stream], copy=True)
            if q.kind == "whatif":
                ctx = ctx * q.perturb_scale + q.perturb_offset
            q.ctx = ctx
            q.context_window = self._ctx_window[q.stream]
            admitted.append(i)
        return admitted

    def build_batch(self) -> Optional[Tuple[Dict[str, List[ForecastQuery]],
                                            List[np.ndarray]]]:
        """The tick's fleet batch: for every stream (in fleet order) the
        stacked contexts of its active slots — streams with no active query
        contribute a zero-row batch, so the dispatch shape stays one
        (stream bucket, shape bucket) entry.  None when no slot is
        active."""
        by_stream: Dict[str, List[ForecastQuery]] = {sid: []
                                                     for sid in self.ids}
        ref = None
        for s in self.sched.slots:
            if s.request is not None:
                by_stream[s.request.stream].append(s.request)
                ref = s.request.ctx
        if ref is None:
            return None
        xs = []
        for sid in self.ids:
            qs = by_stream[sid]
            if qs:
                xs.append(np.stack([q.ctx for q in qs]))
            else:
                xs.append(np.zeros((0,) + ref.shape, ref.dtype))
        return by_stream, xs

    def apply(self, by_stream: Dict[str, List[ForecastQuery]],
              preds: Sequence[np.ndarray],
              model_windows: Dict[str, int],
              fallback: Optional[Dict[str, bool]] = None
              ) -> List[ForecastQuery]:
        """Append the tick's predictions to their queries (same slot order
        ``build_batch`` emitted) and roll each unfinished horizon query's
        context: next row = last row with the target column replaced by the
        prediction, window shifted by one.  ``fallback[sid]`` stamps the
        stream's answers as served from the batch-model fallback."""
        answered = []
        for sid, pred in zip(self.ids, preds):
            for j, q in enumerate(by_stream[sid]):
                p = float(np.asarray(pred[j]).reshape(-1)[0])
                q.answer.append(p)
                q.model_window = model_windows.get(sid, -1)
                if fallback is not None and fallback.get(sid, False):
                    q.served_fallback = True
                if not q.done:
                    nxt = np.array(q.ctx[-1], copy=True)
                    nxt[self.target_col] = p
                    q.ctx = np.concatenate([q.ctx[1:], nxt[None]], axis=0)
                answered.append(q)
        return answered

    def retire(self, now: float) -> List[ForecastQuery]:
        return self.sched.retire_finished(now)

    @property
    def busy(self) -> bool:
        """Anything admitted or admittable?"""
        return not self.sched.idle


def answer_query_unbatched(predict_fn, params, query: ForecastQuery,
                           base_ctx: np.ndarray,
                           target_col: int = 0) -> List[float]:
    """The unbatched reference for one query: a batch-of-one predict per
    horizon step with the same admission perturbation and horizon-feedback
    convention the batched tick path applies.  ``bench_serving`` and the
    parity tests gate the batched answers against this to <=1e-6."""
    ctx = np.array(base_ctx, copy=True)
    if query.kind == "whatif":
        ctx = ctx * query.perturb_scale + query.perturb_offset
    out: List[float] = []
    for _ in range(query.horizon):
        p = float(np.asarray(predict_fn(params, ctx[None])).reshape(-1)[0])
        out.append(p)
        nxt = np.array(ctx[-1], copy=True)
        nxt[target_col] = p
        ctx = np.concatenate([ctx[1:], nxt[None]], axis=0)
    return out


def latency_stats(latencies: Sequence[float]) -> Dict[str, float]:
    """p50/p99/mean over a latency sample (seconds); inf when empty so a
    starved run can never report a finite tail."""
    if not latencies:
        return {"p50_s": float("inf"), "p99_s": float("inf"),
                "mean_s": float("inf"), "max_s": float("inf")}
    arr = np.asarray(sorted(latencies))
    return {"p50_s": float(np.percentile(arr, 50)),
            "p99_s": float(np.percentile(arr, 99)),
            "mean_s": float(arr.mean()),
            "max_s": float(arr.max())}
