"""The paper's own forecaster (Sec. 6.1.2, Fig. 6).

LSTM(40) -> Dense(10, ReLU) -> Dense(1); 10,981 parameters with 5 input
features and lag n=5.  This is the batch/speed model of the faithful
reproduction.
"""
from repro.configs.base import LSTMConfig, ModelConfig

CONFIG = ModelConfig(
    name="lstm-paper",
    family="lstm",
    n_layers=1,
    d_model=40,
    n_heads=1,
    n_kv_heads=1,
    d_ff=10,
    vocab_size=0,
    attention="none",
    dtype="float32",
    param_dtype="float32",
    lstm=LSTMConfig(hidden=40, dense=10, n_features=5, lag=5, out_dim=1),
    citation="Wang et al. 2022, FGCS (this paper), Fig. 6",
)
