"""SeamlessM4T-medium text decoder + speech encoder backbone [arXiv:2308.11596].

Enc-dec: 12L encoder / 12L decoder, d=1024, 16 heads MHA kv=16, d_ff=4096,
vocab=256206.  Speech frontend (mel + conformer feature extractor) is a stub
per the modality carve-out: ``input_specs`` provides (batch, frames, d)
frame embeddings consumed by the encoder.
"""
from repro.configs.base import EncDecConfig, FrontendStub, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,  # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    mlp_variant="relu",
    attention="full",
    encdec=EncDecConfig(n_encoder_layers=12, encoder_len=1024),
    frontend=FrontendStub(n_prefix_tokens=1024, embed_dim=1024),
    citation="arXiv:2308.11596 (SeamlessM4T, medium)",
)
