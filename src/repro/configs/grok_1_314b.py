"""Grok-1 314B MoE [hf:xai-org/grok-1].

64L, d=6144, 48 heads GQA kv=8, vocab=131072; MoE with 8 experts top-2,
expert d_ff=32768 gated-GELU; tanh logit soft-capping (grok signature 30.0).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    mlp_variant="geglu",
    attention="full",
    logit_softcap=30.0,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32768, capacity_factor=1.25),
    citation="hf:xai-org/grok-1",
)
