"""RWKV-6 (Finch) 3B [arXiv:2404.05892].

Attention-free: 32L, d=2560, data-dependent decay time-mix with head_size 64
(40 heads), channel-mix d_ff=8960, vocab=65536.  O(1)-state decode -> runs
long_500k.
"""
from repro.configs.base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,  # d_model / head_size
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    mlp_variant="relu",  # rwkv channel-mix uses squared relu internally
    attention="none",
    rwkv=RWKVConfig(head_size=64, decay_lora=64, gate_lora=64),
    citation="arXiv:2404.05892 (RWKV-6 Finch, data-dependent decay)",
)
