"""H2O-Danube-3-4B [arXiv:2401.16818 lineage].

Llama+Mistral mix with sliding-window attention: 24L, d=3840, 32 heads GQA
kv=8, d_ff=10240 SwiGLU, vocab=32000.  SWA makes this the one *dense* arch
that runs the long_500k decode shape (window=4096 KV cache).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32000,
    mlp_variant="swiglu",
    attention="swa",
    window_size=4096,
    rope_theta=10000.0,
    citation="arXiv:2401.16818 (H2O-Danube); SWA per assignment",
)
