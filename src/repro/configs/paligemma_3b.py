"""PaliGemma-3B language backbone [arXiv:2407.07726].

SigLIP vision frontend is a stub per the modality carve-out: ``input_specs``
provides (batch, 256, 1152) patch embeddings; the model owns the projector and
the Gemma-2B-class decoder (18L, d=2048, 8 heads MQA kv=1, head_dim=256,
d_ff=16384 gated-GELU, vocab=257216).
"""
from repro.configs.base import FrontendStub, ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    mlp_variant="geglu",
    attention="full",
    tie_embeddings=True,
    rope_theta=10000.0,
    norm_eps=1e-6,
    frontend=FrontendStub(n_prefix_tokens=256, embed_dim=1152),
    citation="arXiv:2407.07726 (PaliGemma); gemma backbone per model card",
)
