"""CodeQwen1.5-7B [hf:Qwen/CodeQwen1.5-7B].

Qwen1.5 architecture: 32L, d=4096, 32 heads MHA (kv=32), d_ff=13440 SwiGLU,
vocab=92416, QKV projection biases (qwen signature), rope theta 1e6 for long
code context.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    vocab_size=92416,
    mlp_variant="swiglu",
    attention="full",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    citation="hf:Qwen/CodeQwen1.5-7B (qwen1.5 arch)",
)
