"""Kimi K2 — trillion-param MoE, 32B active [arXiv:2501.kimi2 per assignment].

61L, d=7168, 64 heads GQA kv=8, vocab=163840; DeepSeek-V3-style fine-grained
MoE: 384 routed experts top-8 with per-expert d_ff=2048, 1 shared expert,
first layer dense (d_ff=18432).  This is the paper-table scale config.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,
    d_ff=18432,  # dense-layer / shared-path FFN width
    vocab_size=163840,
    mlp_variant="swiglu",
    attention="full",
    moe=MoEConfig(
        n_experts=384,
        top_k=8,
        d_ff_expert=2048,
        capacity_factor=1.0,
        n_shared_experts=1,
        first_dense_layers=1,
    ),
    citation="arXiv:2501.kimi2 (Kimi K2, 1T total / 32B active)",
)
