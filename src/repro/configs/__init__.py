"""Config registry: ``get_config(name)`` / ``--arch <id>`` resolution."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import (
    ATTENTION_KINDS,
    FAMILIES,
    MLP_VARIANTS,
    SHAPES,
    TPU_V5E,
    EncDecConfig,
    FrontendStub,
    HardwareModel,
    HybridConfig,
    InputShape,
    LSTMConfig,
    ModelConfig,
    MoEConfig,
    RWKVConfig,
    SSMConfig,
    shape_applicable,
)

from repro.configs.paligemma_3b import CONFIG as _paligemma
from repro.configs.h2o_danube_3_4b import CONFIG as _danube
from repro.configs.codeqwen1_5_7b import CONFIG as _codeqwen
from repro.configs.nemotron_4_15b import CONFIG as _nemotron
from repro.configs.grok_1_314b import CONFIG as _grok
from repro.configs.kimi_k2_1t_a32b import CONFIG as _kimi
from repro.configs.tinyllama_1_1b import CONFIG as _tinyllama
from repro.configs.rwkv6_3b import CONFIG as _rwkv6
from repro.configs.zamba2_1_2b import CONFIG as _zamba2
from repro.configs.seamless_m4t_medium import CONFIG as _seamless
from repro.configs.lstm_paper import CONFIG as _lstm_paper

# the ten assigned architectures, in assignment order
ASSIGNED: List[ModelConfig] = [
    _paligemma,
    _danube,
    _codeqwen,
    _nemotron,
    _grok,
    _kimi,
    _tinyllama,
    _rwkv6,
    _zamba2,
    _seamless,
]

REGISTRY: Dict[str, ModelConfig] = {c.name: c for c in ASSIGNED}
REGISTRY[_lstm_paper.name] = _lstm_paper


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(REGISTRY)}"
        )
    return REGISTRY[name]


def get_shape(name: str) -> InputShape:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]


__all__ = [
    "ASSIGNED",
    "REGISTRY",
    "SHAPES",
    "TPU_V5E",
    "get_config",
    "get_shape",
    "shape_applicable",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "RWKVConfig",
    "HybridConfig",
    "EncDecConfig",
    "FrontendStub",
    "LSTMConfig",
    "InputShape",
    "HardwareModel",
    "FAMILIES",
    "ATTENTION_KINDS",
    "MLP_VARIANTS",
]
