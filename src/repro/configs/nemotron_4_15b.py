"""Nemotron-4-15B [arXiv:2402.16819].

32L, d=6144, 48 heads GQA kv=8, d_ff=24576 with **squared-ReLU** MLP (no
gate), vocab=256000, untied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    mlp_variant="squared_relu",
    attention="full",
    rope_theta=10000.0,
    citation="arXiv:2402.16819 (Nemotron-4 15B)",
)
