"""Config dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``; input shapes are
``InputShape``; the pairing of the two (plus a mesh) is what the launcher and
dry-run consume.  Configs are frozen dataclasses so they can be hashed into jit
static args.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block config (Switch/DeepSeek style)."""

    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    n_shared_experts: int = 0
    # how many leading layers use a plain dense MLP instead of MoE
    first_dense_layers: int = 0
    router_aux_loss: float = 0.01
    # one-hot dispatch sub-group length (perf knob: dispatch einsum cost is
    # proportional to this)
    dispatch_group: int = 512
    # "auto" | "onehot" | "shard_map" — force an EP strategy
    ep_mode: str = "auto"


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2-style state-space block config."""

    state_dim: int = 64
    conv_dim: int = 4
    expand: int = 2
    head_dim: int = 64  # SSM head dim (d_inner / n_heads)
    chunk_size: int = 256


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV6 (Finch) time-mix config."""

    head_size: int = 64
    decay_lora: int = 64  # rank of the data-dependent decay LoRA
    gate_lora: int = 64


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style hybrid: SSM backbone + shared attention block."""

    # a single (shared-weight) transformer block is applied every
    # ``attn_every`` backbone layers, concat-skip from the embedding
    attn_every: int = 6


@dataclass(frozen=True)
class EncDecConfig:
    """Encoder-decoder (seamless-m4t style text decoder + speech encoder)."""

    n_encoder_layers: int = 12
    # dry-run encoder memory length (stubbed frontend produces this many frames)
    encoder_len: int = 1024


@dataclass(frozen=True)
class FrontendStub:
    """Modality frontend carve-out: precomputed patch/frame embeddings.

    ``input_specs`` emits an embedding tensor of shape
    (batch, n_prefix_tokens, embed_dim); the model owns only the projector.
    """

    n_prefix_tokens: int
    embed_dim: int


@dataclass(frozen=True)
class LSTMConfig:
    """The paper's forecaster: LSTM(hidden) -> Dense(dense, relu) -> Dense(1)."""

    hidden: int = 40
    dense: int = 10
    n_features: int = 5
    lag: int = 5  # paper sets time lag n = 5
    out_dim: int = 1


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio", "lstm")
ATTENTION_KINDS = ("full", "swa", "none")
MLP_VARIANTS = ("swiglu", "geglu", "squared_relu", "relu", "gelu")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    mlp_variant: str = "swiglu"
    attention: str = "full"
    window_size: int = 4096  # only used when attention == "swa"
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    logit_softcap: float = 0.0  # grok-style tanh soft capping (0 = off)
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    frontend: Optional[FrontendStub] = None
    lstm: Optional[LSTMConfig] = None
    # implementation switches
    use_pallas: bool = False  # Pallas kernels (TPU target / interpret tests)
    remat: str = "none"  # "none" | "block" | "dots" — checkpoint policy
    attn_chunk: int = 1024  # KV chunk for online-softmax attention (XLA path)
    # perf knobs (see EXPERIMENTS.md §Perf)
    attn_p_dtype: str = "float32"  # attention-prob dtype for the PV matmul
    attn_q_chunk: int = 0  # >0: block queries too (bounds the live score set)
    scan_chunked: bool = False  # chunked (vs per-step) RWKV/SSM XLA scans
    scan_chunk: int = 64
    opt_moment_dtype: str = "float32"  # bfloat16 halves AdamW state HBM
    # exact (no-drop) MoE serving: bit-identical decode==prefill==forward,
    # but worst-case dispatch capacity.  False -> capacity-based serving
    # (Switch-style, bounded drop probability) — the production choice for
    # long prefill.  Single-token decode is exact either way (top-k experts
    # are distinct, so capacity 1 suffices).
    moe_exact_serving: bool = True
    citation: str = ""

    # -- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.resolved_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.attention == "none"

    @property
    def supports_long_decode(self) -> bool:
        """Sub-quadratic decode: SSM/linear-attn state, or sliding-window KV."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.attention == "swa"

    @property
    def has_decoder(self) -> bool:
        """Everything here decodes (enc-dec includes a text decoder)."""
        return self.family != "lstm"

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- smoke-test reduction ----------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Same family, CPU-runnable: 2 layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        head_dim = max(32, d_model // n_heads)
        kw = dict(
            n_layers=2,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            dtype="float32",
            param_dtype="float32",
            attn_chunk=64,
            window_size=min(self.window_size, 64),
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=min(self.moe.d_ff_expert, 256),
                n_shared_experts=min(self.moe.n_shared_experts, 1),
                first_dense_layers=min(self.moe.first_dense_layers, 1),
            )
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, state_dim=min(self.ssm.state_dim, 16), chunk_size=32
            )
        if self.rwkv is not None:
            kw["rwkv"] = dataclasses.replace(
                self.rwkv, head_size=32, decay_lora=16, gate_lora=16
            )
        if self.hybrid is not None:
            kw["hybrid"] = dataclasses.replace(self.hybrid, attn_every=1)
        if self.encdec is not None:
            kw["encdec"] = dataclasses.replace(
                self.encdec, n_encoder_layers=2, encoder_len=16
            )
        if self.frontend is not None:
            kw["frontend"] = dataclasses.replace(
                self.frontend, n_prefix_tokens=8, embed_dim=64
            )
        return self.replace(**kw)


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Whether (arch, shape) is a live dry-run combo; else reason for the skip."""
    if shape.kind in ("decode", "prefill") and not cfg.has_decoder:
        return False, "architecture has no decode step"
    if shape.name == "long_500k" and not cfg.supports_long_decode:
        return False, (
            "full quadratic attention; no sliding-window/block-sparse variant "
            "configured (see DESIGN.md long_500k skips)"
        )
    return True, ""


# ---------------------------------------------------------------------------
# Hardware model (TPU v5e class) for the roofline analysis
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HardwareModel:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12  # FLOP/s per chip
    hbm_bw: float = 819e9  # B/s per chip
    ici_bw: float = 50e9  # B/s per link
    hbm_bytes: float = 16e9  # capacity per chip
    vmem_bytes: float = 128 * 1024 * 1024


TPU_V5E = HardwareModel()
