"""TinyLlama-1.1B [arXiv:2401.02385].

Llama-2 architecture, small: 22L, d=2048, 32 heads GQA kv=4, d_ff=5632
SwiGLU, vocab=32000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=64,
    d_ff=5632,
    vocab_size=32000,
    mlp_variant="swiglu",
    attention="full",
    citation="arXiv:2401.02385 (TinyLlama)",
)
