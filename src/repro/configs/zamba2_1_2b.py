"""Zamba2-1.2B [arXiv:2411.15242].

Hybrid: 38 Mamba2 backbone layers (d=2048, ssm_state=64, expand 2) with a
single shared transformer block (32 heads MHA kv=32, d_ff=8192) applied every
6 layers.  SSM state decode -> runs long_500k.
"""
from repro.configs.base import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    mlp_variant="geglu",
    attention="full",  # used by the shared block only
    ssm=SSMConfig(state_dim=64, conv_dim=4, expand=2, head_dim=64, chunk_size=256),
    hybrid=HybridConfig(attn_every=6),
    citation="arXiv:2411.15242 (Zamba2: Mamba2 + shared attention blocks)",
)
