"""Paper Fig. 7: per-window latency of speed/batch/hybrid inference and the
static-vs-dynamic weighting overhead, measured with the REAL modules (jit'd
LSTM inference + scipy-SLSQP / closed-form DWA) on this container.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import numpy as np

from repro.configs import get_config
from repro.core import (
    HybridStreamAnalytics,
    WindowedStream,
    WindowPlan,
    lstm_forecaster,
    make_supervised,
    pretrain_batch_model,
)
from repro.streams.normalize import MinMaxScaler
from repro.streams.sources import gradual_drift, wind_turbine_series


def run(n_windows: int = 12, records: int = 250, fast: bool = False
        ) -> Dict[str, dict]:
    if fast:
        n_windows = 5
    cfg = get_config("lstm-paper")
    hist = wind_turbine_series(2000, seed=0)
    stream = gradual_drift(wind_turbine_series(n_windows * records, seed=3),
                           alphas=np.full(5, 6e-4), seed=1)
    scaler = MinMaxScaler.fit(hist)
    fc_batch = lstm_forecaster(cfg, epochs=10 if fast else 25, batch_size=512)
    fc_speed = lstm_forecaster(cfg, epochs=12 if fast else 40, batch_size=64)
    bp, _ = pretrain_batch_model(
        fc_batch, make_supervised(scaler.transform(hist), 5, 0),
        jax.random.PRNGKey(0))
    plan = WindowPlan(n_windows=n_windows, records_per_window=records, lag=5)
    ws = WindowedStream(scaler.transform(stream), plan)

    # jit warmup so the first measured mode doesn't absorb compile time
    warm = HybridStreamAnalytics(fc_speed, mode=("static", 0.5))
    warm.run(WindowedStream(scaler.transform(stream[: 2 * records]),
                            WindowPlan(2, records, 5)), bp, jax.random.PRNGKey(9))

    out = {}
    for name, mode, solver in (
        ("static", ("static", 0.5), "closed_form"),
        ("dynamic_scipy", "dynamic", "scipy"),
        ("dynamic_closed_form", "dynamic", "closed_form"),
    ):
        h = HybridStreamAnalytics(fc_speed, mode=mode, dwa_solver=solver)
        res = h.run(ws, bp, jax.random.PRNGKey(1))
        lat = res.mean_latency()
        out[name] = lat
    return out


def report(fast: bool = False) -> str:
    res = run(fast=fast)
    lines = ["# Fig. 7 analog: per-window module latency (s, measured)"]
    keys = ("speed_infer", "batch_infer", "hybrid_infer", "weight_solve",
            "speed_train")
    lines.append(f"{'mode':<22}" + "".join(f"{k:>14}" for k in keys))
    for name, lat in res.items():
        lines.append(f"{name:<22}" + "".join(f"{lat[k]:>14.4f}" for k in keys))
    def total(mode):
        lat = res[mode]
        return lat["speed_infer"] + lat["batch_infer"] + lat["hybrid_infer"]

    dyn = res["dynamic_scipy"]["hybrid_infer"]
    sta = res["static"]["hybrid_infer"]
    pct = (total("dynamic_scipy") - total("static")) / max(
        total("static"), 1e-12) * 100
    lines.append(
        f"\n  dynamic (SLSQP) adds {(dyn-sta)*1e3:.2f} ms/window to hybrid "
        f"inference (+{pct:.1f}% of the total inference path).  The paper's "
        f"+14.82% is relative to its Pi/TFLite stack where hybrid inference "
        f"costs seconds; the validated claim is the sign and mechanism "
        f"(solver time), not the ratio."
    )
    cf = res["dynamic_closed_form"]["weight_solve"]
    sp = res["dynamic_scipy"]["weight_solve"]
    lines.append(f"  beyond-paper: closed-form DWA solve {cf*1e6:.0f} us vs "
                 f"SLSQP {sp*1e6:.0f} us ({sp/max(cf,1e-12):.0f}x faster)")
    lines.append(f"  check dynamic>static hybrid latency: "
                 f"{'PASS' if dyn > sta else 'FAIL'}")
    return "\n".join(lines)


if __name__ == "__main__":
    print(report())
