"""§Ablations (beyond-paper): sensitivity of the hybrid learner to the two
knobs the paper fixes — window size (paper: >=200 records / 30 s) and speed
re-training budget (paper: 100 epochs) — under gradual drift.

    PYTHONPATH=src python -m benchmarks.ablation_window
"""
from __future__ import annotations

from typing import Dict

import jax
import numpy as np

from repro.configs import get_config
from repro.core import (
    HybridStreamAnalytics,
    WindowedStream,
    WindowPlan,
    lstm_forecaster,
    make_supervised,
    pretrain_batch_model,
)
from repro.streams.normalize import MinMaxScaler
from repro.streams.sources import gradual_drift, wind_turbine_series


def run(fast: bool = True) -> Dict[str, dict]:
    cfg = get_config("lstm-paper")
    n_stream = 3000
    base = wind_turbine_series(2000 + n_stream, seed=0)
    hist, tail = base[:2000], base[2000:]
    stream = gradual_drift(tail, alphas=np.full(5, 8e-4), seed=1)
    scaler = MinMaxScaler.fit(hist)
    fc_batch = lstm_forecaster(cfg, epochs=10 if fast else 25, batch_size=512)
    bp, _ = pretrain_batch_model(
        fc_batch, make_supervised(scaler.transform(hist), 5, 0),
        jax.random.PRNGKey(0))

    out: Dict[str, dict] = {"window_size": {}, "speed_epochs": {}}

    for records in (125, 250, 500):
        n_windows = n_stream // records
        fc_speed = lstm_forecaster(cfg, epochs=12 if fast else 40, batch_size=64)
        ws = WindowedStream(scaler.transform(stream),
                            WindowPlan(n_windows, records, 5))
        res = HybridStreamAnalytics(fc_speed, mode="dynamic").run(
            ws, bp, jax.random.PRNGKey(1))
        m = res.mean_rmse()
        lat = res.mean_latency()
        out["window_size"][records] = {
            "rmse_hybrid": m["hybrid"], "rmse_speed": m["speed"],
            "t_speed_train": lat["speed_train"],
        }

    for epochs in (5, 15, 40):
        fc_speed = lstm_forecaster(cfg, epochs=epochs, batch_size=64)
        ws = WindowedStream(scaler.transform(stream), WindowPlan(12, 250, 5))
        res = HybridStreamAnalytics(fc_speed, mode="dynamic").run(
            ws, bp, jax.random.PRNGKey(1))
        m = res.mean_rmse()
        lat = res.mean_latency()
        out["speed_epochs"][epochs] = {
            "rmse_hybrid": m["hybrid"],
            "t_speed_train": lat["speed_train"],
        }
    return out


def report(fast: bool = True) -> str:
    res = run(fast=fast)
    lines = ["# §Ablations: hybrid-learner sensitivity (gradual drift)"]
    lines.append("\n  window size (records)  rmse_hybrid  rmse_speed  t_train(s)")
    for r, row in res["window_size"].items():
        lines.append(f"  {r:>20}  {row['rmse_hybrid']:>11.4f}"
                     f"  {row['rmse_speed']:>10.4f}"
                     f"  {row['t_speed_train']:>9.2f}")
    lines.append("\n  speed epochs           rmse_hybrid  t_train(s)")
    for e, row in res["speed_epochs"].items():
        lines.append(f"  {e:>20}  {row['rmse_hybrid']:>11.4f}"
                     f"  {row['t_speed_train']:>9.2f}")
    ws_rows = res["window_size"]
    ep_rows = res["speed_epochs"]
    best_w = min(ws_rows, key=lambda r: ws_rows[r]["rmse_hybrid"])
    best_e = max(ep_rows)
    gain_e = (ep_rows[min(ep_rows)]["rmse_hybrid"]
              - ep_rows[best_e]["rmse_hybrid"]) / ep_rows[min(ep_rows)][
                  "rmse_hybrid"] * 100
    lines.append(
        f"\n  Reading (data-driven): at this gradual-drift rate, LARGER"
        f"\n  windows win (best: {best_w} records) — the drift is slow"
        f"\n  enough that more training data beats faster adaptation; and"
        f"\n  the re-training budget has NOT saturated by {best_e} epochs"
        f"\n  ({gain_e:.0f}% RMSE gain from {min(ep_rows)} to {best_e}),"
        f"\n  supporting the paper's generous 100-epoch speed setting."
        f"\n  Under faster drift the window-size direction reverses — the"
        f"\n  knob is drift-rate-dependent, which motivates the framework's"
        f"\n  drift-triggered re-training hooks (core/drift.py)."
    )
    return "\n".join(lines)


if __name__ == "__main__":
    print(report())
