"""Speed-layer hot-path benchmark: per-window training wall-clock, compiled
vs legacy, tracked as ``BENCH_hotpath.json`` from this PR onward.

The paper's latency claim (Table 3, Sec. 6.3) needs speed-layer retraining to
fit inside every 30 s window.  The legacy path re-traces and re-compiles the
train step every window and dispatches one device call per minibatch; the
compiled path (``repro.training.compiled.CompiledForecaster``) compiles one
epoch-scan executable per shape bucket and dispatches once per window.  This
benchmark drives both over the same drifting windowed stream (paper LSTM
config: H=40, lag 5, 5 features, speed layer bs 64) and records:

* per-window speed-train wall-clock, for each path;
* steady-state (windows >= 2) mean wall and windows/sec;
* first-window vs steady-state ratio (the amortized compile);
* retrace counts (measured trace-time counter on the compiled path; the
  legacy path re-jits by construction, one trace per window);
* ``speedup_steady_state`` = legacy steady mean / compiled steady mean.

    PYTHONPATH=src python -m benchmarks.bench_hotpath            # paper-ish
    PYTHONPATH=src python -m benchmarks.bench_hotpath --smoke    # CI: seconds
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List


def _stream_windows(n_windows: int, records_per_window: int):
    """The paper's drifting wind-turbine stream, windowed and supervised —
    same construction as ``launch.edge_cloud.build_real_pipeline``."""
    import numpy as np

    from repro.core import WindowPlan, WindowedStream
    from repro.streams.normalize import MinMaxScaler
    from repro.streams.sources import gradual_drift, wind_turbine_series

    series = wind_turbine_series(
        1600 + records_per_window * n_windows + 5, seed=0)
    hist, stream_raw = series[:1600], series[1600:]
    stream_raw = gradual_drift(stream_raw, alphas=np.full(5, 1.5e-3), seed=1)
    scaler = MinMaxScaler.fit(hist)
    stream = WindowedStream(scaler.transform(stream_raw),
                            WindowPlan(n_windows, records_per_window, lag=5))
    return [stream.supervised(w) for w in range(n_windows)]


def _drive(fc, windows, key) -> List[float]:
    """One fc.train per window (cold params each window — the paper's speed
    layer), returning per-window wall seconds."""
    from repro.core.stages import split_chain

    keys = split_chain(key, len(windows))
    walls = []
    for data, k in zip(windows, keys):
        t0 = time.perf_counter()
        fc.train(data, None, k)
        walls.append(time.perf_counter() - t0)
    return walls


def _summary(walls: List[float], retraces: List[int]) -> Dict:
    steady = walls[1:] if len(walls) > 1 else walls
    mean_steady = sum(steady) / len(steady)
    return {
        "per_window_wall_s": walls,
        "retraces_per_window": retraces,
        "first_window_wall_s": walls[0],
        "steady_state_wall_s": mean_steady,
        "first_vs_steady_ratio": walls[0] / max(mean_steady, 1e-12),
        "windows_per_sec_steady": 1.0 / max(mean_steady, 1e-12),
        "retraces_after_first_window": sum(retraces[1:]),
    }


def run(n_windows: int = 8, records_per_window: int = 250,
        epochs: int = 10, batch_size: int = 64) -> Dict:
    import jax

    from repro.configs import get_config
    from repro.core import lstm_forecaster
    from repro.core.stages import split_chain

    cfg = get_config("lstm-paper")
    windows = _stream_windows(n_windows, records_per_window)
    key = jax.random.PRNGKey(1)

    # -- compiled hot path ---------------------------------------------------
    fc = lstm_forecaster(cfg, epochs=epochs, batch_size=batch_size)
    eng = fc.engine
    walls, retraces, seen = [], [], 0
    for data, k in zip(windows, split_chain(key, n_windows)):
        t0 = time.perf_counter()
        fc.train(data, None, k)
        walls.append(time.perf_counter() - t0)
        retraces.append(eng.retrace_count - seen)
        seen = eng.retrace_count
    compiled = _summary(walls, retraces)
    compiled["shape_buckets"] = eng.cache_size

    # -- legacy baseline (pre-optimization fit: re-jit every window) ---------
    fl = lstm_forecaster(cfg, epochs=epochs, batch_size=batch_size,
                         compiled=False)
    lwalls = _drive(fl, windows, key)
    # each legacy fit builds a fresh jit, so it retraces every distinct batch
    # shape every window: the full batch plus the ragged tail when n % bs != 0
    # (a sub-batch-size window has only the one ragged shape)
    lretraces = [1 if len(w["x"]) % batch_size == 0 or len(w["x"]) < batch_size
                 else 2 for w in windows]
    legacy = _summary(lwalls, lretraces)

    return {
        "benchmark": "speed_layer_hotpath",
        "config": {
            "model": "lstm-paper",
            "n_windows": n_windows,
            "records_per_window": records_per_window,
            "epochs": epochs,
            "batch_size": batch_size,
        },
        "compiled": compiled,
        "legacy": legacy,
        "speedup_steady_state": (legacy["steady_state_wall_s"]
                                 / max(compiled["steady_state_wall_s"], 1e-12)),
    }


def report(res: Dict) -> str:
    c, l = res["compiled"], res["legacy"]
    lines = [
        "# speed-layer hot path: per-window training wall-clock (s)",
        f"{'window':<8}{'compiled':>12}{'legacy':>12}{'retraces(c)':>12}",
    ]
    for w, (cw, lw, r) in enumerate(zip(c["per_window_wall_s"],
                                        l["per_window_wall_s"],
                                        c["retraces_per_window"])):
        lines.append(f"{w:<8}{cw:>12.4f}{lw:>12.4f}{r:>12}")
    lines += [
        "",
        f"steady-state wall: compiled {c['steady_state_wall_s']:.4f}s "
        f"({c['windows_per_sec_steady']:.1f} windows/s)  "
        f"legacy {l['steady_state_wall_s']:.4f}s "
        f"({l['windows_per_sec_steady']:.1f} windows/s)",
        f"first-vs-steady ratio: compiled {c['first_vs_steady_ratio']:.1f}x  "
        f"legacy {l['first_vs_steady_ratio']:.1f}x",
        f"retraces after first window: compiled "
        f"{c['retraces_after_first_window']} "
        f"(buckets={c['shape_buckets']})  legacy "
        f"{l['retraces_after_first_window']}",
        f"steady-state speedup: {res['speedup_steady_state']:.1f}x",
    ]
    return "\n".join(lines)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="CI-sized run: 4 windows, 3 epochs, 120 records")
    p.add_argument("--windows", type=int, default=None)
    p.add_argument("--epochs", type=int, default=None)
    p.add_argument("--records", type=int, default=None)
    p.add_argument("--out", default="BENCH_hotpath.json")
    args = p.parse_args()

    if args.smoke:
        defaults = dict(n_windows=4, epochs=3, records_per_window=120)
    else:
        defaults = dict(n_windows=8, epochs=10, records_per_window=250)
    if args.windows is not None:
        defaults["n_windows"] = args.windows
    if args.epochs is not None:
        defaults["epochs"] = args.epochs
    if args.records is not None:
        defaults["records_per_window"] = args.records

    res = run(**defaults)
    print(report(res))
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
