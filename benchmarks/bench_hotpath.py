"""Speed-layer hot-path benchmark: per-window training wall-clock, compiled
vs legacy, tracked as ``BENCH_hotpath.json`` from this PR onward.

The paper's latency claim (Table 3, Sec. 6.3) needs speed-layer retraining to
fit inside every 30 s window.  The legacy path re-traces and re-compiles the
train step every window and dispatches one device call per minibatch; the
compiled path (``repro.training.compiled.CompiledForecaster``) compiles one
epoch-scan executable per shape bucket and dispatches once per window.  This
benchmark drives both over the same drifting windowed stream (paper LSTM
config: H=40, lag 5, 5 features, speed layer bs 64) and records:

* per-window speed-train wall-clock, for each path;
* steady-state (windows >= 2) mean wall and windows/sec;
* first-window vs steady-state ratio (the amortized compile);
* retrace counts (measured trace-time counter on the compiled path; the
  legacy path re-jits by construction, one trace per window);
* ``speedup_steady_state`` = legacy steady mean / compiled steady mean.

Since PR 3 the same file also tracks the two kernel-backlog closures
(extended, not forked, per ROADMAP):

* ``fused_vjp`` — the compiled hot path with ``use_pallas=True``, i.e. the
  cached train step running the fused-sequence Pallas kernel end to end
  (fused forward + fused backward via ``jax.custom_vjp``), window-driven
  exactly like ``compiled``; ``speedup_fused_vs_scan_autodiff`` compares
  their steady states (acceptance: fused is no slower);
* ``backward_pass`` — per-train-step ``value_and_grad`` wall for the
  scan-autodiff baseline vs the fused VJP, plus forward-only walls, at the
  paper's speed-layer batch shape;
* ``int8_inference`` — per-window predict wall on float vs int8-synced
  params (the ``quantized_sync`` edge path through the ``int8_matmul``
  kernel) and the float-vs-int8 ``model_nbytes`` the per-window sync
  transfers.

    PYTHONPATH=src python -m benchmarks.bench_hotpath            # paper-ish
    PYTHONPATH=src python -m benchmarks.bench_hotpath --smoke    # CI: seconds
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List


def _stream_windows(n_windows: int, records_per_window: int):
    """The paper's drifting wind-turbine stream, windowed and supervised —
    same construction as ``launch.edge_cloud.build_real_pipeline``."""
    import numpy as np

    from repro.core import WindowPlan, WindowedStream
    from repro.streams.normalize import MinMaxScaler
    from repro.streams.sources import gradual_drift, wind_turbine_series

    series = wind_turbine_series(
        1600 + records_per_window * n_windows + 5, seed=0)
    hist, stream_raw = series[:1600], series[1600:]
    stream_raw = gradual_drift(stream_raw, alphas=np.full(5, 1.5e-3), seed=1)
    scaler = MinMaxScaler.fit(hist)
    stream = WindowedStream(scaler.transform(stream_raw),
                            WindowPlan(n_windows, records_per_window, lag=5))
    return [stream.supervised(w) for w in range(n_windows)]


def _drive(fc, windows, key) -> List[float]:
    """One fc.train per window (cold params each window — the paper's speed
    layer), returning per-window wall seconds."""
    from repro.core.stages import split_chain

    keys = split_chain(key, len(windows))
    walls = []
    for data, k in zip(windows, keys):
        t0 = time.perf_counter()
        fc.train(data, None, k)
        walls.append(time.perf_counter() - t0)
    return walls


def _summary(walls: List[float], retraces: List[int]) -> Dict:
    steady = walls[1:] if len(walls) > 1 else walls
    mean_steady = sum(steady) / len(steady)
    median_steady = sorted(steady)[len(steady) // 2]
    return {
        "per_window_wall_s": walls,
        "retraces_per_window": retraces,
        "first_window_wall_s": walls[0],
        "steady_state_wall_s": mean_steady,
        # at the compiled path's ms scale a single scheduler hiccup skews the
        # mean; cross-path comparisons use the median
        "steady_state_median_s": median_steady,
        "first_vs_steady_ratio": walls[0] / max(mean_steady, 1e-12),
        "windows_per_sec_steady": 1.0 / max(mean_steady, 1e-12),
        "retraces_after_first_window": sum(retraces[1:]),
    }


def _bench_backward_pass(cfg, cfg_fused, batch_size: int, iters: int) -> Dict:
    """Per-train-step ``value_and_grad`` wall: autodiff through the jnp scan
    (the pre-PR-3 training path) vs the fused Pallas VJP — the tentpole's
    backward-pass closure, measured at the paper's speed-layer batch shape."""
    import jax

    from repro.models import lstm as lstm_mod

    c = cfg.lstm
    p = lstm_mod.init_params(cfg, jax.random.PRNGKey(0))
    batch = {
        "x": jax.random.normal(jax.random.PRNGKey(1),
                               (batch_size, c.lag, c.n_features)),
        "y": jax.random.normal(jax.random.PRNGKey(2),
                               (batch_size, c.out_dim)),
    }

    def timed(fn):
        r = fn(p, batch)
        jax.block_until_ready(r)
        t0 = time.perf_counter()
        for _ in range(iters):
            r = fn(p, batch)
        jax.block_until_ready(r)
        return (time.perf_counter() - t0) / iters

    out = {}
    for label, c_ in (("scan_autodiff", cfg), ("fused_vjp", cfg_fused)):
        vg = jax.jit(jax.value_and_grad(
            lambda p, b, c_=c_: lstm_mod.loss_fn(c_, p, b)[0]))
        fwd = jax.jit(lambda p, b, c_=c_: lstm_mod.loss_fn(c_, p, b)[0])
        out[f"{label}_step_s"] = timed(vg)
        out[f"{label}_forward_s"] = timed(fwd)
    out["iters"] = iters
    out["batch_shape"] = [batch_size, c.lag, c.n_features]
    out["fused_vs_scan_step_speedup"] = (
        out["scan_autodiff_step_s"] / max(out["fused_vjp_step_s"], 1e-12))
    return out


def _bench_int8_inference(fc, windows, key, iters: int) -> Dict:
    """Edge-inference closure: predict wall on float params vs the
    int8-synced model (``quantize_tree`` -> ``QTensor`` leaves -> the fused
    ``int8_matmul`` kernel), plus the per-window sync transfer sizes."""
    import jax

    from repro.serving.quantize import quantize_tree, tree_nbytes

    params, _ = fc.train(windows[0], None, key)
    qparams = quantize_tree(params, min_size=64)
    x = windows[-1]["x"]

    def timed(p):
        r = fc.predict(p, x)
        t0 = time.perf_counter()
        for _ in range(iters):
            r = fc.predict(p, x)
        del r
        return (time.perf_counter() - t0) / iters

    nb_f, nb_q = tree_nbytes(params), tree_nbytes(qparams)
    t_float = timed(params)
    t_int8 = timed(qparams)
    return {
        "predict_float_s": t_float,
        "predict_int8_s": t_int8,
        # CI gates this at ~1: on interpret backends (CPU CI) the serving
        # path dequantizes a synced QTensor tree once per install and
        # serves the cached float tree, so quantized predict keeps the 4x
        # sync-transfer win without paying the interpreted per-step qmatmul
        "predict_ratio_int8_vs_float": t_int8 / max(t_float, 1e-12),
        "iters": iters,
        "batch": int(x.shape[0]),
        "model_nbytes_float": nb_f,
        "model_nbytes_int8": nb_q,
        "sync_bytes_ratio": nb_f / max(nb_q, 1),
    }


def run(n_windows: int = 8, records_per_window: int = 250,
        epochs: int = 10, batch_size: int = 64,
        micro_iters: int = 50) -> Dict:
    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.core import lstm_forecaster
    from repro.core.stages import split_chain

    cfg = get_config("lstm-paper")
    cfg_fused = dataclasses.replace(cfg, use_pallas=True)
    windows = _stream_windows(n_windows, records_per_window)
    key = jax.random.PRNGKey(1)

    # -- compiled hot path (scan-autodiff) vs fused-VJP hot path -------------
    # the two paths are driven *interleaved*, window by window, so transient
    # host noise (this is a shared container) biases neither side
    fc = lstm_forecaster(cfg, epochs=epochs, batch_size=batch_size)
    ff = lstm_forecaster(cfg_fused, epochs=epochs, batch_size=batch_size)
    eng, feng = fc.engine, ff.engine
    walls, retraces, seen = [], [], 0
    fwalls, fretraces, fseen = [], [], 0
    for data, k in zip(windows, split_chain(key, n_windows)):
        t0 = time.perf_counter()
        fc.train(data, None, k)
        walls.append(time.perf_counter() - t0)
        retraces.append(eng.retrace_count - seen)
        seen = eng.retrace_count
        t0 = time.perf_counter()
        ff.train(data, None, k)
        fwalls.append(time.perf_counter() - t0)
        fretraces.append(feng.retrace_count - fseen)
        fseen = feng.retrace_count
    compiled = _summary(walls, retraces)
    compiled["shape_buckets"] = eng.cache_size
    fused = _summary(fwalls, fretraces)
    fused["shape_buckets"] = feng.cache_size

    # -- legacy baseline (pre-optimization fit: re-jit every window) ---------
    fl = lstm_forecaster(cfg, epochs=epochs, batch_size=batch_size,
                         compiled=False)
    lwalls = _drive(fl, windows, key)
    # each legacy fit builds a fresh jit, so it retraces every distinct batch
    # shape every window: the full batch plus the ragged tail when n % bs != 0
    # (a sub-batch-size window has only the one ragged shape)
    lretraces = [1 if len(w["x"]) % batch_size == 0 or len(w["x"]) < batch_size
                 else 2 for w in windows]
    legacy = _summary(lwalls, lretraces)

    return {
        "benchmark": "speed_layer_hotpath",
        "config": {
            "model": "lstm-paper",
            "n_windows": n_windows,
            "records_per_window": records_per_window,
            "epochs": epochs,
            "batch_size": batch_size,
        },
        "compiled": compiled,
        "fused_vjp": fused,
        "legacy": legacy,
        "speedup_steady_state": (legacy["steady_state_wall_s"]
                                 / max(compiled["steady_state_wall_s"], 1e-12)),
        "speedup_fused_vs_scan_autodiff": (
            compiled["steady_state_median_s"]
            / max(fused["steady_state_median_s"], 1e-12)),
        "backward_pass": _bench_backward_pass(cfg, cfg_fused, batch_size,
                                              micro_iters),
        "int8_inference": _bench_int8_inference(fc, windows, key,
                                                micro_iters),
    }


def report(res: Dict) -> str:
    c, l, f = res["compiled"], res["legacy"], res["fused_vjp"]
    lines = [
        "# speed-layer hot path: per-window training wall-clock (s)",
        f"{'window':<8}{'compiled':>12}{'fused_vjp':>12}{'legacy':>12}"
        f"{'retraces(c)':>12}",
    ]
    for w, (cw, fw, lw, r) in enumerate(zip(c["per_window_wall_s"],
                                            f["per_window_wall_s"],
                                            l["per_window_wall_s"],
                                            c["retraces_per_window"])):
        lines.append(f"{w:<8}{cw:>12.4f}{fw:>12.4f}{lw:>12.4f}{r:>12}")
    bp, q = res["backward_pass"], res["int8_inference"]
    lines += [
        "",
        f"steady-state wall: compiled {c['steady_state_wall_s']:.4f}s "
        f"({c['windows_per_sec_steady']:.1f} windows/s)  "
        f"fused_vjp {f['steady_state_wall_s']:.4f}s "
        f"({f['windows_per_sec_steady']:.1f} windows/s)  "
        f"legacy {l['steady_state_wall_s']:.4f}s "
        f"({l['windows_per_sec_steady']:.1f} windows/s)",
        f"first-vs-steady ratio: compiled {c['first_vs_steady_ratio']:.1f}x  "
        f"legacy {l['first_vs_steady_ratio']:.1f}x",
        f"retraces after first window: compiled "
        f"{c['retraces_after_first_window']} "
        f"(buckets={c['shape_buckets']})  legacy "
        f"{l['retraces_after_first_window']}",
        f"steady-state speedup vs legacy: {res['speedup_steady_state']:.1f}x",
        f"fused-VJP vs scan-autodiff steady state: "
        f"{res['speedup_fused_vs_scan_autodiff']:.2f}x",
        "",
        "# backward pass (per train step, value_and_grad, "
        f"batch {bp['batch_shape']})",
        f"scan-autodiff step {bp['scan_autodiff_step_s']*1e6:>8.0f}us  "
        f"(fwd {bp['scan_autodiff_forward_s']*1e6:.0f}us)",
        f"fused-VJP     step {bp['fused_vjp_step_s']*1e6:>8.0f}us  "
        f"(fwd {bp['fused_vjp_forward_s']*1e6:.0f}us)   "
        f"step speedup {bp['fused_vs_scan_step_speedup']:.2f}x",
        "",
        f"# int8 edge inference (batch {q['batch']})",
        f"predict: float {q['predict_float_s']*1e3:.2f}ms  "
        f"int8 {q['predict_int8_s']*1e3:.2f}ms  "
        f"(ratio {q['predict_ratio_int8_vs_float']:.2f}x)",
        f"model sync bytes: float {q['model_nbytes_float']}  "
        f"int8 {q['model_nbytes_int8']}  "
        f"({q['sync_bytes_ratio']:.1f}x smaller)",
    ]
    return "\n".join(lines)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="CI-sized run: 4 windows, 3 epochs, 120 records")
    p.add_argument("--windows", type=int, default=None)
    p.add_argument("--epochs", type=int, default=None)
    p.add_argument("--records", type=int, default=None)
    p.add_argument("--out", default="BENCH_hotpath.json")
    args = p.parse_args()

    if args.smoke:
        defaults = dict(n_windows=4, epochs=3, records_per_window=120,
                        micro_iters=15)
    else:
        defaults = dict(n_windows=8, epochs=10, records_per_window=250)
    if args.windows is not None:
        defaults["n_windows"] = args.windows
    if args.epochs is not None:
        defaults["epochs"] = args.epochs
    if args.records is not None:
        defaults["records_per_window"] = args.records

    res = run(**defaults)
    print(report(res))
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
