"""§Roofline report: read the dry-run artifacts (experiments/dryrun/*.json)
and emit the per-(arch x shape x mesh) three-term roofline table with the
dominant bottleneck and MODEL_FLOPS/HLO_FLOPs useful ratio.

model_flops is recomputed here (not read from the artifact) so analytic
fixes do not require re-compiling the sweep.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from repro.configs import get_config, get_shape
from repro.launch import analysis

MITIGATIONS = {
    "compute": "cut redundant matmul work (dispatch einsums, remat policy)",
    "memory": "fuse/flash the attention path; bf16 intermediates; smaller "
              "dispatch groups",
    "collective": "re-shard to cut all-reduce volume (FSDP gather schedule, "
                  "TP axis choice); overlap collectives with compute",
}


def load(dryrun_dir: str = "experiments/dryrun") -> List[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        recs.append(json.load(open(f)))
    return recs


def recompute(rec: dict) -> dict:
    """Roofline row from an artifact, with fresh analytic MODEL_FLOPS."""
    cfg = get_config(rec["arch"])
    shape = get_shape(rec["shape"])
    hs = rec["hlo_summary"]
    summ = analysis.HLOSummary(
        dot_flops=hs["dot_flops_per_chip"],
        traffic_bytes=hs["traffic_bytes_per_chip"],
        collective_bytes=hs["collective_bytes_per_chip"],
        collectives=hs.get("collectives", {}),
        n_while=hs.get("n_while", 0),
        trip_counts=hs.get("trip_counts", []),
        param_bytes=hs.get("param_bytes_per_chip", 0),
        output_bytes=0,
    )
    mf = analysis.model_flops(cfg, shape)
    rl = analysis.roofline(summ, rec["n_chips"], mf)
    hbm = rec.get("bytes_per_device", 0)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": rl.compute_s, "memory_s": rl.memory_s,
        "collective_s": rl.collective_s, "dominant": rl.dominant,
        "useful_ratio": min(rl.useful_ratio, 10.0),
        "bytes_per_device_GB": hbm / 1e9,
        "fits_hbm": hbm <= 16e9,
        "mitigation": MITIGATIONS[rl.dominant],
    }


def report(dryrun_dir: str = "experiments/dryrun", mesh: str = "16x16") -> str:
    recs = load(dryrun_dir)
    lines = [f"# §Roofline: per-chip seconds per step ({mesh} mesh, TPU v5e "
             "constants: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI)"]
    lines.append(
        f"{'arch':<22}{'shape':<13}{'compute_s':>11}{'memory_s':>11}"
        f"{'coll_s':>11}{'dominant':>11}{'useful':>8}{'GB/dev':>8}{'fits':>6}"
    )
    skips = []
    for rec in recs:
        if rec["mesh"] != mesh:
            continue
        if rec["status"] == "skip":
            skips.append(rec)
            continue
        if rec["status"] != "ok":
            lines.append(f"{rec['arch']:<22}{rec['shape']:<13} FAILED")
            continue
        row = recompute(rec)
        lines.append(
            f"{row['arch']:<22}{row['shape']:<13}{row['compute_s']:>11.3e}"
            f"{row['memory_s']:>11.3e}{row['collective_s']:>11.3e}"
            f"{row['dominant']:>11}{row['useful_ratio']:>8.3f}"
            f"{row['bytes_per_device_GB']:>8.1f}"
            f"{'yes' if row['fits_hbm'] else 'NO':>6}"
        )
    if skips:
        lines.append("\n# recorded skips (see DESIGN.md §Arch-applicability)")
        for rec in skips:
            lines.append(f"  {rec['arch']:<22}{rec['shape']:<13} "
                         f"{rec.get('skip_reason', '')[:60]}")
    return "\n".join(lines)


def perf_report(perf_dir: str = "experiments/perf",
                dryrun_dir: str = "experiments/dryrun") -> str:
    """§Perf: paper-faithful baseline vs hillclimb variants (single-pod)."""
    base = {(r["arch"], r["shape"]): r for r in load(dryrun_dir)
            if r["status"] == "ok" and r["mesh"] == "16x16"}
    lines = ["# §Perf variants (single-pod; baselines from experiments/dryrun)"]
    lines.append(f"{'variant':<48}{'compute_s':>11}{'memory_s':>11}"
                 f"{'coll_s':>11}{'dominant':>11}")
    printed_base = set()
    for f in sorted(glob.glob(os.path.join(perf_dir, "*.json"))):
        r = json.load(open(f))
        if r["status"] != "ok":
            continue
        key = (r["arch"], r["shape"])
        if key in base and key not in printed_base:
            printed_base.add(key)
            b = recompute(base[key])
            lines.append(
                f"{r['arch'] + ' x ' + r['shape'] + ' [BASELINE]':<48}"
                f"{b['compute_s']:>11.3e}{b['memory_s']:>11.3e}"
                f"{b['collective_s']:>11.3e}{b['dominant']:>11}"
            )
        row = recompute(r)
        name = os.path.basename(f)[:-5]
        lines.append(
            f"{name:<48}{row['compute_s']:>11.3e}{row['memory_s']:>11.3e}"
            f"{row['collective_s']:>11.3e}{row['dominant']:>11}"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(report())
    print()
    print(report(mesh="2x16x16"))
    print()
    try:
        print(perf_report())
    except Exception as e:  # noqa: BLE001
        print("no perf artifacts:", e)
