"""Shared host-device plumbing for the fleet-scale benchmarks.

XLA fixes its device count at backend initialization, so exposing the
host's cores as devices (``--xla_force_host_platform_device_count``) must
happen *before the first jax import anywhere in the process* — and can
never be changed afterwards.  Every benchmark that shards a stream axis
used to carry its own copy of this dance; they all route through here now:

* :func:`ensure_host_devices` — the in-process shim: append the device-count
  flag to ``XLA_FLAGS`` unless one is already inherited (so an outer harness
  can still pin it).  Call it from ``main()`` before any jax-importing work.
* :func:`subprocess_env` — the sweep cell: an environ copy with the count
  pinned to exactly ``n`` (*replacing* any inherited flag).  Weak-scaling
  sweeps need a fresh process per device count, and the child must not
  inherit the parent's mesh size.
"""
from __future__ import annotations

import os
import re
from typing import Dict, Optional

_FLAG = "--xla_force_host_platform_device_count"


def ensure_host_devices(n: Optional[int] = None) -> int:
    """Expose ``n`` host devices to XLA (default: the machine's core count)
    by appending to ``XLA_FLAGS`` — an inherited device-count flag wins.
    Must run before the first jax import; returns the count requested (the
    inherited one when present)."""
    flags = os.environ.get("XLA_FLAGS", "")
    m = re.search(_FLAG + r"=(\d+)", flags)
    if m:
        return int(m.group(1))
    n = n or os.cpu_count() or 1
    os.environ["XLA_FLAGS"] = (flags + " " if flags else "") + f"{_FLAG}={n}"
    return n


def subprocess_env(n: int) -> Dict[str, str]:
    """An ``os.environ`` copy whose XLA device count is exactly ``n``: any
    inherited ``--xla_force_host_platform_device_count`` is stripped first,
    so a sweep's child processes get the cell's mesh size, not the
    parent's."""
    env = dict(os.environ)
    flags = re.sub(_FLAG + r"=\d+", "", env.get("XLA_FLAGS", "")).strip()
    env["XLA_FLAGS"] = (flags + " " if flags else "") + f"{_FLAG}={n}"
    return env
