"""Elastic placement benchmark: static vs reactive vs proactive placement
under a scripted load spike, tracked as ``BENCH_elastic.json``.

Sections:

* ``parity`` — an elastic run under calm load against static placement:
  the controller observes every tick but never acts, so per-stream window
  RMSE and served answers must match static placement exactly (<= 1e-6);
  train/predict stay at one aggregated dispatch per window.
* ``spike`` — the same scripted spike (heavy serving load + inflated stage
  walls on the 1-worker edge) run three ways: ``static`` (no controller),
  ``reactive`` (queue-EWMA scaling + migration), ``proactive`` (the same
  plus the LSTM load forecaster scaling ahead of the ramp).  Gates:
  p99 answer latency proactive <= reactive <= static, at least one stream
  migrates edge->cloud in the elastic runs, zero dropped windows across
  the migration, and the fleet's aggregated train/predict dispatch
  counters stay at exactly one dispatch per window.
* ``determinism`` — the proactive spike run twice must be byte-identical
  (ledger, forecasts, migration schedule): elastic decisions replay.

    PYTHONPATH=src python -m benchmarks.bench_elastic            # full
    PYTHONPATH=src python -m benchmarks.bench_elastic --smoke    # CI
"""
from __future__ import annotations

import argparse
import json
from typing import Dict

PERIOD = 5.0
CALM_QPS = 6.0
SPIKE_QPS = 12.0


def _spike_costs() -> Dict[str, float]:
    """The scripted spike: serving ticks and per-window inference walls
    heavy enough to saturate the 1-worker edge (deterministic virtual
    walls, identical for all three modes)."""
    from repro.core.scenarios import CHAOS_STAGE_COSTS

    costs = dict(CHAOS_STAGE_COSTS)
    costs["serving"] = 0.2
    costs["speed_inference"] = 0.4
    costs["batch_inference"] = 0.4
    return costs


def _controller_factory(mode: str):
    from repro.runtime import LoadForecaster, PlacementController

    def build():
        return PlacementController(
            proactive=(mode == "proactive"), migrate_up_s=0.8,
            migrate_down_s=0.05, scale_up_s=1.5, scale_down_s=0.05,
            persistence=1, cooldown=2, max_workers=3, min_residency=2,
            forecaster=(LoadForecaster(horizon=3)
                        if mode == "proactive" else None))

    return build


def _executor(pipeline, *, elastic, qps, stage_costs, factory=None):
    from repro.runtime import FleetBusExecutor, paper_topology
    from repro.runtime.deployment import edge_cloud_integrated

    stages, bp, streams, cost = pipeline
    ex = FleetBusExecutor(
        stages, edge_cloud_integrated(), paper_topology(), cost,
        window_period_s=PERIOD, qps=qps, serve_slots=4,
        stage_costs=stage_costs, elastic=elastic,
        controller_factory=factory)
    return ex, streams, bp


def _mode_metrics(res, n_windows: int) -> Dict:
    s = res.serving or {}
    scored = {sid: len(r.records) for sid, r in res.results.items()}
    expected = n_windows - 1  # warmup window is not scored
    p = res.placement or {}
    ctl = p.get("controller") or {}
    return {
        "p99_s": s.get("p99_s", float("inf")),
        "mean_s": s.get("mean_s", None),
        "n_answered": s.get("n_answered", 0),
        "n_starved": s.get("n_starved", 0),
        "windows_scored": sum(scored.values()),
        "dropped_windows": sum(max(0, expected - n) for n in scored.values()),
        "train_dispatches": res.train_dispatches,
        "infer_dispatches": res.infer_dispatches,
        "migrations": p.get("migrations", []),
        "n_migrations": len(p.get("migrations", [])),
        "scale_events": ctl.get("scale_events", 0),
        "proactive_scale_events": ctl.get("proactive_scale_events", 0),
        "final_workers": p.get("final_workers", {}),
        "stream_site": p.get("stream_site", {}),
    }


def run(smoke: bool) -> Dict:
    import jax

    from repro.core.scenarios import forecast_signature, ledger_signature
    from repro.launch.edge_cloud import build_fleet_pipeline

    n_streams, n_windows, rpw = (2, 5, 80) if smoke else (3, 6, 120)
    print(f"building fleet pipeline ({n_streams} streams, {n_windows} "
          f"windows) ...")
    pipeline = build_fleet_pipeline(n_streams, n_windows, fast=True,
                                    records_per_window=rpw,
                                    scenario="gradual", verbose=False)
    key = jax.random.PRNGKey(1)
    spike = _spike_costs()
    from repro.core.scenarios import CHAOS_STAGE_COSTS
    calm = dict(CHAOS_STAGE_COSTS)

    out: Dict = {"config": {
        "smoke": smoke, "n_streams": n_streams, "n_windows": n_windows,
        "records_per_window": rpw, "period_s": PERIOD,
        "calm_qps": CALM_QPS, "spike_qps": SPIKE_QPS,
        "spike_stage_costs": spike,
    }}

    # -- parity: calm elastic == static --------------------------------------
    print("parity: static vs elastic under calm load ...")
    ex, streams, bp = _executor(pipeline, elastic=False, qps=CALM_QPS,
                                stage_costs=calm)
    r_static = ex.run(streams, bp, key)
    ex, _, _ = _executor(pipeline, elastic=True, qps=CALM_QPS,
                         stage_costs=calm)
    r_calm = ex.run(streams, bp, key)
    diffs = [abs(a.rmse_hybrid - b.rmse_hybrid)
             for sid in r_static.results
             for a, b in zip(r_static.results[sid].records,
                             r_calm.results[sid].records)]
    out["parity"] = {
        "rmse_max_abs_diff": max(diffs),
        "forecasts_identical": (forecast_signature(r_static)
                                == forecast_signature(r_calm)),
        "calm_migrations": len(r_calm.placement["migrations"]),
        "train_dispatches": r_calm.train_dispatches,
        "infer_dispatches": r_calm.infer_dispatches,
    }

    # -- the spike, three ways -----------------------------------------------
    out["spike"] = {}
    results = {}
    for mode in ("static", "reactive", "proactive"):
        print(f"spike: {mode} ...")
        if mode == "static":
            ex, _, _ = _executor(pipeline, elastic=False, qps=SPIKE_QPS,
                                 stage_costs=spike)
        else:
            ex, _, _ = _executor(pipeline, elastic=mode, qps=SPIKE_QPS,
                                 stage_costs=spike,
                                 factory=_controller_factory(mode))
        res = ex.run(streams, bp, key)
        results[mode] = res
        out["spike"][mode] = _mode_metrics(res, n_windows)

    # -- determinism ---------------------------------------------------------
    print("determinism: proactive spike x2 ...")
    ex, _, _ = _executor(pipeline, elastic="proactive", qps=SPIKE_QPS,
                         stage_costs=spike,
                         factory=_controller_factory("proactive"))
    r2 = ex.run(streams, bp, key)
    r1 = results["proactive"]
    out["determinism"] = {
        "ledger_identical": ledger_signature(r1) == ledger_signature(r2),
        "forecasts_identical": (forecast_signature(r1)
                                == forecast_signature(r2)),
        "migrations_identical": (r1.placement["migrations"]
                                 == r2.placement["migrations"]),
        "depth_series_identical": all(
            r1.ledger.depth_series(s) == r2.ledger.depth_series(s)
            for s in ("edge", "cloud")),
    }
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer streams/windows)")
    ap.add_argument("--devices", type=int, default=None,
                    help="host devices to expose to XLA (default: the "
                         "machine's core count); the fleet's stream axis "
                         "shards across them")
    ap.add_argument("--out", default="BENCH_elastic.json")
    args = ap.parse_args()

    # before the first lazy jax import below: give the fleet a mesh
    from benchmarks._device_env import ensure_host_devices
    ensure_host_devices(args.devices)

    res = run(args.smoke)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2, sort_keys=True)
    print(f"\nwrote {args.out}")

    p = res["parity"]
    print(f"parity: rmse diff {p['rmse_max_abs_diff']:.2e}, forecasts "
          f"identical: {p['forecasts_identical']}, calm migrations: "
          f"{p['calm_migrations']}")
    for mode, m in res["spike"].items():
        print(f"{mode:>10}: p99 {m['p99_s']:.3f}s, answered "
              f"{m['n_answered']} (starved {m['n_starved']}), migrations "
              f"{m['n_migrations']}, scale events {m['scale_events']} "
              f"({m['proactive_scale_events']} proactive), dropped windows "
              f"{m['dropped_windows']}")
    d = res["determinism"]
    print(f"determinism: ledger {d['ledger_identical']}, forecasts "
          f"{d['forecasts_identical']}, migrations "
          f"{d['migrations_identical']}, depth {d['depth_series_identical']}")


if __name__ == "__main__":
    main()
