"""Fleet-scale speed-layer benchmark: N streams per node, tracked as
``BENCH_fleet.json`` from this PR onward.

The single-stream hot path (``BENCH_hotpath.json``) made one window's
retrain cheap; serving a *fleet* of sensors from one node multiplies every
per-window cost by N unless the fleet trains together.  This benchmark pins
the two fleet properties the executors rely on:

* ``fleet_training`` — per-window wall of the one-dispatch vmapped fleet
  fit (``FleetForecaster.train_fleet``) vs N sequential single-stream
  ``CompiledForecaster`` fits over the same windows and keys, interleaved
  window by window so host noise biases neither side.  Records per-window
  walls, steady-state streams/sec for both paths, the dispatch counts (the
  fleet path must be exactly one per window), the retrace counters (zero
  new traces after each (stream-bucket, shape-bucket)'s first window), and
  the max parameter divergence of fleet-vs-sequential fits (vmap batching
  tolerance, not a semantic difference).

* ``executor_parity`` — a full ``InProcessFleetExecutor`` run (ungated)
  against N sequential ``InProcessExecutor`` runs with the same per-stream
  root keys: max per-window RMSE divergence across every stream, plus the
  fleet run's train-dispatch count.

* ``drift_gated`` — drift-gated retraining vs the paper's every-window
  policy on the stationary and abrupt scenarios: the stationary fleet must
  *skip* retrains (>0, counted), and the abrupt fleet's gated accuracy must
  track the every-window accuracy within tolerance.

    PYTHONPATH=src python -m benchmarks.bench_fleet            # paper-ish
    PYTHONPATH=src python -m benchmarks.bench_fleet --smoke    # CI: seconds
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List


def _fleet_streams(n_streams: int, n_windows: int, records_per_window: int,
                   scenario: str, seed: int = 0):
    """N correlated turbines, each scaled by its own history — the exact
    construction the launcher's fleet mode runs
    (``streams.sources.fleet_windowed_streams``)."""
    import numpy as np

    from repro.streams.sources import fleet_windowed_streams

    alphas = np.full(5, 1.5e-3) if scenario == "gradual" else None
    return fleet_windowed_streams(n_streams, n_windows, records_per_window,
                                  scenario, seed=seed, alphas=alphas)


def _summary(walls: List[float]) -> Dict:
    steady = walls[1:] if len(walls) > 1 else walls
    mean_steady = sum(steady) / len(steady)
    return {
        "per_window_wall_s": walls,
        "first_window_wall_s": walls[0],
        "steady_state_wall_s": mean_steady,
        "steady_state_median_s": sorted(steady)[len(steady) // 2],
    }


def _bench_fleet_training(cfg, streams, epochs: int, batch_size: int,
                          key) -> Dict:
    """The training hot path alone: one-dispatch fleet fit vs N sequential
    single-stream fits, window-interleaved, identical per-stream keys."""
    import jax
    import numpy as np

    from repro.core import lstm_fleet_forecaster, lstm_forecaster
    from repro.runtime import fleet_key_chains

    ids = list(streams)
    n_windows = min(len(s) for s in streams.values())
    keys = fleet_key_chains(key, ids, n_windows)

    ff = lstm_fleet_forecaster(cfg, epochs=epochs, batch_size=batch_size)
    seq = {sid: lstm_forecaster(cfg, epochs=epochs, batch_size=batch_size)
           for sid in ids}

    fwalls, swalls, max_param_diff = [], [], 0.0
    for w in range(n_windows):
        datas = [streams[sid].supervised(w) for sid in ids]
        wkeys = [keys[sid][w] for sid in ids]
        t0 = time.perf_counter()
        fleet_params, _ = ff.train_fleet(datas, wkeys)
        fwalls.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        seq_params = [seq[sid].train(d, None, k)[0]
                      for sid, d, k in zip(ids, datas, wkeys)]
        swalls.append(time.perf_counter() - t0)
        for fp, sp in zip(fleet_params, seq_params):
            for a, b in zip(jax.tree_util.tree_leaves(fp),
                            jax.tree_util.tree_leaves(sp)):
                max_param_diff = max(max_param_diff, float(np.max(np.abs(
                    np.asarray(a) - np.asarray(b)))))

    fleet = _summary(fwalls)
    fleet["dispatches"] = ff.train_dispatches
    fleet["dispatches_per_window"] = ff.train_dispatches / n_windows
    fleet["trace_counts"] = {str(k): v for k, v in ff.trace_counts().items()}
    fleet["retraces_after_first_window"] = ff.retrace_count - len(
        ff.trace_counts())
    fleet["streams_per_sec_steady"] = (
        len(ids) / max(fleet["steady_state_wall_s"], 1e-12))
    sequential = _summary(swalls)
    sequential["dispatches"] = n_windows * len(ids)
    sequential["streams_per_sec_steady"] = (
        len(ids) / max(sequential["steady_state_wall_s"], 1e-12))
    return {
        "fleet": fleet,
        "sequential": sequential,
        "speedup_fleet_vs_sequential": (
            sequential["steady_state_median_s"]
            / max(fleet["steady_state_median_s"], 1e-12)),
        "max_param_abs_diff": max_param_diff,
        "n_windows": n_windows,
        "n_streams": len(ids),
    }


def _bench_executor_parity(cfg, streams, bp, epochs: int, batch_size: int,
                           key) -> Dict:
    """Full fleet run vs N sequential single-stream runs: per-window RMSE
    divergence across every stream and record."""
    import jax

    from repro.core import (
        FleetStages,
        PipelineStages,
        lstm_fleet_forecaster,
        lstm_forecaster,
    )
    from repro.runtime import InProcessExecutor, InProcessFleetExecutor

    ids = list(streams)
    ff = lstm_fleet_forecaster(cfg, epochs=epochs, batch_size=batch_size)
    fleet_res = InProcessFleetExecutor(
        FleetStages.build(ff, mode="dynamic")).run(
            streams, bp, key)

    max_diff = 0.0
    for i, sid in enumerate(ids):
        fc = lstm_forecaster(cfg, epochs=epochs, batch_size=batch_size)
        seq = InProcessExecutor(PipelineStages.build(fc, mode="dynamic")).run(
            streams[sid], bp, jax.random.fold_in(key, i))
        for a, b in zip(seq.records, fleet_res.results[sid].records):
            max_diff = max(
                max_diff,
                abs(a.rmse_batch - b.rmse_batch),
                abs(a.rmse_speed - b.rmse_speed),
                abs(a.rmse_hybrid - b.rmse_hybrid))
    return {
        "rmse_max_abs_diff": max_diff,
        "train_dispatches": fleet_res.train_dispatches,
        "n_windows": fleet_res.n_windows,
        "dispatches_per_window": (fleet_res.train_dispatches
                                  / fleet_res.n_windows),
        "fleet_mean_rmse": fleet_res.mean_rmse(),
    }


def _bench_drift_gated(cfg, bp, n_streams: int, n_windows: int,
                       records_per_window: int, epochs: int, batch_size: int,
                       key) -> Dict:
    """Drift-gated vs every-window retraining on the stationary and abrupt
    scenarios."""
    from repro.core import FleetStages, lstm_fleet_forecaster
    from repro.core.drift import DriftGate
    from repro.runtime import InProcessFleetExecutor

    out = {}
    for scenario in ("none", "abrupt"):
        streams, _ = _fleet_streams(n_streams, n_windows, records_per_window,
                                    scenario)
        runs = {}
        for label, gate in (("every_window", None), ("gated", DriftGate())):
            ff = lstm_fleet_forecaster(cfg, epochs=epochs,
                                       batch_size=batch_size)
            ex = InProcessFleetExecutor(FleetStages.build(ff, mode="dynamic"),
                                        gate=gate)
            res = ex.run(streams, bp, key)
            runs[label] = res
        every, gated = runs["every_window"], runs["gated"]
        out[scenario] = {
            "skipped_retrains": gated.skipped_retrains(),
            "total_retrains": gated.total_retrains(),
            "every_window_retrains": every.total_retrains(),
            "train_dispatches_gated": gated.train_dispatches,
            "train_dispatches_every_window": every.train_dispatches,
            "hybrid_rmse_gated": gated.mean_rmse()["hybrid"],
            "hybrid_rmse_every_window": every.mean_rmse()["hybrid"],
            "speed_rmse_gated": gated.mean_rmse()["speed"],
            "speed_rmse_every_window": every.mean_rmse()["speed"],
            "gate_stats": gated.gate_stats,
        }
        out[scenario]["hybrid_rmse_ratio"] = (
            out[scenario]["hybrid_rmse_gated"]
            / max(out[scenario]["hybrid_rmse_every_window"], 1e-12))
    return out


def run(n_streams: int = 8, n_windows: int = 8,
        records_per_window: int = 250, epochs: int = 10,
        batch_size: int = 64) -> Dict:
    import jax

    from repro.configs import get_config
    from repro.core import lstm_forecaster, pretrain_batch_model

    cfg = get_config("lstm-paper")
    key = jax.random.PRNGKey(1)
    streams, hist0 = _fleet_streams(n_streams, n_windows, records_per_window,
                                    "gradual")
    fc_batch = lstm_forecaster(cfg, epochs=max(epochs // 2, 2),
                               batch_size=256)
    bp, _ = pretrain_batch_model(fc_batch, hist0, jax.random.PRNGKey(0))

    return {
        "benchmark": "fleet_speed_layer",
        "config": {
            "model": "lstm-paper",
            "n_streams": n_streams,
            "n_windows": n_windows,
            "records_per_window": records_per_window,
            "epochs": epochs,
            "batch_size": batch_size,
        },
        "fleet_training": _bench_fleet_training(cfg, streams, epochs,
                                                batch_size, key),
        "executor_parity": _bench_executor_parity(cfg, streams, bp, epochs,
                                                  batch_size, key),
        "drift_gated": _bench_drift_gated(cfg, bp, n_streams, n_windows,
                                          records_per_window, epochs,
                                          batch_size, key),
    }


def report(res: Dict) -> str:
    tr, par, dg = (res["fleet_training"], res["executor_parity"],
                   res["drift_gated"])
    f, s = tr["fleet"], tr["sequential"]
    lines = [
        f"# fleet speed layer: {tr['n_streams']} streams, "
        f"{tr['n_windows']} windows, per-window training wall (s)",
        f"{'window':<8}{'fleet(1 dispatch)':>18}{'sequential(xN)':>16}",
    ]
    for w, (fw, sw) in enumerate(zip(f["per_window_wall_s"],
                                     s["per_window_wall_s"])):
        lines.append(f"{w:<8}{fw:>18.4f}{sw:>16.4f}")
    lines += [
        "",
        f"steady state: fleet {f['steady_state_wall_s']:.4f}s "
        f"({f['streams_per_sec_steady']:.1f} streams/s)  sequential "
        f"{s['steady_state_wall_s']:.4f}s "
        f"({s['streams_per_sec_steady']:.1f} streams/s)  "
        f"speedup {tr['speedup_fleet_vs_sequential']:.2f}x",
        f"fleet dispatches: {f['dispatches']} "
        f"({f['dispatches_per_window']:.2f}/window; sequential pays "
        f"{s['dispatches']})",
        f"retraces after first window per bucket: "
        f"{f['retraces_after_first_window']}  (buckets: {f['trace_counts']})",
        f"fleet-vs-sequential max param diff: {tr['max_param_abs_diff']:.2e}",
        "",
        "# executor parity (fleet run vs N sequential single-stream runs)",
        f"max per-window RMSE divergence: {par['rmse_max_abs_diff']:.2e}",
        f"train dispatches: {par['train_dispatches']} "
        f"({par['dispatches_per_window']:.2f}/window)",
        "",
        "# drift-gated retraining vs every-window",
    ]
    for scenario, d in dg.items():
        lines.append(
            f"{scenario:<10} retrains {d['total_retrains']}"
            f"/{d['every_window_retrains']} (skipped "
            f"{d['skipped_retrains']}), dispatches "
            f"{d['train_dispatches_gated']}"
            f"/{d['train_dispatches_every_window']}, hybrid RMSE "
            f"{d['hybrid_rmse_gated']:.4f} vs "
            f"{d['hybrid_rmse_every_window']:.4f} "
            f"(ratio {d['hybrid_rmse_ratio']:.3f})")
    return "\n".join(lines)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="CI-sized run: 4 streams, 4 windows, 3 epochs, "
                        "120 records")
    p.add_argument("--streams", type=int, default=None)
    p.add_argument("--windows", type=int, default=None)
    p.add_argument("--epochs", type=int, default=None)
    p.add_argument("--records", type=int, default=None)
    p.add_argument("--out", default="BENCH_fleet.json")
    args = p.parse_args()

    if args.smoke:
        defaults = dict(n_streams=4, n_windows=4, epochs=3,
                        records_per_window=120)
    else:
        defaults = dict(n_streams=8, n_windows=8, epochs=10,
                        records_per_window=250)
    if args.streams is not None:
        defaults["n_streams"] = args.streams
    if args.windows is not None:
        defaults["n_windows"] = args.windows
    if args.epochs is not None:
        defaults["epochs"] = args.epochs
    if args.records is not None:
        defaults["records_per_window"] = args.records

    res = run(**defaults)
    print(report(res))
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
