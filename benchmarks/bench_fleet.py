"""Fleet-scale speed-layer benchmark: N streams per node, tracked as
``BENCH_fleet.json`` from this PR onward.

The single-stream hot path (``BENCH_hotpath.json``) made one window's
retrain cheap; serving a *fleet* of sensors from one node multiplies every
per-window cost by N unless the fleet trains — and serves — together.
This benchmark pins the fleet properties the executors rely on:

* ``fleet_training`` — per-window wall of the one-dispatch fleet fit
  (``FleetForecaster.train_fleet``: staged device buffers, donated
  opt-state, stream axis sharded across the local device mesh) vs N
  sequential single-stream ``CompiledForecaster`` fits over the same
  windows and keys, interleaved window by window so host noise biases
  neither side.  Both sides report wall/stream and dispatches/sec **from
  the same per-window clock** (time until trained params are
  device-resident and ready), the dispatch counts (the fleet path must be
  exactly one per window), the retrace + staging-allocation counters (zero
  new traces, zero host re-stacks after each bucket's first window), and
  the max parameter divergence of fleet-vs-sequential fits (vmap batching
  tolerance, not a semantic difference).

* ``fleet_inference`` — the serving counterpart: one vmapped
  ``predict_fleet`` dispatch per window vs N sequential per-stream
  predicts, same clock; per-stream parity (<=1e-6), and the int8 fleet
  sync numbers (per-stream sync bytes float-vs-int8, batched int8 predict
  wall).

* ``executor_parity`` — a full ``InProcessFleetExecutor`` run (ungated)
  against N sequential ``InProcessExecutor`` runs with the same per-stream
  root keys: max per-window RMSE divergence across every stream, plus the
  fleet run's train-dispatch count.

* ``drift_gated`` — drift-gated retraining vs the paper's every-window
  policy on the stationary and abrupt scenarios: the stationary fleet must
  *skip* retrains (>0, counted), and the abrupt fleet's gated accuracy must
  track the every-window accuracy within tolerance.

* ``batch_refresh`` — the cloud-side heavy-retraining path riding the same
  sharded dispatch: a gated run with a ``BatchRefresh`` stage (batch models
  retrained from archived drifted windows on a cadence, one fleet dispatch
  per refresh round) vs the same run without, refresh dispatch accounting
  CI-gated.

* ``weak_scaling`` — the thousand-stream sweep: wall/stream and dispatch
  overhead at S x device-count cells, each cell a fresh subprocess with its
  XLA device count pinned (``benchmarks._device_env.subprocess_env``; the
  count is fixed at backend init, so a sweep cannot run in-process).  Every
  cell must hold one dispatch per window and zero retraces after its first
  window; sampled streams must match the unsharded sequential path to 1e-6
  and agree across device counts; and wall/stream at the largest S must
  stay within 1.5x of the 8-stream baseline (overhead amortizes, compute
  weak-scales).

The process exposes the host's cores as XLA devices
(``--xla_force_host_platform_device_count``) before touching jax, so the
fleet paths shard their stream axis across the mesh — the configuration a
fleet node actually runs, and the one the tracked numbers come from.

    PYTHONPATH=src python -m benchmarks.bench_fleet            # paper-ish
    PYTHONPATH=src python -m benchmarks.bench_fleet --smoke    # CI: seconds
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from typing import Dict, List, Optional

from benchmarks._device_env import ensure_host_devices, subprocess_env


def _fleet_streams(n_streams: int, n_windows: int, records_per_window: int,
                   scenario: str, seed: int = 0):
    """N correlated turbines, each scaled by its own history — the exact
    construction the launcher's fleet mode runs
    (``streams.sources.fleet_windowed_streams``)."""
    import numpy as np

    from repro.streams.sources import fleet_windowed_streams

    alphas = np.full(5, 1.5e-3) if scenario == "gradual" else None
    return fleet_windowed_streams(n_streams, n_windows, records_per_window,
                                  scenario, seed=seed, alphas=alphas)


def _summary(walls: List[float], n_streams: int,
             dispatches_per_window: float) -> Dict:
    """Per-window wall statistics plus the two rates the fleet-vs-sequential
    comparison is made in: wall/stream and dispatches/sec, both derived from
    the same per-window clock (median steady-state wall)."""
    steady = walls[1:] if len(walls) > 1 else walls
    mean_steady = sum(steady) / len(steady)
    median = sorted(steady)[len(steady) // 2]
    return {
        "per_window_wall_s": walls,
        "first_window_wall_s": walls[0],
        "steady_state_wall_s": mean_steady,
        "steady_state_median_s": median,
        "wall_per_stream_steady_s": median / n_streams,
        "dispatches_per_sec_steady": dispatches_per_window / max(median,
                                                                 1e-12),
    }


def _bench_fleet_training(cfg, streams, epochs: int, batch_size: int,
                          key) -> Dict:
    """The training hot path alone: one-dispatch fleet fit vs N sequential
    single-stream fits, window-interleaved, identical per-stream keys."""
    import jax
    import numpy as np

    from repro.core import lstm_fleet_forecaster, lstm_forecaster
    from repro.runtime import fleet_key_chains

    ids = list(streams)
    n_windows = min(len(s) for s in streams.values())
    keys = fleet_key_chains(key, ids, n_windows)

    ff = lstm_fleet_forecaster(cfg, epochs=epochs, batch_size=batch_size)
    seq = {sid: lstm_forecaster(cfg, epochs=epochs, batch_size=batch_size)
           for sid in ids}

    fwalls, swalls, max_param_diff = [], [], 0.0
    for w in range(n_windows):
        datas = [streams[sid].supervised(w) for sid in ids]
        wkeys = [keys[sid][w] for sid in ids]
        t0 = time.perf_counter()
        fleet_params, _ = ff.train_fleet(datas, wkeys)
        fwalls.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        seq_params = [seq[sid].train(d, None, k)[0]
                      for sid, d, k in zip(ids, datas, wkeys)]
        swalls.append(time.perf_counter() - t0)
        for fp, sp in zip(fleet_params, seq_params):
            for a, b in zip(jax.tree_util.tree_leaves(fp),
                            jax.tree_util.tree_leaves(sp)):
                max_param_diff = max(max_param_diff, float(np.max(np.abs(
                    np.asarray(a) - np.asarray(b)))))

    fleet = _summary(fwalls, len(ids), ff.train_dispatches / n_windows)
    fleet["dispatches"] = ff.train_dispatches
    fleet["dispatches_per_window"] = ff.train_dispatches / n_windows
    fleet["trace_counts"] = {str(k): v for k, v in ff.trace_counts().items()}
    fleet["retraces_after_first_window"] = ff.retrace_count - len(
        ff.trace_counts())
    fleet["staging_allocs"] = ff.staging_allocs
    sequential = _summary(swalls, len(ids), float(len(ids)))
    sequential["dispatches"] = n_windows * len(ids)
    return {
        "fleet": fleet,
        "sequential": sequential,
        "speedup_fleet_vs_sequential": (
            sequential["steady_state_median_s"]
            / max(fleet["steady_state_median_s"], 1e-12)),
        "max_param_abs_diff": max_param_diff,
        "n_windows": n_windows,
        "n_streams": len(ids),
        "devices": _device_count(),
    }


def _device_count() -> int:
    import jax

    return jax.device_count()


def _bench_fleet_inference(cfg, streams, epochs: int, batch_size: int,
                           key) -> Dict:
    """The serving hot path: one vmapped ``predict_fleet`` dispatch per
    window vs N sequential per-stream predicts (same params, same windows,
    same clock), plus the int8 fleet-sync numbers."""
    import numpy as np

    from repro.core import lstm_fleet_forecaster
    from repro.runtime import fleet_key_chains
    from repro.serving.quantize import quantize_tree, tree_nbytes
    from repro.training.compiled import materialize_params

    ids = list(streams)
    n_windows = min(len(s) for s in streams.values())
    keys = fleet_key_chains(key, ids, n_windows)
    ff = lstm_fleet_forecaster(cfg, epochs=epochs, batch_size=batch_size)
    params, _ = ff.train_fleet(
        [streams[sid].supervised(0) for sid in ids],
        [keys[sid][0] for sid in ids])

    d0 = ff.predict_dispatches
    fwalls, swalls, parity = [], [], 0.0
    for w in range(n_windows):
        xs = [streams[sid].supervised(w)["x"] for sid in ids]
        t0 = time.perf_counter()
        fleet_preds = ff.predict_fleet(params, xs)
        fwalls.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        seq_preds = [ff.single.predict(p, x) for p, x in zip(params, xs)]
        swalls.append(time.perf_counter() - t0)
        for a, b in zip(fleet_preds, seq_preds):
            parity = max(parity, float(np.max(np.abs(a - b))))
    float_dispatches = ff.predict_dispatches - d0

    qparams = [quantize_tree(p, min_size=64) for p in params]
    qwalls = []
    for w in range(n_windows):
        xs = [streams[sid].supervised(w)["x"] for sid in ids]
        t0 = time.perf_counter()
        ff.predict_fleet(qparams, xs)
        qwalls.append(time.perf_counter() - t0)

    fleet = _summary(fwalls, len(ids), float_dispatches / n_windows)
    fleet["dispatches"] = float_dispatches
    fleet["dispatches_per_window"] = float_dispatches / n_windows
    sequential = _summary(swalls, len(ids), float(len(ids)))
    sequential["dispatches"] = n_windows * len(ids)
    sequential["dispatches_per_window"] = float(len(ids))
    float_bytes = tree_nbytes(materialize_params(params[0]))
    int8_bytes = tree_nbytes(qparams[0])
    return {
        "fleet": fleet,
        "sequential": sequential,
        "speedup_fleet_vs_sequential": (
            sequential["steady_state_median_s"]
            / max(fleet["steady_state_median_s"], 1e-12)),
        "per_stream_parity_max_abs_diff": parity,
        "predict_trace_counts": {str(k): v
                                 for k, v in ff.predict_trace_counts().items()},
        "int8_sync": {
            "steady_state_median_s": _summary(qwalls, len(ids), 1.0)[
                "steady_state_median_s"],
            "sync_bytes_float_per_stream": float_bytes,
            "sync_bytes_int8_per_stream": int8_bytes,
            "transfer_ratio": float_bytes / max(int8_bytes, 1),
        },
        "n_windows": n_windows,
        "n_streams": len(ids),
        "devices": _device_count(),
    }


def _bench_executor_parity(cfg, streams, bp, epochs: int, batch_size: int,
                           key) -> Dict:
    """Full fleet run vs N sequential single-stream runs: per-window RMSE
    divergence across every stream and record."""
    import jax

    from repro.core import (
        FleetStages,
        PipelineStages,
        lstm_fleet_forecaster,
        lstm_forecaster,
    )
    from repro.runtime import InProcessExecutor, InProcessFleetExecutor

    ids = list(streams)
    ff = lstm_fleet_forecaster(cfg, epochs=epochs, batch_size=batch_size)
    fleet_res = InProcessFleetExecutor(
        FleetStages.build(ff, mode="dynamic")).run(
            streams, bp, key)

    max_diff = 0.0
    for i, sid in enumerate(ids):
        fc = lstm_forecaster(cfg, epochs=epochs, batch_size=batch_size)
        seq = InProcessExecutor(PipelineStages.build(fc, mode="dynamic")).run(
            streams[sid], bp, jax.random.fold_in(key, i))
        for a, b in zip(seq.records, fleet_res.results[sid].records):
            max_diff = max(
                max_diff,
                abs(a.rmse_batch - b.rmse_batch),
                abs(a.rmse_speed - b.rmse_speed),
                abs(a.rmse_hybrid - b.rmse_hybrid))
    return {
        "rmse_max_abs_diff": max_diff,
        "train_dispatches": fleet_res.train_dispatches,
        "n_windows": fleet_res.n_windows,
        "dispatches_per_window": (fleet_res.train_dispatches
                                  / fleet_res.n_windows),
        "fleet_mean_rmse": fleet_res.mean_rmse(),
    }


def _bench_drift_gated(cfg, bp, n_streams: int, n_windows: int,
                       records_per_window: int, epochs: int, batch_size: int,
                       key) -> Dict:
    """Drift-gated vs every-window retraining on the stationary and abrupt
    scenarios."""
    from repro.core import FleetStages, lstm_fleet_forecaster
    from repro.core.drift import DriftGate
    from repro.runtime import InProcessFleetExecutor

    out = {}
    for scenario in ("none", "abrupt"):
        streams, _ = _fleet_streams(n_streams, n_windows, records_per_window,
                                    scenario)
        runs = {}
        for label, gate in (("every_window", None), ("gated", DriftGate())):
            ff = lstm_fleet_forecaster(cfg, epochs=epochs,
                                       batch_size=batch_size)
            ex = InProcessFleetExecutor(FleetStages.build(ff, mode="dynamic"),
                                        gate=gate)
            res = ex.run(streams, bp, key)
            runs[label] = res
        every, gated = runs["every_window"], runs["gated"]
        out[scenario] = {
            "skipped_retrains": gated.skipped_retrains(),
            "total_retrains": gated.total_retrains(),
            "every_window_retrains": every.total_retrains(),
            "train_dispatches_gated": gated.train_dispatches,
            "train_dispatches_every_window": every.train_dispatches,
            "hybrid_rmse_gated": gated.mean_rmse()["hybrid"],
            "hybrid_rmse_every_window": every.mean_rmse()["hybrid"],
            "speed_rmse_gated": gated.mean_rmse()["speed"],
            "speed_rmse_every_window": every.mean_rmse()["speed"],
            "gate_stats": gated.gate_stats,
        }
        out[scenario]["hybrid_rmse_ratio"] = (
            out[scenario]["hybrid_rmse_gated"]
            / max(out[scenario]["hybrid_rmse_every_window"], 1e-12))
    return out


def _bench_batch_refresh(cfg, bp, n_streams: int, n_windows: int,
                         records_per_window: int, epochs: int,
                         batch_size: int, key) -> Dict:
    """The cloud-side heavy-retraining path riding the fleet dispatch: a
    drift-gated run with a ``BatchRefresh`` stage (batch models retrained
    from archived drifted windows, one sharded fleet dispatch per refresh
    round) against the same gated run without one, on the abrupt
    scenario."""
    from repro.core import FleetStages, lstm_fleet_forecaster
    from repro.core.drift import DriftGate
    from repro.core.stages import BatchRefresh
    from repro.runtime import InProcessFleetExecutor

    streams, _ = _fleet_streams(n_streams, n_windows, records_per_window,
                                "abrupt")
    runs = {}
    for label in ("gated", "gated_refresh"):
        ff = lstm_fleet_forecaster(cfg, epochs=epochs, batch_size=batch_size)
        rf = (BatchRefresh(ff, every=2, min_windows=2)
              if label == "gated_refresh" else None)
        ex = InProcessFleetExecutor(FleetStages.build(ff, mode="dynamic"),
                                    gate=DriftGate(), batch_refresh=rf)
        runs[label] = ex.run(streams, bp, key)
    base, ref = runs["gated"], runs["gated_refresh"]
    rounds = max(ref.refresh["rounds"], 1)
    return {
        "refresh": ref.refresh,
        "dispatches_per_round": ref.refresh["dispatches"] / rounds,
        "train_dispatches": ref.train_dispatches,
        "train_dispatches_baseline": base.train_dispatches,
        "n_windows": ref.n_windows,
        "hybrid_rmse_refresh": ref.mean_rmse()["hybrid"],
        "hybrid_rmse_baseline": base.mean_rmse()["hybrid"],
        "batch_rmse_refresh": ref.mean_rmse()["batch"],
        "batch_rmse_baseline": base.mean_rmse()["batch"],
    }


# ---------------------------------------------------------------------------
# Weak scaling: wall/stream and dispatch overhead, S x devices, one
# subprocess per cell (XLA fixes its device count at backend init)
# ---------------------------------------------------------------------------


def _weak_cell(spec: Dict) -> Dict:
    """One (n_streams, devices) cell, run inside a child process whose XLA
    device count the parent pinned via ``subprocess_env``: W windows of the
    one-dispatch fleet fit over synthetic per-stream windows (deterministic
    per (seed, stream, window) — identical data in every cell), plus the
    two per-cell correctness probes the sweep gates on:

    * parity vs the unsharded path — sampled streams (first/middle/last)
      refit sequentially through ``CompiledForecaster`` with the same keys;
    * probe predictions — the sampled streams' materialized params predict
      a fixed probe batch, serialized so the parent can compare the *same*
      stream's prediction across device counts."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core import lstm_fleet_forecaster, lstm_forecaster
    from repro.runtime import fleet_key_chains
    from repro.training.compiled import (
        bucket_streams,
        materialize_params,
        stream_mesh_devices,
    )

    S, W = spec["n_streams"], spec["n_windows"]
    n, epochs, bs = spec["examples"], spec["epochs"], spec["batch_size"]
    seed = spec["seed"]
    cfg = get_config("lstm-paper")
    ids = [f"s{i:04d}" for i in range(S)]
    keys = fleet_key_chains(jax.random.PRNGKey(seed), ids, W)

    def window(i, w):
        rng = np.random.default_rng(seed * 1_000_003 + i * 9176 + w)
        x = rng.normal(0, 1, (n, 5, 5)).astype(np.float32)
        y = x[:, :, 0].mean(axis=1, keepdims=True).astype(np.float32)
        return {"x": x, "y": y}

    ff = lstm_fleet_forecaster(cfg, epochs=epochs, batch_size=bs)
    walls, last_params = [], None
    for w in range(W):
        datas = [window(i, w) for i in range(S)]
        wkeys = [keys[sid][w] for sid in ids]
        t0 = time.perf_counter()
        last_params, _ = ff.train_fleet(datas, wkeys)
        walls.append(time.perf_counter() - t0)

    sample = sorted({0, S // 2, S - 1})
    parity, w = 0.0, W - 1
    for i in sample:
        fc = lstm_forecaster(cfg, epochs=epochs, batch_size=bs)
        sp, _ = fc.train(window(i, w), None, keys[ids[i]][w])
        for a, b in zip(jax.tree_util.tree_leaves(sp),
                        jax.tree_util.tree_leaves(last_params[i])):
            parity = max(parity, float(np.max(np.abs(
                np.asarray(a) - np.asarray(b)))))

    probe_rng = np.random.default_rng(seed + 777_777)
    probe_x = probe_rng.normal(0, 1, (4, 5, 5)).astype(np.float32)
    probe = [np.asarray(ff.single.predict(
        materialize_params(last_params[i]), probe_x)).tolist()
        for i in sample]

    steady = walls[1:] if len(walls) > 1 else walls
    med = sorted(steady)[len(steady) // 2]
    sb = bucket_streams(S)
    return {
        "n_streams": S,
        "devices": jax.device_count(),
        "mesh_devices": len(stream_mesh_devices(sb)),
        "stream_bucket": sb,
        "per_window_wall_s": walls,
        "steady_state_median_s": med,
        "wall_per_stream_steady_s": med / S,
        "dispatches": ff.train_dispatches,
        "dispatches_per_window": ff.train_dispatches / W,
        "executables": len(ff.trace_counts()),
        "retraces_after_first_window": (ff.retrace_count
                                        - len(ff.trace_counts())),
        "parity_streams": sample,
        "parity_max_abs_diff": parity,
        "probe_preds": probe,
    }


def _run_weak_cell(spec: Dict, n_devices: int) -> Dict:
    """Launch one sweep cell in a fresh process with its device count
    pinned, and parse the cell JSON it prints."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = subprocess_env(n_devices)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_fleet",
         "--weak-cell", json.dumps(spec)],
        env=env, cwd=root, capture_output=True, text=True)
    if proc.returncode != 0:
        raise RuntimeError(
            f"weak-scaling cell {spec} on {n_devices} device(s) failed:\n"
            f"{proc.stderr[-2000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _bench_weak_scaling(streams_list: List[int], devices_list: List[int],
                        *, n_windows: int = 5, epochs: int = 2,
                        batch_size: int = 32, examples: int = 32,
                        seed: int = 0) -> Dict:
    """The thousand-stream weak-scaling sweep: every (S, devices) cell in
    its own process (XLA fixes the device count per process), aggregated
    into the properties CI gates — one dispatch per window at every scale,
    per-stream parity vs the unsharded path, cross-device probe agreement,
    and wall/stream at the largest S within 1.5x of the 8-stream
    baseline."""
    import numpy as np

    cells = []
    for d in devices_list:
        for S in streams_list:
            spec = dict(n_streams=S, n_windows=n_windows, epochs=epochs,
                        batch_size=batch_size, examples=examples, seed=seed)
            cells.append(_run_weak_cell(spec, d))
    by = {(c["n_streams"], c["devices"]): c for c in cells}
    base_S, top_S = min(streams_list), max(streams_list)
    ratios = {
        str(d): (by[(top_S, d)]["wall_per_stream_steady_s"]
                 / max(by[(base_S, d)]["wall_per_stream_steady_s"], 1e-12))
        for d in devices_list}
    cross = {}
    for S in streams_list:
        preds = [np.asarray(by[(S, d)]["probe_preds"], dtype=np.float64)
                 for d in devices_list]
        cross[str(S)] = (float(max(np.max(np.abs(p - preds[0]))
                                   for p in preds[1:]))
                         if len(preds) > 1 else 0.0)
    return {
        "streams": streams_list,
        "devices": devices_list,
        "cell_config": {"n_windows": n_windows, "epochs": epochs,
                        "batch_size": batch_size,
                        "examples_per_window": examples, "seed": seed},
        "cells": cells,
        "wall_per_stream_steady_s": {
            str(d): {str(S): by[(S, d)]["wall_per_stream_steady_s"]
                     for S in streams_list}
            for d in devices_list},
        "weak_scaling_ratio": ratios,
        "weak_scaling_ratio_worst": max(ratios.values()),
        "dispatches_per_window_max": max(c["dispatches_per_window"]
                                         for c in cells),
        "retraces_after_first_window_total": sum(
            c["retraces_after_first_window"] for c in cells),
        "parity_max_abs_diff": max(c["parity_max_abs_diff"] for c in cells),
        "cross_device_probe_max_abs_diff": cross,
        "cross_device_probe_worst": max(cross.values()),
    }


def run(n_streams: int = 8, n_windows: int = 8,
        records_per_window: int = 250, epochs: int = 10,
        batch_size: int = 64,
        weak_streams: Optional[List[int]] = None,
        weak_devices: Optional[List[int]] = None) -> Dict:
    import jax

    from repro.configs import get_config
    from repro.core import lstm_forecaster, pretrain_batch_model

    cfg = get_config("lstm-paper")
    key = jax.random.PRNGKey(1)
    streams, hist0 = _fleet_streams(n_streams, n_windows, records_per_window,
                                    "gradual")
    fc_batch = lstm_forecaster(cfg, epochs=max(epochs // 2, 2),
                               batch_size=256)
    bp, _ = pretrain_batch_model(fc_batch, hist0, jax.random.PRNGKey(0))

    return {
        "benchmark": "fleet_speed_layer",
        "config": {
            "model": "lstm-paper",
            "n_streams": n_streams,
            "n_windows": n_windows,
            "records_per_window": records_per_window,
            "epochs": epochs,
            "batch_size": batch_size,
        },
        "fleet_training": _bench_fleet_training(cfg, streams, epochs,
                                                batch_size, key),
        "fleet_inference": _bench_fleet_inference(cfg, streams, epochs,
                                                  batch_size, key),
        "executor_parity": _bench_executor_parity(cfg, streams, bp, epochs,
                                                  batch_size, key),
        "drift_gated": _bench_drift_gated(cfg, bp, n_streams, n_windows,
                                          records_per_window, epochs,
                                          batch_size, key),
        "batch_refresh": _bench_batch_refresh(cfg, bp, n_streams, n_windows,
                                              records_per_window, epochs,
                                              batch_size, key),
        "weak_scaling": _bench_weak_scaling(
            weak_streams or [8, 64, 256, 1024],
            weak_devices or [1, 2, 4, 8]),
    }


def report(res: Dict) -> str:
    tr, fi, par, dg = (res["fleet_training"], res["fleet_inference"],
                       res["executor_parity"], res["drift_gated"])
    f, s = tr["fleet"], tr["sequential"]
    lines = [
        f"# fleet speed layer: {tr['n_streams']} streams, "
        f"{tr['n_windows']} windows, {tr['devices']} device(s), "
        f"per-window training wall (s)",
        f"{'window':<8}{'fleet(1 dispatch)':>18}{'sequential(xN)':>16}",
    ]
    for w, (fw, sw) in enumerate(zip(f["per_window_wall_s"],
                                     s["per_window_wall_s"])):
        lines.append(f"{w:<8}{fw:>18.4f}{sw:>16.4f}")
    lines += [
        "",
        f"steady state (median): fleet {f['steady_state_median_s']:.4f}s "
        f"({f['wall_per_stream_steady_s'] * 1e3:.1f} ms/stream, "
        f"{f['dispatches_per_sec_steady']:.1f} dispatch/s)  sequential "
        f"{s['steady_state_median_s']:.4f}s "
        f"({s['wall_per_stream_steady_s'] * 1e3:.1f} ms/stream, "
        f"{s['dispatches_per_sec_steady']:.1f} dispatch/s)  "
        f"speedup {tr['speedup_fleet_vs_sequential']:.2f}x",
        f"fleet dispatches: {f['dispatches']} "
        f"({f['dispatches_per_window']:.2f}/window; sequential pays "
        f"{s['dispatches']})",
        f"retraces after first window per bucket: "
        f"{f['retraces_after_first_window']}  (buckets: {f['trace_counts']})",
        f"staging-buffer allocations (whole run): {f['staging_allocs']}",
        f"fleet-vs-sequential max param diff: {tr['max_param_abs_diff']:.2e}",
        "",
        "# fleet inference (one vmapped predict vs N sequential predicts)",
        f"steady state (median): fleet "
        f"{fi['fleet']['steady_state_median_s'] * 1e3:.2f}ms "
        f"(1 dispatch/window)  sequential "
        f"{fi['sequential']['steady_state_median_s'] * 1e3:.2f}ms "
        f"({fi['n_streams']} dispatches/window)  "
        f"speedup {fi['speedup_fleet_vs_sequential']:.2f}x",
        f"per-stream parity: {fi['per_stream_parity_max_abs_diff']:.2e}",
        f"int8 sync: {fi['int8_sync']['sync_bytes_int8_per_stream']:.0f} B"
        f"/stream vs {fi['int8_sync']['sync_bytes_float_per_stream']:.0f} B "
        f"float ({fi['int8_sync']['transfer_ratio']:.1f}x smaller), "
        f"batched int8 predict "
        f"{fi['int8_sync']['steady_state_median_s'] * 1e3:.2f}ms",
        "",
        "# executor parity (fleet run vs N sequential single-stream runs)",
        f"max per-window RMSE divergence: {par['rmse_max_abs_diff']:.2e}",
        f"train dispatches: {par['train_dispatches']} "
        f"({par['dispatches_per_window']:.2f}/window)",
        "",
        "# drift-gated retraining vs every-window",
    ]
    for scenario, d in dg.items():
        lines.append(
            f"{scenario:<10} retrains {d['total_retrains']}"
            f"/{d['every_window_retrains']} (skipped "
            f"{d['skipped_retrains']}), dispatches "
            f"{d['train_dispatches_gated']}"
            f"/{d['train_dispatches_every_window']}, hybrid RMSE "
            f"{d['hybrid_rmse_gated']:.4f} vs "
            f"{d['hybrid_rmse_every_window']:.4f} "
            f"(ratio {d['hybrid_rmse_ratio']:.3f})")
    br = res["batch_refresh"]
    lines += [
        "",
        "# batch-model refresh from archived drifted windows (abrupt)",
        f"rounds {br['refresh']['rounds']}, dispatches "
        f"{br['refresh']['dispatches']} "
        f"({br['dispatches_per_round']:.2f}/round), refreshed streams "
        f"{sorted(br['refresh']['refreshed'])}",
        f"batch RMSE {br['batch_rmse_refresh']:.4f} vs "
        f"{br['batch_rmse_baseline']:.4f} unrefreshed; hybrid "
        f"{br['hybrid_rmse_refresh']:.4f} vs "
        f"{br['hybrid_rmse_baseline']:.4f}",
        "",
        "# weak scaling (one subprocess per cell; wall/stream, steady "
        "median)",
    ]
    ws = res["weak_scaling"]
    lines.append(f"{'streams':<10}" + "".join(
        f"{str(d) + ' dev (ms)':>14}" for d in ws["devices"]))
    for S in ws["streams"]:
        row = f"{S:<10}"
        for d in ws["devices"]:
            wps = ws["wall_per_stream_steady_s"][str(d)][str(S)]
            row += f"{wps * 1e3:>14.3f}"
        lines.append(row)
    lines += [
        f"weak-scaling ratio (wall/stream at S={max(ws['streams'])} vs "
        f"S={min(ws['streams'])}): worst "
        f"{ws['weak_scaling_ratio_worst']:.3f} across device counts",
        f"dispatches/window max {ws['dispatches_per_window_max']:.2f}, "
        f"retraces after first window {ws['retraces_after_first_window_total']}",
        f"parity vs unsharded path: {ws['parity_max_abs_diff']:.2e}; "
        f"cross-device probe agreement: "
        f"{ws['cross_device_probe_worst']:.2e}",
    ]
    return "\n".join(lines)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="CI-sized run: 4 streams, 4 windows, 3 epochs, "
                        "120 records")
    p.add_argument("--streams", type=int, default=None)
    p.add_argument("--windows", type=int, default=None)
    p.add_argument("--epochs", type=int, default=None)
    p.add_argument("--records", type=int, default=None)
    p.add_argument("--devices", type=int, default=None,
                   help="host devices to expose to XLA (default: the "
                        "machine's core count); the fleet paths shard "
                        "their stream axis across them")
    p.add_argument("--weak-cell", default=None, metavar="SPEC_JSON",
                   help=argparse.SUPPRESS)  # sweep child-process mode
    p.add_argument("--out", default="BENCH_fleet.json")
    args = p.parse_args()

    if args.weak_cell is not None:
        # child of the weak-scaling sweep: the parent pinned the device
        # count in our environment; print the cell JSON and nothing else
        print(json.dumps(_weak_cell(json.loads(args.weak_cell))))
        return

    # must land before the first (lazy) jax import anywhere below: expose
    # the cores as XLA devices so the fleet's stream axis has a mesh
    ensure_host_devices(args.devices)

    if args.smoke:
        defaults = dict(n_streams=4, n_windows=4, epochs=3,
                        records_per_window=120,
                        weak_streams=[8, 64, 1024], weak_devices=[1, 2])
    else:
        defaults = dict(n_streams=8, n_windows=8, epochs=10,
                        records_per_window=250)
    if args.streams is not None:
        defaults["n_streams"] = args.streams
    if args.windows is not None:
        defaults["n_windows"] = args.windows
    if args.epochs is not None:
        defaults["epochs"] = args.epochs
    if args.records is not None:
        defaults["records_per_window"] = args.records

    res = run(**defaults)
    print(report(res))
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
