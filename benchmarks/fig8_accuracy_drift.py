"""Paper Fig. 8 + Tables 4-6: RMSE of speed / batch / static(3:7, 5:5, 7:3)
/ dynamic hybrid inference under the three concept-drift scenarios, plus the
time-percentage-best tables and the dynamic-improvement percentages.
"""
from __future__ import annotations

from typing import Dict

import jax
import numpy as np

from repro.configs import get_config
from repro.core import (
    HybridStreamAnalytics,
    WindowedStream,
    WindowPlan,
    lstm_forecaster,
    make_supervised,
    pretrain_batch_model,
)
from repro.streams.normalize import MinMaxScaler
from repro.streams.sources import abrupt_drift, gradual_drift, wind_turbine_series

MODES = {
    "speed": "speed",
    "batch": "batch",
    "static_3_7": ("static", 0.3),
    "static_5_5": ("static", 0.5),
    "static_7_3": ("static", 0.7),
    "dynamic": "dynamic",
}


def make_scenarios(n_hist: int, n_stream: int, seed: int = 0):
    """no-drift / gradual / abrupt streams (paper Sec. 6.1.1) + history."""
    base = wind_turbine_series(n_hist + n_stream, seed=seed)
    hist = base[:n_hist]
    tail = base[n_hist:]
    return hist, {
        "no_drift": tail.copy(),
        # mild drifts: strong enough that batch degrades, mild enough that
        # combining batch + speed still helps (the paper's regime)
        "gradual": gradual_drift(tail, alphas=np.full(5, 6e-4), seed=seed + 1),
        "abrupt": abrupt_drift(tail, alphas=np.full(5, 1.2e-3), seed=seed + 2,
                               n_switches=4),
    }


def run(
    n_windows: int = 20,
    records_per_window: int = 250,
    batch_epochs: int = 25,
    speed_epochs: int = 40,
    n_hist: int = 4000,
    fast: bool = False,
) -> Dict[str, dict]:
    if fast:
        n_windows, batch_epochs, speed_epochs, n_hist = 6, 8, 12, 1500
    cfg = get_config("lstm-paper")
    n_stream = n_windows * records_per_window
    hist, scenarios = make_scenarios(n_hist, n_stream)
    scaler = MinMaxScaler.fit(hist)
    fc_batch = lstm_forecaster(cfg, epochs=batch_epochs, batch_size=512)
    fc_speed = lstm_forecaster(cfg, epochs=speed_epochs, batch_size=64)
    bp, _ = pretrain_batch_model(
        fc_batch, make_supervised(scaler.transform(hist), cfg.lstm.lag, 0),
        jax.random.PRNGKey(0),
    )

    out: Dict[str, dict] = {}
    for scen, stream in scenarios.items():
        plan = WindowPlan(n_windows=n_windows,
                          records_per_window=records_per_window,
                          lag=cfg.lstm.lag)
        ws = WindowedStream(scaler.transform(stream), plan)
        rows = {}
        for name, mode in MODES.items():
            h = HybridStreamAnalytics(fc_speed, mode=mode)
            res = h.run(ws, bp, jax.random.PRNGKey(1))
            m = res.mean_rmse()
            rows[name] = {
                "rmse_hybrid": m["hybrid"],
                "rmse_speed": m["speed"],
                "rmse_batch": m["batch"],
                "best_fraction": res.best_fraction(),
                "per_window_hybrid": [r.rmse_hybrid for r in res.records],
            }
        out[scen] = rows
    return out


def report(fast: bool = False) -> str:
    res = run(fast=fast)
    lines = ["# Fig. 8 analog: mean RMSE per inference approach per scenario"]
    hdr = f"{'scenario':<10}" + "".join(f"{m:>13}" for m in MODES)
    lines.append(hdr)
    for scen, rows in res.items():
        vals = []
        for name in MODES:
            r = rows[name]
            v = {"speed": r["rmse_speed"], "batch": r["rmse_batch"]}.get(
                name, r["rmse_hybrid"])
            vals.append(v)
        lines.append(f"{scen:<10}" + "".join(f"{v:>13.4f}" for v in vals))

    lines.append("\n# Tables 4-6 analog: fraction of windows each approach is best")
    for scen, rows in res.items():
        lines.append(f"  [{scen}]")
        for name in ("static_3_7", "static_5_5", "static_7_3", "dynamic"):
            bf = rows[name]["best_fraction"]
            lines.append(
                f"    {name:<12} speed={bf['speed']:.3f} "
                f"batch={bf['batch']:.3f} hybrid={bf['hybrid']:.3f}"
            )

    lines.append("\n# paper-claim checks")
    checks = {}
    for scen, rows in res.items():
        dyn = rows["dynamic"]["rmse_hybrid"]
        speed = rows["dynamic"]["rmse_speed"]
        batch = rows["dynamic"]["rmse_batch"]
        statics = [rows[k]["rmse_hybrid"] for k in
                   ("static_3_7", "static_5_5", "static_7_3")]
        checks[f"{scen}: dynamic is best hybrid"] = dyn <= min(statics) + 1e-9
        checks[f"{scen}: dynamic <= best constituent * 1.05"] = (
            dyn <= min(speed, batch) * 1.05)
        if scen != "no_drift":
            checks[f"{scen}: speed beats batch (drift adaptation)"] = speed < batch
        imp = (min(statics) - dyn) / min(statics) * 100
        checks[f"{scen}: dynamic improvement vs best static = {imp:.2f}%"] = True
    for k, v in checks.items():
        lines.append(f"  {k}: {'PASS' if v else 'FAIL'}")
    return "\n".join(lines)


if __name__ == "__main__":
    print(report())
