"""Chaos benchmark: per-scenario degradation envelopes, tracked as
``BENCH_chaos.json`` — graceful degradation as a measured, CI-gated
property.

Sections:

* ``parity`` — the fault-free chaos run (an *empty* ``FaultPlane`` attached,
  interposition active) against the plain no-plane path under identical
  deterministic stage costs: hybrid-RMSE delta must be <= 1e-6 and train
  dispatch counts identical, so the fault plane itself is proven to be a
  no-op when no faults fire.
* ``scenarios`` — every scenario in ``core.scenarios.SCENARIOS`` under one
  fixed seed: RMSE ratio vs fault-free, p99 answer latency, max served
  staleness, fallback fraction, fault/recovery counters, zero unhandled
  exceptions.  Scenario-specific gates: corrupted publishes detected 100%
  and never installed; partitioned sync keeps served staleness within the
  watchdog bound and hybrid RMSE <= 1.5x fault-free.
* ``determinism`` — the RNG-heaviest scenario (sensor_chaos) run twice under
  the same seed must produce byte-identical bus logs, ledgers, and
  forecasts; a different seed must produce a different fault schedule.

    PYTHONPATH=src python -m benchmarks.bench_chaos            # full
    PYTHONPATH=src python -m benchmarks.bench_chaos --smoke    # CI
"""
from __future__ import annotations

import argparse
import json
from typing import Dict

SEED = 0


def run(smoke: bool) -> Dict:
    from repro.core.scenarios import (
        RMSE_RATIO_MAX,
        SCENARIOS,
        ChaosHarness,
        bus_signature,
        forecast_signature,
        ledger_signature,
    )

    if smoke:
        h = ChaosHarness(n_streams=2, n_windows=4, records_per_window=80,
                         qps=6.0, verbose=True)
    else:
        h = ChaosHarness(n_streams=3, n_windows=6, records_per_window=120,
                         qps=8.0, verbose=True)

    out: Dict = {"config": {
        "smoke": smoke, "seed": SEED, "n_streams": h.n_streams,
        "n_windows": h.n_windows, "records_per_window": h.rpw,
        "period_s": h.period, "qps": h.qps,
        "staleness_bound": h.staleness_bound,
    }}

    # -- parity: empty fault plane == no fault plane -------------------------
    print("parity: plain (no plane) vs fault_free (empty plane) ...")
    plain = h.run_plain()
    env_ff, res_ff = h.run_scenario("fault_free", seed=SEED)
    assert res_ff is not None, env_ff
    rmse_plain = plain.mean_rmse()["hybrid"]
    out["parity"] = {
        "rmse_plain": rmse_plain,
        "rmse_fault_free": env_ff["rmse_hybrid"],
        "rmse_abs_delta": abs(rmse_plain - env_ff["rmse_hybrid"]),
        "train_dispatches_plain": plain.train_dispatches,
        "train_dispatches_fault_free": env_ff["train_dispatches"],
        "forecasts_identical": (forecast_signature(plain)
                                == forecast_signature(res_ff)),
    }

    # -- the scenario envelopes ----------------------------------------------
    base = env_ff["rmse_hybrid"]
    out["scenarios"] = {}
    for name in SCENARIOS:
        print(f"scenario: {name} ...")
        env, res = h.run_scenario(name, seed=SEED)
        env["rmse_ratio_vs_fault_free"] = (
            env.get("rmse_hybrid", float("inf")) / base if base else
            float("inf"))
        env["rmse_ratio_max"] = RMSE_RATIO_MAX[name]
        if name == "corrupted_int8_sync" and res is not None:
            stats = env["fault_stats"]
            env["corrupt_injected"] = stats.get("msg_corrupt", 0)
            env["corrupt_detected_frac"] = (
                env["corrupt_rejected"] / env["corrupt_injected"]
                if env["corrupt_injected"] else 1.0)
        out["scenarios"][name] = env

    # -- determinism: same seed -> byte-identical run ------------------------
    print("determinism: sensor_chaos x2 same seed, x1 different seed ...")
    _, r1 = h.run_scenario("sensor_chaos", seed=SEED)
    _, r2 = h.run_scenario("sensor_chaos", seed=SEED)
    _, r3 = h.run_scenario("sensor_chaos", seed=SEED + 7)
    out["determinism"] = {
        "bus_log_identical": bus_signature(r1) == bus_signature(r2),
        "ledger_identical": ledger_signature(r1) == ledger_signature(r2),
        "forecasts_identical": (forecast_signature(r1)
                                == forecast_signature(r2)),
        "different_seed_differs": bus_signature(r1) != bus_signature(r3),
    }
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer streams/windows)")
    ap.add_argument("--out", default="BENCH_chaos.json")
    args = ap.parse_args()

    res = run(args.smoke)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2, sort_keys=True)
    print(f"\nwrote {args.out}")

    p = res["parity"]
    print(f"parity: rmse delta {p['rmse_abs_delta']:.2e}, dispatches "
          f"{p['train_dispatches_fault_free']}=="
          f"{p['train_dispatches_plain']}, forecasts identical: "
          f"{p['forecasts_identical']}")
    for name, env in res["scenarios"].items():
        if env.get("unhandled_exception"):
            print(f"{name:>20}: EXCEPTION {env['unhandled_exception']}")
            continue
        print(f"{name:>20}: rmse x{env['rmse_ratio_vs_fault_free']:.3f} "
              f"(max {env['rmse_ratio_max']}), "
              f"p99 {env['p99_latency_s']*1e3:.1f}ms, "
              f"stale<= {env['max_staleness']}, "
              f"fallback {env['fallback_frac']:.2f}, "
              f"answered {env['n_answered']} (starved {env['n_starved']})")
    d = res["determinism"]
    print(f"determinism: bus {d['bus_log_identical']}, ledger "
          f"{d['ledger_identical']}, forecasts {d['forecasts_identical']}, "
          f"seed-sensitivity {d['different_seed_differs']}")


if __name__ == "__main__":
    main()
