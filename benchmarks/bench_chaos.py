"""Chaos benchmark: per-scenario degradation envelopes, tracked as
``BENCH_chaos.json`` — graceful degradation as a measured, CI-gated
property.

Sections:

* ``parity`` — the fault-free chaos run (an *empty* ``FaultPlane`` attached,
  interposition active) against the plain no-plane path under identical
  deterministic stage costs: hybrid-RMSE delta must be <= 1e-6 and train
  dispatch counts identical, so the fault plane itself is proven to be a
  no-op when no faults fire.
* ``scenarios`` — every scenario in ``core.scenarios.SCENARIOS`` under one
  fixed seed: RMSE ratio vs fault-free, p99 answer latency, max served
  staleness, fallback fraction, fault/recovery counters, zero unhandled
  exceptions.  Scenario-specific gates: corrupted publishes detected 100%
  and never installed; *forged* publishes (valid crc32, no valid HMAC)
  rejected 100% by the signed-sync verifier; partitioned sync keeps served
  staleness within the watchdog bound and hybrid RMSE <= 1.5x fault-free.
* ``health`` — the self-diagnosing health plane's own envelope: zero false
  positives on the fault-free run (no suspicions, no Byzantine flags, no
  signature rejections, no threshold adaptations), and partition/crash
  detection latency within 2 heartbeat intervals of fault onset.
* ``adaptive`` — the adaptive-threshold path against a static-threshold
  plane on the fault-free run: byte-identical bus log, ledger, and
  forecasts (adaptation must cost nothing when calm); a faulty run must
  record at least one threshold adaptation.
* ``determinism`` — EVERY scenario rerun under the same seed must produce
  byte-identical bus logs, ledgers, and forecasts; a different seed must
  produce a different fault schedule.

    PYTHONPATH=src python -m benchmarks.bench_chaos            # full
    PYTHONPATH=src python -m benchmarks.bench_chaos --smoke    # CI
"""
from __future__ import annotations

import argparse
import json
from typing import Dict

SEED = 0


def run(smoke: bool) -> Dict:
    from repro.core.scenarios import (
        RMSE_RATIO_MAX,
        SCENARIOS,
        ChaosHarness,
        bus_signature,
        forecast_signature,
        ledger_signature,
    )

    if smoke:
        h = ChaosHarness(n_streams=2, n_windows=4, records_per_window=80,
                         qps=6.0, verbose=True)
    else:
        h = ChaosHarness(n_streams=3, n_windows=6, records_per_window=120,
                         qps=8.0, verbose=True)

    out: Dict = {"config": {
        "smoke": smoke, "seed": SEED, "n_streams": h.n_streams,
        "n_windows": h.n_windows, "records_per_window": h.rpw,
        "period_s": h.period, "qps": h.qps,
        "staleness_bound": h.staleness_bound,
    }}

    def sigs(res):
        return (bus_signature(res), ledger_signature(res),
                forecast_signature(res))

    # -- parity: empty fault plane == no fault plane -------------------------
    print("parity: plain (no plane) vs fault_free (empty plane) ...")
    plain = h.run_plain()
    env_ff, res_ff = h.run_scenario("fault_free", seed=SEED)
    assert res_ff is not None, env_ff
    rmse_plain = plain.mean_rmse()["hybrid"]
    out["parity"] = {
        "rmse_plain": rmse_plain,
        "rmse_fault_free": env_ff["rmse_hybrid"],
        "rmse_abs_delta": abs(rmse_plain - env_ff["rmse_hybrid"]),
        "train_dispatches_plain": plain.train_dispatches,
        "train_dispatches_fault_free": env_ff["train_dispatches"],
        "forecasts_identical": (forecast_signature(plain)
                                == forecast_signature(res_ff)),
    }

    # -- the scenario envelopes ----------------------------------------------
    base = env_ff["rmse_hybrid"]
    out["scenarios"] = {}
    first_sigs: Dict[str, tuple] = {}
    for name in SCENARIOS:
        print(f"scenario: {name} ...")
        env, res = h.run_scenario(name, seed=SEED)
        env["rmse_ratio_vs_fault_free"] = (
            env.get("rmse_hybrid", float("inf")) / base if base else
            float("inf"))
        env["rmse_ratio_max"] = RMSE_RATIO_MAX[name]
        if name == "corrupted_int8_sync" and res is not None:
            stats = env["fault_stats"]
            env["corrupt_injected"] = stats.get("msg_corrupt", 0)
            env["corrupt_detected_frac"] = (
                env["corrupt_rejected"] / env["corrupt_injected"]
                if env["corrupt_injected"] else 1.0)
        if name == "forged_sync" and res is not None:
            stats = env["fault_stats"]
            env["forged_injected"] = stats.get("msg_forge", 0)
            env["forged_detected_frac"] = (
                env["forged_rejected"] / env["forged_injected"]
                if env["forged_injected"] else 1.0)
        if res is not None:
            first_sigs[name] = sigs(res)
        out["scenarios"][name] = env

    # -- health plane: false-positive floor + detection latency --------------
    hff = out["scenarios"]["fault_free"].get("health", {})
    out["health"] = {
        "fault_free_suspicions": hff.get("n_suspected", -1),
        "fault_free_byz_flagged": hff.get("byz_flagged", -1),
        "fault_free_forged_rejected": out["scenarios"]["fault_free"].get(
            "forged_rejected", -1),
        "fault_free_threshold_adaptations": hff.get(
            "threshold_adaptations", -1),
        "detection": {},
    }
    for name in ("partitioned_sync", "site_crash"):
        hs = out["scenarios"][name].get("health", {})
        out["health"]["detection"][name] = {
            "latency_s": hs.get("detection_latency_s"),
            "latency_hb_intervals": hs.get("detection_latency_hb_intervals"),
            "n_recovered": hs.get("n_recovered", 0),
        }

    # -- adaptive thresholds: free when calm, engaged under faults -----------
    print("adaptive: fault_free under static thresholds ...")
    _, r_static = h.run_scenario("fault_free", seed=SEED, adaptive=False)
    st = sigs(r_static)
    out["adaptive"] = {
        "calm_bus_identical": first_sigs["fault_free"][0] == st[0],
        "calm_ledger_identical": first_sigs["fault_free"][1] == st[1],
        "calm_forecasts_identical": first_sigs["fault_free"][2] == st[2],
        "faulty_threshold_adaptations": out["scenarios"][
            "partitioned_sync"].get("health", {}).get(
                "threshold_adaptations", 0),
    }

    # -- determinism: same seed -> byte-identical, every scenario ------------
    out["determinism"] = {"per_scenario": {}}
    for name in SCENARIOS:
        print(f"determinism: {name} rerun ...")
        _, r2 = h.run_scenario(name, seed=SEED)
        s1, s2 = first_sigs[name], sigs(r2)
        out["determinism"]["per_scenario"][name] = {
            "bus_log_identical": s1[0] == s2[0],
            "ledger_identical": s1[1] == s2[1],
            "forecasts_identical": s1[2] == s2[2],
        }
    print("determinism: sensor_chaos under a different seed ...")
    _, r3 = h.run_scenario("sensor_chaos", seed=SEED + 7)
    out["determinism"]["different_seed_differs"] = (
        first_sigs["sensor_chaos"][0] != bus_signature(r3))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer streams/windows)")
    ap.add_argument("--out", default="BENCH_chaos.json")
    args = ap.parse_args()

    res = run(args.smoke)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2, sort_keys=True)
    print(f"\nwrote {args.out}")

    p = res["parity"]
    print(f"parity: rmse delta {p['rmse_abs_delta']:.2e}, dispatches "
          f"{p['train_dispatches_fault_free']}=="
          f"{p['train_dispatches_plain']}, forecasts identical: "
          f"{p['forecasts_identical']}")
    for name, env in res["scenarios"].items():
        if env.get("unhandled_exception"):
            print(f"{name:>20}: EXCEPTION {env['unhandled_exception']}")
            continue
        print(f"{name:>20}: rmse x{env['rmse_ratio_vs_fault_free']:.3f} "
              f"(max {env['rmse_ratio_max']}), "
              f"p99 {env['p99_latency_s']*1e3:.1f}ms, "
              f"stale<= {env['max_staleness']}, "
              f"fallback {env['fallback_frac']:.2f}, "
              f"answered {env['n_answered']} (starved {env['n_starved']})")
    h = res["health"]
    print(f"health: fault-free FPs {h['fault_free_suspicions']} suspicions/"
          f"{h['fault_free_byz_flagged']} byz flags/"
          f"{h['fault_free_forged_rejected']} sig rejects, "
          + ", ".join(
              f"{n} detected in {det['latency_hb_intervals']:.2f} hb "
              f"intervals" for n, det in h["detection"].items()
              if det["latency_hb_intervals"] is not None))
    fg = res["scenarios"]["forged_sync"]
    print(f"forged sync: {fg['forged_rejected']}/{fg['forged_injected']} "
          f"rejected by HMAC (checksum alone accepted all of them)")
    a = res["adaptive"]
    print(f"adaptive: calm run identical to static thresholds "
          f"{a['calm_bus_identical'] and a['calm_ledger_identical'] and a['calm_forecasts_identical']}, "
          f"{a['faulty_threshold_adaptations']} adaptation(s) under the "
          f"partition")
    d = res["determinism"]
    ok = all(all(s.values()) for s in d["per_scenario"].values())
    print(f"determinism: all {len(d['per_scenario'])} scenarios rerun "
          f"byte-identical: {ok}, seed-sensitivity "
          f"{d['different_seed_differs']}")


if __name__ == "__main__":
    main()
