"""Measure the real wall-times of the paper's modules on this container
(LSTM batch/speed inference, speed training, DWA solve) to calibrate the
edge-cloud runtime's CostModel.

The paper's absolute Table-3 numbers come from a Pi 4 + TFLite + Kafka + AWS
stack; we report OUR measured computation plus the modeled communication and
validate the paper's *orderings and ratios*, not its absolute seconds.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import numpy as np

from repro.configs import get_config
from repro.core import lstm_forecaster, make_supervised
from repro.core.weighting import dwa_scipy
from repro.runtime.latency import CostModel
from repro.streams.sources import wind_turbine_series


@dataclass
class Calibration:
    cost: CostModel
    details: dict


def calibrate(records_per_window: int = 250, speed_epochs: int = 100,
              fast: bool = False) -> Calibration:
    cfg = get_config("lstm-paper")
    if fast:
        speed_epochs = 10
    series = wind_turbine_series(records_per_window * 4, seed=0)
    data = make_supervised(series[: records_per_window + 5], 5, 0)

    fc = lstm_forecaster(cfg, epochs=speed_epochs, batch_size=64)
    key = jax.random.PRNGKey(0)
    params, t_train = fc.train(data, None, key)
    # re-measure training post-jit-warmup (the paper's steady-state windows)
    _, t_train = fc.train(data, None, key)

    x = data["x"]
    fc.predict(params, x)  # warmup
    t0 = time.perf_counter()
    for _ in range(5):
        preds = fc.predict(params, x)
    t_infer = (time.perf_counter() - t0) / 5

    y = data["y"]
    t0 = time.perf_counter()
    for _ in range(5):
        dwa_scipy([preds, preds * 0.9], y)
    t_dwa = (time.perf_counter() - t0) / 5

    # paper's Kafka injection: ~7 records/s for >=200-record windows; the
    # effective pipelined ingest overhead charged to communication
    ingest_s = records_per_window / 7.0 * 0.45

    cost = CostModel(
        batch_infer_s=t_infer,
        speed_infer_s=t_infer * 1.05,  # includes model (re)load from disk
        hybrid_combine_s=t_infer * 0.1,
        weight_solve_s=t_dwa,
        speed_train_s=t_train,
        ingest_s=ingest_s,
        model_nbytes=44_000.0,
        window_nbytes=records_per_window * 5 * 4,
        result_nbytes=records_per_window * 4,
    )
    return Calibration(cost=cost, details={
        "t_train_s": t_train, "t_infer_s": t_infer, "t_dwa_s": t_dwa,
        "speed_epochs": speed_epochs,
    })
