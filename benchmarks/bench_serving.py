"""Request-plane serving benchmark: continuous-batched user queries answered
from the device-resident fleet state, tracked as ``BENCH_serving.json`` from
this PR onward — the repo's first latency-under-load numbers.

Two sections:

* ``parity`` — correctness of the batched tick path: a handcrafted query mix
  (point / multi-step horizon / perturbed what-if, including several queries
  of the *same* stream sharing a tick, submitted in staggered waves so slots
  recycle mid-run) driven through ``QueryPlane`` + ``ServingStage`` against
  frozen fleet params, compared per answer against the unbatched reference
  (``answer_query_unbatched``: a batch-of-one ``CompiledForecaster.predict``
  per horizon step).  CI gates max |diff| <= 1e-6 (vmap batching tolerance,
  the same bound ``bench_fleet`` holds per-stream predictions to) and
  exactly one vmapped dispatch per serving tick.

* ``open_loop`` — the measured plane: a deterministic open-loop arrival
  trace (uniform 1/qps spacing, seeded kind mix) replayed through a full
  ``FleetBusExecutor`` run on the edge-cloud-integrated deployment, serving
  ticks interleaved with the training windows under the serving site's
  worker occupancy.  Reports p50/p99/mean request latency, offered vs
  sustained QPS, dispatches/tick, starved-request count, and the staleness
  of the models that answered (how many windows each answer's serving model
  trailed its context).  CI gates: no starved requests, sustained >= offered
  at the smoke rate, finite p99, dispatches/tick == 1.

    PYTHONPATH=src python -m benchmarks.bench_serving            # full
    PYTHONPATH=src python -m benchmarks.bench_serving --smoke    # CI
"""
from __future__ import annotations

import argparse
import json
from typing import Dict


def _bench_parity(n_streams: int, records_per_window: int, epochs: int,
                  n_slots: int) -> Dict:
    """Batched-vs-unbatched answer parity on frozen fleet params."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core import lstm_fleet_forecaster
    from repro.core.stages import ServingStage
    from repro.runtime import fleet_key_chains
    from repro.serving.query_plane import (
        ForecastQuery,
        QueryPlane,
        answer_query_unbatched,
    )
    from repro.streams.sources import fleet_windowed_streams

    cfg = get_config("lstm-paper")
    streams, _ = fleet_windowed_streams(n_streams, 2, records_per_window,
                                        "gradual")
    ids = list(streams)
    keys = fleet_key_chains(jax.random.PRNGKey(2), ids, 1)
    ff = lstm_fleet_forecaster(cfg, epochs=epochs, batch_size=64)
    params, _ = ff.train_fleet(
        [streams[sid].supervised(0) for sid in ids],
        [keys[sid][0] for sid in ids])
    base_ctx = {sid: np.asarray(streams[sid].supervised(0)["x"])[-1]
                for sid in ids}

    # the query mix: same-stream multiples sharing a tick, horizons that
    # hold a slot for several ticks, perturbed what-ifs — submitted in two
    # waves so the second wave admits into recycled slots mid-run
    specs = [(0, "point", 1, 1.0, 0.0), (0, "horizon", 3, 1.0, 0.0),
             (1, "whatif", 1, 1.1, 0.05), (2, "point", 1, 1.0, 0.0),
             (1, "horizon", 2, 1.0, 0.0), (0, "whatif", 1, 0.9, -0.02),
             (2, "horizon", 3, 1.0, 0.0), (0, "point", 1, 1.0, 0.0),
             (1, "point", 1, 1.0, 0.0), (2, "whatif", 1, 1.05, 0.01)]
    queries = [ForecastQuery(uid=i, stream=ids[s % len(ids)], kind=k,
                             horizon=h, perturb_scale=sc, perturb_offset=of)
               for i, (s, k, h, sc, of) in enumerate(specs)]

    plane = QueryPlane(ids, n_slots)
    for sid in ids:
        plane.observe_window(sid, streams[sid].supervised(0)["x"], 0)
    wave2 = queries[6:]
    for q in queries[:6]:
        plane.submit(q)

    stage = ServingStage(ff)
    model_windows = {sid: 0 for sid in ids}
    tick = 0
    while plane.busy:
        plane.admit(float(tick))
        batch = plane.build_batch()
        if batch is None:
            break
        by_stream, xs = batch
        out = stage(params_seq=params, xs=xs)
        plane.apply(by_stream, out["preds"], model_windows)
        plane.retire(float(tick))
        tick += 1
        if tick == 2 and wave2:
            for q in wave2:
                plane.submit(q)
            wave2 = []

    max_diff = 0.0
    for q in queries:
        ref = answer_query_unbatched(ff.single.predict,
                                     params[ids.index(q.stream)], q,
                                     base_ctx[q.stream])
        assert len(q.answer) == q.horizon, \
            f"query {q.uid} got {len(q.answer)}/{q.horizon} answers"
        max_diff = max(max_diff, max(abs(a - b)
                                     for a, b in zip(q.answer, ref)))
    return {
        "max_abs_diff": max_diff,
        "n_queries": len(queries),
        "ticks": stage.ticks,
        "dispatches": stage.dispatches,
        "dispatches_per_tick": stage.dispatches / max(stage.ticks, 1),
        "n_slots": n_slots,
        "n_streams": len(ids),
    }


def _bench_open_loop(n_streams: int, n_windows: int,
                     records_per_window: int, qps: float, n_slots: int,
                     period_s: float, fast: bool) -> Dict:
    """Open-loop load through a full fleet-executor run: the headline
    latency/QPS numbers."""
    import jax

    from repro.launch.edge_cloud import build_fleet_pipeline
    from repro.runtime import FleetBusExecutor, paper_topology
    from repro.runtime.deployment import edge_cloud_integrated

    stages, bp, streams, cost = build_fleet_pipeline(
        n_streams, n_windows, fast=fast,
        records_per_window=records_per_window)
    ex = FleetBusExecutor(stages, edge_cloud_integrated(), paper_topology(),
                          cost, window_period_s=period_s, qps=qps,
                          serve_slots=n_slots)
    res = ex.run(streams, bp, jax.random.PRNGKey(1))
    answered = [q for q in res.queries if q.finished_at is not None]
    staleness = [q.context_window - q.model_window for q in answered
                 if q.model_window >= 0]
    out = dict(res.serving)
    out.update({
        "deployment": "edge-cloud-integrated",
        "n_streams": n_streams,
        "n_windows": n_windows,
        "window_period_s": period_s,
        "max_staleness_windows": max(staleness) if staleness else 0,
        "mean_staleness_windows": (sum(staleness) / len(staleness)
                                   if staleness else 0.0),
    })
    return out


def run(n_streams: int = 4, n_windows: int = 4,
        records_per_window: int = 120, epochs: int = 3, qps: float = 20.0,
        n_slots: int = 4, period_s: float = 5.0, fast: bool = True) -> Dict:
    return {
        "benchmark": "serving_request_plane",
        "config": {
            "model": "lstm-paper",
            "n_streams": n_streams,
            "n_windows": n_windows,
            "records_per_window": records_per_window,
            "epochs": epochs,
            "qps": qps,
            "n_slots": n_slots,
            "window_period_s": period_s,
        },
        "parity": _bench_parity(n_streams, records_per_window, epochs,
                                n_slots),
        "open_loop": _bench_open_loop(n_streams, n_windows,
                                      records_per_window, qps, n_slots,
                                      period_s, fast),
    }


def report(res: Dict) -> str:
    p, o = res["parity"], res["open_loop"]
    return "\n".join([
        f"# request plane: {o['n_streams']} streams, {o['slots']} slots, "
        f"{o['deployment']}",
        "",
        "# parity (batched ticks vs unbatched per-query reference)",
        f"{p['n_queries']} queries over {p['ticks']} ticks "
        f"({p['dispatches_per_tick']:.2f} dispatches/tick): "
        f"max |diff| = {p['max_abs_diff']:.2e}",
        "",
        f"# open loop ({o['n_requests']} requests at "
        f"{o['offered_qps']:.1f} qps offered)",
        f"answered {o['n_answered']}/{o['n_requests']} "
        f"({o['n_starved']} starved) over {o['ticks']} serving ticks, "
        f"{o['dispatches_per_tick']:.2f} dispatches/tick",
        f"sustained {o['sustained_qps']:.1f} qps  "
        f"p50 {o['p50_s']*1e3:.2f}ms  p99 {o['p99_s']*1e3:.2f}ms  "
        f"mean {o['mean_s']*1e3:.2f}ms  max {o['max_s']*1e3:.2f}ms",
        f"model staleness: max {o['max_staleness_windows']} windows, "
        f"mean {o['mean_staleness_windows']:.2f}",
    ])


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="CI-sized run: 3 streams, 3 windows, 20 qps")
    p.add_argument("--streams", type=int, default=None)
    p.add_argument("--windows", type=int, default=None)
    p.add_argument("--qps", type=float, default=None)
    p.add_argument("--slots", type=int, default=None)
    p.add_argument("--devices", type=int, default=None,
                   help="host devices to expose to XLA (default: the "
                        "machine's core count); the serving fleet's stream "
                        "axis shards across them")
    p.add_argument("--out", default="BENCH_serving.json")
    args = p.parse_args()

    # before the first lazy jax import below: give the fleet a mesh
    from benchmarks._device_env import ensure_host_devices
    ensure_host_devices(args.devices)

    if args.smoke:
        defaults = dict(n_streams=3, n_windows=3, records_per_window=120,
                        epochs=3, qps=20.0, n_slots=4, period_s=5.0,
                        fast=True)
    else:
        defaults = dict(n_streams=6, n_windows=5, records_per_window=250,
                        epochs=10, qps=50.0, n_slots=8, period_s=10.0,
                        fast=True)
    if args.streams is not None:
        defaults["n_streams"] = args.streams
    if args.windows is not None:
        defaults["n_windows"] = args.windows
    if args.qps is not None:
        defaults["qps"] = args.qps
    if args.slots is not None:
        defaults["n_slots"] = args.slots

    res = run(**defaults)
    print(report(res))
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
