"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run           # fast mode (default)
    PYTHONPATH=src python -m benchmarks.run --full    # paper-scale settings

Emits each report plus a ``name,us_per_call,derived`` CSV summary line per
benchmark (us_per_call = the benchmark's primary latency; derived = its
primary derived metric).
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true",
                   help="paper-scale settings (slow)")
    p.add_argument("--only", default=None,
                   help="comma list: table3,fig7,fig8,roofline")
    args = p.parse_args()
    fast = not args.full
    only = set(args.only.split(",")) if args.only else None
    csv_rows = [("name", "us_per_call", "derived")]

    def want(name):
        return only is None or name in only

    if want("table3"):
        from benchmarks import table3_deployment_latency as t3

        t0 = time.perf_counter()
        rep = t3.report(fast=fast)
        print("=" * 72)
        print(rep)
        res = t3.run(fast=fast)
        integ = res["edge-cloud-integrated"]["rows"]["hybrid_inference"]
        csv_rows.append(("table3_deployment_latency",
                         f"{integ.get('total', 0) * 1e6:.0f}",
                         f"oom_edge={res['edge-centric']['oom']}"))
        print(f"[table3 took {time.perf_counter()-t0:.1f}s]")

    if want("fig7"):
        from benchmarks import fig7_weighting_latency as f7

        t0 = time.perf_counter()
        rep = f7.report(fast=fast)
        print("=" * 72)
        print(rep)
        res = f7.run(fast=fast)
        dyn = res["dynamic_scipy"]["hybrid_infer"]
        sta = res["static"]["hybrid_infer"]

        def tot(m):
            return (res[m]["speed_infer"] + res[m]["batch_infer"]
                    + res[m]["hybrid_infer"])

        pct = (tot("dynamic_scipy") - tot("static")) / max(tot("static"),
                                                           1e-12) * 100
        csv_rows.append(("fig7_weighting_latency", f"{dyn * 1e6:.0f}",
                         f"dyn_overhead_of_total_pct={pct:.1f}"))
        print(f"[fig7 took {time.perf_counter()-t0:.1f}s]")

    if want("fig8"):
        from benchmarks import fig8_accuracy_drift as f8

        t0 = time.perf_counter()
        rep = f8.report(fast=fast)
        print("=" * 72)
        print(rep)
        res = f8.run(fast=fast)
        dyn = res["gradual"]["dynamic"]["rmse_hybrid"]
        csv_rows.append(("fig8_accuracy_drift", "0",
                         f"gradual_dynamic_rmse={dyn:.4f}"))
        print(f"[fig8 took {time.perf_counter()-t0:.1f}s]")

    if want("ablation") and only is not None:
        # beyond-paper; only when explicitly requested (slow)
        from benchmarks import ablation_window as ab

        t0 = time.perf_counter()
        print("=" * 72)
        print(ab.report(fast=fast))
        csv_rows.append(("ablation_window", "0", "see report"))
        print(f"[ablation took {time.perf_counter()-t0:.1f}s]")

    if want("roofline"):
        from benchmarks import roofline_report as rr

        t0 = time.perf_counter()
        print("=" * 72)
        try:
            print(rr.report())
            print()
            print(rr.report(mesh="2x16x16"))
            print()
            try:
                print(rr.perf_report())
            except Exception as e:  # noqa: BLE001
                print("(no §Perf artifacts:", e, ")")
            rows = [rr.recompute(r) for r in rr.load()
                    if r["status"] == "ok" and r["mesh"] == "16x16"]
            n_fit = sum(r["fits_hbm"] for r in rows)
            csv_rows.append(("roofline", "0",
                             f"n_ok={len(rows)};fits_hbm={n_fit}"))
        except FileNotFoundError:
            print("no dry-run artifacts; run: python -m repro.launch.dryrun --all")
        print(f"[roofline took {time.perf_counter()-t0:.1f}s]")

    print("=" * 72)
    for row in csv_rows:
        print(",".join(str(x) for x in row))


if __name__ == "__main__":
    main()
