"""Paper Table 3: inference-phase latency (computation/communication/total)
for batch / speed / hybrid inference under the three deployment modalities,
plus the training-phase latency and the edge-centric OOM reproduction.
"""
from __future__ import annotations

from typing import Dict

from benchmarks.calibrate import Calibration, calibrate
from repro.runtime import (
    EdgeCloudSimulation,
    cloud_centric,
    edge_centric,
    edge_cloud_integrated,
    paper_topology,
)

ROWS = ("speed_inference", "batch_inference", "hybrid_inference")


def run(cal: Calibration | None = None, n_windows: int = 25,
        fast: bool = False) -> Dict[str, dict]:
    cal = cal or calibrate(fast=fast)
    topo = paper_topology()
    out = {}
    for factory in (cloud_centric, edge_centric, edge_cloud_integrated):
        dep = factory()
        sim = EdgeCloudSimulation(dep, topo, cal.cost, dynamic_weighting=True)
        res = sim.run(n_windows)
        t = res.table3()
        out[dep.name] = {
            "rows": {m: t.get(m, {}) for m in ROWS},
            "training": t.get("speed_training", {}),
            "model_sync_comm": t.get("model_sync", {}).get("communication", 0.0),
            "failures": len(res.failures),
            "oom": bool(res.failures),
        }
    return out


def report(fast: bool = False) -> str:
    res = run(fast=fast)
    lines = ["# Table 3 analog: inference-phase latency per deployment (s)"]
    lines.append(f"{'deployment':<24}{'module':<18}{'comp':>8}{'comm':>8}{'total':>8}")
    for dep, r in res.items():
        for m in ROWS:
            row = r["rows"][m]
            lines.append(
                f"{dep:<24}{m:<18}{row.get('computation', 0):>8.2f}"
                f"{row.get('communication', 0):>8.2f}{row.get('total', 0):>8.2f}"
            )
        tr = r["training"]
        if r["oom"]:
            lines.append(f"{dep:<24}{'speed_training':<18}{'OOM (edge capacity exceeded)':>24}")
        else:
            lines.append(
                f"{dep:<24}{'speed_training':<18}{tr.get('computation', 0):>8.2f}"
                f"{tr.get('communication', 0) + r['model_sync_comm']:>8.2f}"
                f"{tr.get('total', 0) + r['model_sync_comm']:>8.2f}"
            )
    # paper-claim checks
    tot = {d: sum(r["rows"][m].get("total", 0) for m in ROWS)
           for d, r in res.items()}
    checks = {
        "cloud_comm>edge_comm (inference)": (
            res["cloud-centric"]["rows"]["batch_inference"]["communication"]
            > res["edge-cloud-integrated"]["rows"]["batch_inference"]["communication"]
        ),
        "edge_centric_training_OOM": res["edge-centric"]["oom"],
        "integrated_beats_edge_centric_total": (
            tot["edge-cloud-integrated"] < tot["edge-centric"]
        ),
        "integrated_trains_without_capacity_limits": (
            not res["edge-cloud-integrated"]["oom"]
        ),
    }
    lines.append("\n# paper-claim checks")
    for k, v in checks.items():
        lines.append(f"  {k}: {'PASS' if v else 'FAIL'}")
    return "\n".join(lines)


if __name__ == "__main__":
    print(report())
