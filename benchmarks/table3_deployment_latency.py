"""Paper Table 3: inference-phase latency (computation/communication/total)
for batch / speed / hybrid inference under the three deployment modalities,
plus the training-phase latency and the edge-centric OOM reproduction.

Two ways to produce the numbers:

* calibrated — the discrete-event simulation replays ``CostModel`` constants
  measured once by ``benchmarks.calibrate`` (the original path);
* measured — the ``BusExecutor`` schedules the real pipeline stages on the
  TopicBus and accounts each stage's actual wall-clock, rescaled by site
  ``compute_scale`` (plus site-occupancy queueing the calibrated path cannot
  see).

``report(measured=True)`` prints both side by side; they should agree on the
paper's *orderings* (that is the point of calibration) while the measured
column is the ground truth for this container.
"""
from __future__ import annotations

from typing import Dict

from benchmarks.calibrate import Calibration, calibrate
from repro.runtime import (
    ALL_DEPLOYMENTS,
    EdgeCloudSimulation,
    cloud_centric,
    edge_centric,
    edge_cloud_integrated,
    paper_topology,
)

ROWS = ("speed_inference", "batch_inference", "hybrid_inference")


def _summarize(table, failures, e2e=None) -> dict:
    return {
        "rows": {m: table.get(m, {}) for m in ROWS},
        "training": table.get("speed_training", {}),
        "model_sync_comm": table.get("model_sync", {}).get("communication", 0.0),
        "failures": len(failures),
        "oom": bool(failures),
        "e2e_s": e2e,
    }


def run(cal: Calibration | None = None, n_windows: int = 25,
        fast: bool = False) -> Dict[str, dict]:
    """Calibrated simulation (CostModel replay)."""
    cal = cal or calibrate(fast=fast)
    topo = paper_topology()
    out = {}
    for factory in (cloud_centric, edge_centric, edge_cloud_integrated):
        dep = factory()
        sim = EdgeCloudSimulation(dep, topo, cal.cost, dynamic_weighting=True)
        res = sim.run(n_windows)
        out[dep.name] = _summarize(res.table3(), res.failures)
    return out


def run_measured(n_windows: int = 5, fast: bool = True) -> Dict[str, dict]:
    """Real LSTM compute scheduled on the TopicBus by the BusExecutor.
    Experiment definition is shared with the launcher's ``--real`` mode
    (``repro.launch.edge_cloud.build_real_pipeline``)."""
    import jax

    from repro.launch.edge_cloud import build_real_pipeline
    from repro.runtime import BusExecutor

    stages, bp, stream, cost = build_real_pipeline(n_windows, fast=fast)

    out = {}
    for name in ("cloud-centric", "edge-centric", "edge-cloud-integrated"):
        ex = BusExecutor(stages, ALL_DEPLOYMENTS[name](), paper_topology(),
                         cost)
        res = ex.run(stream, bp, jax.random.PRNGKey(1))
        out[name] = _summarize(res.table3(), res.failures,
                               e2e=res.mean_e2e_s())
    return out


def _claim_checks(res: Dict[str, dict]) -> Dict[str, bool]:
    tot = {d: sum(r["rows"][m].get("total", 0) for m in ROWS)
           for d, r in res.items()}
    checks = {
        "cloud_comm>edge_comm (inference)": (
            res["cloud-centric"]["rows"]["batch_inference"]["communication"]
            > res["edge-cloud-integrated"]["rows"]["batch_inference"]["communication"]
        ),
        "edge_centric_training_OOM": res["edge-centric"]["oom"],
        "integrated_beats_edge_centric_total": (
            tot["edge-cloud-integrated"] < tot["edge-centric"]
        ),
        "integrated_trains_without_capacity_limits": (
            not res["edge-cloud-integrated"]["oom"]
        ),
    }
    e2e = {d: r.get("e2e_s") for d, r in res.items()}
    if all(v is not None for v in e2e.values()):
        checks["e2e: integrated < cloud < edge"] = (
            e2e["edge-cloud-integrated"] < e2e["cloud-centric"]
            < e2e["edge-centric"]
        )
    return checks


def _render(res: Dict[str, dict], title: str) -> list:
    lines = [f"# Table 3 analog ({title}): inference-phase latency per deployment (s)"]
    lines.append(f"{'deployment':<24}{'module':<18}{'comp':>8}{'comm':>8}{'total':>8}")
    for dep, r in res.items():
        for m in ROWS:
            row = r["rows"][m]
            lines.append(
                f"{dep:<24}{m:<18}{row.get('computation', 0):>8.2f}"
                f"{row.get('communication', 0):>8.2f}{row.get('total', 0):>8.2f}"
            )
        tr = r["training"]
        if r["oom"]:
            lines.append(f"{dep:<24}{'speed_training':<18}{'OOM (edge capacity exceeded)':>24}")
        else:
            lines.append(
                f"{dep:<24}{'speed_training':<18}{tr.get('computation', 0):>8.2f}"
                f"{tr.get('communication', 0) + r['model_sync_comm']:>8.2f}"
                f"{tr.get('total', 0) + r['model_sync_comm']:>8.2f}"
            )
        if r.get("e2e_s") is not None:
            lines.append(f"{dep:<24}{'e2e window':<18}{r['e2e_s']:>24.3f}")
    lines.append(f"\n# paper-claim checks ({title})")
    for k, v in _claim_checks(res).items():
        lines.append(f"  {k}: {'PASS' if v else 'FAIL'}")
    return lines


def report(fast: bool = False, measured: bool = False,
           n_windows_measured: int = 5) -> str:
    lines = _render(run(fast=fast), "calibrated")
    if measured:
        lines.append("")
        lines.extend(_render(run_measured(n_windows=n_windows_measured,
                                          fast=fast), "measured"))
        lines.append("\n(calibrated replays CostModel constants; measured is "
                     "real stage wall-clock on the bus — compare orderings, "
                     "not absolute seconds)")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys

    print(report(fast="--fast" in sys.argv, measured="--measured" in sys.argv))
